"""L1 correctness: Pallas kernels vs. pure-jnp oracles.

hypothesis sweeps shapes (including non-tile-aligned, degenerate, and
MXU-boundary cases) and value distributions; assert_allclose against ref.py.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_ws, bias_act, maxpool2x2, MXU_TILE
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------- matmul_ws

@settings(**SETTINGS)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**32 - 1),
)
def test_matmul_ws_small_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    out = matmul_ws(jnp.asarray(x), jnp.asarray(w), bm=16, bn=16, bk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(x, w)), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (MXU_TILE, MXU_TILE, MXU_TILE),          # exactly one MXU tile
        (MXU_TILE + 1, MXU_TILE - 1, MXU_TILE),  # off-by-one around the tile
        (1, 1, 1),                               # degenerate
        (257, 130, 127),                         # multi-tile, ragged
        (3, 500, 2),                             # deep K accumulation
    ],
)
def test_matmul_ws_tile_boundaries(m, k, n):
    rng = np.random.default_rng(m * 7919 + k * 31 + n)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    out = matmul_ws(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), x.astype(np.float64) @ w, rtol=1e-4, atol=1e-4)


def test_matmul_ws_zero_padding_exact():
    # Padding must contribute exactly zero: an all-ones input keeps exact sums.
    x = np.ones((100, 37), np.float32)
    w = np.ones((37, 99), np.float32)
    out = np.asarray(matmul_ws(jnp.asarray(x), jnp.asarray(w)))
    assert (out == 37.0).all()


def test_matmul_ws_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul_ws(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        matmul_ws(jnp.zeros((2, 3, 4)), jnp.zeros((4, 5)))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**32 - 1))
def test_matmul_ws_fp16_range_weights(seed):
    # The paper's regime: weights clipped into [-1, 1] and representable in
    # binary16. The kernel must be exact for these too.
    rng = np.random.default_rng(seed)
    x = _rand(rng, 33, 65)
    w = np.clip(_rand(rng, 65, 17), -1, 1).astype(np.float16).astype(np.float32)
    out = matmul_ws(jnp.asarray(x), jnp.asarray(w), bm=32, bn=32, bk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(x, w)), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- bias_act

@settings(**SETTINGS)
@given(
    r=st.integers(1, 300),
    c=st.integers(1, 48),
    act=st.sampled_from(["relu", "linear"]),
    seed=st.integers(0, 2**32 - 1),
)
def test_bias_act_matches_ref(r, c, act, seed):
    rng = np.random.default_rng(seed)
    x, b = _rand(rng, r, c), _rand(rng, c)
    out = bias_act(jnp.asarray(x), jnp.asarray(b), act=act, block_rows=64)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.bias_act_ref(x, b, act)))


def test_bias_act_rejects_unknown_activation():
    with pytest.raises(ValueError):
        bias_act(jnp.zeros((2, 2)), jnp.zeros((2,)), act="gelu")


# ---------------------------------------------------------------- maxpool

@settings(**SETTINGS)
@given(
    n=st.integers(1, 9),
    hw=st.sampled_from([2, 4, 8, 16, 32]),
    c=st.integers(1, 16),
    seed=st.integers(0, 2**32 - 1),
)
def test_maxpool_matches_ref(n, hw, c, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, n, hw, hw, c)
    out = maxpool2x2(jnp.asarray(x), block_rows=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.maxpool2x2_ref(x)))


def test_maxpool_rejects_odd_spatial():
    with pytest.raises(ValueError):
        maxpool2x2(jnp.zeros((1, 3, 4, 1)))
