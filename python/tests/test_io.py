"""Round-trip tests for the binary interchange formats (Rust parses these)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import io as io_mod


@settings(max_examples=20, deadline=None)
@given(
    count=st.integers(1, 6),
    seed=st.integers(0, 2**32 - 1),
)
def test_weights_roundtrip(tmp_path_factory, count, seed):
    tmp = tmp_path_factory.mktemp("w")
    rng = np.random.default_rng(seed)
    params = []
    for i in range(count):
        ndim = int(rng.integers(1, 5))
        shape = tuple(int(rng.integers(1, 6)) for _ in range(ndim))
        params.append((f"layer{i}.w", rng.standard_normal(shape).astype(np.float32)))
    path = str(tmp / "w.bin")
    io_mod.write_weights(path, params)
    back = io_mod.read_weights(path)
    assert [n for n, _ in back] == [n for n, _ in params]
    for (_, a), (_, b) in zip(params, back):
        assert a.shape == b.shape
        np.testing.assert_array_equal(a, b)


def test_weights_unicode_names(tmp_path):
    params = [("conv0.w/µ", np.ones((2, 2), np.float32))]
    path = str(tmp_path / "w.bin")
    io_mod.write_weights(path, params)
    assert io_mod.read_weights(path)[0][0] == "conv0.w/µ"


def test_weights_bad_magic(tmp_path):
    path = str(tmp_path / "bad.bin")
    with open(path, "wb") as f:
        f.write(b"XXXX" + b"\x00" * 16)
    with pytest.raises(AssertionError):
        io_mod.read_weights(path)


def test_testset_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    imgs = rng.standard_normal((5, 8, 8, 3)).astype(np.float32)
    labels = rng.integers(0, 10, 5).astype(np.int32)
    path = str(tmp_path / "t.bin")
    io_mod.write_testset(path, imgs, labels)
    i2, l2 = io_mod.read_testset(path)
    np.testing.assert_array_equal(imgs, i2)
    np.testing.assert_array_equal(labels, l2)


def test_dataset_determinism():
    from compile import data
    a_img, a_lab = data.make_split(64, seed=5)
    b_img, b_lab = data.make_split(64, seed=5)
    np.testing.assert_array_equal(a_img, b_img)
    np.testing.assert_array_equal(a_lab, b_lab)
    c_img, _ = data.make_split(64, seed=6)
    assert np.abs(a_img - c_img).max() > 0
