"""L2 correctness: model shapes, Pallas-vs-ref path equality, conv oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as model_mod
from compile.kernels import ref


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(42)


@pytest.mark.parametrize("name", ["vggmini", "inceptionmini"])
def test_model_shapes(name, rng):
    init, apply = model_mod.MODELS[name]
    params = init(jax.random.PRNGKey(0))
    pd = model_mod.param_dict(params)
    x = jnp.asarray(rng.standard_normal((3, 32, 32, 3)).astype(np.float32))
    out = apply(pd, x)
    assert out.shape == (3, model_mod.NUM_CLASSES)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("name", ["vggmini", "inceptionmini"])
def test_param_order_deterministic(name):
    init, _ = model_mod.MODELS[name]
    p1 = [n for n, _ in init(jax.random.PRNGKey(0))]
    p2 = [n for n, _ in init(jax.random.PRNGKey(1))]
    assert p1 == p2  # order is structural, not key-dependent


@pytest.mark.parametrize("name", ["vggmini", "inceptionmini"])
def test_pallas_path_matches_ref_path(name, rng):
    """The core L2 contract: the AOT (Pallas) path == training (ref) path."""
    init, apply = model_mod.MODELS[name]
    params = init(jax.random.PRNGKey(3))
    pd = model_mod.param_dict(params)
    x = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))
    ref_out = apply(pd, x, use_pallas=False)
    pal_out = apply(pd, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(ref_out), np.asarray(pal_out), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride,pad", [(1, "SAME"), (1, "VALID"), (2, "SAME")])
def test_conv2d_im2col_matches_lax(stride, pad, rng):
    x = jnp.asarray(rng.standard_normal((2, 12, 12, 5)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((3, 3, 5, 7)).astype(np.float32))
    b = jnp.zeros((7,), jnp.float32)
    got = model_mod.conv2d(x, w, b, stride=stride, padding=pad, act="linear")
    want = ref.conv2d_ref(x, w, stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_weight_clip_premise():
    """Freshly-initialized nets may exceed [-1,1]; the trainer's projection
    is what guarantees the premise. Emulate one projected step and check."""
    init, _ = model_mod.MODELS["vggmini"]
    params = init(jax.random.PRNGKey(0))
    clipped = [(n, jnp.clip(a, -1.0, 1.0)) for n, a in params]
    assert max(float(jnp.abs(a).max()) for _, a in clipped) <= 1.0


def test_num_params_counts():
    init, _ = model_mod.MODELS["vggmini"]
    params = init(jax.random.PRNGKey(0))
    total = model_mod.num_params(params)
    bysum = sum(int(np.prod(a.shape)) for _, a in params)
    assert total == bysum > 100_000
