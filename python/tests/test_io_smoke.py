"""Always-runnable interchange-format tests (numpy + stdlib only).

The hypothesis-driven sweep lives in test_io.py; this module pins fixed
vectors so the binary formats stay covered — and the suite stays non-empty
— on hosts without jax/hypothesis (see conftest.py).
"""

import struct

import pytest

# importorskip (not a conftest collect_ignore) so this module is always
# *collected*: a host with no numpy then reports "skipped" and exits 0
# instead of "no tests collected" / exit 5.
np = pytest.importorskip("numpy")

from compile import io as io_mod  # noqa: E402  (needs numpy present)


def test_weights_header_layout(tmp_path):
    path = str(tmp_path / "w.bin")
    w = np.arange(6, dtype=np.float32).reshape(2, 3)
    io_mod.write_weights(path, [("layer0.w", w)])
    blob = open(path, "rb").read()
    assert blob[:4] == b"MLCW"
    version, count = struct.unpack_from("<II", blob, 4)
    assert (version, count) == (1, 1)
    name_len = struct.unpack_from("<H", blob, 12)[0]
    assert blob[14 : 14 + name_len] == b"layer0.w"


def test_weights_fixed_roundtrip(tmp_path):
    path = str(tmp_path / "w.bin")
    params = [
        ("conv.w", np.linspace(-1, 1, 24, dtype=np.float32).reshape(2, 3, 4)),
        ("conv.b", np.zeros(4, dtype=np.float32)),
    ]
    io_mod.write_weights(path, params)
    back = io_mod.read_weights(path)
    assert [n for n, _ in back] == [n for n, _ in params]
    for (_, a), (_, b) in zip(params, back):
        assert a.shape == b.shape
        np.testing.assert_array_equal(a, b)


def test_weights_scalar_stored_as_rank1(tmp_path):
    # np.ascontiguousarray promotes 0-d arrays to shape (1,), so the format
    # never carries rank 0 from the writer; pin that so a future "fix" on
    # either side is a conscious format change (the Rust reader accepts
    # ndim == 0 defensively).
    path = str(tmp_path / "w.bin")
    io_mod.write_weights(path, [("scalar", np.float32(0.5).reshape(()))])
    [(name, back)] = io_mod.read_weights(path)
    assert name == "scalar"
    assert back.shape == (1,)
    assert back[0] == np.float32(0.5)


def test_testset_fixed_roundtrip(tmp_path):
    path = str(tmp_path / "t.bin")
    images = np.arange(2 * 2 * 2 * 1, dtype=np.float32).reshape(2, 2, 2, 1)
    labels = np.array([3, 7], dtype=np.int32)
    io_mod.write_testset(path, images, labels)
    bi, bl = io_mod.read_testset(path)
    np.testing.assert_array_equal(bi, images)
    np.testing.assert_array_equal(bl, labels)


def test_corrupt_magic_rejected(tmp_path):
    path = str(tmp_path / "w.bin")
    io_mod.write_weights(path, [("a", np.ones(3, dtype=np.float32))])
    blob = bytearray(open(path, "rb").read())
    blob[0] = ord("X")
    open(path, "wb").write(bytes(blob))
    with pytest.raises(Exception):
        io_mod.read_weights(path)
