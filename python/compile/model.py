"""L2: the CNN models, written in JAX over the L1 Pallas kernels.

Two model families mirror the paper's evaluation pair:

  * ``vggmini``       — a plain 3x3-conv stack (VGG16's structural family)
  * ``inceptionmini`` — multi-branch inception modules (Inception V3 family)

Both are pure functions over an ordered parameter list, so the AOT artifact
exposes weights as HLO *parameters*: the Rust coordinator owns the weights,
pushes them through the simulated MLC STT-RAM buffer (encode -> store ->
fault -> decode), and feeds the surviving values to the compiled executable.
That is exactly the paper's threat model — faults hit the weight buffer, not
the activations datapath.

Every layer's GEMM goes through ``kernels.matmul_ws`` (the weight-stationary
Pallas kernel) when ``use_pallas=True`` — the AOT path — and through the
pure-jnp oracle when ``use_pallas=False`` — the training path (interpret-mode
Pallas is orders of magnitude too slow to train under; the two paths are
asserted equal in python/tests/test_model.py).

Parameter convention: ``params`` is a list of (name, array) in a fixed
topological order; conv weights are HWIO, dense weights are [in, out].
The same order is serialized into the weight manifest consumed by Rust.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul_ws, bias_act, maxpool2x2
from .kernels import ref

NUM_CLASSES = 10


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def _tile(dim: int, cap: int) -> int:
    """Block size for one GEMM dim: multiple of 8, capped.

    Tile caps are a *deployment* parameter. On a real TPU the schedule is
    MXU-shaped (128x128x128, DESIGN.md §Hardware-Adaptation). The artifacts
    built here execute on CPU PJRT, where the interpret-lowered grid becomes
    an XLA while-loop: small tiles mean thousands of loop trips (57 s per
    batch measured at 128-caps on vggmini), so the CPU artifacts use large
    tiles that collapse most layers to a single grid step while keeping the
    same kernel code. EXPERIMENTS.md §Perf records the before/after.
    """
    return min(cap, ((dim + 7) // 8) * 8)


# CPU-PJRT tile caps (TPU would use 128/128/128 — see DESIGN.md).
TILE_CAPS_M = 4096
TILE_CAPS_N = 512
TILE_CAPS_K = 2048


def _gemm(x2d: jax.Array, w2d: jax.Array, use_pallas: bool) -> jax.Array:
    if use_pallas:
        m, k = x2d.shape
        _, n = w2d.shape
        return matmul_ws(
            x2d,
            w2d,
            bm=_tile(m, TILE_CAPS_M),
            bn=_tile(n, TILE_CAPS_N),
            bk=_tile(k, TILE_CAPS_K),
        )
    return ref.matmul_ref(x2d, w2d)


def _bias_relu(x2d: jax.Array, b: jax.Array, act: str, use_pallas: bool) -> jax.Array:
    if use_pallas:
        return bias_act(x2d, b, act=act)
    return ref.bias_act_ref(x2d, b, act)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    act: str = "relu",
    use_pallas: bool = False,
) -> jax.Array:
    """NHWC conv as im2col + WS GEMM (how the paper's accelerator runs it)."""
    n, h, wd, c = x.shape
    r, s, ci, co = w.shape
    assert ci == c, (x.shape, w.shape)
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(r, s),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [n, ho, wo, c*r*s], feature order (C, R, S): channel-major
    _, ho, wo, k = patches.shape
    x2d = patches.reshape(n * ho * wo, k)
    # Match the patch feature order: HWIO -> (I, R, S, O) -> [I*R*S, O].
    w2d = jnp.transpose(w, (2, 0, 1, 3)).reshape(r * s * ci, co)
    y2d = _gemm(x2d, w2d, use_pallas)
    y2d = _bias_relu(y2d, b, act, use_pallas)
    return y2d.reshape(n, ho, wo, co)


def dense(
    x: jax.Array, w: jax.Array, b: jax.Array, *, act: str = "relu", use_pallas: bool = False
) -> jax.Array:
    y = _gemm(x, w, use_pallas)
    return _bias_relu(y, b, act, use_pallas)


def maxpool(x: jax.Array, use_pallas: bool = False) -> jax.Array:
    if use_pallas:
        return maxpool2x2(x)
    return ref.maxpool2x2_ref(x)


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------

def _he(key, shape) -> jax.Array:
    fan_in = int(np.prod(shape[:-1]))
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def _conv_param(key, name, r, s, ci, co, params):
    k1, k2 = jax.random.split(key)
    params.append((f"{name}.w", _he(k1, (r, s, ci, co))))
    params.append((f"{name}.b", jnp.zeros((co,), jnp.float32)))
    return k2


def _dense_param(key, name, ci, co, params):
    k1, k2 = jax.random.split(key)
    params.append((f"{name}.w", _he(k1, (ci, co))))
    params.append((f"{name}.b", jnp.zeros((co,), jnp.float32)))
    return k2


# --------------------------------------------------------------------------
# VGG-Mini
# --------------------------------------------------------------------------

VGG_CFG = [(32, 2), (64, 2), (128, 2)]  # (channels, convs-per-stage); pool after each


def init_vggmini(key) -> list[tuple[str, jax.Array]]:
    params: list[tuple[str, jax.Array]] = []
    ci = 3
    for si, (co, reps) in enumerate(VGG_CFG):
        for rj in range(reps):
            key = _conv_param(key, f"conv{si}_{rj}", 3, 3, ci, co, params)
            ci = co
    key = _dense_param(key, "fc0", 4 * 4 * 128, 256, params)
    key = _dense_param(key, "fc1", 256, NUM_CLASSES, params)
    return params


def vggmini_apply(params: dict[str, jax.Array], x: jax.Array, *, use_pallas: bool = False) -> jax.Array:
    for si, (_, reps) in enumerate(VGG_CFG):
        for rj in range(reps):
            x = conv2d(
                x, params[f"conv{si}_{rj}.w"], params[f"conv{si}_{rj}.b"], use_pallas=use_pallas
            )
        x = maxpool(x, use_pallas)
    x = x.reshape(x.shape[0], -1)
    x = dense(x, params["fc0.w"], params["fc0.b"], use_pallas=use_pallas)
    return dense(x, params["fc1.w"], params["fc1.b"], act="linear", use_pallas=use_pallas)


# --------------------------------------------------------------------------
# Inception-Mini
# --------------------------------------------------------------------------
#
# Each module concatenates four branches (1x1 / 1x1->3x3 / 1x1->"5x5" as a
# 3x3 pair, pool->1x1), the Inception V3 "module A" shape scaled down.

INC_MODULES = [
    # (b1, (r3, b3), (r5, b5a, b5b), bp) -> concat channels
    dict(b1=24, r3=16, b3=32, r5=8, b5a=16, b5b=16, bp=24),   # -> 96ch
    dict(b1=32, r3=24, b3=48, r5=12, b5a=24, b5b=24, bp=24),  # -> 128ch
]


def _inc_module_params(key, name, ci, m, params):
    key = _conv_param(key, f"{name}.b1", 1, 1, ci, m["b1"], params)
    key = _conv_param(key, f"{name}.b3r", 1, 1, ci, m["r3"], params)
    key = _conv_param(key, f"{name}.b3", 3, 3, m["r3"], m["b3"], params)
    key = _conv_param(key, f"{name}.b5r", 1, 1, ci, m["r5"], params)
    key = _conv_param(key, f"{name}.b5a", 3, 3, m["r5"], m["b5a"], params)
    key = _conv_param(key, f"{name}.b5b", 3, 3, m["b5a"], m["b5b"], params)
    key = _conv_param(key, f"{name}.bp", 1, 1, ci, m["bp"], params)
    return key


def _inc_module_out(m) -> int:
    return m["b1"] + m["b3"] + m["b5b"] + m["bp"]


def init_inceptionmini(key) -> list[tuple[str, jax.Array]]:
    params: list[tuple[str, jax.Array]] = []
    key = _conv_param(key, "stem0", 3, 3, 3, 32, params)
    ci = 32
    for mi, m in enumerate(INC_MODULES):
        key = _inc_module_params(key, f"inc{mi}", ci, m, params)
        ci = _inc_module_out(m)
    key = _dense_param(key, "fc", ci, NUM_CLASSES, params)
    return params


def _avgpool3x3_same(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 3, 3, 1), (1, 1, 1, 1), "SAME"
    ) / 9.0


def _inc_module_apply(params, name, x, m, use_pallas):
    cv = functools.partial(conv2d, use_pallas=use_pallas)
    b1 = cv(x, params[f"{name}.b1.w"], params[f"{name}.b1.b"])
    b3 = cv(x, params[f"{name}.b3r.w"], params[f"{name}.b3r.b"])
    b3 = cv(b3, params[f"{name}.b3.w"], params[f"{name}.b3.b"])
    b5 = cv(x, params[f"{name}.b5r.w"], params[f"{name}.b5r.b"])
    b5 = cv(b5, params[f"{name}.b5a.w"], params[f"{name}.b5a.b"])
    b5 = cv(b5, params[f"{name}.b5b.w"], params[f"{name}.b5b.b"])
    bp = _avgpool3x3_same(x)
    bp = cv(bp, params[f"{name}.bp.w"], params[f"{name}.bp.b"])
    return jnp.concatenate([b1, b3, b5, bp], axis=-1)


def inceptionmini_apply(
    params: dict[str, jax.Array], x: jax.Array, *, use_pallas: bool = False
) -> jax.Array:
    x = conv2d(x, params["stem0.w"], params["stem0.b"], use_pallas=use_pallas)
    x = maxpool(x, use_pallas)  # 16x16
    x = _inc_module_apply(params, "inc0", x, INC_MODULES[0], use_pallas)
    x = maxpool(x, use_pallas)  # 8x8
    x = _inc_module_apply(params, "inc1", x, INC_MODULES[1], use_pallas)
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    return dense(x, params["fc.w"], params["fc.b"], act="linear", use_pallas=use_pallas)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

MODELS: dict[str, tuple[Callable, Callable]] = {
    "vggmini": (init_vggmini, vggmini_apply),
    "inceptionmini": (init_inceptionmini, inceptionmini_apply),
}


def param_dict(params: list[tuple[str, jax.Array]]) -> dict[str, jax.Array]:
    return dict(params)


def num_params(params: list[tuple[str, jax.Array]]) -> int:
    return int(sum(np.prod(a.shape) for _, a in params))
