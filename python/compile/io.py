"""Binary artifact formats shared with the Rust runtime.

No serde/npz on the Rust side (offline vendor set), so the interchange is a
deliberately tiny format both sides implement and test:

weights.bin  : b"MLCW" u32 version=1 u32 count
               repeat count times:
                 u16 name_len, name (utf-8), u8 ndim, u32 dims[ndim],
                 f32 data (C order, little-endian)
testset.bin  : b"MLCT" u32 version=1 u32 n u32 h u32 w u32 c
               f32 images [n,h,w,c], i32 labels [n]
manifest.json: human-readable sidecar (model name, batch size, param order,
               shapes, training metadata). Rust parses it with the in-tree
               JSON codec.
"""

from __future__ import annotations

import json
import struct

import numpy as np

WEIGHTS_MAGIC = b"MLCW"
TESTSET_MAGIC = b"MLCT"
VERSION = 1


def write_weights(path: str, params: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<II", VERSION, len(params)))
        for name, arr in params:
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def read_weights(path: str) -> list[tuple[str, np.ndarray]]:
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:4] == WEIGHTS_MAGIC, "bad magic"
    version, count = struct.unpack_from("<II", buf, 4)
    assert version == VERSION
    off = 12
    out = []
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", buf, off)
        off += 2
        name = buf[off : off + nlen].decode("utf-8")
        off += nlen
        (ndim,) = struct.unpack_from("<B", buf, off)
        off += 1
        dims = struct.unpack_from(f"<{ndim}I", buf, off)
        off += 4 * ndim
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(buf, "<f4", n, off).reshape(dims)
        off += 4 * n
        out.append((name, arr))
    return out


def write_testset(path: str, images: np.ndarray, labels: np.ndarray) -> None:
    images = np.ascontiguousarray(images, np.float32)
    labels = np.ascontiguousarray(labels, np.int32)
    n, h, w, c = images.shape
    assert labels.shape == (n,)
    with open(path, "wb") as f:
        f.write(TESTSET_MAGIC)
        f.write(struct.pack("<IIIII", VERSION, n, h, w, c))
        f.write(images.tobytes())
        f.write(labels.tobytes())


def read_testset(path: str) -> tuple[np.ndarray, np.ndarray]:
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:4] == TESTSET_MAGIC, "bad magic"
    version, n, h, w, c = struct.unpack_from("<IIIII", buf, 4)
    assert version == VERSION
    off = 24
    imgs = np.frombuffer(buf, "<f4", n * h * w * c, off).reshape(n, h, w, c)
    off += 4 * n * h * w * c
    labels = np.frombuffer(buf, "<i4", n, off)
    return imgs, labels


def write_manifest(path: str, manifest: dict) -> None:
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
