"""Deterministic synthetic image dataset (ImageNet stand-in).

The paper evaluates VGG16 / Inception V3 on ImageNet. ImageNet (and the
pretrained checkpoints) are not available in this environment, so we build
the closest synthetic equivalent that exercises the same code path: a
10-class 32x32x3 classification task whose classes are procedurally
generated texture/shape templates with additive noise and random geometric
jitter. What must transfer from the paper's setting (see DESIGN.md §2) is
not ImageNet semantics but that (a) a conv net trains to high accuracy on
the task, (b) trained weights are roughly sign-balanced, and (c) weights are
normalized into [-1, 1] — all of which hold here.

Everything is keyed by an explicit PRNG seed: the same seed produces the
same dataset in every run (training, AOT export, and the Rust-side test-set
binary all agree).
"""

from __future__ import annotations

import numpy as np

NUM_CLASSES = 10
IMG = 32
CHANNELS = 3


def _class_template(cls: int) -> np.ndarray:
    """A fixed, class-specific 32x32x3 template in [-1, 1]."""
    rng = np.random.default_rng(1000 + cls)
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32) / (IMG - 1)
    t = np.zeros((IMG, IMG, CHANNELS), np.float32)
    # Each class mixes: an oriented sinusoid grating, a blob at a fixed
    # location, and a per-channel polarity. Distinct frequencies/phases per
    # class keep the Bayes error near zero while still requiring spatial
    # filters (not just color histograms) to separate some pairs.
    freq = 2.0 + cls * 0.9
    theta = cls * (np.pi / NUM_CLASSES)
    grating = np.sin(2 * np.pi * freq * (xx * np.cos(theta) + yy * np.sin(theta)))
    cy, cx = rng.uniform(0.25, 0.75, 2)
    blob = np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / 0.02))
    pol = rng.choice([-1.0, 1.0], CHANNELS)
    for ch in range(CHANNELS):
        w1, w2 = rng.uniform(0.4, 1.0, 2)
        t[:, :, ch] = pol[ch] * (w1 * grating + w2 * blob)
    return np.clip(t, -1.5, 1.5) / 1.5


_TEMPLATES = None


def templates() -> np.ndarray:
    global _TEMPLATES
    if _TEMPLATES is None:
        _TEMPLATES = np.stack([_class_template(c) for c in range(NUM_CLASSES)])
    return _TEMPLATES


def make_split(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """n examples: (images [n,32,32,3] f32 in ~[-1,1], labels [n] i32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, NUM_CLASSES, n).astype(np.int32)
    tmpl = templates()[labels]
    # Geometric jitter: circular shift by up to +-5 px per axis, plus a
    # per-image gain so color polarity alone cannot separate classes.
    shifts = rng.integers(-5, 6, (n, 2))
    imgs = np.empty_like(tmpl)
    for i in range(n):
        imgs[i] = np.roll(tmpl[i], shifts[i], axis=(0, 1))
    gains = rng.uniform(0.5, 1.3, (n, 1, 1, 1)).astype(np.float32)
    imgs = imgs * gains + rng.normal(0.0, 1.0, imgs.shape).astype(np.float32)
    return np.clip(imgs, -2.5, 2.5).astype(np.float32), labels


def train_test(n_train: int = 4096, n_test: int = 1024, seed: int = 7):
    xtr, ytr = make_split(n_train, seed)
    xte, yte = make_split(n_test, seed + 1)
    return (xtr, ytr), (xte, yte)
