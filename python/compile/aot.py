"""AOT pipeline: train -> verify Pallas-vs-ref -> lower to HLO text -> export.

Emits, per model, into --out-dir (default ../artifacts):

  <model>.hlo.txt       HLO *text* of the batched inference function with
                        weights as leading parameters (weights stay under
                        Rust's control so the MLC STT-RAM buffer simulation
                        can corrupt them before every execution)
  <model>.weights.bin   trained parameters (compile/io.py format)
  <model>.manifest.json param order/shapes + training metadata
  testset.bin           shared held-out split
  matmul_ws.hlo.txt     small standalone Pallas-GEMM artifact (runtime tests)

HLO text — NOT lowered.compile() / proto .serialize(): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the vendored `xla` crate binds) rejects (`proto.id() <= INT_MAX`).
The text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Python runs once, at build time; `make artifacts` is a no-op when outputs
are newer than their inputs. Nothing here is on the Rust request path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import io as io_mod
from . import model as model_mod
from . import train as train_mod

DEFAULT_BATCH = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str, params: list[tuple[str, np.ndarray]], batch: int) -> str:
    """Lower `fn(w_0.., w_n-1, x) -> (logits,)` with the Pallas path."""
    _, apply_raw = model_mod.MODELS[name]
    order = [n for n, _ in params]

    def fn(*args):
        *ws, x = args
        pd = dict(zip(order, ws))
        return (apply_raw(pd, x, use_pallas=True),)

    specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for _, a in params]
    xspec = jax.ShapeDtypeStruct((batch, data_mod.IMG, data_mod.IMG, data_mod.CHANNELS), jnp.float32)
    lowered = jax.jit(fn).lower(*specs, xspec)
    return to_hlo_text(lowered)


def selfcheck(name: str, params: list[tuple[str, np.ndarray]], xte: np.ndarray) -> float:
    """Pallas path must match the reference path on the trained weights."""
    _, apply_raw = model_mod.MODELS[name]
    pd = {n: jnp.asarray(a) for n, a in params}
    x = jnp.asarray(xte[:16])
    ref = apply_raw(pd, x, use_pallas=False)
    pal = apply_raw(pd, x, use_pallas=True)
    err = float(jnp.max(jnp.abs(ref - pal)))
    if err > 1e-3:
        raise AssertionError(f"{name}: pallas-vs-ref selfcheck failed, max err {err}")
    return err


def lower_matmul_artifact() -> str:
    from .kernels import matmul_ws

    def fn(x, w):
        return (matmul_ws(x, w),)

    xs = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((16, 12), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(xs, ws))


def build_model(name: str, out_dir: str, batch: int, seed: int, epochs: int, force: bool) -> None:
    wpath = os.path.join(out_dir, f"{name}.weights.bin")
    mpath = os.path.join(out_dir, f"{name}.manifest.json")
    hpath = os.path.join(out_dir, f"{name}.hlo.txt")

    if not force and all(os.path.exists(p) for p in (wpath, mpath, hpath)):
        print(f"[aot] {name}: artifacts up to date, skipping")
        return

    if not force and os.path.exists(wpath) and os.path.exists(mpath):
        # Weights cached from a previous run (training is the expensive
        # step): reuse them and only re-lower the HLO.
        print(f"[aot] {name}: reusing cached weights from {wpath}")
        params = io_mod.read_weights(wpath)
        with open(mpath) as f:
            meta = json.load(f)["training"]
    else:
        # Per-model hyperparameters: the deeper VGG stack needs a gentler LR
        # (lr=0.05 diverged in epoch 0 before gradient clipping was added).
        lr = {"vggmini": 0.02}.get(name, 0.05)
        params, meta = train_mod.train_model(name, seed=seed, epochs=epochs, lr=lr)
    (_, _), (xte, yte) = data_mod.train_test(meta["n_train"], meta["n_test"], seed)
    err = selfcheck(name, params, xte)
    print(f"[aot] {name}: pallas-vs-ref selfcheck max err {err:.2e}")

    hlo = lower_model(name, params, batch)
    with open(hpath, "w") as f:
        f.write(hlo)
    io_mod.write_weights(wpath, params)
    manifest = {
        "format_version": io_mod.VERSION,
        "batch": batch,
        "input_shape": [batch, data_mod.IMG, data_mod.IMG, data_mod.CHANNELS],
        "num_classes": model_mod.NUM_CLASSES,
        "params": [
            {"name": n, "shape": list(a.shape), "size": int(np.prod(a.shape))}
            for n, a in params
        ],
        "selfcheck_max_err": err,
        "training": meta,
    }
    io_mod.write_manifest(mpath, manifest)
    print(f"[aot] {name}: wrote {hpath} ({len(hlo)} chars), {wpath}, {mpath}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", default="vggmini,inceptionmini")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--epochs", type=int, default=14)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    # Shared test split.
    tpath = os.path.join(out_dir, "testset.bin")
    if args.force or not os.path.exists(tpath):
        (_, _), (xte, yte) = data_mod.train_test(seed=args.seed)
        io_mod.write_testset(tpath, xte, yte)
        print(f"[aot] wrote {tpath} ({len(xte)} images)")

    # Small standalone kernel artifact for runtime integration tests.
    kpath = os.path.join(out_dir, "matmul_ws.hlo.txt")
    if args.force or not os.path.exists(kpath):
        with open(kpath, "w") as f:
            f.write(lower_matmul_artifact())
        print(f"[aot] wrote {kpath}")

    for name in args.models.split(","):
        name = name.strip()
        if name not in model_mod.MODELS:
            sys.exit(f"unknown model {name!r}; have {sorted(model_mod.MODELS)}")
        build_model(name, out_dir, args.batch, args.seed, args.epochs, args.force)

    stamp = os.path.join(out_dir, ".stamp")
    with open(stamp, "w") as f:
        json.dump({"models": args.models, "batch": args.batch, "seed": args.seed}, f)
    print("[aot] done")


if __name__ == "__main__":
    main()
