"""Weight-stationary tiled matmul Pallas kernel.

This is the hot-spot of the paper's accelerator: every convolution is
lowered to an im2col GEMM `out[M, N] = x[M, K] @ w[K, N]` and executed on a
weight-stationary systolic array. On TPU the MXU *is* a 128x128 WS systolic
array, so the mapping is direct:

  * grid = (M/BM, N/BN, K/BK); the K axis is the innermost (fastest moving)
    grid dimension so a given weight tile w[K-block, N-block] stays resident
    in VMEM across the accumulation — the "weight-stationary" schedule.
  * the accumulator lives in a VMEM scratch buffer (pltpu-style scratch via
    `pl.pallas_call`'s scratch_shapes), zeroed at k==0 and flushed to the
    output tile at k==K/BK-1.
  * BlockSpec index maps express the HBM->VMEM double-buffered transfers the
    paper models with SRAM ping-pong buffers (SCALE-Sim "double buffer").

VMEM/MXU accounting for one (BM, BN, BK) = (128, 128, 128) f32 step:
  x tile 64 KiB + w tile 64 KiB + acc 64 KiB + out 64 KiB = 256 KiB << 16 MiB
  VMEM, leaving room for >16 in-flight double-buffered tiles; each step
  issues 128^3 MACs = 16 MXU passes at 8x128x128, i.e. the schedule is
  MXU-bound, not transfer-bound (arithmetic intensity 128 FLOP/B at f32).

interpret=True everywhere: CPU PJRT cannot run Mosaic custom-calls. The
kernel still lowers into the same HLO module as the surrounding JAX program,
which is what the Rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# MXU-shaped tile. The last two dims of a TPU tile must be (8k, 128); a
# 128x128 f32 block is 16 lane-groups — the canonical MXU operand shape.
MXU_TILE = 128


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    """One grid step: acc += x_tile @ w_tile; flush on the last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU op: always accumulate in f32 regardless of operand dtype.
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(a: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_ws(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = MXU_TILE,
    bn: int = MXU_TILE,
    bk: int = MXU_TILE,
) -> jax.Array:
    """`x[M, K] @ w[K, N]` on the weight-stationary Pallas schedule.

    Shapes need not be tile-aligned; inputs are zero-padded to the block
    grid and the result is sliced back (zero padding is exact for matmul).
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul_ws expects 2-D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    m, k = x.shape
    _, n = w.shape

    xp = _pad_to(x.astype(jnp.float32), bm, bk)
    wp = _pad_to(w.astype(jnp.float32), bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    k_steps = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            # x tile follows (i, k): new ifmap slice each K step.
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            # w tile follows (k, j): stationary w.r.t. i — the WS schedule.
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, wp)
    return out[:m, :n]
