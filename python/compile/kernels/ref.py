"""Pure-jnp oracles for every Pallas kernel.

These are the correctness contract: python/tests/test_kernels.py sweeps
shapes/dtypes (hypothesis) and asserts the Pallas kernels match these
references bit-for-bit (f32) or to tight tolerance where reassociation
differs. The AOT pipeline refuses to emit artifacts if the oracle check
fails (see aot.py --selfcheck).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def bias_act_ref(x: jax.Array, b: jax.Array, act: str = "relu") -> jax.Array:
    y = x + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act != "linear":
        raise ValueError(act)
    return y


def maxpool2x2_ref(x: jax.Array) -> jax.Array:
    n, h, w, c = x.shape
    return jnp.max(x.reshape(n, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride: int = 1, padding: str = "SAME") -> jax.Array:
    """NHWC x HWIO convolution oracle (used for the im2col path in model.py)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
