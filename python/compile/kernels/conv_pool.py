"""Elementwise / pooling Pallas kernels used around the GEMM hot loop.

These are the "vector unit" companions to `matmul_ws`: on the paper's
accelerator the PE array produces raw partial sums and a small post-processing
unit applies bias + activation before results are written back to the output
buffer; pooling runs as a separate pass over the output buffer. Expressing
them as Pallas kernels keeps the whole layer inside one lowered HLO module.

Both kernels are row-tiled so that arbitrarily large batches stream through a
bounded VMEM footprint (one (block_rows, C) tile resident at a time).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bias_act_kernel(x_ref, b_ref, o_ref, *, act: str):
    y = x_ref[...] + b_ref[...]
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "linear":
        pass
    else:  # pragma: no cover - guarded in the wrapper
        raise ValueError(act)
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("act", "block_rows"))
def bias_act(x: jax.Array, b: jax.Array, *, act: str = "relu", block_rows: int = 256) -> jax.Array:
    """`act(x + b)` with x:[R, C], b:[C] — fused bias + activation kernel."""
    if act not in ("relu", "linear"):
        raise ValueError(f"unsupported activation {act!r}")
    r, c = x.shape
    pr = (-r) % block_rows
    xp = jnp.pad(x, ((0, pr), (0, 0)))
    rp = xp.shape[0]
    out = pl.pallas_call(
        functools.partial(_bias_act_kernel, act=act),
        grid=(rp // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), x.dtype),
        interpret=True,
    )(xp, b)
    return out[:r]


def _maxpool_kernel(x_ref, o_ref):
    x = x_ref[...]  # [rows, H, W, C]
    r, h, w, c = x.shape
    x = x.reshape(r, h // 2, 2, w // 2, 2, c)
    o_ref[...] = jnp.max(x, axis=(2, 4))


@functools.partial(jax.jit, static_argnames=("block_rows",))
def maxpool2x2(x: jax.Array, *, block_rows: int = 8) -> jax.Array:
    """2x2/stride-2 max pool over NHWC input (H, W even)."""
    n, h, w, c = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"maxpool2x2 needs even H, W; got {x.shape}")
    pr = (-n) % block_rows
    xp = jnp.pad(x, ((0, pr), (0, 0), (0, 0), (0, 0)))
    np_ = xp.shape[0]
    out = pl.pallas_call(
        _maxpool_kernel,
        grid=(np_ // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((block_rows, h // 2, w // 2, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, h // 2, w // 2, c), x.dtype),
        interpret=True,
    )(xp)
    return out[:n]
