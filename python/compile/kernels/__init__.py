# L1: Pallas kernels for the CNN accelerator hot-spot.
#
# The paper's compute substrate is a weight-stationary (WS) systolic array;
# the TPU MXU is a 128x128 WS systolic array, so the convolution GEMM maps
# directly: `matmul_ws` tiles the im2col GEMM into MXU-shaped blocks with a
# VMEM accumulator, and BlockSpec index maps express the HBM<->VMEM schedule
# that the paper's SRAM/STT double buffers express on the ASIC.
#
# All kernels are lowered with interpret=True: the CPU PJRT plugin cannot run
# Mosaic custom-calls, and correctness (vs. kernels/ref.py) is the signal
# that feeds the AOT artifacts. TPU-side performance is estimated
# analytically in DESIGN.md / EXPERIMENTS.md from the BlockSpec.

from .matmul_ws import matmul_ws, MXU_TILE
from .conv_pool import bias_act, maxpool2x2

__all__ = ["matmul_ws", "MXU_TILE", "bias_act", "maxpool2x2"]
