"""Training loop (build-time only): SGD + momentum with weight clipping.

The paper's premise is that "weights are normalized between -1 and 1 after
each convolutional layer" (weight normalization [38]); we realize it as
projected SGD — after every update all parameters are clipped into
[-1, 1] — so the exported checkpoints satisfy the |w| < 2 precondition the
sign-bit-protection scheme relies on (exponent MSB of binary16 unused).

Runs on the pure-jnp reference path (interpret-mode Pallas is far too slow
to train under); python/tests/test_model.py asserts the Pallas and reference
paths agree, and aot.py re-verifies at export time.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod

WEIGHT_CLIP = 1.0
GRAD_CLIP_NORM = 5.0


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_step(apply_fn, lr: float, momentum: float = 0.9):
    def loss_fn(pd, x, y):
        return cross_entropy(apply_fn(pd, x), y)

    @jax.jit
    def step(pd, vel, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(pd, x, y)
        # Global-norm gradient clipping: deep stacks on this synthetic data
        # see occasional large first-epoch gradients that otherwise blow the
        # run (observed: vggmini at lr=0.05 diverged in epoch 0).
        gnorm = jnp.sqrt(
            sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)) + 1e-12
        )
        scale = jnp.minimum(1.0, GRAD_CLIP_NORM / gnorm)
        grads = jax.tree.map(lambda g: g * scale, grads)
        new_vel = jax.tree.map(lambda v, g: momentum * v - lr * g, vel, grads)
        new_pd = jax.tree.map(
            lambda p, v: jnp.clip(p + v, -WEIGHT_CLIP, WEIGHT_CLIP), pd, new_vel
        )
        return new_pd, new_vel, loss

    return step


def evaluate(apply_fn, pd, x, y, batch: int = 256) -> float:
    correct = 0
    for i in range(0, len(x), batch):
        logits = apply_fn(pd, jnp.asarray(x[i : i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(y[i : i + batch])))
    return correct / len(x)


def train_model(
    name: str,
    *,
    seed: int = 7,
    epochs: int = 14,
    batch: int = 128,
    lr: float = 0.05,
    n_train: int = 4096,
    n_test: int = 1024,
    log=print,
) -> tuple[list[tuple[str, np.ndarray]], dict]:
    """Returns (ordered params, training-metadata dict)."""
    init_fn, apply_raw = model_mod.MODELS[name]
    apply_fn = lambda pd, x: apply_raw(pd, x, use_pallas=False)

    (xtr, ytr), (xte, yte) = data_mod.train_test(n_train, n_test, seed)
    params = init_fn(jax.random.PRNGKey(seed))
    order = [n for n, _ in params]
    pd = model_mod.param_dict(params)
    vel = jax.tree.map(jnp.zeros_like, pd)
    step = make_step(apply_fn, lr)

    rng = np.random.default_rng(seed + 99)
    t0 = time.time()
    losses = []
    for ep in range(epochs):
        perm = rng.permutation(n_train)
        ep_loss = 0.0
        nb = 0
        for i in range(0, n_train, batch):
            idx = perm[i : i + batch]
            pd, vel, loss = step(pd, vel, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]))
            ep_loss += float(loss)
            nb += 1
        losses.append(ep_loss / nb)
        log(f"[{name}] epoch {ep:2d} loss {losses[-1]:.4f}")
    train_acc = evaluate(apply_fn, pd, xtr[:1024], ytr[:1024])
    test_acc = evaluate(apply_fn, pd, xte, yte)
    elapsed = time.time() - t0
    log(f"[{name}] train_acc={train_acc:.4f} test_acc={test_acc:.4f} ({elapsed:.1f}s)")

    out_params = [(n, np.asarray(pd[n])) for n in order]
    # Premise check: every exported weight is in [-1, 1].
    wmax = max(float(np.abs(a).max()) for _, a in out_params)
    assert wmax <= WEIGHT_CLIP + 1e-6, f"weight clip violated: {wmax}"
    meta = {
        "model": name,
        "seed": seed,
        "epochs": epochs,
        "batch": batch,
        "lr": lr,
        "n_train": n_train,
        "n_test": n_test,
        "train_acc": train_acc,
        "test_acc": test_acc,
        "loss_curve": losses,
        "max_abs_weight": wmax,
        "num_params": model_mod.num_params(out_params),
        "train_seconds": elapsed,
    }
    return out_params, meta
