# Makes `pytest python/tests/ -q` work from the repository root:
# the test modules import the build-time `compile` package from python/.
#
# Also the suite's skip guard: the heavy L1 test modules need jax and
# hypothesis, which CI (and the offline Rust-focused container) may not
# carry. Modules whose dependencies are missing are excluded at collection
# time so `pytest python/tests -q` passes everywhere; the numpy-only
# interchange-format tests always run.
import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))


def _missing(*modules):
    return [m for m in modules if importlib.util.find_spec(m) is None]


# test module -> the optional dependencies it imports at module scope.
# test_io_smoke.py is deliberately absent: it importorskips numpy itself,
# so at least one module is always *collected* and pytest exits 0 (an
# all-ignored run would exit 5, "no tests collected").
_REQUIREMENTS = {
    "test_io.py": ("numpy", "hypothesis"),
    "test_kernels.py": ("numpy", "hypothesis", "jax"),
    "test_model.py": ("numpy", "jax"),
}

collect_ignore = []
_skip_notes = []
for _name, _deps in _REQUIREMENTS.items():
    _gone = _missing(*_deps)
    if _gone:
        collect_ignore.append(os.path.join("python", "tests", _name))
        _skip_notes.append(
            f"python/tests/{_name} not collected (missing: {', '.join(_gone)})"
        )


def pytest_report_header(config):
    # stderr writes at conftest import time are swallowed by pytest's
    # capture; the report header is the supported way to surface this.
    return _skip_notes
