# Makes `pytest python/tests/ -q` work from the repository root:
# the test modules import the build-time `compile` package from python/.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
