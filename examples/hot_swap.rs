//! Zero-downtime delivery demo + chaos smoke: stream a new weight
//! version through verify → stage → canary → hot swap while the
//! incumbent keeps serving, then prove the rollback contract by letting
//! two more deliveries fail on purpose (DESIGN.md §14).
//!
//! ```bash
//! make swap-demo         # == cargo run --release --offline --example hot_swap
//! ```
//!
//! Self-contained (no trained artifacts): a synthetic linear classifier
//! serves from a shared multi-tenant MLC buffer pool sized to hold the
//! live and the staged version side by side. The script:
//!
//! 1. serves version 0 and checks every answer against its decode;
//! 2. leaves a tail of requests **in flight**, then delivers v1 through
//!    a chaos stream (every chunk times out once and arrives corrupted
//!    once — the retry/backoff path converges) and hot-swaps it in; the
//!    in-flight tail must drain on the old engine, bit-exact;
//! 3. delivers v2 with one chunk corrupted past the retry budget —
//!    `RetriesExhausted`, rollback, v1 keeps serving bit-identically;
//! 4. delivers v3 with a deliberately wrong canary expectation —
//!    `CanaryFailed`, rollback, v1 still serving.
//!
//! The process exits non-zero if any request is dropped or mis-served,
//! or if a failed delivery leaves anything but the incumbent serving —
//! this is the CI chaos gate. Writes `DELIVERY_hot_swap.json` (counts,
//! verdicts) to `$MLCSTT_BENCH_DIR` (default `bench_out/`).
//!
//! Environment (via `api::Config`): MLCSTT_EVAL scales the streamed
//! weight count (default 512 → 4096 in CI), MLCSTT_REQUESTS the replay
//! length per phase, plus the delivery knobs MLCSTT_DELIVERY_RETRIES /
//! MLCSTT_DELIVERY_BACKOFF_MS / MLCSTT_CANARY and the pool geometry
//! knobs.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use mlcstt::api::{
    deliver, BufferPool, CanaryCheck, ChaosStream, Config, DeliveryError, DeploymentManifest,
    MemoryStream, ModelRegistry,
};
use mlcstt::coordinator::{BatchClassifier, LinearEngine, StoreConfig};
use mlcstt::runtime::artifacts::{ParamSpec, WeightFile};
use mlcstt::stt::ErrorModel;
use mlcstt::util::json::{obj, Json};
use mlcstt::util::rng::Xoshiro256;

const CLASSES: usize = 8;
const BATCH: usize = 8;
const MODEL: &str = "hotswap-demo";
const CHUNK: usize = 256;

/// Deterministic f16-representable weights for one version.
fn weights_for(version: u64, dim: usize) -> WeightFile {
    let mut rng = Xoshiro256::seeded(0x5EED ^ version.wrapping_mul(0x9E37_79B9));
    WeightFile {
        params: vec![ParamSpec {
            name: "classifier.w".into(),
            shape: vec![CLASSES, dim],
            data: (0..CLASSES * dim)
                .map(|_| {
                    mlcstt::fp::quantize_f16(((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
                })
                .collect(),
        }],
    }
}

/// Canary probes for a version's clean weights: each probe image is a
/// class row of the weight matrix, and the expectation is the clean
/// decode's own argmax — robust to the mantissa-LSB faults the
/// protected store may keep.
fn canary_checks(weights: &WeightFile, dim: usize, sabotage: bool) -> Result<Vec<CanaryCheck>> {
    let reference = LinearEngine::new(CLASSES, dim, 1, weights.flat())?;
    (0..BATCH)
        .map(|c| {
            let row = (c % CLASSES) * dim;
            let image = weights.params[0].data[row..row + dim].to_vec();
            let mut expect = reference.classify_batch(&image)?[0];
            if sabotage {
                expect = (expect + 1) % CLASSES;
            }
            Ok(CanaryCheck { image, expect })
        })
        .collect()
}

/// Replay `n` closed-loop requests and demand every answer match the
/// reference decode exactly. Returns the served count (anything short of
/// `n` means a drop, which is a hard failure upstream).
fn replay(
    registry: &ModelRegistry,
    reference: &LinearEngine,
    dim: usize,
    n: usize,
    rng: &mut Xoshiro256,
) -> Result<usize> {
    for _ in 0..n {
        let image: Vec<f32> = (0..dim).map(|_| (rng.next_gaussian() * 0.5) as f32).collect();
        let want = reference.classify_batch(&image)?[0];
        let got = registry
            .submit(MODEL, image)?
            .ticket()
            .context("request shed during replay")?
            .wait()
            .context("request dropped during replay")?
            .class;
        ensure!(got == want, "mis-served: predicted {got}, decode says {want}");
    }
    Ok(n)
}

/// Decode-reference engine for the pool tenant currently backing `tag`.
fn pool_reference(pool: &BufferPool, tag: &str, dim: usize) -> Result<LinearEngine> {
    let tensors = pool.tensors(tag)?;
    LinearEngine::new(CLASSES, dim, 1, tensors[0].data.clone())
}

fn main() -> Result<()> {
    let config = Config::builder().max_wait(Duration::from_millis(5)).build();
    let eval = config.eval_or(512);
    let requests = config.requests_or(96);
    let dim = (eval / CLASSES).max(8);
    let n_weights = CLASSES * dim;
    println!(
        "hot-swap chaos smoke: {n_weights} weights/version in {} chunks, {requests} requests/phase",
        n_weights.div_ceil(CHUNK),
    );

    // Pool sized for the live and the staged version side by side (plus
    // slack), unless the environment picks its own geometry.
    let pool = BufferPool::from_config(&config)
        .unwrap_or_else(|| BufferPool::new(9 * n_weights / 2, 4, 256, config.evict_policy()));
    let store = StoreConfig {
        error_model: ErrorModel::at_rate(0.002),
        seed: 11,
        ..StoreConfig::default()
    };

    // Version 0 goes live through the ordinary pooled path.
    let v0 = weights_for(0, dim);
    pool.admit(MODEL, &store, &v0)?;
    let mut registry = ModelRegistry::new().with_pool(pool.clone());
    registry.register_pooled(
        MODEL,
        move |t: &[ParamSpec]| LinearEngine::new(CLASSES, dim, BATCH, t[0].data.clone()),
        config.server(),
    )?;
    let mut rng = Xoshiro256::seeded(7);
    let v0_reference = pool_reference(&pool, MODEL, dim)?;
    let mut served = replay(&registry, &v0_reference, dim, requests, &mut rng)?;
    println!("phase 1: {served} requests served by v0, all matching its decode");

    // Leave a tail in flight across the swap: admitted before the park,
    // these must drain on the old engine, bit-exact.
    let mut tail = Vec::new();
    let mut tail_want = Vec::new();
    for _ in 0..2 * BATCH {
        let image: Vec<f32> = (0..dim).map(|_| (rng.next_gaussian() * 0.5) as f32).collect();
        tail_want.push(v0_reference.classify_batch(&image)?[0]);
        tail.push(registry.submit(MODEL, image)?.ticket()?);
    }

    // Delivery 1 (succeeds): every chunk times out once and arrives
    // corrupted once before coming clean — inside the default budget.
    let v1 = weights_for(1, dim);
    let manifest = DeploymentManifest::describe(MODEL, 1, &v1, CHUNK, &store)?;
    let mut stream =
        ChaosStream::new(MemoryStream::from_weights(1, &v1, CHUNK)).fail_first(1).corrupt_first(1);
    let checks = canary_checks(&v1, dim, false)?;
    let delivered = deliver(&mut registry, &manifest, &mut stream, &checks, &config, move |t| {
        LinearEngine::new(CLASSES, dim, BATCH, t[0].data.clone())
    })
    .map_err(|e| anyhow::anyhow!("chaos delivery should converge, got: {e}"))?;
    println!(
        "phase 2: v1 swapped in after {} retries ({:.1} ms backoff), {} canary batches",
        delivered.retries,
        delivered.backoff_total.as_secs_f64() * 1e3,
        delivered.canary_batches,
    );
    for (t, want) in tail.into_iter().zip(tail_want) {
        let got = t.wait().context("in-flight request dropped by the swap")?.class;
        ensure!(got == want, "in-flight request mis-served across the swap");
        served += 1;
    }
    let v1_tag = format!("{MODEL}@v1");
    ensure!(!pool.contains(MODEL), "old tenant should be withdrawn after the swap");
    let v1_reference = pool_reference(&pool, &v1_tag, dim)?;
    served += replay(&registry, &v1_reference, dim, requests, &mut rng)?;
    println!("phase 2: in-flight tail drained bit-exact; v1 now answers every request");

    // Delivery 2 (fails): one chunk stays corrupted past the budget.
    let v2 = weights_for(2, dim);
    let manifest2 = DeploymentManifest::describe(MODEL, 2, &v2, CHUNK, &store)?;
    let budget = config.delivery_retries_or(mlcstt::api::DEFAULT_DELIVERY_RETRIES);
    let mut stream2 = ChaosStream::new(MemoryStream::from_weights(2, &v2, CHUNK))
        .corrupt_first(budget + 1)
        .on_chunk(0);
    let checks2 = canary_checks(&v2, dim, false)?;
    let err = deliver(&mut registry, &manifest2, &mut stream2, &checks2, &config, move |t| {
        LinearEngine::new(CLASSES, dim, BATCH, t[0].data.clone())
    })
    .expect_err("a chunk corrupted past the budget must fail the delivery");
    ensure!(
        matches!(err, DeliveryError::RetriesExhausted { chunk: 0, .. }),
        "expected RetriesExhausted on chunk 0, got: {err}"
    );
    ensure!(!pool.contains(&format!("{MODEL}@v2")), "failed staging must be withdrawn");
    served += replay(&registry, &v1_reference, dim, requests, &mut rng)?;
    println!("phase 3: exhausted delivery rolled back ({err}); v1 still serving bit-identically");

    // Delivery 3 (fails): clean stream, sabotaged canary expectations.
    let v3 = weights_for(3, dim);
    let manifest3 = DeploymentManifest::describe(MODEL, 3, &v3, CHUNK, &store)?;
    let mut stream3 = MemoryStream::from_weights(3, &v3, CHUNK);
    let checks3 = canary_checks(&v3, dim, true)?;
    let err3 = deliver(&mut registry, &manifest3, &mut stream3, &checks3, &config, move |t| {
        LinearEngine::new(CLASSES, dim, BATCH, t[0].data.clone())
    })
    .expect_err("a sabotaged canary must block the swap");
    ensure!(
        matches!(err3, DeliveryError::CanaryFailed { .. }),
        "expected CanaryFailed, got: {err3}"
    );
    ensure!(!pool.contains(&format!("{MODEL}@v3")), "canary-failed staging must be withdrawn");
    served += replay(&registry, &v1_reference, dim, requests, &mut rng)?;
    println!("phase 4: flaky canary rolled back ({err3}); v1 still serving bit-identically");

    let report = registry.shutdown();
    println!("\n{report}");
    ensure!(report.swaps == 1, "exactly one swap should have committed");
    ensure!(report.rollbacks == 2, "exactly two deliveries should have rolled back");
    ensure!(report.total_errors() == 0, "no request may error in this smoke");
    ensure!(report.total_shed() == 0, "no request may shed in this smoke");

    let doc = obj(vec![
        ("schema", Json::Str("mlcstt/delivery-smoke/v1".into())),
        ("weights_per_version", Json::from(n_weights)),
        ("chunks", Json::from(manifest.chunk_count())),
        ("served", Json::from(served)),
        ("dropped", Json::from(0usize)),
        ("mis_served", Json::from(0usize)),
        ("swaps", Json::Num(report.swaps as f64)),
        ("rollbacks", Json::Num(report.rollbacks as f64)),
        ("chunk_retries", Json::Num(report.delivery_retries as f64)),
        ("unavailable", Json::from(report.total_unavailable())),
        ("delivery", delivered.to_json()),
        ("exhausted_error", Json::Str(err.to_string())),
        ("canary_error", Json::Str(err3.to_string())),
    ]);
    let out_dir = mlcstt::api::env::bench_dir().unwrap_or_else(|| PathBuf::from("bench_out"));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let path = out_dir.join("DELIVERY_hot_swap.json");
    std::fs::write(&path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("wrote {}", path.display());
    println!("\nhot-swap chaos smoke PASSED: {served} served, 0 dropped, 0 mis-served");
    Ok(())
}
