//! Open-loop load test of the serving coordinator: Poisson arrivals at a
//! sweep of offered rates, measuring batch fill, p50/p99 latency, and
//! achieved throughput — the batcher characterization behind the §Perf
//! coordinator-overhead claim.
//!
//! ```bash
//! make artifacts && cargo run --offline --release --example load_test
//! ```
//!
//! Uses the faster inceptionmini artifact; `MLCSTT_RATES` (comma-separated
//! req/s) and `MLCSTT_REQUESTS` override the sweep.

use std::time::{Duration, Instant};

use anyhow::Result;

use mlcstt::api::{Config, Deployment};
use mlcstt::coordinator::{poisson_trace, Server};
use mlcstt::encoding::Policy;
use mlcstt::runtime::artifacts::{model_available, TestSet};
use mlcstt::stt::ErrorModel;

fn main() -> Result<()> {
    // MLCSTT_ARTIFACTS / MLCSTT_REQUESTS / MLCSTT_RATES resolve through
    // the layered config in one place.
    let config = Config::builder().max_wait(Duration::from_millis(25)).build();
    let dir = config.artifacts_dir().to_path_buf();
    let model = "inceptionmini";
    anyhow::ensure!(
        model_available(&dir, model),
        "{model}: run `make artifacts` first"
    );
    let requests = config.requests_or(96);
    let rates = config.rates_or(&[50.0, 200.0]);

    // The deployment owns encode -> store -> faults -> materialize; its
    // engine factory is re-used to pin a fresh worker per offered rate.
    let dep = Deployment::builder()
        .config(config.clone())
        .model(model)
        .policy(Policy::Hybrid)
        .granularity(4)
        .error_model(ErrorModel::at_rate(0.015))
        .build()?;
    let test = TestSet::read(&dir.join("testset.bin"))?;

    println!("open-loop Poisson load test — {model}, {requests} requests per rate");
    for rate in rates {
        let trace = poisson_trace(requests, rate, test.n, 0xBEEF);
        let server = Server::start(dep.engine_factory()?, config.server())?;

        let start = Instant::now();
        let mut tickets = Vec::with_capacity(trace.len());
        for (arrival, &idx) in trace.arrivals.iter().zip(&trace.image_idx) {
            if let Some(gap) = arrival.checked_sub(start.elapsed()) {
                std::thread::sleep(gap);
            }
            tickets.push(server.submit(test.image(idx).to_vec())?);
        }
        for t in tickets {
            t.wait()?;
        }
        let rep = server.shutdown();
        println!(
            "offered {rate:>6.0} req/s | served {} in {} batches (fill {:>4.1}) | p50 {:>7.1} ms p99 {:>7.1} ms | achieved {:>6.1} req/s",
            rep.served, rep.batches, rep.mean_batch_fill, rep.p50_ms, rep.p99_ms, rep.throughput_rps
        );
    }
    Ok(())
}
