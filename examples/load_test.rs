//! Overload characterization of the serving coordinator: closed- and
//! open-loop arrival processes swept past saturation, with bounded
//! admission, load shedding, and SLO accounting (DESIGN.md §11).
//!
//! ```bash
//! cargo run --offline --release --example load_test     # synthetic fallback
//! make artifacts && cargo run --offline --release --example load_test
//! ```
//!
//! Runs anywhere: with trained artifacts present the PJRT inceptionmini
//! engine is driven directly; without them a buffer-free `LinearEngine`
//! wrapped in a `ThrottledEngine` (fixed per-batch service time, so the
//! saturation point is known by construction) exercises the identical
//! serving path. The sweep:
//!
//! 1. **calibrate** — a closed-ish pipelined burst through a deep queue
//!    measures achieved saturation throughput;
//! 2. **open loop** — Poisson arrivals at each offered rate (default
//!    0.5×/1×/2×/4× the measured saturation; `MLCSTT_RATES` overrides
//!    with absolute req/s) against a *shallow* bounded queue
//!    (`MLCSTT_QUEUE_DEPTH`, default 32 here), counting sheds client-
//!    and server-side;
//! 3. **closed loop** — K client threads, one request in flight each
//!    (never sheds; the latency floor).
//!
//! Every run lands in `bench_out/LOAD_serving.json` with the same top
//! level as the `BENCH_*.json` pipeline (`bench`, `git_sha`, `records`;
//! core fields `name`/`n`/`median_ns`/`p95_ns`/`per_sec` map to served /
//! p50 / p95 / achieved rps) plus the SLO extension fields, so the
//! overload envelope is a tracked CI artifact.
//!
//! Environment (via `api::Config`): MLCSTT_REQUESTS (per rate point,
//! default 256), MLCSTT_RATES, MLCSTT_QUEUE_DEPTH, MLCSTT_MAX_WAIT_MS,
//! MLCSTT_ARTIFACTS, MLCSTT_THREADS, MLCSTT_BENCH_DIR.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use mlcstt::api::{Config, Deployment};
use mlcstt::coordinator::{
    poisson_trace, Admission, BatchClassifier, LinearEngine, RequestError, Server, ServerConfig,
    ServerReport, ThrottledEngine,
};
use mlcstt::encoding::Policy;
use mlcstt::runtime::artifacts::{model_available, TestSet};
use mlcstt::stt::ErrorModel;
use mlcstt::util::json::{self, Json};
use mlcstt::util::rng::Xoshiro256;

/// Shallow demo default for the bounded queue: deep enough for the
/// closed-loop clients, shallow enough that a 2x-saturation open loop
/// visibly sheds at a few hundred requests.
const DEMO_QUEUE_DEPTH: usize = 32;

/// Closed-loop client threads (each holds one request in flight).
const CLOSED_CLIENTS: usize = 4;

/// Synthetic-fallback geometry and per-batch service time: saturation is
/// BATCH / SERVICE = 8 / 4 ms = 2000 req/s by construction.
const CLASSES: usize = 8;
const DIM: usize = 64;
const BATCH: usize = 8;
const SERVICE: Duration = Duration::from_millis(4);

fn main() -> Result<()> {
    let config = Config::from_env();
    let requests = config.requests_or(256);
    let dir = config.artifacts_dir().to_path_buf();
    let model = "inceptionmini";

    let records = if model_available(&dir, model) {
        println!("load test — PJRT {model} engine, {requests} requests per rate point");
        let dep = Deployment::builder()
            .config(config.clone())
            .model(model)
            .policy(Policy::Hybrid)
            .granularity(4)
            .error_model(ErrorModel::at_rate(0.015))
            .build()?;
        let test = TestSet::read(&dir.join("testset.bin"))?;
        let pool: Vec<Vec<f32>> = (0..test.n).map(|i| test.image(i).to_vec()).collect();
        campaign(&config, "pjrt", requests, &pool, || dep.engine_factory())?
    } else {
        println!(
            "load test — no artifacts; synthetic throttled LinearEngine \
             (saturation {} req/s by construction), {requests} requests per rate point",
            BATCH as u64 * 1000 / SERVICE.as_millis() as u64
        );
        let mut rng = Xoshiro256::seeded(41);
        let weights: Vec<f32> = (0..CLASSES * DIM)
            .map(|_| if rng.chance(0.5) { 0.5 } else { -0.5 })
            .collect();
        let pool: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..DIM).map(|_| (rng.next_gaussian() * 0.5) as f32).collect())
            .collect();
        campaign(&config, "synthetic", requests, &pool, move || {
            let w = weights.clone();
            Ok(move || {
                let inner = LinearEngine::new(CLASSES, DIM, BATCH, w)?;
                Ok(ThrottledEngine::new(inner, SERVICE))
            })
        })?
    };

    // Same sink as the bench_report pipeline: LOAD_*.json next to
    // BENCH_*.json under MLCSTT_BENCH_DIR (default bench_out/), anchored
    // at the workspace root.
    let out_dir = bench_out_dir();
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let path = out_dir.join("LOAD_serving.json");
    let doc = json::obj(vec![
        ("bench", "load_serving".into()),
        ("git_sha", Json::Str(git_sha())),
        ("records", Json::Arr(records)),
    ]);
    let mut text = doc.to_string_pretty();
    text.push('\n');
    std::fs::write(&path, text).with_context(|| format!("writing {}", path.display()))?;
    println!("load_report: wrote {}", path.display());
    Ok(())
}

/// The full sweep against one engine source: calibrate, open-loop rate
/// sweep, closed-loop floor. `mk` yields a fresh worker-thread factory
/// per server start (one pinned server per run).
fn campaign<C, F, M>(
    config: &Config,
    source: &str,
    requests: usize,
    pool: &[Vec<f32>],
    mk: M,
) -> Result<Vec<Json>>
where
    C: BatchClassifier,
    F: FnOnce() -> Result<C> + Send + 'static,
    M: Fn() -> Result<F>,
{
    let mut records = Vec::new();

    // --- 1. Calibrate: pipelined burst through a queue deep enough to
    // never shed; achieved throughput ~= saturation.
    let cal_n = requests.clamp(16, 64);
    let mut deep = config.server();
    deep.queue_depth = cal_n + CLOSED_CLIENTS;
    let server = Server::start(mk()?, deep)?;
    let mut tickets = Vec::with_capacity(cal_n);
    for i in 0..cal_n {
        tickets.push(server.submit(pool[i % pool.len()].clone())?.ticket()?);
    }
    for t in tickets {
        t.wait()?;
    }
    let cal = server.shutdown();
    let saturation = cal.throughput_rps.max(1.0);
    println!(
        "calibration: {} requests -> saturation ~{saturation:.0} req/s (p50 {:.1} ms)",
        cal.served, cal.p50_ms
    );
    records.push(record(&format!("{source}:calibrate"), "closed-burst", saturation, &cal));

    // --- 2. Open loop at each offered rate. MLCSTT_RATES gives absolute
    // req/s; the default sweep brackets the measured saturation so the
    // 2x/4x points exercise shedding.
    let rates = config.rates_or(&[]);
    let rates = if rates.is_empty() {
        vec![0.5 * saturation, saturation, 2.0 * saturation, 4.0 * saturation]
    } else {
        rates
    };
    let shallow = {
        let mut s = config.server();
        s.queue_depth = config.queue_depth_or(DEMO_QUEUE_DEPTH);
        s
    };
    for (ri, &rate) in rates.iter().enumerate() {
        let rep = open_loop(mk()?, shallow.clone(), pool, requests, rate, 0xBEEF ^ ri as u64)?;
        println!(
            "open  {rate:>8.0} req/s offered | served {:>5} shed {:>5} err {:>3} | \
             fill {:>4.1} | p50 {:>7.1} p95 {:>7.1} p99 {:>7.1} ms | q.max {:>3} | achieved {:>7.1} req/s",
            rep.served,
            rep.shed,
            rep.errors,
            rep.mean_batch_fill,
            rep.p50_ms,
            rep.p95_ms,
            rep.p99_ms,
            rep.queue_max,
            rep.throughput_rps
        );
        records.push(record(&format!("{source}:open@{rate:.0}"), "open", rate, &rep));
    }

    // --- 3. Closed loop: K clients, one request in flight each — the
    // latency floor, and by construction shed-free.
    let rep = closed_loop(mk()?, shallow, pool, requests)?;
    println!(
        "closed {CLOSED_CLIENTS} clients          | served {:>5} shed {:>5} | p50 {:>7.1} p99 {:>7.1} ms | achieved {:>7.1} req/s",
        rep.served, rep.shed, rep.p50_ms, rep.p99_ms, rep.throughput_rps
    );
    records.push(record(
        &format!("{source}:closed@{CLOSED_CLIENTS}"),
        "closed",
        rep.throughput_rps,
        &rep,
    ));
    Ok(records)
}

/// Open loop: Poisson arrivals at `rate` req/s; a shed or slow server
/// never delays the arrival process. Returns the server's report (its
/// shed counter is cross-checked against the client-side count).
fn open_loop<C, F>(
    factory: F,
    cfg: ServerConfig,
    pool: &[Vec<f32>],
    requests: usize,
    rate: f64,
    seed: u64,
) -> Result<ServerReport>
where
    C: BatchClassifier,
    F: FnOnce() -> Result<C> + Send + 'static,
{
    let server = Server::start(factory, cfg)?;
    let trace = poisson_trace(requests, rate, pool.len(), seed);
    let start = Instant::now();
    let mut tickets = Vec::with_capacity(requests);
    let mut client_shed = 0usize;
    for (arrival, &idx) in trace.arrivals.iter().zip(&trace.image_idx) {
        if let Some(gap) = arrival.checked_sub(start.elapsed()) {
            std::thread::sleep(gap);
        }
        match server.submit(pool[idx].clone())? {
            Admission::Accepted(t) => tickets.push(t),
            Admission::Rejected { .. } => client_shed += 1,
        }
    }
    let mut engine_errors = 0usize;
    for t in tickets {
        match t.wait() {
            Ok(_) => {}
            Err(RequestError::Engine { .. }) => engine_errors += 1,
            Err(e) => anyhow::bail!("unexpected request outcome: {e}"),
        }
    }
    let rep = server.shutdown();
    anyhow::ensure!(
        rep.shed == client_shed && rep.errors == engine_errors,
        "accounting drift: server {} shed / {} errors vs client {client_shed} / {engine_errors}",
        rep.shed,
        rep.errors
    );
    Ok(rep)
}

/// Closed loop: `CLOSED_CLIENTS` scoped threads sharing the server, each
/// submitting its next request only after the previous answer.
fn closed_loop<C, F>(
    factory: F,
    cfg: ServerConfig,
    pool: &[Vec<f32>],
    requests: usize,
) -> Result<ServerReport>
where
    C: BatchClassifier,
    F: FnOnce() -> Result<C> + Send + 'static,
{
    let server = Server::start(factory, cfg)?;
    let per_client = requests.div_ceil(CLOSED_CLIENTS);
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for c in 0..CLOSED_CLIENTS {
            let server = &server;
            handles.push(scope.spawn(move || -> Result<()> {
                for i in 0..per_client {
                    let img = pool[(c * per_client + i) % pool.len()].clone();
                    server.submit(img)?.ticket()?.wait()?;
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("client thread panicked")?;
        }
        Ok(())
    })?;
    Ok(server.shutdown())
}

/// One LOAD record: the five core `BENCH_*.json` fields (`name`, `n`,
/// `median_ns`, `p95_ns`, `per_sec`) mapped onto serving terms, plus the
/// SLO extension fields.
fn record(name: &str, mode: &str, offered_rps: f64, r: &ServerReport) -> Json {
    json::obj(vec![
        ("name", name.into()),
        ("n", Json::Num(r.served as f64)),
        ("median_ns", Json::Num(r.p50_ms * 1e6)),
        ("p95_ns", Json::Num(r.p95_ms * 1e6)),
        ("per_sec", Json::Num(r.throughput_rps)),
        ("mode", mode.into()),
        ("offered_rps", Json::Num(offered_rps)),
        ("served", Json::Num(r.served as f64)),
        ("shed", Json::Num(r.shed as f64)),
        ("errors", Json::Num(r.errors as f64)),
        ("batches", Json::Num(r.batches as f64)),
        ("mean_batch_fill", Json::Num(r.mean_batch_fill)),
        ("p50_ms", Json::Num(r.p50_ms)),
        ("p95_ms", Json::Num(r.p95_ms)),
        ("p99_ms", Json::Num(r.p99_ms)),
        ("queue_mean", Json::Num(r.queue_mean)),
        ("queue_max", Json::Num(r.queue_max as f64)),
        ("wall_s", Json::Num(r.wall_s)),
    ])
}

/// Where LOAD_*.json lands: MLCSTT_BENCH_DIR (default `bench_out/`),
/// relative values anchored at the workspace root (mirrors the bench
/// harness; examples cannot include `benches/harness.rs`).
fn bench_out_dir() -> PathBuf {
    let p = mlcstt::api::env::bench_dir().unwrap_or_else(|| PathBuf::from("bench_out"));
    if p.is_absolute() {
        return p;
    }
    let root = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(m) => {
            let m = PathBuf::from(m);
            m.parent().map(|x| x.to_path_buf()).unwrap_or(m)
        }
        Err(_) => PathBuf::from("."),
    };
    root.join(p)
}

/// Current commit: `GITHUB_SHA` in CI, `git rev-parse` locally.
fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        return sha;
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| "unknown".to_string())
}
