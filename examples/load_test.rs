//! Open-loop load test of the serving coordinator: Poisson arrivals at a
//! sweep of offered rates, measuring batch fill, p50/p99 latency, and
//! achieved throughput — the batcher characterization behind the §Perf
//! coordinator-overhead claim.
//!
//! ```bash
//! make artifacts && cargo run --offline --release --example load_test
//! ```
//!
//! Uses the faster inceptionmini artifact; `MLCSTT_RATES` (comma-separated
//! req/s) and `MLCSTT_REQUESTS` override the sweep.

use std::time::{Duration, Instant};

use anyhow::Result;

use mlcstt::coordinator::{
    poisson_trace, InferenceEngine, Server, ServerConfig, StoreConfig, WeightStore,
};
use mlcstt::encoding::Policy;
use mlcstt::experiments::load_model;
use mlcstt::runtime::artifacts::{model_available, model_paths, TestSet};
use mlcstt::runtime::Executor;
use mlcstt::stt::ErrorModel;

fn main() -> Result<()> {
    let dir = std::path::PathBuf::from(
        std::env::var("MLCSTT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let model = "inceptionmini";
    anyhow::ensure!(
        model_available(&dir, model),
        "{model}: run `make artifacts` first"
    );
    let requests: usize = std::env::var("MLCSTT_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);
    let rates: Vec<f64> = std::env::var("MLCSTT_RATES")
        .unwrap_or_else(|_| "50,200".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();

    let (manifest, weights) = load_model(&dir, model)?;
    let test = TestSet::read(&dir.join("testset.bin"))?;
    let cfg = StoreConfig {
        policy: Policy::Hybrid,
        granularity: 4,
        error_model: ErrorModel::at_rate(0.015),
        ..StoreConfig::default()
    };
    let mut store = WeightStore::load(&cfg, &weights)?;
    let tensors = store.materialize()?;

    println!("open-loop Poisson load test — {model}, {requests} requests per rate");
    for rate in rates {
        let trace = poisson_trace(requests, rate, test.n, 0xBEEF);
        let tensors = tensors.clone();
        let manifest2 = manifest.clone();
        let (hlo, _, _) = model_paths(&dir, model);
        let server = Server::start(
            move || {
                let exec = Executor::from_hlo_file(&hlo)?;
                InferenceEngine::new(exec, manifest2, &tensors)
            },
            ServerConfig {
                max_wait: Duration::from_millis(25),
                ..ServerConfig::default()
            },
        )?;

        let start = Instant::now();
        let mut tickets = Vec::with_capacity(trace.len());
        for (arrival, &idx) in trace.arrivals.iter().zip(&trace.image_idx) {
            if let Some(gap) = arrival.checked_sub(start.elapsed()) {
                std::thread::sleep(gap);
            }
            tickets.push(server.submit(test.image(idx).to_vec())?);
        }
        for t in tickets {
            t.wait()?;
        }
        let rep = server.shutdown();
        println!(
            "offered {rate:>6.0} req/s | served {} in {} batches (fill {:>4.1}) | p50 {:>7.1} ms p99 {:>7.1} ms | achieved {:>6.1} req/s",
            rep.served, rep.batches, rep.mean_batch_fill, rep.p50_ms, rep.p99_ms, rep.throughput_rps
        );
    }
    Ok(())
}
