//! Quickstart: the paper's scheme on a handful of weights, no artifacts
//! needed.
//!
//! ```bash
//! cargo run --offline --release --example quickstart
//! ```
//!
//! Walks one weight through sign protection + the three reformations
//! (reproducing the paper's Table 2 examples bit-for-bit), then encodes a
//! small tensor, injects faults at the published error rate, and shows what
//! the protection buys.

use mlcstt::encoding::{scheme, Policy, Scheme, WeightCodec};
use mlcstt::fp;
use mlcstt::stt::{AccessKind, CostModel, ErrorModel};
use mlcstt::util::rng::Xoshiro256;

fn cells_str(h: u16) -> String {
    fp::cells(h)
        .iter()
        .map(|c| format!("{c:02b}"))
        .collect::<Vec<_>>()
        .join(" ")
}

fn main() {
    // --- Table 2, live. -----------------------------------------------
    println!("== the paper's Table 2, recomputed ==");
    for w in [0.004222f32, 0.020614, 0.0004982] {
        let h = fp::f32_to_f16_bits(w);
        let p = scheme::protect_sign(h);
        println!("\nweight {w}  ->  f16 {:#06x}", h);
        for s in Scheme::ALL {
            let img = scheme::apply(s, p);
            let soft = fp::soft_cells(img);
            println!("  {:<8} {}   soft cells: {soft}", format!("{s:?}"), cells_str(img));
        }
        let (best, soft) = mlcstt::encoding::select_scheme(Policy::Hybrid, &[p]);
        println!("  best: {best:?} ({soft} soft cells)");
    }

    // --- A tensor through the full pipeline. ---------------------------
    println!("\n== 10k-weight tensor, fault injection at 2e-2 ==");
    let mut rng = Xoshiro256::seeded(1);
    let weights: Vec<f32> = (0..10_000)
        .map(|_| ((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
        .collect();

    let cost = CostModel::default();
    let err = ErrorModel::at_rate(0.02);
    for policy in Policy::ALL {
        let codec = WeightCodec::new(policy, 4);
        let mut enc = codec.encode(&weights);
        let write = enc.access_energy(&cost, AccessKind::Write);

        // Fault the stored image, then decode and count damage.
        let mut frng = Xoshiro256::seeded(42);
        for w in enc.words.iter_mut() {
            *w = err.corrupt_word_write(*w, &mut frng);
        }
        let decoded = enc.decode();
        let sign_flips = weights
            .iter()
            .zip(&decoded)
            .filter(|(a, b)| a.is_sign_negative() != b.is_sign_negative() && **a != 0.0)
            .count();
        let max_err = weights
            .iter()
            .zip(&decoded)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!(
            "{:<18} soft cells {:>6}  write {:>8.1} nJ  sign flips {:>3}  max |err| {:.4}",
            policy.label(),
            enc.soft_cells(),
            write.nanojoules,
            sign_flips,
            max_err
        );
    }
    println!("\nsign-protected systems flip zero signs: cell 0 holds 00/11,");
    println!("the immune base states — that is the whole trick, for free.");
}
