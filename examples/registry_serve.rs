//! Multi-model serving demo: several named deployments behind one
//! `api::ModelRegistry`, requests routed by model tag, one report section
//! per model (DESIGN.md §10).
//!
//! ```bash
//! make serve-demo        # == cargo run --release --offline --example registry_serve
//! ```
//!
//! Runs anywhere: with trained artifacts present every available Mini-net
//! is deployed through the MLC buffer (hybrid, g=4, published 1.5e-2
//! rate) and served through PJRT; without them the demo serves two
//! pure-host linear classifiers from one **shared multi-tenant buffer
//! pool** deliberately sized too small for both — the workload ping-pongs
//! the pool, and the report shows the absorbed evict→rebuild stalls plus
//! the per-bank "buffer lifetime under traffic" wear table (DESIGN.md
//! §12).
//!
//! Environment (via `api::Config`): MLCSTT_REQUESTS (total replay length,
//! default 96), MLCSTT_ARTIFACTS, MLCSTT_THREADS, and the pool knobs
//! MLCSTT_POOL_KB / MLCSTT_POOL_BANKS / MLCSTT_POOL_EXTENT /
//! MLCSTT_EVICT (default geometry: 1.5 KB, 4 banks, 128-word extents,
//! LRU).

use std::time::Duration;

use anyhow::Result;

use mlcstt::api::{BufferPool, Config, Deployment, ModelRegistry};
use mlcstt::coordinator::{LinearEngine, StoreConfig};
use mlcstt::encoding::Policy;
use mlcstt::runtime::artifacts::{model_available, ParamSpec, TestSet, WeightFile};
use mlcstt::stt::ErrorModel;
use mlcstt::util::rng::Xoshiro256;

fn main() -> Result<()> {
    let config = Config::builder().max_wait(Duration::from_millis(5)).build();
    let requests = config.requests_or(96);
    let dir = config.artifacts_dir().to_path_buf();

    let artifact_models: Vec<&str> = ["vggmini", "inceptionmini"]
        .into_iter()
        .filter(|m| model_available(&dir, m))
        .collect();

    if artifact_models.is_empty() {
        println!("(no artifacts — serving two linear models from one shared buffer pool)\n");
        return serve_pooled(&config, requests);
    }

    // One deployment per artifact model, all behind one registry.
    let mut registry = ModelRegistry::new();
    let mut deployments = Vec::new();
    for model in &artifact_models {
        let dep = Deployment::builder()
            .config(config.clone())
            .model(*model)
            .policy(Policy::Hybrid)
            .granularity(4)
            .error_model(ErrorModel::at_rate(0.015))
            .seed(11)
            .build()?;
        let sr = dep.store_report();
        println!(
            "{model}: {} tensors / {} weights staged through the MLC buffer ({} faulted cells)",
            sr.tensors, sr.weights, sr.injected_faults
        );
        registry.register_deployment(&dep, config.server())?;
        deployments.push(dep);
    }

    // Interleave tagged requests round-robin across the models.
    let test = TestSet::read(&dir.join("testset.bin"))?;
    let mut rng = Xoshiro256::seeded(3);
    let mut tickets = Vec::with_capacity(requests);
    for r in 0..requests {
        let model = artifact_models[r % artifact_models.len()];
        let i = rng.below(test.n as u64) as usize;
        tickets.push(registry.submit(model, test.image(i).to_vec())?.ticket()?);
    }
    for t in tickets {
        t.wait()?;
    }
    println!("\nper-model serving report:\n{}", registry.shutdown());
    Ok(())
}

/// Backend-free fallback: two linear classifiers sharing one multi-tenant
/// buffer pool sized for only one of them. Each model's weight matrix is
/// admitted once; under traffic the least-recently-served model is
/// evicted and transparently rebuilt (bit-identical weights and bills)
/// the next time its worker needs it — the `rebuilds` column and the wear
/// table in the final report are the point of the demo.
fn serve_pooled(config: &Config, requests: usize) -> Result<()> {
    const CLASSES: usize = 8;
    const DIM: usize = 64;
    const BATCH: usize = 8;

    // Both models need 4 extents (512 words / 128); the default pool has
    // 6, so at most one model is resident at a time.
    let pool = BufferPool::from_config(config)
        .unwrap_or_else(|| BufferPool::new(1536, 4, 128, config.evict_policy()));
    println!(
        "shared pool: {} extents of {} words across 4 banks, evict={:?}",
        pool.free_extents(),
        pool.extent_words(),
        config.evict_policy(),
    );

    let mut registry = ModelRegistry::new().with_pool(pool.clone());
    for (name, rate, seed) in [("linear-clean", 0.0, 1u64), ("linear-faulted", 0.02, 2)] {
        let mut rng = Xoshiro256::seeded(seed);
        let weights: Vec<f32> = (0..CLASSES * DIM)
            .map(|_| if rng.chance(0.5) { 0.5 } else { -0.5 })
            .collect();
        let store_cfg = StoreConfig {
            error_model: ErrorModel::at_rate(rate),
            seed,
            ..StoreConfig::default()
        };
        let wf = WeightFile {
            params: vec![ParamSpec {
                name: "classifier.w".into(),
                shape: vec![CLASSES, DIM],
                data: weights,
            }],
        };
        let sr = pool.admit(name, &store_cfg, &wf)?;
        println!(
            "{name}: {} weights admitted to the pool, {} faulted cells",
            sr.weights, sr.injected_faults
        );
        registry.register_pooled(
            name,
            move |tensors: &[ParamSpec]| {
                LinearEngine::new(CLASSES, DIM, BATCH, tensors[0].data.clone())
            },
            config.server(),
        )?;
    }

    let mut rng = Xoshiro256::seeded(7);
    let mut tickets = Vec::with_capacity(requests);
    for r in 0..requests {
        let tag = if r % 2 == 0 { "linear-clean" } else { "linear-faulted" };
        let image: Vec<f32> = (0..DIM)
            .map(|_| (rng.next_gaussian() * 0.5) as f32)
            .collect();
        tickets.push(registry.submit(tag, image)?.ticket()?);
    }
    for t in tickets {
        t.wait()?;
    }

    let report = registry.shutdown();
    println!("\nper-model serving report:\n{report}");
    println!(
        "pool: {} rebuilds absorbed, wear spread {:.2}",
        pool.rebuilds(),
        pool.wear_spread()
    );
    Ok(())
}
