//! Multi-model serving demo: several named deployments behind one
//! `api::ModelRegistry`, requests routed by model tag, one report section
//! per model (DESIGN.md §10).
//!
//! ```bash
//! make serve-demo        # == cargo run --release --offline --example registry_serve
//! ```
//!
//! Runs anywhere: with trained artifacts present every available Mini-net
//! is deployed through the MLC buffer (hybrid, g=4, published 1.5e-2
//! rate) and served through PJRT; without them the demo falls back to two
//! pure-host linear classifiers whose weight matrices still live in the
//! simulated buffer — same registry, same routing contract, no backend.
//!
//! Environment (via `api::Config`): MLCSTT_REQUESTS (total replay length,
//! default 96), MLCSTT_ARTIFACTS, MLCSTT_THREADS.

use std::time::Duration;

use anyhow::Result;

use mlcstt::api::{Config, Deployment, ModelRegistry};
use mlcstt::coordinator::LinearEngine;
use mlcstt::encoding::Policy;
use mlcstt::runtime::artifacts::{model_available, ParamSpec, TestSet, WeightFile};
use mlcstt::stt::ErrorModel;
use mlcstt::util::rng::Xoshiro256;

fn main() -> Result<()> {
    let config = Config::builder().max_wait(Duration::from_millis(5)).build();
    let requests = config.requests_or(96);
    let dir = config.artifacts_dir().to_path_buf();

    let artifact_models: Vec<&str> = ["vggmini", "inceptionmini"]
        .into_iter()
        .filter(|m| model_available(&dir, m))
        .collect();

    if artifact_models.is_empty() {
        println!("(no artifacts — serving two buffer-backed linear models instead)\n");
        return serve_synthetic(&config, requests);
    }

    // One deployment per artifact model, all behind one registry.
    let mut registry = ModelRegistry::new();
    let mut deployments = Vec::new();
    for model in &artifact_models {
        let dep = Deployment::builder()
            .config(config.clone())
            .model(*model)
            .policy(Policy::Hybrid)
            .granularity(4)
            .error_model(ErrorModel::at_rate(0.015))
            .seed(11)
            .build()?;
        let sr = dep.store_report();
        println!(
            "{model}: {} tensors / {} weights staged through the MLC buffer ({} faulted cells)",
            sr.tensors, sr.weights, sr.injected_faults
        );
        registry.register_deployment(&dep, config.server())?;
        deployments.push(dep);
    }

    // Interleave tagged requests round-robin across the models.
    let test = TestSet::read(&dir.join("testset.bin"))?;
    let mut rng = Xoshiro256::seeded(3);
    let mut tickets = Vec::with_capacity(requests);
    for r in 0..requests {
        let model = artifact_models[r % artifact_models.len()];
        let i = rng.below(test.n as u64) as usize;
        tickets.push(registry.submit(model, test.image(i).to_vec())?.ticket()?);
    }
    for t in tickets {
        t.wait()?;
    }
    println!("\nper-model serving report:\n{}", registry.shutdown());
    Ok(())
}

/// Backend-free fallback: two linear classifiers whose weight matrices go
/// through the simulated MLC buffer (one clean, one faulted) before
/// serving — the registry path exercised end to end with zero PJRT.
fn serve_synthetic(config: &Config, requests: usize) -> Result<()> {
    const CLASSES: usize = 8;
    const DIM: usize = 64;
    const BATCH: usize = 8;

    let mut registry = ModelRegistry::new();
    for (name, rate, seed) in [("linear-clean", 0.0, 1u64), ("linear-faulted", 0.02, 2)] {
        let mut rng = Xoshiro256::seeded(seed);
        let weights: Vec<f32> = (0..CLASSES * DIM)
            .map(|_| if rng.chance(0.5) { 0.5 } else { -0.5 })
            .collect();
        // Stage the matrix through the buffer like any model tensor.
        let dep = Deployment::builder()
            .config(config.clone())
            .name(name)
            .weights(WeightFile {
                params: vec![ParamSpec {
                    name: "classifier.w".into(),
                    shape: vec![CLASSES, DIM],
                    data: weights,
                }],
            })
            .error_model(ErrorModel::at_rate(rate))
            .seed(seed)
            .build()?;
        let sr = dep.store_report();
        println!(
            "{name}: {} weights through the buffer, {} faulted cells",
            sr.weights, sr.injected_faults
        );
        let stored = dep.tensors()[0].data.clone();
        registry.register(
            name,
            move || LinearEngine::new(CLASSES, DIM, BATCH, stored),
            config.server(),
        )?;
    }

    let mut rng = Xoshiro256::seeded(7);
    let mut tickets = Vec::with_capacity(requests);
    for r in 0..requests {
        let tag = if r % 2 == 0 { "linear-clean" } else { "linear-faulted" };
        let image: Vec<f32> = (0..DIM)
            .map(|_| (rng.next_gaussian() * 0.5) as f32)
            .collect();
        tickets.push(registry.submit(tag, image)?.ticket()?);
    }
    for t in tickets {
        t.wait()?;
    }
    println!("\nper-model serving report:\n{}", registry.shutdown());
    Ok(())
}
