//! Bandwidth study (paper Fig. 9) across all four layer tables, plus the
//! per-layer mechanism view: which layers become off-chip-cheap when the
//! same-area MLC STT-RAM buffer replaces SRAM.
//!
//! ```bash
//! cargo run --offline --release --example bandwidth_study
//! ```

use mlcstt::metrics::Table;
use mlcstt::models;
use mlcstt::systolic::{simulate_network, ArrayConfig};

fn main() {
    for net in ["vgg16", "inceptionv3", "vggmini", "inceptionmini"] {
        let layers: Vec<_> = models::by_name(net)
            .unwrap()
            .into_iter()
            .filter(|l| l.h > 1)
            .collect();
        let mut t = Table::new(
            &format!("{net}: per-layer off-chip bytes/cycle vs buffer size"),
            &["layer", "256KB(SRAM)", "512KB", "1024KB", "2048KB", "util%"],
        );
        let cfgs: Vec<ArrayConfig> = [256usize, 512, 1024, 2048]
            .iter()
            .map(|kb| ArrayConfig::new(kb * 1024))
            .collect();
        let all: Vec<Vec<_>> = cfgs.iter().map(|c| simulate_network(&layers, c)).collect();
        for (i, layer) in layers.iter().enumerate() {
            let util = all[0][i].utilization(&cfgs[0]);
            t.row(vec![
                layer.name.clone(),
                format!("{:.2}", all[0][i].offchip_bpc()),
                format!("{:.2}", all[1][i].offchip_bpc()),
                format!("{:.2}", all[2][i].offchip_bpc()),
                format!("{:.2}", all[3][i].offchip_bpc()),
                format!("{:.0}", 100.0 * util),
            ]);
        }
        println!("{t}");
    }
    println!(
        "reading: early layers are ofmap/ifmap-bound (flat rows); the deep\n\
         512-channel layers are weight-bound and drop sharply once the ifmap\n\
         fits on-chip — the paper's Fig. 9 story."
    );
}
