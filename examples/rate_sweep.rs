//! Fig. 8-style accuracy-vs-error-rate sweep through the snapshot-reuse
//! campaign API (`experiments::run_rate_sweep_with`, DESIGN.md §9): each
//! policy's image is encoded and stored **once**; every rate point only
//! rewinds the stored words and re-injects faults before materializing
//! through the pipelined serve path.
//!
//! ```bash
//! make sweep                 # == cargo run --release --offline --example rate_sweep
//! ```
//!
//! Runs anywhere: with trained artifacts present it sweeps the real model
//! through PJRT (`experiments::run_rate_sweep`); without them it falls
//! back to a synthetic trained-shaped tensor and scores weight fidelity
//! (fraction of weights decoded bit-identically to clean) instead of
//! model accuracy — same sweep machinery, same one-encode contract.

use mlcstt::api::Config;
use mlcstt::coordinator::StoreConfig;
use mlcstt::experiments::{rate_sweep_table, run_rate_sweep, run_rate_sweep_with};
use mlcstt::fp;
use mlcstt::runtime::artifacts::{model_available, ParamSpec, WeightFile};
use mlcstt::util::rng::Xoshiro256;

const RATES: [f64; 5] = [0.0, 0.005, 0.01, 0.015, 0.02];
const SEED: u64 = 7;

fn main() -> anyhow::Result<()> {
    // MLCSTT_ARTIFACTS / MLCSTT_EVAL resolve through the layered config.
    let config = Config::from_env();
    let dir = config.artifacts_dir().to_path_buf();

    if model_available(&dir, "vggmini") {
        let sweep = run_rate_sweep(&dir, "vggmini", &RATES, 4, config.eval_or(512), SEED)?;
        println!("{}", sweep.table);
        println!(
            "(encode+store passes: {} — one per policy for all {} rate points)",
            sweep.encode_passes,
            RATES.len()
        );
        return Ok(());
    }

    println!("(vggmini artifacts missing — sweeping a synthetic tensor, fidelity metric)\n");
    let n = config.eval_or(1 << 18);
    let mut rng = Xoshiro256::seeded(SEED);
    let weights = WeightFile {
        params: vec![ParamSpec {
            name: "synthetic.w".into(),
            shape: vec![n],
            data: (0..n)
                .map(|_| ((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
                .collect(),
        }],
    };
    let base = StoreConfig {
        granularity: 4,
        seed: SEED,
        ..StoreConfig::default()
    };
    let clean = &weights.params[0].data;
    let (points, encode_passes) =
        run_rate_sweep_with(&weights, &base, &RATES, |_, _, tensors, _| {
            let same = clean
                .iter()
                .zip(&tensors[0].data)
                .filter(|(a, b)| fp::quantize_f16(**a).to_bits() == b.to_bits())
                .count();
            Ok(same as f64 / clean.len() as f64)
        })?;
    println!(
        "{}",
        rate_sweep_table(
            &format!("synthetic ({n} weights, g=4, seed={SEED}) — weight fidelity"),
            1.0,
            &points,
        )
    );
    println!(
        "(encode+store passes: {encode_passes} — one per policy for all {} rate points)",
        RATES.len()
    );
    Ok(())
}
