//! Retention demo + CI gate for the background scrubbing subsystem
//! (DESIGN.md §15): the same weight image ages in two MLC buffers under
//! identical retention faults — one buffer is scrubbed every cycle, the
//! other is neglected — and only the scrubbed one still decodes
//! bit-identically to the trained weights at the end.
//!
//! ```bash
//! make scrub-demo        # == cargo run --release --offline --example scrub_retention
//! ```
//!
//! Self-contained (no trained artifacts): a synthetic linear classifier's
//! weights are encoded once, stored into twin buffers, and aged for
//! `CYCLES` disturb rounds at a deliberately hot soft-error rate. Each
//! round the scrubbed twin runs one scrub pass — golden-checksum
//! detection, in-place repair from the clean image, per-bank EWMA
//! telemetry. The gate:
//!
//! 1. the scrubbed twin's final decode is **bit-identical** to the clean
//!    weights (fidelity 1.0) and classifies a probe set exactly like the
//!    clean reference;
//! 2. the neglected twin has accumulated decode damage (fidelity < 1.0)
//!    — the decay the scrubber exists to hold back;
//! 3. the online EWMA primed and tracked a nonzero corrected-flip rate.
//!
//! The process exits non-zero if any of that fails — this is the CI
//! retention gate. Writes `SCRUB_retention.json` (fidelities, agreement
//! counts, telemetry) to `$MLCSTT_BENCH_DIR` (default `bench_out/`).
//!
//! Environment (via `api::Config`): MLCSTT_EVAL scales the weight count
//! (default 4096), plus the usual pool-free buffer knobs. The scrub
//! schedule here is driven explicitly (one pass per cycle) so the demo
//! is deterministic; the scheduler policies are pinned in
//! `rust/tests/scrub.rs`.

use std::path::PathBuf;

use anyhow::{ensure, Context, Result};

use mlcstt::api::Config;
use mlcstt::buffer::{shard_checksums, BufferConfig, MlcBuffer};
use mlcstt::coordinator::LinearEngine;
use mlcstt::encoding::{protection_for, Policy, WeightCodec};
use mlcstt::fp;
use mlcstt::scrub::RateEstimator;
use mlcstt::stt::ErrorModel;
use mlcstt::util::json::{obj, Json};
use mlcstt::util::rng::Xoshiro256;

const CLASSES: usize = 8;
const BANKS: usize = 16;
const CYCLES: usize = 8;
const RATE: f64 = 0.02;
const SEED: u64 = 0x5C12B;

fn main() -> Result<()> {
    let config = Config::from_env();
    let dim = (config.eval_or(4096) / CLASSES).max(16);
    let granularity = 4;

    // Trained-like weights, encoded once: this clean image is both the
    // repair source and the fidelity oracle.
    let mut rng = Xoshiro256::seeded(SEED);
    let weights: Vec<f32> = (0..CLASSES * dim)
        .map(|_| fp::quantize_f16(((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0)))
        .collect();
    let enc = WeightCodec::new(Policy::Hybrid, granularity).encode(&weights);
    let golden = shard_checksums(&enc.words);
    let prot = protection_for(Policy::Hybrid, granularity);
    let mut clean_decode = Vec::new();
    enc.decode_into(&mut clean_decode);

    // Twin buffers, same geometry and seed: identical disturb streams,
    // so the only difference between them is the scrubbing.
    let mk = || -> Result<(MlcBuffer, mlcstt::buffer::Region)> {
        let cfg = BufferConfig::new(enc.len() * 2, BANKS)
            .with_error_model(ErrorModel::at_rate(0.0));
        let mut buf = MlcBuffer::new(cfg, SEED ^ 0xA6E);
        let region = buf.store(&enc).map_err(anyhow::Error::from)?;
        Ok((buf, region))
    };
    let (mut scrubbed, sregion) = mk()?;
    let (mut neglected, nregion) = mk()?;

    println!(
        "aging {} weights (hybrid/g{granularity}) for {CYCLES} cycles at rate {RATE}: \
         scrubbed twin vs neglected twin",
        CLASSES * dim,
    );
    let model = ErrorModel::at_rate(RATE);
    let mut estimator = RateEstimator::new(BANKS);
    let mut corrected_words = 0u64;
    let mut dirty_shards = 0u64;
    for cycle in 0..CYCLES {
        // Same seed stream on both twins; the flip *counts* may differ
        // after cycle 0 because corruption is content-dependent (the
        // vulnerable-cell mask of an already-corrupted word differs).
        let fs: u64 = scrubbed
            .corrupt_region_write_shards(&sregion, &model, 2)
            .map_err(anyhow::Error::from)?
            .iter()
            .sum();
        let fnn: u64 = neglected
            .corrupt_region_write_shards(&nregion, &model, 2)
            .map_err(anyhow::Error::from)?
            .iter()
            .sum();
        let pass = scrubbed
            .scrub_region(&sregion, &enc.words, &golden, prot.as_ref())
            .map_err(anyhow::Error::from)
            .with_context(|| format!("scrub pass {cycle}"))?;
        estimator.observe(&pass);
        corrected_words += pass.corrected_words;
        dirty_shards += pass.dirty_shards;
        println!(
            "cycle {cycle}: {fs} words flipped; scrub repaired {} words / {} shards (ewma {:.5})",
            pass.corrected_words,
            pass.dirty_shards,
            estimator.observed_rate(),
        );
    }

    // Final decodes. Fidelity = fraction of weights that decode
    // bit-identically to the clean image.
    let fidelity = |buf: &mut MlcBuffer, region| -> Result<(Vec<f32>, f64)> {
        let mut out = Vec::new();
        buf.load_decoded(region, &mut out, 2).map_err(anyhow::Error::from)?;
        let same = out
            .iter()
            .zip(&clean_decode)
            .filter(|(a, b)| a.to_bits() == b.to_bits())
            .count();
        let f = same as f64 / clean_decode.len() as f64;
        Ok((out, f))
    };
    let (s_out, s_fidelity) = fidelity(&mut scrubbed, &sregion)?;
    let (n_out, n_fidelity) = fidelity(&mut neglected, &nregion)?;

    // Classification agreement against the clean reference on a probe
    // set — the accuracy face of the same decay.
    let reference = LinearEngine::new(CLASSES, dim, 1, clean_decode.clone())?;
    let s_engine = LinearEngine::new(CLASSES, dim, 1, s_out)?;
    let n_engine = LinearEngine::new(CLASSES, dim, 1, n_out)?;
    let probes = 64usize;
    let mut prng = Xoshiro256::seeded(SEED ^ 0xBEEF);
    let (mut s_agree, mut n_agree) = (0usize, 0usize);
    for _ in 0..probes {
        let image: Vec<f32> = (0..dim).map(|_| (prng.next_gaussian() * 0.5) as f32).collect();
        let want = reference.classify_batch(&image)?[0];
        if s_engine.classify_batch(&image)?[0] == want {
            s_agree += 1;
        }
        if n_engine.classify_batch(&image)?[0] == want {
            n_agree += 1;
        }
    }

    println!(
        "scrubbed:  fidelity {s_fidelity:.4}, {s_agree}/{probes} probe agreement\n\
         neglected: fidelity {n_fidelity:.4}, {n_agree}/{probes} probe agreement\n\
         scrub telemetry: {corrected_words} words repaired across {dirty_shards} dirty shards, \
         ewma {:.5} (configured rate {RATE})",
        estimator.observed_rate(),
    );

    // The gate.
    ensure!(
        s_fidelity == 1.0 && s_agree == probes,
        "scrubbed twin must decode and classify bit-identically \
         (fidelity {s_fidelity}, agreement {s_agree}/{probes})"
    );
    ensure!(
        n_fidelity < 1.0,
        "neglected twin was expected to accumulate decode damage at rate {RATE} x {CYCLES} cycles"
    );
    ensure!(n_agree <= s_agree, "decay cannot improve agreement");
    ensure!(estimator.observed_rate() > 0.0, "EWMA never primed");
    ensure!(corrected_words > 0 && dirty_shards > 0, "scrubber never repaired anything");

    let doc = obj(vec![
        ("schema", Json::Str("mlcstt/scrub-retention/v1".into())),
        ("weights", Json::from(CLASSES * dim)),
        ("cycles", Json::from(CYCLES)),
        ("rate", Json::from(RATE)),
        ("scrubbed_fidelity", Json::from(s_fidelity)),
        ("neglected_fidelity", Json::from(n_fidelity)),
        ("probes", Json::from(probes)),
        ("scrubbed_agreement", Json::from(s_agree)),
        ("neglected_agreement", Json::from(n_agree)),
        ("corrected_words", Json::Num(corrected_words as f64)),
        ("dirty_shards", Json::Num(dirty_shards as f64)),
        ("observed_rate", Json::from(estimator.observed_rate())),
        (
            "bank_rates",
            Json::Arr(estimator.bank_rates().iter().map(|&r| Json::from(r)).collect()),
        ),
    ]);
    let out_dir = mlcstt::api::env::bench_dir().unwrap_or_else(|| PathBuf::from("bench_out"));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let path = out_dir.join("SCRUB_retention.json");
    std::fs::write(&path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("wrote {}", path.display());
    println!("PASSED");
    Ok(())
}
