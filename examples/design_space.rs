//! Design-space exploration: granularity x error-rate x policy, using the
//! analytic side of the stack (no PJRT needed, runs anywhere).
//!
//! ```bash
//! cargo run --offline --release --example design_space
//! ```
//!
//! For each (policy, granularity) the example reports stored soft-cell
//! fraction, payload energy savings, metadata overhead, and the expected
//! number of corrupted cells per million weights across the published
//! error-rate band — the quantities a designer trades when picking the
//! paper's configuration.

use mlcstt::encoding::{Policy, WeightCodec};
use mlcstt::metrics::Table;
use mlcstt::stt::{AccessKind, CostModel};
use mlcstt::util::rng::Xoshiro256;

fn main() {
    let n = 1 << 20;
    let mut rng = Xoshiro256::seeded(17);
    let weights: Vec<f32> = (0..n)
        .map(|_| ((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
        .collect();
    let cost = CostModel::default();

    let base = WeightCodec::new(Policy::Unprotected, 1).encode(&weights);
    let pe = |e: &mlcstt::encoding::Encoded, k| {
        e.words.iter().map(|&w| cost.word(w, k).nanojoules).sum::<f64>()
    };
    let base_read = pe(&base, AccessKind::Read);
    let base_write = pe(&base, AccessKind::Write);
    let base_soft = base.soft_cells();
    println!(
        "population: {n} clipped-Gaussian weights; unprotected soft fraction {:.2}%\n",
        100.0 * base_soft as f64 / (8 * n) as f64
    );

    let mut t = Table::new(
        "design space (1M synthetic weights)",
        &[
            "policy",
            "g",
            "soft%",
            "read save%",
            "write save%",
            "meta ovh%",
            "E[flips]/M @1.5e-2",
            "@2e-2",
        ],
    );
    for policy in [Policy::ProtectRound, Policy::ProtectRotate, Policy::Hybrid] {
        for g in [1usize, 2, 4, 8, 16] {
            let enc = WeightCodec::new(policy, g).encode(&weights);
            let soft = enc.soft_cells();
            t.row(vec![
                policy.label().into(),
                g.to_string(),
                format!("{:.2}", 100.0 * soft as f64 / (8 * n) as f64),
                format!("{:.2}", 100.0 * (1.0 - pe(&enc, AccessKind::Read) / base_read)),
                format!("{:.2}", 100.0 * (1.0 - pe(&enc, AccessKind::Write) / base_write)),
                format!("{:.3}", 100.0 * enc.metadata_overhead()),
                format!("{:.0}", soft as f64 * 0.015 / (n as f64 / 1e6)),
                format!("{:.0}", soft as f64 * 0.02 / (n as f64 / 1e6)),
            ]);
        }
    }
    println!("{t}");
    println!(
        "unprotected reference: E[flips]/M = {:.0} @1.5e-2, {:.0} @2e-2 — and those\n\
         include sign bits, which the protected systems never expose.",
        base_soft as f64 * 0.015 / (n as f64 / 1e6),
        base_soft as f64 * 0.02 / (n as f64 / 1e6),
    );
}
