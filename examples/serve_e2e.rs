//! End-to-end driver (DESIGN.md §5 "E2E"): the full three-layer system on a
//! real workload, built entirely on the `api` facade.
//!
//! ```bash
//! make artifacts && cargo run --offline --release --example serve_e2e
//! ```
//!
//! For each trained artifact model:
//!  1. loads the JAX/Pallas-lowered HLO and the trained weights,
//!  2. measures error-free accuracy through PJRT,
//!  3. pushes the weights through the simulated MLC STT-RAM buffer under
//!     each protection system at the published 2e-2 soft-error rate,
//!  4. serves a request replay through the registry (queue -> batcher ->
//!     PJRT, one thread-pinned worker per model) and reports latency,
//!  5. prints the paper's headline comparison: hybrid accuracy == error-free
//!     while read/write energy drops vs the unprotected baseline.
//!
//! Environment (resolved once through `api::Config`): MLCSTT_EVAL (test
//! images per accuracy point, default 256), MLCSTT_REQUESTS (serving
//! replay length, default 128), MLCSTT_ARTIFACTS, MLCSTT_THREADS.

use std::time::Duration;

use anyhow::{Context, Result};

use mlcstt::api::{Config, Deployment, ModelRegistry};
use mlcstt::encoding::Policy;
use mlcstt::experiments::{load_model, run_accuracy_experiment};
use mlcstt::runtime::artifacts::{model_available, TestSet};
use mlcstt::stt::{AccessKind, CostModel, ErrorModel};
use mlcstt::util::rng::Xoshiro256;

fn main() -> Result<()> {
    // Layered resolution (builder -> MLCSTT_* -> defaults) in one place.
    let config = Config::builder().max_wait(Duration::from_millis(10)).build();
    let dir = config.artifacts_dir().to_path_buf();
    let eval = config.eval_or(256);
    let requests = config.requests_or(128);

    let mut ran = false;
    for model in ["vggmini", "inceptionmini"] {
        if !model_available(&dir, model) {
            eprintln!("{model}: artifacts missing — run `make artifacts`");
            continue;
        }
        ran = true;
        println!("\n================ {model} ================");

        // --- Fig. 8 accuracy sweep at the published worst-case rate.
        let exp = run_accuracy_experiment(&dir, model, 0.02, 4, eval, 7)?;
        println!("{}", exp.table);

        // --- Energy headline (payload accounting, hybrid g=4 vs baseline).
        let (_, weights) = load_model(&dir, model)?;
        let flat = weights.flat();
        let cost = CostModel::default();
        let base = mlcstt::encoding::WeightCodec::new(Policy::Unprotected, 1).encode(&flat);
        let hyb = mlcstt::encoding::WeightCodec::hybrid(4).encode(&flat);
        let pe = |e: &mlcstt::encoding::Encoded, k| {
            e.words
                .iter()
                .map(|&w| cost.word(w, k).nanojoules)
                .sum::<f64>()
        };
        println!(
            "energy (payload): read -{:.1}%  write -{:.1}%  vs unprotected baseline",
            100.0 * (1.0 - pe(&hyb, AccessKind::Read) / pe(&base, AccessKind::Read)),
            100.0 * (1.0 - pe(&hyb, AccessKind::Write) / pe(&base, AccessKind::Write)),
        );

        // --- Serving replay through the registry (hybrid weights). The
        // deployment owns the whole weight path; the registry pins its
        // engine to a worker and routes by the model tag.
        let dep = Deployment::builder()
            .config(config.clone())
            .model(model)
            .policy(Policy::Hybrid)
            .granularity(4)
            .error_model(ErrorModel::at_rate(0.02))
            .seed(11)
            .build()?;
        let mut registry = ModelRegistry::new();
        registry.register_deployment(&dep, config.server())?;

        let test = TestSet::read(&dir.join("testset.bin"))?;
        let mut rng = Xoshiro256::seeded(3);
        let mut tickets = Vec::new();
        let mut expected = Vec::new();
        for _ in 0..requests {
            let i = rng.below(test.n as u64) as usize;
            expected.push(test.labels[i] as usize);
            tickets.push(registry.submit(model, test.image(i).to_vec())?.ticket()?);
        }
        let mut correct = 0usize;
        for (t, want) in tickets.into_iter().zip(expected) {
            if t.wait().context("response")?.class == want {
                correct += 1;
            }
        }
        let report = registry.shutdown();
        let rep = &report.sections[0].1;
        println!(
            "serving: {} req ({} shed, {} errors), {} batches (fill {:.1}), acc {:.4}, \
             p50 {:.1} ms, p95 {:.1} ms, p99 {:.1} ms, {:.1} req/s",
            rep.served,
            rep.shed,
            rep.errors,
            rep.batches,
            rep.mean_batch_fill,
            correct as f64 / requests as f64,
            rep.p50_ms,
            rep.p95_ms,
            rep.p99_ms,
            rep.throughput_rps
        );
    }
    anyhow::ensure!(ran, "no artifacts found — run `make artifacts` first");
    Ok(())
}
