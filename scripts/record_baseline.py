#!/usr/bin/env python3
"""Propose an updated bench/baseline.json from a full bench run.

Reads the machine-readable ``BENCH_hotpath.json`` a bench run emitted,
applies a safety margin (floors sit well below observed throughput so
shared-runner noise never trips the 25% CI gate), and writes a proposed
baseline next to a markdown diff of old floor vs observed vs proposed.

Stdlib only — runs on a bare CI python. Typical CI usage
(``.github/workflows/bench-record.yml``)::

    python3 scripts/record_baseline.py \
        --report bench_out/BENCH_hotpath.json \
        --baseline bench/baseline.json \
        --out baseline-proposed.json \
        --summary "$GITHUB_STEP_SUMMARY"

The proposal keeps the baseline's record *set* (every gated name stays
gated) and adds any new records the report carries, so a bench added in a
PR gets a floor on the next recording run rather than silently escaping
the gate. Records in the baseline but missing from the report keep their
old floor and are flagged in the diff.
"""

import argparse
import json
import os
import sys

DEFAULT_MARGIN = 0.5  # proposed floor = margin x observed throughput


def load(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def fmt(x):
    return f"{x:.3g}" if x is not None else "-"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--report", required=True, help="BENCH_hotpath.json from the run")
    ap.add_argument("--baseline", required=True, help="committed bench/baseline.json")
    ap.add_argument("--out", required=True, help="where to write the proposed baseline")
    ap.add_argument(
        "--margin",
        type=float,
        default=DEFAULT_MARGIN,
        help=f"floor = margin x observed per_sec (default {DEFAULT_MARGIN})",
    )
    ap.add_argument("--sha", default=os.environ.get("GITHUB_SHA", "local"))
    ap.add_argument("--summary", default=None, help="markdown diff target (append)")
    args = ap.parse_args()
    if not 0.0 < args.margin <= 1.0:
        sys.exit(f"--margin {args.margin} out of (0, 1]")

    report = load(args.report)
    baseline = load(args.baseline)
    observed = {r["name"]: r for r in report.get("records", [])}
    old = {r["name"]: r for r in baseline.get("records", [])}

    rows = []  # (name, old_floor, observed, proposed, note)
    proposed_records = []
    # Baseline order first (stable diffs), then report-only names.
    names = list(old) + [n for n in observed if n not in old]
    for name in names:
        prev = old.get(name, {}).get("per_sec")
        got = observed.get(name)
        if got is None:
            rows.append((name, prev, None, prev, "missing from report: floor kept"))
            proposed_records.append(old[name])
            continue
        floor = args.margin * got["per_sec"]
        note = "new record" if name not in old else ""
        rows.append((name, prev, got["per_sec"], floor, note))
        proposed_records.append(
            {
                "name": name,
                "n": got["n"],
                "median_ns": got["median_ns"],
                "p95_ns": got["p95_ns"],
                "per_sec": floor,
            }
        )

    proposal = {
        "bench": baseline.get("bench", report.get("bench", "hotpath")),
        "git_sha": args.sha,
        "comment": (
            f"Recorded floors: {args.margin:g}x the observed median throughput of "
            f"bench run {args.sha} (see bench-record workflow). Review the diff in "
            "the run summary, then replace bench/baseline.json with this file."
        ),
        "records": proposed_records,
    }
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(proposal, f, indent=2)
        f.write("\n")

    lines = [
        "## Proposed bench baseline",
        "",
        f"margin: floors at {args.margin:g}x observed; run: `{args.sha}`",
        "",
        "| record | old floor/s | observed/s | proposed floor/s | note |",
        "|---|---|---|---|---|",
    ]
    for name, prev, got, floor, note in rows:
        lines.append(f"| {name} | {fmt(prev)} | {fmt(got)} | {fmt(floor)} | {note} |")
    table = "\n".join(lines) + "\n"
    print(table)
    if args.summary:
        with open(args.summary, "a", encoding="utf-8") as f:
            f.write(table)

    missing = [n for n, _, got, _, _ in rows if got is None]
    if missing:
        print(f"warning: {len(missing)} baseline record(s) missing from the report: "
              + ", ".join(missing), file=sys.stderr)


if __name__ == "__main__":
    main()
