# Build targets the rest of the repo refers to. The only non-cargo step is
# `make artifacts`: it runs the L1 AOT pipeline (train the Mini nets, lower
# to HLO text, export weights/manifests/testset into artifacts/). Requires
# jax; aot.py itself skips work whose outputs are already present (pass
# FORCE=1 to retrain). Everything else is a thin cargo alias.

ARTIFACTS ?= artifacts
FORCE ?=

.PHONY: artifacts build test bench sweep serve-demo swap-demo scrub-demo load clean-artifacts

artifacts:
	python3 python/compile/aot.py --out-dir $(ARTIFACTS) $(if $(FORCE),--force,)

# Fig. 8-style error-rate sweep via the snapshot-reuse campaign API
# (DESIGN.md §9). Uses trained artifacts when present, otherwise falls
# back to a synthetic tensor — runs anywhere.
sweep:
	cargo run --release --offline --example rate_sweep

# Multi-model serving demo through api::ModelRegistry (DESIGN.md §10).
# Uses trained artifacts when present, otherwise serves two buffer-backed
# linear classifiers — runs anywhere, no PJRT needed.
serve-demo:
	cargo run --release --offline --example registry_serve

# Zero-downtime delivery chaos smoke (DESIGN.md §14): streams three
# versioned rollouts through injected read faults, a retry-exhausting
# corruption, and a failing canary, asserting zero dropped or mis-served
# requests and bit-identical rollback. Emits bench_out/DELIVERY_hot_swap.json.
swap-demo:
	cargo run --release --offline --example hot_swap

# Background-scrubbing retention gate (DESIGN.md §15): ages twin buffers
# under identical retention faults, scrubbing only one, and asserts the
# scrubbed twin decodes bit-identically while the neglected twin decays.
# Emits bench_out/SCRUB_retention.json.
scrub-demo:
	cargo run --release --offline --example scrub_retention

# Overload characterization (DESIGN.md §11): closed/open-loop sweep past
# saturation with bounded admission; emits bench_out/LOAD_serving.json.
# Uses trained artifacts when present, otherwise a synthetic throttled
# engine with a known saturation point — runs anywhere.
load:
	cargo run --release --offline --example load_test

build:
	cargo build --release --offline

test:
	cargo test -q --offline

bench:
	cargo bench --offline

clean-artifacts:
	rm -rf $(ARTIFACTS)
