//! Background scrubbing & retention subsystem (ISSUE 10, DESIGN.md §15):
//!
//! * one scrub pass restores a disturbed region **bit-identically** to
//!   its clean image for every [`Policy::EXTENDED`] member — stored
//!   words, shard checksums, and decoded floats all match a
//!   never-disturbed twin — and draws no RNG, so later fault injection
//!   is unchanged by whether a scrub ran;
//! * [`ScrubPolicy::Off`] is the byte-for-byte status quo: a pool with
//!   the (default) off scheduler serves, bills, and decodes exactly like
//!   one that has never heard of scrubbing;
//! * the scheduled path fires between leases and leaves no residual
//!   dirt, while an unscrubbed twin accumulates it — the retention
//!   story of `examples/scrub_retention.rs` as a test;
//! * the adaptive interval is monotone non-increasing in the decay
//!   signal, halves exactly at the threshold, and treats the observed
//!   rate and the E[SSE] channel symmetrically;
//! * the per-bank EWMA telemetry ranks injected error rates correctly;
//! * scrub repairs age the pool's banks through the same wear ledger as
//!   serving writes.

use std::time::Duration;

use mlcstt::api::{BufferPool, EvictPolicy, ScrubPolicy};
use mlcstt::buffer::{shard_checksums, BufferConfig, MlcBuffer, LOAD_SHARD_WORDS};
use mlcstt::coordinator::StoreConfig;
use mlcstt::encoding::{protection_for, Policy, WeightCodec};
use mlcstt::fp;
use mlcstt::runtime::artifacts::{ParamSpec, WeightFile};
use mlcstt::stt::ErrorModel;
use mlcstt::util::rng::Xoshiro256;

/// Deterministic f16-representable weights (what a trained file holds).
fn tensor(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seeded(seed);
    (0..n)
        .map(|_| fp::quantize_f16((rng.next_gaussian() * 0.4) as f32))
        .collect()
}

fn weight_file(n: usize, seed: u64) -> WeightFile {
    WeightFile {
        params: vec![ParamSpec {
            name: "w".into(),
            shape: vec![n],
            data: tensor(n, seed),
        }],
    }
}

fn store_cfg(rate: f64, seed: u64) -> StoreConfig {
    StoreConfig {
        error_model: ErrorModel::at_rate(rate),
        seed,
        ..StoreConfig::default()
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

// --------------------------------------------------- buffer-level repair

#[test]
fn scrub_restores_bit_identity_for_every_policy() {
    // Two shards (one partial) so the cursor crosses a shard boundary.
    let ws = tensor(LOAD_SHARD_WORDS + 4321, 0xA11CE);
    for policy in Policy::EXTENDED {
        let enc = WeightCodec::new(policy, 4).encode(&ws);
        let golden = shard_checksums(&enc.words);
        let mk = || {
            let cfg = BufferConfig::new(enc.len() * 2, 7)
                .with_error_model(ErrorModel::at_rate(0.0));
            let mut buf = MlcBuffer::new(cfg, 0x5EED);
            let region = buf.store(&enc).unwrap();
            (buf, region)
        };
        let (mut disturbed, dregion) = mk();
        let (mut pristine, pregion) = mk();

        let flips = disturbed
            .corrupt_region_write_shards(&dregion, &ErrorModel::at_rate(0.3), 3)
            .unwrap();
        assert!(flips.iter().sum::<u64>() > 0, "{policy:?}: nothing flipped");
        assert_ne!(
            disturbed.region_shard_checksums(&dregion).unwrap(),
            golden,
            "{policy:?}: corruption must show in the checksums"
        );

        // One pass detects against the golden checksums and repairs the
        // stored image in place.
        let prot = protection_for(policy, enc.granularity);
        let pass = disturbed
            .scrub_region(&dregion, &enc.words, &golden, prot.as_ref())
            .unwrap();
        assert!(pass.dirty_shards > 0 && pass.corrected_words > 0, "{policy:?}");
        assert_eq!(pass.scrubbed_words, dregion.len as u64, "{policy:?}");
        assert!(pass.corrected_cells >= pass.corrected_words, "{policy:?}");
        assert_eq!(
            disturbed.region_shard_checksums(&dregion).unwrap(),
            golden,
            "{policy:?}: repair must restore the golden image"
        );

        // The decoded read after repair is bit-identical to the twin
        // that was never disturbed.
        let (mut got, mut want) = (Vec::new(), Vec::new());
        disturbed.load_decoded(&dregion, &mut got, 2).unwrap();
        pristine.load_decoded(&pregion, &mut want, 2).unwrap();
        assert_eq!(bits(&got), bits(&want), "{policy:?}: decode differs after scrub");

        // No RNG draws: a clean-region scrub is invisible to the fault
        // stream, so the next injection matches a twin that never
        // scrubbed — flip sets and resulting images both.
        let spass = pristine
            .scrub_region(&pregion, &enc.words, &golden, prot.as_ref())
            .unwrap();
        assert_eq!(spass.dirty_shards, 0, "{policy:?}");
        assert_eq!(spass.corrected_words, 0, "{policy:?}");
        let (mut plain, nregion) = mk();
        let f_scrubbed = pristine
            .corrupt_region_write_shards(&pregion, &ErrorModel::at_rate(0.02), 2)
            .unwrap();
        let f_plain = plain
            .corrupt_region_write_shards(&nregion, &ErrorModel::at_rate(0.02), 2)
            .unwrap();
        assert_eq!(f_scrubbed, f_plain, "{policy:?}: scrub consumed RNG");
        assert_eq!(
            pristine.region_shard_checksums(&pregion).unwrap(),
            plain.region_shard_checksums(&nregion).unwrap(),
            "{policy:?}: post-injection images diverged"
        );
    }
}

// ------------------------------------------------------ off = status quo

#[test]
fn scrub_off_is_byte_for_byte_status_quo() {
    let wf = weight_file(4096, 7);
    let mk = || {
        let pool = BufferPool::new(8192 * 2, 16, 256, EvictPolicy::Lru);
        pool.admit("m", &store_cfg(0.01, 3), &wf).unwrap();
        pool
    };
    let with_off = mk();
    with_off.set_scrub(ScrubPolicy::Off); // explicit, same as the default
    let untouched = mk(); // never calls any scrub API

    for _ in 0..3 {
        let a: Vec<f32> = with_off
            .lease("m")
            .unwrap()
            .build_engine(&mut |t: &[ParamSpec]| Ok(t[0].data.clone()))
            .unwrap();
        let b: Vec<f32> = untouched
            .lease("m")
            .unwrap()
            .build_engine(&mut |t: &[ParamSpec]| Ok(t[0].data.clone()))
            .unwrap();
        assert_eq!(bits(&a), bits(&b));
    }
    let (ra, rb) = (with_off.report("m").unwrap(), untouched.report("m").unwrap());
    assert_eq!(ra.write_energy, rb.write_energy);
    assert_eq!(ra.read_energy, rb.read_energy);
    assert_eq!(ra.injected_faults, rb.injected_faults);

    let t = with_off.scrub_telemetry();
    assert_eq!(t.policy, "off");
    assert_eq!(t.passes, 0);
    assert!(t.interval.is_none());
}

// ----------------------------------------------- scheduled path + repair

#[test]
fn scheduled_scrub_fires_between_leases_and_repairs() {
    let wf = weight_file(4096, 7);
    let pool = BufferPool::new(8192 * 2, 16, 256, EvictPolicy::Lru);
    pool.admit("m", &store_cfg(0.0, 3), &wf).unwrap();
    pool.set_scrub(ScrubPolicy::Fixed(Duration::ZERO));

    assert!(pool.disturb(&ErrorModel::at_rate(0.4)).unwrap() > 0);
    let _: Vec<f32> = pool
        .lease("m")
        .unwrap()
        .build_engine(&mut |t: &[ParamSpec]| Ok(t[0].data.clone()))
        .unwrap();
    let after_lease = pool.scrub_telemetry();
    assert_eq!(after_lease.passes, 1, "zero-interval schedule must fire at the lease");
    assert!(after_lease.corrected_words > 0 && after_lease.dirty_shards > 0);
    assert_eq!(pool.rebuilds(), 0, "repair is in place, not a rebuild");

    // The scheduled pass left nothing behind: a verification pass finds
    // no new dirt.
    let verify = pool.scrub_pass().unwrap();
    assert_eq!(verify.dirty_shards, after_lease.dirty_shards);
    assert_eq!(verify.corrected_words, after_lease.corrected_words);
}

#[test]
fn retention_residual_dirt_scrubbed_vs_not() {
    let wf = weight_file(4096, 7);
    let mk = || {
        let pool = BufferPool::new(8192 * 2, 16, 256, EvictPolicy::Lru);
        pool.admit("m", &store_cfg(0.0, 3), &wf).unwrap();
        pool
    };
    let scrubbed = mk();
    let neglected = mk();
    for _ in 0..4 {
        scrubbed.disturb(&ErrorModel::at_rate(0.05)).unwrap();
        scrubbed.scrub_pass().unwrap();
        neglected.disturb(&ErrorModel::at_rate(0.05)).unwrap();
    }

    // Verification pass: the scrubbed pool holds a clean image; the
    // neglected one has four cycles of decay still sitting in it.
    let before = scrubbed.scrub_telemetry();
    let after = scrubbed.scrub_pass().unwrap();
    assert_eq!(after.dirty_shards, before.dirty_shards, "scrubbing must hold the image clean");
    let t = neglected.scrub_pass().unwrap();
    assert!(t.dirty_shards > 0, "unscrubbed decay must accumulate");
}

// ------------------------------------------------------ adaptive schedule

#[test]
fn adaptive_interval_monotone_in_decay_signal() {
    let base = Duration::from_millis(800);
    let p = ScrubPolicy::Adaptive { base, threshold: 0.05 };

    assert_eq!(p.interval(0.0, 0.0).unwrap(), base, "no signal, no tightening");
    let mut last = base;
    for rate in [0.001, 0.01, 0.05, 0.2, 1.0] {
        let d = p.interval(rate, 0.0).unwrap();
        assert!(d <= last, "interval must tighten monotonically (rate {rate})");
        last = d;
    }
    // Halved exactly at the threshold, through either signal channel —
    // the effective signal is the max of the two.
    assert_eq!(p.interval(0.05, 0.0).unwrap(), base / 2);
    assert_eq!(p.interval(0.0, 0.05).unwrap(), base / 2);
    assert_eq!(p.interval(0.02, 0.05).unwrap(), p.interval(0.05, 0.02).unwrap());

    // Fixed ignores the signals entirely; Off has no interval.
    assert_eq!(ScrubPolicy::Fixed(base).interval(1.0, 1.0).unwrap(), base);
    assert!(ScrubPolicy::Off.interval(1.0, 1.0).is_none());
}

// ------------------------------------------------------ telemetry ranking

#[test]
fn ewma_tracks_injected_rate_rank() {
    let wf = weight_file(4096, 9);
    let mut observed = Vec::new();
    for rate in [0.005, 0.03, 0.15] {
        let pool = BufferPool::new(8192 * 2, 16, 256, EvictPolicy::Lru);
        pool.admit("m", &store_cfg(0.0, 5), &wf).unwrap();
        for _ in 0..3 {
            pool.disturb(&ErrorModel::at_rate(rate)).unwrap();
            pool.scrub_pass().unwrap();
        }
        let t = pool.scrub_telemetry();
        assert!(t.observed_rate > 0.0, "rate {rate}: EWMA never primed");
        assert_eq!(t.bank_rates.len(), 16);
        observed.push(t.observed_rate);
    }
    assert!(
        observed[0] < observed[1] && observed[1] < observed[2],
        "EWMA must rank injected rates: {observed:?}"
    );
}

// --------------------------------------------------------- wear coupling

#[test]
fn scrub_repairs_charge_pool_wear() {
    let total_writes = |pool: &BufferPool| -> f64 {
        pool.bank_wear()
            .iter()
            .map(|w| w.mean_writes * w.extents as f64)
            .sum()
    };
    let wf = weight_file(4096, 7);
    let pool = BufferPool::new(8192 * 2, 16, 256, EvictPolicy::Lru);
    pool.admit("m", &store_cfg(0.0, 3), &wf).unwrap();
    let before = total_writes(&pool);
    assert!(pool.disturb(&ErrorModel::at_rate(0.4)).unwrap() > 0);
    let t = pool.scrub_pass().unwrap();
    assert!(t.corrected_words > 0);
    assert!(
        total_writes(&pool) > before,
        "scrub rewrites must age the banks through the wear ledger"
    );
}
