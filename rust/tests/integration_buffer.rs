//! Integration: buffer x encoding x error model — transactional accounting
//! under realistic workloads.

use mlcstt::buffer::{BufferConfig, MlcBuffer};
use mlcstt::encoding::{Policy, WeightCodec};
use mlcstt::stt::{AccessKind, CostModel, ErrorModel};
use mlcstt::util::rng::Xoshiro256;

fn weights(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seeded(seed);
    (0..n)
        .map(|_| ((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
        .collect()
}

#[test]
fn buffer_energy_matches_codec_accounting() {
    // Fault-free store/load must bill exactly what the codec predicts.
    let ws = weights(4096, 1);
    let enc = WeightCodec::hybrid(4).encode(&ws);
    let cost = CostModel::default();
    let cfg = BufferConfig::new(enc.len() * 2, 1).with_error_model(ErrorModel::at_rate(0.0));
    let mut buf = MlcBuffer::new(cfg, 9);
    let region = buf.store(&enc).unwrap();
    let expect_w = enc.access_energy(&cost, AccessKind::Write);
    assert!((buf.stats().write_energy.nanojoules - expect_w.nanojoules).abs() < 1e-6);
    buf.load(&region).unwrap();
    let expect_r = enc.access_energy(&cost, AccessKind::Read);
    assert!((buf.stats().read_energy.nanojoules - expect_r.nanojoules).abs() < 1e-6);
}

#[test]
fn full_model_fits_sram_equivalent_buffer() {
    // An 814k-weight model (vggmini-sized) in fp16 = 1.6 MB; a 512 KB-SRAM-
    // equivalent MLC buffer (2 MB) must hold it, the SRAM itself must not.
    let ws = weights(814_122, 2);
    let enc = WeightCodec::hybrid(4).encode(&ws);

    let mlc = BufferConfig::sram_equivalent(512 * 1024, 16)
        .with_error_model(ErrorModel::at_rate(0.0));
    let mut buf = MlcBuffer::new(mlc, 1);
    buf.store(&enc).expect("must fit the MLC buffer");

    let sram_words = 512 * 1024 / 2;
    assert!(enc.len() > sram_words, "model should overflow raw SRAM");
}

#[test]
fn fault_rate_scales_with_soft_cells_not_words() {
    // Two same-length streams with very different soft-cell counts must see
    // proportionally different fault counts.
    let dense_soft = vec![0x5555u16; 50_000]; // 8 soft cells/word
    let sparse_soft = vec![0x0001u16; 50_000]; // 1 soft cell/word
    let mk = |words: Vec<u16>| mlcstt::encoding::Encoded {
        words,
        schemes: vec![],
        granularity: 1,
        policy: Policy::Unprotected,
    };
    let cfg = BufferConfig::new(200_000, 4).with_error_model(ErrorModel::at_rate(0.02));
    let mut b1 = MlcBuffer::new(cfg.clone(), 5);
    b1.store(&mk(dense_soft)).unwrap();
    let f_dense = b1.stats().injected_faults;
    let mut b2 = MlcBuffer::new(cfg, 5);
    b2.store(&mk(sparse_soft)).unwrap();
    let f_sparse = b2.stats().injected_faults;
    let ratio = f_dense as f64 / f_sparse as f64;
    // A word with 8 vulnerable cells is ~8x likelier to corrupt (per-cell
    // independence; words count once even with multiple hits, so allow a
    // generous band).
    assert!(ratio > 5.0 && ratio < 9.0, "ratio {ratio}");
}

#[test]
fn many_tensors_sequential_layout_and_isolation() {
    let cfg = BufferConfig::new(1 << 20, 8).with_error_model(ErrorModel::at_rate(0.0));
    let mut buf = MlcBuffer::new(cfg, 3);
    let mut regions = Vec::new();
    let mut encs = Vec::new();
    for t in 0..20 {
        let ws = weights(500 + t * 37, 100 + t as u64);
        let enc = WeightCodec::hybrid(1 + t % 16).encode(&ws);
        regions.push(buf.store(&enc).unwrap());
        encs.push(enc);
    }
    // Read back in reverse order; every region must decode to its own data.
    for (region, enc) in regions.iter().zip(&encs).rev() {
        let back = buf.load(region).unwrap();
        assert_eq!(back.words, enc.words);
        assert_eq!(back.decode(), enc.decode());
    }
}

#[test]
fn clear_and_reuse_cycles() {
    let cfg = BufferConfig::new(10_000, 4).with_error_model(ErrorModel::at_rate(0.0));
    let mut buf = MlcBuffer::new(cfg, 1);
    for round in 0..10 {
        let ws = weights(2000, round);
        let enc = WeightCodec::hybrid(4).encode(&ws);
        let r = buf.store(&enc).unwrap();
        assert_eq!(buf.load(&r).unwrap().decode(), enc.decode());
        buf.clear();
    }
    // Stats survive clears (cumulative across rounds).
    assert_eq!(buf.stats().writes, 10 * 2000);
    assert_eq!(buf.stats().reads, 10 * 2000);
}

#[test]
fn deterministic_replay_across_buffers() {
    let ws = weights(30_000, 8);
    let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
    let cfg = BufferConfig::new(60_000, 4).with_error_model(ErrorModel::at_rate(0.02));
    let run = |seed: u64| {
        let mut b = MlcBuffer::new(cfg.clone(), seed);
        let r = b.store(&enc).unwrap();
        b.load(&r).unwrap().words
    };
    assert_eq!(run(42), run(42));
    assert_ne!(run(42), run(43));
}
