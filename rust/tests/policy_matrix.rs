//! ISSUE 8 policy-matrix suite: every [`ProtectionPolicy`] implementation
//! exercised over granularity × error-rate, pinned against the retained
//! scalar codec oracle.
//!
//! * **Rate-0 matrix**: every policy × granularity {1, 4, 16} × worker
//!   count — trait encode/decode bit-identical to the scalar codec path,
//!   exactly-lossless policies (unprotected, rotate, zero-parity)
//!   reproduce the fp16 quantization bit-for-bit.
//! * **Bounded decode**: under injected faults at the paper's two rates,
//!   every sign- or parity-protected policy decodes finite values with
//!   |w| < 2 (the Fig. 8 mechanism: no 65504-scale outliers).
//! * **Hybrid through the trait**: stored words, scheme symbols, same-seed
//!   flip sets, energy bills (packed and scalar), and decoded tensors all
//!   bit-identical to calling [`WeightCodec`] directly — the tentpole's
//!   "refactor changed nothing" contract.
//! * **Store level**: `WeightStore` now routes encodes through
//!   [`protection_for`]; snapshot + reinject replays the same flip set a
//!   fresh faulted load produces, for every policy including zero-parity.
//! * **Estimator vs campaign** (ISSUE 8 satellite): the analytic
//!   entropy/census estimator's predicted accuracy-loss *ranking* of the
//!   policies matches the real fault campaign's ranking at both paper
//!   rates (Spearman rank correlation, not absolute SSE).

mod common;

use mlcstt::api::Deployment;
use mlcstt::coordinator::StoreConfig;
use mlcstt::encoding::{protection_for, Encoded, Policy, WeightCodec};
use mlcstt::faults::{estimate_policy_impact, FaultCampaign};
use mlcstt::fp;
use mlcstt::stt::error::{ERROR_RATE_HI, ERROR_RATE_LO};
use mlcstt::stt::{AccessKind, CostModel, ErrorModel};

const GRANULARITIES: [usize; 3] = [1, 4, 16];

/// Policies whose decode reproduces the fp16 quantization exactly at rate
/// 0: no Round candidate (the only lossy reformation) and no lossy repair.
fn is_exactly_lossless(policy: Policy) -> bool {
    matches!(
        policy,
        Policy::Unprotected | Policy::ProtectRotate | Policy::ZeroSpaceParity
    )
}

#[test]
fn matrix_rate_zero_roundtrips_bit_exact() {
    let ws = common::trained_like_weights(4096, "policy_matrix/roundtrip");
    let quantized: Vec<f32> = ws.iter().map(|&w| fp::quantize_f16(w)).collect();
    for policy in Policy::EXTENDED {
        for g in GRANULARITIES {
            let oracle = WeightCodec::new(policy, g).encode_scalar(&ws);
            let want = oracle.decode();
            let prot = protection_for(policy, g);
            let mut enc = Encoded::with_context(policy, g);
            for workers in [1usize, 3] {
                prot.encode_into(&ws, &mut enc, workers);
                assert_eq!(enc.words, oracle.words, "{policy:?} g={g} w={workers}");
                assert_eq!(enc.schemes, oracle.schemes, "{policy:?} g={g}");
                let mut dec = Vec::new();
                prot.decode_into(&enc, &mut dec, workers);
                assert_eq!(dec.len(), want.len());
                for (i, (a, b)) in dec.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{policy:?} g={g} w={workers} i={i}"
                    );
                }
                if is_exactly_lossless(policy) {
                    for (i, (a, b)) in dec.iter().zip(&quantized).enumerate() {
                        assert_eq!(a.to_bits(), b.to_bits(), "{policy:?} g={g} i={i}");
                    }
                }
                assert!(dec.iter().all(|w| w.is_finite() && w.abs() < 2.0));
            }
        }
    }
}

#[test]
fn matrix_protected_decodes_stay_bounded_under_faults() {
    let ws = common::trained_like_weights(6000, "policy_matrix/bounded");
    for policy in Policy::EXTENDED {
        if policy == Policy::Unprotected {
            continue; // the unbounded baseline the others are measured against
        }
        for g in GRANULARITIES {
            for rate in [0.0, ERROR_RATE_LO, ERROR_RATE_HI] {
                let prot = protection_for(policy, g);
                let mut enc = Encoded::with_context(policy, g);
                prot.encode_into(&ws, &mut enc, 2);
                let campaign =
                    FaultCampaign::new(ErrorModel::at_rate(rate), common::seed_of("bounded"));
                let flips = campaign.inject(&mut enc);
                if rate > 0.0 {
                    assert!(flips > 0, "{policy:?} g={g}: campaign must bite");
                }
                let mut dec = Vec::new();
                prot.decode_into(&enc, &mut dec, 2);
                for (i, w) in dec.iter().enumerate() {
                    assert!(
                        w.is_finite() && w.abs() < 2.0,
                        "{policy:?} g={g} rate={rate}: decoded[{i}]={w} escaped (-2, 2)"
                    );
                }
            }
        }
    }
}

#[test]
fn hybrid_through_trait_is_bit_identical_to_codec_oracle() {
    let ws = common::trained_like_weights(8192, "policy_matrix/hybrid-oracle");
    let cost = CostModel::default();
    for g in GRANULARITIES {
        let codec = WeightCodec::hybrid(g);
        let mut direct = codec.encode(&ws);
        let prot = protection_for(Policy::Hybrid, g);
        let mut via = Encoded::with_context(Policy::Hybrid, g);
        prot.encode_into(&ws, &mut via, 3);
        assert_eq!(via.words, direct.words, "g={g}: stored words diverged");
        assert_eq!(via.schemes, direct.schemes, "g={g}: metadata diverged");

        // Same-seed campaigns replay the identical flip set on both paths.
        let campaign =
            FaultCampaign::new(ErrorModel::at_rate(ERROR_RATE_HI), common::seed_of("oracle"));
        let flips_direct = campaign.inject(&mut direct);
        let flips_via = campaign.inject(&mut via);
        assert_eq!(flips_via, flips_direct, "g={g}: flip counts diverged");
        assert_eq!(via.words, direct.words, "g={g}: faulted words diverged");

        for kind in [AccessKind::Read, AccessKind::Write] {
            let a = via.access_energy(&cost, kind).nanojoules;
            let b = direct.access_energy_scalar(&cost, kind).nanojoules;
            assert_eq!(a, b, "g={g} {kind:?}: energy bill diverged");
        }

        let mut dec = Vec::new();
        prot.decode_into(&via, &mut dec, 3);
        let want = direct.decode();
        for (i, (a, b)) in dec.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "g={g} i={i}: decode diverged");
        }

        let bits = prot.metadata_overhead_bits(ws.len());
        assert_eq!(bits, 2 * ws.len().div_ceil(g) as u64, "g={g}");
        let ratio = bits as f64 / (16 * ws.len()) as f64;
        assert!((ratio - direct.metadata_overhead()).abs() < 1e-12, "g={g}");
    }
}

#[test]
fn store_level_matrix_snapshot_reinject_matches_fresh_load() {
    let wf = common::weight_file_for("vggmini", 4, 12_000, "policy_matrix/store");
    let seed = common::seed_of("policy_matrix/inject");
    for policy in Policy::EXTENDED {
        // Staged clean load, then rewind + re-inject at the paper rate —
        // the sweep's snapshot-reuse path, now routed through the trait.
        let mut staged = Deployment::builder()
            .weights_ref(&wf)
            .store(StoreConfig {
                policy,
                granularity: 4,
                error_model: ErrorModel::at_rate(0.0),
                seed,
                ..StoreConfig::default()
            })
            .staged()
            .build()
            .unwrap();
        let snap = staged.snapshot();
        staged
            .reinject(&snap, &ErrorModel::at_rate(ERROR_RATE_LO), seed)
            .unwrap();
        staged.materialize().unwrap();

        // Oracle: a fresh one-shot load at the same rate and seed.
        let fresh = Deployment::builder()
            .weights_ref(&wf)
            .store(StoreConfig {
                policy,
                granularity: 4,
                error_model: ErrorModel::at_rate(ERROR_RATE_LO),
                seed,
                ..StoreConfig::default()
            })
            .build()
            .unwrap();

        for (a, b) in staged.tensors().iter().zip(fresh.tensors()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.data.len(), b.data.len());
            for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{policy:?} {} [{i}]: reinject != fresh load",
                    a.name
                );
            }
        }
        let (ra, rb) = (staged.store_report(), fresh.store_report());
        assert_eq!(ra.injected_faults, rb.injected_faults, "{policy:?}");
        assert_eq!(ra.soft_cells_stored, rb.soft_cells_stored, "{policy:?}");
        assert_eq!(ra.metadata_overhead, rb.metadata_overhead, "{policy:?}");
        assert_eq!(
            ra.read_energy.nanojoules, rb.read_energy.nanojoules,
            "{policy:?}"
        );
        if policy == Policy::ZeroSpaceParity {
            assert_eq!(ra.metadata_overhead, 0.0, "parity must be zero-space");
        }
    }
}

// ------------------------------------------------- estimator vs campaign

/// Saturate non-finite decodes to ±65504 — the `bitflip_sse_study` (and
/// estimator) convention, so unprotected infinities count as the largest
/// representable damage instead of poisoning the sum.
fn sat(v: f32) -> f64 {
    if v.is_finite() {
        v as f64
    } else if v.is_sign_negative() {
        -65504.0
    } else {
        65504.0
    }
}

/// Measured campaign damage: mean SSE between the policy's clean decode
/// and its faulted decode over several seeds.
fn campaign_sse(policy: Policy, ws: &[f32], rate: f64, seeds: &[u64]) -> f64 {
    let codec = WeightCodec::new(policy, 4);
    let clean = codec.encode(ws).decode();
    let mut total = 0.0f64;
    for &seed in seeds {
        let campaign = FaultCampaign::new(ErrorModel::at_rate(rate), seed);
        let (faulted, _) = campaign.encode_fault_decode(&codec, ws);
        total += faulted
            .iter()
            .zip(&clean)
            .map(|(f, c)| {
                let d = sat(*f) - sat(*c);
                d * d
            })
            .sum::<f64>();
    }
    total / seeds.len() as f64
}

/// Ordinal ranks of `values` (0 = smallest). Ties are impossible in
/// practice here (continuous SSE sums), so ordinal ranking is stable.
fn ranks(values: &[f64]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..values.len()).collect();
    order.sort_by(|&a, &b| values[a].total_cmp(&values[b]));
    let mut out = vec![0usize; values.len()];
    for (rank, &idx) in order.iter().enumerate() {
        out[idx] = rank;
    }
    out
}

/// Spearman rank correlation via the classic 1 - 6Σd²/(n(n²-1)) identity.
fn spearman(a: &[f64], b: &[f64]) -> f64 {
    let (ra, rb) = (ranks(a), ranks(b));
    let n = a.len() as f64;
    let d2: f64 = ra
        .iter()
        .zip(&rb)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum();
    1.0 - 6.0 * d2 / (n * (n * n - 1.0))
}

#[test]
fn estimator_ranking_matches_fault_campaign() {
    let ws = common::trained_like_weights(32_768, "policy_matrix/estimator");
    let seeds = [common::seed_of("est/1"), common::seed_of("est/2"), common::seed_of("est/3")];
    for rate in [ERROR_RATE_LO, ERROR_RATE_HI] {
        let predicted: Vec<f64> = Policy::EXTENDED
            .iter()
            .map(|&p| estimate_policy_impact(p, 4, &ws, rate).expected_sse)
            .collect();
        let measured: Vec<f64> = Policy::EXTENDED
            .iter()
            .map(|&p| campaign_sse(p, &ws, rate, &seeds))
            .collect();
        // The estimator is a ranking tool (first-order, no multi-flip
        // terms): assert rank agreement, not absolute SSE.
        let rho = spearman(&predicted, &measured);
        assert!(
            rho >= 0.7,
            "rate={rate}: Spearman {rho:.3} < 0.7\npredicted={predicted:?}\nmeasured={measured:?}"
        );
        // Both methods must agree the unprotected baseline is worst: its
        // unguarded exponent/sign flips produce 65504-scale outliers.
        let unprotected = 0; // Policy::EXTENDED[0]
        let worst_pred = ranks(&predicted)[unprotected];
        let worst_meas = ranks(&measured)[unprotected];
        assert_eq!(worst_pred, Policy::EXTENDED.len() - 1, "rate={rate}");
        assert_eq!(worst_meas, Policy::EXTENDED.len() - 1, "rate={rate}");
    }
}

#[test]
fn overhead_bits_per_policy() {
    for policy in Policy::EXTENDED {
        for g in GRANULARITIES {
            let bits = protection_for(policy, g).metadata_overhead_bits(1000);
            if policy.has_metadata() {
                assert_eq!(bits, 2 * 1000usize.div_ceil(g) as u64, "{policy:?} g={g}");
            } else {
                assert_eq!(bits, 0, "{policy:?} g={g}: must be zero-space");
            }
        }
    }
}
