//! ISSUE 5 facade equivalence suite: every entry-point path rebuilt on
//! `api::{Config, Deployment, ModelRegistry}` pinned **bit-identical** to
//! the pre-facade hand-rolled sequence it replaced.
//!
//! * **Store path** (the `mlcstt serve` / `serve_e2e` weight path): a
//!   hand-rolled `StoreConfig` → `WeightStore::load` → `materialize` →
//!   `report` vs `Deployment::builder()...build()` — tensors, flip sets,
//!   and energy reports equal across policies × rates × granularities.
//! * **Accuracy experiment shape**: the per-policy restage loop scored on
//!   the synthetic linear task, old vs `Deployment`, equal accuracies.
//! * **Sweep**: the flip-set-aware `run_rate_sweep_with` vs the retained
//!   always-rematerialize oracle vs a restage-per-point baseline.
//! * **Serving**: registry-routed submission vs a directly started
//!   `Server` (same engine), plus multi-model routing determinism under
//!   interleaving.
//! * **Censuses**: the newly threaded `pattern_counts` / `soft_cells`
//!   vs their packed serial kernels, integer-exact at every worker count.

mod common;

use std::time::Duration;

use mlcstt::api::{Config, Deployment, ModelRegistry};
use mlcstt::coordinator::{LinearEngine, Server, ServerConfig, StoreConfig, WeightStore};
use mlcstt::encoding::{Policy, WeightCodec};
use mlcstt::experiments::{run_rate_sweep_with, run_rate_sweep_with_rematerialize};
use mlcstt::fp;
use mlcstt::stt::ErrorModel;

fn serve_cfg() -> ServerConfig {
    ServerConfig {
        max_wait: Duration::from_millis(1),
        codec_threads: 1,
        ..ServerConfig::default()
    }
}

#[test]
fn deployment_build_matches_hand_rolled_store_path() {
    let wf = common::weight_file_for("vggmini", 5, 20_000, "facade/store");
    for policy in Policy::ALL {
        for (rate, g) in [(0.0f64, 4usize), (0.02, 4), (0.015, 7)] {
            let sc = StoreConfig {
                policy,
                granularity: g,
                error_model: ErrorModel::at_rate(rate),
                seed: 7,
                ..StoreConfig::default()
            };
            // Old path: hand-rolled lifecycle.
            let mut store = WeightStore::load(&sc, &wf).unwrap();
            let want = store.materialize().unwrap();
            let want_report = store.report();
            // New path: the deployment builder.
            let dep = Deployment::builder()
                .weights(wf.clone())
                .store(sc.clone())
                .build()
                .unwrap();
            for (a, b) in want.iter().zip(dep.tensors()) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.data, b.data, "{policy:?} rate={rate} g={g} {}", a.name);
            }
            let got = dep.store_report();
            assert_eq!(got.write_energy, want_report.write_energy, "{policy:?} rate={rate}");
            assert_eq!(got.read_energy, want_report.read_energy, "{policy:?} rate={rate}");
            assert_eq!(got.injected_faults, want_report.injected_faults);
            assert_eq!(got.soft_cells_stored, want_report.soft_cells_stored);
            assert_eq!(got.metadata_overhead, want_report.metadata_overhead);
            assert_eq!(got.tensors, want_report.tensors);
            assert_eq!(got.weights, want_report.weights);
        }
    }
}

#[test]
fn accuracy_experiment_loop_matches_old_path_on_synthetic_task() {
    // The Fig. 8 per-policy loop scored without PJRT: restaging into the
    // synthetic linear task, accuracies and flip counts must match the
    // pre-facade hand-rolled sequence exactly.
    let task = common::SyntheticTask::new(8, 256, 64, "facade/acc");
    let wf = task.weight_file();
    for policy in Policy::ALL {
        let sc = StoreConfig {
            policy,
            granularity: 4,
            error_model: ErrorModel::at_rate(0.02),
            seed: 7,
            ..StoreConfig::default()
        };
        let mut store = WeightStore::load(&sc, &wf).unwrap();
        let old_tensors = store.materialize().unwrap();
        let old_acc = task.accuracy(&old_tensors[0].data);
        let old_flips = store.report().injected_faults;

        let dep = Deployment::builder().weights(wf.clone()).store(sc).build().unwrap();
        let new_acc = task.accuracy(&dep.tensors()[0].data);
        assert_eq!(new_acc, old_acc, "{policy:?}");
        assert_eq!(dep.store_report().injected_faults, old_flips, "{policy:?}");
    }
}

#[test]
fn flip_aware_sweep_matches_rematerialize_oracle_and_restage_baseline() {
    let wf = common::weight_file_for("inceptionmini", 4, 15_000, "facade/sweep");
    let rates = [0.0f64, 0.005, 0.02];
    let base = StoreConfig {
        granularity: 4,
        seed: 0xFACADE,
        ..StoreConfig::default()
    };
    let fidelity = |tensors: &[mlcstt::runtime::artifacts::ParamSpec]| {
        let mut same = 0usize;
        let mut total = 0usize;
        for (c, t) in wf.params.iter().zip(tensors) {
            for (a, b) in c.data.iter().zip(&t.data) {
                same += (fp::quantize_f16(*a).to_bits() == b.to_bits()) as usize;
                total += 1;
            }
        }
        same as f64 / total as f64
    };

    let (fast, fast_passes) = run_rate_sweep_with(&wf, &base, &rates, |_, _, tensors, _| {
        Ok(fidelity(tensors))
    })
    .unwrap();
    let (oracle, oracle_passes) =
        run_rate_sweep_with_rematerialize(&wf, &base, &rates, |_, _, tensors, _| {
            Ok(fidelity(tensors))
        })
        .unwrap();
    assert_eq!(fast_passes, Policy::ALL.len());
    assert_eq!(oracle_passes, Policy::ALL.len());

    for (pi, &rate) in rates.iter().enumerate() {
        for (si, policy) in Policy::ALL.into_iter().enumerate() {
            let (f, o) = (&fast[pi], &oracle[pi]);
            assert_eq!(f.rows[si].accuracy, o.rows[si].accuracy, "{policy:?} rate={rate}");
            assert_eq!(f.rows[si].flipped_cells, o.rows[si].flipped_cells);
            assert_eq!(f.reports[si].read_energy, o.reports[si].read_energy);
            assert_eq!(f.reports[si].write_energy, o.reports[si].write_energy);
            assert_eq!(f.reports[si].injected_faults, o.reports[si].injected_faults);

            // Restage-per-point baseline: a fresh store per (policy, rate).
            let cfg = StoreConfig {
                policy,
                error_model: ErrorModel::at_rate(rate),
                ..base.clone()
            };
            let mut store = WeightStore::load(&cfg, &wf).unwrap();
            let tensors = store.materialize().unwrap();
            let report = store.report();
            assert_eq!(f.rows[si].accuracy, fidelity(&tensors), "{policy:?} rate={rate}");
            assert_eq!(f.reports[si].read_energy, report.read_energy, "{policy:?} rate={rate}");
            assert_eq!(f.reports[si].write_energy, report.write_energy);
            assert_eq!(f.reports[si].injected_faults, report.injected_faults);
        }
    }
}

/// Linear engine over buffer-materialized weights — both serving paths
/// must classify identically.
fn buffered_linear(task: &common::SyntheticTask, rate: f64, seed: u64) -> LinearEngine {
    let dep = Deployment::builder()
        .weights(task.weight_file())
        .error_model(ErrorModel::at_rate(rate))
        .seed(seed)
        .build()
        .unwrap();
    LinearEngine::new(task.classes, task.dim, 4, dep.tensors()[0].data.clone()).unwrap()
}

#[test]
fn registry_serving_matches_direct_server_and_routes_deterministically() {
    let task_a = common::SyntheticTask::new(6, 128, 48, "facade/serve-a");
    let task_b = common::SyntheticTask::new(6, 128, 48, "facade/serve-b");
    let engine_a = buffered_linear(&task_a, 0.02, 11);
    let engine_b = buffered_linear(&task_b, 0.0, 12);

    // Ground truth straight from the engine (no serving layer).
    let expect = |eng: &LinearEngine, task: &common::SyntheticTask| -> Vec<usize> {
        (0..task.labels.len())
            .map(|i| eng.classify_one(&task.samples[i * task.dim..(i + 1) * task.dim]))
            .collect()
    };
    let want_a = expect(&engine_a, &task_a);
    let want_b = expect(&engine_b, &task_b);

    // Old path: one direct Server around engine a.
    let ea = engine_a.clone();
    let server = Server::start(move || Ok(ea), serve_cfg()).unwrap();
    let direct: Vec<usize> = (0..task_a.labels.len())
        .map(|i| {
            let img = task_a.samples[i * task_a.dim..(i + 1) * task_a.dim].to_vec();
            server.submit(img).unwrap().ticket().unwrap().wait().unwrap().class
        })
        .collect();
    server.shutdown();
    assert_eq!(direct, want_a, "direct server must match the bare engine");

    // New path: both models behind the registry, requests interleaved.
    let (ea, eb) = (engine_a.clone(), engine_b.clone());
    let mut registry = ModelRegistry::new();
    registry.register("a", move || Ok(ea), serve_cfg()).unwrap();
    registry.register("b", move || Ok(eb), serve_cfg()).unwrap();
    let mut tickets = Vec::new();
    for i in 0..task_a.labels.len() {
        let img_a = task_a.samples[i * task_a.dim..(i + 1) * task_a.dim].to_vec();
        let img_b = task_b.samples[i * task_b.dim..(i + 1) * task_b.dim].to_vec();
        tickets.push(("a", i, registry.submit("a", img_a).unwrap().ticket().unwrap()));
        tickets.push(("b", i, registry.submit("b", img_b).unwrap().ticket().unwrap()));
    }
    for (tag, i, ticket) in tickets {
        let got = ticket.wait().unwrap().class;
        let want = if tag == "a" { want_a[i] } else { want_b[i] };
        assert_eq!(got, want, "model {tag} request {i}");
    }
    let report = registry.shutdown();
    assert_eq!(report.sections.len(), 2);
    assert_eq!(report.sections[0].1.served, task_a.labels.len());
    assert_eq!(report.sections[1].1.served, task_b.labels.len());
}

#[test]
fn threaded_censuses_are_integer_exact_at_every_worker_count() {
    let ws = common::trained_like_weights(70_001, "facade/census");
    let enc = WeightCodec::hybrid(4).encode(&ws);
    // Per-word ground truth.
    let mut pc = [0u64; 4];
    let mut soft = 0u64;
    for &w in &enc.words {
        for (a, p) in pc.iter_mut().zip(fp::pattern_counts(w)) {
            *a += p as u64;
        }
        soft += fp::soft_cells(w) as u64;
    }
    assert_eq!(enc.pattern_counts(), pc);
    assert_eq!(enc.soft_cells(), soft);
    for workers in [1usize, 2, 3, 7, 16] {
        assert_eq!(fp::count_patterns_threaded(&enc.words, workers), pc, "workers={workers}");
        assert_eq!(fp::soft_cells_threaded(&enc.words, workers), soft, "workers={workers}");
    }
}

#[test]
fn config_views_feed_the_serve_path() {
    // The config's server/store views are what `mlcstt serve` now runs
    // on; pin the wiring (threads ceiling flows into both views).
    let cfg = Config::builder().threads(2).max_wait(Duration::from_millis(3)).build();
    assert_eq!(cfg.server().codec_threads, 2);
    assert_eq!(cfg.server().max_wait, Duration::from_millis(3));
    assert_eq!(cfg.store().threads, 2);
    // And a deployment built under it pins its store to the ceiling while
    // staying bit-identical to the auto path (worker invariance).
    let task = common::SyntheticTask::new(4, 64, 8, "facade/config");
    let wf = task.weight_file();
    let pinned = Deployment::builder()
        .config(cfg)
        .weights(wf.clone())
        .error_model(ErrorModel::at_rate(0.02))
        .seed(5)
        .build()
        .unwrap();
    let auto = Deployment::builder()
        .weights(wf)
        .threads(0)
        .error_model(ErrorModel::at_rate(0.02))
        .seed(5)
        .build()
        .unwrap();
    assert_eq!(pinned.tensors()[0].data, auto.tensors()[0].data);
    assert_eq!(
        pinned.store_report().injected_faults,
        auto.store_report().injected_faults
    );
}
