//! Read-path parity (ISSUE 3): the threaded load/decode overhaul must
//! change speed and nothing else.
//!
//! * threaded-vs-single `load` / `load_with_disturb` determinism across
//!   1/2/7 workers (including the shard-carry rule at bank boundaries that
//!   are *not* aligned with [`LOAD_SHARD_WORDS`]);
//! * exhaustive 65536-pattern equivalence of the LUT and branchless f16
//!   converters against the scalar oracle, in every lane position;
//! * fault-sampler compatibility: the geometric-skip slice sampler vs the
//!   retained binomial/naive paths at rates {0, 1.5e-2, 2e-2, 1.0}.
//!
//! The `MLCSTT_THREADS` plumbing satellite lives in `tests/env_plumbing.rs`
//! (its own binary — it mutates the environment).

mod common;

use mlcstt::buffer::{BufferConfig, LOAD_SHARD_WORDS, MlcBuffer, Region};
use mlcstt::encoding::{Policy, WeightCodec};
use mlcstt::fp;
use mlcstt::stt::error::{ERROR_RATE_HI, ERROR_RATE_LO};
use mlcstt::stt::ErrorModel;
use mlcstt::util::rng::Xoshiro256;

// ------------------------------------------------------------- threading

/// A stored multi-shard region whose bank width (7) does not divide
/// [`LOAD_SHARD_WORDS`]: every interior shard boundary lands mid-slot, so
/// the carry rule is exercised on each one.
fn stored_buffer(banks: usize, write_rate: f64, disturb: f64) -> (MlcBuffer, Region) {
    let ws = common::trained_like_weights(2 * LOAD_SHARD_WORDS + 4321, "read_path/load");
    let enc = WeightCodec::hybrid(16).encode(&ws);
    let cfg = BufferConfig::new(enc.len() * 2, banks)
        .with_error_model(ErrorModel::new(write_rate, disturb));
    let mut buf = MlcBuffer::new(cfg, 0x10AD);
    let region = buf.store(&enc).unwrap();
    (buf, region)
}

#[test]
fn threaded_load_bit_identical_across_worker_counts() {
    for banks in [1usize, 7, 16] {
        let run = |workers: usize| {
            let (mut buf, region) = stored_buffer(banks, ERROR_RATE_LO, 0.0);
            buf.reset_stats();
            let enc = buf.load_with_threads(&region, workers).unwrap();
            let stats = buf.stats().clone();
            (enc.words, enc.schemes, stats.read_energy, stats.reads)
        };
        let (w1, s1, e1, r1) = run(1);
        for workers in [2usize, 7] {
            let (wn, sn, en, rn) = run(workers);
            assert_eq!(w1, wn, "banks={banks} workers={workers}");
            assert_eq!(s1, sn, "banks={banks} workers={workers}");
            assert_eq!(e1, en, "banks={banks} workers={workers}: read bill differs");
            assert_eq!(r1, rn, "banks={banks} workers={workers}");
        }
    }
}

#[test]
fn threaded_load_cycles_match_serial_slot_walk() {
    // The carry-rule reduction must equal a straightforward serial walk of
    // the banked slots (the pre-threading definition of read latency).
    for banks in [1usize, 4, 7] {
        let (mut buf, region) = stored_buffer(banks, ERROR_RATE_HI, 0.0);
        buf.reset_stats();
        let enc = buf.load_with_threads(&region, 5).unwrap();
        let cost = buf.config.cost.clone();
        let mut want_cycles = 0u64;
        let mut want_nj = 0.0f64;
        for slot in enc.words.chunks(banks) {
            let mut slot_max = 0u64;
            for &w in slot {
                let e = cost.word(w, mlcstt::stt::AccessKind::Read);
                want_nj += e.nanojoules;
                slot_max = slot_max.max(e.cycles);
            }
            want_cycles += slot_max;
        }
        // Metadata reads billed on top of the payload walk. Cycles are
        // integer-exact; nanojoules allow for the shard-partial summation
        // order differing from this flat serial walk.
        let meta = cost.trilevel_cell(mlcstt::stt::AccessKind::Read);
        let groups = enc.schemes.len() as u64;
        let got = buf.stats().read_energy;
        assert_eq!(got.cycles, want_cycles + meta.cycles * groups, "banks={banks}");
        let want_nj = want_nj + meta.nanojoules * groups as f64;
        assert!(
            (got.nanojoules - want_nj).abs() < 1e-9 * want_nj.max(1.0),
            "banks={banks}: {} vs {want_nj}",
            got.nanojoules
        );
    }
}

#[test]
fn threaded_disturb_load_bit_identical_across_worker_counts() {
    let run = |workers: usize| {
        let (mut buf, region) = stored_buffer(7, 0.0, 0.05);
        assert_eq!(buf.stats().injected_faults, 0, "write path must be clean");
        let enc = buf.load_with_disturb_threads(&region, workers).unwrap();
        let stats = buf.stats().clone();
        // Disturbance is persistent: a second plain load sees the flips.
        let again = buf.load_with_threads(&region, workers).unwrap();
        assert_eq!(enc.words, again.words);
        (enc.words, stats.injected_faults, stats.read_energy)
    };
    let (w1, f1, e1) = run(1);
    assert!(f1 > 0, "disturb path inert at rate 0.05");
    for workers in [2usize, 7] {
        let (wn, fn_, en) = run(workers);
        assert_eq!(w1, wn, "workers={workers}");
        assert_eq!(f1, fn_, "workers={workers}");
        assert_eq!(e1, en, "workers={workers}");
    }
}

// ------------------------------------------------------------ converters
//
// The per-function exhaustive LUT/branchless-vs-scalar sweep lives in
// `fp`'s unit tests; these cover the *batch* entry points the codec uses.

#[test]
fn exhaustive_decode_slice_every_lane_position() {
    // Every pattern rides through the batch decode in all four positions
    // of a mixed-neighbour word group (a lane-position regression would
    // only show against varied neighbours).
    let mut dst = [0f32; 4];
    for h in 0..=u16::MAX {
        let a = h.wrapping_mul(0x9E37).rotate_left(3);
        let src = [h, a, !h, h ^ 0x5A5A];
        fp::decode_f16_slice(&src, &mut dst);
        for (i, (&s, &d)) in src.iter().zip(&dst).enumerate() {
            assert_eq!(
                d.to_bits(),
                fp::f16_bits_to_f32(s).to_bits(),
                "h={h:#06x} lane={i}"
            );
        }
    }
}

#[test]
fn exhaustive_fast_encoder_roundtrip_and_quantize_slice() {
    // Every f16 value, encoded back from its exact f32 image, through both
    // the fast scalar call and the batch quantize path.
    let mut xs = Vec::with_capacity(1 << 16);
    let mut want = Vec::with_capacity(1 << 16);
    for h in 0..=u16::MAX {
        let x = fp::f16_bits_to_f32(h);
        let w = fp::f32_to_f16_bits(x);
        assert_eq!(fp::f32_to_f16_bits_fast(x), w, "h={h:#06x}");
        xs.push(x);
        want.push(w);
    }
    let mut out = vec![0u16; xs.len()];
    fp::quantize_into(&xs, &mut out);
    assert_eq!(out, want);
}

// ---------------------------------------------------- fault-sampler compat

fn mixed_words(n: usize, tag: &str) -> Vec<u16> {
    let ws = common::trained_like_weights(n, tag);
    WeightCodec::new(Policy::Unprotected, 1).encode(&ws).words
}

#[test]
fn sampler_compat_rate_zero_is_identity_for_all_paths() {
    let model = ErrorModel::at_rate(0.0);
    let orig = mixed_words(4096, "compat/zero");
    let mut geo = orig.clone();
    let mut rng = Xoshiro256::seeded(1);
    assert_eq!(model.corrupt_words_write(&mut geo, &mut rng), (0, 0));
    assert_eq!(geo, orig);
    let mut rng = Xoshiro256::seeded(1);
    for &w in &orig {
        assert_eq!(model.corrupt_word_write(w, &mut rng), w);
        assert_eq!(model.corrupt_word_write_naive(w, &mut rng), w);
    }
}

#[test]
fn sampler_compat_rate_one_flips_the_same_cell_sets() {
    // At rate 1 the flipped-cell set is deterministic (every vulnerable
    // cell, exactly one junction) — old binomial and new geometric paths
    // must corrupt identical cell sets, junction choice aside.
    let model = ErrorModel::at_rate(1.0);
    let orig = mixed_words(4099, "compat/one");
    let mut geo = orig.clone();
    let mut rng = Xoshiro256::seeded(2);
    model.corrupt_words_write(&mut geo, &mut rng);
    let mut rng = Xoshiro256::seeded(3);
    for (&o, &g) in orig.iter().zip(&geo) {
        let b = model.corrupt_word_write(o, &mut rng);
        let soft = (o ^ (o >> 1)) & 0x5555;
        for cell in 0..8u32 {
            let is_soft = (soft >> (2 * cell)) & 1 != 0;
            let dg = ((o ^ g) >> (2 * cell)) & 0b11;
            let db = ((o ^ b) >> (2 * cell)) & 0b11;
            if is_soft {
                assert!(dg == 0b01 || dg == 0b10, "geo missed a soft cell, o={o:#06x}");
                assert!(db == 0b01 || db == 0b10, "binomial missed a soft cell");
            } else {
                assert_eq!(dg, 0, "geo touched a base cell, o={o:#06x}");
                assert_eq!(db, 0, "binomial touched a base cell");
            }
        }
    }
}

#[test]
fn sampler_compat_published_rates_match_binomial_statistics() {
    // At the paper's rates the three samplers draw from the same per-cell
    // Bernoulli law: compare total-flip means over repeated passes.
    for rate in [ERROR_RATE_LO, ERROR_RATE_HI] {
        let model = ErrorModel::at_rate(rate);
        let orig = mixed_words(8192, "compat/rates");
        let soft_total: u64 = orig.iter().map(|&w| fp::soft_cells(w) as u64).sum();
        let expect = soft_total as f64 * rate;
        let passes = 60;

        let mut rng = Xoshiro256::seeded(11);
        let mut geo_flips = 0u64;
        for _ in 0..passes {
            let mut buf = orig.clone();
            let (_, cells) = model.corrupt_words_write(&mut buf, &mut rng);
            geo_flips += cells;
        }
        let mut rng = Xoshiro256::seeded(12);
        let mut bin_flips = 0u64;
        for _ in 0..passes {
            for &w in &orig {
                let c = model.corrupt_word_write(w, &mut rng);
                bin_flips += u64::from(fp::soft_cells(w ^ c).max(1)) * u64::from(c != w);
            }
        }
        let geo_mean = geo_flips as f64 / passes as f64;
        let bin_mean = bin_flips as f64 / passes as f64;
        // Mean flips per pass within 5% of the analytic expectation for
        // both samplers (tight enough to catch an off-by-one in the skip
        // bookkeeping, loose enough to never flake at these sample sizes).
        assert!(
            (geo_mean - expect).abs() / expect < 0.05,
            "rate={rate}: geometric mean {geo_mean} vs expected {expect}"
        );
        assert!(
            (bin_mean - expect).abs() / expect < 0.05,
            "rate={rate}: binomial mean {bin_mean} vs expected {expect}"
        );
    }
}

// The `MLCSTT_THREADS` plumbing test lives in its own binary
// (`tests/env_plumbing.rs`): it mutates the process environment, and
// glibc setenv racing the getenv calls sibling tests make (via
// `threads::available` / `fp::f16_mode`) would be undefined behavior.
