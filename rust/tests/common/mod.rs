//! Shared fixture layer for the integration/e2e suite (registered targets
//! include this via `mod common;` — with `autotests = false` in Cargo.toml
//! the directory itself never becomes a test binary).
//!
//! Provides the three fixtures ISSUE 1 calls for:
//!
//! * seeded RNG streams derived from a human-readable tag, so every test's
//!   randomness is independent yet reproducible;
//! * canned VGG16 / Inception-V3 / Mini-net layer slices from
//!   [`mlcstt::models`], paired with trained-shaped synthetic weights, so
//!   weight-path tests exercise real layer geometries without artifacts;
//! * unique temp artifact directories that clean up on drop.
//!
//! Plus the pure-Rust synthetic classification task the e2e pipeline uses
//! to measure *model accuracy* end to end when no PJRT backend is linked:
//! a linear (nearest-centroid-style) classifier over Gaussian class blobs
//! whose weight matrix lives in the simulated MLC buffer.

#![allow(dead_code)] // each test binary uses the subset it needs

use std::path::PathBuf;

use mlcstt::models::{self, ConvLayer};
use mlcstt::runtime::artifacts::{ParamSpec, WeightFile};
use mlcstt::util::rng::Xoshiro256;

// ---------------------------------------------------------------- rng

/// Stable 64-bit hash of a tag (FNV-1a) — lets each test derive an
/// independent, documented seed from a string instead of a magic number.
pub fn seed_of(tag: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in tag.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Seeded generator for a named fixture stream.
pub fn rng(tag: &str) -> Xoshiro256 {
    Xoshiro256::seeded(seed_of(tag))
}

/// Clipped-Gaussian weights — the shape of trained conv-net weights, and
/// within the paper's |w| <= 1 premise.
pub fn trained_like_weights(n: usize, tag: &str) -> Vec<f32> {
    let mut r = rng(tag);
    (0..n)
        .map(|_| ((r.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
        .collect()
}

// ---------------------------------------------------------------- models

/// A canned slice of a real network's layer table: `(network, layers)`.
/// The e2e tests size their tensors after these geometries so buffer
/// layout/granularity interactions happen at realistic shapes.
pub fn layer_slice(net: &str, take: usize) -> Vec<ConvLayer> {
    let layers = models::by_name(net).expect("known model table");
    layers.into_iter().take(take).collect()
}

/// A `WeightFile` with one synthetic trained-shaped tensor per layer of a
/// canned model slice (weight counts capped per layer to keep tests fast).
pub fn weight_file_for(net: &str, take: usize, cap_per_layer: usize, tag: &str) -> WeightFile {
    let params = layer_slice(net, take)
        .iter()
        .map(|l| {
            let n = l.weight_elems().min(cap_per_layer).max(1);
            ParamSpec {
                name: l.name.clone(),
                shape: vec![n],
                data: trained_like_weights(n, &format!("{tag}/{}", l.name)),
            }
        })
        .collect();
    WeightFile { params }
}

// ---------------------------------------------------------------- tmp dirs

/// A unique temp directory that is removed when dropped.
pub struct TmpDir {
    path: PathBuf,
}

impl TmpDir {
    /// Unique per (test-tag, process): no `Date.now`-style entropy needed.
    pub fn new(tag: &str) -> TmpDir {
        let path = std::env::temp_dir().join(format!(
            "mlcstt-test-{}-{:016x}",
            std::process::id(),
            seed_of(tag)
        ));
        // A stale dir from a crashed run is fine to reuse after cleaning.
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).expect("create tmp artifact dir");
        TmpDir { path }
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TmpDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

// ---------------------------------------------------------------- task

/// A synthetic linear classification task with a known-good weight matrix.
///
/// `classes` unit-scale centroid rows form the weight matrix `w[c][d]`
/// (every entry in [-1, 1], satisfying the trainer's clip premise); samples
/// are `centroid + noise`, classified by `argmax_c x · w_c`. Clean accuracy
/// is ~100% by construction, with margins wide enough that the bounded
/// (|Δw| < 2) perturbations a *sign-protected* fault campaign can produce
/// leave predictions intact, while the unbounded (±65504-scale) outliers
/// unprotected faults produce scramble them — the paper's Fig. 8 mechanism
/// in miniature.
pub struct SyntheticTask {
    pub classes: usize,
    pub dim: usize,
    /// Flattened class-major weight matrix, the tensor under test.
    pub weights: Vec<f32>,
    /// Evaluation set: flattened samples + labels.
    pub samples: Vec<f32>,
    pub labels: Vec<usize>,
}

impl SyntheticTask {
    pub fn new(classes: usize, dim: usize, eval_n: usize, tag: &str) -> SyntheticTask {
        let mut r = rng(&format!("task/{tag}"));
        // Random ±0.5 centroid rows: far apart w.h.p. in high dimension.
        let weights: Vec<f32> = (0..classes * dim)
            .map(|_| if r.chance(0.5) { 0.5 } else { -0.5 })
            .collect();
        let mut samples = Vec::with_capacity(eval_n * dim);
        let mut labels = Vec::with_capacity(eval_n);
        for i in 0..eval_n {
            let c = i % classes;
            labels.push(c);
            for d in 0..dim {
                let noise = (r.next_gaussian() * 0.1) as f32;
                samples.push(weights[c * dim + d] + noise);
            }
        }
        SyntheticTask {
            classes,
            dim,
            weights,
            samples,
            labels,
        }
    }

    /// The weight matrix as a one-tensor `WeightFile` (the coordinator's
    /// input format).
    pub fn weight_file(&self) -> WeightFile {
        WeightFile {
            params: vec![ParamSpec {
                name: "classifier.w".into(),
                shape: vec![self.classes, self.dim],
                data: self.weights.clone(),
            }],
        }
    }

    /// Accuracy of the classifier under a (possibly corrupted) weight
    /// matrix. NaN scores (decodable from unprotected fault patterns) rank
    /// below every real score.
    pub fn accuracy(&self, weights: &[f32]) -> f64 {
        assert_eq!(weights.len(), self.classes * self.dim);
        let n = self.labels.len();
        let mut correct = 0usize;
        for (i, &label) in self.labels.iter().enumerate() {
            let x = &self.samples[i * self.dim..(i + 1) * self.dim];
            let mut best = 0usize;
            let mut best_score = f64::NEG_INFINITY;
            for c in 0..self.classes {
                let w = &weights[c * self.dim..(c + 1) * self.dim];
                let score: f64 = x
                    .iter()
                    .zip(w)
                    .map(|(a, b)| (*a as f64) * (*b as f64))
                    .sum();
                if score.is_finite() && score > best_score {
                    best_score = score;
                    best = c;
                }
            }
            if best == label {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }
}
