//! Overload-grade serving contracts (ISSUE 6): typed engine errors,
//! NaN-free idle reports, bounded-queue shedding, cross-model fairness,
//! backlog batch formation, and the 2x-saturation envelope.
//!
//! Everything here runs backend-free: `LinearEngine` (pure host) plus
//! `ThrottledEngine` (fixed per-batch service time, so saturation is
//! known by construction) drive the identical `Server`/`ModelRegistry`
//! path the PJRT engine uses.

use std::cell::Cell;
use std::time::Duration;

use anyhow::Result;
use mlcstt::api::ModelRegistry;
use mlcstt::coordinator::{
    Admission, BatchClassifier, LinearEngine, RequestError, Server, ServerConfig, ThrottledEngine,
};

/// A classifier whose engine always fails — the LinearEngine-shaped
/// stand-in for a PJRT executor dying mid-serve.
struct FailingEngine {
    batch: usize,
    dim: usize,
}

impl BatchClassifier for FailingEngine {
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn image_elems(&self) -> usize {
        self.dim
    }
    fn classify_batch(&self, _images: &[f32]) -> Result<Vec<usize>> {
        anyhow::bail!("device lost")
    }
}

/// Fails every other batch (first fails). `Cell` is fine: the engine
/// lives on its single worker thread and never crosses it.
struct FlakyEngine {
    inner: LinearEngine,
    calls: Cell<usize>,
}

impl BatchClassifier for FlakyEngine {
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }
    fn image_elems(&self) -> usize {
        self.inner.image_elems()
    }
    fn classify_batch(&self, images: &[f32]) -> Result<Vec<usize>> {
        let n = self.calls.get();
        self.calls.set(n + 1);
        if n % 2 == 0 {
            anyhow::bail!("transient device error");
        }
        self.inner.classify_batch(images)
    }
}

fn linear(batch: usize) -> LinearEngine {
    // Class 0 likes +x, class 1 likes -x.
    LinearEngine::new(2, 2, batch, vec![1.0, 0.0, -1.0, 0.0]).unwrap()
}

fn cfg(max_wait_ms: u64, queue_depth: usize) -> ServerConfig {
    ServerConfig {
        max_wait: Duration::from_millis(max_wait_ms),
        codec_threads: 1,
        queue_depth,
    }
}

/// The headline bugfix pin: an engine error must never surface to a
/// client as a successful class-0 prediction, never count as served, and
/// never contribute a latency sample.
#[test]
fn engine_errors_are_typed_not_class_zero() {
    let server = Server::start(|| Ok(FailingEngine { batch: 4, dim: 2 }), cfg(1, 64)).unwrap();
    let n = 8usize;
    let mut tickets = Vec::new();
    for _ in 0..n {
        tickets.push(server.submit(vec![1.0, 0.0]).unwrap().ticket().unwrap());
    }
    for t in tickets {
        match t.wait() {
            Err(RequestError::Engine { message }) => {
                assert!(message.contains("device lost"), "{message}");
            }
            other => panic!("engine failure must be a typed error, got {other:?}"),
        }
    }
    let rep = server.shutdown();
    assert_eq!(rep.errors, n, "every request counted as an error");
    assert_eq!(rep.served, 0, "no fabricated successes");
    assert_eq!(rep.shed, 0);
    assert!(rep.batches >= 1);
    assert_eq!(rep.p50_ms, 0.0, "failed requests leave no latency samples");
    assert_eq!(rep.throughput_rps, 0.0);
}

/// A flaky engine splits traffic into served + errors with nothing lost.
#[test]
fn flaky_engine_accounts_every_request() {
    let server = Server::start(
        || {
            Ok(FlakyEngine {
                inner: linear(1),
                calls: Cell::new(0),
            })
        },
        cfg(1, 64),
    )
    .unwrap();
    // Sequential submit -> wait: batch size 1 makes each request its own
    // batch, so outcomes alternate error/success deterministically.
    let n = 6usize;
    let mut served = 0usize;
    let mut errors = 0usize;
    for _ in 0..n {
        match server.submit(vec![1.0, 0.0]).unwrap().ticket().unwrap().wait() {
            Ok(resp) => {
                assert_eq!(resp.class, 0);
                served += 1;
            }
            Err(RequestError::Engine { .. }) => errors += 1,
            Err(e) => panic!("unexpected outcome {e:?}"),
        }
    }
    assert_eq!((served, errors), (3, 3));
    let rep = server.shutdown();
    assert_eq!(rep.served, served);
    assert_eq!(rep.errors, errors);
    assert!(rep.p50_ms > 0.0, "served requests do leave latency samples");
    assert!(rep.throughput_rps > 0.0);
}

/// The NaN bugfix pin: an idle server reports a defined zero, not NaN.
#[test]
fn idle_shutdown_reports_zero_not_nan() {
    let server = Server::start(|| Ok(linear(2)), cfg(1, 64)).unwrap();
    let rep = server.shutdown();
    assert_eq!(rep.served, 0);
    assert_eq!(rep.throughput_rps, 0.0, "idle window is 0.0, not NaN");
    assert!(!rep.throughput_rps.is_nan());
    assert!(rep.wall_s >= 0.0);
    assert_eq!(rep.p50_ms, 0.0);
    assert_eq!(rep.queue_max, 0);
    assert_eq!(rep.queue_mean, 0.0);
}

/// Near-instant serving must produce a finite throughput (the historical
/// `started == finished` window yielded inf).
#[test]
fn instant_serve_reports_finite_throughput() {
    let server = Server::start(|| Ok(linear(1)), cfg(1, 64)).unwrap();
    let resp = server
        .submit(vec![1.0, 0.0])
        .unwrap()
        .ticket()
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.class, 0);
    let rep = server.shutdown();
    assert_eq!(rep.served, 1);
    assert!(rep.throughput_rps.is_finite());
    assert!(rep.throughput_rps >= 0.0);
    assert!(rep.wall_s >= 0.0);
}

/// Bounded admission: past `queue_depth` in-flight requests, submits shed
/// immediately with a typed rejection — they never block, and the
/// server's shed counter matches the client's count exactly.
#[test]
fn full_queue_sheds_instead_of_blocking() {
    let server = Server::start(
        || Ok(ThrottledEngine::new(linear(2), Duration::from_millis(20))),
        cfg(1, 4),
    )
    .unwrap();
    let n = 40usize;
    let mut tickets = Vec::new();
    let mut shed = 0usize;
    for _ in 0..n {
        match server.submit(vec![1.0, 0.0]).unwrap() {
            Admission::Accepted(t) => tickets.push(t),
            Admission::Rejected { depth } => {
                assert!(depth >= 4, "shed only at the bound, observed {depth}");
                shed += 1;
            }
        }
    }
    assert!(shed > 0, "2x+ overload against depth 4 must shed");
    let accepted = tickets.len();
    for t in tickets {
        t.wait().unwrap();
    }
    let rep = server.shutdown();
    assert_eq!(rep.shed, shed, "server-side shed counter matches client");
    assert_eq!(rep.served, accepted);
    assert_eq!(rep.served + rep.shed, n, "every request accounted");
    assert!(rep.queue_max <= 4, "observed depth never exceeds the bound");
    assert!(rep.queue_max > 0);
    // Bounded queue => bounded latency: worst case is the full queue
    // draining ahead of you, far under this ceiling.
    assert!(rep.p99_ms < 1000.0, "p99 {} ms", rep.p99_ms);
}

/// `Admission::ticket()` converts a shed into the typed error.
#[test]
fn rejected_admission_converts_to_typed_error() {
    let server = Server::start(
        || Ok(ThrottledEngine::new(linear(1), Duration::from_millis(20))),
        cfg(1, 1),
    )
    .unwrap();
    // Fill the depth-1 queue, then the next submit must shed.
    let mut first = None;
    let mut saw_shed = false;
    for _ in 0..20 {
        match server.submit(vec![1.0, 0.0]).unwrap() {
            Admission::Accepted(t) => {
                if first.is_none() {
                    first = Some(t);
                }
            }
            adm @ Admission::Rejected { .. } => {
                assert!(adm.is_rejected());
                match adm.ticket() {
                    Err(RequestError::Shed { depth }) => assert!(depth >= 1),
                    Err(e) => panic!("expected Shed, got {e:?}"),
                    Ok(_) => panic!("expected Shed, got an accepted ticket"),
                }
                saw_shed = true;
                break;
            }
        }
    }
    assert!(saw_shed, "a depth-1 queue under burst must shed");
    first.unwrap().wait().unwrap();
    server.shutdown();
}

/// Cross-model fairness: under a registry-wide budget, a flooded hot
/// model sheds while a cold sibling keeps serving untouched.
#[test]
fn fair_gate_sheds_hot_model_not_cold() {
    let mut reg = ModelRegistry::with_budget(8);
    reg.register(
        "hot",
        || Ok(ThrottledEngine::new(linear(2), Duration::from_millis(10))),
        cfg(1, 64),
    )
    .unwrap();
    reg.register("cold", || Ok(linear(2)), cfg(1, 64)).unwrap();

    // Flood the hot model far past the shared budget...
    let hot_n = 100usize;
    let mut hot_tickets = Vec::new();
    for _ in 0..hot_n {
        match reg.submit("hot", vec![1.0, 0.0]).unwrap() {
            Admission::Accepted(t) => hot_tickets.push(t),
            Admission::Rejected { .. } => {}
        }
    }
    let depths = reg.queue_depths();
    assert_eq!(depths.len(), 2);
    assert_eq!(depths[0].0, "hot");

    // ...and the cold model still serves every request, sequentially.
    for _ in 0..10 {
        let resp = reg
            .submit("cold", vec![1.0, 0.0])
            .unwrap()
            .ticket()
            .expect("cold model must not shed under the hot flood")
            .wait()
            .unwrap();
        assert_eq!(resp.class, 0);
    }
    for t in hot_tickets {
        t.wait().unwrap();
    }
    let report = reg.shutdown();
    let hot = &report.sections[0].1;
    let cold = &report.sections[1].1;
    assert!(hot.shed > 0, "hot model over its fair share must shed");
    assert_eq!(hot.served + hot.shed, hot_n);
    assert_eq!(cold.shed, 0, "cold model never sheds");
    assert_eq!(cold.served, 10);
    assert_eq!(report.total_served(), hot.served + 10);
    assert_eq!(report.total_shed(), hot.shed);
}

/// A backlogged queue forms (near-)full batches with no added waiting:
/// the coalesce deadline anchors at admission, so queue time eats the
/// batching budget.
#[test]
fn backlog_forms_full_batches() {
    let server = Server::start(
        || Ok(ThrottledEngine::new(linear(4), Duration::from_millis(5))),
        cfg(50, 100),
    )
    .unwrap();
    let n = 40usize;
    let mut tickets = Vec::new();
    for _ in 0..n {
        tickets.push(server.submit(vec![1.0, 0.0]).unwrap().ticket().unwrap());
    }
    for t in tickets {
        t.wait().unwrap();
    }
    let rep = server.shutdown();
    assert_eq!(rep.served, n);
    assert!(
        rep.mean_batch_fill > 2.0,
        "backlog must coalesce, fill {}",
        rep.mean_batch_fill
    );
    assert!(rep.batches < n, "batching actually batched");
    assert!(rep.queue_mean > 0.0);
}

/// The acceptance envelope: offered load at ~2x the known saturation of
/// a throttled engine, against a bounded queue — the run completes with
/// bounded latency, nonzero sheds, full percentile + queue-depth stats.
#[test]
fn two_x_saturation_completes_with_bounded_latency_and_sheds() {
    // batch 8 / 4 ms => saturation 2000 req/s; offer ~4000 req/s.
    let server = Server::start(
        || Ok(ThrottledEngine::new(linear(8), Duration::from_millis(4))),
        cfg(20, 16),
    )
    .unwrap();
    let n = 200usize;
    let gap = Duration::from_micros(250); // 1 / 4000 rps
    // Absolute-schedule pacing (arrival i lands at i * gap): per-sleep
    // overhead cannot accumulate and silently lower the offered rate.
    let start = std::time::Instant::now();
    let mut tickets = Vec::new();
    let mut shed = 0usize;
    for i in 0..n {
        if let Some(ahead) = (gap * i as u32).checked_sub(start.elapsed()) {
            std::thread::sleep(ahead);
        }
        match server.submit(vec![1.0, 0.0]).unwrap() {
            Admission::Accepted(t) => tickets.push(t),
            Admission::Rejected { .. } => shed += 1,
        }
    }
    for t in tickets {
        t.wait().unwrap();
    }
    let rep = server.shutdown();
    assert!(rep.shed > 0, "2x saturation against depth 16 must shed");
    assert_eq!(rep.shed, shed);
    assert!(rep.served > 0);
    assert_eq!(rep.served + rep.shed, n);
    assert_eq!(rep.errors, 0);
    // Full SLO surface: ordered percentiles and queue-depth stats.
    assert!(rep.p50_ms > 0.0);
    assert!(rep.p95_ms >= rep.p50_ms);
    assert!(rep.p99_ms >= rep.p95_ms);
    assert!(rep.queue_max > 0 && rep.queue_max <= 16);
    assert!(rep.queue_mean > 0.0);
    assert!(rep.throughput_rps > 0.0 && rep.throughput_rps.is_finite());
    // Bounded queue => bounded tail: worst case is a full 16-deep queue
    // draining at 2 batches (8 ms) plus service — orders of magnitude
    // under this ceiling even on a loaded CI host.
    assert!(
        rep.p99_ms < 1000.0,
        "latency must not grow without bound, p99 {} ms",
        rep.p99_ms
    );
}

/// Typed unavailability (ISSUE 9 satellite): a parked model — the
/// rebuild/hot-swap window — declines new arrivals with
/// `RequestError::Unavailable` naming the model and reason, never a
/// generic engine error; requests admitted *before* the park still
/// drain; the report counts the declines in its own column; and
/// unparking restores service.
#[test]
fn parked_model_declines_typed_and_drains_admitted_work() {
    let server = Server::start(
        || Ok(ThrottledEngine::new(linear(2), Duration::from_millis(10))),
        cfg(1, 64),
    )
    .unwrap();
    // Admit work, then park: the admitted tickets must still serve.
    let mut admitted = Vec::new();
    for _ in 0..4 {
        admitted.push(server.submit(vec![1.0, 0.0]).unwrap().ticket().unwrap());
    }
    server.set_unavailable("m", "hot swap: draining");
    // New arrivals are declined with the typed reason, not queued.
    let n_declined = 3usize;
    for _ in 0..n_declined {
        match server.submit(vec![1.0, 0.0]).unwrap().ticket().unwrap().wait() {
            Err(RequestError::Unavailable { model, reason }) => {
                assert_eq!(model, "m");
                assert_eq!(reason, "hot swap: draining");
            }
            other => panic!("parked model must decline typed, got {other:?}"),
        }
    }
    for t in admitted {
        assert_eq!(t.wait().unwrap().class, 0, "pre-park work drains normally");
    }
    // Unparking restores service.
    server.set_available();
    let resp = server.submit(vec![1.0, 0.0]).unwrap().ticket().unwrap().wait().unwrap();
    assert_eq!(resp.class, 0);
    let rep = server.shutdown();
    assert_eq!(rep.unavailable, n_declined, "declines counted in their own column");
    assert_eq!(rep.served, 5, "declines are not served");
    assert_eq!(rep.errors, 0, "declines are not engine errors");
    assert_eq!(rep.shed, 0, "declines are not sheds");
}

/// Unknown tags stay errors (now with a lazy, allocation-light message)
/// and indexed routing still addresses the right model.
#[test]
fn registry_unknown_tag_is_lazy_error() {
    let mut reg = ModelRegistry::new();
    reg.register("a", || Ok(linear(2)), cfg(1, 64)).unwrap();
    reg.register("b", || Ok(linear(2)), cfg(1, 64)).unwrap();
    let err = reg.submit("nope", vec![1.0, 0.0]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown model"), "{msg}");
    assert!(msg.contains("2 registered"), "{msg}");
    let resp = reg
        .submit("b", vec![1.0, 0.0])
        .unwrap()
        .ticket()
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.class, 0);
    let report = reg.shutdown();
    assert_eq!(report.total_served(), 1);
}
