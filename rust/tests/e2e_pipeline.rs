//! End-to-end pipeline verification (ISSUE 1 tentpole): drive the full
//! coordinator weight path — encode → store in the banked MLC buffer →
//! seeded fault injection at the paper's soft-error rates → decode →
//! accuracy/energy accounting — and assert the paper's headline result:
//! sign-protected systems lose no accuracy where the unprotected baseline
//! measurably degrades, while the rotate/round reformation cuts the costly
//! `01`/`10` MLC cell patterns and the energy they bill.
//!
//! Inference here is the pure-Rust linear classifier from `common` (this
//! build links the offline `xla` stub, so the PJRT executable path — the
//! same `WeightStore::materialize` tensors fed to `InferenceEngine` — is
//! covered by `integration_coordinator.rs` on provisioned hosts). All
//! randomness is seeded; there is no wall-clock or OS entropy anywhere.

mod common;

use mlcstt::encoding::{Policy, WeightCodec};
use mlcstt::runtime::artifacts::WeightFile;
use mlcstt::coordinator::{StoreConfig, WeightStore};
use mlcstt::stt::error::{ERROR_RATE_HI, ERROR_RATE_LO};
use mlcstt::stt::ErrorModel;

use common::SyntheticTask;

fn store_cfg(policy: Policy, rate: f64, seed: u64) -> StoreConfig {
    StoreConfig {
        policy,
        granularity: 4,
        error_model: ErrorModel::at_rate(rate),
        seed,
        ..StoreConfig::default()
    }
}

/// Push a weight file through the coordinator path and return the decoded
/// (possibly corrupted) flat tensor plus the store's accounting report.
fn through_buffer(
    wf: &WeightFile,
    policy: Policy,
    rate: f64,
    seed: u64,
) -> (Vec<f32>, mlcstt::coordinator::StoreReport) {
    let mut store = WeightStore::load(&store_cfg(policy, rate, seed), wf).expect("store");
    let tensors = store.materialize().expect("materialize");
    let flat: Vec<f32> = tensors.into_iter().flat_map(|p| p.data).collect();
    (flat, store.report())
}

// ---------------------------------------------------------------- headline

#[test]
fn headline_protected_accuracy_survives_where_unprotected_degrades() {
    let task = SyntheticTask::new(10, 256, 400, "headline");
    let wf = task.weight_file();
    let clean_acc = task.accuracy(&task.weights);
    assert!(
        clean_acc > 0.95,
        "task mis-constructed: clean accuracy {clean_acc}"
    );

    // Both published MLC soft-error-rate bounds (Wen et al. [12]).
    for (rate, seed) in [(ERROR_RATE_LO, 0xE2E1u64), (ERROR_RATE_HI, 0xE2E2)] {
        let (raw, raw_report) = through_buffer(&wf, Policy::Unprotected, rate, seed);
        let (hyb, hyb_report) = through_buffer(&wf, Policy::Hybrid, rate, seed);
        let (rot, _) = through_buffer(&wf, Policy::ProtectRotate, rate, seed);

        assert!(
            raw_report.injected_faults > 0,
            "rate {rate}: campaign injected nothing"
        );

        let raw_acc = task.accuracy(&raw);
        let hyb_acc = task.accuracy(&hyb);
        let rot_acc = task.accuracy(&rot);

        // Unprotected: sign/backup-bit flips produce ±65504-scale weight
        // outliers that scramble the argmax — measurable degradation (the
        // typical drop here is tens of points; 5 is the assertion floor).
        assert!(
            raw_acc < clean_acc - 0.05,
            "rate {rate}: unprotected did not degrade (clean {clean_acc}, raw {raw_acc})"
        );
        // Protected systems: every fault is confined to bits 13..0 of a
        // word whose sign pair is immune, so |Δw| stays bounded and the
        // classifier's margins absorb it — no accuracy loss (allow one
        // prediction of slack on 400).
        for (label, acc) in [("hybrid", hyb_acc), ("rotate", rot_acc)] {
            assert!(
                acc >= clean_acc - 1.0 / 400.0 - 1e-9,
                "rate {rate}: {label} lost accuracy (clean {clean_acc}, got {acc})"
            );
        }
        assert!(
            hyb_acc > raw_acc,
            "rate {rate}: hybrid {hyb_acc} should beat unprotected {raw_acc}"
        );

        // Energy accounting along the same transactions: sign protection
        // plus reformation must bill less write energy than the unprotected
        // baseline even though it also pays for the tri-level metadata
        // plane, and must store strictly fewer vulnerable cells.
        assert!(
            hyb_report.write_energy.nanojoules < raw_report.write_energy.nanojoules,
            "rate {rate}: hybrid write {} !< raw write {}",
            hyb_report.write_energy.nanojoules,
            raw_report.write_energy.nanojoules
        );
        assert!(hyb_report.soft_cells_stored < raw_report.soft_cells_stored);
        assert!(hyb_report.metadata_overhead > 0.0);
    }
}

#[test]
fn protected_signs_never_flip_unprotected_signs_do() {
    let task = SyntheticTask::new(10, 256, 16, "signs");
    let wf = task.weight_file();
    let rate = ERROR_RATE_HI;

    let sign_flips = |decoded: &[f32]| {
        task.weights
            .iter()
            .zip(decoded)
            .filter(|(a, b)| a.is_sign_negative() != b.is_sign_negative() && **a != 0.0)
            .count()
    };

    let (raw, _) = through_buffer(&wf, Policy::Unprotected, rate, 0x51);
    assert!(
        sign_flips(&raw) > 0,
        "2560 weights at rate {rate}: expected unprotected sign flips"
    );
    for policy in [Policy::ProtectRound, Policy::ProtectRotate, Policy::Hybrid] {
        let (dec, _) = through_buffer(&wf, policy, rate, 0x51);
        assert_eq!(sign_flips(&dec), 0, "{policy:?} flipped a sign");
    }
}

// ----------------------------------------------------- deterministic bound

#[test]
fn protection_bounds_decoded_magnitude_even_at_rate_one() {
    // The invariant behind the accuracy result, asserted with zero
    // statistical slack: under sign protection the backup/sign cell is a
    // base state (immune), so no fault can push the stored exponent past
    // 01111 — every decoded weight stays finite with |w| < 2 even when
    // EVERY vulnerable cell is corrupted (rate 1.0). The unprotected
    // baseline has no such bound and visibly explodes.
    let wf = common::weight_file_for("vgg16", 6, 4096, "bound/vgg16");

    for policy in [Policy::ProtectRound, Policy::ProtectRotate, Policy::Hybrid] {
        let (dec, report) = through_buffer(&wf, policy, 1.0, 0xB0);
        assert!(report.injected_faults > 0);
        for (i, w) in dec.iter().enumerate() {
            assert!(
                w.is_finite() && w.abs() < 2.0,
                "{policy:?}: decoded[{i}] = {w} escaped the |w| < 2 envelope"
            );
        }
    }

    let (raw, _) = through_buffer(&wf, Policy::Unprotected, 1.0, 0xB0);
    let max = raw.iter().fold(0f32, |m, w| m.max(w.abs()));
    assert!(
        !max.is_finite() || max > 2.0,
        "unprotected at rate 1.0 stayed bounded ({max}) — error model inert?"
    );
}

// ------------------------------------------------- reformation mechanics

#[test]
fn reformation_reduces_costly_intermediate_patterns() {
    // Fig. 6: the rotate/round schemes exist to cut `01`/`10` cells. Check
    // the stored stream census on real layer geometries (VGG16 and
    // Inception-V3 slices) and on the synthetic classifier tensor.
    for (label, wf) in [
        ("vgg16-slice", common::weight_file_for("vgg16", 5, 8192, "fig6/vgg")),
        (
            "inception-slice",
            common::weight_file_for("inception_v3", 8, 8192, "fig6/inc"),
        ),
        ("classifier", SyntheticTask::new(10, 256, 1, "fig6/task").weight_file()),
    ] {
        let flat = wf.flat();
        let raw = WeightCodec::new(Policy::Unprotected, 1).encode(&flat);
        let hyb = WeightCodec::hybrid(4).encode(&flat);
        let rc = raw.pattern_counts();
        let hc = hyb.pattern_counts();
        assert!(
            hc[1] + hc[2] < rc[1] + rc[2],
            "{label}: hybrid {}+{} !< raw {}+{} intermediate cells",
            hc[1],
            hc[2],
            rc[1],
            rc[2]
        );
        // Same cell total: the scheme reshapes patterns, never the length.
        assert_eq!(rc.iter().sum::<u64>(), hc.iter().sum::<u64>());
    }
}

// --------------------------------------------------------- reproducibility

#[test]
fn full_pipeline_is_bit_reproducible_under_seed() {
    let wf = common::weight_file_for("inception_v3", 6, 4096, "repro");
    for policy in [Policy::Unprotected, Policy::Hybrid] {
        let (a, ra) = through_buffer(&wf, policy, ERROR_RATE_HI, 0xD5);
        let (b, rb) = through_buffer(&wf, policy, ERROR_RATE_HI, 0xD5);
        assert_eq!(
            a.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
            "{policy:?}: same seed diverged"
        );
        assert_eq!(ra.injected_faults, rb.injected_faults);

        let (c, _) = through_buffer(&wf, policy, ERROR_RATE_HI, 0xD6);
        assert_ne!(
            a.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
            c.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
            "{policy:?}: different seeds agreed"
        );
    }
}

// ------------------------------------------------------------ artifact io

#[test]
fn pipeline_artifacts_round_trip_through_tmp_dir() {
    // The fixture tmp-dir layer: write a real experiment artifact (the
    // decoded tensors as a manifest-style JSON report), read it back, and
    // confirm cleanup. Keeps the artifact-dir plumbing honest without
    // needing `make artifacts`.
    use mlcstt::util::json::{obj, Json};

    let task = SyntheticTask::new(4, 64, 32, "artifacts");
    let wf = task.weight_file();
    let (dec, report) = through_buffer(&wf, Policy::Hybrid, ERROR_RATE_LO, 0xAA);
    let acc = task.accuracy(&dec);

    let dir = common::TmpDir::new("e2e-artifacts");
    let path = dir.file("e2e_report.json");
    let doc = obj(vec![
        ("policy", "hybrid".into()),
        ("rate", ERROR_RATE_LO.into()),
        ("accuracy", acc.into()),
        ("weights", task.weights.len().into()),
        ("injected_faults", (report.injected_faults as usize).into()),
    ]);
    std::fs::write(&path, doc.to_string_pretty()).expect("write report");

    let back = Json::parse(&std::fs::read_to_string(&path).expect("read report")).expect("parse");
    assert_eq!(back.path("accuracy").and_then(Json::as_f64), Some(acc));
    assert_eq!(
        back.path("weights").and_then(Json::as_usize),
        Some(task.weights.len())
    );

    let kept = dir.path().to_path_buf();
    drop(dir);
    assert!(!kept.exists(), "TmpDir leaked {kept:?}");
}
