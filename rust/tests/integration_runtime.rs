//! Integration: PJRT runtime against the real AOT artifacts.
//!
//! These tests need `make artifacts` to have run; they skip (with a stderr
//! note) when artifacts are absent so `cargo test` stays green on a fresh
//! clone.

use std::path::PathBuf;

use mlcstt::runtime::artifacts::{model_available, model_paths, Manifest, TestSet, WeightFile};
use mlcstt::runtime::executor::{argmax_rows, Executor};

fn dir() -> PathBuf {
    // MLCSTT_ARTIFACTS resolves through the single env layer.
    mlcstt::api::Config::from_env().artifacts_dir().to_path_buf()
}

macro_rules! require {
    ($cond:expr, $what:expr) => {
        if !$cond {
            eprintln!("SKIP: {} (run `make artifacts`)", $what);
            return;
        }
    };
}

#[test]
fn pallas_matmul_artifact_executes_correctly() {
    // The standalone Pallas weight-stationary GEMM artifact: fn(x[8,16],
    // w[16,12]) -> x @ w. Verify numerics against a host matmul.
    let path = dir().join("matmul_ws.hlo.txt");
    require!(path.exists(), "matmul_ws.hlo.txt");

    let exec = Executor::from_hlo_file(&path).expect("compile");
    let x: Vec<f32> = (0..8 * 16).map(|i| (i as f32 * 0.37).sin()).collect();
    let w: Vec<f32> = (0..16 * 12).map(|i| (i as f32 * 0.11).cos()).collect();
    let out = exec
        .execute_f32(&[(&x, &[8, 16][..]), (&w, &[16, 12][..])])
        .expect("execute");
    assert_eq!(out.len(), 8 * 12);

    // Host reference.
    for i in 0..8 {
        for j in 0..12 {
            let mut acc = 0f32;
            for k in 0..16 {
                acc += x[i * 16 + k] * w[k * 12 + j];
            }
            let got = out[i * 12 + j];
            assert!(
                (acc - got).abs() < 1e-4,
                "[{i},{j}]: host {acc} vs pjrt {got}"
            );
        }
    }
}

#[test]
fn staged_execution_matches_literal_execution() {
    let path = dir().join("matmul_ws.hlo.txt");
    require!(path.exists(), "matmul_ws.hlo.txt");
    let exec = Executor::from_hlo_file(&path).expect("compile");
    let x: Vec<f32> = (0..8 * 16).map(|i| i as f32 * 0.01).collect();
    let w: Vec<f32> = (0..16 * 12).map(|i| 1.0 - i as f32 * 0.005).collect();

    let lit = exec
        .execute_f32(&[(&x, &[8, 16][..]), (&w, &[16, 12][..])])
        .unwrap();

    let xb = exec.stage_f32(&x, &[8, 16]).unwrap();
    let wb = exec.stage_f32(&w, &[16, 12]).unwrap();
    let staged = exec.execute_staged(&[&xb, &wb]).unwrap();
    let staged: Vec<f32> = staged.to_vec().unwrap();
    assert_eq!(lit, staged);

    // Staged buffers are reusable across calls.
    let again: Vec<f32> = exec.execute_staged(&[&xb, &wb]).unwrap().to_vec().unwrap();
    assert_eq!(lit, again);
}

#[test]
fn model_artifacts_are_mutually_consistent() {
    let d = dir();
    require!(model_available(&d, "vggmini"), "vggmini artifacts");
    let (_, wpath, mpath) = model_paths(&d, "vggmini");
    let manifest = Manifest::read(&mpath).unwrap();
    let weights = WeightFile::read(&wpath).unwrap();
    manifest.validate(&weights).unwrap();

    assert_eq!(manifest.input_shape, vec![manifest.batch, 32, 32, 3]);
    assert_eq!(manifest.num_classes, 10);
    // The trainer's premise: every weight within [-1, 1].
    let max = weights
        .flat()
        .iter()
        .fold(0f32, |m, w| m.max(w.abs()));
    assert!(max <= 1.0 + 1e-6, "weight clip violated: {max}");
}

#[test]
fn testset_artifact_well_formed() {
    let path = dir().join("testset.bin");
    require!(path.exists(), "testset.bin");
    let t = TestSet::read(&path).unwrap();
    assert_eq!((t.h, t.w, t.c), (32, 32, 3));
    assert!(t.n >= 256);
    assert_eq!(t.images.len(), t.n * 32 * 32 * 3);
    assert!(t.labels.iter().all(|&l| (0..10).contains(&l)));
    // Labels are roughly balanced (10 classes, multinomial).
    let mut counts = [0usize; 10];
    for &l in &t.labels {
        counts[l as usize] += 1;
    }
    let min = *counts.iter().min().unwrap();
    assert!(min > t.n / 30, "class balance {counts:?}");
}

#[test]
fn model_inference_beats_chance_end_to_end() {
    // Full path: HLO compile -> weights as parameters -> classify a batch.
    let d = dir();
    require!(model_available(&d, "vggmini"), "vggmini artifacts");
    let (hlo, wpath, mpath) = model_paths(&d, "vggmini");
    let manifest = Manifest::read(&mpath).unwrap();
    require!(manifest.test_acc > 0.5, "vggmini trained to usable accuracy");
    let weights = WeightFile::read(&wpath).unwrap();
    let test = TestSet::read(&d.join("testset.bin")).unwrap();

    let exec = Executor::from_hlo_file(&hlo).expect("compile model");
    let mut inputs: Vec<(&[f32], &[usize])> = weights
        .params
        .iter()
        .map(|p| (p.data.as_slice(), p.shape.as_slice()))
        .collect();
    let batch_elems: usize = manifest.input_shape.iter().product();
    let images = &test.images[..batch_elems];
    inputs.push((images, manifest.input_shape.as_slice()));
    let logits = exec.execute_f32(&inputs).expect("execute");
    let preds = argmax_rows(&logits, manifest.num_classes);
    let correct = preds
        .iter()
        .zip(&test.labels[..manifest.batch])
        .filter(|(p, l)| **p == **l as usize)
        .count();
    // A trained model must crush the 10% chance floor on its own test data.
    assert!(
        correct * 2 > manifest.batch,
        "only {correct}/{} correct",
        manifest.batch
    );
}
