//! Cross-module integration: encoding x faults x energy — the paper's
//! claims as executable assertions.

use mlcstt::encoding::{Policy, WeightCodec};
use mlcstt::faults::FaultCampaign;
use mlcstt::fp;
use mlcstt::stt::{AccessKind, CostModel, ErrorModel};
use mlcstt::util::rng::Xoshiro256;

fn trained_like_weights(n: usize, seed: u64) -> Vec<f32> {
    // Clipped Gaussian — the shape of trained conv-net weights.
    let mut rng = Xoshiro256::seeded(seed);
    (0..n)
        .map(|_| ((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
        .collect()
}

#[test]
fn headline_claim_energy_and_reliability_together() {
    // Abstract: "same level of accuracy compared to an error-free baseline
    // while improving the read and write energy" — at the weight level:
    // hybrid must simultaneously (a) never flip a sign, (b) cut both read
    // and write payload energy vs the unprotected baseline.
    let ws = trained_like_weights(100_000, 1);
    let cost = CostModel::default();

    let base = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
    let hyb = WeightCodec::hybrid(4).encode(&ws);

    let pe = |e: &mlcstt::encoding::Encoded, k| -> f64 {
        e.words.iter().map(|&w| cost.word(w, k).nanojoules).sum()
    };
    let read_save = 1.0 - pe(&hyb, AccessKind::Read) / pe(&base, AccessKind::Read);
    let write_save = 1.0 - pe(&hyb, AccessKind::Write) / pe(&base, AccessKind::Write);
    assert!(read_save > 0.03, "read saving {read_save}");
    assert!(write_save > 0.03, "write saving {write_save}");

    let campaign = FaultCampaign::new(ErrorModel::at_rate(0.02), 77);
    let (decoded, _) = campaign.encode_fault_decode(&WeightCodec::hybrid(4), &ws);
    let sign_flips = ws
        .iter()
        .zip(&decoded)
        .filter(|(a, b)| a.is_sign_negative() != b.is_sign_negative() && **a != 0.0)
        .count();
    assert_eq!(sign_flips, 0);
}

#[test]
fn fig6_trend_soft_cells_grow_with_granularity() {
    let ws = trained_like_weights(65_536, 2);
    let mut prev = 0u64;
    for g in [1usize, 2, 4, 8, 16] {
        let soft = WeightCodec::hybrid(g).encode(&ws).soft_cells();
        assert!(soft >= prev, "g={g}");
        prev = soft;
    }
    // And even g=16 must beat the unprotected baseline.
    let base = WeightCodec::new(Policy::Unprotected, 1).encode(&ws).soft_cells();
    assert!(prev < base);
}

#[test]
fn fig8_ordering_expected_damage() {
    // The Fig. 8 mechanism: expected corrupted-cell count must be strictly
    // worst for the unprotected baseline, better under each single scheme,
    // and best under hybrid. (Round-vs-rotate order is population-dependent:
    // on accuracy the paper finds rotate slightly ahead because it is
    // lossless, not because it exposes fewer cells.)
    let ws = trained_like_weights(200_000, 3);
    let soft = |p: Policy| WeightCodec::new(p, 1).encode(&ws).soft_cells();
    let unprot = soft(Policy::Unprotected);
    let round = soft(Policy::ProtectRound);
    let rotate = soft(Policy::ProtectRotate);
    let hybrid = soft(Policy::Hybrid);
    assert!(unprot > round, "{unprot} vs {round}");
    assert!(unprot > rotate, "{unprot} vs {rotate}");
    assert!(hybrid <= round && hybrid <= rotate, "{hybrid} vs {round}/{rotate}");
    assert!(hybrid < unprot);
}

#[test]
fn rounding_error_never_exceeds_fig4_bound() {
    // Round touches only the last 4 mantissa bits: the stored/decoded word
    // must agree with the quantized original on everything above the low
    // nibble — the exact containment Fig. 4 uses to declare it safe.
    let ws = trained_like_weights(50_000, 4);
    let enc = WeightCodec::new(Policy::ProtectRound, 1).encode(&ws);
    for (w, d) in ws.iter().zip(enc.decode()) {
        let qb = fp::f32_to_f16_bits(fp::quantize_f16(*w));
        let db = fp::f32_to_f16_bits(d);
        assert_eq!(qb & !0xF, db & !0xF, "w={w} q={qb:#06x} d={db:#06x}");
    }
}

#[test]
fn fault_campaign_rates_match_analytic_expectation() {
    let ws = trained_like_weights(500_000, 5);
    for rate in [0.015f64, 0.02] {
        let mut enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let expected: f64 = enc
            .words
            .iter()
            .map(|&w| fp::soft_cells(w) as f64 * rate)
            .sum();
        let campaign = FaultCampaign::new(ErrorModel::at_rate(rate), 1234);
        let flips = campaign.inject(&mut enc) as f64;
        let rel = (flips - expected).abs() / expected;
        assert!(rel < 0.05, "rate {rate}: {flips} vs {expected}");
    }
}

#[test]
fn decode_is_identity_on_fault_free_lossless_stream() {
    let ws: Vec<f32> = trained_like_weights(10_000, 6)
        .iter()
        .map(|&w| fp::quantize_f16(w))
        .collect();
    for g in [1usize, 3, 4, 7, 16] {
        let enc = WeightCodec::new(Policy::ProtectRotate, g).encode(&ws);
        assert_eq!(enc.decode(), ws, "g={g}");
    }
}

#[test]
fn all_positive_and_all_negative_populations() {
    // Edge populations: all-positive weights have cell0=00 already; the
    // all-negative case is where sign protection pays the most.
    let pos: Vec<f32> = (1..=1000).map(|i| i as f32 / 1001.0).collect();
    let neg: Vec<f32> = pos.iter().map(|x| -x).collect();

    let base_pos = WeightCodec::new(Policy::Unprotected, 1).encode(&pos);
    let base_neg = WeightCodec::new(Policy::Unprotected, 1).encode(&neg);
    // Unprotected negatives carry a vulnerable 10 sign cell per weight.
    assert!(base_neg.soft_cells() >= base_pos.soft_cells() + 1000);

    let hyb_neg = WeightCodec::hybrid(1).encode(&neg);
    // Protection turns every 10 sign cell into immune 11.
    assert!(hyb_neg.soft_cells() + 1000 <= base_neg.soft_cells());
}

#[test]
fn zero_and_boundary_weights() {
    let ws = vec![0.0f32, -0.0, 1.0, -1.0, 0.5, -0.5, fp::f16_bits_to_f32(0x0001)];
    for policy in [Policy::ProtectRotate, Policy::Hybrid] {
        let enc = WeightCodec::new(policy, 2).encode(&ws);
        let dec = enc.decode();
        for (a, b) in ws.iter().zip(&dec) {
            if policy == Policy::ProtectRotate {
                assert_eq!(fp::quantize_f16(*a).to_bits(), b.to_bits());
            } else {
                assert!((fp::quantize_f16(*a) - b).abs() <= 0.002);
            }
        }
    }
}
