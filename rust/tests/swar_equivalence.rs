//! SWAR ↔ scalar equivalence (ISSUE 2): the word-packed kernels must match
//! the scalar oracle **exhaustively** — every one of the 65536 binary16 bit
//! patterns, in every lane position — and the threaded codec/buffer paths
//! must be bit-identical to their single-threaded runs on the e2e fixture
//! weights. Together these pin that the hot-path rewrite changed the speed
//! of the paper's scheme and nothing else.

mod common;

use mlcstt::buffer::{BufferConfig, MlcBuffer, STORE_SHARD_WORDS};
use mlcstt::encoding::{scheme, swar, Encoded, Policy, Scheme, WeightCodec};
use mlcstt::fp;
use mlcstt::stt::error::ERROR_RATE_HI;
use mlcstt::stt::ErrorModel;

/// Every 16-bit pattern, in every lane, alongside varied neighbours (so a
/// cross-lane leak against *any* neighbour content would be caught).
fn lane_mixes(h: u16) -> [[u16; 4]; 4] {
    let a = h.wrapping_mul(0x9E37).rotate_left(3);
    let b = !h;
    let c = h ^ 0x5A5A;
    [
        [h, a, b, c],
        [a, h, c, b],
        [b, c, h, a],
        [c, b, a, h],
    ]
}

#[test]
fn exhaustive_protect_unprotect_all_patterns() {
    for h in 0..=u16::MAX {
        for ws in lane_mixes(h) {
            let x = fp::pack4(ws);
            assert_eq!(
                fp::unpack4(swar::protect_sign4(x)),
                ws.map(scheme::protect_sign),
                "protect h={h:#06x}"
            );
            assert_eq!(
                fp::unpack4(swar::unprotect_sign4(x)),
                ws.map(scheme::unprotect_sign),
                "unprotect h={h:#06x}"
            );
        }
    }
}

#[test]
fn exhaustive_rotate_both_directions_all_patterns() {
    for h in 0..=u16::MAX {
        for ws in lane_mixes(h) {
            let x = fp::pack4(ws);
            let right = swar::rotate_field_right4(x);
            assert_eq!(
                fp::unpack4(right),
                ws.map(scheme::rotate_field_right),
                "rotate right h={h:#06x}"
            );
            assert_eq!(
                fp::unpack4(swar::rotate_field_left4(x)),
                ws.map(scheme::rotate_field_left),
                "rotate left h={h:#06x}"
            );
            // Packed round-trip: left inverts right, lanes independent.
            assert_eq!(swar::rotate_field_left4(right), x, "roundtrip h={h:#06x}");
        }
    }
}

#[test]
fn exhaustive_round_nibble_all_patterns() {
    for h in 0..=u16::MAX {
        for ws in lane_mixes(h) {
            let x = fp::pack4(ws);
            assert_eq!(
                fp::unpack4(swar::round_low_nibble4(x)),
                ws.map(scheme::round_low_nibble),
                "round h={h:#06x}"
            );
        }
        // And the scalar itself is Table 1 verbatim on this word.
        let rounded = scheme::round_low_nibble(h);
        assert_eq!(
            rounded & 0xF,
            scheme::ROUND_TABLE[(h & 0xF) as usize] as u16
        );
        assert_eq!(rounded & !0xF, h & !0xF);
    }
}

#[test]
fn exhaustive_cell_census_all_patterns() {
    for h in 0..=u16::MAX {
        let ws = lane_mixes(h)[0];
        let x = fp::pack4(ws);
        let soft: u32 = ws.iter().map(|&w| fp::soft_cells(w)).sum();
        assert_eq!(fp::soft_cells_packed(x), soft, "soft h={h:#06x}");
        let mut pc = [0u32; 4];
        for &w in &ws {
            for (a, c) in pc.iter_mut().zip(fp::pattern_counts(w)) {
                *a += c;
            }
        }
        assert_eq!(fp::pattern_counts_packed(x), pc, "census h={h:#06x}");
    }
}

#[test]
fn exhaustive_apply_invert_roundtrip_protected_words() {
    // For every |w| < 2 pattern (backup bit free — the codec's domain),
    // the packed apply/invert of each lossless scheme round-trips, and
    // Round's packed image matches the scalar one.
    for h in 0..=u16::MAX {
        if !fp::backup_bit_free(h) {
            continue;
        }
        let p = scheme::protect_sign(h);
        let x = fp::pack4([p; 4]);
        for s in Scheme::ALL {
            let stored = swar::apply4(s, x);
            assert_eq!(
                fp::unpack4(stored),
                [scheme::apply(s, p); 4],
                "{s:?} h={h:#06x}"
            );
            let back = fp::unpack4(swar::invert4(s, stored));
            assert_eq!(back, [scheme::invert(s, scheme::apply(s, p)); 4]);
            if s.is_lossless() {
                assert_eq!(back, [h; 4], "{s:?} lossless h={h:#06x}");
            }
        }
    }
}

// ------------------------------------------------------------- threading

#[test]
fn threaded_encode_decode_matches_single_thread_on_fixture_weights() {
    let ws = common::trained_like_weights(150_000, "swar/threads");
    for (policy, g) in [
        (Policy::Unprotected, 1usize),
        (Policy::Hybrid, 1),
        (Policy::Hybrid, 16),
        (Policy::ProtectRotate, 4),
    ] {
        let codec = WeightCodec::new(policy, g);
        let mut single = Encoded::with_context(policy, g);
        codec.encode_into_threaded(&ws, &mut single, 1);
        // The scalar oracle agrees with the single-threaded SWAR path.
        let oracle = codec.encode_scalar(&ws);
        assert_eq!(single.words, oracle.words, "{policy:?} g={g} vs oracle");
        assert_eq!(single.schemes, oracle.schemes);

        for workers in [2usize, 5, 16] {
            let mut multi = Encoded::with_context(policy, g);
            codec.encode_into_threaded(&ws, &mut multi, workers);
            assert_eq!(single.words, multi.words, "{policy:?} g={g} w={workers}");
            assert_eq!(single.schemes, multi.schemes);

            let mut d_single = Vec::new();
            let mut d_multi = Vec::new();
            single.decode_into_threaded(&mut d_single, 1);
            multi.decode_into_threaded(&mut d_multi, workers);
            assert_eq!(d_single, d_multi, "{policy:?} g={g} w={workers}");
            assert_eq!(d_single, single.decode_scalar());
        }
    }
}

#[test]
fn threaded_pipeline_deterministic_end_to_end() {
    // encode -> banked store (seeded faults) -> load -> decode must be
    // bit-identical for any worker count: shard seeds derive from stream
    // position, not thread schedule.
    let ws = common::trained_like_weights(2 * STORE_SHARD_WORDS + 777, "swar/pipeline");
    let codec = WeightCodec::hybrid(16);
    let enc = codec.encode(&ws);
    let cfg = BufferConfig::new(enc.len() * 2, 8)
        .with_error_model(ErrorModel::at_rate(ERROR_RATE_HI));

    let run = |workers: usize| {
        let mut buf = MlcBuffer::new(cfg.clone(), 0xE2E);
        let region = buf.store_with_threads(&enc, workers).unwrap();
        let loaded = buf.load(&region).unwrap();
        let mut decoded = Vec::new();
        loaded.decode_into_threaded(&mut decoded, workers);
        (loaded.words, decoded, buf.stats().injected_faults)
    };

    let (words1, dec1, faults1) = run(1);
    assert!(faults1 > 0, "fault path inert at the published rate");
    for workers in [2usize, 4, 9] {
        let (words_n, dec_n, faults_n) = run(workers);
        assert_eq!(words1, words_n, "stored image differs at workers={workers}");
        assert_eq!(dec1, dec_n, "decode differs at workers={workers}");
        assert_eq!(faults1, faults_n, "fault count differs at workers={workers}");
    }
}
