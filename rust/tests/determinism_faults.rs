//! Determinism contract for fault campaigns (ISSUE 1 satellite): the same
//! seed must produce the *identical set of injected bit flips* across two
//! runs — not just the same count — at every layer of the stack
//! (`FaultCampaign`, `ErrorModel`, `MlcBuffer`). This is the `util::rng`
//! contract every reported accuracy number in EXPERIMENTS.md leans on.

mod common;

use std::collections::BTreeSet;

use mlcstt::buffer::{BufferConfig, MlcBuffer};
use mlcstt::encoding::{Policy, WeightCodec};
use mlcstt::faults::FaultCampaign;
use mlcstt::stt::error::ERROR_RATE_HI;
use mlcstt::stt::ErrorModel;
use mlcstt::util::rng::Xoshiro256;

/// The exact flip set of a campaign over a stream: (word index, bit) pairs.
fn flip_set(before: &[u16], after: &[u16]) -> BTreeSet<(usize, u32)> {
    assert_eq!(before.len(), after.len());
    let mut set = BTreeSet::new();
    for (i, (b, a)) in before.iter().zip(after).enumerate() {
        let mut diff = b ^ a;
        while diff != 0 {
            let bit = diff.trailing_zeros();
            set.insert((i, bit));
            diff &= diff - 1;
        }
    }
    set
}

#[test]
fn campaign_same_seed_identical_flip_sets() {
    let ws = common::trained_like_weights(40_000, "det/campaign");
    let codec = WeightCodec::new(Policy::Unprotected, 1);
    let clean = codec.encode(&ws);

    let run = |seed: u64| {
        let mut enc = codec.encode(&ws);
        let campaign = FaultCampaign::new(ErrorModel::at_rate(ERROR_RATE_HI), seed);
        let reported = campaign.inject(&mut enc);
        (flip_set(&clean.words, &enc.words), reported)
    };

    let (set_a, rep_a) = run(0xFA11);
    let (set_b, rep_b) = run(0xFA11);
    assert_eq!(set_a, set_b, "same seed produced different flip sets");
    assert_eq!(rep_a, rep_b);
    assert!(!set_a.is_empty(), "campaign inert at the published rate");

    let (set_c, _) = run(0xFA12);
    assert_ne!(set_a, set_c, "different seeds produced identical flip sets");
}

#[test]
fn campaign_flip_count_matches_flip_set() {
    // `inject` reports corrupted-cell counts; the reported number must
    // equal the reconstructed per-cell flip set (each corrupted cell flips
    // exactly one of its two bits, so cells == bit flips).
    let ws = common::trained_like_weights(30_000, "det/count");
    let codec = WeightCodec::new(Policy::Unprotected, 1);
    let clean = codec.encode(&ws);
    let mut enc = codec.encode(&ws);
    let campaign = FaultCampaign::new(ErrorModel::at_rate(ERROR_RATE_HI), 0xC0DE);
    let reported = campaign.inject(&mut enc);
    let set = flip_set(&clean.words, &enc.words);
    assert_eq!(set.len() as u64, reported, "reported cells != observed bit flips");
    // And every flip landed in a cell that was vulnerable beforehand.
    for &(i, bit) in &set {
        let cell = (clean.words[i] >> (bit & !1)) & 0b11;
        assert!(
            cell == 0b01 || cell == 0b10,
            "flip at word {i} bit {bit} hit immune cell {cell:02b}"
        );
    }
}

#[test]
fn error_model_stream_determinism_per_word_and_order() {
    // The ErrorModel itself: one shared stream, same seed, same order ->
    // identical words; consuming in a different order diverges (the
    // documented contract: determinism is per (seed, draw sequence)).
    let model = ErrorModel::at_rate(0.5);
    let words: Vec<u16> = (0..2000u16).map(|i| i.wrapping_mul(0x9E37)).collect();

    let pass = |seed: u64| -> Vec<u16> {
        let mut rng = Xoshiro256::seeded(seed);
        words
            .iter()
            .map(|&w| model.corrupt_word_write(w, &mut rng))
            .collect()
    };
    assert_eq!(pass(7), pass(7));

    let mut rng = Xoshiro256::seeded(7);
    let reversed: Vec<u16> = words
        .iter()
        .rev()
        .map(|&w| model.corrupt_word_write(w, &mut rng))
        .collect();
    let mut reversed_back = reversed;
    reversed_back.reverse();
    assert_ne!(
        pass(7),
        reversed_back,
        "order-independent corruption would mean the stream is not being consumed"
    );
}

#[test]
fn buffer_seed_controls_injection_identically() {
    // Same data, same buffer seed -> bit-identical stored images and the
    // same injected_faults accounting; campaigns are replayable from the
    // (config, seed) pair alone.
    let ws = common::trained_like_weights(20_000, "det/buffer");
    let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
    let cfg = BufferConfig::new(enc.len() * 2, 8)
        .with_error_model(ErrorModel::at_rate(ERROR_RATE_HI));

    let run = |seed: u64| {
        let mut buf = MlcBuffer::new(cfg.clone(), seed);
        let r = buf.store(&enc).unwrap();
        let words = buf.load(&r).unwrap().words;
        (words, buf.stats().injected_faults)
    };
    let (w1, f1) = run(0x5EED);
    let (w2, f2) = run(0x5EED);
    assert_eq!(w1, w2);
    assert_eq!(f1, f2);
    assert!(f1 > 0);

    let (w3, _) = run(0x5EEE);
    assert_ne!(w1, w3);
}
