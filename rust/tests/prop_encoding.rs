//! Property tests over the encoding stack (in-tree `util::prop` harness —
//! proptest is not in the offline vendor set).

use mlcstt::encoding::scheme::{
    self, protect_sign, rotate_field_left, rotate_field_right, round_low_nibble, unprotect_sign,
};
use mlcstt::encoding::{parity, protection_for, select_scheme, Policy, Scheme, WeightCodec};
use mlcstt::fp;
use mlcstt::util::prop::{prop_assert, Runner};

const CASES: usize = 400;

#[test]
fn prop_protect_unprotect_roundtrip() {
    Runner::new("protect-roundtrip", 0xA1, CASES).run(|g| {
        // Any word with a clear backup bit (the |w|<2 domain).
        let h = g.u16() & !fp::BACKUP_MASK;
        prop_assert(
            unprotect_sign(protect_sign(h)) == h,
            format!("h={h:#06x}"),
        )
    });
}

#[test]
fn prop_protected_sign_cell_is_base() {
    Runner::new("protected-cell0-base", 0xA2, CASES).run(|g| {
        let h = g.u16() & !fp::BACKUP_MASK;
        let cell0 = (protect_sign(h) >> 14) & 0b11;
        prop_assert(
            cell0 == 0b00 || cell0 == 0b11,
            format!("h={h:#06x} cell0={cell0:02b}"),
        )
    });
}

#[test]
fn prop_rotation_involution_on_any_word() {
    Runner::new("rotate-involution", 0xA3, CASES).run(|g| {
        let h = g.u16();
        let ok = rotate_field_left(rotate_field_right(h)) == h
            && rotate_field_right(rotate_field_left(h)) == h;
        prop_assert(ok, format!("h={h:#06x}"))
    });
}

#[test]
fn prop_rotation_preserves_popcount_and_sign_pair() {
    Runner::new("rotate-conserves", 0xA4, CASES).run(|g| {
        let h = g.u16();
        let r = rotate_field_right(h);
        prop_assert(
            r.count_ones() == h.count_ones() && (r & 0xC000) == (h & 0xC000),
            format!("h={h:#06x} r={r:#06x}"),
        )
    });
}

#[test]
fn prop_round_output_nibble_is_mlc_friendly() {
    Runner::new("round-friendly", 0xA5, CASES).run(|g| {
        let h = g.u16();
        let nib = round_low_nibble(h) & 0xF;
        prop_assert(
            matches!(nib, 0b0000 | 0b0011 | 0b1100 | 0b1111),
            format!("h={h:#06x} nib={nib:04b}"),
        )
    });
}

#[test]
fn prop_round_moves_value_at_most_8_ulps() {
    Runner::new("round-bounded", 0xA6, CASES).run(|g| {
        let h = g.u16();
        let delta = (round_low_nibble(h) & 0xF) as i32 - (h & 0xF) as i32;
        prop_assert(delta.abs() <= 8, format!("h={h:#06x} delta={delta}"))
    });
}

#[test]
fn prop_selection_minimizes_over_candidates() {
    Runner::new("selection-minimal", 0xA7, 200).run(|g| {
        let ws = g.weights(1, 64);
        let protected: Vec<u16> = ws
            .iter()
            .map(|&w| protect_sign(fp::f32_to_f16_bits(w)))
            .collect();
        let (best, cost) = select_scheme(Policy::Hybrid, &protected);
        for s in Scheme::ALL {
            let c: u32 = protected
                .iter()
                .map(|&p| fp::soft_cells(scheme::apply(s, p)))
                .sum();
            if c < cost {
                return Err(format!("{s:?} has {c} < chosen {best:?} {cost}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lossless_policies_roundtrip_any_weights() {
    Runner::new("codec-roundtrip", 0xA8, 150).run(|g| {
        let ws: Vec<f32> = g.weights(1, 200).iter().map(|&w| fp::quantize_f16(w)).collect();
        let granularity = 1 + g.below(16);
        let codec = WeightCodec::new(Policy::ProtectRotate, granularity);
        let back = codec.encode(&ws).decode();
        prop_assert(back == ws, format!("g={granularity} n={}", ws.len()))
    });
}

#[test]
fn prop_hybrid_never_more_soft_cells_than_restricted_policies() {
    Runner::new("hybrid-dominates", 0xA9, 150).run(|g| {
        let ws = g.weights(1, 128);
        let granularity = 1 + g.below(8);
        let soft = |p: Policy| WeightCodec::new(p, granularity).encode(&ws).soft_cells();
        let h = soft(Policy::Hybrid);
        prop_assert(
            h <= soft(Policy::ProtectRound) && h <= soft(Policy::ProtectRotate),
            format!("g={granularity}"),
        )
    });
}

#[test]
fn prop_decode_sign_always_matches_original() {
    Runner::new("sign-preserved", 0xAA, 200).run(|g| {
        let ws: Vec<f32> = g.weights(1, 100);
        let codec = WeightCodec::hybrid(1 + g.below(4));
        let dec = codec.encode(&ws).decode();
        for (a, b) in ws.iter().zip(&dec) {
            if *a != 0.0 && a.is_sign_negative() != b.is_sign_negative() {
                return Err(format!("sign changed: {a} -> {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pattern_counts_invariants() {
    Runner::new("pattern-census", 0xAB, 200).run(|g| {
        let ws = g.weights(1, 100);
        let enc = WeightCodec::hybrid(4).encode(&ws);
        let pc = enc.pattern_counts();
        let ok = pc.iter().sum::<u64>() == 8 * ws.len() as u64
            && pc[1] + pc[2] == enc.soft_cells();
        prop_assert(ok, format!("{pc:?}"))
    });
}

// ------------------------------------------------- zero-space parity

/// Bit positions covered by the in-place parity code: the protected field
/// (bits 6..=13) plus the parity bit itself (bit 14).
const DETECT_BITS: [u16; 9] = [6, 7, 8, 9, 10, 11, 12, 13, 14];

#[test]
fn prop_parity_detects_any_single_flip_in_protected_field() {
    Runner::new("parity-detects-single-flip", 0xAC, CASES).run(|g| {
        let h = fp::f32_to_f16_bits(g.weights(1, 1)[0]);
        let stored = parity::encode_word(h);
        let bit = *g.pick(&DETECT_BITS);
        let flipped = stored ^ (1u16 << bit);
        prop_assert(
            !parity::mismatch(stored) && parity::mismatch(flipped),
            format!("h={h:#06x} stored={stored:#06x} bit={bit}"),
        )
    });
}

#[test]
fn prop_parity_repair_never_increases_error() {
    // Detect-and-saturate vs decoding the same corrupted word with no
    // repair: clamping into [-1, 1] is a projection onto a convex set
    // containing the true weight, so it can never move the decode away
    // from it — for *any* flip pattern, not just single detectable flips.
    Runner::new("parity-repair-contracts", 0xAD, CASES).run(|g| {
        let w = g.weights(1, 1)[0];
        let h = fp::f32_to_f16_bits(w);
        let truth = fp::f16_bits_to_f32(h);
        let corrupted = parity::encode_word(h) ^ g.u16();
        let repaired = parity::decode_word(corrupted);
        let unrepaired = fp::f16_bits_to_f32(corrupted & !fp::BACKUP_MASK);
        prop_assert(
            (repaired - truth).abs() <= (unrepaired - truth).abs(),
            format!(
                "w={w} corrupted={corrupted:#06x}: |{repaired} - {truth}| > |{unrepaired} - {truth}|"
            ),
        )
    });
}

#[test]
fn prop_parity_overhead_is_exactly_zero() {
    Runner::new("parity-zero-space", 0xAE, 150).run(|g| {
        let ws = g.weights(1, 300);
        let granularity = 1 + g.below(16);
        let prot = protection_for(Policy::ZeroSpaceParity, granularity);
        if prot.metadata_overhead_bits(ws.len()) != 0 {
            return Err(format!("overhead bits nonzero at n={}", ws.len()));
        }
        let enc = WeightCodec::new(Policy::ZeroSpaceParity, granularity).encode(&ws);
        let ok = enc.schemes.is_empty()
            && enc.metadata_overhead() == 0.0
            && enc.decode().iter().zip(&ws).all(|(d, w)| *d == fp::quantize_f16(*w));
        prop_assert(ok, format!("g={granularity} n={}", ws.len()))
    });
}
