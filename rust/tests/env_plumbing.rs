//! `MLCSTT_*` layering (ISSUE 3 + ISSUE 5 satellites), isolated in its
//! own test binary: the single test below mutates the process
//! environment, and glibc's setenv is undefined behavior against
//! concurrent getenv — sibling tests in a shared binary read the
//! environment through `threads::available()` and `fp::f16_mode()` on
//! parallel harness threads. Cargo runs test binaries sequentially, so a
//! dedicated binary with one test is race-free by construction.
//!
//! Precedence contract (resolved only in `api::config`): **builder beats
//! env beats default**, with the historical fallback quirks pinned —
//! `MLCSTT_THREADS=0` clamps to 1, unparsable values degrade to the
//! default instead of erroring.

use std::path::Path;

use mlcstt::api::{Config, EvictPolicy, ScrubMode, ScrubPolicy};
use mlcstt::encoding::Policy;
use mlcstt::coordinator::ServerConfig;
use mlcstt::fp::{self, F16Mode};
use mlcstt::util::threads;

#[test]
fn mlcstt_env_layering_builder_beats_env_beats_default() {
    // --- f16 FIRST: the converter selection latches process-wide on its
    // first resolution, so this is the only window where builder-beats-env
    // is observable. With the env demanding `scalar`, a builder override
    // must win the pin...
    std::env::set_var("MLCSTT_F16", "scalar");
    let cfg = Config::builder().f16(F16Mode::Branchless).build();
    assert_eq!(cfg.f16(), F16Mode::Branchless, "builder beats env");
    assert_eq!(fp::f16_mode(), F16Mode::Branchless, "and pins the process");
    // ...and once latched, later env reads cannot rebind it (documented
    // latch semantics: all modes are bit-exact, only speed differs).
    assert_eq!(Config::from_env().f16(), F16Mode::Branchless);
    std::env::remove_var("MLCSTT_F16");

    // --- threads: env beats default...
    std::env::set_var("MLCSTT_THREADS", "3");
    assert_eq!(threads::available(), 3);
    assert_eq!(ServerConfig::default().codec_threads, 3);
    assert_eq!(Config::from_env().threads(), 3);
    assert_eq!(Config::from_env().server().codec_threads, 3);
    assert_eq!(Config::from_env().store().threads, 3);
    // ...builder beats env...
    assert_eq!(Config::builder().threads(5).build().threads(), 5);
    // ...0 clamps to 1 on both layers...
    std::env::set_var("MLCSTT_THREADS", "0");
    assert_eq!(threads::available(), 1);
    assert_eq!(Config::from_env().threads(), 1);
    assert_eq!(Config::builder().threads(0).build().threads(), 1);
    // ...and an unparsable value degrades to the machine default.
    std::env::set_var("MLCSTT_THREADS", "not-a-number");
    assert!(threads::available() >= 1);
    assert!(Config::from_env().threads() >= 1);
    std::env::remove_var("MLCSTT_THREADS");
    assert!(threads::available() >= 1);
    assert!(ServerConfig::default().codec_threads >= 1);

    // --- eval: builder beats env beats caller default.
    std::env::set_var("MLCSTT_EVAL", "123");
    assert_eq!(Config::from_env().eval_or(512), 123);
    assert_eq!(Config::builder().eval(7).build().eval_or(512), 7);
    std::env::set_var("MLCSTT_EVAL", "garbage");
    assert_eq!(Config::from_env().eval_or(512), 512, "unparsable -> default");
    std::env::remove_var("MLCSTT_EVAL");
    assert_eq!(Config::from_env().eval_or(512), 512);

    // --- requests mirrors eval.
    std::env::set_var("MLCSTT_REQUESTS", "44");
    assert_eq!(Config::from_env().requests_or(128), 44);
    assert_eq!(Config::builder().requests(9).build().requests_or(128), 9);
    std::env::remove_var("MLCSTT_REQUESTS");
    assert_eq!(Config::from_env().requests_or(128), 128);

    // --- artifacts: builder beats env beats the crate default.
    std::env::set_var("MLCSTT_ARTIFACTS", "/tmp/mlcstt-env-test");
    assert_eq!(Config::from_env().artifacts_dir(), Path::new("/tmp/mlcstt-env-test"));
    let flagged = Config::builder().artifacts("elsewhere").build();
    assert_eq!(flagged.artifacts_dir(), Path::new("elsewhere"));
    std::env::remove_var("MLCSTT_ARTIFACTS");
    assert_eq!(Config::from_env().artifacts_dir(), Path::new(mlcstt::ARTIFACT_DIR));

    // --- rates: env parses a comma list, skipping junk entries.
    std::env::set_var("MLCSTT_RATES", "10, 20,junk,30");
    assert_eq!(Config::from_env().rates_or(&[1.0]), vec![10.0, 20.0, 30.0]);
    assert_eq!(Config::builder().rates(vec![5.0]).build().rates_or(&[1.0]), vec![5.0]);
    std::env::remove_var("MLCSTT_RATES");
    assert_eq!(Config::from_env().rates_or(&[1.0, 2.0]), vec![1.0, 2.0]);

    // --- queue depth (ISSUE 6): builder beats env beats caller default,
    // with the MLCSTT_THREADS-style 0 -> 1 clamp on both layers.
    std::env::set_var("MLCSTT_QUEUE_DEPTH", "17");
    assert_eq!(Config::from_env().queue_depth_or(1024), 17);
    assert_eq!(Config::from_env().server().queue_depth, 17);
    assert_eq!(Config::builder().queue_depth(5).build().queue_depth_or(1024), 5);
    std::env::set_var("MLCSTT_QUEUE_DEPTH", "0");
    assert_eq!(Config::from_env().queue_depth_or(1024), 1, "0 clamps to 1");
    std::env::set_var("MLCSTT_QUEUE_DEPTH", "junk");
    assert_eq!(Config::from_env().queue_depth_or(1024), 1024, "unparsable -> default");
    std::env::remove_var("MLCSTT_QUEUE_DEPTH");
    assert_eq!(Config::from_env().queue_depth_or(1024), 1024);

    // --- registry-wide fair-admission budget: unset means no gate.
    std::env::set_var("MLCSTT_QUEUE_BUDGET", "64");
    assert_eq!(Config::from_env().queue_budget(), Some(64));
    assert_eq!(Config::builder().queue_budget(9).build().queue_budget(), Some(9));
    std::env::remove_var("MLCSTT_QUEUE_BUDGET");
    assert_eq!(Config::from_env().queue_budget(), None);

    // --- batch-coalesce deadline: builder beats env beats the 20 ms
    // default, and the env value is milliseconds.
    std::env::set_var("MLCSTT_MAX_WAIT_MS", "7");
    assert_eq!(Config::from_env().max_wait(), std::time::Duration::from_millis(7));
    assert_eq!(Config::from_env().server().max_wait, std::time::Duration::from_millis(7));
    assert_eq!(
        Config::builder().max_wait(std::time::Duration::from_millis(3)).build().max_wait(),
        std::time::Duration::from_millis(3)
    );
    std::env::set_var("MLCSTT_MAX_WAIT_MS", "junk");
    assert_eq!(Config::from_env().max_wait(), std::time::Duration::from_millis(20));
    std::env::remove_var("MLCSTT_MAX_WAIT_MS");
    assert_eq!(Config::from_env().max_wait(), std::time::Duration::from_millis(20));

    // --- shared-pool knobs (ISSUE 7): capacity follows the eval pattern
    // (unset means "no pool" rather than a default size)...
    std::env::set_var("MLCSTT_POOL_KB", "96");
    assert_eq!(Config::from_env().pool_kb(), Some(96));
    assert_eq!(Config::builder().pool_kb(32).build().pool_kb(), Some(32));
    std::env::set_var("MLCSTT_POOL_KB", "junk");
    assert_eq!(Config::from_env().pool_kb(), None, "unparsable -> no pool");
    std::env::remove_var("MLCSTT_POOL_KB");
    assert_eq!(Config::from_env().pool_kb(), None);

    // ...banks and extent follow the MLCSTT_THREADS clamp pattern...
    std::env::set_var("MLCSTT_POOL_BANKS", "8");
    assert_eq!(Config::from_env().pool_banks_or(4), 8);
    assert_eq!(Config::builder().pool_banks(2).build().pool_banks_or(4), 2);
    std::env::set_var("MLCSTT_POOL_BANKS", "0");
    assert_eq!(Config::from_env().pool_banks_or(4), 1, "0 clamps to 1");
    std::env::remove_var("MLCSTT_POOL_BANKS");
    assert_eq!(Config::from_env().pool_banks_or(4), 4);

    std::env::set_var("MLCSTT_POOL_EXTENT", "256");
    assert_eq!(Config::from_env().pool_extent_or(8192), 256);
    assert_eq!(Config::builder().pool_extent(64).build().pool_extent_or(8192), 64);
    std::env::set_var("MLCSTT_POOL_EXTENT", "0");
    assert_eq!(Config::from_env().pool_extent_or(8192), 1, "0 clamps to 1");
    std::env::remove_var("MLCSTT_POOL_EXTENT");
    assert_eq!(Config::from_env().pool_extent_or(8192), 8192);

    // ...and the eviction policy follows the MLCSTT_F16 enum-parse
    // pattern: unknown labels degrade to the LRU default.
    std::env::set_var("MLCSTT_EVICT", "deny");
    assert_eq!(Config::from_env().evict_policy(), EvictPolicy::Deny);
    assert_eq!(
        Config::builder().evict(EvictPolicy::Lru).build().evict_policy(),
        EvictPolicy::Lru,
        "builder beats env"
    );
    std::env::set_var("MLCSTT_EVICT", "lru");
    assert_eq!(Config::from_env().evict_policy(), EvictPolicy::Lru);
    std::env::set_var("MLCSTT_EVICT", "sometimes");
    assert_eq!(Config::from_env().evict_policy(), EvictPolicy::Lru, "unknown -> default");
    std::env::remove_var("MLCSTT_EVICT");
    assert_eq!(Config::from_env().evict_policy(), EvictPolicy::Lru);

    // --- protection policy (ISSUE 8): the same enum-parse pattern, and
    // the resolved value must reach the deployment's store view.
    std::env::set_var("MLCSTT_POLICY", "zero-parity");
    assert_eq!(Config::from_env().policy_or(Policy::Hybrid), Policy::ZeroSpaceParity);
    assert_eq!(Config::from_env().store().policy, Policy::ZeroSpaceParity);
    std::env::set_var("MLCSTT_POLICY", "parity"); // short alias
    assert_eq!(Config::from_env().policy_or(Policy::Hybrid), Policy::ZeroSpaceParity);
    std::env::set_var("MLCSTT_POLICY", "unprotected");
    assert_eq!(Config::from_env().store().policy, Policy::Unprotected);
    assert_eq!(
        Config::builder().policy(Policy::ProtectRotate).build().store().policy,
        Policy::ProtectRotate,
        "builder beats env"
    );
    std::env::set_var("MLCSTT_POLICY", "extra-protected");
    assert_eq!(Config::from_env().store().policy, Policy::Hybrid, "unknown -> default");
    std::env::remove_var("MLCSTT_POLICY");
    assert_eq!(Config::from_env().store().policy, Policy::Hybrid);

    // --- delivery retry budget (ISSUE 9): builder beats env beats the
    // caller default; 0 is meaningful (fail on the first bad read), so
    // no clamp — and junk degrades to the default.
    std::env::set_var("MLCSTT_DELIVERY_RETRIES", "7");
    assert_eq!(Config::from_env().delivery_retries_or(3), 7);
    assert_eq!(Config::builder().delivery_retries(1).build().delivery_retries_or(3), 1);
    std::env::set_var("MLCSTT_DELIVERY_RETRIES", "0");
    assert_eq!(Config::from_env().delivery_retries_or(3), 0, "0 means fail-fast, no clamp");
    assert_eq!(Config::builder().delivery_retries(0).build().delivery_retries_or(3), 0);
    std::env::set_var("MLCSTT_DELIVERY_RETRIES", "junk");
    assert_eq!(Config::from_env().delivery_retries_or(3), 3, "unparsable -> default");
    std::env::remove_var("MLCSTT_DELIVERY_RETRIES");
    assert_eq!(
        Config::from_env().delivery_retries_or(mlcstt::api::DEFAULT_DELIVERY_RETRIES),
        mlcstt::api::DEFAULT_DELIVERY_RETRIES
    );

    // --- delivery backoff base: env value is milliseconds, 0 means
    // retry immediately (no clamp).
    std::env::set_var("MLCSTT_DELIVERY_BACKOFF_MS", "12");
    assert_eq!(
        Config::from_env().delivery_backoff_or(std::time::Duration::from_millis(5)),
        std::time::Duration::from_millis(12)
    );
    assert_eq!(
        Config::builder()
            .delivery_backoff(std::time::Duration::from_millis(2))
            .build()
            .delivery_backoff_or(std::time::Duration::from_millis(5)),
        std::time::Duration::from_millis(2),
        "builder beats env"
    );
    std::env::set_var("MLCSTT_DELIVERY_BACKOFF_MS", "0");
    assert_eq!(
        Config::from_env().delivery_backoff_or(std::time::Duration::from_millis(5)),
        std::time::Duration::ZERO,
        "0 retries immediately, no clamp"
    );
    std::env::set_var("MLCSTT_DELIVERY_BACKOFF_MS", "junk");
    assert_eq!(
        Config::from_env().delivery_backoff_or(std::time::Duration::from_millis(5)),
        std::time::Duration::from_millis(5),
        "unparsable -> default"
    );
    std::env::remove_var("MLCSTT_DELIVERY_BACKOFF_MS");
    assert_eq!(
        Config::from_env().delivery_backoff_or(mlcstt::api::DEFAULT_DELIVERY_BACKOFF),
        mlcstt::api::DEFAULT_DELIVERY_BACKOFF
    );

    // --- canary batches: 0 is meaningful (skip the probe), no clamp.
    std::env::set_var("MLCSTT_CANARY", "4");
    assert_eq!(Config::from_env().canary_or(1), 4);
    assert_eq!(Config::builder().canary(2).build().canary_or(1), 2, "builder beats env");
    std::env::set_var("MLCSTT_CANARY", "0");
    assert_eq!(Config::from_env().canary_or(1), 0, "0 skips the canary, no clamp");
    std::env::set_var("MLCSTT_CANARY", "junk");
    assert_eq!(Config::from_env().canary_or(1), 1, "unparsable -> default");
    std::env::remove_var("MLCSTT_CANARY");
    assert_eq!(
        Config::from_env().canary_or(mlcstt::api::DEFAULT_CANARY_BATCHES),
        mlcstt::api::DEFAULT_CANARY_BATCHES
    );

    // --- scrub interval (ISSUE 10): env value is milliseconds; unset or
    // zero means scrubbing stays off (the pre-subsystem default).
    std::env::set_var("MLCSTT_SCRUB_MS", "250");
    assert_eq!(
        Config::from_env().scrub_interval(),
        Some(std::time::Duration::from_millis(250))
    );
    assert_eq!(
        Config::from_env().scrub_policy(),
        ScrubPolicy::Fixed(std::time::Duration::from_millis(250)),
        "interval with no mode means fixed"
    );
    assert_eq!(
        Config::builder()
            .scrub_interval(std::time::Duration::from_millis(40))
            .build()
            .scrub_interval(),
        Some(std::time::Duration::from_millis(40)),
        "builder beats env"
    );
    std::env::set_var("MLCSTT_SCRUB_MS", "0");
    assert_eq!(Config::from_env().scrub_policy(), ScrubPolicy::Off, "0 means off");
    std::env::set_var("MLCSTT_SCRUB_MS", "junk");
    assert_eq!(Config::from_env().scrub_interval(), None, "unparsable -> off");
    assert_eq!(Config::from_env().scrub_policy(), ScrubPolicy::Off);
    std::env::remove_var("MLCSTT_SCRUB_MS");
    assert_eq!(Config::from_env().scrub_interval(), None);
    assert_eq!(Config::from_env().scrub_policy(), ScrubPolicy::Off);

    // --- scrub mode: the MLCSTT_F16 enum-parse pattern; a mode without
    // an interval still resolves to Off (the interval is the master
    // switch), and `off` wins even over a nonzero interval.
    std::env::set_var("MLCSTT_SCRUB_MS", "100");
    std::env::set_var("MLCSTT_SCRUB", "adaptive");
    assert_eq!(
        Config::from_env().scrub_policy(),
        ScrubPolicy::Adaptive {
            base: std::time::Duration::from_millis(100),
            threshold: mlcstt::scrub::DEFAULT_SCRUB_THRESHOLD,
        }
    );
    assert_eq!(
        Config::builder().scrub_mode(ScrubMode::Fixed).build().scrub_policy(),
        ScrubPolicy::Fixed(std::time::Duration::from_millis(100)),
        "builder beats env"
    );
    std::env::set_var("MLCSTT_SCRUB", "off");
    assert_eq!(Config::from_env().scrub_policy(), ScrubPolicy::Off, "off beats the interval");
    std::env::set_var("MLCSTT_SCRUB", "aggressively");
    assert_eq!(
        Config::from_env().scrub_policy(),
        ScrubPolicy::Fixed(std::time::Duration::from_millis(100)),
        "unknown mode -> fixed default"
    );
    std::env::remove_var("MLCSTT_SCRUB");

    // --- adaptive decay threshold: builder beats env beats the crate
    // default; junk degrades to the default.
    std::env::set_var("MLCSTT_SCRUB_THRESH", "0.2");
    assert_eq!(Config::from_env().scrub_threshold(), 0.2);
    std::env::set_var("MLCSTT_SCRUB", "adaptive");
    assert_eq!(
        Config::from_env().scrub_policy(),
        ScrubPolicy::Adaptive {
            base: std::time::Duration::from_millis(100),
            threshold: 0.2,
        },
        "threshold reaches the assembled policy"
    );
    assert_eq!(
        Config::builder().scrub_threshold(0.01).build().scrub_threshold(),
        0.01,
        "builder beats env"
    );
    std::env::set_var("MLCSTT_SCRUB_THRESH", "junk");
    assert_eq!(
        Config::from_env().scrub_threshold(),
        mlcstt::scrub::DEFAULT_SCRUB_THRESHOLD,
        "unparsable -> default"
    );
    std::env::remove_var("MLCSTT_SCRUB_THRESH");
    std::env::remove_var("MLCSTT_SCRUB");
    std::env::remove_var("MLCSTT_SCRUB_MS");
    assert_eq!(Config::from_env().scrub_threshold(), mlcstt::scrub::DEFAULT_SCRUB_THRESHOLD);
    assert_eq!(Config::from_env().scrub_policy(), ScrubPolicy::Off);
}
