//! `MLCSTT_THREADS` plumbing (ISSUE 3 satellite), isolated in its own
//! test binary: the single test below mutates the process environment,
//! and glibc's setenv is undefined behavior against concurrent getenv —
//! sibling tests in a shared binary read the environment through
//! `threads::available()` and `fp::f16_mode()` on parallel harness
//! threads. Cargo runs test binaries sequentially, so a dedicated binary
//! with one test is race-free by construction.

use mlcstt::coordinator::ServerConfig;
use mlcstt::util::threads;

#[test]
fn mlcstt_threads_pins_server_codec_parallelism() {
    std::env::set_var("MLCSTT_THREADS", "3");
    assert_eq!(threads::available(), 3);
    assert_eq!(ServerConfig::default().codec_threads, 3);
    std::env::set_var("MLCSTT_THREADS", "0"); // floors at 1
    assert_eq!(threads::available(), 1);
    assert_eq!(ServerConfig::default().codec_threads, 1);
    std::env::remove_var("MLCSTT_THREADS");
    assert!(threads::available() >= 1);
    assert!(ServerConfig::default().codec_threads >= 1);
}
