//! Property tests for the binary16 codec and the sign-backup premise
//! (ISSUE 1 satellite): fp16 round-trips bit-exactly, and for every
//! |w| <= 1 the designated exponent MSB (bit 14) is free — which is what
//! makes sign-backup encode/decode lossless.

mod common;

use mlcstt::encoding::scheme::{protect_sign, unprotect_sign};
use mlcstt::fp;
use mlcstt::util::prop::{prop_assert, Runner};

const CASES: usize = 500;

#[test]
fn prop_f16_bits_roundtrip_exactly_through_f32() {
    // Any non-NaN bit pattern survives f16 -> f32 -> f16 unchanged
    // (f32 strictly contains f16; NaNs only need to stay NaNs).
    Runner::new("f16-bit-roundtrip", common::seed_of("prop_fp/roundtrip"), CASES).run(|g| {
        let h = g.u16();
        let exp = (h >> 10) & 0x1F;
        let man = h & 0x3FF;
        if exp == 0x1F && man != 0 {
            return prop_assert(
                fp::f16_bits_to_f32(h).is_nan(),
                format!("NaN pattern {h:#06x} decoded non-NaN"),
            );
        }
        let back = fp::f32_to_f16_bits(fp::f16_bits_to_f32(h));
        prop_assert(back == h, format!("{h:#06x} -> {back:#06x}"))
    });
}

#[test]
fn prop_quantize_is_idempotent() {
    // Quantization is a projection: applying it twice changes nothing.
    Runner::new("quantize-idempotent", common::seed_of("prop_fp/idem"), CASES).run(|g| {
        let w = g.weight();
        let q = fp::quantize_f16(w);
        prop_assert(
            fp::quantize_f16(q).to_bits() == q.to_bits(),
            format!("w={w} q={q}"),
        )
    });
}

#[test]
fn prop_backup_bit_free_for_all_unit_weights() {
    // The paper's §4.1 premise, over the actual trainer domain |w| <= 1:
    // the encoded exponent MSB is always zero, so bit 14 is free to host
    // the sign backup.
    Runner::new("backup-free", common::seed_of("prop_fp/free"), CASES).run(|g| {
        let w = g.weight(); // uniform in [-1, 1]
        let h = fp::f32_to_f16_bits(w);
        prop_assert(
            fp::backup_bit_free(h),
            format!("w={w} encodes {h:#06x} with bit 14 set"),
        )
    });
}

#[test]
fn prop_sign_backup_encode_decode_lossless() {
    // protect -> unprotect is the identity on every |w| <= 1 weight, and
    // the protected image differs from the original only in bit 14.
    Runner::new("sign-backup-lossless", common::seed_of("prop_fp/lossless"), CASES).run(|g| {
        let w = fp::quantize_f16(g.weight());
        let h = fp::f32_to_f16_bits(w);
        let p = protect_sign(h);
        if unprotect_sign(p) != h {
            return Err(format!("{h:#06x}: protect/unprotect not lossless"));
        }
        if p & !fp::BACKUP_MASK != h & !fp::BACKUP_MASK {
            return Err(format!("{h:#06x}: protection touched bits besides 14"));
        }
        // The backup equals the sign, making cell 0 a base state.
        let backup = (p >> 14) & 1;
        let sign = (p >> 15) & 1;
        prop_assert(backup == sign, format!("{h:#06x}: backup {backup} != sign {sign}"))
    });
}

#[test]
fn prop_cells_from_cells_inverse() {
    Runner::new("cells-inverse", common::seed_of("prop_fp/cells"), CASES).run(|g| {
        let h = g.u16();
        let cs = fp::cells(h);
        let ok = fp::from_cells(&cs) == h
            && fp::pattern_counts(h).iter().sum::<u32>() == fp::CELLS_PER_WORD as u32
            && fp::soft_cells(h) == fp::pattern_counts(h)[1] + fp::pattern_counts(h)[2];
        prop_assert(ok, format!("h={h:#06x}"))
    });
}

/// Exhaustive companion (fast: 64k decode/encode pairs): the |w| < 2
/// boundary of the premise, bit-for-bit — every finite f16 below 2.0 has
/// bit 14 clear; every one at or above 2.0 (or non-finite) has it set.
#[test]
fn exhaustive_premise_boundary() {
    for h in 0..=u16::MAX {
        let v = fp::f16_bits_to_f32(h);
        if v.is_finite() && v.abs() < 2.0 {
            assert!(fp::backup_bit_free(h), "h={h:#06x} v={v}");
        } else {
            assert!(!fp::backup_bit_free(h), "h={h:#06x} v={v}");
        }
    }
}
