//! ISSUE 4 equivalence suite: every fast path of the sweep-scale
//! measurement stack pinned against its retained oracle.
//!
//! * **Tally energy** ([`Encoded::access_energy`]) vs the per-word
//!   [`Encoded::access_energy_scalar`] loop: cycles integer-exact, census
//!   integer-exact, nanojoules to f64 rounding — over random and boundary
//!   streams at every policy and granularity, for every worker count.
//! * **Snapshot-reuse sweeps** ([`run_rate_sweep_with`]) vs the
//!   restage-per-point baseline (a fresh [`WeightStore::load`] per
//!   (policy, rate)): flip sets, accuracies, and energy reports
//!   bit-identical at a fixed seed, with exactly one encode+store per
//!   policy asserted.
//! * **Pipelined materialize** vs the serial oracle is pinned in
//!   `coordinator::store` unit tests; the sweep tests here exercise it on
//!   every point as well (the sweep materializes through the pipeline).

mod common;

use std::collections::HashMap;

use mlcstt::coordinator::{StoreConfig, WeightStore};
use mlcstt::encoding::swar::{energy_tally, energy_tally_threaded, EnergyTally};
use mlcstt::encoding::{Encoded, Policy, WeightCodec};
use mlcstt::experiments::run_rate_sweep_with;
use mlcstt::fp;
use mlcstt::runtime::artifacts::{ParamSpec, WeightFile};
use mlcstt::stt::{AccessKind, CostModel, ErrorModel};

const KINDS: [AccessKind; 2] = [AccessKind::Read, AccessKind::Write];

/// Word streams hitting the census boundaries: empty, sub-lane-group
/// ragged lengths, uniform all-base / all-soft, and a long mixed stream.
fn boundary_streams() -> Vec<Vec<u16>> {
    let mut streams: Vec<Vec<u16>> = (0..10usize)
        .map(|len| (0..len as u16).map(|i| i.wrapping_mul(0x4D2F)).collect())
        .collect();
    streams.push(vec![0x0000; 333]);
    streams.push(vec![0xFFFF; 333]);
    streams.push(vec![0x5555; 333]);
    streams.push(vec![0xAAAA; 333]);
    streams.push(
        (0..100_003u32)
            .map(|i| (i.wrapping_mul(40503) >> 2) as u16)
            .collect(),
    );
    streams
}

fn per_word_tally(words: &[u16]) -> EnergyTally {
    let mut want = EnergyTally::default();
    for &w in words {
        for (a, p) in want.patterns.iter_mut().zip(fp::pattern_counts(w)) {
            *a += p as u64;
        }
        want.hard_words += (fp::soft_cells(w) > 0) as u64;
        want.words += 1;
    }
    want
}

#[test]
fn census_is_exact_and_worker_invariant() {
    for words in &boundary_streams() {
        let want = per_word_tally(words);
        assert_eq!(energy_tally(words), want, "len={}", words.len());
        for workers in [1usize, 2, 3, 7, 16] {
            assert_eq!(
                energy_tally_threaded(words, workers),
                want,
                "len={} workers={workers}",
                words.len()
            );
        }
    }
}

#[test]
fn tally_energy_matches_scalar_oracle_on_raw_streams() {
    let cost = CostModel::default();
    for words in &boundary_streams() {
        let enc = Encoded {
            words: words.clone(),
            schemes: vec![],
            granularity: 1,
            policy: Policy::Unprotected,
        };
        for kind in KINDS {
            let fast = enc.access_energy(&cost, kind);
            let oracle = enc.access_energy_scalar(&cost, kind);
            assert_eq!(fast.cycles, oracle.cycles, "len={} {kind:?}", words.len());
            let diff = (fast.nanojoules - oracle.nanojoules).abs();
            let tol = 1e-12 * oracle.nanojoules.max(1.0);
            assert!(
                diff <= tol,
                "len={} {kind:?}: {} vs {}",
                words.len(),
                fast.nanojoules,
                oracle.nanojoules
            );
        }
    }
}

#[test]
fn tally_energy_matches_scalar_oracle_all_policies_granularities() {
    let cost = CostModel::default();
    let ws = common::trained_like_weights(80_000, "sweep/tally");
    for policy in Policy::ALL {
        for g in [1usize, 2, 4, 7, 8, 16] {
            let enc = WeightCodec::new(policy, g).encode(&ws);
            for kind in KINDS {
                let fast = enc.access_energy(&cost, kind);
                let oracle = enc.access_energy_scalar(&cost, kind);
                assert_eq!(fast.cycles, oracle.cycles, "{policy:?} g={g} {kind:?}");
                let rel = (fast.nanojoules - oracle.nanojoules).abs() / oracle.nanojoules;
                assert!(rel < 1e-12, "{policy:?} g={g} {kind:?}: rel={rel}");
            }
        }
    }
}

/// Multi-tensor weight file with a multi-shard tensor, so the sweep
/// exercises the per-shard seed replay across store-shard boundaries.
fn sweep_weight_file() -> WeightFile {
    WeightFile {
        params: vec![
            ParamSpec {
                name: "conv.w".into(),
                shape: vec![40_000],
                data: common::trained_like_weights(40_000, "sweep/conv"),
            },
            ParamSpec {
                name: "fc.w".into(),
                shape: vec![9_001],
                data: common::trained_like_weights(9_001, "sweep/fc"),
            },
        ],
    }
}

#[test]
fn snapshot_sweep_matches_restage_per_point_baseline() {
    let wf = sweep_weight_file();
    let seed = 0xF1685EEDu64;
    let rates = [0.0f64, 0.005, 0.015, 0.02];
    let base = StoreConfig {
        granularity: 4,
        seed,
        ..StoreConfig::default()
    };

    // Sweep path: one encode+store per policy, reinject per point. The
    // eval closure records the materialized tensors for comparison and
    // scores the fraction of weights still bit-identical to clean.
    let mut sweep_tensors: HashMap<(String, u64), Vec<ParamSpec>> = HashMap::new();
    let (points, encode_passes) =
        run_rate_sweep_with(&wf, &base, &rates, |policy, rate, tensors, _| {
            sweep_tensors.insert((policy.label().into(), rate.to_bits()), tensors.to_vec());
            Ok(fidelity(&wf, tensors))
        })
        .unwrap();
    assert_eq!(
        encode_passes,
        Policy::ALL.len(),
        "sweep must encode+store exactly once per policy"
    );
    assert_eq!(points.len(), rates.len());

    // Baseline: a fresh re-quantize/re-encode/re-store per (policy, rate).
    for (pi, &rate) in rates.iter().enumerate() {
        for (si, policy) in Policy::ALL.into_iter().enumerate() {
            let cfg = StoreConfig {
                policy,
                error_model: ErrorModel::at_rate(rate),
                ..base.clone()
            };
            let mut store = WeightStore::load(&cfg, &wf).unwrap();
            let want = store.materialize().unwrap();
            let want_report = store.report();

            let got = &sweep_tensors[&(policy.label().to_string(), rate.to_bits())];
            for (a, b) in want.iter().zip(got) {
                assert_eq!(a.name, b.name);
                assert_eq!(
                    a.data, b.data,
                    "flip set diverged: {policy:?} rate={rate} tensor={}",
                    a.name
                );
            }
            let row = &points[pi].rows[si];
            assert_eq!(row.system, policy.label());
            assert_eq!(row.accuracy, fidelity(&wf, &want), "{policy:?} rate={rate}");
            let report = &points[pi].reports[si];
            assert_eq!(report.write_energy, want_report.write_energy, "{policy:?} rate={rate}");
            assert_eq!(report.read_energy, want_report.read_energy, "{policy:?} rate={rate}");
            assert_eq!(
                report.injected_faults, want_report.injected_faults,
                "{policy:?} rate={rate}"
            );
            assert_eq!(row.flipped_cells, want_report.injected_faults);
        }
    }
}

/// Fraction of weights decoded bit-identically to their f16-quantized
/// originals — a deterministic accuracy stand-in for artifact-free runs.
fn fidelity(clean: &WeightFile, tensors: &[ParamSpec]) -> f64 {
    let mut same = 0usize;
    let mut total = 0usize;
    for (c, t) in clean.params.iter().zip(tensors) {
        for (a, b) in c.data.iter().zip(&t.data) {
            same += (fp::quantize_f16(*a).to_bits() == b.to_bits()) as usize;
            total += 1;
        }
    }
    same as f64 / total as f64
}

#[test]
fn sweep_accuracy_matches_baseline_on_synthetic_task() {
    // The Fig. 8 mechanism end to end, artifact-free: a linear classifier
    // whose weight matrix lives in the buffer. The sweep's accuracy per
    // (policy, rate) must equal the restage-per-point baseline's.
    let task = common::SyntheticTask::new(8, 256, 64, "sweep/task");
    let wf = task.weight_file();
    let seed = 99u64;
    let rates = [0.0f64, 0.02];
    let base = StoreConfig {
        granularity: 4,
        seed,
        ..StoreConfig::default()
    };
    let (points, _) = run_rate_sweep_with(&wf, &base, &rates, |_, _, tensors, _| {
        Ok(task.accuracy(&tensors[0].data))
    })
    .unwrap();

    for (pi, &rate) in rates.iter().enumerate() {
        for (si, policy) in Policy::ALL.into_iter().enumerate() {
            let cfg = StoreConfig {
                policy,
                error_model: ErrorModel::at_rate(rate),
                ..base.clone()
            };
            let mut store = WeightStore::load(&cfg, &wf).unwrap();
            let tensors = store.materialize().unwrap();
            let want = task.accuracy(&tensors[0].data);
            assert_eq!(points[pi].rows[si].accuracy, want, "{policy:?} rate={rate}");
        }
    }
    // Sanity: at rate 0 every system scores clean-task accuracy.
    for row in &points[0].rows {
        assert_eq!(row.flipped_cells, 0, "{}", row.system);
    }
}

#[test]
fn reinject_is_seed_deterministic() {
    let wf = sweep_weight_file();
    let mut store = WeightStore::load(
        &StoreConfig {
            error_model: ErrorModel::at_rate(0.0),
            ..StoreConfig::default()
        },
        &wf,
    )
    .unwrap();
    let snap = store.snapshot();
    let model = ErrorModel::at_rate(0.02);

    store.reinject(&snap, &model, 1).unwrap();
    let a = store.materialize().unwrap();
    store.reinject(&snap, &model, 1).unwrap();
    let b = store.materialize().unwrap();
    store.reinject(&snap, &model, 2).unwrap();
    let c = store.materialize().unwrap();

    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.data, y.data, "same seed must replay the same flips");
    }
    assert!(
        a.iter().zip(&c).any(|(x, y)| x.data != y.data),
        "different seeds should produce different flip sets"
    );
}
