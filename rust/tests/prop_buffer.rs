//! Property tests over the buffer + error model (in-tree prop harness).

use mlcstt::buffer::{BufferConfig, MlcBuffer};
use mlcstt::encoding::{Policy, WeightCodec};
use mlcstt::fp;
use mlcstt::stt::ErrorModel;
use mlcstt::util::prop::{prop_assert, Runner};

#[test]
fn prop_fault_free_buffer_is_transparent() {
    Runner::new("buffer-transparent", 0xB1, 100).run(|g| {
        let ws = g.weights(1, 300);
        let granularity = 1 + g.below(16);
        let enc = WeightCodec::new(Policy::Hybrid, granularity).encode(&ws);
        let cfg =
            BufferConfig::new(enc.len() * 2, 1 + g.below(16)).with_error_model(ErrorModel::at_rate(0.0));
        let mut buf = MlcBuffer::new(cfg, g.u64());
        let r = buf.store(&enc).map_err(|e| e.to_string())?;
        let back = buf.load(&r).map_err(|e| e.to_string())?;
        prop_assert(
            back.words == enc.words && back.schemes == enc.schemes,
            "buffer mutated a fault-free stream",
        )
    });
}

#[test]
fn prop_faults_only_touch_soft_cells() {
    Runner::new("faults-respect-immunity", 0xB2, 100).run(|g| {
        let ws = g.weights(1, 300);
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let cfg = BufferConfig::new(enc.len() * 2, 4)
            .with_error_model(ErrorModel::at_rate(1.0));
        let mut buf = MlcBuffer::new(cfg, g.u64());
        let r = buf.store(&enc).map_err(|e| e.to_string())?;
        let back = buf.load(&r).map_err(|e| e.to_string())?;
        for (orig, got) in enc.words.iter().zip(&back.words) {
            let changed = orig ^ got;
            // Every changed cell must have been a soft cell in the original.
            for i in 0..8 {
                let cell_mask = 0b11 << (2 * i);
                if changed & cell_mask != 0 {
                    let cell = (orig >> (2 * i)) & 0b11;
                    if cell == 0b00 || cell == 0b11 {
                        return Err(format!(
                            "immune cell changed: {orig:#06x} -> {got:#06x}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_energy_monotone_in_soft_cells() {
    Runner::new("energy-monotone", 0xB3, 200).run(|g| {
        use mlcstt::stt::{AccessKind, CostModel};
        let cost = CostModel::default();
        let a = g.u16();
        let b = g.u16();
        let (lo, hi) = if fp::soft_cells(a) <= fp::soft_cells(b) {
            (a, b)
        } else {
            (b, a)
        };
        let ok = cost.word(lo, AccessKind::Write).nanojoules
            <= cost.word(hi, AccessKind::Write).nanojoules
            && cost.word(lo, AccessKind::Read).nanojoules
                <= cost.word(hi, AccessKind::Read).nanojoules;
        prop_assert(ok, format!("lo={lo:#06x} hi={hi:#06x}"))
    });
}

#[test]
fn prop_capacity_accounting_exact() {
    Runner::new("capacity-exact", 0xB4, 100).run(|g| {
        let cap_words = 64 + g.below(2000);
        let cfg = BufferConfig::new(cap_words * 2, 4).with_error_model(ErrorModel::at_rate(0.0));
        let mut buf = MlcBuffer::new(cfg, 1);
        let mut stored = 0usize;
        loop {
            let n = 1 + g.below(256);
            let ws = g.weights(n.max(1), n.max(1));
            let enc = WeightCodec::hybrid(4).encode(&ws);
            match buf.store(&enc) {
                Ok(_) => {
                    stored += enc.len();
                    if stored == cap_words {
                        break;
                    }
                }
                Err(_) => {
                    // Rejection must be exactly because it would overflow.
                    if stored + enc.len() <= cap_words {
                        return Err(format!(
                            "spurious rejection: {stored}+{} <= {cap_words}",
                            enc.len()
                        ));
                    }
                    break;
                }
            }
            if stored > cap_words {
                return Err(format!("overfilled: {stored} > {cap_words}"));
            }
        }
        prop_assert(
            buf.free_words() == cap_words - stored,
            format!("free {} vs {}", buf.free_words(), cap_words - stored),
        )
    });
}

#[test]
fn prop_seeded_injection_reproducible() {
    Runner::new("injection-reproducible", 0xB5, 60).run(|g| {
        let ws = g.weights(8, 500);
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let seed = g.u64();
        let run = |s: u64| {
            let cfg = BufferConfig::new(enc.len() * 2, 4)
                .with_error_model(ErrorModel::at_rate(0.02));
            let mut buf = MlcBuffer::new(cfg, s);
            let r = buf.store(&enc).unwrap();
            buf.load(&r).unwrap().words
        };
        prop_assert(run(seed) == run(seed), "same seed diverged")
    });
}
