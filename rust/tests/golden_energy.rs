//! Golden-vector pinning of the `stt::energy` cost model (ISSUE 1
//! satellite): the per-cell read/write costs are the paper's Table 4
//! constants, verbatim. A refactor that drifts any of them — or the
//! pattern→cost billing convention built on them — fails here with the
//! exact divergent number, not somewhere downstream in an aggregate.

use mlcstt::stt::cell::CellPattern;
use mlcstt::stt::{AccessKind, CostModel, Energy};

/// Paper Table 4, row-major: (label, read (nJ, cyc), write (nJ, cyc)).
const TABLE4: [(&str, (f64, u64), (f64, u64)); 4] = [
    ("SLC", (0.415, 13), (0.876, 49)),
    ("MLC uniform", (0.424, 19), (1.859, 90)),
    ("Hybrid soft", (0.427, 14), (1.084, 50)),
    ("Hybrid hard", (0.579, 20), (2.653, 95)),
];

fn e(nj: f64, cyc: u64) -> Energy {
    Energy {
        nanojoules: nj,
        cycles: cyc,
    }
}

#[test]
fn table4_constants_pinned_verbatim() {
    let m = CostModel::default();
    let got = [
        ("SLC", m.slc_read, m.slc_write),
        ("MLC uniform", m.mlc_read, m.mlc_write),
        ("Hybrid soft", m.soft_read, m.soft_write),
        ("Hybrid hard", m.hard_read, m.hard_write),
    ];
    for ((label, read, write), (glabel, gread, gwrite)) in TABLE4.iter().zip(got) {
        assert_eq!(*label, glabel);
        assert_eq!(e(read.0, read.1), gread, "{label} read drifted");
        assert_eq!(e(write.0, write.1), gwrite, "{label} write drifted");
    }
}

#[test]
fn per_pattern_billing_convention_pinned() {
    // The content-aware convention (DESIGN.md §5): base states (00/11,
    // one programming pulse) bill the hybrid-soft column, intermediate
    // states (01/10, two pulses) bill hybrid-hard.
    let m = CostModel::default();
    let cases = [
        (CellPattern::P00, 0.427, 1.084),
        (CellPattern::P01, 0.579, 2.653),
        (CellPattern::P10, 0.579, 2.653),
        (CellPattern::P11, 0.427, 1.084),
    ];
    for (p, read_nj, write_nj) in cases {
        assert_eq!(m.cell(p, AccessKind::Read).nanojoules, read_nj, "{p:?} read");
        assert_eq!(m.cell(p, AccessKind::Write).nanojoules, write_nj, "{p:?} write");
    }
    // Tri-level metadata cells bill the SLC column.
    assert_eq!(m.trilevel_cell(AccessKind::Read), e(0.415, 13));
    assert_eq!(m.trilevel_cell(AccessKind::Write), e(0.876, 49));
}

#[test]
fn word_level_golden_vectors() {
    // Hand-computed word costs for pinned 16-bit images. Energy sums the 8
    // cells; latency is the max over cells (parallel row access).
    let m = CostModel::default();
    let golden: [(u16, u32 /* soft cells */); 6] = [
        (0x0000, 0), // all 00
        (0xFFFF, 0), // all 11
        (0x5555, 8), // all 01
        (0xAAAA, 8), // all 10
        (0x0001, 1), // one 01, seven 00
        (0x1C53, 3), // paper Table 2 row 1 image (soft = 3)
    ];
    for (h, soft) in golden {
        let base = 8 - soft;
        let w = m.word(h, AccessKind::Write);
        let r = m.word(h, AccessKind::Read);
        let expect_w = soft as f64 * 2.653 + base as f64 * 1.084;
        let expect_r = soft as f64 * 0.579 + base as f64 * 0.427;
        assert!(
            (w.nanojoules - expect_w).abs() < 1e-12,
            "{h:#06x} write {} != {expect_w}",
            w.nanojoules
        );
        assert!(
            (r.nanojoules - expect_r).abs() < 1e-12,
            "{h:#06x} read {} != {expect_r}",
            r.nanojoules
        );
        assert_eq!(w.cycles, if soft > 0 { 95 } else { 50 }, "{h:#06x} write cycles");
        assert_eq!(r.cycles, if soft > 0 { 20 } else { 14 }, "{h:#06x} read cycles");
    }
    // Content-blind uniform MLC billing.
    let u = m.word_uniform(AccessKind::Write);
    assert!((u.nanojoules - 8.0 * 1.859).abs() < 1e-12);
    assert_eq!(u.cycles, 90);
    let ur = m.word_uniform(AccessKind::Read);
    assert!((ur.nanojoules - 8.0 * 0.424).abs() < 1e-12);
    assert_eq!(ur.cycles, 19);
}

#[test]
fn stream_level_golden_total() {
    // A fixed 4-word stream with 0+8+1+3 = 12 soft and 20 base cells:
    // total write energy is pinned to one closed-form number, so *any*
    // accounting change (per-cell costs, summing, metadata) shows up as a
    // single-number diff.
    use mlcstt::encoding::{Encoded, Policy};
    let enc = Encoded {
        words: vec![0x0000, 0x5555, 0x0001, 0x1C53],
        schemes: vec![],
        granularity: 1,
        policy: Policy::Unprotected,
    };
    let m = CostModel::default();
    let w = enc.access_energy(&m, AccessKind::Write);
    let expect = 12.0 * 2.653 + 20.0 * 1.084;
    assert!(
        (w.nanojoules - expect).abs() < 1e-12,
        "stream write {} != {expect}",
        w.nanojoules
    );
    let r = enc.access_energy(&m, AccessKind::Read);
    let expect_r = 12.0 * 0.579 + 20.0 * 0.427;
    assert!((r.nanojoules - expect_r).abs() < 1e-12);
}
