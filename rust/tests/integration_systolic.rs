//! Integration: systolic model x real layer tables — the Fig. 9 claims.

use mlcstt::models;
use mlcstt::systolic::{simulate_network, top_k_by, ArrayConfig};

fn convs(net: &str) -> Vec<models::ConvLayer> {
    models::by_name(net)
        .unwrap()
        .into_iter()
        .filter(|l| l.h > 1)
        .collect()
}

#[test]
fn vgg16_offchip_bandwidth_drops_with_mlc_buffer() {
    // The paper's Conv11 story: off-chip demand falls substantially from
    // the 256 KB SRAM baseline to the same-area 1024 KB MLC buffer.
    let layers = convs("vgg16");
    let small = simulate_network(&layers, &ArrayConfig::new(256 * 1024));
    let large = simulate_network(&layers, &ArrayConfig::new(1024 * 1024));
    let conv11_s = small.iter().find(|r| r.name == "Conv11").unwrap();
    let conv11_l = large.iter().find(|r| r.name == "Conv11").unwrap();
    let drop = 1.0 - conv11_l.offchip_bpc() / conv11_s.offchip_bpc();
    // Paper: 25.5 -> 17.1 bytes/cycle (-33%). Require a comparable drop.
    assert!(drop > 0.2, "Conv11 off-chip drop {drop}");
}

#[test]
fn inception_keeps_gaining_through_2048kb() {
    // Paper: "Inception V3 enjoys more from larger MLC STT-RAM buffers" —
    // in our model the stem/ofmap-bound layers are flat (physically
    // fetch-once already), but the network-total off-chip traffic keeps
    // falling all the way to 2048 KB, and the 1024->2048 step still helps
    // (unlike VGG16, whose interior layers saturate at 1024 KB).
    let layers = convs("inceptionv3");
    let total = |kb: usize| -> u64 {
        simulate_network(&layers, &ArrayConfig::new(kb * 1024))
            .iter()
            .map(|r| r.offchip_bytes())
            .sum()
    };
    let t256 = total(256);
    let t1024 = total(1024);
    let t2048 = total(2048);
    assert!(t1024 < t256);
    assert!(
        t2048 < t1024,
        "inception should still gain at 2048 KB: {t1024} -> {t2048}"
    );
    // And the headline: >= 10% total reduction SRAM -> largest MLC.
    assert!((t2048 as f64) < 0.9 * t256 as f64, "{t256} -> {t2048}");
}

#[test]
fn deep_vgg_layers_are_weight_bound() {
    // Conv11-13 (14x14x512): weights dominate off-chip traffic at small
    // buffers — the precondition for the paper's focus on the weight buffer.
    let layers = convs("vgg16");
    let reports = simulate_network(&layers, &ArrayConfig::new(256 * 1024));
    let conv12 = reports.iter().find(|r| r.name == "Conv12").unwrap();
    let weight_bytes = (conv12.k * conv12.n * 2) as u64;
    assert!(weight_bytes * 2 > conv12.offchip_bytes(),
        "weights {weight_bytes} vs total {}", conv12.offchip_bytes());
}

#[test]
fn total_traffic_conservation_sanity() {
    // Off-chip reads can never be less than the unique bytes of each
    // operand; on-chip traffic can never be less than off-chip payload.
    for net in ["vgg16", "inceptionv3", "vggmini", "inceptionmini"] {
        let layers = convs(net);
        let reports = simulate_network(&layers, &ArrayConfig::new(2048 * 1024));
        for (l, r) in layers.iter().zip(&reports) {
            let unique_in = ((l.h * l.w * l.c + l.weight_elems()) * 2) as u64;
            assert!(
                r.offchip_read >= unique_in,
                "{net}/{}: {} < {unique_in}",
                l.name,
                r.offchip_read
            );
            assert!(r.onchip_bytes() >= r.offchip_write);
        }
    }
}

#[test]
fn utilization_bounded_and_plausible() {
    for net in ["vgg16", "inceptionv3"] {
        let layers = convs(net);
        let cfg = ArrayConfig::new(1024 * 1024);
        for r in simulate_network(&layers, &cfg) {
            let u = r.utilization(&cfg);
            assert!(u > 0.0 && u <= 1.0, "{net}/{}: {u}", r.name);
        }
        // The big mid-network convs should keep the array mostly busy.
        let reports = simulate_network(&layers, &cfg);
        let best = reports
            .iter()
            .map(|r| r.utilization(&cfg))
            .fold(0.0f64, f64::max);
        assert!(best > 0.5, "{net}: best utilization {best}");
    }
}

#[test]
fn mini_nets_fit_entirely_in_mlc_buffer() {
    // The artifact models' full weight sets fit the 2048 KB buffer, so
    // their off-chip weight traffic is fetch-once at every layer.
    for net in ["vggmini", "inceptionmini"] {
        let layers = convs(net);
        let total_weight_bytes: usize = layers.iter().map(|l| l.weight_elems() * 2).sum();
        assert!(total_weight_bytes < 2048 * 1024, "{net}");
        let reports = simulate_network(&layers, &ArrayConfig::new(2048 * 1024));
        for (l, r) in layers.iter().zip(&reports) {
            let once = (l.weight_elems() + l.h * l.w * l.c) * 2;
            assert!(
                (r.offchip_read as usize) <= once + once / 2,
                "{net}/{}: reads {} vs fetch-once {once}",
                l.name,
                r.offchip_read
            );
        }
    }
}
