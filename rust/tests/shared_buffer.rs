//! Multi-tenant shared buffer contract (ISSUE 7, DESIGN.md §12):
//!
//! * the extent allocator never overlaps live regions and every region
//!   starts at a bank-slot-aligned extent boundary, under arbitrary
//!   alloc/free churn;
//! * an evicted tenant's on-demand rebuild is **bit-identical** to a
//!   fresh private store under the same recipe — decoded tensors, flip
//!   counts, and f64 energy bills included;
//! * wear counters are monotone, placement rotates deterministically
//!   under equal wear, and the leveling spread stays within the hot
//!   threshold;
//! * a registry serving two tenants through a pool that fits only one
//!   completes a mixed workload with no lost, duplicated, or cross-wired
//!   responses, while the ping-pong evictions surface as `rebuilds`.

use std::time::Duration;

use mlcstt::api::{BufferPool, EvictPolicy, ModelRegistry};
use mlcstt::buffer::shared::{PoolRegion, SharedMlcBuffer, LEVEL_RATIO};
use mlcstt::buffer::AccessStats;
use mlcstt::coordinator::{LinearEngine, ServerConfig, StoreConfig, WeightStore};
use mlcstt::encoding::WeightCodec;
use mlcstt::fp;
use mlcstt::runtime::artifacts::{ParamSpec, WeightFile};
use mlcstt::stt::ErrorModel;
use mlcstt::util::rng::Xoshiro256;

/// Deterministic f16-representable weights (what a trained file holds).
fn tensor(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seeded(seed);
    (0..n)
        .map(|_| fp::quantize_f16((rng.next_gaussian() * 0.4) as f32))
        .collect()
}

fn weight_file(parts: &[(&str, usize)], seed: u64) -> WeightFile {
    WeightFile {
        params: parts
            .iter()
            .enumerate()
            .map(|(i, (name, n))| ParamSpec {
                name: (*name).to_string(),
                shape: vec![*n],
                data: tensor(*n, seed + i as u64),
            })
            .collect(),
    }
}

fn store_cfg(rate: f64, seed: u64, banks: usize) -> StoreConfig {
    StoreConfig {
        error_model: ErrorModel::at_rate(rate),
        seed,
        banks,
        ..StoreConfig::default()
    }
}

// ------------------------------------------------------- allocator churn

#[test]
fn allocator_never_overlaps_and_stays_bank_aligned_under_churn() {
    const BANKS: usize = 4;
    const EW: usize = 32; // words per extent
    const EXTENTS: usize = 24;
    let mut pool = SharedMlcBuffer::new(EXTENTS * EW * 2, BANKS, EW, 9);
    let codec = WeightCodec::hybrid(4);
    let model = ErrorModel::at_rate(0.0);
    let mut rng = Xoshiro256::seeded(42);
    let mut stats = AccessStats::default();
    let mut live: Vec<PoolRegion> = Vec::new();

    for step in 0..200u64 {
        if !live.is_empty() && rng.chance(0.4) {
            let i = rng.below(live.len() as u64) as usize;
            pool.free(&live.swap_remove(i));
        } else {
            let words = 1 + rng.below((3 * EW) as u64) as usize;
            let enc = codec.encode(&tensor(words, step));
            let mut frng = Xoshiro256::seeded(step);
            match pool.alloc_store(&enc, &model, &mut frng, 1, &mut stats) {
                Ok(r) => live.push(r),
                Err(_) => {
                    // Full — drain and keep churning.
                    for r in live.drain(..) {
                        pool.free(&r);
                    }
                }
            }
        }

        // Invariants hold after every step.
        let mut owned = vec![false; pool.extents()];
        for r in &live {
            assert_eq!(r.region.offset, r.first_extent * EW, "extent-aligned offset");
            assert_eq!(r.region.offset % BANKS, 0, "bank-slot-aligned start");
            assert_eq!(r.n_extents, r.region.len.div_ceil(EW).max(1));
            for e in r.first_extent..r.first_extent + r.n_extents {
                assert!(!owned[e], "extent {e} owned by two live regions");
                owned[e] = true;
            }
        }
        let in_use: usize = live.iter().map(|r| r.n_extents).sum();
        assert_eq!(pool.free_extents(), EXTENTS - in_use);
    }
}

// --------------------------------------------- evict→rebuild bit-identity

#[test]
fn rebuild_after_eviction_is_bit_identical_to_a_fresh_store() {
    // Pool of 20 extents × 256 words @ 16 banks: tenant a needs 17
    // extents (12 + 5), tenant b needs 16 — only one fits at a time.
    let pool = BufferPool::new(20 * 256 * 2, 16, 256, EvictPolicy::Lru);
    let wf_a = weight_file(&[("conv.w", 3000), ("fc.w", 1100)], 5);
    let wf_b = weight_file(&[("w", 4096)], 6);
    let ca = store_cfg(0.02, 11, 16);
    let cb = store_cfg(0.015, 22, 16);

    let first = pool.admit("a", &ca, &wf_a).unwrap();
    pool.admit("b", &cb, &wf_b).unwrap(); // evicts a
    assert!(!pool.resident("a").unwrap());
    assert!(pool.resident("b").unwrap());
    assert!(pool.ensure_resident("a").unwrap()); // rebuilds a, evicts b
    let rebuilt = pool.report("a").unwrap();
    let tensors = pool.tensors("a").unwrap();

    // Oracle: a private store+materialize under the same recipe and the
    // pool's bank count, at a placement the pool never used.
    let mut fresh = WeightStore::load(&ca, &wf_a).unwrap();
    let want_tensors = fresh.materialize().unwrap();
    let want = fresh.report();

    assert_eq!(rebuilt.tensors, want.tensors);
    assert_eq!(rebuilt.weights, want.weights);
    assert_eq!(rebuilt.injected_faults, want.injected_faults);
    assert!(rebuilt.injected_faults > 0, "the rate must actually flip cells");
    assert_eq!(rebuilt.write_energy, want.write_energy, "f64 write bill");
    assert_eq!(rebuilt.read_energy, want.read_energy, "f64 read bill");
    assert_eq!(
        rebuilt.metadata_overhead.to_bits(),
        want.metadata_overhead.to_bits()
    );
    assert_eq!(rebuilt.soft_cells_stored, want.soft_cells_stored);

    assert_eq!(tensors.len(), want_tensors.len());
    for (got, want) in tensors.iter().zip(&want_tensors) {
        assert_eq!(got.name, want.name);
        assert_eq!(got.shape, want.shape);
        assert_eq!(got.data, want.data, "decoded tensor {} differs", got.name);
    }

    // And the rebuild reproduced the initial admit exactly.
    assert_eq!(first.write_energy, rebuilt.write_energy);
    assert_eq!(first.read_energy, rebuilt.read_energy);
    assert_eq!(first.injected_faults, rebuilt.injected_faults);
}

#[test]
fn deny_policy_fails_admission_without_evicting_the_resident() {
    let pool = BufferPool::new(16 * 256 * 2, 16, 256, EvictPolicy::Deny);
    let wf = weight_file(&[("w", 4096)], 6);
    pool.admit("a", &store_cfg(0.0, 1, 16), &wf).unwrap();
    assert!(pool.admit("b", &store_cfg(0.0, 2, 16), &wf).is_err());
    assert!(pool.resident("a").unwrap(), "the resident survives a denied admit");
    assert_eq!(pool.evictions(), 0);
}

// ------------------------------------------- wear leveling + determinism

#[test]
fn wear_is_monotone_and_leveling_rotation_is_deterministic() {
    // 8 extents of 64 words, 4 banks; a 128-word tensor fills exactly two
    // extents, so repeated alloc/free sweeps the plane in pairs.
    let run = || {
        let mut pool = SharedMlcBuffer::new(8 * 64 * 2, 4, 64, 3);
        let enc = WeightCodec::hybrid(4).encode(&tensor(128, 77));
        let model = ErrorModel::at_rate(0.0);
        let mut rng = Xoshiro256::seeded(5);
        let mut stats = AccessStats::default();
        let mut placements = Vec::new();
        let mut last_total = 0u64;
        for _ in 0..24 {
            let r = pool.alloc_store(&enc, &model, &mut rng, 1, &mut stats).unwrap();
            placements.push(r.first_extent);
            let total: u64 = pool.extent_writes().iter().sum();
            assert!(total > last_total, "wear counters only grow");
            last_total = total;
            pool.free(&r);
        }
        (placements, pool.extent_writes(), pool.wear_spread())
    };

    let (p1, w1, s1) = run();
    let (p2, w2, s2) = run();
    assert_eq!(p1, p2, "placement sequence is deterministic");
    assert_eq!(w1, w2, "wear ledger is deterministic");
    assert_eq!(s1.to_bits(), s2.to_bits());

    // Equal-wear rotation: each sweep of 4 allocations covers the whole
    // plane instead of re-burning extent 0.
    let sweep: Vec<usize> = vec![0, 2, 4, 6];
    assert_eq!(p1, sweep.repeat(6));
    assert!(w1.iter().all(|&w| w > 0), "every extent absorbed writes");
    assert!((s1 - 1.0).abs() < 1e-12, "perfectly level after whole sweeps");
    assert!(s1 <= LEVEL_RATIO);
}

// --------------------------------------- serving across eviction ping-pong

#[test]
fn registry_serves_two_tenants_through_a_pool_that_fits_one() {
    const CLASSES: usize = 8;
    const DIM: usize = 64;
    const BATCH: usize = 4;
    const REQUESTS: usize = 64;

    // 6 extents of 128 words @ 4 banks; each 512-word model needs 4
    // extents, so residency ping-pongs between the tenants.
    let pool = BufferPool::new(6 * 128 * 2, 4, 128, EvictPolicy::Lru);
    let ca = store_cfg(0.0, 1, 4);
    let cb = store_cfg(0.02, 2, 4);
    let wf = |seed| weight_file(&[("classifier.w", CLASSES * DIM)], seed);
    pool.admit("a", &ca, &wf(31)).unwrap();
    // Host-side oracle from the (bit-identical under rebuild) tensors.
    let ta = pool.tensors("a").unwrap()[0].data.clone();
    pool.admit("b", &cb, &wf(32)).unwrap();
    let tb = pool.tensors("b").unwrap()[0].data.clone();
    let oracle_a = LinearEngine::new(CLASSES, DIM, BATCH, ta).unwrap();
    let oracle_b = LinearEngine::new(CLASSES, DIM, BATCH, tb).unwrap();

    let scfg = ServerConfig {
        max_wait: Duration::from_millis(1),
        codec_threads: 1,
        ..ServerConfig::default()
    };
    let mut registry = ModelRegistry::new().with_pool(pool.clone());
    for name in ["a", "b"] {
        registry
            .register_pooled(
                name,
                move |tensors: &[ParamSpec]| {
                    LinearEngine::new(CLASSES, DIM, BATCH, tensors[0].data.clone())
                },
                scfg.clone(),
            )
            .unwrap();
    }

    let mut rng = Xoshiro256::seeded(7);
    let mut tickets = Vec::with_capacity(REQUESTS);
    for r in 0..REQUESTS {
        let image: Vec<f32> = (0..DIM).map(|_| (rng.next_gaussian() * 0.5) as f32).collect();
        let (tag, oracle) = if r % 2 == 0 { ("a", &oracle_a) } else { ("b", &oracle_b) };
        let want = oracle.classify_one(&image);
        tickets.push((registry.submit(tag, image).unwrap().ticket().unwrap(), want));
    }
    for (t, want) in tickets {
        let resp = t.wait().unwrap();
        assert_eq!(resp.class, want, "response lost, duplicated, or cross-wired");
    }

    let report = registry.shutdown();
    assert_eq!(report.total_served(), REQUESTS);
    assert_eq!(report.total_errors(), 0);
    assert_eq!(report.total_shed(), 0);
    assert!(report.total_rebuilds() > 0, "ping-pong must absorb rebuild stalls");
    assert!(report.pool_evictions > 0);
    assert_eq!(report.wear.len(), 4, "one wear row per bank");
    assert!(report.wear.iter().any(|w| w.max_writes > 0));
    assert!(
        pool.wear_spread() <= LEVEL_RATIO,
        "leveling spread {} over threshold",
        pool.wear_spread()
    );
    let shown = format!("{report}");
    assert!(shown.contains("rebuilds"));
    assert!(shown.contains("buffer lifetime under traffic"));

    // After all that traffic, the last rebuild's bills still equal a
    // fresh private store — eviction never leaks accounting.
    let mut fresh = WeightStore::load(&cb, &wf(32)).unwrap();
    fresh.materialize().unwrap();
    let want = fresh.report();
    let got = pool.report("b").unwrap();
    assert_eq!(got.write_energy, want.write_energy);
    assert_eq!(got.read_energy, want.read_energy);
    assert_eq!(got.injected_faults, want.injected_faults);
}
