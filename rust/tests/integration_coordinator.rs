//! Integration: the full coordinator — weight store -> engine -> server —
//! over the real artifacts (skips without them).

use std::path::PathBuf;
use std::time::Duration;

use mlcstt::coordinator::{InferenceEngine, Server, ServerConfig, StoreConfig, WeightStore};
use mlcstt::encoding::Policy;
use mlcstt::runtime::artifacts::{model_available, model_paths, Manifest, TestSet, WeightFile};
use mlcstt::runtime::Executor;
use mlcstt::stt::ErrorModel;

fn dir() -> PathBuf {
    // MLCSTT_ARTIFACTS resolves through the single env layer.
    mlcstt::api::Config::from_env().artifacts_dir().to_path_buf()
}

macro_rules! require {
    ($cond:expr, $what:expr) => {
        if !$cond {
            eprintln!("SKIP: {} (run `make artifacts`)", $what);
            return;
        }
    };
}

fn load(model: &str) -> (Manifest, WeightFile, TestSet, PathBuf) {
    let d = dir();
    let (hlo, wpath, mpath) = model_paths(&d, model);
    let manifest = Manifest::read(&mpath).unwrap();
    let weights = WeightFile::read(&wpath).unwrap();
    let test = TestSet::read(&d.join("testset.bin")).unwrap();
    (manifest, weights, test, hlo)
}

#[test]
fn lossless_store_preserves_engine_accuracy() {
    // Fault-free ProtectRotate store must reproduce the fp16-quantized
    // model exactly, so engine accuracy matches the direct-weights run.
    require!(model_available(&dir(), "inceptionmini"), "inceptionmini artifacts");
    let (manifest, weights, test, hlo) = load("inceptionmini");

    let exec = Executor::from_hlo_file(&hlo).unwrap();
    let engine = InferenceEngine::new(exec, manifest.clone(), &weights.params).unwrap();
    let (direct, _, n) = engine.accuracy(&test, 128).unwrap();
    drop(engine);

    let cfg = StoreConfig {
        policy: Policy::ProtectRotate,
        granularity: 4,
        error_model: ErrorModel::at_rate(0.0),
        ..StoreConfig::default()
    };
    let mut store = WeightStore::load(&cfg, &weights).unwrap();
    let tensors = store.materialize().unwrap();
    let exec = Executor::from_hlo_file(&hlo).unwrap();
    let engine = InferenceEngine::new(exec, manifest, &tensors).unwrap();
    let (through_buffer, _, _) = engine.accuracy(&test, 128).unwrap();

    // fp16 quantization of an fp32-trained model can move a prediction or
    // two at the margin; allow a 2-image band on 128.
    assert!(
        (direct - through_buffer).abs() <= 2.0 / n as f64,
        "direct {direct} vs buffered {through_buffer}"
    );
}

#[test]
fn faulted_unprotected_store_degrades_accuracy_more_than_hybrid() {
    require!(model_available(&dir(), "inceptionmini"), "inceptionmini artifacts");
    let (manifest, weights, test, hlo) = load("inceptionmini");
    let eval = 128;

    let mut accs = Vec::new();
    for policy in [Policy::Unprotected, Policy::Hybrid] {
        let cfg = StoreConfig {
            policy,
            granularity: 4,
            error_model: ErrorModel::at_rate(0.02),
            seed: 99,
            ..StoreConfig::default()
        };
        let mut store = WeightStore::load(&cfg, &weights).unwrap();
        let tensors = store.materialize().unwrap();
        let exec = Executor::from_hlo_file(&hlo).unwrap();
        let engine = InferenceEngine::new(exec, manifest.clone(), &tensors).unwrap();
        let (acc, _, _) = engine.accuracy(&test, eval).unwrap();
        accs.push((policy.label(), acc));
    }
    assert!(
        accs[1].1 >= accs[0].1,
        "hybrid {:?} should not trail unprotected {:?}",
        accs[1],
        accs[0]
    );
}

#[test]
fn server_round_trips_requests_and_reports_metrics() {
    require!(model_available(&dir(), "inceptionmini"), "inceptionmini artifacts");
    let (manifest, weights, test, hlo) = load("inceptionmini");

    let cfg = StoreConfig {
        policy: Policy::Hybrid,
        granularity: 4,
        error_model: ErrorModel::at_rate(0.015),
        ..StoreConfig::default()
    };
    let mut store = WeightStore::load(&cfg, &weights).unwrap();
    let tensors = store.materialize().unwrap();

    let manifest2 = manifest.clone();
    let server = Server::start(
        move || {
            let exec = Executor::from_hlo_file(&hlo)?;
            InferenceEngine::new(exec, manifest2, &tensors)
        },
        ServerConfig {
            max_wait: Duration::from_millis(5),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let n = 40usize;
    let mut tickets = Vec::new();
    for i in 0..n {
        tickets.push(server.submit(test.image(i % test.n).to_vec()).unwrap().ticket().unwrap());
    }
    let mut classes = Vec::new();
    for t in tickets {
        let resp = t.wait().unwrap();
        assert!(resp.class < manifest.num_classes);
        classes.push(resp.class);
    }
    let report = server.shutdown();
    assert_eq!(report.served, n);
    assert_eq!(report.shed, 0);
    assert_eq!(report.errors, 0);
    assert!(report.batches >= 1);
    assert!(report.p95_ms >= report.p50_ms);
    assert!(report.p99_ms >= report.p95_ms);
    assert!(report.throughput_rps > 0.0);
    assert!(report.wall_s > 0.0);
    // Predictions must not be a constant (the model actually ran).
    assert!(classes.iter().any(|&c| c != classes[0]));
}

#[test]
fn server_rejects_malformed_images() {
    require!(model_available(&dir(), "inceptionmini"), "inceptionmini artifacts");
    let (manifest, weights, _test, hlo) = load("inceptionmini");
    let cfg = StoreConfig {
        error_model: ErrorModel::at_rate(0.0),
        ..StoreConfig::default()
    };
    let mut store = WeightStore::load(&cfg, &weights).unwrap();
    let tensors = store.materialize().unwrap();
    let manifest2 = manifest.clone();
    let server = Server::start(
        move || {
            let exec = Executor::from_hlo_file(&hlo)?;
            InferenceEngine::new(exec, manifest2, &tensors)
        },
        ServerConfig::default(),
    )
    .unwrap();
    assert!(server.submit(vec![0.0; 7]).is_err());
    let report = server.shutdown();
    assert_eq!(report.served, 0);
    // Idle window: a defined 0.0, never NaN (ISSUE 6 bugfix).
    assert_eq!(report.throughput_rps, 0.0);
}

#[test]
fn store_rejects_mismatched_manifest_order() {
    require!(model_available(&dir(), "vggmini"), "vggmini artifacts");
    let (manifest, mut weights, _test, hlo) = load("vggmini");
    // Swap two tensors: engine construction must refuse.
    weights.params.swap(0, 2);
    let cfg = StoreConfig {
        error_model: ErrorModel::at_rate(0.0),
        ..StoreConfig::default()
    };
    let mut store = WeightStore::load(&cfg, &weights).unwrap();
    let tensors = store.materialize().unwrap();
    let exec = Executor::from_hlo_file(&hlo).unwrap();
    assert!(InferenceEngine::new(exec, manifest, &tensors).is_err());
}
