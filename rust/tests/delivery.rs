//! Zero-downtime delivery contracts (ISSUE 9, DESIGN.md §14): under
//! corrupted chunks, truncated streams, wrong-version manifests, flaky
//! reads, and sabotaged canaries, every delivery outcome is either "the
//! old version still serving bit-identically" or a typed
//! [`DeliveryError`] — never a partial swap, never a dropped request.
//!
//! Everything runs backend-free: synthetic f16-representable weights
//! through `LinearEngine`, staged stores at rate 0 so "bit-identical"
//! is checkable as exact prediction equality against a clean reference
//! decode.

use std::time::Duration;

use anyhow::Result;
use mlcstt::api::{
    deliver, BufferPool, CanaryCheck, ChaosStream, Config, DeliveryError, DeploymentManifest,
    EvictPolicy, MemoryStream, ModelRegistry, WeightStream,
};
use mlcstt::coordinator::{BatchClassifier, LinearEngine, StoreConfig};
use mlcstt::runtime::artifacts::{ParamSpec, WeightFile};
use mlcstt::stt::ErrorModel;
use mlcstt::util::prop::{prop_assert, Runner};
use mlcstt::util::rng::Xoshiro256;

const CLASSES: usize = 4;
const DIM: usize = 16;
const BATCH: usize = 4;

/// Deterministic f16-representable weights (bit-exact through a rate-0
/// store decode) for one version.
fn weights(seed: u64) -> WeightFile {
    let mut rng = Xoshiro256::seeded(seed);
    WeightFile {
        params: vec![ParamSpec {
            name: "w".into(),
            shape: vec![CLASSES, DIM],
            data: (0..CLASSES * DIM)
                .map(|_| {
                    mlcstt::fp::quantize_f16(((rng.next_gaussian() * 0.3) as f32).clamp(-1.0, 1.0))
                })
                .collect(),
        }],
    }
}

/// Fault-free staged-store recipe (decode == quantized input).
fn clean_store(seed: u64) -> StoreConfig {
    StoreConfig {
        error_model: ErrorModel::at_rate(0.0),
        seed,
        threads: 1,
        ..StoreConfig::default()
    }
}

/// Delivery config: explicit budget, zero backoff (no sleeps in tests),
/// one canary batch.
fn config(retries: usize) -> Config {
    Config::builder()
        .max_wait(Duration::from_millis(1))
        .threads(1)
        .delivery_retries(retries)
        .delivery_backoff(Duration::ZERO)
        .canary(1)
        .build()
}

/// A registry serving `v0` as the incumbent under the tag "m".
fn fresh_registry(v0: &WeightFile) -> Result<ModelRegistry> {
    let mut registry = ModelRegistry::new();
    let flat = v0.flat();
    registry.register(
        "m",
        move || LinearEngine::new(CLASSES, DIM, BATCH, flat),
        config(0).server(),
    )?;
    Ok(registry)
}

/// Canary expectations from a version's clean decode; `sabotage` shifts
/// every expected class so the probe can only fail.
fn canary(version_weights: &WeightFile, sabotage: bool) -> Result<Vec<CanaryCheck>> {
    let reference = LinearEngine::new(CLASSES, DIM, 1, version_weights.flat())?;
    (0..BATCH)
        .map(|c| {
            let row = (c % CLASSES) * DIM;
            let image = version_weights.params[0].data[row..row + DIM].to_vec();
            let mut expect = reference.classify_batch(&image)?[0];
            if sabotage {
                expect = (expect + 1) % CLASSES;
            }
            Ok(CanaryCheck { image, expect })
        })
        .collect()
}

/// True iff `probes` served answers all match the reference decode.
fn serves_exactly(
    registry: &ModelRegistry,
    reference: &WeightFile,
    probes: usize,
    seed: u64,
) -> Result<bool> {
    let engine = LinearEngine::new(CLASSES, DIM, 1, reference.flat())?;
    let mut rng = Xoshiro256::seeded(seed);
    for _ in 0..probes {
        let image: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian() as f32).collect();
        let want = engine.classify_batch(&image)?[0];
        let got = registry.submit("m", image)?.ticket()?.wait()?.class;
        if got != want {
            return Ok(false);
        }
    }
    Ok(true)
}

fn build(t: &[ParamSpec]) -> Result<LinearEngine> {
    LinearEngine::new(CLASSES, DIM, BATCH, t[0].data.clone())
}

/// Property: a fault injected deeper than the retry budget — corrupted,
/// truncated, or failing reads on a random chunk, at a random chunk
/// geometry — always fails with a typed error attributing the right
/// chunk, never advances the version, and leaves the incumbent serving
/// bit-identically.
#[test]
fn property_failed_delivery_always_rolls_back_bit_identical() {
    let mut r = Runner::new("failed-delivery-rollback", 0xDE11, 24);
    r.run(|g| {
        let v0 = weights(1);
        let v1 = weights(2);
        let chunk = 1 + g.below(CLASSES * DIM + 8);
        let budget = g.below(3);
        let cfg = config(budget);
        let manifest = DeploymentManifest::describe("m", 1, &v1, chunk, &clean_store(9))
            .map_err(|e| e.to_string())?;
        let target = g.below(manifest.chunk_count());
        let deep = budget + 1; // one fault past the budget
        let base = MemoryStream::from_weights(1, &v1, chunk);
        let mut stream: Box<dyn WeightStream> = match g.below(3) {
            0 => Box::new(ChaosStream::new(base).corrupt_first(deep).on_chunk(target)),
            1 => Box::new(ChaosStream::new(base).truncate_first(deep).on_chunk(target)),
            _ => Box::new(ChaosStream::new(base).fail_first(deep).on_chunk(target)),
        };
        let mut registry = fresh_registry(&v0).map_err(|e| e.to_string())?;
        let checks = canary(&v1, false).map_err(|e| e.to_string())?;
        let err = match deliver(&mut registry, &manifest, stream.as_mut(), &checks, &cfg, build) {
            Err(e) => e,
            Ok(_) => return Err("a fault past the budget must fail the delivery".into()),
        };
        let typed = match (&err, budget) {
            (DeliveryError::ChecksumMismatch { chunk: c, .. }, 0) => *c == target,
            (DeliveryError::Truncated { chunk: c, .. }, 0) => *c == target,
            (DeliveryError::Read { chunk: c, .. }, 0) => *c == target,
            (DeliveryError::RetriesExhausted { chunk: c, retries, .. }, b) if b > 0 => {
                *c == target && *retries == b
            }
            _ => false,
        };
        prop_assert(typed, format!("unexpected error shape (budget {budget}): {err}"))?;
        prop_assert(registry.version("m") == 0, "a failed delivery must not advance the version")?;
        let intact =
            serves_exactly(&registry, &v0, 6, g.u64()).map_err(|e| e.to_string())?;
        prop_assert(intact, "the incumbent must keep serving bit-identically after rollback")
    });
}

/// Property: any chaos schedule *inside* the retry budget converges — the
/// swap commits, the retry spend is exactly the injected fault count, and
/// the new version serves bit-identically to its clean decode.
#[test]
fn property_recoverable_chaos_converges_to_a_bit_exact_swap() {
    let mut r = Runner::new("chaos-convergent-swap", 0x54A9, 16);
    r.run(|g| {
        let v0 = weights(1);
        let v1 = weights(2);
        let chunk = 1 + g.below(CLASSES * DIM);
        let fails = g.below(2);
        let truncates = g.below(2);
        let corrupts = g.below(2);
        let per_chunk = fails + truncates + corrupts;
        let cfg = config(per_chunk); // budget == injected faults: converges exactly
        let manifest = DeploymentManifest::describe("m", 1, &v1, chunk, &clean_store(4))
            .map_err(|e| e.to_string())?;
        let mut stream = ChaosStream::new(MemoryStream::from_weights(1, &v1, chunk))
            .fail_first(fails)
            .truncate_first(truncates)
            .corrupt_first(corrupts);
        let mut registry = fresh_registry(&v0).map_err(|e| e.to_string())?;
        let checks = canary(&v1, false).map_err(|e| e.to_string())?;
        let report = deliver(&mut registry, &manifest, &mut stream, &checks, &cfg, build)
            .map_err(|e| format!("in-budget chaos must converge, got: {e}"))?;
        prop_assert(
            report.retries == (per_chunk * manifest.chunk_count()) as u64,
            format!(
                "retry spend {} != {} faults injected",
                report.retries,
                per_chunk * manifest.chunk_count()
            ),
        )?;
        prop_assert(registry.version("m") == 1, "the committed version must be live")?;
        let exact = serves_exactly(&registry, &v1, 6, g.u64()).map_err(|e| e.to_string())?;
        prop_assert(exact, "the swapped version must serve its clean decode bit-identically")
    });
}

/// Version gates fail fast and typed: a stream claiming the wrong
/// version, and a manifest that does not advance the live version, are
/// both rejected before any chunk transfers, and each rejection counts
/// as a rollback.
#[test]
fn wrong_version_manifests_are_rejected_before_any_read() {
    let v0 = weights(1);
    let v1 = weights(2);
    let mut registry = fresh_registry(&v0).unwrap();
    let manifest = DeploymentManifest::describe("m", 2, &v1, 16, &clean_store(3)).unwrap();

    // The stream claims v7 against a v2 manifest.
    let mut s = MemoryStream::from_weights(7, &v1, 16);
    let err = deliver(&mut registry, &manifest, &mut s, &[], &config(1), build).unwrap_err();
    assert_eq!(
        err,
        DeliveryError::VersionConflict { model: "m".into(), offered: 2, found: 7 }
    );
    assert_eq!(registry.version("m"), 0);

    // A clean delivery commits v2...
    let mut s = MemoryStream::from_weights(2, &v1, 16);
    deliver(&mut registry, &manifest, &mut s, &[], &config(1), build).unwrap();
    assert_eq!(registry.version("m"), 2);

    // ...after which re-offering v2 is stale, and rejected.
    let mut s = MemoryStream::from_weights(2, &v1, 16);
    let err = deliver(&mut registry, &manifest, &mut s, &[], &config(1), build).unwrap_err();
    assert_eq!(
        err,
        DeliveryError::VersionConflict { model: "m".into(), offered: 2, found: 2 }
    );

    let report = registry.shutdown();
    assert_eq!(report.swaps, 1, "only the clean delivery swapped");
    assert_eq!(report.rollbacks, 2, "both rejections counted as rollbacks");
}

/// In-flight requests admitted before a swap drain on the old engine,
/// answering from the old decode — nothing is dropped at the instant of
/// the swap, and the retired section accounts for them.
#[test]
fn in_flight_requests_drain_on_the_old_engine_across_a_swap() {
    let v0 = weights(1);
    let v1 = weights(2);
    let mut registry = fresh_registry(&v0).unwrap();
    let reference = LinearEngine::new(CLASSES, DIM, 1, v0.flat()).unwrap();
    let mut rng = Xoshiro256::seeded(17);
    let mut tail = Vec::new();
    for _ in 0..2 * BATCH {
        let image: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian() as f32).collect();
        let want = reference.classify_batch(&image).unwrap()[0];
        tail.push((registry.submit("m", image).unwrap().ticket().unwrap(), want));
    }

    let manifest = DeploymentManifest::describe("m", 1, &v1, 16, &clean_store(8)).unwrap();
    let mut s = MemoryStream::from_weights(1, &v1, 16);
    let checks = canary(&v1, false).unwrap();
    deliver(&mut registry, &manifest, &mut s, &checks, &config(0), build).unwrap();

    for (t, want) in tail {
        let got = t.wait().expect("in-flight request dropped by the swap").class;
        assert_eq!(got, want, "in-flight request must answer from the old decode");
    }
    assert!(serves_exactly(&registry, &v1, 6, 23).unwrap(), "new decode serves after the swap");

    let report = registry.shutdown();
    assert_eq!(report.retired.len(), 1);
    assert_eq!(report.retired[0].1.served, 2 * BATCH, "retired section owns the drained tail");
    assert_eq!(report.retired[0].1.shed, 0);
    assert_eq!(report.retired[0].1.errors, 0);
}

/// Pool-staged deliveries hand tenants over atomically: a failed canary
/// withdraws the staged tenant and keeps the live one; a success retires
/// the old tenant and leaves exactly the new version resident.
#[test]
fn pool_staging_swaps_tenants_and_rolls_back_cleanly() {
    let v0 = weights(1);
    let v1 = weights(2);
    let v2 = weights(3);
    let pool = BufferPool::new(4096, 4, 64, EvictPolicy::Lru);
    pool.admit("m", &clean_store(5), &v0).unwrap();
    let mut registry = ModelRegistry::new().with_pool(pool.clone());
    registry.register_pooled("m", build, config(0).server()).unwrap();

    // Sabotaged canary: rollback withdraws the staged tenant.
    let manifest = DeploymentManifest::describe("m", 1, &v1, 16, &clean_store(6)).unwrap();
    let mut s = MemoryStream::from_weights(1, &v1, 16);
    let checks = canary(&v1, true).unwrap();
    let err = deliver(&mut registry, &manifest, &mut s, &checks, &config(0), build).unwrap_err();
    assert!(
        matches!(err, DeliveryError::CanaryFailed { mismatches, .. } if mismatches > 0),
        "sabotaged canary must fail typed, got: {err}"
    );
    assert!(pool.contains("m"), "live tenant must survive a canary rollback");
    assert!(!pool.contains("m@v1"), "staged tenant must be withdrawn on rollback");
    assert!(serves_exactly(&registry, &v0, 6, 31).unwrap());

    // Clean canary: the swap commits and the old tenant retires.
    let mut s = MemoryStream::from_weights(1, &v1, 16);
    let checks = canary(&v1, false).unwrap();
    deliver(&mut registry, &manifest, &mut s, &checks, &config(0), build).unwrap();
    assert!(!pool.contains("m"), "pre-delivery tenant retires after the swap");
    assert!(pool.contains("m@v1"));
    assert!(serves_exactly(&registry, &v1, 6, 37).unwrap());

    // A second committed delivery retires the prior versioned tenant.
    let manifest2 = DeploymentManifest::describe("m", 2, &v2, 16, &clean_store(7)).unwrap();
    let mut s = MemoryStream::from_weights(2, &v2, 16);
    let checks = canary(&v2, false).unwrap();
    deliver(&mut registry, &manifest2, &mut s, &checks, &config(0), build).unwrap();
    assert!(!pool.contains("m@v1"));
    assert!(pool.contains("m@v2"));
    assert!(serves_exactly(&registry, &v2, 6, 41).unwrap());

    let report = registry.shutdown();
    assert_eq!(report.swaps, 2);
    assert_eq!(report.rollbacks, 1);
}

/// `MLCSTT_CANARY=0` (here via the builder) skips probing entirely: even
/// expectations that could only fail do not block the swap.
#[test]
fn canary_zero_skips_probing() {
    let v0 = weights(1);
    let v1 = weights(2);
    let mut registry = fresh_registry(&v0).unwrap();
    let cfg = Config::builder()
        .max_wait(Duration::from_millis(1))
        .threads(1)
        .delivery_retries(0)
        .delivery_backoff(Duration::ZERO)
        .canary(0)
        .build();
    let manifest = DeploymentManifest::describe("m", 1, &v1, 16, &clean_store(2)).unwrap();
    let mut s = MemoryStream::from_weights(1, &v1, 16);
    let checks = canary(&v1, true).unwrap(); // would fail if probed
    let report = deliver(&mut registry, &manifest, &mut s, &checks, &cfg, build).unwrap();
    assert_eq!(report.canary_batches, 0);
    assert_eq!(registry.version("m"), 1);
    registry.shutdown();
}

/// Delivering to a tag the registry does not serve is a typed staging
/// error, not a panic or a silent no-op.
#[test]
fn unknown_model_is_a_typed_staging_error() {
    let v0 = weights(1);
    let v1 = weights(2);
    let mut registry = fresh_registry(&v0).unwrap();
    let manifest = DeploymentManifest::describe("ghost", 1, &v1, 16, &clean_store(2)).unwrap();
    let mut s = MemoryStream::from_weights(1, &v1, 16);
    let err = deliver(&mut registry, &manifest, &mut s, &[], &config(0), build).unwrap_err();
    assert!(
        matches!(&err, DeliveryError::Staging { message } if message.contains("ghost")),
        "expected a typed staging error naming the tag, got: {err}"
    );
    registry.shutdown();
}
