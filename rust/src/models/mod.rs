//! Network descriptors.
//!
//! * [`ConvLayer`] — the layer shape record consumed by [`crate::systolic`];
//! * [`vgg16`] / [`inception_v3`] — the *real, full-size* layer tables the
//!   paper feeds to SCALE-Sim for the Fig. 9 bandwidth study (the encoding
//!   and accuracy experiments use the trained Mini nets from `artifacts/`,
//!   see DESIGN.md §2 for the substitution argument);
//! * [`vgg_mini`] / [`inception_mini`] — descriptors of the JAX-trained
//!   artifact models, kept in sync with `python/compile/model.py`.

/// A convolution (or fully-connected) layer shape.
///
/// Convolutions are NHWC with square `r x r` kernels and SAME padding
/// (VGG/Inception style); `stride` subsamples the output grid. FC layers
/// are expressed as 1x1 convs over a 1x1 spatial grid.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConvLayer {
    pub name: String,
    /// Input height / width / channels.
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Output channels.
    pub k: usize,
    /// Kernel size (r x r).
    pub r: usize,
    pub stride: usize,
    /// Depth multiplier for grouped convs; 1 for the networks here.
    pub groups: usize,
}

impl ConvLayer {
    pub fn conv(
        name: &str,
        h: usize,
        w: usize,
        c: usize,
        k: usize,
        r: usize,
        stride: usize,
        groups: usize,
    ) -> Self {
        ConvLayer {
            name: name.to_string(),
            h,
            w,
            c,
            k,
            r,
            stride,
            groups,
        }
    }

    /// Fully-connected layer: `inputs -> outputs`.
    pub fn fc(name: &str, inputs: usize, outputs: usize) -> Self {
        Self::conv(name, 1, 1, inputs, outputs, 1, 1, 1)
    }

    /// Output spatial dims under SAME padding.
    pub fn out_dims(&self) -> (usize, usize) {
        (self.h.div_ceil(self.stride), self.w.div_ceil(self.stride))
    }

    /// im2col GEMM dimensions `(M, K, N)`.
    pub fn gemm_dims(&self) -> (usize, usize, usize) {
        let (oh, ow) = self.out_dims();
        (oh * ow, self.r * self.r * self.c / self.groups, self.k)
    }

    /// Weight count (excluding bias, matching the paper's buffer contents).
    pub fn weight_elems(&self) -> usize {
        self.r * self.r * self.c * self.k / self.groups
    }

    /// MAC count for one inference.
    pub fn macs(&self) -> u64 {
        let (m, k, n) = self.gemm_dims();
        m as u64 * k as u64 * n as u64
    }
}

/// VGG16 (Simonyan & Zisserman, 2014), 224x224x3 input: the 13 conv layers
/// + 3 FC layers. Names follow the paper's "ConvNN" indexing (Conv11,
/// Conv12 are the 512-channel 14x14 layers the paper calls out in Fig. 9).
pub fn vgg16() -> Vec<ConvLayer> {
    vec![
        ConvLayer::conv("Conv1", 224, 224, 3, 64, 3, 1, 1),
        ConvLayer::conv("Conv2", 224, 224, 64, 64, 3, 1, 1),
        ConvLayer::conv("Conv3", 112, 112, 64, 128, 3, 1, 1),
        ConvLayer::conv("Conv4", 112, 112, 128, 128, 3, 1, 1),
        ConvLayer::conv("Conv5", 56, 56, 128, 256, 3, 1, 1),
        ConvLayer::conv("Conv6", 56, 56, 256, 256, 3, 1, 1),
        ConvLayer::conv("Conv7", 56, 56, 256, 256, 3, 1, 1),
        ConvLayer::conv("Conv8", 28, 28, 256, 512, 3, 1, 1),
        ConvLayer::conv("Conv9", 28, 28, 512, 512, 3, 1, 1),
        ConvLayer::conv("Conv10", 28, 28, 512, 512, 3, 1, 1),
        ConvLayer::conv("Conv11", 14, 14, 512, 512, 3, 1, 1),
        ConvLayer::conv("Conv12", 14, 14, 512, 512, 3, 1, 1),
        ConvLayer::conv("Conv13", 14, 14, 512, 512, 3, 1, 1),
        ConvLayer::fc("FC1", 7 * 7 * 512, 4096),
        ConvLayer::fc("FC2", 4096, 4096),
        ConvLayer::fc("FC3", 4096, 1000),
    ]
}

/// Inception V3 (Szegedy et al., 2015), 299x299x3 input: the stem plus the
/// heaviest conv of each branch in every mixed block — the layers that
/// dominate bandwidth (Fig. 9 reports only top-3 layers, so lighter 1x1
/// reductions inside branches never surface; spot-rank tests below verify).
pub fn inception_v3() -> Vec<ConvLayer> {
    vec![
        // Stem.
        ConvLayer::conv("Conv1_3x3/2", 299, 299, 3, 32, 3, 2, 1),
        ConvLayer::conv("Conv2_3x3", 149, 149, 32, 32, 3, 1, 1),
        ConvLayer::conv("Conv3_3x3", 147, 147, 32, 64, 3, 1, 1),
        ConvLayer::conv("Conv4_1x1", 73, 73, 64, 80, 1, 1, 1),
        ConvLayer::conv("Conv5_3x3", 73, 73, 80, 192, 3, 1, 1),
        // Mixed 5b-5d (35x35, Inception-A): 5x5 branch + double-3x3 branch.
        ConvLayer::conv("Mixed5b_5x5", 35, 35, 48, 64, 5, 1, 1),
        ConvLayer::conv("Mixed5b_3x3dbl", 35, 35, 64, 96, 3, 1, 1),
        ConvLayer::conv("Mixed5c_5x5", 35, 35, 48, 64, 5, 1, 1),
        ConvLayer::conv("Mixed5c_3x3dbl", 35, 35, 64, 96, 3, 1, 1),
        ConvLayer::conv("Mixed5d_5x5", 35, 35, 48, 64, 5, 1, 1),
        ConvLayer::conv("Mixed5d_3x3dbl", 35, 35, 64, 96, 3, 1, 1),
        // Mixed 6a (grid reduction to 17x17).
        ConvLayer::conv("Mixed6a_3x3/2", 35, 35, 288, 384, 3, 2, 1),
        // Mixed 6b-6e (17x17, Inception-B): factorized 7x1/1x7 stacks; the
        // bandwidth-dominant member is the 7-tap conv at 192 channels,
        // modeled at its im2col-equivalent K (7*1*192) via r=7 rows.
        ConvLayer::conv("Mixed6b_7x7", 17, 17, 128, 192, 7, 1, 7),
        ConvLayer::conv("Mixed6c_7x7", 17, 17, 160, 192, 7, 1, 7),
        ConvLayer::conv("Mixed6d_7x7", 17, 17, 160, 192, 7, 1, 7),
        ConvLayer::conv("Mixed6e_7x7", 17, 17, 192, 192, 7, 1, 7),
        // Mixed 7a (grid reduction to 8x8).
        ConvLayer::conv("Mixed7a_3x3/2", 17, 17, 192, 320, 3, 2, 1),
        // Mixed 7b-7c (8x8, Inception-C).
        ConvLayer::conv("Mixed7b_3x3", 8, 8, 448, 384, 3, 1, 1),
        ConvLayer::conv("Mixed7c_3x3", 8, 8, 448, 384, 3, 1, 1),
        // Classifier.
        ConvLayer::fc("FC", 2048, 1000),
    ]
}

/// The JAX-trained VGG-Mini (python/compile/model.py `VGG_CFG`).
pub fn vgg_mini() -> Vec<ConvLayer> {
    vec![
        ConvLayer::conv("conv0_0", 32, 32, 3, 32, 3, 1, 1),
        ConvLayer::conv("conv0_1", 32, 32, 32, 32, 3, 1, 1),
        ConvLayer::conv("conv1_0", 16, 16, 32, 64, 3, 1, 1),
        ConvLayer::conv("conv1_1", 16, 16, 64, 64, 3, 1, 1),
        ConvLayer::conv("conv2_0", 8, 8, 64, 128, 3, 1, 1),
        ConvLayer::conv("conv2_1", 8, 8, 128, 128, 3, 1, 1),
        ConvLayer::fc("fc0", 4 * 4 * 128, 256),
        ConvLayer::fc("fc1", 256, 10),
    ]
}

/// The JAX-trained Inception-Mini (python/compile/model.py `INC_MODULES`).
pub fn inception_mini() -> Vec<ConvLayer> {
    vec![
        ConvLayer::conv("stem0", 32, 32, 3, 32, 3, 1, 1),
        ConvLayer::conv("inc0.b1", 16, 16, 32, 24, 1, 1, 1),
        ConvLayer::conv("inc0.b3r", 16, 16, 32, 16, 1, 1, 1),
        ConvLayer::conv("inc0.b3", 16, 16, 16, 32, 3, 1, 1),
        ConvLayer::conv("inc0.b5r", 16, 16, 32, 8, 1, 1, 1),
        ConvLayer::conv("inc0.b5a", 16, 16, 8, 16, 3, 1, 1),
        ConvLayer::conv("inc0.b5b", 16, 16, 16, 16, 3, 1, 1),
        ConvLayer::conv("inc0.bp", 16, 16, 32, 24, 1, 1, 1),
        ConvLayer::conv("inc1.b1", 8, 8, 96, 32, 1, 1, 1),
        ConvLayer::conv("inc1.b3r", 8, 8, 96, 24, 1, 1, 1),
        ConvLayer::conv("inc1.b3", 8, 8, 24, 48, 3, 1, 1),
        ConvLayer::conv("inc1.b5r", 8, 8, 96, 12, 1, 1, 1),
        ConvLayer::conv("inc1.b5a", 8, 8, 12, 24, 3, 1, 1),
        ConvLayer::conv("inc1.b5b", 8, 8, 24, 24, 3, 1, 1),
        ConvLayer::conv("inc1.bp", 8, 8, 96, 24, 1, 1, 1),
        ConvLayer::fc("fc", 128, 10),
    ]
}

/// Registry by name (CLI + benches).
pub fn by_name(name: &str) -> Option<Vec<ConvLayer>> {
    match name {
        "vgg16" => Some(vgg16()),
        "inceptionv3" | "inception_v3" => Some(inception_v3()),
        "vggmini" | "vgg_mini" => Some(vgg_mini()),
        "inceptionmini" | "inception_mini" => Some(inception_mini()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_weight_count_matches_published() {
        // VGG16 conv+fc weights (no biases): 138.34M params total,
        // 14.71M of them convolutional.
        let layers = vgg16();
        let conv: usize = layers[..13].iter().map(|l| l.weight_elems()).sum();
        let total: usize = layers.iter().map(|l| l.weight_elems()).sum();
        assert_eq!(conv, 14_710_464);
        assert_eq!(total, 138_344_128);
    }

    #[test]
    fn vgg16_macs_match_published_order() {
        // ~15.5 GMACs for one 224x224 inference (conv layers).
        let macs: u64 = vgg16()[..13].iter().map(|l| l.macs()).sum();
        assert!((15.3e9..15.7e9).contains(&(macs as f64)), "{macs}");
    }

    #[test]
    fn conv11_is_the_paper_layer() {
        let l = &vgg16()[10];
        assert_eq!(l.name, "Conv11");
        assert_eq!((l.h, l.w, l.c, l.k), (14, 14, 512, 512));
    }

    #[test]
    fn out_dims_same_padding() {
        let l = ConvLayer::conv("x", 17, 17, 8, 8, 3, 2, 1);
        assert_eq!(l.out_dims(), (9, 9));
        let l2 = ConvLayer::conv("y", 224, 224, 3, 64, 3, 1, 1);
        assert_eq!(l2.out_dims(), (224, 224));
    }

    #[test]
    fn fc_as_1x1_conv() {
        let l = ConvLayer::fc("fc", 4096, 1000);
        assert_eq!(l.gemm_dims(), (1, 4096, 1000));
        assert_eq!(l.weight_elems(), 4_096_000);
    }

    #[test]
    fn inception_tables_nonempty_and_named() {
        let inc = inception_v3();
        assert!(inc.len() >= 20);
        assert!(inc.iter().any(|l| l.name.contains("Mixed6")));
        // The stem's 149x149x32 conv is among the heaviest ifmaps.
        let stem = &inc[1];
        assert_eq!(stem.h * stem.w * stem.c, 149 * 149 * 32);
    }

    #[test]
    fn mini_tables_match_python_param_counts() {
        // vgg_mini weight elems must equal the manifest's conv/fc w sizes:
        // 864+9216+18432+36864+73728+147456+524288+2560 = 813408
        let total: usize = vgg_mini().iter().map(|l| l.weight_elems()).sum();
        assert_eq!(total, 813_408);
    }

    #[test]
    fn registry_lookup() {
        assert!(by_name("vgg16").is_some());
        assert!(by_name("inceptionv3").is_some());
        assert!(by_name("vggmini").is_some());
        assert!(by_name("nope").is_none());
    }
}
