//! # mlcstt
//!
//! Reproduction of *"Reliable and Energy Efficient MLC STT-RAM Buffer for
//! CNN Accelerators"* (Jasemi, Hessabi, Bagherzadeh, 2020) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper's contribution — sign-bit protection plus rotate/round data
//! reformation for half-precision CNN weights stored in 2-bit MLC STT-RAM —
//! lives in [`encoding`]; the substrates it depends on are built from
//! scratch:
//!
//! * [`fp`] — IEEE binary16 codec and bit-pattern analysis,
//! * [`stt`] — MLC STT-RAM cell model: content-dependent energy/latency
//!   (paper Table 4) and the soft-error model of Wen et al. (DAC'14),
//! * [`buffer`] — a banked MLC weight buffer with transactional accounting
//!   and a tri-level metadata plane,
//! * [`systolic`] — a SCALE-Sim-style weight-stationary systolic-array
//!   bandwidth/cycle model (paper Fig. 9),
//! * [`models`] — real VGG16 / Inception-V3 layer tables plus the trained
//!   Mini-net descriptors,
//! * [`faults`] — seeded fault-injection campaigns,
//! * [`runtime`] — PJRT executor for the AOT-lowered JAX/Pallas artifacts,
//! * [`coordinator`] — the inference service that owns weights behind the
//!   simulated buffer (encode → store → fault → decode → execute),
//! * [`scrub`] — background integrity maintenance for data at rest:
//!   golden-checksum scrub passes with in-place repair, per-bank
//!   error-rate telemetry, and the adaptive scrub scheduler (DESIGN.md
//!   §15),
//! * [`metrics`] — report tables matching the paper's figures,
//! * [`util`] — zero-dependency PRNG / JSON / CLI / stats / property-test
//!   support (the offline vendor set carries only `xla` and `anyhow`).
//!
//! The public surface over all of it is [`api`]: a layered
//! [`api::Config`] (builder → `MLCSTT_*` env → defaults, resolved in one
//! place), the [`api::Deployment`] builder owning the encode → store →
//! materialize → engine lifecycle, and the multi-model
//! [`api::ModelRegistry`] router (DESIGN.md §10). Every binary, example,
//! and experiment driver goes through it.
//!
//! Experiment-to-module index: see `DESIGN.md` §5. Every paper table and
//! figure has a bench (`rust/benches/`) that regenerates it.

pub mod api;
pub mod buffer;
pub mod coordinator;
pub mod encoding;
pub mod experiments;
pub mod faults;
pub mod fp;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod scrub;
pub mod stt;
pub mod systolic;
pub mod util;

/// Crate version (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Default artifact directory, relative to the repository root.
pub const ARTIFACT_DIR: &str = "artifacts";

#[cfg(test)]
mod tests {
    #[test]
    fn version_is_semver_ish() {
        let v = super::version();
        assert_eq!(v.split('.').count(), 3);
    }
}
