//! L3 coordinator: the inference service built around the MLC STT-RAM
//! weight buffer.
//!
//! The paper's threat/efficiency model lives on the *weight path*:
//!
//! ```text
//!   trained weights ──encode──▶ MLC buffer ──(faults)──▶ decode ──▶ PJRT
//!                                                                    ▲
//!   requests ──▶ queue ──▶ batcher ──▶ worker ── images ─────────────┘
//! ```
//!
//! * [`store`] — [`store::WeightStore`]: owns the simulated buffer; encodes
//!   every tensor with the configured policy/granularity, bills energy,
//!   injects faults, and materializes the decoded (possibly corrupted)
//!   tensors the executable will consume;
//! * [`engine`] — [`engine::InferenceEngine`]: binds a materialized weight
//!   set to a compiled PJRT executable, staging weights on the device once;
//! * [`server`] — [`server::Server`]: a threaded request-queue/batcher
//!   (vLLM-router-style, scaled to this workload) with bounded admission,
//!   load shedding, and SLO accounting (DESIGN.md §11).

pub mod engine;
pub mod server;
pub mod store;
pub mod workload;

pub use engine::{accuracy_of, BatchClassifier, InferenceEngine, LinearEngine, ThrottledEngine};
pub use server::{
    Admission, FairGate, RequestError, Server, ServerConfig, ServerReport, Ticket,
    DEFAULT_QUEUE_DEPTH,
};
pub use store::{CleanMaterialize, StoreConfig, StoreReport, StoreSnapshot, WeightStore};
pub use workload::{poisson_trace, uniform_trace, Trace};
