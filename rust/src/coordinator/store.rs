//! Weight store: every model tensor lives (encoded) in the simulated MLC
//! STT-RAM buffer; reads decode through the per-group scheme metadata.

use anyhow::{anyhow, ensure, Context, Result};

use crate::buffer::{
    BufferConfig, BufferSnapshot, LOAD_SHARD_WORDS, MlcBuffer, Region, STORE_SHARD_WORDS,
};
use crate::encoding::codec::MIN_WEIGHTS_PER_WORKER;
use crate::encoding::{Policy, WeightCodec};
use crate::runtime::artifacts::{ParamSpec, WeightFile};
use crate::stt::{Energy, ErrorModel, WearTracker};
use crate::util::threads;

/// Resolve a pinned worker count against the actual work: `pin == 0`
/// defers to the auto policy; a nonzero pin is a **cap**, still floored by
/// the per-worker minimum so tiny tensors stay single-threaded (spawning
/// the full pinned fan-out for a 1k-word bias tensor would cost more than
/// the work).
pub(crate) fn workers_for(pin: usize, items: usize, min_per_worker: usize) -> usize {
    if pin == 0 {
        threads::auto_workers(items, min_per_worker)
    } else {
        pin.min(items / min_per_worker.max(1)).max(1)
    }
}

/// Store configuration: protection policy + buffer sizing.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    pub policy: Policy,
    pub granularity: usize,
    pub error_model: ErrorModel,
    /// Buffer capacity in bytes; `None` sizes the buffer to fit the model
    /// exactly (the common experiment configuration).
    pub capacity_bytes: Option<usize>,
    /// Parallel buffer banks (read/write slot width).
    pub banks: usize,
    /// Fault-injection RNG seed for the underlying buffer.
    pub seed: u64,
    /// Codec worker-thread **cap** for encode/decode on this store's
    /// tensors; `0` auto-sizes per tensor (respecting `MLCSTT_THREADS`,
    /// see [`crate::util::threads::available`]). A nonzero cap is still
    /// floored by per-worker minimum work, so tiny tensors run inline.
    /// Serving deployments pin this from
    /// [`crate::coordinator::ServerConfig::codec_threads`]. Results are
    /// bit-identical for every value.
    pub threads: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            policy: Policy::Hybrid,
            granularity: 4,
            error_model: ErrorModel::default(),
            capacity_bytes: None,
            banks: 16,
            seed: 0xD1CE,
            threads: 0,
        }
    }
}

/// Accounting snapshot for reports.
#[derive(Clone, Debug)]
pub struct StoreReport {
    pub tensors: usize,
    pub weights: usize,
    pub write_energy: Energy,
    pub read_energy: Energy,
    pub injected_faults: u64,
    pub metadata_overhead: f64,
    pub soft_cells_stored: u64,
}

/// A reusable capture of a fully-loaded store: the stored payload image
/// plus its accounting, taken once per policy so an N-point error-rate
/// sweep re-injects faults instead of re-encoding and re-storing
/// (DESIGN.md §9). Create with [`WeightStore::snapshot`], rewind with
/// [`WeightStore::reinject`].
#[derive(Clone, Debug)]
pub struct StoreSnapshot {
    buffer: BufferSnapshot,
}

/// A clean (fault-free) materialize captured for sweep reuse: the decoded
/// tensors plus, per tensor, the payload-word read bill the buffer
/// charged and the per-shard clean read partials.
/// [`WeightStore::materialize_reusing`] hands back the cached tensor —
/// and replays the cached bill — for every region whose last re-injection
/// took **zero** flips; a region with *some* flips reuses the cache at
/// **shard** grain ([`crate::buffer::LOAD_SHARD_WORDS`] steps): only its
/// dirty shards re-read and re-decode, while clean shards replay their
/// cached partials and floats (DESIGN.md §10). Capture with
/// [`WeightStore::materialize_clean_cache`] on the same clean store the
/// [`StoreSnapshot`] was taken from.
#[derive(Clone, Debug)]
pub struct CleanMaterialize {
    /// Policy of the store the cache was captured from — part of the
    /// mismatch guard in [`WeightStore::materialize_reusing`].
    policy: Policy,
    tensors: Vec<ParamSpec>,
    bills: Vec<Energy>,
    /// Per tensor, the clean image's per-shard load partials — what a
    /// fresh read of a flip-free shard would contribute to the carry-rule
    /// reduction.
    partials: Vec<Vec<crate::buffer::LoadPartial>>,
}

impl CleanMaterialize {
    /// The clean decoded tensors, in store order.
    pub fn tensors(&self) -> &[ParamSpec] {
        &self.tensors
    }
}

/// The store itself.
pub struct WeightStore {
    codec: WeightCodec,
    buffer: MlcBuffer,
    /// (tensor meta, buffer region); data inside ParamSpec holds the
    /// *original* weights for reference, regions hold the stored images.
    entries: Vec<(ParamSpec, Region)>,
    metadata_overhead: f64,
    soft_cells: u64,
    /// Pinned codec worker count (0 = auto per tensor).
    threads: usize,
    /// Per-region, per-shard words-corrupted counts from the most recent
    /// [`Self::reinject`] (`None` until one runs) — the validity signal
    /// for [`Self::materialize_reusing`]'s shard-grain flip-skip.
    last_flips: Option<Vec<Vec<u64>>>,
    /// Endurance stress of every intended stored word (the lifetime
    /// projection `mlcstt serve` prints; DESIGN.md §12).
    wear: WearTracker,
}

impl WeightStore {
    /// Encode + store every tensor of a weight file.
    pub fn load(cfg: &StoreConfig, weights: &WeightFile) -> Result<Self> {
        let codec = WeightCodec::new(cfg.policy, cfg.granularity);
        let total = weights.total_elems();
        ensure!(total > 0, "empty weight file");
        let capacity = cfg.capacity_bytes.unwrap_or(total * 2);
        let buffer_cfg =
            BufferConfig::new(capacity, cfg.banks).with_error_model(cfg.error_model.clone());
        let mut buffer = MlcBuffer::new(buffer_cfg, cfg.seed);

        let mut entries = Vec::with_capacity(weights.params.len());
        let mut overhead_num = 0.0;
        let mut soft = 0u64;
        let mut enc = crate::encoding::Encoded::with_context(cfg.policy, cfg.granularity);
        let mut wear = WearTracker::new();
        // The store drives encoding through the ProtectionPolicy trait
        // (DESIGN.md §13): for the paper's scheme family the boxed
        // implementation delegates to the exact `WeightCodec` call it
        // replaced, so stored bytes are bit-identical by construction
        // (pinned by `rust/tests/policy_matrix.rs`).
        let protection = crate::encoding::protection_for(cfg.policy, cfg.granularity);
        for p in &weights.params {
            let w = workers_for(cfg.threads, p.data.len(), MIN_WEIGHTS_PER_WORKER);
            protection.encode_into(&p.data, &mut enc, w);
            soft += enc.soft_cells();
            overhead_num += enc.metadata_overhead() * enc.len() as f64;
            wear.record_stream(&enc.words);
            let region = buffer
                .store(&enc)
                .with_context(|| format!("storing tensor {}", p.name))?;
            entries.push((p.clone(), region));
        }
        Ok(WeightStore {
            codec,
            buffer,
            entries,
            metadata_overhead: overhead_num / total as f64,
            soft_cells: soft,
            threads: cfg.threads,
            last_flips: None,
            wear,
        })
    }

    /// Endurance stress of the initial store's intended words: the
    /// single-tenant lifetime projection (`stress/write`, relative
    /// lifetime, writes-to-rated) behind the `mlcstt serve` report line.
    pub fn wear(&self) -> &WearTracker {
        &self.wear
    }

    pub fn policy(&self) -> Policy {
        self.codec.policy
    }

    /// Read every tensor back through the buffer (bills read energy) and
    /// decode to the f32 tensors fed to the executable. This is the serve
    /// path: each tensor goes through the fused, double-buffered
    /// load→decode pipeline of [`crate::buffer::MlcBuffer::load_decoded`]
    /// (decode shard `k` overlaps the copy+bill of shard `k+1`;
    /// DESIGN.md §9), under the pinned worker count
    /// ([`StoreConfig::threads`], `MLCSTT_THREADS`-aware when 0/auto).
    /// Tensors and accounting are bit-identical to
    /// [`Self::materialize_serial`] for every worker count.
    pub fn materialize(&mut self) -> Result<Vec<ParamSpec>> {
        let mut out = Vec::with_capacity(self.entries.len());
        for i in 0..self.entries.len() {
            let (spec, _) = self.load_entry(i)?;
            out.push(spec);
        }
        Ok(out)
    }

    /// Fused load→decode of entry `i` under this store's worker pin;
    /// returns the decoded tensor and the payload read bill
    /// ([`MlcBuffer::load_decoded`]'s return). This is the **single**
    /// code path behind [`Self::materialize`],
    /// [`Self::materialize_clean_cache`], and the dirty-region branch of
    /// [`Self::materialize_reusing`] — their bit-identical-accounting
    /// contract depends on all three sharing it.
    fn load_entry(&mut self, i: usize) -> Result<(ParamSpec, Energy)> {
        let (meta, region) = &self.entries[i];
        let w = workers_for(self.threads, region.len, LOAD_SHARD_WORDS);
        let mut data = Vec::new();
        let bill = self
            .buffer
            .load_decoded(region, &mut data, w)
            .with_context(|| format!("loading tensor {}", meta.name))?;
        let spec = ParamSpec {
            name: meta.name.clone(),
            shape: meta.shape.clone(),
            data,
        };
        Ok((spec, bill))
    }

    /// The pre-pipeline serve path — a full threaded load, then a full
    /// threaded decode per tensor, via
    /// [`crate::buffer::MlcBuffer::load_with_threads`] and
    /// [`crate::encoding::Encoded::decode_into_threaded`]. Kept as the
    /// pipeline's equivalence oracle and bench denominator.
    pub fn materialize_serial(&mut self) -> Result<Vec<ParamSpec>> {
        let mut out = Vec::with_capacity(self.entries.len());
        for (meta, region) in &self.entries {
            let wl = workers_for(self.threads, region.len, LOAD_SHARD_WORDS);
            let enc = self
                .buffer
                .load_with_threads(region, wl)
                .with_context(|| format!("loading tensor {}", meta.name))?;
            let mut data = Vec::new();
            let wd = workers_for(self.threads, enc.len(), MIN_WEIGHTS_PER_WORKER);
            enc.decode_into_threaded(&mut data, wd);
            out.push(ParamSpec {
                name: meta.name.clone(),
                shape: meta.shape.clone(),
                data,
            });
        }
        Ok(out)
    }

    /// Capture the stored image + accounting for sweep reuse — typically
    /// right after a fault-free [`Self::load`], so the snapshot holds each
    /// tensor's *clean* encoded words (DESIGN.md §9).
    pub fn snapshot(&self) -> StoreSnapshot {
        StoreSnapshot {
            buffer: self.buffer.snapshot(),
        }
    }

    /// Rewind stored payloads + accounting to `snap`, reseed the fault
    /// RNG with `seed`, and re-inject write-path faults at `model`'s rate
    /// into every tensor, in store order. The resulting stored image,
    /// flip set, and accounting are **bit-identical** to a fresh
    /// [`Self::load`] whose config carried (`model`, `seed`) — the
    /// per-shard seed draws replay in exactly the order the original
    /// stores drew them — at none of the re-quantize/re-encode/re-store
    /// cost. Returns total words corrupted.
    pub fn reinject(&mut self, snap: &StoreSnapshot, model: &ErrorModel, seed: u64) -> Result<u64> {
        // Fault shards and load shards are the same word ranges, which is
        // what lets `materialize_reusing` map flip counts onto read shards.
        const _: () = assert!(STORE_SHARD_WORDS == LOAD_SHARD_WORDS);
        self.buffer.restore(&snap.buffer, seed);
        let mut per_region = Vec::with_capacity(self.entries.len());
        let mut corrupted = 0u64;
        for (meta, region) in &self.entries {
            let w = workers_for(self.threads, region.len, STORE_SHARD_WORDS);
            let per_shard = self
                .buffer
                .corrupt_region_write_shards(region, model, w)
                .with_context(|| format!("re-injecting tensor {}", meta.name))?;
            corrupted += per_shard.iter().sum::<u64>();
            per_region.push(per_shard);
        }
        self.last_flips = Some(per_region);
        Ok(corrupted)
    }

    /// A [`Self::materialize`] that also captures, per tensor, the
    /// payload read bill the buffer charged — the cache side of the
    /// flip-set-aware sweep (DESIGN.md §10). Call it on the **clean**
    /// store right after [`Self::snapshot`]: the read energy it bills is
    /// rewound by the next [`Self::reinject`] (restore replays the
    /// snapshot's accounting), so the capture itself never shows up in a
    /// sweep point's report.
    pub fn materialize_clean_cache(&mut self) -> Result<CleanMaterialize> {
        let mut tensors = Vec::with_capacity(self.entries.len());
        let mut bills = Vec::with_capacity(self.entries.len());
        let mut partials = Vec::with_capacity(self.entries.len());
        for i in 0..self.entries.len() {
            let (spec, bill) = self.load_entry(i)?;
            tensors.push(spec);
            bills.push(bill);
            // Per-shard clean partials for the shard-grain reuse path —
            // computed without billing, so capturing them leaves the
            // accounting exactly where `load_entry` put it.
            let (meta, region) = &self.entries[i];
            let p = self
                .buffer
                .region_load_partials(region)
                .with_context(|| format!("caching shard partials for {}", meta.name))?;
            partials.push(p);
        }
        Ok(CleanMaterialize {
            policy: self.policy(),
            tensors,
            bills,
            partials,
        })
    }

    /// Flip-set-aware materialize, at **shard** grain: tensors whose
    /// regions took **zero** flips in the preceding [`Self::reinject`]
    /// still hold the clean snapshot bytes, so their decode is taken from
    /// `cache` and their read bill replayed
    /// ([`MlcBuffer::replay_region_read`]) instead of re-reading the
    /// buffer; a tensor with *some* flips skips just its clean shards
    /// (cached partials + floats) and re-reads/re-decodes only the dirty
    /// ones. Output tensors and cumulative accounting are
    /// **bit-identical** to a plain [`Self::materialize`] — the
    /// always-rematerialize oracle retained precisely to pin this
    /// (`experiments::run_rate_sweep_with_rematerialize`,
    /// `rust/tests/api_facade.rs`).
    ///
    /// Errors if no re-injection has run, or if `cache` mismatches this
    /// store's policy or tensor layout (count, names, shapes). The guard
    /// cannot detect a cache captured from *different weight contents*
    /// with an identical layout — capturing the cache from this store's
    /// own clean snapshot (as `experiments::run_rate_sweep_with` does)
    /// remains the caller's contract.
    pub fn materialize_reusing(&mut self, cache: &CleanMaterialize) -> Result<Vec<ParamSpec>> {
        let flips = self
            .last_flips
            .clone()
            .ok_or_else(|| anyhow!("materialize_reusing requires a preceding reinject"))?;
        ensure!(
            flips.len() == self.entries.len() && cache.tensors.len() == self.entries.len(),
            "clean cache ({} tensors) does not match store ({} tensors)",
            cache.tensors.len(),
            self.entries.len()
        );
        ensure!(
            cache.policy == self.policy(),
            "clean cache was captured under policy {:?}, store runs {:?}",
            cache.policy,
            self.policy()
        );
        for ((meta, _), cached) in self.entries.iter().zip(&cache.tensors) {
            ensure!(
                cached.name == meta.name && cached.shape == meta.shape,
                "clean cache tensor {:?} does not match store entry {:?}",
                cached.name,
                meta.name
            );
        }
        let mut out = Vec::with_capacity(self.entries.len());
        for i in 0..self.entries.len() {
            if flips[i].iter().all(|&n| n == 0) {
                let (meta, region) = &self.entries[i];
                self.buffer
                    .replay_region_read(region, cache.bills[i])
                    .with_context(|| format!("replaying read bill for {}", meta.name))?;
                out.push(cache.tensors[i].clone());
            } else {
                let (meta, region) = &self.entries[i];
                let mut data = Vec::new();
                self.buffer
                    .load_decoded_reusing(
                        region,
                        &cache.partials[i],
                        &flips[i],
                        &cache.tensors[i].data,
                        &mut data,
                    )
                    .with_context(|| format!("shard-reusing read of {}", meta.name))?;
                out.push(ParamSpec {
                    name: meta.name.clone(),
                    shape: meta.shape.clone(),
                    data,
                });
            }
        }
        Ok(out)
    }

    /// Report current accounting.
    pub fn report(&self) -> StoreReport {
        let stats = self.buffer.stats();
        StoreReport {
            tensors: self.entries.len(),
            weights: self.entries.iter().map(|(p, _)| p.len()).sum(),
            write_energy: stats.write_energy,
            read_energy: stats.read_energy,
            injected_faults: stats.injected_faults,
            metadata_overhead: self.metadata_overhead,
            soft_cells_stored: self.soft_cells,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp;

    fn weight_file(n: usize) -> WeightFile {
        let data: Vec<f32> = (0..n)
            .map(|i| fp::quantize_f16((i as f32 / n as f32) * 1.6 - 0.8))
            .collect();
        WeightFile {
            params: vec![
                ParamSpec {
                    name: "w0".into(),
                    shape: vec![n / 2, 2],
                    data: data[..n / 2 * 2].to_vec(),
                },
                ParamSpec {
                    name: "b0".into(),
                    shape: vec![n - n / 2 * 2],
                    data: data[n / 2 * 2..].to_vec(),
                },
            ],
        }
    }

    fn quiet(policy: Policy, granularity: usize) -> StoreConfig {
        StoreConfig {
            policy,
            granularity,
            error_model: ErrorModel::at_rate(0.0),
            ..StoreConfig::default()
        }
    }

    #[test]
    fn roundtrip_lossless_policy() {
        let wf = weight_file(1001);
        let mut store = WeightStore::load(&quiet(Policy::ProtectRotate, 4), &wf).unwrap();
        let out = store.materialize().unwrap();
        assert_eq!(out.len(), 2);
        for (orig, got) in wf.params.iter().zip(&out) {
            assert_eq!(orig.data, got.data, "{}", orig.name);
            assert_eq!(orig.shape, got.shape);
        }
    }

    #[test]
    fn energy_accounted_on_both_paths() {
        let wf = weight_file(512);
        let mut store = WeightStore::load(&quiet(Policy::Hybrid, 4), &wf).unwrap();
        let before = store.report();
        assert!(before.write_energy.nanojoules > 0.0);
        assert_eq!(before.read_energy.nanojoules, 0.0);
        store.materialize().unwrap();
        let after = store.report();
        assert!(after.read_energy.nanojoules > 0.0);
        assert_eq!(after.weights, 512);
        assert_eq!(after.tensors, 2);
    }

    #[test]
    fn faults_flow_into_materialized_tensors() {
        let wf = weight_file(20_000);
        let cfg = StoreConfig {
            policy: Policy::Unprotected,
            granularity: 1,
            error_model: ErrorModel::at_rate(0.02),
            ..StoreConfig::default()
        };
        let mut store = WeightStore::load(&cfg, &wf).unwrap();
        let out = store.materialize().unwrap();
        let report = store.report();
        assert!(report.injected_faults > 0);
        let changed = wf
            .params
            .iter()
            .zip(&out)
            .flat_map(|(a, b)| a.data.iter().zip(&b.data))
            .filter(|(x, y)| {
                // compare against the f16-quantized original
                fp::quantize_f16(**x) != **y
            })
            .count();
        assert!(changed > 0);
    }

    #[test]
    fn capacity_must_fit_model() {
        let wf = weight_file(100);
        let cfg = StoreConfig {
            capacity_bytes: Some(50), // 25 words < 100
            error_model: ErrorModel::at_rate(0.0),
            ..StoreConfig::default()
        };
        assert!(WeightStore::load(&cfg, &wf).is_err());
    }

    #[test]
    fn workers_for_caps_by_pin_and_floors_by_work() {
        // pin 0 defers to auto (always >= 1); nonzero pins cap but never
        // force threading onto tiny tensors.
        assert_eq!(workers_for(7, 1000, 65536), 1, "tiny tensor stays inline");
        assert_eq!(workers_for(7, 140_000, 65536), 2, "cap floored by work");
        assert_eq!(workers_for(1, 1 << 20, 65536), 1, "pin 1 is inline");
        assert!(workers_for(0, 1 << 20, 65536) >= 1);
    }

    #[test]
    fn pinned_threads_materialize_identically() {
        // The serve path must produce bit-identical tensors whatever the
        // pinned codec worker count (0 = auto included). Tensors exceed
        // 2 * MIN_WEIGHTS_PER_WORKER words so pinned runs really thread.
        let wf = weight_file(300_000);
        let run = |threads: usize| {
            let cfg = StoreConfig {
                threads,
                error_model: ErrorModel::at_rate(0.02),
                seed: 9,
                ..StoreConfig::default()
            };
            let mut store = WeightStore::load(&cfg, &wf).unwrap();
            store.materialize().unwrap()
        };
        let base = run(1);
        for t in [0usize, 2, 7] {
            let got = run(t);
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.data, b.data, "threads={t} tensor={}", a.name);
            }
        }
    }

    #[test]
    fn pipelined_materialize_matches_serial_oracle() {
        // Tensors big enough for the multi-shard pipeline (plus a tiny
        // one for the serial fallback), with faults, across thread pins.
        let wf = weight_file(150_000);
        for threads in [0usize, 1, 2, 7] {
            let cfg = StoreConfig {
                threads,
                granularity: 7, // shard-straddling groups
                error_model: ErrorModel::at_rate(0.02),
                seed: 42,
                ..StoreConfig::default()
            };
            let mut a = WeightStore::load(&cfg, &wf).unwrap();
            let mut b = WeightStore::load(&cfg, &wf).unwrap();
            let serial = a.materialize_serial().unwrap();
            let pipelined = b.materialize().unwrap();
            for (x, y) in serial.iter().zip(&pipelined) {
                assert_eq!(x.data, y.data, "threads={threads} tensor={}", x.name);
            }
            let (ra, rb) = (a.report(), b.report());
            assert_eq!(ra.read_energy, rb.read_energy, "threads={threads}");
            assert_eq!(ra.injected_faults, rb.injected_faults);
        }
    }

    #[test]
    fn snapshot_reinject_matches_fresh_load() {
        // reinject at (model, seed) must reproduce a fresh load whose
        // config carried the same rate and seed: tensors and accounting
        // bit-identical (the sweep contract, DESIGN.md §9).
        let wf = weight_file(90_000);
        let seed = 7u64;
        for rate in [0.0f64, 0.015, 0.02] {
            let mut fresh = WeightStore::load(
                &StoreConfig {
                    error_model: ErrorModel::at_rate(rate),
                    seed,
                    ..StoreConfig::default()
                },
                &wf,
            )
            .unwrap();
            let want = fresh.materialize().unwrap();

            let mut reused = WeightStore::load(&quiet(Policy::Hybrid, 4), &wf).unwrap();
            let snap = reused.snapshot();
            reused.reinject(&snap, &ErrorModel::at_rate(rate), seed).unwrap();
            let got = reused.materialize().unwrap();
            for (x, y) in want.iter().zip(&got) {
                assert_eq!(x.data, y.data, "rate={rate} tensor={}", x.name);
            }
            let (rf, rr) = (fresh.report(), reused.report());
            assert_eq!(rf.write_energy, rr.write_energy, "rate={rate}");
            assert_eq!(rf.read_energy, rr.read_energy, "rate={rate}");
            assert_eq!(rf.injected_faults, rr.injected_faults, "rate={rate}");
        }
    }

    #[test]
    fn flip_aware_materialize_matches_always_rematerialize_oracle() {
        // Zero-flip regions take the cached-decode + replayed-bill path;
        // tensors and cumulative accounting must stay bit-identical to
        // the plain materialize for every rate (incl. 0.0, where every
        // region reuses the cache).
        let wf = weight_file(90_001);
        let seed = 5u64;
        let mut reuse = WeightStore::load(&quiet(Policy::Hybrid, 4), &wf).unwrap();
        let snap = reuse.snapshot();
        let cache = reuse.materialize_clean_cache().unwrap();
        let mut oracle = WeightStore::load(&quiet(Policy::Hybrid, 4), &wf).unwrap();
        let osnap = oracle.snapshot();
        for rate in [0.0f64, 0.02] {
            reuse.reinject(&snap, &ErrorModel::at_rate(rate), seed).unwrap();
            let got = reuse.materialize_reusing(&cache).unwrap();
            oracle.reinject(&osnap, &ErrorModel::at_rate(rate), seed).unwrap();
            let want = oracle.materialize().unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.data, b.data, "rate={rate} tensor={}", a.name);
            }
            let (ro, rr) = (oracle.report(), reuse.report());
            assert_eq!(ro.read_energy, rr.read_energy, "rate={rate}");
            assert_eq!(ro.write_energy, rr.write_energy, "rate={rate}");
            assert_eq!(ro.injected_faults, rr.injected_faults, "rate={rate}");
        }
        // Without a preceding reinject the fast path must refuse.
        let mut fresh = WeightStore::load(&quiet(Policy::Hybrid, 4), &wf).unwrap();
        assert!(fresh.materialize_reusing(&cache).is_err());
        // And a cache captured under a different policy must be rejected
        // even though the tensor layout matches.
        let mut other = WeightStore::load(&quiet(Policy::ProtectRotate, 4), &wf).unwrap();
        let other_snap = other.snapshot();
        other.reinject(&other_snap, &ErrorModel::at_rate(0.0), seed).unwrap();
        assert!(other.materialize_reusing(&cache).is_err());
    }

    #[test]
    fn report_overhead_matches_table3() {
        let wf = weight_file(4096);
        for (g, ov) in [(1usize, 0.125), (4, 0.03125), (16, 0.0078125)] {
            let store = WeightStore::load(&quiet(Policy::Hybrid, g), &wf).unwrap();
            assert!((store.report().metadata_overhead - ov).abs() < 1e-9, "g={g}");
        }
    }
}
