//! Open-loop workload generation for serving experiments.
//!
//! The paper's serving context (Fig. 1: DMA-fed accelerator) implies
//! bursty, independent request arrivals; we model them as a Poisson
//! process with exponential inter-arrival gaps — the standard open-loop
//! serving-benchmark methodology — so the coordinator's batcher can be
//! characterized under load (fill factor, p99 latency vs. offered rate)
//! rather than only in closed-loop replay.

use std::time::Duration;

use crate::util::rng::Xoshiro256;

/// A generated request trace: arrival offsets + test-set image indices.
#[derive(Clone, Debug)]
pub struct Trace {
    /// Arrival time of each request, relative to trace start.
    pub arrivals: Vec<Duration>,
    /// Index into the test set for each request.
    pub image_idx: Vec<usize>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Total span of the trace.
    pub fn span(&self) -> Duration {
        self.arrivals.last().copied().unwrap_or(Duration::ZERO)
    }
}

/// Poisson arrivals at `rate_rps` over `n` requests, drawing image indices
/// uniformly from `[0, pool)`. Deterministic under `seed`.
pub fn poisson_trace(n: usize, rate_rps: f64, pool: usize, seed: u64) -> Trace {
    assert!(rate_rps > 0.0 && pool > 0);
    let mut rng = Xoshiro256::seeded(seed);
    let mut t = 0.0f64;
    let mut arrivals = Vec::with_capacity(n);
    let mut image_idx = Vec::with_capacity(n);
    for _ in 0..n {
        // Exponential gap via inverse CDF.
        let u = (1.0 - rng.next_f64()).max(1e-12);
        t += -u.ln() / rate_rps;
        arrivals.push(Duration::from_secs_f64(t));
        image_idx.push(rng.below(pool as u64) as usize);
    }
    Trace {
        arrivals,
        image_idx,
    }
}

/// Uniform (constant-gap) arrivals — the control trace.
pub fn uniform_trace(n: usize, rate_rps: f64, pool: usize, seed: u64) -> Trace {
    assert!(rate_rps > 0.0 && pool > 0);
    let mut rng = Xoshiro256::seeded(seed);
    let gap = 1.0 / rate_rps;
    Trace {
        arrivals: (1..=n)
            .map(|i| Duration::from_secs_f64(gap * i as f64))
            .collect(),
        image_idx: (0..n).map(|_| rng.below(pool as u64) as usize).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate_converges() {
        let t = poisson_trace(20_000, 500.0, 16, 1);
        let measured = t.len() as f64 / t.span().as_secs_f64();
        assert!(
            (measured - 500.0).abs() < 25.0,
            "measured rate {measured}"
        );
    }

    #[test]
    fn poisson_gaps_are_exponential_ish() {
        // CV (std/mean) of exponential gaps is 1; uniform trace has CV 0.
        let t = poisson_trace(10_000, 100.0, 4, 2);
        let gaps: Vec<f64> = t
            .arrivals
            .windows(2)
            .map(|w| (w[1] - w[0]).as_secs_f64())
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.08, "cv {cv}");

        let u = uniform_trace(100, 100.0, 4, 2);
        let ugaps: Vec<f64> = u
            .arrivals
            .windows(2)
            .map(|w| (w[1] - w[0]).as_secs_f64())
            .collect();
        let umean = ugaps.iter().sum::<f64>() / ugaps.len() as f64;
        let uvar =
            ugaps.iter().map(|g| (g - umean).powi(2)).sum::<f64>() / ugaps.len() as f64;
        assert!(uvar.sqrt() / umean < 0.01);
    }

    #[test]
    fn traces_deterministic_and_monotone() {
        let a = poisson_trace(100, 50.0, 8, 7);
        let b = poisson_trace(100, 50.0, 8, 7);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.image_idx, b.image_idx);
        assert!(a.arrivals.windows(2).all(|w| w[1] > w[0]));
        assert!(a.image_idx.iter().all(|&i| i < 8));
        let c = poisson_trace(100, 50.0, 8, 8);
        assert_ne!(a.arrivals, c.arrivals);
    }
}
