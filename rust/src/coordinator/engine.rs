//! Inference engine: a materialized weight set bound to a compiled PJRT
//! executable.
//!
//! Weights are staged on the device **once** per fault campaign
//! (`execute_b` path) — the request loop only uploads the image batch. This
//! is the hot-path optimization measured in EXPERIMENTS.md §Perf.

use anyhow::{ensure, Context, Result};

use crate::runtime::artifacts::{Manifest, ParamSpec, TestSet};
use crate::runtime::executor::{argmax_rows, Executor};

/// What the serving loop needs from a model: batch geometry plus one
/// classify call. [`crate::coordinator::Server`] (and the
/// [`crate::api::ModelRegistry`] built on it) is generic over this trait,
/// keeping the thread-pinned-FFI factory pattern: the instance is
/// constructed *inside* the worker thread and never crosses it, so
/// implementors need not be `Send` ([`InferenceEngine`] holds raw PJRT
/// pointers and is not).
pub trait BatchClassifier {
    /// Images per compiled batch.
    fn batch_size(&self) -> usize;

    /// Flattened floats per image.
    fn image_elems(&self) -> usize;

    /// Classify exactly one batch (`batch_size() * image_elems()` floats);
    /// returns the predicted class per image.
    fn classify_batch(&self, images: &[f32]) -> Result<Vec<usize>>;

    /// Weight rebuilds this engine absorbed while serving. Pool-backed
    /// engines ([`crate::api::PooledEngine`]) re-materialize an evicted
    /// model's region on demand inside `classify_batch` and count each
    /// stall here; engines whose weights cannot be evicted report 0. The
    /// serving loop polls this after every batch into
    /// [`crate::coordinator::ServerReport::rebuilds`].
    fn rebuilds(&self) -> u64 {
        0
    }
}

/// A ready-to-serve model instance.
pub struct InferenceEngine {
    exec: Executor,
    manifest: Manifest,
    /// Device-staged weight buffers, in HLO parameter order.
    staged: Vec<xla::PjRtBuffer>,
}

impl InferenceEngine {
    /// Bind decoded tensors to the executable. `tensors` must match the
    /// manifest's parameter order/shapes (the weight-store preserves both).
    pub fn new(exec: Executor, manifest: Manifest, tensors: &[ParamSpec]) -> Result<Self> {
        ensure!(
            tensors.len() == manifest.params.len(),
            "tensor count {} != manifest {}",
            tensors.len(),
            manifest.params.len()
        );
        let mut staged = Vec::with_capacity(tensors.len());
        for (t, (name, shape, _)) in tensors.iter().zip(&manifest.params) {
            ensure!(&t.name == name, "order mismatch: {} vs {name}", t.name);
            ensure!(&t.shape == shape, "{name}: shape mismatch");
            staged.push(
                exec.stage_f32(&t.data, &t.shape)
                    .with_context(|| format!("staging {name}"))?,
            );
        }
        Ok(InferenceEngine {
            exec,
            manifest,
            staged,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.batch
    }

    /// Flattened floats per image (input shape product over the batch).
    pub fn image_elems(&self) -> usize {
        let total: usize = self.manifest.input_shape.iter().product();
        total / self.manifest.batch
    }

    pub fn num_classes(&self) -> usize {
        self.manifest.num_classes
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Replace the staged weights (same executable, same manifest): the
    /// fault-campaign loop re-stages corrupted tensors without paying the
    /// HLO compile again.
    pub fn restage(&mut self, tensors: &[ParamSpec]) -> Result<()> {
        ensure!(
            tensors.len() == self.manifest.params.len(),
            "tensor count {} != manifest {}",
            tensors.len(),
            self.manifest.params.len()
        );
        let mut staged = Vec::with_capacity(tensors.len());
        for (t, (name, shape, _)) in tensors.iter().zip(&self.manifest.params) {
            ensure!(&t.name == name, "order mismatch: {} vs {name}", t.name);
            ensure!(&t.shape == shape, "{name}: shape mismatch");
            staged.push(self.exec.stage_f32(&t.data, &t.shape)?);
        }
        self.staged = staged;
        Ok(())
    }

    /// Classify exactly one batch of images (flattened NHWC, length =
    /// batch * H * W * C). Returns predicted class per image.
    pub fn classify_batch(&self, images: &[f32]) -> Result<Vec<usize>> {
        let want: usize = self.manifest.input_shape.iter().product();
        ensure!(
            images.len() == want,
            "batch wants {want} floats, got {}",
            images.len()
        );
        let img = self.exec.stage_f32(images, &self.manifest.input_shape)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.staged.iter().collect();
        args.push(&img);
        let out = self.exec.execute_staged(&args)?;
        let logits = out.to_vec::<f32>().context("reading logits")?;
        Ok(argmax_rows(&logits, self.manifest.num_classes))
    }

    /// Classify `n` images from a test set (padding the final partial batch
    /// by repetition) and return (accuracy, correct, evaluated).
    pub fn accuracy(&self, test: &TestSet, n: usize) -> Result<(f64, usize, usize)> {
        accuracy_of(self, test, n)
    }
}

impl BatchClassifier for InferenceEngine {
    fn batch_size(&self) -> usize {
        InferenceEngine::batch_size(self)
    }

    fn image_elems(&self) -> usize {
        InferenceEngine::image_elems(self)
    }

    fn classify_batch(&self, images: &[f32]) -> Result<Vec<usize>> {
        InferenceEngine::classify_batch(self, images)
    }
}

/// Test-set accuracy of any [`BatchClassifier`] (padding the final partial
/// batch by repetition): (accuracy, correct, evaluated).
pub fn accuracy_of<C: BatchClassifier>(
    engine: &C,
    test: &TestSet,
    n: usize,
) -> Result<(f64, usize, usize)> {
    let n = n.min(test.n);
    ensure!(n > 0, "empty evaluation");
    let batch = engine.batch_size();
    let img_elems = test.h * test.w * test.c;
    let mut correct = 0usize;
    let mut buf = vec![0f32; batch * img_elems];
    let mut i = 0usize;
    while i < n {
        let take = (n - i).min(batch);
        for j in 0..batch {
            // Pad the tail batch by repeating the last image.
            let src = test.image(i + j.min(take - 1));
            buf[j * img_elems..(j + 1) * img_elems].copy_from_slice(src);
        }
        let preds = engine.classify_batch(&buf)?;
        for j in 0..take {
            if preds[j] == test.labels[i + j] as usize {
                correct += 1;
            }
        }
        i += take;
    }
    Ok((correct as f64 / n as f64, correct, n))
}

/// A pure-host linear (nearest-centroid-style) classifier: `argmax_c x ·
/// w_c` over a class-major weight matrix. The PJRT-free
/// [`BatchClassifier`]: it serves the registry demo, the
/// `registry_route` bench, and the facade equivalence tests on machines
/// where the `xla` vendor stub has no backend — and since its weight
/// matrix is an ordinary tensor, it can be materialized through the MLC
/// buffer like any model (the `rust/tests/common` synthetic task in
/// library form).
#[derive(Clone, Debug)]
pub struct LinearEngine {
    classes: usize,
    dim: usize,
    batch: usize,
    /// Flattened class-major weight matrix `w[c][d]`.
    weights: Vec<f32>,
}

impl LinearEngine {
    /// A classifier over `classes` rows of `dim` weights, serving
    /// `batch`-image batches. `weights` is the flattened class-major
    /// matrix (length `classes * dim`).
    pub fn new(classes: usize, dim: usize, batch: usize, weights: Vec<f32>) -> Result<Self> {
        ensure!(classes >= 1 && dim >= 1 && batch >= 1, "degenerate geometry");
        ensure!(
            weights.len() == classes * dim,
            "weight matrix wants {} floats, got {}",
            classes * dim,
            weights.len()
        );
        Ok(LinearEngine {
            classes,
            dim,
            batch,
            weights,
        })
    }

    /// Classify one image (`dim` floats). NaN scores — decodable from
    /// unprotected fault patterns — rank below every other score
    /// (infinities keep their usual argmax order), and ties keep the
    /// lowest class index (deterministic routing contract).
    pub fn classify_one(&self, image: &[f32]) -> usize {
        debug_assert_eq!(image.len(), self.dim);
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for c in 0..self.classes {
            let w = &self.weights[c * self.dim..(c + 1) * self.dim];
            let score: f64 = image
                .iter()
                .zip(w)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            if !score.is_nan() && score > best_score {
                best_score = score;
                best = c;
            }
        }
        best
    }
}

impl BatchClassifier for LinearEngine {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn image_elems(&self) -> usize {
        self.dim
    }

    fn classify_batch(&self, images: &[f32]) -> Result<Vec<usize>> {
        ensure!(
            images.len() == self.batch * self.dim,
            "batch wants {} floats, got {}",
            self.batch * self.dim,
            images.len()
        );
        Ok(images
            .chunks_exact(self.dim)
            .map(|x| self.classify_one(x))
            .collect())
    }
}

/// A [`BatchClassifier`] decorator with a fixed per-batch service time:
/// every `classify_batch` sleeps `service` before delegating to the inner
/// engine. This gives the serving loop a *known* saturation throughput —
/// `batch_size / service` requests per second — which the overload tests
/// (`rust/tests/overload.rs`) and the `load_test` example's synthetic
/// fallback use to drive the server past saturation deterministically,
/// without depending on host speed.
pub struct ThrottledEngine<C: BatchClassifier> {
    inner: C,
    service: std::time::Duration,
}

impl<C: BatchClassifier> ThrottledEngine<C> {
    /// Wrap `inner` with a fixed per-batch `service` time.
    pub fn new(inner: C, service: std::time::Duration) -> Self {
        ThrottledEngine { inner, service }
    }

    /// Saturation throughput, requests per second: `batch / service`.
    pub fn saturation_rps(&self) -> f64 {
        self.inner.batch_size() as f64 / self.service.as_secs_f64().max(1e-9)
    }
}

impl<C: BatchClassifier> BatchClassifier for ThrottledEngine<C> {
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }

    fn image_elems(&self) -> usize {
        self.inner.image_elems()
    }

    fn classify_batch(&self, images: &[f32]) -> Result<Vec<usize>> {
        std::thread::sleep(self.service);
        self.inner.classify_batch(images)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_engine_classifies_centroids() {
        // Two orthogonal centroids; each classifies to itself.
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let eng = LinearEngine::new(2, 2, 2, w).unwrap();
        let batch = vec![0.9, 0.1, -0.2, 0.8];
        assert_eq!(eng.classify_batch(&batch).unwrap(), vec![0, 1]);
        assert_eq!(eng.batch_size(), 2);
        assert_eq!(eng.image_elems(), 2);
    }

    #[test]
    fn linear_engine_nan_ranks_last_and_ties_take_first() {
        let eng = LinearEngine::new(2, 1, 1, vec![f32::NAN, 0.0]).unwrap();
        // Class 0 scores NaN, class 1 scores 0.0 -> class 1 wins.
        assert_eq!(eng.classify_one(&[1.0]), 1);
        let tie = LinearEngine::new(2, 1, 1, vec![0.5, 0.5]).unwrap();
        assert_eq!(tie.classify_one(&[1.0]), 0);
        // +inf is a real argmax winner, not a NaN-like reject.
        let inf = LinearEngine::new(2, 1, 1, vec![f32::INFINITY, 1.0]).unwrap();
        assert_eq!(inf.classify_one(&[1.0]), 0);
    }

    #[test]
    fn throttled_engine_delegates_and_knows_its_saturation() {
        let inner = LinearEngine::new(2, 2, 4, vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let eng = ThrottledEngine::new(inner, std::time::Duration::from_millis(2));
        assert_eq!(eng.batch_size(), 4);
        assert_eq!(eng.image_elems(), 2);
        // batch 4 / 2 ms = 2000 rps.
        assert!((eng.saturation_rps() - 2000.0).abs() < 1e-6);
        let t0 = std::time::Instant::now();
        let preds = eng.classify_batch(&[0.9, 0.1, 0.1, 0.9, 1.0, 0.0, 0.0, 1.0]).unwrap();
        assert_eq!(preds, vec![0, 1, 0, 1]);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(2));
    }

    #[test]
    fn linear_engine_rejects_bad_geometry() {
        assert!(LinearEngine::new(2, 3, 1, vec![0.0; 5]).is_err());
        let eng = LinearEngine::new(2, 3, 2, vec![0.0; 6]).unwrap();
        assert!(eng.classify_batch(&[0.0; 5]).is_err());
    }
}
