//! Inference engine: a materialized weight set bound to a compiled PJRT
//! executable.
//!
//! Weights are staged on the device **once** per fault campaign
//! (`execute_b` path) — the request loop only uploads the image batch. This
//! is the hot-path optimization measured in EXPERIMENTS.md §Perf.

use anyhow::{ensure, Context, Result};

use crate::runtime::artifacts::{Manifest, ParamSpec, TestSet};
use crate::runtime::executor::{argmax_rows, Executor};

/// A ready-to-serve model instance.
pub struct InferenceEngine {
    exec: Executor,
    manifest: Manifest,
    /// Device-staged weight buffers, in HLO parameter order.
    staged: Vec<xla::PjRtBuffer>,
}

impl InferenceEngine {
    /// Bind decoded tensors to the executable. `tensors` must match the
    /// manifest's parameter order/shapes (the weight-store preserves both).
    pub fn new(exec: Executor, manifest: Manifest, tensors: &[ParamSpec]) -> Result<Self> {
        ensure!(
            tensors.len() == manifest.params.len(),
            "tensor count {} != manifest {}",
            tensors.len(),
            manifest.params.len()
        );
        let mut staged = Vec::with_capacity(tensors.len());
        for (t, (name, shape, _)) in tensors.iter().zip(&manifest.params) {
            ensure!(&t.name == name, "order mismatch: {} vs {name}", t.name);
            ensure!(&t.shape == shape, "{name}: shape mismatch");
            staged.push(
                exec.stage_f32(&t.data, &t.shape)
                    .with_context(|| format!("staging {name}"))?,
            );
        }
        Ok(InferenceEngine {
            exec,
            manifest,
            staged,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.batch
    }

    pub fn num_classes(&self) -> usize {
        self.manifest.num_classes
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Replace the staged weights (same executable, same manifest): the
    /// fault-campaign loop re-stages corrupted tensors without paying the
    /// HLO compile again.
    pub fn restage(&mut self, tensors: &[ParamSpec]) -> Result<()> {
        ensure!(
            tensors.len() == self.manifest.params.len(),
            "tensor count {} != manifest {}",
            tensors.len(),
            self.manifest.params.len()
        );
        let mut staged = Vec::with_capacity(tensors.len());
        for (t, (name, shape, _)) in tensors.iter().zip(&self.manifest.params) {
            ensure!(&t.name == name, "order mismatch: {} vs {name}", t.name);
            ensure!(&t.shape == shape, "{name}: shape mismatch");
            staged.push(self.exec.stage_f32(&t.data, &t.shape)?);
        }
        self.staged = staged;
        Ok(())
    }

    /// Classify exactly one batch of images (flattened NHWC, length =
    /// batch * H * W * C). Returns predicted class per image.
    pub fn classify_batch(&self, images: &[f32]) -> Result<Vec<usize>> {
        let want: usize = self.manifest.input_shape.iter().product();
        ensure!(
            images.len() == want,
            "batch wants {want} floats, got {}",
            images.len()
        );
        let img = self.exec.stage_f32(images, &self.manifest.input_shape)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.staged.iter().collect();
        args.push(&img);
        let out = self.exec.execute_staged(&args)?;
        let logits = out.to_vec::<f32>().context("reading logits")?;
        Ok(argmax_rows(&logits, self.manifest.num_classes))
    }

    /// Classify `n` images from a test set (padding the final partial batch
    /// by repetition) and return (accuracy, correct, evaluated).
    pub fn accuracy(&self, test: &TestSet, n: usize) -> Result<(f64, usize, usize)> {
        let n = n.min(test.n);
        ensure!(n > 0, "empty evaluation");
        let batch = self.manifest.batch;
        let img_elems = test.h * test.w * test.c;
        let mut correct = 0usize;
        let mut buf = vec![0f32; batch * img_elems];
        let mut i = 0usize;
        while i < n {
            let take = (n - i).min(batch);
            for j in 0..batch {
                // Pad the tail batch by repeating the last image.
                let src = test.image(i + j.min(take - 1));
                buf[j * img_elems..(j + 1) * img_elems].copy_from_slice(src);
            }
            let preds = self.classify_batch(&buf)?;
            for j in 0..take {
                if preds[j] == test.labels[i + j] as usize {
                    correct += 1;
                }
            }
            i += take;
        }
        Ok((correct as f64 / n as f64, correct, n))
    }
}
