//! Threaded request server: queue → batcher → inference worker.
//!
//! A deliberately small vLLM-router-shaped loop scaled to this workload:
//! clients submit single images; the batcher coalesces up to `batch` images
//! (the artifact's compiled batch size) or flushes on `max_wait`; a worker
//! thread runs the PJRT executable; responses return through per-request
//! channels. Latency/throughput percentiles feed EXPERIMENTS.md §Perf.
//!
//! PJRT handles are not `Send` (raw pointers under the hood), so the engine
//! is *constructed inside* the worker thread from a `Send` factory closure —
//! the standard pattern for thread-pinned FFI state. No tokio in the
//! offline vendor set — std threads + mpsc are plenty for a single-executor
//! CPU pipeline (the PJRT call dominates end-to-end time; see the
//! coordinator-overhead measurement in `bench_hotpath`).

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::engine::BatchClassifier;
use crate::util::stats::Percentiles;

/// One classification request.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    respond: Sender<Response>,
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct Response {
    /// Predicted class index.
    pub class: usize,
    /// Time spent queued + batched + executed.
    pub latency: Duration,
}

/// Server tuning.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Flush a partial batch after this long (fills with repeats).
    pub max_wait: Duration,
    /// Worker-thread cap for codec work on the serve path. The server
    /// loop itself runs no codec work — weight materialization happens
    /// before [`Server::start`] — so this value flows into
    /// [`crate::coordinator::StoreConfig::threads`], which drives
    /// `load_with_threads` +
    /// [`crate::encoding::Encoded::decode_into_threaded`] during
    /// materialization. Since the facade, [`crate::api::Config::server`]
    /// is the one place this struct is built for serving: it carries the
    /// layered resolution (builder → `MLCSTT_THREADS` →
    /// `available_parallelism`; DESIGN.md §10). The `Default` here keeps
    /// the env → machine layers for direct construction. Results are
    /// bit-identical for every value (DESIGN.md §7/§8); only latency
    /// changes.
    pub codec_threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(20),
            codec_threads: crate::util::threads::available(),
        }
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug)]
pub struct ServerReport {
    /// Requests answered.
    pub served: usize,
    /// Batches executed.
    pub batches: usize,
    /// Mean real requests per batch (the rest is padding).
    pub mean_batch_fill: f64,
    /// Median end-to-end request latency, milliseconds.
    pub p50_ms: f64,
    /// 99th-percentile end-to-end request latency, milliseconds.
    pub p99_ms: f64,
    /// Requests per second over the serving wall-clock window.
    pub throughput_rps: f64,
}

/// A running server around one engine.
pub struct Server {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    img_elems: usize,
}

#[derive(Default)]
struct Metrics {
    served: usize,
    batches: usize,
    fill_sum: usize,
    latencies: Percentiles,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Client handle returned by [`Server::submit`].
pub struct Ticket {
    rx: Receiver<Response>,
}

impl Ticket {
    /// Block until the server answers this request.
    pub fn wait(self) -> Result<Response> {
        Ok(self.rx.recv()?)
    }
}

impl Server {
    /// Spawn the worker thread; `factory` builds the engine **inside** the
    /// thread (PJRT state is thread-pinned, which is why the engine type
    /// `C` needs no `Send` bound — only the factory crosses the thread).
    /// Blocks until the engine is up. Any [`BatchClassifier`] serves:
    /// the PJRT [`crate::coordinator::InferenceEngine`] in production,
    /// [`crate::coordinator::LinearEngine`] for backend-free demos and the
    /// routing benches.
    pub fn start<F, C>(factory: F, cfg: ServerConfig) -> Result<Self>
    where
        C: BatchClassifier,
        F: FnOnce() -> Result<C> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let m = Arc::clone(&metrics);

        let worker = std::thread::spawn(move || {
            let engine = match factory() {
                Ok(e) => e,
                Err(err) => {
                    let _ = ready_tx.send(Err(err));
                    return;
                }
            };
            let batch = engine.batch_size();
            let img_elems = engine.image_elems();
            let _ = ready_tx.send(Ok((batch, img_elems)));
            worker_loop(engine, rx, m, cfg, batch, img_elems);
        });

        let (_, img_elems) = ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))??;

        Ok(Server {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            img_elems,
        })
    }

    /// Submit one image; returns a ticket to wait on.
    pub fn submit(&self, image: Vec<f32>) -> Result<Ticket> {
        anyhow::ensure!(
            image.len() == self.img_elems,
            "image wants {} floats, got {}",
            self.img_elems,
            image.len()
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("server running")
            .send(Request {
                image,
                enqueued: Instant::now(),
                respond: rtx,
            })
            .map_err(|_| anyhow!("worker gone"))?;
        Ok(Ticket { rx: rrx })
    }

    /// Stop the worker and return final metrics.
    pub fn shutdown(mut self) -> ServerReport {
        self.tx.take(); // close the queue
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        let m = self.metrics.lock().unwrap();
        let mut lat = m.latencies.clone();
        let wall = match (m.started, m.finished) {
            (Some(s), Some(f)) if f > s => (f - s).as_secs_f64(),
            _ => f64::NAN,
        };
        ServerReport {
            served: m.served,
            batches: m.batches,
            mean_batch_fill: if m.batches == 0 {
                0.0
            } else {
                m.fill_sum as f64 / m.batches as f64
            },
            p50_ms: if lat.is_empty() { 0.0 } else { lat.pct(50.0) * 1e3 },
            p99_ms: if lat.is_empty() { 0.0 } else { lat.pct(99.0) * 1e3 },
            throughput_rps: m.served as f64 / wall,
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop<C: BatchClassifier>(
    engine: C,
    rx: Receiver<Request>,
    metrics: Arc<Mutex<Metrics>>,
    cfg: ServerConfig,
    batch: usize,
    img_elems: usize,
) {
    let mut images = vec![0f32; batch * img_elems];
    loop {
        // Block for the first request of the batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone
        };
        {
            let mut m = metrics.lock().unwrap();
            m.started.get_or_insert_with(Instant::now);
        }
        let deadline = Instant::now() + cfg.max_wait;
        let mut pending = vec![first];
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Assemble the batch, padding with the last image.
        for (j, slot) in images.chunks_mut(img_elems).enumerate() {
            let r = &pending[j.min(pending.len() - 1)];
            slot.copy_from_slice(&r.image);
        }
        let preds = match engine.classify_batch(&images) {
            Ok(p) => p,
            Err(_) => vec![0; batch], // degrade: report class 0
        };
        let now = Instant::now();

        let mut m = metrics.lock().unwrap();
        m.batches += 1;
        m.fill_sum += pending.len();
        for (j, req) in pending.iter().enumerate() {
            let latency = now - req.enqueued;
            m.latencies.add(latency.as_secs_f64());
            m.served += 1;
            let _ = req.respond.send(Response {
                class: preds[j],
                latency,
            });
        }
        m.finished = Some(now);
    }
}
