//! Threaded request server: bounded admission → batcher → inference worker.
//!
//! A deliberately small vLLM-router-shaped loop scaled to this workload:
//! clients submit single images through a **bounded** admission queue; the
//! batcher coalesces up to `batch` images (the artifact's compiled batch
//! size) or flushes on an admission-anchored deadline; a worker thread runs
//! the PJRT executable; responses return through per-request channels.
//! Latency/throughput percentiles feed EXPERIMENTS.md §Perf and the
//! `LOAD_*.json` overload envelope (DESIGN.md §11).
//!
//! PJRT handles are not `Send` (raw pointers under the hood), so the engine
//! is *constructed inside* the worker thread from a `Send` factory closure —
//! the standard pattern for thread-pinned FFI state. No tokio in the
//! offline vendor set — std threads + mpsc are plenty for a single-executor
//! CPU pipeline (the PJRT call dominates end-to-end time; see the
//! coordinator-overhead measurement in `bench_hotpath`).
//!
//! # Admission, shedding, and honesty (DESIGN.md §11)
//!
//! Three serving contracts, all pinned by `rust/tests/overload.rs`:
//!
//! * **Bounded queues shed, never block.** [`Server::submit`] admits at
//!   most [`ServerConfig::queue_depth`] in-flight requests; past that it
//!   returns [`Admission::Rejected`] immediately (with the observed depth)
//!   instead of queueing unbounded work. Sheds are counted in
//!   [`ServerReport::shed`].
//! * **Engine errors are errors.** A failing `classify_batch` resolves
//!   every request of that batch as [`RequestError::Engine`] — never a
//!   fabricated class-0 "success" — counted in [`ServerReport::errors`]
//!   and excluded from the latency percentiles.
//! * **Reports never lie with NaN.** An idle server reports
//!   `throughput_rps = 0.0` over a well-defined wall window
//!   ([`ServerReport::wall_s`]), not `NaN`/`inf`.
//! * **Unavailability is typed.** A model parked mid-rebuild or
//!   mid-hot-swap ([`Server::set_unavailable`]) resolves every submission
//!   as [`RequestError::Unavailable`] — naming the model and why — instead
//!   of a generic engine error, counted in [`ServerReport::unavailable`]
//!   (DESIGN.md §14).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::engine::BatchClassifier;
use crate::util::stats::{Percentiles, Summary};

/// Default bound on in-flight requests per model
/// ([`ServerConfig::queue_depth`]): deep enough that offline drivers and
/// benches never shed by accident, shallow enough that a stuck engine
/// cannot absorb unbounded memory.
pub const DEFAULT_QUEUE_DEPTH: usize = 1024;

/// One classification request.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    respond: Sender<Result<Response, RequestError>>,
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct Response {
    /// Predicted class index.
    pub class: usize,
    /// Time spent queued + batched + executed.
    pub latency: Duration,
}

/// Typed per-request failure, distinguishable from a prediction.
///
/// Before ISSUE 6 an engine failure was answered as "class 0" and counted
/// as served; a client could not tell a degraded answer from a real one
/// (the exact failure mode Khoshavi et al. 2020's error-impact estimation
/// assumes away). Now every non-answer is one of these variants.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestError {
    /// The engine's `classify_batch` failed; the whole batch resolves to
    /// this error (counted in [`ServerReport::errors`], never as served).
    Engine {
        /// The engine's error rendered with its context chain.
        message: String,
    },
    /// The admission queue was full; the request was shed without
    /// queueing (counted in [`ServerReport::shed`]).
    Shed {
        /// In-flight depth observed at the admission decision.
        depth: usize,
    },
    /// The worker vanished before answering (shutdown race).
    Disconnected,
    /// The model is parked — mid-rebuild or mid-hot-swap — and declining
    /// work until the operation settles (counted in
    /// [`ServerReport::unavailable`]). Unlike [`RequestError::Shed`] this
    /// is not a load signal: retrying immediately is pointless until the
    /// swap/rebuild finishes, and unlike [`RequestError::Engine`] nothing
    /// failed — the request was never attempted.
    Unavailable {
        /// The model the request was routed to.
        model: String,
        /// Why it is parked (e.g. `"hot swap: draining"`).
        reason: String,
    },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Engine { message } => write!(f, "engine error: {message}"),
            RequestError::Shed { depth } => {
                write!(f, "request shed: admission queue full (depth {depth})")
            }
            RequestError::Disconnected => write!(f, "server worker disconnected"),
            RequestError::Unavailable { model, reason } => {
                write!(f, "model {model:?} unavailable: {reason}")
            }
        }
    }
}

impl std::error::Error for RequestError {}

/// Admission decision from [`Server::submit`]: the bounded queue either
/// accepted the request (yielding a [`Ticket`]) or shed it immediately.
///
/// Shedding is a *value*, not an `Err`: an overloaded server is operating
/// exactly as configured, and load generators need to count sheds without
/// conflating them with real failures (malformed image, worker gone).
#[must_use = "a shed request is silent unless the caller checks it"]
pub enum Admission {
    /// Queued; wait on the ticket for the answer.
    Accepted(Ticket),
    /// Shed at admission: the queue already held `depth` requests.
    Rejected {
        /// In-flight depth observed at the admission decision.
        depth: usize,
    },
}

impl Admission {
    /// True iff the request was shed.
    pub fn is_rejected(&self) -> bool {
        matches!(self, Admission::Rejected { .. })
    }

    /// Unwrap to a [`Ticket`], converting a shed into
    /// [`RequestError::Shed`] — for closed-loop callers that treat
    /// shedding as exceptional (tests, strict drivers).
    pub fn ticket(self) -> Result<Ticket, RequestError> {
        match self {
            Admission::Accepted(t) => Ok(t),
            Admission::Rejected { depth } => Err(RequestError::Shed { depth }),
        }
    }
}

/// Server tuning.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Coalesce a partial batch up to this long **past first admission**
    /// (fills with repeats). The deadline anchors at the first pending
    /// request's enqueue time, so time spent queued behind a backlog
    /// counts against the coalesce budget: a saturated queue flushes
    /// full batches with no added wait (DESIGN.md §11).
    pub max_wait: Duration,
    /// Worker-thread cap for codec work on the serve path. The server
    /// loop itself runs no codec work — weight materialization happens
    /// before [`Server::start`] — so this value flows into
    /// [`crate::coordinator::StoreConfig::threads`], which drives
    /// `load_with_threads` +
    /// [`crate::encoding::Encoded::decode_into_threaded`] during
    /// materialization. Since the facade, [`crate::api::Config::server`]
    /// is the one place this struct is built for serving: it carries the
    /// layered resolution (builder → `MLCSTT_THREADS` →
    /// `available_parallelism`; DESIGN.md §10). The `Default` here keeps
    /// the env → machine layers for direct construction. Results are
    /// bit-identical for every value (DESIGN.md §7/§8); only latency
    /// changes.
    pub codec_threads: usize,
    /// Bound on in-flight (admitted, unanswered-by-worker-dequeue)
    /// requests. `submit` sheds past this depth instead of queueing.
    /// Layered as builder → `MLCSTT_QUEUE_DEPTH` →
    /// [`DEFAULT_QUEUE_DEPTH`] through [`crate::api::Config::server`];
    /// clamped to ≥ 1 (a zero-depth queue could never serve).
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(20),
            codec_threads: crate::util::threads::available(),
            queue_depth: DEFAULT_QUEUE_DEPTH,
        }
    }
}

/// Cross-model admission gate: a registry-wide in-flight budget that
/// keeps one model's backlog from starving its siblings.
///
/// The rule is max-min-fair in spirit: while the registry-wide in-flight
/// total is under `budget`, every model admits freely (work-conserving —
/// a single hot model may use the whole budget when it is alone). Once
/// the total reaches the budget, only models *below their fair share*
/// (`budget / models`, floored at 1 so a cold model can always queue)
/// keep admitting; above-share models shed. The per-model
/// [`ServerConfig::queue_depth`] bound still applies on top.
///
/// Counters are sampled without a lock, so the budget is approximate
/// under concurrent submitters (off by at most the number of in-flight
/// `submit` calls); the per-model bound stays exact. Pinned by
/// `rust/tests/overload.rs::fair_gate_sheds_hot_model_not_cold`.
#[derive(Clone, Debug)]
pub struct FairGate {
    total: Arc<AtomicUsize>,
    models: Arc<AtomicUsize>,
    budget: usize,
}

impl FairGate {
    /// A gate with a registry-wide in-flight `budget`.
    pub fn new(budget: usize) -> Self {
        FairGate {
            total: Arc::new(AtomicUsize::new(0)),
            models: Arc::new(AtomicUsize::new(0)),
            budget,
        }
    }

    /// Register one more model sharing this gate (shrinks fair share).
    pub fn add_model(&self) {
        self.models.fetch_add(1, Ordering::SeqCst);
    }

    /// Registry-wide in-flight total right now.
    pub fn in_flight(&self) -> usize {
        self.total.load(Ordering::SeqCst)
    }

    /// Admission rule for a model currently holding `own_depth` in-flight
    /// requests.
    fn admits(&self, own_depth: usize) -> bool {
        let total = self.total.load(Ordering::SeqCst);
        if total < self.budget {
            return true;
        }
        let models = self.models.load(Ordering::SeqCst).max(1);
        own_depth < (self.budget / models).max(1)
    }

    fn on_admit(&self) {
        self.total.fetch_add(1, Ordering::SeqCst);
    }

    fn on_dequeue(&self) {
        // Saturating: a shutdown race must not wrap the counter.
        let _ = self
            .total
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |t| t.checked_sub(1));
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug)]
pub struct ServerReport {
    /// Requests answered with a prediction.
    pub served: usize,
    /// Requests shed at admission (queue full / over fair share).
    pub shed: usize,
    /// Requests resolved as engine errors (never counted as served).
    pub errors: usize,
    /// Requests declined with [`RequestError::Unavailable`] because the
    /// model was parked mid-rebuild/mid-swap when they arrived.
    pub unavailable: usize,
    /// Batches executed.
    pub batches: usize,
    /// Mean real requests per batch (the rest is padding).
    pub mean_batch_fill: f64,
    /// Median end-to-end request latency, milliseconds (served only).
    pub p50_ms: f64,
    /// 95th-percentile end-to-end request latency, milliseconds.
    pub p95_ms: f64,
    /// 99th-percentile end-to-end request latency, milliseconds.
    pub p99_ms: f64,
    /// Served requests per second over [`ServerReport::wall_s`];
    /// 0.0 (never NaN/inf) when the window is empty or degenerate.
    pub throughput_rps: f64,
    /// Serving wall-clock window, seconds: first admission → last batch
    /// completion, or launch → shutdown for an idle server.
    pub wall_s: f64,
    /// Mean in-flight depth observed at admission decisions (0.0 idle).
    pub queue_mean: f64,
    /// Deepest in-flight depth observed at an admission decision.
    pub queue_max: usize,
    /// Weight rebuilds the engine absorbed transparently while serving
    /// ([`BatchClassifier::rebuilds`]): evict→rematerialize stalls for
    /// pool-backed engines, 0 for engines that never rebuild.
    pub rebuilds: u64,
}

/// State shared between the client-facing [`Server`] handle and its
/// worker thread: metrics, the in-flight depth counter that implements
/// the bounded queue, and the optional cross-model [`FairGate`].
#[derive(Clone)]
struct Shared {
    metrics: Arc<Mutex<Metrics>>,
    depth: Arc<AtomicUsize>,
    gate: Option<FairGate>,
    /// `Some((model, reason))` while the model is parked (mid-rebuild /
    /// mid-hot-swap): [`Server::submit`] resolves requests as
    /// [`RequestError::Unavailable`] without touching the queue.
    parked: Arc<Mutex<Option<(String, String)>>>,
}

impl Shared {
    /// Worker-side bookkeeping for one dequeued request.
    fn dequeued(&self) {
        self.depth.fetch_sub(1, Ordering::SeqCst);
        if let Some(g) = &self.gate {
            g.on_dequeue();
        }
    }
}

/// A running server around one engine.
pub struct Server {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    shared: Shared,
    queue_bound: usize,
    img_elems: usize,
    launched: Instant,
}

#[derive(Default)]
struct Metrics {
    served: usize,
    shed: usize,
    errors: usize,
    unavailable: usize,
    batches: usize,
    fill_sum: usize,
    rebuilds: u64,
    latencies: Percentiles,
    queue_depth: Summary,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Client handle returned by an accepted [`Server::submit`].
pub struct Ticket {
    rx: Receiver<Result<Response, RequestError>>,
}

impl Ticket {
    /// Block until the server resolves this request — a prediction, or a
    /// typed [`RequestError`] (engine failure / worker gone). The error
    /// is a concrete type so callers can branch on it without downcasts;
    /// `?` still lifts it into `anyhow::Result` at facade call sites.
    pub fn wait(self) -> Result<Response, RequestError> {
        match self.rx.recv() {
            Ok(outcome) => outcome,
            Err(_) => Err(RequestError::Disconnected),
        }
    }
}

impl Server {
    /// Spawn the worker thread; `factory` builds the engine **inside** the
    /// thread (PJRT state is thread-pinned, which is why the engine type
    /// `C` needs no `Send` bound — only the factory crosses the thread).
    /// Blocks until the engine is up. Any [`BatchClassifier`] serves:
    /// the PJRT [`crate::coordinator::InferenceEngine`] in production,
    /// [`crate::coordinator::LinearEngine`] for backend-free demos and the
    /// routing benches, [`crate::coordinator::ThrottledEngine`] for
    /// overload tests with a known saturation point.
    pub fn start<F, C>(factory: F, cfg: ServerConfig) -> Result<Self>
    where
        C: BatchClassifier,
        F: FnOnce() -> Result<C> + Send + 'static,
    {
        Self::start_with_gate(factory, cfg, None)
    }

    /// [`Server::start`] under a cross-model [`FairGate`]. Used by
    /// [`crate::api::ModelRegistry`] when a registry-wide in-flight
    /// budget is configured; the gate must already count this model
    /// (see [`FairGate::add_model`]).
    pub fn start_with_gate<F, C>(
        factory: F,
        cfg: ServerConfig,
        gate: Option<FairGate>,
    ) -> Result<Self>
    where
        C: BatchClassifier,
        F: FnOnce() -> Result<C> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();
        let shared = Shared {
            metrics: Arc::new(Mutex::new(Metrics::default())),
            depth: Arc::new(AtomicUsize::new(0)),
            gate,
            parked: Arc::new(Mutex::new(None)),
        };
        let worker_shared = shared.clone();
        let queue_bound = cfg.queue_depth.max(1);

        let worker = std::thread::spawn(move || {
            let engine = match factory() {
                Ok(e) => e,
                Err(err) => {
                    let _ = ready_tx.send(Err(err));
                    return;
                }
            };
            let batch = engine.batch_size();
            let img_elems = engine.image_elems();
            let _ = ready_tx.send(Ok((batch, img_elems)));
            worker_loop(engine, rx, worker_shared, cfg, batch, img_elems);
        });

        let (_, img_elems) = ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))??;

        Ok(Server {
            tx: Some(tx),
            worker: Some(worker),
            shared,
            queue_bound,
            img_elems,
            launched: Instant::now(),
        })
    }

    /// Submit one image through bounded admission. `Ok(Accepted(ticket))`
    /// means queued; `Ok(Rejected { depth })` means shed because the
    /// queue held `queue_depth` requests (or the [`FairGate`] ruled this
    /// model over its fair share). `Err` is reserved for caller bugs and
    /// teardown: a malformed image or a vanished worker.
    pub fn submit(&self, image: Vec<f32>) -> Result<Admission> {
        anyhow::ensure!(
            image.len() == self.img_elems,
            "image wants {} floats, got {}",
            self.img_elems,
            image.len()
        );
        // A parked model declines before admission: the request never
        // queues, and the ticket is pre-resolved with the typed reason so
        // callers keep the single accept-then-wait control flow.
        if let Some((model, reason)) = self.shared.parked.lock().unwrap().clone() {
            self.shared.metrics.lock().unwrap().unavailable += 1;
            let (rtx, rrx) = mpsc::channel();
            let _ = rtx.send(Err(RequestError::Unavailable { model, reason }));
            return Ok(Admission::Accepted(Ticket { rx: rrx }));
        }
        // Exact admission: compare-and-increment so concurrent submitters
        // can never overshoot the bound.
        let mut observed = 0usize;
        let admitted = self
            .shared
            .depth
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
                observed = d;
                let fair = match &self.shared.gate {
                    Some(g) => g.admits(d),
                    None => true,
                };
                (d < self.queue_bound && fair).then_some(d + 1)
            })
            .is_ok();
        {
            let mut m = self.shared.metrics.lock().unwrap();
            m.queue_depth.add(observed as f64);
            if !admitted {
                m.shed += 1;
                return Ok(Admission::Rejected { depth: observed });
            }
        }
        if let Some(g) = &self.shared.gate {
            g.on_admit();
        }
        let (rtx, rrx) = mpsc::channel();
        let sent = self.tx.as_ref().expect("server running").send(Request {
            image,
            enqueued: Instant::now(),
            respond: rtx,
        });
        if sent.is_err() {
            // Roll the admission back so the counters stay truthful.
            self.shared.dequeued();
            return Err(anyhow!("worker gone"));
        }
        Ok(Admission::Accepted(Ticket { rx: rrx }))
    }

    /// In-flight (admitted, not yet dequeued by the worker) requests
    /// right now — the live queue-depth sample behind
    /// [`crate::api::ModelRegistry::queue_depths`].
    pub fn queued(&self) -> usize {
        self.shared.depth.load(Ordering::SeqCst)
    }

    /// Park this model: until [`Server::set_available`], every `submit`
    /// resolves as [`RequestError::Unavailable`] naming `model` and
    /// `reason`, counted in [`ServerReport::unavailable`]. Requests
    /// already admitted keep draining through the worker — parking gates
    /// *new* arrivals only, which is exactly the hot-swap contract
    /// (DESIGN.md §14): the old engine finishes what it accepted.
    pub fn set_unavailable(&self, model: &str, reason: &str) {
        *self.shared.parked.lock().unwrap() = Some((model.to_string(), reason.to_string()));
    }

    /// Reopen admission after [`Server::set_unavailable`] (rollback path:
    /// a failed swap hands the queue back to the incumbent engine).
    pub fn set_available(&self) {
        *self.shared.parked.lock().unwrap() = None;
    }

    /// Stop the worker and return final metrics. Total accounting always
    /// balances: every submitted request is exactly one of served /
    /// shed / errors / unavailable (or still holds an unresolved ticket,
    /// impossible after the worker drains and exits).
    pub fn shutdown(mut self) -> ServerReport {
        self.tx.take(); // close the queue
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        let m = self.shared.metrics.lock().unwrap();
        let mut lat = m.latencies.clone();
        // Well-defined wall window even when no request ever arrived:
        // fall back to launch → now, and report 0.0 throughput on a
        // degenerate (empty or zero-width) window instead of NaN/inf.
        let started = m.started.unwrap_or(self.launched);
        let finished = m.finished.unwrap_or_else(Instant::now);
        let wall_s = finished.saturating_duration_since(started).as_secs_f64();
        let pct = |lat: &mut Percentiles, p: f64| {
            if lat.is_empty() {
                0.0
            } else {
                lat.pct(p) * 1e3
            }
        };
        ServerReport {
            served: m.served,
            shed: m.shed,
            errors: m.errors,
            unavailable: m.unavailable,
            batches: m.batches,
            mean_batch_fill: if m.batches == 0 {
                0.0
            } else {
                m.fill_sum as f64 / m.batches as f64
            },
            p50_ms: pct(&mut lat, 50.0),
            p95_ms: pct(&mut lat, 95.0),
            p99_ms: pct(&mut lat, 99.0),
            throughput_rps: if wall_s > 0.0 {
                m.served as f64 / wall_s
            } else {
                0.0
            },
            wall_s,
            queue_mean: if m.queue_depth.count() == 0 {
                0.0
            } else {
                m.queue_depth.mean()
            },
            queue_max: if m.queue_depth.count() == 0 {
                0
            } else {
                m.queue_depth.max() as usize
            },
            rebuilds: m.rebuilds,
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop<C: BatchClassifier>(
    engine: C,
    rx: Receiver<Request>,
    shared: Shared,
    cfg: ServerConfig,
    batch: usize,
    img_elems: usize,
) {
    let mut images = vec![0f32; batch * img_elems];
    loop {
        // Block for the first request of the batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone
        };
        shared.dequeued();
        {
            let mut m = shared.metrics.lock().unwrap();
            // The serving window opens at the first request's *admission*,
            // not the worker's dequeue — queue time is serving time.
            m.started.get_or_insert(first.enqueued);
        }
        let mut pending = vec![first];
        // Backlog-greedy: drain whatever is already queued, no waiting —
        // a saturated queue forms full batches immediately.
        while pending.len() < batch {
            match rx.try_recv() {
                Ok(r) => {
                    shared.dequeued();
                    pending.push(r);
                }
                Err(_) => break,
            }
        }
        // Coalesce the remainder up to the admission-anchored deadline:
        // time the first request already spent queued counts against the
        // budget, so batching never adds wait on top of a backlog.
        let deadline = pending[0].enqueued + cfg.max_wait;
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    shared.dequeued();
                    pending.push(r);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Assemble the batch, padding with the last image.
        for (j, slot) in images.chunks_mut(img_elems).enumerate() {
            let r = &pending[j.min(pending.len() - 1)];
            slot.copy_from_slice(&r.image);
        }
        let outcome = engine.classify_batch(&images);
        let now = Instant::now();

        let mut m = shared.metrics.lock().unwrap();
        m.batches += 1;
        m.fill_sum += pending.len();
        // Cheap cumulative poll (the engine lives only in this thread, so
        // this is the one place its rebuild counter can be read).
        m.rebuilds = engine.rebuilds();
        match outcome {
            Ok(preds) => {
                for (j, req) in pending.iter().enumerate() {
                    let latency = now - req.enqueued;
                    m.latencies.add(latency.as_secs_f64());
                    m.served += 1;
                    let _ = req.respond.send(Ok(Response {
                        class: preds[j],
                        latency,
                    }));
                }
            }
            Err(err) => {
                // An engine failure resolves the whole batch as typed
                // errors: no fabricated class, no served count, and no
                // latency samples (failed requests would poison the
                // percentiles the SLO report is built on).
                let message = format!("{err:#}");
                m.errors += pending.len();
                for req in &pending {
                    let _ = req.respond.send(Err(RequestError::Engine {
                        message: message.clone(),
                    }));
                }
            }
        }
        m.finished = Some(now);
    }
}
