//! Entropy-based error-impact estimation (Khoshavi et al. 2020 direction):
//! predict a policy's accuracy loss from the *stored stream's content* —
//! no fault campaign, no RNG.
//!
//! Derivation (DESIGN.md §13): the write/retention fault model
//! ([`crate::stt::ErrorModel`]) corrupts each vulnerable (`01`/`10`) cell
//! independently with probability `rate`, then flips exactly one of its
//! two junction bits, chosen uniformly. To first order in `rate` the
//! expected decoded-value damage of a stream is therefore a sum over
//! (stored word, vulnerable cell, junction) triples:
//!
//! ```text
//!   E[SSE] ≈ Σ_words Σ_{vulnerable cells} Σ_{junction ∈ {lo, hi}}
//!            (rate / 2) · (decode(word ^ junction_bit) − decode(word))²
//! ```
//!
//! Because stored words repeat heavily (weights are quantized f16), the
//! sum collapses onto a pattern census: one `(scheme symbol, word)`
//! histogram over the stream, then one decode-delta evaluation per
//! *distinct* bucket instead of per word. Non-finite corrupted decodes
//! saturate to ±65504, the same convention as
//! [`super::bitflip_sse_study`]. The estimate drops the O(rate²)
//! multi-flip terms, so it is a *ranking* tool, not an absolute
//! predictor — `rust/tests/policy_matrix.rs` validates exactly that: the
//! estimator's policy ordering matches the real campaign's at the
//! published rates.
//!
//! The per-bit Shannon entropy of the stored stream rides along as the
//! Khoshavi-style diagnostic: high-entropy bit positions are where
//! content-dependent vulnerability (and thus damage) concentrates.

use crate::encoding::{parity, scheme, Encoded, Policy, Scheme, WeightCodec};
use crate::fp;

/// Saturation value for corrupted decodes that overflow f16 (the
/// [`super::bitflip_sse_study`] convention).
const SATURATE: f32 = 65504.0;

/// One policy's predicted fault impact at one error rate — everything the
/// sweep front reports for the "entropy-estimated" system.
#[derive(Clone, Debug)]
pub struct ImpactEstimate {
    /// Policy the estimated stream was encoded under.
    pub policy: Policy,
    /// Per-cell corruption probability the estimate is evaluated at.
    pub rate: f64,
    /// First-order expected sum of squared decoded-value errors.
    pub expected_sse: f64,
    /// First-order expected number of weights whose decode changes.
    pub expected_upsets: f64,
    /// `1 - expected_upsets / n`: the predicted fraction of weights that
    /// decode bit-exactly despite faults (clamped to `[0, 1]`).
    pub predicted_fidelity: f64,
    /// Shannon entropy (bits) of each stored bit position over the stream,
    /// LSB first — the content-concentration diagnostic.
    pub bit_entropy: [f64; 16],
    /// Mean of [`Self::bit_entropy`].
    pub mean_entropy: f64,
}

/// Decode one stored image under an explicit `(policy, scheme)` pair —
/// the bucket-level form of [`Encoded::decode_word`].
#[inline]
fn decode_stored(policy: Policy, s: Scheme, stored: u16) -> f32 {
    let v = match policy {
        Policy::Unprotected => fp::f16_bits_to_f32(stored),
        Policy::ZeroSpaceParity => parity::decode_word(stored),
        _ => fp::f16_bits_to_f32(scheme::invert(s, stored)),
    };
    if v.is_finite() {
        v
    } else {
        SATURATE.copysign(v)
    }
}

/// Estimate the fault impact of an encoded (clean) stream at `rate`
/// analytically. Deterministic, RNG-free, and O(distinct words), not
/// O(weights): the heavy quantization of f16 weight tensors makes the
/// census tiny relative to the stream.
pub fn estimate_impact(enc: &Encoded, rate: f64) -> ImpactEstimate {
    let n = enc.len();
    // (scheme symbol, stored word) census. Metadata-free policies have a
    // single implicit NoChange symbol.
    let syms = if enc.policy.has_metadata() { 3 } else { 1 };
    let mut census = vec![0u64; syms << 16];
    let mut bit_counts = [0u64; 16];
    for (i, &w) in enc.words.iter().enumerate() {
        let s = if syms == 1 {
            0
        } else {
            enc.scheme_of(i).symbol() as usize
        };
        census[(s << 16) | w as usize] += 1;
        let mut m = w;
        while m != 0 {
            bit_counts[m.trailing_zeros() as usize] += 1;
            m &= m - 1;
        }
    }

    let mut expected_sse = 0.0f64;
    let mut expected_upsets = 0.0f64;
    let junction_p = rate * 0.5;
    for (key, &count) in census.iter().enumerate() {
        if count == 0 {
            continue;
        }
        let w = (key & 0xFFFF) as u16;
        let s = Scheme::ALL[key >> 16];
        let clean = decode_stored(enc.policy, s, w);
        // Each vulnerable cell fails with probability `rate` and flips its
        // low (soft) or high (hard) junction with probability 1/2 each.
        let mut mask = (w ^ (w >> 1)) & 0x5555;
        while mask != 0 {
            let lo = mask.trailing_zeros();
            for bit in [lo, lo + 1] {
                let hit = decode_stored(enc.policy, s, w ^ (1 << bit));
                if hit != clean {
                    let d = (hit - clean) as f64;
                    expected_sse += count as f64 * junction_p * d * d;
                    expected_upsets += count as f64 * junction_p;
                }
            }
            mask &= mask - 1;
        }
    }

    let mut bit_entropy = [0.0f64; 16];
    if n > 0 {
        for (h, &ones) in bit_entropy.iter_mut().zip(&bit_counts) {
            let p = ones as f64 / n as f64;
            if p > 0.0 && p < 1.0 {
                *h = -(p * p.log2() + (1.0 - p) * (1.0 - p).log2());
            }
        }
    }
    let mean_entropy = bit_entropy.iter().sum::<f64>() / 16.0;
    let predicted_fidelity = if n == 0 {
        1.0
    } else {
        (1.0 - expected_upsets / n as f64).clamp(0.0, 1.0)
    };

    ImpactEstimate {
        policy: enc.policy,
        rate,
        expected_sse,
        expected_upsets,
        predicted_fidelity,
        bit_entropy,
        mean_entropy,
    }
}

/// Convenience wrapper: encode `weights` under `(policy, granularity)` and
/// estimate the impact of faulting that stream at `rate`.
pub fn estimate_policy_impact(
    policy: Policy,
    granularity: usize,
    weights: &[f32],
    rate: f64,
) -> ImpactEstimate {
    let enc = WeightCodec::new(policy, granularity).encode(weights);
    estimate_impact(&enc, rate)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stt::error::ERROR_RATE_HI;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| fp::quantize_f16((i as f32 / n as f32) * 1.8 - 0.9))
            .collect()
    }

    #[test]
    fn zero_rate_predicts_zero_damage() {
        let est = estimate_policy_impact(Policy::Hybrid, 4, &ramp(500), 0.0);
        assert_eq!(est.expected_sse, 0.0);
        assert_eq!(est.expected_upsets, 0.0);
        assert_eq!(est.predicted_fidelity, 1.0);
    }

    #[test]
    fn first_order_estimate_is_linear_in_rate() {
        let ws = ramp(777);
        for policy in Policy::EXTENDED {
            let a = estimate_policy_impact(policy, 4, &ws, 1e-2);
            let b = estimate_policy_impact(policy, 4, &ws, 2e-2);
            assert!(
                (b.expected_sse - 2.0 * a.expected_sse).abs() <= 1e-9 * a.expected_sse.max(1.0),
                "{policy:?}"
            );
            assert!(
                (b.expected_upsets - 2.0 * a.expected_upsets).abs()
                    <= 1e-9 * a.expected_upsets.max(1.0),
                "{policy:?}"
            );
        }
    }

    #[test]
    fn protection_ranks_below_unprotected() {
        let ws = ramp(4096);
        let raw = estimate_policy_impact(Policy::Unprotected, 1, &ws, ERROR_RATE_HI);
        // The paper's scheme suppresses vulnerable cells *and* shields the
        // sign; parity cannot reduce vulnerability but clamps the
        // catastrophic exponent flips. Both must predict less damage.
        for policy in [Policy::Hybrid, Policy::ZeroSpaceParity] {
            let est = estimate_policy_impact(policy, 4, &ws, ERROR_RATE_HI);
            assert!(
                est.expected_sse < raw.expected_sse,
                "{policy:?}: {} vs raw {}",
                est.expected_sse,
                raw.expected_sse
            );
        }
    }

    #[test]
    fn estimator_is_deterministic_and_entropy_bounded() {
        let ws = ramp(1000);
        let a = estimate_policy_impact(Policy::Hybrid, 4, &ws, ERROR_RATE_HI);
        let b = estimate_policy_impact(Policy::Hybrid, 4, &ws, ERROR_RATE_HI);
        assert_eq!(a.expected_sse, b.expected_sse);
        assert_eq!(a.bit_entropy, b.bit_entropy);
        for h in a.bit_entropy {
            assert!((0.0..=1.0).contains(&h), "entropy {h} out of range");
        }
        assert!(a.mean_entropy > 0.0);
    }

    #[test]
    fn empty_stream_is_benign() {
        let est = estimate_policy_impact(Policy::Hybrid, 4, &[], ERROR_RATE_HI);
        assert_eq!(est.expected_sse, 0.0);
        assert_eq!(est.predicted_fidelity, 1.0);
        assert_eq!(est.mean_entropy, 0.0);
    }
}
