//! Experiment-level fault campaigns.
//!
//! The paper's methodology (§6): take the trained weights, push them
//! through the encoder into the MLC buffer, inject soft errors into the
//! *stored images* (write/retention path; `00`/`11` cells immune), then
//! decode and run inference on the corrupted weights — no retraining, since
//! faults happen at inference time and are undetectable.
//!
//! [`FaultCampaign`] packages that flow with explicit seeding so every
//! reported accuracy number is reproducible, plus the Fig. 4 bit-position
//! SSE study. [`estimator`] is the campaign's analytic complement: a
//! census-driven first-order prediction of the same damage, no RNG needed.

pub mod estimator;

pub use estimator::{estimate_impact, estimate_policy_impact, ImpactEstimate};

use crate::encoding::{Encoded, WeightCodec};
use crate::fp;
use crate::stt::ErrorModel;
use crate::util::rng::Xoshiro256;

/// A seeded fault-injection campaign over one weight tensor set.
#[derive(Clone, Debug)]
pub struct FaultCampaign {
    pub error_model: ErrorModel,
    pub seed: u64,
}

impl FaultCampaign {
    pub fn new(error_model: ErrorModel, seed: u64) -> Self {
        FaultCampaign { error_model, seed }
    }

    /// Corrupt an encoded stream in place (write/retention faults) via the
    /// packed geometric-skip sampler (DESIGN.md §8), and report how many
    /// cells actually flipped.
    pub fn inject(&self, enc: &mut Encoded) -> u64 {
        let mut rng = Xoshiro256::seeded(self.seed);
        let (_, cells_flipped) = self.error_model.corrupt_words_write(&mut enc.words, &mut rng);
        cells_flipped
    }

    /// The full §6 pipeline for one tensor: encode -> fault -> decode.
    /// Returns the decoded (possibly corrupted) weights and the flip count.
    pub fn encode_fault_decode(&self, codec: &WeightCodec, weights: &[f32]) -> (Vec<f32>, u64) {
        let mut enc = codec.encode(weights);
        let flips = self.inject(&mut enc);
        (enc.decode(), flips)
    }
}

/// Fig. 4 study: flip a single bit position across a random population of
/// weights in [-1, 1] and measure SSE against the clean values.
///
/// Returns `sse[bit]` for bit = 0 (LSB) .. 15 (sign), over `n` samples.
pub fn bitflip_sse_study(n: usize, seed: u64) -> [f64; 16] {
    let mut rng = Xoshiro256::seeded(seed);
    let mut sse = [0.0f64; 16];
    for _ in 0..n {
        let w = rng.next_f32() * 2.0 - 1.0;
        let h = fp::f32_to_f16_bits(w);
        let clean = fp::f16_bits_to_f32(h);
        for bit in 0..16 {
            let mut corrupted = fp::f16_bits_to_f32(fp::flip_bit(h, bit));
            // Flipping the exponent MSB of a weight with exp=01111 (|w| in
            // [1, 2)) overflows to f16 infinity; saturate to the max finite
            // value so the SSE stays summable (the usual convention in
            // fault-tolerance studies; documented in EXPERIMENTS.md F4).
            if !corrupted.is_finite() {
                corrupted = 65504.0f32.copysign(corrupted);
            }
            let d = (corrupted - clean) as f64;
            sse[bit as usize] += d * d;
        }
    }
    sse
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Policy;
    use crate::stt::error::ERROR_RATE_HI;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| fp::quantize_f16((i as f32 / n as f32) * 1.8 - 0.9))
            .collect()
    }

    #[test]
    fn zero_rate_changes_nothing() {
        let ws = ramp(512);
        let campaign = FaultCampaign::new(ErrorModel::at_rate(0.0), 1);
        let codec = WeightCodec::hybrid(4);
        let (out, flips) = campaign.encode_fault_decode(&codec, &ws);
        assert_eq!(flips, 0);
        // Hybrid may round; compare against the fault-free decode.
        assert_eq!(out, codec.encode(&ws).decode());
    }

    #[test]
    fn campaign_is_reproducible() {
        let ws = ramp(2048);
        let codec = WeightCodec::new(Policy::Unprotected, 1);
        let c1 = FaultCampaign::new(ErrorModel::at_rate(ERROR_RATE_HI), 42);
        let c2 = FaultCampaign::new(ErrorModel::at_rate(ERROR_RATE_HI), 42);
        assert_eq!(
            c1.encode_fault_decode(&codec, &ws).0,
            c2.encode_fault_decode(&codec, &ws).0
        );
        let c3 = FaultCampaign::new(ErrorModel::at_rate(ERROR_RATE_HI), 43);
        assert_ne!(
            c1.encode_fault_decode(&codec, &ws).0,
            c3.encode_fault_decode(&codec, &ws).0
        );
    }

    #[test]
    fn protection_preserves_every_sign() {
        // At an absurd 50% rate, the unprotected stream flips many signs;
        // any sign-protected policy must flip none (cell 0 is a base state).
        let ws = ramp(4096);
        let campaign = FaultCampaign::new(ErrorModel::at_rate(0.5), 7);

        let raw = campaign
            .encode_fault_decode(&WeightCodec::new(Policy::Unprotected, 1), &ws)
            .0;
        let raw_sign_flips = ws
            .iter()
            .zip(&raw)
            .filter(|(a, b)| (a.is_sign_negative() != b.is_sign_negative()) && **a != 0.0)
            .count();
        assert!(raw_sign_flips > 0, "expected sign flips in unprotected run");

        for policy in [Policy::ProtectRound, Policy::ProtectRotate, Policy::Hybrid] {
            let out = campaign
                .encode_fault_decode(&WeightCodec::new(policy, 4), &ws)
                .0;
            let flips = ws
                .iter()
                .zip(&out)
                .filter(|(a, b)| (a.is_sign_negative() != b.is_sign_negative()) && **a != 0.0)
                .count();
            assert_eq!(flips, 0, "{policy:?}");
        }
    }

    #[test]
    fn hybrid_suffers_fewer_flips_than_unprotected() {
        let ws = ramp(8192);
        let campaign = FaultCampaign::new(ErrorModel::at_rate(ERROR_RATE_HI), 11);
        let mut raw = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let mut hyb = WeightCodec::hybrid(1).encode(&ws);
        let raw_flips = campaign.inject(&mut raw);
        let hyb_flips = campaign.inject(&mut hyb);
        assert!(
            hyb_flips < raw_flips,
            "hybrid {hyb_flips} vs raw {raw_flips}"
        );
    }

    #[test]
    fn sse_study_shape_matches_fig4() {
        let sse = bitflip_sse_study(20_000, 3);
        // The paper's conclusion from Fig. 4: the last 4 mantissa bits have
        // negligible impact — that is what licenses the Round scheme.
        let low4: f64 = sse[0..4].iter().sum();
        for high in 10..16 {
            assert!(
                sse[high] > 100.0 * low4,
                "bit {high}: {} vs low4 {low4}",
                sse[high]
            );
        }
        // Bit 14 (exponent MSB / backup bit) dominates everything: flipping
        // it scales |w| by 2^16 — exactly why it may only hold a *copy*.
        for b in 0..14 {
            assert!(sse[14] > sse[b], "bit {b}");
        }
        assert!(sse[14] > sse[15]);
        // Mantissa bits are monotone in significance.
        for b in 0..9 {
            assert!(sse[b] <= sse[b + 1] * 1.01, "bit {b}");
        }
        // Sign-bit SSE has the closed form E[(2w)^2] = 4/3 over U[-1,1].
        let sign_mean = sse[15] / 20_000.0;
        assert!((sign_mean - 4.0 / 3.0).abs() < 0.05, "sign mean {sign_mean}");
    }

    #[test]
    fn sse_study_deterministic() {
        assert_eq!(bitflip_sse_study(1000, 9), bitflip_sse_study(1000, 9));
    }
}
