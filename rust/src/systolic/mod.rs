//! Weight-stationary systolic-array bandwidth/cycle model (SCALE-Sim
//! substitute; paper §6 "Bandwidth Model", Fig. 9).
//!
//! Models the paper's accelerator: an `rows x cols` PE array fed by three
//! double-buffered on-chip buffers (ifmap / weight / ofmap). Convolutions
//! run as im2col GEMMs `out[M, N] = ifmap[M, K] @ w[K, N]` with
//!
//! * `M` = output pixels,  `K` = R*S*C,  `N` = output channels;
//! * weight-stationary folds: `SR = ceil(K / rows)` row folds and
//!   `SC = ceil(N / cols)` column folds; each fold pins an `rows x cols`
//!   weight tile in the array and streams ifmap rows through it;
//! * the ofmap buffer bounds how many output rows (`M_tile`) can accumulate
//!   partial sums across row folds; smaller buffers mean more M-tiles and
//!   therefore more weight-tile reloads from the on-chip buffer — this is
//!   the mechanism by which a larger (MLC STT-RAM) buffer cuts *on-chip*
//!   traffic (paper Fig. 9, right pair of bars);
//! * the ifmap buffer bounds DRAM reuse: if the layer's ifmap does not fit,
//!   it is re-fetched once per column fold — the mechanism by which a
//!   larger buffer cuts *off-chip* traffic (Fig. 9, left pair).
//!
//! All quantities are analytical (SCALE-Sim's closed-form mode): exact
//! element counts over the fold structure, with double buffering assumed to
//! overlap transfers with compute (the paper's buffers are all
//! double-buffered), so cycles are compute-bound.

pub mod dataflow;

use crate::models::ConvLayer;

/// Bytes per stored element (binary16 weights/activations).
pub const BYTES_PER_ELEM: usize = 2;

/// PE array + buffer configuration.
#[derive(Clone, Debug)]
pub struct ArrayConfig {
    /// PE rows (K dimension of a fold).
    pub rows: usize,
    /// PE columns (N dimension of a fold).
    pub cols: usize,
    /// Total on-chip buffer capacity in bytes (split below).
    pub buffer_bytes: usize,
    /// Fraction of the buffer dedicated to the ifmap buffer.
    pub ifmap_frac: f64,
    /// Fraction for the weight buffer.
    pub weight_frac: f64,
    // Remainder goes to the ofmap buffer.
}

impl ArrayConfig {
    /// SCALE-Sim-like defaults: 32x32 array, ifmap 50% / weight 25% /
    /// ofmap 25% buffer split.
    pub fn new(buffer_bytes: usize) -> Self {
        ArrayConfig {
            rows: 32,
            cols: 32,
            buffer_bytes,
            ifmap_frac: 0.5,
            weight_frac: 0.25,
        }
    }

    pub fn ifmap_buffer(&self) -> usize {
        (self.buffer_bytes as f64 * self.ifmap_frac) as usize
    }

    pub fn weight_buffer(&self) -> usize {
        (self.buffer_bytes as f64 * self.weight_frac) as usize
    }

    pub fn ofmap_buffer(&self) -> usize {
        self.buffer_bytes - self.ifmap_buffer() - self.weight_buffer()
    }
}

/// Per-layer simulation result.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    /// GEMM dimensions after im2col.
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Fold structure.
    pub row_folds: usize,
    pub col_folds: usize,
    pub m_tiles: usize,
    /// Compute cycles (double-buffered, transfer-overlapped), including
    /// per-fold array fill/drain overhead.
    pub cycles: u64,
    /// Pure streaming cycles (fold structure only, buffer-independent) —
    /// the denominator of the Fig. 9 "required bandwidth" metric, i.e. the
    /// sustained rate the buffers must supply to keep the array busy.
    pub stream_cycles: u64,
    /// Traffic in bytes.
    pub offchip_read: u64,
    pub offchip_write: u64,
    pub onchip_read: u64,
    pub onchip_write: u64,
}

impl LayerReport {
    pub fn offchip_bytes(&self) -> u64 {
        self.offchip_read + self.offchip_write
    }

    pub fn onchip_bytes(&self) -> u64 {
        self.onchip_read + self.onchip_write
    }

    /// Off-chip bytes per streaming cycle — the Fig. 9 left metric
    /// (required sustained DRAM bandwidth; denominator is buffer-
    /// independent so the series isolates the traffic change).
    pub fn offchip_bpc(&self) -> f64 {
        self.offchip_bytes() as f64 / self.stream_cycles as f64
    }

    /// On-chip bytes per streaming cycle — the Fig. 9 right metric.
    pub fn onchip_bpc(&self) -> f64 {
        self.onchip_bytes() as f64 / self.stream_cycles as f64
    }

    /// MAC utilization: useful MACs / (cycles * PEs).
    pub fn utilization(&self, cfg: &ArrayConfig) -> f64 {
        let macs = self.m as f64 * self.k as f64 * self.n as f64;
        macs / (self.cycles as f64 * (cfg.rows * cfg.cols) as f64)
    }
}

/// Simulate one conv/fc layer on the WS array.
pub fn simulate_layer(layer: &ConvLayer, cfg: &ArrayConfig) -> LayerReport {
    let (m, k, n) = layer.gemm_dims();
    let row_folds = k.div_ceil(cfg.rows);
    let col_folds = n.div_ceil(cfg.cols);

    // --- M tiling: ofmap partials for an M-tile x cols stripe must fit in
    // the ofmap buffer while row folds accumulate into it.
    let stripe_bytes = cfg.cols * BYTES_PER_ELEM;
    let m_tile = (cfg.ofmap_buffer() / stripe_bytes).clamp(1, m.max(1));
    let m_tiles = m.div_ceil(m_tile);

    // --- Cycles: per (m_tile, row fold, col fold): array fill (rows) +
    // stream (tile rows) + drain (rows + cols).
    let folds = (row_folds * col_folds * m_tiles) as u64;
    let fill_drain = (2 * cfg.rows + cfg.cols) as u64;
    let stream: u64 = (row_folds * col_folds) as u64 * m as u64;
    let cycles = folds * fill_drain + stream;

    // --- On-chip traffic (buffer <-> array), in elements first.
    // Weight tile loaded once per fold per M-tile (the Fig. 9 on-chip
    // mechanism: small ofmap buffers force re-loading stationary weights).
    let weight_reads = (k * n) as u64 * m_tiles as u64;
    // Ifmap rows streamed once per column fold.
    let ifmap_reads = (m * k) as u64 * col_folds as u64;
    // Ofmap: every row fold writes a partial stripe; row folds after the
    // first also read the previous partial back for accumulation.
    let ofmap_writes = (m * n) as u64 * row_folds as u64;
    let ofmap_reads = (m * n) as u64 * (row_folds as u64 - 1);
    let onchip_read = (weight_reads + ifmap_reads + ofmap_reads) * BYTES_PER_ELEM as u64;
    let onchip_write = ofmap_writes * BYTES_PER_ELEM as u64;

    // --- Off-chip traffic (DRAM <-> buffer).
    // The scheduler picks the cheaper of the two canonical loop orders:
    //  (a) weight-outer: weights stream once; if the ifmap does not fit its
    //      buffer it re-enters once per column fold;
    //  (b) ifmap-outer: the ifmap streams once in chunks; every chunk needs
    //      all the weights again, so weights re-enter once per ifmap chunk
    //      that exceeds the weight buffer's residency.
    // Large early layers (big ifmap, few weights) pick (a); deep late layers
    // (small ifmap, many weights — VGG16 Conv11-13) pick (b) once the ifmap
    // fits, which is exactly the Fig. 9 off-chip reduction mechanism.
    let weight_elems = (k * n) as u64;
    let ifmap_elems = (layer.h * layer.w * layer.c) as u64;
    let ifmap_fits = ifmap_elems as usize * BYTES_PER_ELEM <= cfg.ifmap_buffer();
    let weights_fit = weight_elems as usize * BYTES_PER_ELEM <= cfg.weight_buffer();

    let order_a = {
        let i = if ifmap_fits {
            ifmap_elems
        } else {
            ifmap_elems * col_folds as u64
        };
        (weight_elems, i)
    };
    let order_b = {
        let ifmap_chunks = (ifmap_elems as usize * BYTES_PER_ELEM)
            .div_ceil(cfg.ifmap_buffer().max(1)) as u64;
        let w = if weights_fit {
            weight_elems
        } else {
            weight_elems * ifmap_chunks
        };
        (w, ifmap_elems)
    };
    let (w_dram, i_dram) = if order_a.0 + order_a.1 <= order_b.0 + order_b.1 {
        order_a
    } else {
        order_b
    };
    // Ofmap leaves once; if the ofmap buffer cannot hold even one stripe
    // across row folds (m_tile == 1 with multiple row folds) partials
    // spill to DRAM and come back.
    let spills = if m_tile == 1 && row_folds > 1 {
        (m * n) as u64 * (row_folds as u64 - 1) * 2
    } else {
        0
    };
    let offchip_read = (w_dram + i_dram + spills / 2) * BYTES_PER_ELEM as u64;
    let offchip_write = ((m * n) as u64 + spills / 2) * BYTES_PER_ELEM as u64;

    LayerReport {
        name: layer.name.clone(),
        m,
        k,
        n,
        row_folds,
        col_folds,
        m_tiles,
        cycles,
        stream_cycles: stream,
        offchip_read,
        offchip_write,
        onchip_read,
        onchip_write,
    }
}

/// Simulate a whole network; returns per-layer reports.
pub fn simulate_network(layers: &[ConvLayer], cfg: &ArrayConfig) -> Vec<LayerReport> {
    layers.iter().map(|l| simulate_layer(l, cfg)).collect()
}

/// The paper's Fig. 9 statistic: the top-`k` layers by the given bandwidth
/// metric (worst-case layers dominate provisioning).
pub fn top_k_by<F: Fn(&LayerReport) -> f64>(
    reports: &[LayerReport],
    k: usize,
    metric: F,
) -> Vec<(String, f64)> {
    let mut xs: Vec<(String, f64)> = reports
        .iter()
        .map(|r| (r.name.clone(), metric(r)))
        .collect();
    xs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    xs.truncate(k);
    xs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ConvLayer;

    fn tiny() -> ConvLayer {
        // 8x8x16 input, 3x3x16x32 kernel, stride 1, same padding.
        ConvLayer::conv("tiny", 8, 8, 16, 32, 3, 1, 1)
    }

    #[test]
    fn gemm_dims_exact() {
        let l = tiny();
        let (m, k, n) = l.gemm_dims();
        assert_eq!((m, k, n), (64, 144, 32));
    }

    #[test]
    fn fold_structure() {
        let r = simulate_layer(&tiny(), &ArrayConfig::new(1 << 20));
        assert_eq!(r.row_folds, 144usize.div_ceil(32));
        assert_eq!(r.col_folds, 1);
        assert_eq!(r.m_tiles, 1); // 64*32*2B = 4 KiB << ofmap buffer
    }

    #[test]
    fn traffic_closed_form_small() {
        let cfg = ArrayConfig::new(1 << 20);
        let l = tiny();
        let r = simulate_layer(&l, &cfg);
        let (m, k, n) = l.gemm_dims();
        // Everything fits: weights once, ifmap once, ofmap once.
        assert_eq!(r.offchip_read as usize, (k * n + 8 * 8 * 16) * 2);
        assert_eq!(r.offchip_write as usize, m * n * 2);
        // On-chip: weights k*n (one M-tile), ifmap m*k (one col fold),
        // ofmap (rf writes + rf-1 reads).
        let rf = r.row_folds as u64;
        let expect_read = (k * n) as u64 + (m * k) as u64 + (m * n) as u64 * (rf - 1);
        assert_eq!(r.onchip_read, expect_read * 2);
        assert_eq!(r.onchip_write, (m * n) as u64 * rf * 2);
    }

    #[test]
    fn bigger_buffer_never_increases_traffic() {
        let l = ConvLayer::conv("mid", 56, 56, 128, 128, 3, 1, 1);
        let mut prev_off = u64::MAX;
        let mut prev_on = u64::MAX;
        for kb in [64usize, 128, 256, 512, 1024, 2048] {
            let r = simulate_layer(&l, &ArrayConfig::new(kb * 1024));
            assert!(r.offchip_bytes() <= prev_off, "{kb} KB off-chip");
            assert!(r.onchip_bytes() <= prev_on, "{kb} KB on-chip");
            prev_off = r.offchip_bytes();
            prev_on = r.onchip_bytes();
        }
    }

    #[test]
    fn cycles_exceed_pure_streaming_bound() {
        let cfg = ArrayConfig::new(256 * 1024);
        let l = tiny();
        let r = simulate_layer(&l, &cfg);
        let stream = (r.row_folds * r.col_folds * r.m) as u64;
        assert!(r.cycles > stream);
        assert!(r.utilization(&cfg) <= 1.0);
        assert!(r.utilization(&cfg) > 0.0);
    }

    #[test]
    fn utilization_improves_with_matched_dims() {
        // A K=32-deep layer fills a 32-row array exactly.
        let cfg = ArrayConfig::new(1 << 20);
        let matched = ConvLayer::fc("m", 32, 32);
        let ragged = ConvLayer::fc("r", 33, 33);
        let um = simulate_layer(&matched, &cfg).utilization(&cfg);
        let ur = simulate_layer(&ragged, &cfg).utilization(&cfg);
        assert!(um > ur);
    }

    #[test]
    fn top_k_sorts_descending() {
        let cfg = ArrayConfig::new(256 * 1024);
        let layers = vec![
            ConvLayer::conv("a", 8, 8, 16, 16, 3, 1, 1),
            ConvLayer::conv("b", 32, 32, 64, 64, 3, 1, 1),
            ConvLayer::conv("c", 16, 16, 32, 32, 3, 1, 1),
        ];
        let reports = simulate_network(&layers, &cfg);
        let top = top_k_by(&reports, 2, |r| r.offchip_bpc());
        assert_eq!(top.len(), 2);
        assert!(top[0].1 >= top[1].1);
    }

    #[test]
    fn buffer_split_sums_to_capacity() {
        let cfg = ArrayConfig::new(1_000_000);
        assert_eq!(
            cfg.ifmap_buffer() + cfg.weight_buffer() + cfg.ofmap_buffer(),
            1_000_000
        );
    }
}
