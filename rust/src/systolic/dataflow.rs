//! Output-stationary (OS) dataflow ablation.
//!
//! The paper (§2.1, citing Eyeriss) lists five dataflow classes and picks
//! weight-stationary "without loss of generality". This module implements
//! the output-stationary alternative so that choice is *checked*, not
//! assumed: under OS each PE pins one output pixel-channel and both ifmap
//! rows and weight columns stream through, so the weight tensor is re-read
//! once per M-fold — which is exactly what makes WS the right choice for a
//! buffer-constrained weight path (the quantity the paper's scheme
//! optimizes).
//!
//! Metrics mirror [`super::simulate_layer`] so the two dataflows are
//! directly comparable per layer.

use super::{ArrayConfig, LayerReport, BYTES_PER_ELEM};
use crate::models::ConvLayer;

/// Simulate one layer under output-stationary mapping.
///
/// Mapping: the `rows x cols` array pins an `rows`-pixel x `cols`-channel
/// output tile; the K dimension streams through the array. Folds:
/// `ceil(M/rows) x ceil(N/cols)`, each streaming all `K` operands.
pub fn simulate_layer_os(layer: &ConvLayer, cfg: &ArrayConfig) -> LayerReport {
    let (m, k, n) = layer.gemm_dims();
    let m_folds = m.div_ceil(cfg.rows);
    let n_folds = n.div_ceil(cfg.cols);
    let folds = (m_folds * n_folds) as u64;

    // Cycles: per fold, K operands stream + fill/drain.
    let fill_drain = (cfg.rows + cfg.cols) as u64;
    let stream = folds * k as u64;
    let cycles = stream + folds * fill_drain;

    // On-chip: each fold reads rows*K ifmap values and K*cols weights and
    // writes rows*cols outputs exactly once (outputs never move until
    // complete — the OS advantage).
    let ifmap_reads = (m_folds * n_folds * cfg.rows.min(m) * k) as u64;
    let weight_reads = (m_folds * n_folds * k * cfg.cols.min(n)) as u64;
    let ofmap_writes = (m * n) as u64;
    let onchip_read = (ifmap_reads + weight_reads) * BYTES_PER_ELEM as u64;
    let onchip_write = ofmap_writes * BYTES_PER_ELEM as u64;

    // Off-chip: ifmap enters once if it fits; weights are consumed once
    // per M-fold group unless the whole tensor fits the weight buffer —
    // the OS weakness on weight-heavy layers.
    let ifmap_elems = (layer.h * layer.w * layer.c) as u64;
    let weight_elems = (k * n) as u64;
    let ifmap_fits = ifmap_elems as usize * BYTES_PER_ELEM <= cfg.ifmap_buffer();
    let weights_fit = weight_elems as usize * BYTES_PER_ELEM <= cfg.weight_buffer();
    let i_dram = if ifmap_fits {
        ifmap_elems
    } else {
        ifmap_elems * n_folds as u64
    };
    let w_dram = if weights_fit {
        weight_elems
    } else {
        weight_elems * m_folds as u64
    };
    let offchip_read = (i_dram + w_dram) * BYTES_PER_ELEM as u64;
    let offchip_write = (m * n) as u64 * BYTES_PER_ELEM as u64;

    LayerReport {
        name: layer.name.clone(),
        m,
        k,
        n,
        row_folds: m_folds,
        col_folds: n_folds,
        m_tiles: 1,
        cycles,
        stream_cycles: stream,
        offchip_read,
        offchip_write,
        onchip_read,
        onchip_write,
    }
}

/// Network-level OS sweep (mirrors [`super::simulate_network`]).
pub fn simulate_network_os(layers: &[ConvLayer], cfg: &ArrayConfig) -> Vec<LayerReport> {
    layers.iter().map(|l| simulate_layer_os(l, cfg)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::systolic::simulate_network;

    fn convs(net: &str) -> Vec<ConvLayer> {
        models::by_name(net)
            .unwrap()
            .into_iter()
            .filter(|l| l.h > 1)
            .collect()
    }

    #[test]
    fn os_writes_each_output_once() {
        let l = ConvLayer::conv("t", 16, 16, 32, 64, 3, 1, 1);
        let cfg = ArrayConfig::new(256 * 1024);
        let r = simulate_layer_os(&l, &cfg);
        let (m, _, n) = l.gemm_dims();
        assert_eq!(r.onchip_write as usize, m * n * 2);
        assert_eq!(r.offchip_write as usize, m * n * 2);
    }

    #[test]
    fn ws_beats_os_on_weight_heavy_layers_with_small_buffers() {
        // VGG16 Conv11 (4.7 MB of weights): OS re-streams weights per
        // M-fold once the tensor exceeds the weight buffer, so WS must
        // move fewer off-chip bytes at SRAM-scale buffers — the paper's
        // implicit justification for the WS baseline.
        let layers = convs("vgg16");
        let cfg = ArrayConfig::new(256 * 1024);
        let ws = simulate_network(&layers, &cfg);
        let os = simulate_network_os(&layers, &cfg);
        let wsr = ws.iter().find(|r| r.name == "Conv11").unwrap();
        let osr = os.iter().find(|r| r.name == "Conv11").unwrap();
        assert!(
            wsr.offchip_bytes() < osr.offchip_bytes(),
            "WS {} vs OS {}",
            wsr.offchip_bytes(),
            osr.offchip_bytes()
        );
    }

    #[test]
    fn os_competitive_on_output_heavy_early_layers() {
        // Conv1 produces a 6.4 MB ofmap from 86 KB of weights: OS's
        // write-once property keeps it within 2x of WS there.
        let layers = convs("vgg16");
        let cfg = ArrayConfig::new(256 * 1024);
        let ws = &simulate_network(&layers, &cfg)[0];
        let os = &simulate_network_os(&layers, &cfg)[0];
        assert!(os.offchip_bytes() < 2 * ws.offchip_bytes());
    }

    #[test]
    fn reports_are_internally_consistent() {
        for net in ["vgg16", "inceptionv3"] {
            let cfg = ArrayConfig::new(1024 * 1024);
            for r in simulate_network_os(&convs(net), &cfg) {
                assert!(r.cycles >= r.stream_cycles);
                assert!(r.offchip_bytes() > 0);
                assert!(r.onchip_bytes() >= r.offchip_write);
                assert!(r.utilization(&cfg) <= 1.0);
            }
        }
    }
}
