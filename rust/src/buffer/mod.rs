//! The on-chip MLC STT-RAM weight buffer.
//!
//! Models the physical resource the paper replaces SRAM with: a banked
//! array of 2-bit MLC cells holding encoded binary16 words, plus a
//! tri-level metadata plane holding one scheme symbol per group. Tracks
//! content-dependent energy and banked latency for every transaction, and
//! applies write-path fault injection exactly once per stored word (the
//! paper's write/retention error model).
//!
//! Capacity semantics: MLC STT-RAM offers ~4x the capacity of SRAM at equal
//! area (paper §1), so configs are usually constructed via
//! [`BufferConfig::sram_equivalent`].

use crate::encoding::{Encoded, Scheme};
use crate::stt::{AccessKind, CostModel, Energy, ErrorModel};
use crate::util::rng::Xoshiro256;
use crate::util::threads;

/// Fixed store-shard size in words. Shard boundaries — and therefore the
/// per-shard RNG seed assignment — depend only on the stream length, never
/// on the worker count, so the injected fault set is bit-identical whether
/// a store runs inline or across any number of threads (pinned by
/// `rust/tests/swar_equivalence.rs`).
pub const STORE_SHARD_WORDS: usize = 1 << 15;

/// Static buffer configuration.
#[derive(Clone, Debug)]
pub struct BufferConfig {
    /// Payload capacity in bytes (each binary16 word takes 8 MLC cells =
    /// 2 logical bytes).
    pub capacity_bytes: usize,
    /// Parallel banks: one word per bank per access slot; latency of a slot
    /// is the max cell latency among its words.
    pub banks: usize,
    pub cost: CostModel,
    pub error_model: ErrorModel,
}

impl BufferConfig {
    pub fn new(capacity_bytes: usize, banks: usize) -> Self {
        assert!(banks >= 1);
        BufferConfig {
            capacity_bytes,
            banks,
            cost: CostModel::default(),
            error_model: ErrorModel::default(),
        }
    }

    /// An MLC buffer occupying the same die area as `sram_bytes` of SRAM
    /// (4x density, paper §1).
    pub fn sram_equivalent(sram_bytes: usize, banks: usize) -> Self {
        Self::new(sram_bytes * 4, banks)
    }

    pub fn with_error_model(mut self, m: ErrorModel) -> Self {
        self.error_model = m;
        self
    }

    pub fn capacity_words(&self) -> usize {
        self.capacity_bytes / 2
    }
}

/// Cumulative transaction statistics.
#[derive(Clone, Debug, Default)]
pub struct AccessStats {
    pub writes: u64,
    pub reads: u64,
    pub write_energy: Energy,
    pub read_energy: Energy,
    pub injected_faults: u64,
}

/// A stored tensor's location + codec context.
#[derive(Clone, Debug)]
pub struct Region {
    pub offset: usize,
    pub len: usize,
    /// Metadata context needed to decode reads from this region.
    pub granularity: usize,
    pub policy: crate::encoding::Policy,
    meta_offset: usize,
    meta_len: usize,
}

/// The buffer itself.
pub struct MlcBuffer {
    pub config: BufferConfig,
    words: Vec<u16>,
    meta: Vec<u8>, // tri-level symbols, one per group
    used_words: usize,
    used_meta: usize,
    stats: AccessStats,
    rng: Xoshiro256,
}

/// Errors surfaced to the coordinator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BufferError {
    CapacityExceeded { requested: usize, free: usize },
    BadRegion,
}

impl std::fmt::Display for BufferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BufferError::CapacityExceeded { requested, free } => {
                write!(f, "capacity exceeded: requested {requested} words, {free} free")
            }
            BufferError::BadRegion => write!(f, "invalid region"),
        }
    }
}

impl std::error::Error for BufferError {}

impl MlcBuffer {
    pub fn new(config: BufferConfig, seed: u64) -> Self {
        let cap = config.capacity_words();
        MlcBuffer {
            config,
            words: vec![0; cap],
            meta: Vec::new(),
            used_words: 0,
            used_meta: 0,
            stats: AccessStats::default(),
            rng: Xoshiro256::seeded(seed),
        }
    }

    pub fn free_words(&self) -> usize {
        self.words.len() - self.used_words
    }

    pub fn stats(&self) -> &AccessStats {
        &self.stats
    }

    /// Reset contents and allocation (stats are kept; call `reset_stats`
    /// separately so experiments can reuse a warm buffer).
    pub fn clear(&mut self) {
        self.used_words = 0;
        self.used_meta = 0;
        self.meta.clear();
    }

    pub fn reset_stats(&mut self) {
        self.stats = AccessStats::default();
    }

    /// Store an encoded stream: bills content-dependent write energy,
    /// applies write-path fault injection to the stored image, and records
    /// the tri-level metadata (fault-free by construction). Large streams
    /// shard across worker threads (see [`STORE_SHARD_WORDS`]).
    pub fn store(&mut self, enc: &Encoded) -> Result<Region, BufferError> {
        self.store_with_threads(enc, threads::auto_workers(enc.len(), STORE_SHARD_WORDS))
    }

    /// [`Self::store`] with an explicit worker count. The stored image,
    /// fault set, and energy accounting are bit-identical for every
    /// `workers` value: each fixed-size shard draws its RNG seed from the
    /// buffer stream in shard order before any worker runs, and per-shard
    /// energy partials are reduced in shard order.
    pub fn store_with_threads(
        &mut self,
        enc: &Encoded,
        workers: usize,
    ) -> Result<Region, BufferError> {
        if enc.len() > self.free_words() {
            return Err(BufferError::CapacityExceeded {
                requested: enc.len(),
                free: self.free_words(),
            });
        }
        let offset = self.used_words;

        let n_shards = enc.len().div_ceil(STORE_SHARD_WORDS);
        let seeds: Vec<u64> = (0..n_shards).map(|_| self.rng.next_u64()).collect();
        let cost = &self.config.cost;
        let model = &self.config.error_model;
        let dst_all = &mut self.words[offset..offset + enc.len()];

        let partials: Vec<(Energy, u64)>;
        if workers <= 1 || n_shards <= 1 {
            partials = enc
                .words
                .chunks(STORE_SHARD_WORDS)
                .zip(dst_all.chunks_mut(STORE_SHARD_WORDS))
                .zip(&seeds)
                .map(|((src, dst), &seed)| store_shard(cost, model, src, dst, seed))
                .collect();
        } else {
            // Hand each worker a contiguous batch of (shard, dst) jobs; the
            // shard index travels with the job so partials can be reduced
            // in shard order afterwards.
            let jobs: Vec<(usize, &[u16], &mut [u16])> = enc
                .words
                .chunks(STORE_SHARD_WORDS)
                .zip(dst_all.chunks_mut(STORE_SHARD_WORDS))
                .enumerate()
                .map(|(k, (src, dst))| (k, src, dst))
                .collect();
            let per_worker = jobs.len().div_ceil(workers.max(1));
            let mut indexed: Vec<(usize, Energy, u64)> = std::thread::scope(|scope| {
                let seeds = &seeds;
                let mut handles = Vec::new();
                let mut it = jobs.into_iter();
                loop {
                    let batch: Vec<_> = it.by_ref().take(per_worker).collect();
                    if batch.is_empty() {
                        break;
                    }
                    handles.push(scope.spawn(move || {
                        batch
                            .into_iter()
                            .map(|(k, src, dst)| {
                                let (e, f) = store_shard(cost, model, src, dst, seeds[k]);
                                (k, e, f)
                            })
                            .collect::<Vec<_>>()
                    }));
                }
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            });
            indexed.sort_unstable_by_key(|&(k, _, _)| k);
            partials = indexed.into_iter().map(|(_, e, f)| (e, f)).collect();
        }

        for (energy, faults) in partials {
            self.stats.write_energy.add(energy);
            self.stats.injected_faults += faults;
        }
        self.used_words += enc.len();
        self.stats.writes += enc.len() as u64;

        let meta_offset = self.used_meta;
        for s in &enc.schemes {
            self.meta.push(s.symbol());
            self.stats
                .write_energy
                .add(self.config.cost.trilevel_cell(AccessKind::Write));
        }
        self.used_meta += enc.schemes.len();

        Ok(Region {
            offset,
            len: enc.len(),
            granularity: enc.granularity,
            policy: enc.policy,
            meta_offset,
            meta_len: enc.schemes.len(),
        })
    }

    /// Read a region back as an `Encoded` view (stored images + schemes),
    /// billing content-dependent read energy with banked latency.
    pub fn load(&mut self, region: &Region) -> Result<Encoded, BufferError> {
        if region.offset + region.len > self.used_words
            || region.meta_offset + region.meta_len > self.used_meta
        {
            return Err(BufferError::BadRegion);
        }
        let mut out = Vec::with_capacity(region.len);
        let mut slot_cycles_total = 0u64;
        let mut nj = 0.0f64;
        for slot in self.words[region.offset..region.offset + region.len]
            .chunks(self.config.banks)
        {
            let mut slot_cycles = 0u64;
            for &w in slot {
                // Read disturbance (off by default) mutates nothing here —
                // the paper ignores it; ablations use `load_with_disturb`.
                let e = self.config.cost.word(w, AccessKind::Read);
                nj += e.nanojoules;
                slot_cycles = slot_cycles.max(e.cycles);
                out.push(w);
            }
            slot_cycles_total += slot_cycles;
        }
        self.stats.read_energy.add(Energy {
            nanojoules: nj,
            cycles: slot_cycles_total,
        });
        self.stats.reads += region.len as u64;

        let mut schemes = Vec::with_capacity(region.meta_len);
        for &sym in &self.meta[region.meta_offset..region.meta_offset + region.meta_len] {
            schemes.push(Scheme::from_symbol(sym).expect("tri-level symbol"));
            self.stats
                .read_energy
                .add(self.config.cost.trilevel_cell(AccessKind::Read));
        }

        Ok(Encoded {
            words: out,
            schemes,
            granularity: region.granularity,
            policy: region.policy,
        })
    }

    /// Ablation path: a read that also applies read-disturb errors to the
    /// stored cells (persistently, as disturbance physically flips them).
    pub fn load_with_disturb(&mut self, region: &Region) -> Result<Encoded, BufferError> {
        for i in region.offset..region.offset + region.len {
            let w = self.words[i];
            let d = self.config.error_model.corrupt_word_read(w, &mut self.rng);
            if d != w {
                self.stats.injected_faults += 1;
                self.words[i] = d;
            }
        }
        self.load(region)
    }
}

/// Write one store shard: bill the energy of programming the *intended*
/// image, then let the write/retention error model corrupt vulnerable
/// cells in the stored copy. Returns `(energy, injected_faults)`.
fn store_shard(
    cost: &CostModel,
    model: &ErrorModel,
    src: &[u16],
    dst: &mut [u16],
    seed: u64,
) -> (Energy, u64) {
    let mut rng = Xoshiro256::seeded(seed);
    let mut energy = Energy::ZERO;
    let mut faults = 0u64;
    for (d, &w) in dst.iter_mut().zip(src) {
        energy.add(cost.word(w, AccessKind::Write));
        let stored = model.corrupt_word_write(w, &mut rng);
        if stored != w {
            faults += 1;
        }
        *d = stored;
    }
    (energy, faults)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::{Policy, WeightCodec};
    use crate::fp;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| fp::quantize_f16((i as f32 / n as f32) * 1.8 - 0.9))
            .collect()
    }

    fn quiet_config(words: usize) -> BufferConfig {
        BufferConfig::new(words * 2, 4).with_error_model(ErrorModel::at_rate(0.0))
    }

    #[test]
    fn store_load_roundtrip_fault_free() {
        let ws = ramp(500);
        let enc = WeightCodec::hybrid(4).encode(&ws);
        let mut buf = MlcBuffer::new(quiet_config(1000), 1);
        let region = buf.store(&enc).unwrap();
        let back = buf.load(&region).unwrap();
        assert_eq!(back.words, enc.words);
        assert_eq!(back.schemes, enc.schemes);
        assert_eq!(back.decode(), enc.decode());
    }

    #[test]
    fn capacity_enforced() {
        let ws = ramp(100);
        let enc = WeightCodec::hybrid(1).encode(&ws);
        let mut buf = MlcBuffer::new(quiet_config(50), 1);
        match buf.store(&enc) {
            Err(BufferError::CapacityExceeded { requested, free }) => {
                assert_eq!(requested, 100);
                assert_eq!(free, 50);
            }
            other => panic!("expected capacity error, got {other:?}"),
        }
    }

    #[test]
    fn sram_equivalent_density() {
        let cfg = BufferConfig::sram_equivalent(256 * 1024, 8);
        assert_eq!(cfg.capacity_bytes, 1024 * 1024);
        assert_eq!(cfg.capacity_words(), 512 * 1024);
    }

    #[test]
    fn write_energy_tracks_content() {
        // All-zero words (8 base cells each) vs alternating (8 soft cells).
        let mut buf = MlcBuffer::new(quiet_config(100), 1);
        let cheap = Encoded {
            words: vec![0x0000; 10],
            schemes: vec![],
            granularity: 1,
            policy: Policy::Unprotected,
        };
        buf.store(&cheap).unwrap();
        let cheap_nj = buf.stats().write_energy.nanojoules;

        let mut buf2 = MlcBuffer::new(quiet_config(100), 1);
        let dear = Encoded {
            words: vec![0x5555; 10],
            schemes: vec![],
            granularity: 1,
            policy: Policy::Unprotected,
        };
        buf2.store(&dear).unwrap();
        let dear_nj = buf2.stats().write_energy.nanojoules;
        assert!((cheap_nj - 10.0 * 8.0 * 1.084).abs() < 1e-9);
        assert!((dear_nj - 10.0 * 8.0 * 2.653).abs() < 1e-9);
    }

    #[test]
    fn banked_read_latency() {
        // 8 all-base words over 4 banks = 2 slots * 14 cycles.
        let mut buf = MlcBuffer::new(quiet_config(100), 1);
        let enc = Encoded {
            words: vec![0xFFFF; 8],
            schemes: vec![],
            granularity: 1,
            policy: Policy::Unprotected,
        };
        let r = buf.store(&enc).unwrap();
        buf.reset_stats();
        buf.load(&r).unwrap();
        assert_eq!(buf.stats().read_energy.cycles, 2 * 14);
    }

    #[test]
    fn fault_injection_counts_and_biases() {
        let ws = ramp(20_000);
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let cfg = BufferConfig::new(50_000 * 2, 4)
            .with_error_model(ErrorModel::at_rate(0.02));
        let mut buf = MlcBuffer::new(cfg, 99);
        let r = buf.store(&enc).unwrap();
        let faults = buf.stats().injected_faults;
        assert!(faults > 0, "expected some injected faults");
        let back = buf.load(&r).unwrap();
        let diff = back
            .words
            .iter()
            .zip(&enc.words)
            .filter(|(a, b)| a != b)
            .count() as u64;
        assert_eq!(diff, faults);
    }

    #[test]
    fn store_identical_across_worker_counts() {
        // Multi-shard stream (> STORE_SHARD_WORDS): the stored image, fault
        // accounting, and energy must not depend on how many threads ran.
        let ws = ramp(STORE_SHARD_WORDS + 5000);
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let cfg = BufferConfig::new(enc.len() * 2, 4)
            .with_error_model(ErrorModel::at_rate(0.02));
        let run = |workers: usize| {
            let mut buf = MlcBuffer::new(cfg.clone(), 0xD15C);
            let r = buf.store_with_threads(&enc, workers).unwrap();
            let words = buf.load(&r).unwrap().words;
            let s = buf.stats();
            (words, s.injected_faults, s.write_energy)
        };
        let (w1, f1, e1) = run(1);
        for workers in [2usize, 3, 8] {
            let (wn, fn_, en) = run(workers);
            assert_eq!(w1, wn, "workers={workers}");
            assert_eq!(f1, fn_, "workers={workers}");
            assert_eq!(e1, en, "workers={workers}");
        }
        assert!(f1 > 0);
    }

    #[test]
    fn metadata_survives_faults() {
        // Metadata plane is tri-level: fault-free even at rate 1.
        let ws = ramp(512);
        let enc = WeightCodec::hybrid(2).encode(&ws);
        let cfg = BufferConfig::new(2048, 2).with_error_model(ErrorModel::at_rate(1.0));
        let mut buf = MlcBuffer::new(cfg, 5);
        let r = buf.store(&enc).unwrap();
        let back = buf.load(&r).unwrap();
        assert_eq!(back.schemes, enc.schemes);
    }

    #[test]
    fn multiple_regions_do_not_alias() {
        let a = WeightCodec::hybrid(1).encode(&ramp(64));
        let b = WeightCodec::hybrid(4).encode(&ramp(128)[64..].to_vec());
        let mut buf = MlcBuffer::new(quiet_config(1024), 1);
        let ra = buf.store(&a).unwrap();
        let rb = buf.store(&b).unwrap();
        assert_eq!(buf.load(&ra).unwrap().words, a.words);
        assert_eq!(buf.load(&rb).unwrap().words, b.words);
        assert_eq!(ra.offset + ra.len, rb.offset);
    }

    #[test]
    fn bad_region_rejected() {
        let mut buf = MlcBuffer::new(quiet_config(10), 1);
        let bogus = Region {
            offset: 0,
            len: 5,
            granularity: 1,
            policy: Policy::Hybrid,
            meta_offset: 0,
            meta_len: 5,
        };
        assert_eq!(buf.load(&bogus).unwrap_err(), BufferError::BadRegion);
    }

    #[test]
    fn clear_releases_capacity() {
        let enc = WeightCodec::hybrid(1).encode(&ramp(100));
        let mut buf = MlcBuffer::new(quiet_config(100), 1);
        buf.store(&enc).unwrap();
        assert_eq!(buf.free_words(), 0);
        buf.clear();
        assert_eq!(buf.free_words(), 100);
        buf.store(&enc).unwrap();
    }

    #[test]
    fn read_disturb_ablation_persists_flips() {
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ramp(8192));
        let cfg = BufferConfig::new(8192 * 2, 4)
            .with_error_model(ErrorModel::new(0.0, 0.05));
        let mut buf = MlcBuffer::new(cfg, 17);
        let r = buf.store(&enc).unwrap();
        assert_eq!(buf.stats().injected_faults, 0); // write path clean
        let first = buf.load_with_disturb(&r).unwrap();
        assert!(buf.stats().injected_faults > 0);
        // The disturbance is persistent: a plain load now sees the flips.
        let second = buf.load(&r).unwrap();
        assert_eq!(first.words, second.words);
    }
}
