//! The on-chip MLC STT-RAM weight buffer.
//!
//! Models the physical resource the paper replaces SRAM with: a banked
//! array of 2-bit MLC cells holding encoded binary16 words, plus a
//! tri-level metadata plane holding one scheme symbol per group. Tracks
//! content-dependent energy and banked latency for every transaction, and
//! applies write-path fault injection exactly once per stored word (the
//! paper's write/retention error model).
//!
//! Capacity semantics: MLC STT-RAM offers ~4x the capacity of SRAM at equal
//! area (paper §1), so configs are usually constructed via
//! [`BufferConfig::sram_equivalent`].

use std::sync::mpsc;

use crate::encoding::{codec, Encoded, Policy, ProtectionPolicy, Scheme};
use crate::stt::{AccessKind, CostModel, Energy, ErrorModel};
use crate::util::rng::Xoshiro256;
use crate::util::threads;

pub mod shared;

/// Fixed store-shard size in words. Shard boundaries — and therefore the
/// per-shard RNG seed assignment — depend only on the stream length, never
/// on the worker count, so the injected fault set is bit-identical whether
/// a store runs inline or across any number of threads (pinned by
/// `rust/tests/swar_equivalence.rs`).
pub const STORE_SHARD_WORDS: usize = 1 << 15;

/// Fixed load-shard size in words. Like [`STORE_SHARD_WORDS`], boundaries
/// depend only on the region length — never on the worker count or the
/// bank geometry — so threaded reads bill bit-identical energy and cycles
/// for any `MLCSTT_THREADS` value. A bank slot that straddles a shard
/// boundary is handled by the shard-carry rule in
/// [`MlcBuffer::load_with_threads`] (DESIGN.md §8).
pub const LOAD_SHARD_WORDS: usize = 1 << 15;

/// Static buffer configuration.
#[derive(Clone, Debug)]
pub struct BufferConfig {
    /// Payload capacity in bytes (each binary16 word takes 8 MLC cells =
    /// 2 logical bytes).
    pub capacity_bytes: usize,
    /// Parallel banks: one word per bank per access slot; latency of a slot
    /// is the max cell latency among its words.
    pub banks: usize,
    /// Per-cell access cost table (paper Table 4).
    pub cost: CostModel,
    /// Write/retention + read-disturb soft-error model.
    pub error_model: ErrorModel,
}

impl BufferConfig {
    /// A buffer of `capacity_bytes` payload across `banks` parallel banks
    /// with the paper's default cost table and error model.
    pub fn new(capacity_bytes: usize, banks: usize) -> Self {
        assert!(banks >= 1);
        BufferConfig {
            capacity_bytes,
            banks,
            cost: CostModel::default(),
            error_model: ErrorModel::default(),
        }
    }

    /// An MLC buffer occupying the same die area as `sram_bytes` of SRAM
    /// (4x density, paper §1).
    pub fn sram_equivalent(sram_bytes: usize, banks: usize) -> Self {
        Self::new(sram_bytes * 4, banks)
    }

    /// Builder-style error-model override.
    pub fn with_error_model(mut self, m: ErrorModel) -> Self {
        self.error_model = m;
        self
    }

    /// Payload capacity in binary16 words (2 logical bytes each).
    pub fn capacity_words(&self) -> usize {
        self.capacity_bytes / 2
    }
}

/// Cumulative transaction statistics.
#[derive(Clone, Debug, Default)]
pub struct AccessStats {
    /// Words written across all stores.
    pub writes: u64,
    /// Words read across all loads.
    pub reads: u64,
    /// Content-dependent energy + banked latency billed on the write path.
    pub write_energy: Energy,
    /// Content-dependent energy + banked latency billed on the read path.
    pub read_energy: Energy,
    /// Words corrupted by fault injection (write path and disturb reads).
    pub injected_faults: u64,
}

/// Point-in-time image of the buffer's stored payload + accounting, for
/// sweep-scale snapshot/re-inject fault campaigns (DESIGN.md §9). Taken
/// by [`MlcBuffer::snapshot`], rewound by [`MlcBuffer::restore`].
#[derive(Clone, Debug)]
pub struct BufferSnapshot {
    words: Vec<u16>,
    stats: AccessStats,
}

/// A stored tensor's location + codec context.
#[derive(Clone, Debug)]
pub struct Region {
    /// First payload word of the region.
    pub offset: usize,
    /// Region length in words.
    pub len: usize,
    /// Metadata context needed to decode reads from this region.
    pub granularity: usize,
    /// Encoding policy the region was stored under.
    pub policy: crate::encoding::Policy,
    meta_offset: usize,
    meta_len: usize,
}

/// The buffer itself.
pub struct MlcBuffer {
    /// Static geometry, cost table, and error model.
    pub config: BufferConfig,
    words: Vec<u16>,
    meta: Vec<u8>, // tri-level symbols, one per group
    used_words: usize,
    used_meta: usize,
    stats: AccessStats,
    rng: Xoshiro256,
}

/// Errors surfaced to the coordinator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BufferError {
    /// A store asked for more free words than the buffer has left.
    CapacityExceeded {
        /// Words the store needed.
        requested: usize,
        /// Words actually free.
        free: usize,
    },
    /// A load named a region outside the current allocation.
    BadRegion,
}

impl std::fmt::Display for BufferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BufferError::CapacityExceeded { requested, free } => {
                write!(f, "capacity exceeded: requested {requested} words, {free} free")
            }
            BufferError::BadRegion => write!(f, "invalid region"),
        }
    }
}

impl std::error::Error for BufferError {}

impl MlcBuffer {
    /// An empty buffer; `seed` drives all fault-injection randomness
    /// (per-shard stream seeds derive from it in shard order).
    pub fn new(config: BufferConfig, seed: u64) -> Self {
        let cap = config.capacity_words();
        MlcBuffer {
            config,
            words: vec![0; cap],
            meta: Vec::new(),
            used_words: 0,
            used_meta: 0,
            stats: AccessStats::default(),
            rng: Xoshiro256::seeded(seed),
        }
    }

    /// Unallocated payload words remaining.
    pub fn free_words(&self) -> usize {
        self.words.len() - self.used_words
    }

    /// Cumulative transaction statistics since the last `reset_stats`.
    pub fn stats(&self) -> &AccessStats {
        &self.stats
    }

    /// Reset contents and allocation (stats are kept; call `reset_stats`
    /// separately so experiments can reuse a warm buffer).
    pub fn clear(&mut self) {
        self.used_words = 0;
        self.used_meta = 0;
        self.meta.clear();
    }

    /// Zero the cumulative statistics (contents untouched).
    pub fn reset_stats(&mut self) {
        self.stats = AccessStats::default();
    }

    /// Store an encoded stream: bills content-dependent write energy,
    /// applies write-path fault injection to the stored image, and records
    /// the tri-level metadata (fault-free by construction). Large streams
    /// shard across worker threads (see [`STORE_SHARD_WORDS`]).
    pub fn store(&mut self, enc: &Encoded) -> Result<Region, BufferError> {
        self.store_with_threads(enc, threads::auto_workers(enc.len(), STORE_SHARD_WORDS))
    }

    /// [`Self::store`] with an explicit worker count. The stored image,
    /// fault set, and energy accounting are bit-identical for every
    /// `workers` value: each fixed-size shard draws its RNG seed from the
    /// buffer stream in shard order before any worker runs, and per-shard
    /// energy partials are reduced in shard order.
    pub fn store_with_threads(
        &mut self,
        enc: &Encoded,
        workers: usize,
    ) -> Result<Region, BufferError> {
        if enc.len() > self.free_words() {
            return Err(BufferError::CapacityExceeded {
                requested: enc.len(),
                free: self.free_words(),
            });
        }
        let offset = self.used_words;

        let n_shards = enc.len().div_ceil(STORE_SHARD_WORDS);
        let seeds: Vec<u64> = (0..n_shards).map(|_| self.rng.next_u64()).collect();
        let cost = &self.config.cost;
        let model = &self.config.error_model;
        let dst_all = &mut self.words[offset..offset + enc.len()];

        // One job per shard; run_sharded returns partials in shard order,
        // so the reduction below is worker-count-invariant.
        let jobs: Vec<(usize, &[u16], &mut [u16])> = enc
            .words
            .chunks(STORE_SHARD_WORDS)
            .zip(dst_all.chunks_mut(STORE_SHARD_WORDS))
            .enumerate()
            .map(|(k, (src, dst))| (k, src, dst))
            .collect();
        let partials = threads::run_sharded(jobs, workers, |(k, src, dst)| {
            store_shard(cost, model, src, dst, seeds[k])
        });

        for (energy, faults) in partials {
            self.stats.write_energy.add(energy);
            self.stats.injected_faults += faults;
        }
        self.used_words += enc.len();
        self.stats.writes += enc.len() as u64;

        let meta_offset = self.used_meta;
        for s in &enc.schemes {
            self.meta.push(s.symbol());
            self.stats
                .write_energy
                .add(self.config.cost.trilevel_cell(AccessKind::Write));
        }
        self.used_meta += enc.schemes.len();

        Ok(Region {
            offset,
            len: enc.len(),
            granularity: enc.granularity,
            policy: enc.policy,
            meta_offset,
            meta_len: enc.schemes.len(),
        })
    }

    /// Read a region back as an `Encoded` view (stored images + schemes),
    /// billing content-dependent read energy with banked latency. Large
    /// regions shard across worker threads (see [`LOAD_SHARD_WORDS`]).
    pub fn load(&mut self, region: &Region) -> Result<Encoded, BufferError> {
        self.load_with_threads(region, threads::auto_workers(region.len, LOAD_SHARD_WORDS))
    }

    /// [`Self::load`] with an explicit worker count. The returned words,
    /// read-energy bill, and banked cycle count are bit-identical for
    /// every `workers` value: shard boundaries sit at fixed multiples of
    /// [`LOAD_SHARD_WORDS`] (data-dependent only), energy partials reduce
    /// in shard order, and a bank slot that straddles a shard boundary is
    /// stitched back together by the shard-carry rule (the open slot's
    /// running max travels with the reduction; DESIGN.md §8).
    pub fn load_with_threads(
        &mut self,
        region: &Region,
        workers: usize,
    ) -> Result<Encoded, BufferError> {
        self.check_region(region)?;
        let banks = self.config.banks;
        let cost = &self.config.cost;
        let src_all = &self.words[region.offset..region.offset + region.len];
        let mut out = vec![0u16; region.len];

        // One job per fixed-size shard; run_sharded returns partials in
        // shard order, which the carry-rule reduction below requires.
        let jobs: Vec<(usize, &[u16], &mut [u16])> = src_all
            .chunks(LOAD_SHARD_WORDS)
            .zip(out.chunks_mut(LOAD_SHARD_WORDS))
            .enumerate()
            .map(|(k, (src, dst))| (k, src, dst))
            .collect();
        let partials = threads::run_sharded(jobs, workers, |(k, src, dst)| {
            load_shard(cost, src, dst, k * LOAD_SHARD_WORDS, banks)
        });

        self.stats.read_energy.add(reduce_load_partials(&partials));
        self.stats.reads += region.len as u64;

        let mut schemes = Vec::with_capacity(region.meta_len);
        for &sym in &self.meta[region.meta_offset..region.meta_offset + region.meta_len] {
            schemes.push(Scheme::from_symbol(sym).expect("tri-level symbol"));
            self.stats
                .read_energy
                .add(self.config.cost.trilevel_cell(AccessKind::Read));
        }

        Ok(Encoded {
            words: out,
            schemes,
            granularity: region.granularity,
            policy: region.policy,
        })
    }

    /// Ablation path: a read that also applies read-disturb errors to the
    /// stored cells (persistently, as disturbance physically flips them).
    pub fn load_with_disturb(&mut self, region: &Region) -> Result<Encoded, BufferError> {
        self.load_with_disturb_threads(
            region,
            threads::auto_workers(region.len, LOAD_SHARD_WORDS),
        )
    }

    /// [`Self::load_with_disturb`] with an explicit worker count. Like the
    /// store path, each fixed-size shard draws its RNG seed from the buffer
    /// stream *in shard order before any worker runs*, so the disturbed
    /// image and fault count are bit-identical for every `workers` value.
    /// At the default `read_disturb_rate` of 0 this is exactly a plain
    /// load: no RNG state is consumed, so a later stochastic store sees
    /// the same seed stream either way.
    pub fn load_with_disturb_threads(
        &mut self,
        region: &Region,
        workers: usize,
    ) -> Result<Encoded, BufferError> {
        self.check_region(region)?;
        if self.config.error_model.read_disturb_rate == 0.0 {
            return self.load_with_threads(region, workers);
        }
        let n_shards = region.len.div_ceil(LOAD_SHARD_WORDS);
        let seeds: Vec<u64> = (0..n_shards).map(|_| self.rng.next_u64()).collect();
        let model = &self.config.error_model;
        let words = &mut self.words[region.offset..region.offset + region.len];

        let jobs: Vec<(usize, &mut [u16])> =
            words.chunks_mut(LOAD_SHARD_WORDS).enumerate().collect();
        let faults: u64 = threads::run_sharded(jobs, workers, |(k, shard)| {
            disturb_shard(model, shard, seeds[k])
        })
        .into_iter()
        .sum();
        self.stats.injected_faults += faults;
        self.load_with_threads(region, workers)
    }

    /// Snapshot the allocated payload words and cumulative statistics —
    /// the sweep-campaign capture point (DESIGN.md §9). Allocation state
    /// (regions, the fault-free metadata plane) is *not* captured:
    /// [`Self::restore`] only rewinds contents and accounting, so every
    /// existing [`Region`] handle stays valid across restore cycles.
    pub fn snapshot(&self) -> BufferSnapshot {
        BufferSnapshot {
            words: self.words[..self.used_words].to_vec(),
            stats: self.stats.clone(),
        }
    }

    /// Restore payload contents and statistics from a snapshot taken on
    /// this buffer (the allocation must be unchanged) and reseed the
    /// fault RNG. Afterwards the buffer is bit-identical — contents,
    /// accounting, and future fault randomness — to a fresh buffer
    /// seeded with `seed` that just performed the snapshot's stores.
    pub fn restore(&mut self, snap: &BufferSnapshot, seed: u64) {
        assert_eq!(
            snap.words.len(),
            self.used_words,
            "snapshot does not match the current allocation"
        );
        self.words[..self.used_words].copy_from_slice(&snap.words);
        self.stats = snap.stats.clone();
        self.rng = Xoshiro256::seeded(seed);
    }

    /// Re-inject write-path faults into a stored region in place, exactly
    /// as [`Self::store`] would have: one RNG seed per fixed
    /// [`STORE_SHARD_WORDS`] shard, drawn from the buffer stream in shard
    /// order before any worker runs, then the packed geometric-skip
    /// sampler per shard. After [`Self::restore`] with the same seed, a
    /// region-ordered sequence of these calls reproduces a fresh
    /// store-at-rate run's flip sets bit-for-bit (pinned by
    /// `rust/tests/sweep_equivalence.rs`). Returns words changed.
    pub fn corrupt_region_write(
        &mut self,
        region: &Region,
        model: &ErrorModel,
        workers: usize,
    ) -> Result<u64, BufferError> {
        Ok(self
            .corrupt_region_write_shards(region, model, workers)?
            .iter()
            .sum())
    }

    /// [`Self::corrupt_region_write`] reporting the flip count of **each**
    /// fixed-size shard instead of the region total. Same seed stream,
    /// same sampler, same stored image — callers that keep the vector can
    /// later skip shards whose count is zero (the shard-grain flip-skip in
    /// `WeightStore::materialize_reusing` and the scrub cursor, DESIGN.md
    /// §15) while bit-identity to the summed variant is trivially
    /// preserved.
    pub fn corrupt_region_write_shards(
        &mut self,
        region: &Region,
        model: &ErrorModel,
        workers: usize,
    ) -> Result<Vec<u64>, BufferError> {
        self.check_region(region)?;
        let n_shards = region.len.div_ceil(STORE_SHARD_WORDS);
        let seeds: Vec<u64> = (0..n_shards).map(|_| self.rng.next_u64()).collect();
        let words = &mut self.words[region.offset..region.offset + region.len];

        let jobs: Vec<(usize, &mut [u16])> =
            words.chunks_mut(STORE_SHARD_WORDS).enumerate().collect();
        let per_shard: Vec<u64> = threads::run_sharded(jobs, workers, |(k, shard)| {
            let mut rng = Xoshiro256::seeded(seeds[k]);
            let (words_changed, _) = model.corrupt_words_write(shard, &mut rng);
            words_changed
        });
        self.stats.injected_faults += per_shard.iter().sum::<u64>();
        Ok(per_shard)
    }

    /// Read a region and decode it straight to f32 — the serve path's
    /// fused load→decode (DESIGN.md §9). Bills read energy and banked
    /// latency bit-identically to [`Self::load_with_threads`] (same
    /// fixed-shard partials, same shard-order carry-rule reduction, same
    /// metadata billing order) and produces bit-identical floats to
    /// [`Encoded::decode_into_threaded`].
    ///
    /// With `workers >= 2` and a multi-shard region the two stages
    /// overlap in a double-buffered pipeline: a scoped decoder thread
    /// decodes shard `k` while this thread copies and bills shard `k+1`;
    /// two recycled shard buffers bound the pipeline depth. Otherwise
    /// both stages run serially inline.
    ///
    /// Returns the **payload-word** energy partial that was billed (the
    /// single [`Energy`] added to the read stats before the per-group
    /// metadata charges). Because stored content alone determines it, a
    /// caller that knows a region's bytes are unchanged can replay the
    /// identical bill through [`Self::replay_region_read`] without
    /// re-reading — the flip-set-aware sweep materialize (DESIGN.md §10).
    pub fn load_decoded(
        &mut self,
        region: &Region,
        out: &mut Vec<f32>,
        workers: usize,
    ) -> Result<Energy, BufferError> {
        self.check_region(region)?;
        // Length-change-only resize: every slot is overwritten below.
        if out.len() != region.len {
            out.resize(region.len, 0.0);
        }
        // The decode stage needs the scheme table up front; its read is
        // *billed* after the word energy, in load order, exactly like
        // `load_with_threads`.
        let meta = &self.meta[region.meta_offset..region.meta_offset + region.meta_len];
        let schemes: Vec<Scheme> = meta
            .iter()
            .map(|&sym| Scheme::from_symbol(sym).expect("tri-level symbol"))
            .collect();
        let n_shards = region.len.div_ceil(LOAD_SHARD_WORDS);
        let banks = self.config.banks;
        let cost = &self.config.cost;
        let src_all = &self.words[region.offset..region.offset + region.len];

        let energy = if workers >= 2 && n_shards >= 2 {
            let mut partials = Vec::with_capacity(n_shards);
            let policy = region.policy;
            let granularity = region.granularity;
            let region_len = region.len;
            let dst: &mut [f32] = out;
            std::thread::scope(|scope| {
                // Depth-1 forward channel + two pre-seeded recycle buffers
                // = the double-buffer rule: one shard decoding, one being
                // copied/billed, never more.
                let (tx, rx) = mpsc::sync_channel::<(usize, Vec<u16>)>(1);
                let (recycle_tx, recycle_rx) = mpsc::channel::<Vec<u16>>();
                for _ in 0..2 {
                    recycle_tx.send(Vec::new()).expect("receiver alive");
                }
                let decoder = scope.spawn(move || {
                    decode_pipeline_stage(
                        policy,
                        granularity,
                        region_len,
                        &schemes,
                        rx,
                        recycle_tx,
                        dst,
                    );
                });
                for (k, src) in src_all.chunks(LOAD_SHARD_WORDS).enumerate() {
                    let mut buf = recycle_rx.recv().expect("decoder alive");
                    buf.resize(src.len(), 0);
                    partials.push(load_shard(cost, src, &mut buf, k * LOAD_SHARD_WORDS, banks));
                    tx.send((k * LOAD_SHARD_WORDS, buf)).expect("decoder alive");
                }
                drop(tx);
                decoder.join().expect("decoder thread");
            });
            reduce_load_partials(&partials)
        } else {
            // Serial fallback: bill-and-copy every shard, then decode the
            // whole region in one group-aligned pass.
            let mut words = vec![0u16; region.len];
            let partials: Vec<LoadPartial> = src_all
                .chunks(LOAD_SHARD_WORDS)
                .zip(words.chunks_mut(LOAD_SHARD_WORDS))
                .enumerate()
                .map(|(k, (src, dst))| load_shard(cost, src, dst, k * LOAD_SHARD_WORDS, banks))
                .collect();
            codec::decode_slice(region.policy, region.granularity, &schemes, 0, &words, out);
            reduce_load_partials(&partials)
        };

        self.stats.read_energy.add(energy);
        self.stats.reads += region.len as u64;
        for _ in 0..region.meta_len {
            self.stats
                .read_energy
                .add(self.config.cost.trilevel_cell(AccessKind::Read));
        }
        Ok(energy)
    }

    /// Bill a region read **without touching the words** — the fast half
    /// of the flip-set-aware sweep materialize (DESIGN.md §10).
    /// `words_energy` must be the payload partial a previous
    /// [`Self::load_decoded`] of this region returned *while the region
    /// held bit-identical content*; this method then replays the exact
    /// accounting sequence of a real load (one payload add, then one
    /// tri-level metadata add per group, in order), so cumulative stats —
    /// including f64 nanojoule association — are bit-identical to having
    /// re-read the region. Soundness rests on the caller's
    /// content-unchanged guarantee; `WeightStore::materialize_reusing`
    /// establishes it from per-region flip counts.
    pub fn replay_region_read(
        &mut self,
        region: &Region,
        words_energy: Energy,
    ) -> Result<(), BufferError> {
        self.check_region(region)?;
        self.stats.read_energy.add(words_energy);
        self.stats.reads += region.len as u64;
        for _ in 0..region.meta_len {
            self.stats
                .read_energy
                .add(self.config.cost.trilevel_cell(AccessKind::Read));
        }
        Ok(())
    }

    /// FNV-1a 64 checksum of each fixed-size shard of a stored region —
    /// the scrub cursor's view of what the region holds *now*. Boundaries
    /// are the same [`LOAD_SHARD_WORDS`] multiples every other shard walk
    /// uses, so these compare index-for-index against the golden vector
    /// [`shard_checksums`] computes from a clean encoded image.
    pub fn region_shard_checksums(&self, region: &Region) -> Result<Vec<u64>, BufferError> {
        self.check_region(region)?;
        Ok(shard_checksums(
            &self.words[region.offset..region.offset + region.len],
        ))
    }

    /// One scrub pass over a stored region (DESIGN.md §15): walk it in
    /// [`LOAD_SHARD_WORDS`] steps, bill the scan as one region read (same
    /// fixed-shard partials and shard-order carry-rule reduction as
    /// [`Self::load_with_threads`], payload plane only — the tri-level
    /// metadata plane is fault-free by construction and is not scanned),
    /// compare each shard's FNV-1a checksum against `golden`, and rewrite
    /// every dirty shard from `clean` through the store path's per-word
    /// billing (shard-order energy adds, no fault injection, **no RNG
    /// draws** — the buffer's seed stream is untouched, so later
    /// stochastic stores and rebuild replays stay bit-identical whether
    /// or not a scrub ran in between).
    ///
    /// `policy` supplies the in-word telemetry channel: its
    /// [`ProtectionPolicy::detect`] verdict is counted per scanned word
    /// (parity / sign-pair disagreement), rank-checkable against the
    /// authoritative checksum detection.
    pub fn scrub_region(
        &mut self,
        region: &Region,
        clean: &[u16],
        golden: &[u64],
        policy: &dyn ProtectionPolicy,
    ) -> Result<RegionScrub, BufferError> {
        self.check_region(region)?;
        let n_shards = region.len.div_ceil(LOAD_SHARD_WORDS);
        if clean.len() != region.len || golden.len() != n_shards {
            return Err(BufferError::BadRegion);
        }
        let banks = self.config.banks;
        let cost = &self.config.cost;
        let words = &mut self.words[region.offset..region.offset + region.len];

        let mut scratch = vec![0u16; LOAD_SHARD_WORDS.min(region.len.max(1))];
        let mut read_partials = Vec::with_capacity(n_shards);
        let mut scrub = RegionScrub::new(banks);
        scrub.scrubbed_words = region.len as u64;
        for (k, (stored, clean_shard)) in words
            .chunks_mut(LOAD_SHARD_WORDS)
            .zip(clean.chunks(LOAD_SHARD_WORDS))
            .enumerate()
        {
            let start = k * LOAD_SHARD_WORDS;
            // Scan: a real shard read (copy-out + per-word billing).
            read_partials.push(load_shard(
                cost,
                stored,
                &mut scratch[..stored.len()],
                start,
                banks,
            ));
            for (i, &w) in stored.iter().enumerate() {
                scrub.scrubbed_per_bank[(start + i) % banks] += 1;
                if policy.detect(w) {
                    scrub.policy_detected += 1;
                }
            }
            // Detect: golden checksum disagreement is authoritative.
            if fnv_words(stored) == golden[k] {
                continue;
            }
            scrub.dirty_shards += 1;
            for (i, (&s, &c)) in stored.iter().zip(clean_shard).enumerate() {
                if s != c {
                    scrub.corrected_words += 1;
                    let x = s ^ c;
                    // Junction flips turn intermediate cells into base
                    // states: a changed cell shows in one (or both) of its
                    // two bit positions.
                    let cells = u64::from(((x | (x >> 1)) & 0x5555u16).count_ones());
                    scrub.corrected_cells += cells;
                    scrub.corrected_per_bank[(start + i) % banks] += cells;
                }
            }
            // Repair: rewrite the whole shard from the clean image with
            // the store path's content-dependent per-word write billing.
            let mut energy = Energy::ZERO;
            for &c in clean_shard {
                energy.add(cost.word(c, AccessKind::Write));
            }
            stored.copy_from_slice(clean_shard);
            scrub.rewritten_words += stored.len() as u64;
            scrub.write_shards.push((k, energy));
        }

        scrub.read_energy = reduce_load_partials(&read_partials);
        self.stats.read_energy.add(scrub.read_energy);
        self.stats.reads += region.len as u64;
        for &(_, energy) in &scrub.write_shards {
            self.stats.write_energy.add(energy);
        }
        self.stats.writes += scrub.rewritten_words;
        Ok(scrub)
    }

    /// Clean-image read partials of a region, computed **without billing**
    /// — the capture half of the shard-grain flip-skip (DESIGN.md §10).
    /// Stored content alone determines each partial, so as long as a shard
    /// later proves flip-free its cached partial replays the exact bill a
    /// fresh read of it would produce.
    pub(crate) fn region_load_partials(
        &self,
        region: &Region,
    ) -> Result<Vec<LoadPartial>, BufferError> {
        self.check_region(region)?;
        let banks = self.config.banks;
        let cost = &self.config.cost;
        let src_all = &self.words[region.offset..region.offset + region.len];
        let mut scratch = vec![0u16; LOAD_SHARD_WORDS.min(region.len.max(1))];
        Ok(src_all
            .chunks(LOAD_SHARD_WORDS)
            .enumerate()
            .map(|(k, src)| {
                load_shard(
                    cost,
                    src,
                    &mut scratch[..src.len()],
                    k * LOAD_SHARD_WORDS,
                    banks,
                )
            })
            .collect())
    }

    /// Shard-grain twin of [`Self::load_decoded`]: decode only the shards
    /// `shard_flips` marks dirty, replaying `clean_partials` + `clean_f32`
    /// for the rest. The bill — one payload [`Energy`] from the full
    /// shard-order carry-rule reduction, then the per-group metadata
    /// charges — is bit-identical to a fresh full read because a clean
    /// shard's cached partial equals what re-reading it would compute,
    /// and the reduction order is unchanged. Dirty shards decode over
    /// their group-aligned hull (a group straddling a shard boundary pulls
    /// in up to `granularity - 1` neighbouring clean words, which decode
    /// to the same floats the clean cache already holds).
    pub(crate) fn load_decoded_reusing(
        &mut self,
        region: &Region,
        clean_partials: &[LoadPartial],
        shard_flips: &[u64],
        clean_f32: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<Energy, BufferError> {
        self.check_region(region)?;
        let n_shards = region.len.div_ceil(LOAD_SHARD_WORDS);
        if clean_partials.len() != n_shards
            || shard_flips.len() != n_shards
            || clean_f32.len() != region.len
        {
            return Err(BufferError::BadRegion);
        }
        if out.len() != region.len {
            out.resize(region.len, 0.0);
        }
        let schemes: Vec<Scheme> = self.meta
            [region.meta_offset..region.meta_offset + region.meta_len]
            .iter()
            .map(|&sym| Scheme::from_symbol(sym).expect("tri-level symbol"))
            .collect();
        let banks = self.config.banks;
        let cost = &self.config.cost;
        let src_all = &self.words[region.offset..region.offset + region.len];
        let g = if region.policy.has_metadata() {
            region.granularity
        } else {
            1
        };

        let mut partials = Vec::with_capacity(n_shards);
        let mut scratch: Vec<u16> = Vec::new();
        for (k, src) in src_all.chunks(LOAD_SHARD_WORDS).enumerate() {
            let start = k * LOAD_SHARD_WORDS;
            if shard_flips[k] == 0 {
                partials.push(clean_partials[k].clone());
                out[start..start + src.len()]
                    .copy_from_slice(&clean_f32[start..start + src.len()]);
            } else {
                scratch.resize(src.len(), 0);
                partials.push(load_shard(cost, src, &mut scratch, start, banks));
                let d_start = start / g * g;
                let d_end = (start + src.len()).div_ceil(g).saturating_mul(g).min(region.len);
                codec::decode_slice(
                    region.policy,
                    region.granularity,
                    &schemes,
                    d_start,
                    &src_all[d_start..d_end],
                    &mut out[d_start..d_end],
                );
            }
        }

        let energy = reduce_load_partials(&partials);
        self.stats.read_energy.add(energy);
        self.stats.reads += region.len as u64;
        for _ in 0..region.meta_len {
            self.stats
                .read_energy
                .add(self.config.cost.trilevel_cell(AccessKind::Read));
        }
        Ok(energy)
    }

    /// Bounds-check a region against the current allocation.
    fn check_region(&self, region: &Region) -> Result<(), BufferError> {
        if region.offset + region.len > self.used_words
            || region.meta_offset + region.meta_len > self.used_meta
        {
            return Err(BufferError::BadRegion);
        }
        Ok(())
    }

    /// A buffer in **pool mode**: the whole payload and metadata plane are
    /// marked allocated up front, so region checks validate against the
    /// full geometry and placement is owned entirely by the caller (the
    /// extent allocator in [`shared::SharedMlcBuffer`]) through
    /// [`Self::store_at`]. The bump-pointer [`Self::store`] sees zero free
    /// words and always fails — pool mode and append mode don't mix.
    pub fn pooled(config: BufferConfig, seed: u64) -> Self {
        let cap = config.capacity_words();
        MlcBuffer {
            config,
            words: vec![0; cap],
            meta: vec![0; cap],
            used_words: cap,
            used_meta: cap,
            stats: AccessStats::default(),
            rng: Xoshiro256::seeded(seed),
        }
    }

    /// Store an encoded stream at an explicit word `offset` (pool mode).
    ///
    /// Identical physics and accounting to [`Self::store_with_threads`] —
    /// same fixed-size shards, same content-dependent write energy summed
    /// in shard order, same write-path fault injection — except that
    /// placement and the fault RNG stream belong to the caller: per-shard
    /// seeds are drawn from `rng` in shard order before any worker runs,
    /// so a tenant that replays its own seed stream reproduces its flip
    /// sets bit-for-bit at *any* offset. Metadata symbols land at the same
    /// index as the payload (one group is never longer than one word, so
    /// disjoint word ranges imply disjoint metadata ranges).
    ///
    /// Returns the region plus a [`StoreBill`] shaped so the caller can
    /// replay the exact `Energy::add` sequence into a second accumulator
    /// (per-tenant stats that stay bit-identical to a private store).
    pub fn store_at(
        &mut self,
        enc: &Encoded,
        offset: usize,
        model: &ErrorModel,
        rng: &mut Xoshiro256,
        workers: usize,
    ) -> Result<(Region, StoreBill), BufferError> {
        if offset + enc.len() > self.words.len() || offset + enc.schemes.len() > self.meta.len() {
            return Err(BufferError::CapacityExceeded {
                requested: enc.len(),
                free: self.words.len().saturating_sub(offset),
            });
        }
        let n_shards = enc.len().div_ceil(STORE_SHARD_WORDS);
        let seeds: Vec<u64> = (0..n_shards).map(|_| rng.next_u64()).collect();
        let cost = &self.config.cost;
        let dst_all = &mut self.words[offset..offset + enc.len()];

        let jobs: Vec<(usize, &[u16], &mut [u16])> = enc
            .words
            .chunks(STORE_SHARD_WORDS)
            .zip(dst_all.chunks_mut(STORE_SHARD_WORDS))
            .enumerate()
            .map(|(k, (src, dst))| (k, src, dst))
            .collect();
        let shards = threads::run_sharded(jobs, workers, |(k, src, dst)| {
            store_shard(cost, model, src, dst, seeds[k])
        });

        for (energy, faults) in &shards {
            self.stats.write_energy.add(*energy);
            self.stats.injected_faults += *faults;
        }
        self.stats.writes += enc.len() as u64;

        for (i, s) in enc.schemes.iter().enumerate() {
            self.meta[offset + i] = s.symbol();
            self.stats
                .write_energy
                .add(self.config.cost.trilevel_cell(AccessKind::Write));
        }

        Ok((
            Region {
                offset,
                len: enc.len(),
                granularity: enc.granularity,
                policy: enc.policy,
                meta_offset: offset,
                meta_len: enc.schemes.len(),
            },
            StoreBill {
                shards,
                meta_writes: enc.schemes.len(),
            },
        ))
    }
}

/// Accounting trace of one [`MlcBuffer::store_at`], shaped so a caller can
/// replay the identical `Energy::add` sequence (per-shard partials in
/// shard order, then one tri-level metadata charge per group) into a
/// second accumulator — per-tenant stats in a shared pool stay bit-
/// identical to what a private buffer would have billed.
#[derive(Clone, Debug)]
pub struct StoreBill {
    /// `(energy, injected_faults)` per fixed-size store shard, in shard
    /// order.
    pub shards: Vec<(Energy, u64)>,
    /// Tri-level metadata symbols written (one write charge each).
    pub meta_writes: usize,
}

/// Outcome of one [`MlcBuffer::scrub_region`] pass, shaped like
/// [`StoreBill`] so a shared-pool caller can replay the identical
/// `Energy::add` sequence — one read add, then the dirty-shard write adds
/// in shard order — into a per-tenant accumulator, and so the wear ledger
/// can charge exactly the rewritten words (DESIGN.md §15).
#[derive(Clone, Debug)]
pub struct RegionScrub {
    /// Payload read energy of the full scan (carry-rule reduction over
    /// every shard, billed as one add before any write add).
    pub read_energy: Energy,
    /// `(shard index, write energy)` of each rewritten shard, in shard
    /// order — empty when the region verified clean.
    pub write_shards: Vec<(usize, Energy)>,
    /// Words scanned (the region length).
    pub scrubbed_words: u64,
    /// Words rewritten (whole dirty shards, through the store path).
    pub rewritten_words: u64,
    /// Scanned words that differed from the clean image.
    pub corrected_words: u64,
    /// MLC cells restored to their intended state within those words.
    pub corrected_cells: u64,
    /// Scanned words the resident policy's in-word redundancy flagged
    /// ([`ProtectionPolicy::detect`]) — telemetry, not the repair trigger.
    pub policy_detected: u64,
    /// Shards whose golden checksum disagreed.
    pub dirty_shards: u64,
    /// Corrected cells attributed to each bank (word index mod banks).
    pub corrected_per_bank: Vec<u64>,
    /// Words scanned per bank — the EWMA denominators.
    pub scrubbed_per_bank: Vec<u64>,
}

impl RegionScrub {
    fn new(banks: usize) -> Self {
        RegionScrub {
            read_energy: Energy::ZERO,
            write_shards: Vec::new(),
            scrubbed_words: 0,
            rewritten_words: 0,
            corrected_words: 0,
            corrected_cells: 0,
            policy_detected: 0,
            dirty_shards: 0,
            corrected_per_bank: vec![0; banks],
            scrubbed_per_bank: vec![0; banks],
        }
    }

    /// Fold another region's pass into this one (bank vectors must match —
    /// both come from the same buffer geometry). Shard indices in
    /// `write_shards` stay region-relative; aggregation is for telemetry,
    /// not bill replay.
    pub fn merge(&mut self, other: &RegionScrub) {
        self.read_energy.add(other.read_energy);
        // Shard indices are region-relative and meaningless after a merge;
        // keep the per-shard energies (for replay-shaped consumers) under
        // a sentinel index.
        for &(_, e) in &other.write_shards {
            self.write_shards.push((usize::MAX, e));
        }
        self.scrubbed_words += other.scrubbed_words;
        self.rewritten_words += other.rewritten_words;
        self.corrected_words += other.corrected_words;
        self.corrected_cells += other.corrected_cells;
        self.policy_detected += other.policy_detected;
        self.dirty_shards += other.dirty_shards;
        for (a, b) in self.corrected_per_bank.iter_mut().zip(&other.corrected_per_bank) {
            *a += b;
        }
        for (a, b) in self.scrubbed_per_bank.iter_mut().zip(&other.scrubbed_per_bank) {
            *a += b;
        }
    }
}

/// FNV-1a 64 over the little-endian bytes of each [`LOAD_SHARD_WORDS`]
/// chunk of an f16 word stream — the golden per-shard checksum vector the
/// scrub cursor compares against (same constants and byte discipline as
/// the delivery manifest's chunk checksums, DESIGN.md §14/§15). Computed
/// once from a clean encoded image; a rebuild reproduces the same words,
/// so the vector survives eviction cycles unchanged.
pub fn shard_checksums(words: &[u16]) -> Vec<u64> {
    words.chunks(LOAD_SHARD_WORDS).map(fnv_words).collect()
}

/// FNV-1a 64 of one word slice (little-endian bytes).
fn fnv_words(words: &[u16]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Write one store shard: bill the energy of programming the *intended*
/// image, then let the write/retention error model corrupt vulnerable
/// cells in the stored copy via the packed geometric-skip sampler
/// (DESIGN.md §8). Returns `(energy, injected_faults)` where faults count
/// changed words.
fn store_shard(
    cost: &CostModel,
    model: &ErrorModel,
    src: &[u16],
    dst: &mut [u16],
    seed: u64,
) -> (Energy, u64) {
    let mut rng = Xoshiro256::seeded(seed);
    let mut energy = Energy::ZERO;
    for &w in src {
        energy.add(cost.word(w, AccessKind::Write));
    }
    dst.copy_from_slice(src);
    let (words_changed, _) = model.corrupt_words_write(dst, &mut rng);
    (energy, words_changed)
}

/// Per-shard read accounting, shaped for the carry-rule reduction in
/// [`MlcBuffer::load_with_threads`]. Bank slots are global (region-relative
/// index / banks); a shard reports the possibly-partial slot it starts in
/// (`head`), the summed maxes of slots fully inside it (`interior`), and —
/// when it touches more than one slot — the possibly-partial slot it ends
/// in (`tail`), which the next shard may continue.
#[derive(Clone, Debug)]
pub(crate) struct LoadPartial {
    /// Read energy of this shard's words (nanojoules sum, in word order).
    nj: f64,
    /// Global index of the first bank slot this shard touches.
    head_slot: usize,
    /// Max cell latency observed in `head_slot` within this shard.
    head_max: u64,
    /// Total cycles of slots that begin *and* end inside this shard.
    interior_cycles: u64,
    /// `(slot, max)` of the last slot touched, when it differs from the
    /// head slot (it may continue into the next shard).
    tail: Option<(usize, u64)>,
}

/// Shard-order reduction of per-shard read partials with the carry rule
/// (DESIGN.md §8): energy partials sum in shard order; `open` is the bank
/// slot still accumulating its latency max across a shard boundary.
/// Shared by [`MlcBuffer::load_with_threads`] and the pipelined
/// [`MlcBuffer::load_decoded`], which is what makes their bills
/// bit-identical.
fn reduce_load_partials(partials: &[LoadPartial]) -> Energy {
    let mut nj = 0.0f64;
    let mut cycles = 0u64;
    let mut open: Option<(usize, u64)> = None;
    for p in partials {
        nj += p.nj;
        let head = match open.take() {
            Some((slot, max)) if slot == p.head_slot => (slot, max.max(p.head_max)),
            Some((_, max)) => {
                // The carried slot closed exactly at the boundary.
                cycles += max;
                (p.head_slot, p.head_max)
            }
            None => (p.head_slot, p.head_max),
        };
        match p.tail {
            Some(tail) => {
                cycles += head.1 + p.interior_cycles;
                open = Some(tail);
            }
            None => open = Some(head),
        }
    }
    if let Some((_, max)) = open {
        cycles += max;
    }
    Energy {
        nanojoules: nj,
        cycles,
    }
}

/// Read one load shard: copy the stored words out and fold per-word read
/// costs into a [`LoadPartial`]. `start` is the shard's region-relative
/// word offset (always a multiple of [`LOAD_SHARD_WORDS`]).
fn load_shard(
    cost: &CostModel,
    src: &[u16],
    dst: &mut [u16],
    start: usize,
    banks: usize,
) -> LoadPartial {
    dst.copy_from_slice(src);
    let head_slot = start / banks;
    let mut nj = 0.0f64;
    let mut cur_slot = head_slot;
    let mut cur_max = 0u64;
    let mut head_max = 0u64;
    let mut interior = 0u64;
    let mut head_done = false;
    for (i, &w) in src.iter().enumerate() {
        let slot = (start + i) / banks;
        if slot != cur_slot {
            if head_done {
                interior += cur_max;
            } else {
                head_max = cur_max;
                head_done = true;
            }
            cur_slot = slot;
            cur_max = 0;
        }
        let e = cost.word(w, AccessKind::Read);
        nj += e.nanojoules;
        cur_max = cur_max.max(e.cycles);
    }
    if head_done {
        LoadPartial {
            nj,
            head_slot,
            head_max,
            interior_cycles: interior,
            tail: Some((cur_slot, cur_max)),
        }
    } else {
        // The whole shard sits inside a single bank slot.
        LoadPartial {
            nj,
            head_slot,
            head_max: cur_max,
            interior_cycles: 0,
            tail: None,
        }
    }
}

/// Decode-stage consumer of the [`MlcBuffer::load_decoded`] pipeline:
/// receives billed shards in shard order, decodes every group-aligned run
/// the moment it arrives, and **carries** the words of a metadata group
/// that straddles a shard boundary (at most `granularity - 1` of them)
/// until the next shard completes it — the pipelined twin of the load
/// path's latency carry rule. Buffers return through `recycle` for reuse.
/// Group boundaries, not shard boundaries, drive the decode kernels, so
/// the output is bit-identical to [`Encoded::decode_into_threaded`] for
/// any shard size.
fn decode_pipeline_stage(
    policy: Policy,
    granularity: usize,
    region_len: usize,
    schemes: &[Scheme],
    rx: mpsc::Receiver<(usize, Vec<u16>)>,
    recycle: mpsc::Sender<Vec<u16>>,
    out: &mut [f32],
) {
    let g = if policy == Policy::Unprotected {
        1
    } else {
        granularity
    };
    // Next undecoded word; always group-aligned when a decode is issued.
    let mut pos = 0usize;
    let mut carry: Vec<u16> = Vec::new();
    while let Ok((start, buf)) = rx.recv() {
        debug_assert_eq!(start, pos + carry.len());
        let end = start + buf.len();
        let mut words: &[u16] = &buf;
        if !carry.is_empty() {
            let take = (g - carry.len()).min(words.len());
            carry.extend_from_slice(&words[..take]);
            words = &words[take..];
            if carry.len() == g || end == region_len {
                codec::decode_slice(
                    policy,
                    granularity,
                    schemes,
                    pos,
                    &carry,
                    &mut out[pos..pos + carry.len()],
                );
                pos += carry.len();
                carry.clear();
            }
        }
        // The final shard's ragged tail group decodes immediately; an
        // interior remainder waits in the carry for the next shard.
        let aligned = if end == region_len {
            words.len()
        } else {
            words.len() / g * g
        };
        if aligned > 0 {
            codec::decode_slice(
                policy,
                granularity,
                schemes,
                pos,
                &words[..aligned],
                &mut out[pos..pos + aligned],
            );
            pos += aligned;
        }
        carry.extend_from_slice(&words[aligned..]);
        // Ignore a closed recycle lane: the producer may already be done.
        let _ = recycle.send(buf);
    }
    debug_assert!(carry.is_empty(), "pipeline left undecoded words");
    debug_assert_eq!(pos, region_len, "pipeline decoded a partial region");
}

/// Apply read-disturb errors to one shard of stored words with its own
/// seeded RNG stream (geometric-skip sampler); returns changed words.
fn disturb_shard(model: &ErrorModel, shard: &mut [u16], seed: u64) -> u64 {
    let mut rng = Xoshiro256::seeded(seed);
    let (words_changed, _) = model.corrupt_words_read(shard, &mut rng);
    words_changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::{Policy, WeightCodec};
    use crate::fp;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| fp::quantize_f16((i as f32 / n as f32) * 1.8 - 0.9))
            .collect()
    }

    fn quiet_config(words: usize) -> BufferConfig {
        BufferConfig::new(words * 2, 4).with_error_model(ErrorModel::at_rate(0.0))
    }

    #[test]
    fn store_load_roundtrip_fault_free() {
        let ws = ramp(500);
        let enc = WeightCodec::hybrid(4).encode(&ws);
        let mut buf = MlcBuffer::new(quiet_config(1000), 1);
        let region = buf.store(&enc).unwrap();
        let back = buf.load(&region).unwrap();
        assert_eq!(back.words, enc.words);
        assert_eq!(back.schemes, enc.schemes);
        assert_eq!(back.decode(), enc.decode());
    }

    #[test]
    fn capacity_enforced() {
        let ws = ramp(100);
        let enc = WeightCodec::hybrid(1).encode(&ws);
        let mut buf = MlcBuffer::new(quiet_config(50), 1);
        match buf.store(&enc) {
            Err(BufferError::CapacityExceeded { requested, free }) => {
                assert_eq!(requested, 100);
                assert_eq!(free, 50);
            }
            other => panic!("expected capacity error, got {other:?}"),
        }
    }

    #[test]
    fn sram_equivalent_density() {
        let cfg = BufferConfig::sram_equivalent(256 * 1024, 8);
        assert_eq!(cfg.capacity_bytes, 1024 * 1024);
        assert_eq!(cfg.capacity_words(), 512 * 1024);
    }

    #[test]
    fn write_energy_tracks_content() {
        // All-zero words (8 base cells each) vs alternating (8 soft cells).
        let mut buf = MlcBuffer::new(quiet_config(100), 1);
        let cheap = Encoded {
            words: vec![0x0000; 10],
            schemes: vec![],
            granularity: 1,
            policy: Policy::Unprotected,
        };
        buf.store(&cheap).unwrap();
        let cheap_nj = buf.stats().write_energy.nanojoules;

        let mut buf2 = MlcBuffer::new(quiet_config(100), 1);
        let dear = Encoded {
            words: vec![0x5555; 10],
            schemes: vec![],
            granularity: 1,
            policy: Policy::Unprotected,
        };
        buf2.store(&dear).unwrap();
        let dear_nj = buf2.stats().write_energy.nanojoules;
        assert!((cheap_nj - 10.0 * 8.0 * 1.084).abs() < 1e-9);
        assert!((dear_nj - 10.0 * 8.0 * 2.653).abs() < 1e-9);
    }

    #[test]
    fn banked_read_latency() {
        // 8 all-base words over 4 banks = 2 slots * 14 cycles.
        let mut buf = MlcBuffer::new(quiet_config(100), 1);
        let enc = Encoded {
            words: vec![0xFFFF; 8],
            schemes: vec![],
            granularity: 1,
            policy: Policy::Unprotected,
        };
        let r = buf.store(&enc).unwrap();
        buf.reset_stats();
        buf.load(&r).unwrap();
        assert_eq!(buf.stats().read_energy.cycles, 2 * 14);
    }

    #[test]
    fn fault_injection_counts_and_biases() {
        let ws = ramp(20_000);
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let cfg = BufferConfig::new(50_000 * 2, 4)
            .with_error_model(ErrorModel::at_rate(0.02));
        let mut buf = MlcBuffer::new(cfg, 99);
        let r = buf.store(&enc).unwrap();
        let faults = buf.stats().injected_faults;
        assert!(faults > 0, "expected some injected faults");
        let back = buf.load(&r).unwrap();
        let diff = back
            .words
            .iter()
            .zip(&enc.words)
            .filter(|(a, b)| a != b)
            .count() as u64;
        assert_eq!(diff, faults);
    }

    #[test]
    fn store_identical_across_worker_counts() {
        // Multi-shard stream (> STORE_SHARD_WORDS): the stored image, fault
        // accounting, and energy must not depend on how many threads ran.
        let ws = ramp(STORE_SHARD_WORDS + 5000);
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let cfg = BufferConfig::new(enc.len() * 2, 4)
            .with_error_model(ErrorModel::at_rate(0.02));
        let run = |workers: usize| {
            let mut buf = MlcBuffer::new(cfg.clone(), 0xD15C);
            let r = buf.store_with_threads(&enc, workers).unwrap();
            let words = buf.load(&r).unwrap().words;
            let s = buf.stats();
            (words, s.injected_faults, s.write_energy)
        };
        let (w1, f1, e1) = run(1);
        for workers in [2usize, 3, 8] {
            let (wn, fn_, en) = run(workers);
            assert_eq!(w1, wn, "workers={workers}");
            assert_eq!(f1, fn_, "workers={workers}");
            assert_eq!(e1, en, "workers={workers}");
        }
        assert!(f1 > 0);
    }

    #[test]
    fn metadata_survives_faults() {
        // Metadata plane is tri-level: fault-free even at rate 1.
        let ws = ramp(512);
        let enc = WeightCodec::hybrid(2).encode(&ws);
        let cfg = BufferConfig::new(2048, 2).with_error_model(ErrorModel::at_rate(1.0));
        let mut buf = MlcBuffer::new(cfg, 5);
        let r = buf.store(&enc).unwrap();
        let back = buf.load(&r).unwrap();
        assert_eq!(back.schemes, enc.schemes);
    }

    #[test]
    fn multiple_regions_do_not_alias() {
        let a = WeightCodec::hybrid(1).encode(&ramp(64));
        let b = WeightCodec::hybrid(4).encode(&ramp(128)[64..].to_vec());
        let mut buf = MlcBuffer::new(quiet_config(1024), 1);
        let ra = buf.store(&a).unwrap();
        let rb = buf.store(&b).unwrap();
        assert_eq!(buf.load(&ra).unwrap().words, a.words);
        assert_eq!(buf.load(&rb).unwrap().words, b.words);
        assert_eq!(ra.offset + ra.len, rb.offset);
    }

    #[test]
    fn bad_region_rejected() {
        let mut buf = MlcBuffer::new(quiet_config(10), 1);
        let bogus = Region {
            offset: 0,
            len: 5,
            granularity: 1,
            policy: Policy::Hybrid,
            meta_offset: 0,
            meta_len: 5,
        };
        assert_eq!(buf.load(&bogus).unwrap_err(), BufferError::BadRegion);
    }

    #[test]
    fn clear_releases_capacity() {
        let enc = WeightCodec::hybrid(1).encode(&ramp(100));
        let mut buf = MlcBuffer::new(quiet_config(100), 1);
        buf.store(&enc).unwrap();
        assert_eq!(buf.free_words(), 0);
        buf.clear();
        assert_eq!(buf.free_words(), 100);
        buf.store(&enc).unwrap();
    }

    #[test]
    fn load_decoded_matches_load_then_decode() {
        // The fused pipeline must return the same floats AND bill the
        // same read energy/cycles as load_with_threads + decode — across
        // worker counts, granularities (incl. g=7, which straddles the
        // 32768-word shard boundary), and a multi-shard region.
        let n = LOAD_SHARD_WORDS * 2 + 4321;
        let ws = ramp(n);
        for (policy, g) in [
            (Policy::Unprotected, 1usize),
            (Policy::Hybrid, 4),
            (Policy::Hybrid, 7),
            (Policy::ProtectRotate, 16),
        ] {
            let enc = WeightCodec::new(policy, g).encode(&ws);
            let cfg = BufferConfig::new(enc.len() * 2, 12)
                .with_error_model(ErrorModel::at_rate(0.015));
            let mut buf = MlcBuffer::new(cfg.clone(), 77);
            let r = buf.store(&enc).unwrap();
            buf.reset_stats();
            let oracle = buf.load_with_threads(&r, 3).unwrap();
            let mut want = Vec::new();
            oracle.decode_into_threaded(&mut want, 3);
            let want_bill = buf.stats().read_energy;

            for workers in [1usize, 2, 7] {
                let mut buf2 = MlcBuffer::new(cfg.clone(), 77);
                let r2 = buf2.store(&enc).unwrap();
                buf2.reset_stats();
                let mut got = Vec::new();
                buf2.load_decoded(&r2, &mut got, workers).unwrap();
                assert_eq!(got, want, "{policy:?} g={g} workers={workers}");
                assert_eq!(
                    buf2.stats().read_energy,
                    want_bill,
                    "{policy:?} g={g} workers={workers}"
                );
                assert_eq!(buf2.stats().reads, n as u64);
            }
        }
    }

    #[test]
    fn replay_region_read_matches_a_real_read() {
        // Billing a cached read must leave stats bit-identical to
        // actually re-reading the (unchanged) region.
        let ws = ramp(LOAD_SHARD_WORDS + 777);
        let enc = WeightCodec::hybrid(4).encode(&ws);
        let cfg = BufferConfig::new(enc.len() * 2, 8).with_error_model(ErrorModel::at_rate(0.0));

        let mut real = MlcBuffer::new(cfg.clone(), 1);
        let r1 = real.store(&enc).unwrap();
        let mut out = Vec::new();
        real.load_decoded(&r1, &mut out, 2).unwrap();
        real.load_decoded(&r1, &mut out, 2).unwrap();

        let mut replayed = MlcBuffer::new(cfg, 1);
        let r2 = replayed.store(&enc).unwrap();
        let bill = replayed.load_decoded(&r2, &mut out, 2).unwrap();
        replayed.replay_region_read(&r2, bill).unwrap();

        assert_eq!(real.stats().read_energy, replayed.stats().read_energy);
        assert_eq!(real.stats().reads, replayed.stats().reads);
    }

    #[test]
    fn snapshot_restore_reinject_matches_fresh_store() {
        // restore + corrupt_region_write after a clean store must
        // reproduce a fresh at-rate store bit-for-bit: same words, same
        // fault count, same write accounting.
        let ws = ramp(STORE_SHARD_WORDS + 9000);
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let seed = 0xBEEF;
        let rate = ErrorModel::at_rate(0.02);

        let mut fresh = MlcBuffer::new(
            BufferConfig::new(enc.len() * 2, 4).with_error_model(rate.clone()),
            seed,
        );
        let rf = fresh.store(&enc).unwrap();
        let want = fresh.load(&rf).unwrap().words;

        let mut buf = MlcBuffer::new(
            BufferConfig::new(enc.len() * 2, 4).with_error_model(ErrorModel::at_rate(0.0)),
            123, // clean-store seed is irrelevant: restore reseeds
        );
        let r = buf.store(&enc).unwrap();
        let snap = buf.snapshot();
        for workers in [1usize, 3] {
            buf.restore(&snap, seed);
            let faults = buf.corrupt_region_write(&r, &rate, workers).unwrap();
            assert_eq!(faults, fresh.stats().injected_faults, "workers={workers}");
            assert_eq!(buf.stats().injected_faults, faults);
            let got = buf.load(&r).unwrap().words;
            assert_eq!(got, want, "workers={workers}");
            assert_eq!(
                buf.stats().write_energy,
                fresh.stats().write_energy,
                "workers={workers}"
            );
        }
    }

    #[test]
    fn disturb_load_at_rate_zero_is_exactly_a_plain_load() {
        // With read disturb off (the default), load_with_disturb must not
        // consume RNG state: a stochastic store issued afterwards has to
        // produce the same flip set as if only plain loads had run.
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ramp(4096));
        let cfg = BufferConfig::new(enc.len() * 4, 4)
            .with_error_model(ErrorModel::new(0.02, 0.0));
        let run = |disturb_first: bool| {
            let mut buf = MlcBuffer::new(cfg.clone(), 0xFEED);
            let r = buf.store(&enc).unwrap();
            let loaded = if disturb_first {
                buf.load_with_disturb(&r).unwrap()
            } else {
                buf.load(&r).unwrap()
            };
            let r2 = buf.store(&enc).unwrap();
            (loaded.words, buf.load(&r2).unwrap().words)
        };
        let (l1, s1) = run(false);
        let (l2, s2) = run(true);
        assert_eq!(l1, l2, "rate-0 disturb load changed the read image");
        assert_eq!(s1, s2, "rate-0 disturb load consumed RNG state");
    }

    #[test]
    fn read_disturb_ablation_persists_flips() {
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ramp(8192));
        let cfg = BufferConfig::new(8192 * 2, 4)
            .with_error_model(ErrorModel::new(0.0, 0.05));
        let mut buf = MlcBuffer::new(cfg, 17);
        let r = buf.store(&enc).unwrap();
        assert_eq!(buf.stats().injected_faults, 0); // write path clean
        let first = buf.load_with_disturb(&r).unwrap();
        assert!(buf.stats().injected_faults > 0);
        // The disturbance is persistent: a plain load now sees the flips.
        let second = buf.load(&r).unwrap();
        assert_eq!(first.words, second.words);
    }

    #[test]
    fn per_shard_flip_counts_sum_and_align() {
        // The shard-resolved disturb reports exactly where the summed
        // variant's flips landed, shard by shard, off the same seed stream.
        let n = STORE_SHARD_WORDS * 2 + 123;
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ramp(n));
        let cfg = BufferConfig::new(enc.len() * 2, 4)
            .with_error_model(ErrorModel::at_rate(0.0));
        let rate = ErrorModel::at_rate(0.02);

        let mut buf = MlcBuffer::new(cfg.clone(), 0xABCD);
        let r = buf.store(&enc).unwrap();
        let per_shard = buf.corrupt_region_write_shards(&r, &rate, 3).unwrap();
        assert_eq!(per_shard.len(), n.div_ceil(STORE_SHARD_WORDS));

        let mut twin = MlcBuffer::new(cfg, 0xABCD);
        let rt = twin.store(&enc).unwrap();
        let total = twin.corrupt_region_write(&rt, &rate, 1).unwrap();
        assert_eq!(per_shard.iter().sum::<u64>(), total);
        assert_eq!(buf.stats().injected_faults, total);

        // Each count is the per-shard word diff against the clean image.
        let stored = buf.load(&r).unwrap().words;
        for (k, (got, clean)) in stored
            .chunks(STORE_SHARD_WORDS)
            .zip(enc.words.chunks(STORE_SHARD_WORDS))
            .enumerate()
        {
            let diff = got.iter().zip(clean).filter(|(a, b)| a != b).count() as u64;
            assert_eq!(diff, per_shard[k], "shard {k}");
        }
        assert!(total > 0);
    }

    #[test]
    fn shard_checksums_follow_stored_content() {
        let n = LOAD_SHARD_WORDS + 500;
        let enc = WeightCodec::hybrid(4).encode(&ramp(n));
        let cfg = BufferConfig::new(enc.len() * 2, 4)
            .with_error_model(ErrorModel::at_rate(0.0));
        let mut buf = MlcBuffer::new(cfg, 3);
        let r = buf.store(&enc).unwrap();
        let golden = shard_checksums(&enc.words);
        assert_eq!(golden.len(), 2);
        assert_eq!(buf.region_shard_checksums(&r).unwrap(), golden);
        // A single flip in shard 1 changes exactly that checksum.
        buf.words[r.offset + LOAD_SHARD_WORDS] ^= 1 << 2;
        let now = buf.region_shard_checksums(&r).unwrap();
        assert_eq!(now[0], golden[0]);
        assert_ne!(now[1], golden[1]);
    }

    #[test]
    fn scrub_restores_clean_image_and_consumes_no_rng() {
        let n = LOAD_SHARD_WORDS + 4000;
        let ws = ramp(n);
        for (policy, g) in [(Policy::Hybrid, 7usize), (Policy::ZeroSpaceParity, 1)] {
            let enc = WeightCodec::new(policy, g).encode(&ws);
            let cfg = BufferConfig::new(enc.len() * 2, 4)
                .with_error_model(ErrorModel::at_rate(0.0));
            let rate = ErrorModel::at_rate(0.02);
            let golden = shard_checksums(&enc.words);
            let prot = crate::encoding::protection_for(policy, g);

            let mut buf = MlcBuffer::new(cfg.clone(), 42);
            let r = buf.store(&enc).unwrap();
            buf.corrupt_region_write(&r, &rate, 2).unwrap();

            // Control: same seed stream, content fixed up by hand instead
            // of by scrub — isolates the RNG-stream comparison below.
            let mut ctrl = MlcBuffer::new(cfg.clone(), 42);
            let rc = ctrl.store(&enc).unwrap();
            ctrl.corrupt_region_write(&rc, &rate, 2).unwrap();
            ctrl.words[rc.offset..rc.offset + rc.len].copy_from_slice(&enc.words);

            let pass = buf
                .scrub_region(&r, &enc.words, &golden, prot.as_ref())
                .unwrap();
            assert!(pass.dirty_shards > 0, "{policy:?}");
            assert!(pass.corrected_words > 0 && pass.corrected_cells >= pass.corrected_words);
            assert_eq!(pass.scrubbed_words, n as u64);
            assert_eq!(buf.load(&r).unwrap().words, enc.words, "{policy:?}");

            // A clean pass detects and rewrites nothing.
            let second = buf
                .scrub_region(&r, &enc.words, &golden, prot.as_ref())
                .unwrap();
            assert_eq!(second.dirty_shards, 0, "{policy:?}");
            assert_eq!(second.corrected_words, 0);
            assert_eq!(second.rewritten_words, 0);
            assert_eq!(second.policy_detected, 0, "{policy:?} clean image flagged");

            // Scrubbing drew no RNG: the next disturb lands identically to
            // the control that never scrubbed.
            buf.corrupt_region_write(&r, &rate, 1).unwrap();
            ctrl.corrupt_region_write(&rc, &rate, 1).unwrap();
            assert_eq!(
                buf.load(&r).unwrap().words,
                ctrl.load(&rc).unwrap().words,
                "{policy:?} scrub consumed RNG state"
            );
        }
    }

    #[test]
    fn scrub_billing_matches_read_and_store_oracles() {
        let n = LOAD_SHARD_WORDS * 2 + 321;
        let enc = WeightCodec::hybrid(4).encode(&ramp(n));
        let cfg = BufferConfig::new(enc.len() * 2, 8)
            .with_error_model(ErrorModel::at_rate(0.0));
        let rate = ErrorModel::at_rate(0.02);

        let mut buf = MlcBuffer::new(cfg.clone(), 7);
        let r = buf.store(&enc).unwrap();
        buf.corrupt_region_write(&r, &rate, 3).unwrap();

        // Read oracle: the payload partial a real read of the corrupted
        // region bills (same seed stream → same corrupted content).
        let mut twin = MlcBuffer::new(cfg.clone(), 7);
        let rt = twin.store(&enc).unwrap();
        twin.corrupt_region_write(&rt, &rate, 3).unwrap();
        let mut sink = Vec::new();
        let read_oracle = twin.load_decoded(&rt, &mut sink, 1).unwrap();

        buf.reset_stats();
        let golden = shard_checksums(&enc.words);
        let prot = crate::encoding::protection_for(Policy::Hybrid, 4);
        let pass = buf
            .scrub_region(&r, &enc.words, &golden, prot.as_ref())
            .unwrap();
        assert!(pass.dirty_shards >= 1);
        assert_eq!(pass.read_energy, read_oracle);
        assert_eq!(buf.stats().read_energy, read_oracle);
        assert_eq!(buf.stats().reads, n as u64);

        // Write oracle: each dirty shard bills exactly the clean words'
        // content-dependent write costs, added in shard order.
        let mut want_write = Energy::ZERO;
        for &(k, e) in &pass.write_shards {
            let lo = k * LOAD_SHARD_WORDS;
            let hi = (lo + LOAD_SHARD_WORDS).min(enc.len());
            let mut o = Energy::ZERO;
            for &w in &enc.words[lo..hi] {
                o.add(cfg.cost.word(w, AccessKind::Write));
            }
            assert_eq!(e, o, "shard {k}");
            want_write.add(o);
        }
        assert_eq!(buf.stats().write_energy, want_write);
        assert_eq!(buf.stats().writes, pass.rewritten_words);
        assert_eq!(
            pass.rewritten_words,
            pass.write_shards
                .iter()
                .map(|&(k, _)| ((k * LOAD_SHARD_WORDS + LOAD_SHARD_WORDS).min(n)
                    - k * LOAD_SHARD_WORDS) as u64)
                .sum::<u64>()
        );
        assert_eq!(buf.stats().injected_faults, 0, "scrub never injects");
    }

    #[test]
    fn load_decoded_reusing_matches_full_read() {
        // Mixed clean/dirty shards: skipped shards replay cached partials
        // and floats; dirty shards re-read and re-decode over their
        // group-aligned hull. Bill and floats must equal a full fresh read
        // — including g=7, whose groups straddle the shard boundary.
        let n = LOAD_SHARD_WORDS * 3 + 777;
        let ws = ramp(n);
        for (policy, g) in [
            (Policy::Hybrid, 7usize),
            (Policy::Unprotected, 1),
            (Policy::ZeroSpaceParity, 1),
        ] {
            let enc = WeightCodec::new(policy, g).encode(&ws);
            let cfg = BufferConfig::new(enc.len() * 2, 12)
                .with_error_model(ErrorModel::at_rate(0.0));
            let rate = ErrorModel::at_rate(0.018);

            let setup = |seed: u64| {
                let mut b = MlcBuffer::new(cfg.clone(), seed);
                let reg = b.store(&enc).unwrap();
                let mut flips = b.corrupt_region_write_shards(&reg, &rate, 2).unwrap();
                // Force shard 1 clean so the skip path actually runs.
                b.words[reg.offset + LOAD_SHARD_WORDS..reg.offset + 2 * LOAD_SHARD_WORDS]
                    .copy_from_slice(&enc.words[LOAD_SHARD_WORDS..2 * LOAD_SHARD_WORDS]);
                flips[1] = 0;
                (b, reg, flips)
            };

            let (mut clean_buf, clean_r, _) = {
                let mut b = MlcBuffer::new(cfg.clone(), 9);
                let reg = b.store(&enc).unwrap();
                (b, reg, ())
            };
            let clean_partials = clean_buf.region_load_partials(&clean_r).unwrap();
            let mut clean_f32 = Vec::new();
            clean_buf.load_decoded(&clean_r, &mut clean_f32, 1).unwrap();

            let (mut twin, rt, _) = setup(9);
            twin.reset_stats();
            let mut want = Vec::new();
            let want_energy = twin.load_decoded(&rt, &mut want, 1).unwrap();

            let (mut buf, r, flips) = setup(9);
            assert!(flips.iter().any(|&f| f > 0), "{policy:?}: no dirty shard");
            buf.reset_stats();
            let mut got = Vec::new();
            let e = buf
                .load_decoded_reusing(&r, &clean_partials, &flips, &clean_f32, &mut got)
                .unwrap();
            assert_eq!(got, want, "{policy:?}");
            assert_eq!(e, want_energy, "{policy:?}");
            assert_eq!(buf.stats().read_energy, twin.stats().read_energy, "{policy:?}");
            assert_eq!(buf.stats().reads, twin.stats().reads);
        }
    }
}
