//! Multi-tenant extent allocation over one banked MLC buffer.
//!
//! [`SharedMlcBuffer`] hosts several models' encoded weights in a single
//! [`MlcBuffer`] (pool mode, see [`MlcBuffer::pooled`]): the payload plane
//! is split into fixed-size, bank-slot-aligned **extents**, a region is a
//! contiguous run of extents, and placement is driven by the endurance
//! model in [`crate::stt::endurance`] — per-extent write counters plus
//! per-bank [`WearTracker`]s turn the seed's dormant wear math into
//! write-leveling decisions and a "buffer lifetime under traffic" report
//! (rendered by `metrics::wear_table`).
//!
//! Invariants (pinned by `rust/tests/shared_buffer.rs`):
//!
//! * extents never overlap: every live region owns a disjoint run of
//!   extents, and `region.offset == first_extent * extent_words`;
//! * extents are bank-slot aligned: `extent_words % banks == 0`, so a
//!   region always starts at a fresh bank slot and its banked read
//!   latency depends only on its length, never its placement — which is
//!   what makes an evicted tenant's rebuild bill bit-identical to a
//!   fresh store at any offset;
//! * placement is deterministic wear-leveling: among windows of free
//!   extents, prefer windows without *hot* extents (write count above
//!   [`SharedMlcBuffer::level_ratio`] × the mean), then the window whose
//!   worst extent is least worn, ties broken by total wear then lowest
//!   start — so repeated alloc/free cycles rotate regions across the
//!   plane instead of re-burning the same cells.
//!
//! Eviction policy lives one layer up in [`crate::api::BufferPool`]; this
//! module only allocates, frees, and keeps the wear ledger. The
//! [`EvictPolicy`] enum is defined here so `util::env` can parse
//! `MLCSTT_EVICT` without reaching into the API layer.

use crate::encoding::{Encoded, ProtectionPolicy};
use crate::stt::endurance::WearTracker;
use crate::stt::{AccessKind, Energy, ErrorModel};
use crate::util::rng::Xoshiro256;

use super::{
    AccessStats, BufferConfig, BufferError, MlcBuffer, Region, RegionScrub, LOAD_SHARD_WORDS,
};

/// Default hot-extent threshold: an extent whose write count exceeds
/// `LEVEL_RATIO ×` the mean extent write count is avoided by placement
/// until the rest of the plane catches up.
pub const LEVEL_RATIO: f64 = 2.0;

/// What a [`crate::api::BufferPool`] does under capacity pressure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Evict the least-recently-served resident model and rebuild it on
    /// demand (the default).
    Lru,
    /// Refuse the allocation instead of evicting anyone.
    Deny,
}

/// Per-extent allocator state.
#[derive(Clone, Debug, Default)]
struct Extent {
    /// Words written into this extent across its lifetime (leveling key).
    writes: u64,
    /// Allocation id of the region currently owning this extent.
    owner: Option<u64>,
}

/// A tenant's slice of the pool: the decodable [`Region`] plus the extent
/// run backing it.
#[derive(Clone, Debug)]
pub struct PoolRegion {
    /// The stored tensor's location + codec context (usable with every
    /// region-based `MlcBuffer` read path).
    pub region: Region,
    /// First extent of the backing run.
    pub first_extent: usize,
    /// Extents in the backing run (`ceil(len / extent_words)`).
    pub n_extents: usize,
    /// Allocation id, so a stale handle can't free a reused extent.
    id: u64,
}

/// One bank's row of the "buffer lifetime under traffic" report.
#[derive(Clone, Debug)]
pub struct BankWear {
    /// Bank index.
    pub bank: usize,
    /// Extents mapped to this bank (`extent % banks` round-robin).
    pub extents: usize,
    /// Worst per-extent write count in this bank.
    pub max_writes: u64,
    /// Mean per-extent write count in this bank.
    pub mean_writes: f64,
    /// Mean endurance stress per stored word (soft transitions weighted
    /// [`crate::stt::endurance::HARD_PULSE_WEIGHT`]×).
    pub stress_per_write: f64,
    /// Lifetime relative to an all-base-state write mix.
    pub relative_lifetime: f64,
    /// Projected word-writes until the rated switching endurance.
    pub writes_until_rated: f64,
}

/// A bank-aligned extent allocator + wear ledger over one pool-mode
/// [`MlcBuffer`]. See the module docs for the invariants.
pub struct SharedMlcBuffer {
    buf: MlcBuffer,
    extent_words: usize,
    extents: Vec<Extent>,
    level_ratio: f64,
    /// Per-bank wear, fed with every *intended* stored word (the pre-fault
    /// image: programming stress is paid for what the write tried to
    /// store, whether or not a fault lands).
    bank_wear: Vec<WearTracker>,
    next_id: u64,
}

impl SharedMlcBuffer {
    /// A pool of `capacity_bytes` across `banks`, carved into extents of
    /// `extent_words` words. `extent_words` must be a positive multiple of
    /// `banks` (bank-slot alignment); a ragged tail of words smaller than
    /// one extent is left unused. `seed` drives only pool-internal
    /// randomness — tenant fault streams are passed per store.
    pub fn new(capacity_bytes: usize, banks: usize, extent_words: usize, seed: u64) -> Self {
        assert!(banks >= 1, "need at least one bank");
        assert!(
            extent_words >= 1 && extent_words % banks == 0,
            "extent_words ({extent_words}) must be a positive multiple of banks ({banks})"
        );
        let config = BufferConfig::new(capacity_bytes, banks);
        let n = config.capacity_words() / extent_words;
        SharedMlcBuffer {
            buf: MlcBuffer::pooled(config, seed),
            extent_words,
            extents: vec![Extent::default(); n],
            level_ratio: LEVEL_RATIO,
            bank_wear: vec![WearTracker::new(); banks],
            next_id: 0,
        }
    }

    /// Builder-style override of the hot-extent threshold ratio.
    pub fn with_level_ratio(mut self, ratio: f64) -> Self {
        assert!(ratio >= 1.0, "a ratio below 1 marks everything hot");
        self.level_ratio = ratio;
        self
    }

    /// Words per extent.
    pub fn extent_words(&self) -> usize {
        self.extent_words
    }

    /// Total extents in the pool.
    pub fn extents(&self) -> usize {
        self.extents.len()
    }

    /// Extents not currently owned by any region.
    pub fn free_extents(&self) -> usize {
        self.extents.iter().filter(|e| e.owner.is_none()).count()
    }

    /// Parallel banks (one word per bank per access slot).
    pub fn banks(&self) -> usize {
        self.buf.config.banks
    }

    /// Usable pool capacity in words (whole extents only).
    pub fn capacity_words(&self) -> usize {
        self.extents.len() * self.extent_words
    }

    /// Hot-extent threshold ratio in force.
    pub fn level_ratio(&self) -> f64 {
        self.level_ratio
    }

    /// Pool-aggregate transaction statistics (all tenants combined).
    pub fn stats(&self) -> &AccessStats {
        self.buf.stats()
    }

    /// Store `enc` into the least-worn free extent window, billing the
    /// write into both the pool-aggregate stats and `tenant` (the exact
    /// same `Energy::add` sequence a private buffer would perform — see
    /// [`MlcBuffer::store_at`] and [`super::StoreBill`]). Per-shard fault
    /// seeds are drawn from `rng` in shard order, so a tenant that replays
    /// its own seed stream gets bit-identical flip sets at any placement.
    pub fn alloc_store(
        &mut self,
        enc: &Encoded,
        model: &ErrorModel,
        rng: &mut Xoshiro256,
        workers: usize,
        tenant: &mut AccessStats,
    ) -> Result<PoolRegion, BufferError> {
        let need = enc.len().div_ceil(self.extent_words).max(1);
        let start = self
            .find_window(need)
            .ok_or(BufferError::CapacityExceeded {
                requested: enc.len(),
                free: self.free_extents() * self.extent_words,
            })?;
        let offset = start * self.extent_words;
        let (region, bill) = self.buf.store_at(enc, offset, model, rng, workers)?;

        // Replay the bill into the tenant's accumulator in fresh-store
        // order: shard partials, word count, then per-group metadata.
        for (energy, faults) in &bill.shards {
            tenant.write_energy.add(*energy);
            tenant.injected_faults += *faults;
        }
        tenant.writes += enc.len() as u64;
        for _ in 0..bill.meta_writes {
            tenant
                .write_energy
                .add(self.buf.config.cost.trilevel_cell(AccessKind::Write));
        }

        // Wear ledger: ownership, per-extent write counters, and per-bank
        // endurance stress over the intended image.
        let id = self.next_id;
        self.next_id += 1;
        for e in start..start + need {
            self.extents[e].owner = Some(id);
        }
        let banks = self.buf.config.banks;
        for (i, &w) in enc.words.iter().enumerate() {
            let e = start + i / self.extent_words;
            self.extents[e].writes += 1;
            self.bank_wear[e % banks].record_word(w);
        }

        Ok(PoolRegion {
            region,
            first_extent: start,
            n_extents: need,
            id,
        })
    }

    /// Release a region's extents back to the free pool. Wear counters
    /// are lifetime counters and survive the free (that's the point).
    /// A stale handle (extents since reallocated) releases nothing.
    pub fn free(&mut self, pr: &PoolRegion) {
        for e in pr.first_extent..pr.first_extent + pr.n_extents {
            if self.extents[e].owner == Some(pr.id) {
                self.extents[e].owner = None;
            }
        }
    }

    /// Fused load→decode of a pool region, billing the read into both the
    /// pool-aggregate stats and `tenant` (payload partial first, then one
    /// tri-level charge per group — the order a private buffer bills).
    pub fn load_decoded(
        &mut self,
        pr: &PoolRegion,
        out: &mut Vec<f32>,
        workers: usize,
        tenant: &mut AccessStats,
    ) -> Result<Energy, BufferError> {
        let energy = self.buf.load_decoded(&pr.region, out, workers)?;
        tenant.read_energy.add(energy);
        tenant.reads += pr.region.len as u64;
        for _ in 0..pr.region.meta_len {
            tenant
                .read_energy
                .add(self.buf.config.cost.trilevel_cell(AccessKind::Read));
        }
        Ok(energy)
    }

    /// One scrub pass over a tenant's pool region (DESIGN.md §15):
    /// delegate detection + repair to [`MlcBuffer::scrub_region`], replay
    /// the bill into `tenant` in the same order the pool-aggregate stats
    /// were charged (scan read first, then the dirty-shard writes in shard
    /// order), and — the part tenant churn alone would miss — charge every
    /// rewritten word to the per-extent write ledger and the per-bank
    /// [`WearTracker`]s, so wear-leveled placement sees scrub traffic
    /// exactly like store traffic.
    pub fn scrub_region(
        &mut self,
        pr: &PoolRegion,
        clean: &[u16],
        golden: &[u64],
        policy: &dyn ProtectionPolicy,
        tenant: &mut AccessStats,
    ) -> Result<RegionScrub, BufferError> {
        let pass = self.buf.scrub_region(&pr.region, clean, golden, policy)?;

        tenant.read_energy.add(pass.read_energy);
        tenant.reads += pr.region.len as u64;
        for &(_, energy) in &pass.write_shards {
            tenant.write_energy.add(energy);
        }
        tenant.writes += pass.rewritten_words;

        // Wear ledger: scrub rewrites program real cells. Stress is paid
        // for the intended (clean) image, like `alloc_store`.
        let banks = self.buf.config.banks;
        for &(k, _) in &pass.write_shards {
            let lo = k * LOAD_SHARD_WORDS;
            let hi = (lo + LOAD_SHARD_WORDS).min(pr.region.len);
            for (i, &w) in clean[lo..hi].iter().enumerate() {
                let e = pr.first_extent + (lo + i) / self.extent_words;
                self.extents[e].writes += 1;
                self.bank_wear[e % banks].record_word(w);
            }
        }
        Ok(pass)
    }

    /// Retention aging hook: re-run the write-path fault sampler over a
    /// resident region in place (the pool buffer's own seed stream, shard
    /// order), reporting per-shard flip counts. Faults are environmental —
    /// no energy is billed — but they count into both the pool-aggregate
    /// and the tenant's `injected_faults`.
    pub fn disturb_region(
        &mut self,
        pr: &PoolRegion,
        model: &ErrorModel,
        workers: usize,
        tenant: &mut AccessStats,
    ) -> Result<Vec<u64>, BufferError> {
        let per_shard = self
            .buf
            .corrupt_region_write_shards(&pr.region, model, workers)?;
        tenant.injected_faults += per_shard.iter().sum::<u64>();
        Ok(per_shard)
    }

    /// The "buffer lifetime under traffic" report: one row per bank with
    /// extent-write extremes and the endurance projection of the wear mix
    /// that bank has absorbed.
    pub fn bank_wear(&self) -> Vec<BankWear> {
        let banks = self.buf.config.banks;
        (0..banks)
            .map(|b| {
                let mut n = 0usize;
                let mut max = 0u64;
                let mut sum = 0u64;
                for e in (b..self.extents.len()).step_by(banks) {
                    n += 1;
                    max = max.max(self.extents[e].writes);
                    sum += self.extents[e].writes;
                }
                let t = &self.bank_wear[b];
                BankWear {
                    bank: b,
                    extents: n,
                    max_writes: max,
                    mean_writes: if n == 0 { 0.0 } else { sum as f64 / n as f64 },
                    stress_per_write: t.stress_per_write(),
                    relative_lifetime: t.relative_lifetime(),
                    writes_until_rated: t.writes_until_rated(),
                }
            })
            .collect()
    }

    /// Leveling quality: max over banks of total words written, divided
    /// by the mean across banks. 1.0 is perfectly level (and the value
    /// reported for an untouched pool); the allocator keeps this within
    /// [`Self::level_ratio`] under steady churn.
    pub fn wear_spread(&self) -> f64 {
        let banks = self.buf.config.banks;
        let totals: Vec<f64> = (0..banks)
            .map(|b| {
                (b..self.extents.len())
                    .step_by(banks)
                    .map(|e| self.extents[e].writes)
                    .sum::<u64>() as f64
            })
            .collect();
        let max = totals.iter().cloned().fold(0.0f64, f64::max);
        let mean = totals.iter().sum::<f64>() / totals.len().max(1) as f64;
        if mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Per-extent write counters, in extent order (test/diagnostic hook).
    pub fn extent_writes(&self) -> Vec<u64> {
        self.extents.iter().map(|e| e.writes).collect()
    }

    /// Deterministic wear-leveled placement: the free window of `need`
    /// contiguous extents minimizing `(contains-hot, max-writes,
    /// sum-writes, start)` lexicographically. Hot = write count strictly
    /// above `level_ratio ×` the mean extent write count.
    fn find_window(&self, need: usize) -> Option<usize> {
        let n = self.extents.len();
        if need > n {
            return None;
        }
        let mean = if n == 0 {
            0.0
        } else {
            self.extents.iter().map(|e| e.writes).sum::<u64>() as f64 / n as f64
        };
        let is_hot =
            |x: &Extent| x.writes > 0 && (x.writes as f64) > self.level_ratio * mean;
        let mut best: Option<(bool, u64, u64, usize)> = None;
        'windows: for s in 0..=n - need {
            let mut max_w = 0u64;
            let mut sum_w = 0u64;
            let mut hot = false;
            for x in &self.extents[s..s + need] {
                if x.owner.is_some() {
                    continue 'windows;
                }
                max_w = max_w.max(x.writes);
                sum_w += x.writes;
                hot |= is_hot(x);
            }
            let key = (hot, max_w, sum_w, s);
            if best.map_or(true, |b| key < b) {
                best = Some(key);
            }
        }
        best.map(|(_, _, _, s)| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::WeightCodec;
    use crate::fp;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| fp::quantize_f16((i as f32 / n as f32) * 1.8 - 0.9))
            .collect()
    }

    #[test]
    fn extents_are_bank_slot_aligned_and_disjoint() {
        // 16 extents of 64 words over 4 banks.
        let mut pool = SharedMlcBuffer::new(16 * 64 * 2, 4, 64, 1);
        let enc = WeightCodec::hybrid(4).encode(&ramp(100)); // 2 extents
        let model = ErrorModel::at_rate(0.0);
        let mut rng = Xoshiro256::seeded(7);
        let mut stats = AccessStats::default();
        let a = pool
            .alloc_store(&enc, &model, &mut rng, 1, &mut stats)
            .unwrap();
        let b = pool
            .alloc_store(&enc, &model, &mut rng, 1, &mut stats)
            .unwrap();
        assert_eq!(a.region.offset, a.first_extent * 64);
        assert_eq!(a.n_extents, 2);
        assert_eq!(a.region.offset % 4, 0, "starts at a fresh bank slot");
        let (a0, a1) = (a.first_extent, a.first_extent + a.n_extents);
        let (b0, b1) = (b.first_extent, b.first_extent + b.n_extents);
        assert!(a1 <= b0 || b1 <= a0, "extent runs overlap");
        assert_eq!(pool.free_extents(), 12);
    }

    #[test]
    fn freed_extents_are_reused_and_stale_handles_are_inert() {
        let mut pool = SharedMlcBuffer::new(4 * 32 * 2, 4, 32, 1);
        let enc = WeightCodec::hybrid(4).encode(&ramp(100)); // 4 extents
        let model = ErrorModel::at_rate(0.0);
        let mut rng = Xoshiro256::seeded(7);
        let mut stats = AccessStats::default();
        let a = pool
            .alloc_store(&enc, &model, &mut rng, 1, &mut stats)
            .unwrap();
        assert!(matches!(
            pool.alloc_store(&enc, &model, &mut rng, 1, &mut stats),
            Err(BufferError::CapacityExceeded { .. })
        ));
        pool.free(&a);
        let b = pool
            .alloc_store(&enc, &model, &mut rng, 1, &mut stats)
            .unwrap();
        // The stale handle to `a` must not free `b`'s extents.
        pool.free(&a);
        assert_eq!(pool.free_extents(), 0);
        let mut out = Vec::new();
        pool.load_decoded(&b, &mut out, 1, &mut stats).unwrap();
        assert_eq!(out, enc.decode());
    }

    #[test]
    fn leveling_rotates_round_robin_over_equal_wear() {
        // 8 one-extent slots; alloc/free the same 1-extent tensor: with
        // all-free equal wear the allocator must sweep the plane instead
        // of re-burning extent 0.
        let mut pool = SharedMlcBuffer::new(8 * 16 * 2, 4, 16, 1);
        let enc = WeightCodec::hybrid(4).encode(&ramp(16));
        let model = ErrorModel::at_rate(0.0);
        let mut rng = Xoshiro256::seeded(7);
        let mut stats = AccessStats::default();
        let mut placements = Vec::new();
        for _ in 0..8 {
            let r = pool
                .alloc_store(&enc, &model, &mut rng, 1, &mut stats)
                .unwrap();
            placements.push(r.first_extent);
            pool.free(&r);
        }
        assert_eq!(placements, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        assert!((pool.wear_spread() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn scrub_rewrites_charge_wear_and_rotate_placement() {
        use crate::encoding::{protection_for, Policy};
        // 8 one-extent slots. Park a tenant in extent 0, disturb + scrub
        // it repeatedly, then free it: the next allocation must avoid the
        // scrub-burned extent exactly as it avoids store-churn wear.
        let mut pool = SharedMlcBuffer::new(8 * 16 * 2, 4, 16, 1);
        let enc = WeightCodec::hybrid(4).encode(&ramp(16));
        let golden = super::super::shard_checksums(&enc.words);
        let prot = protection_for(Policy::Hybrid, 4);
        let model = ErrorModel::at_rate(0.0);
        let hot = ErrorModel::at_rate(1.0);
        let mut rng = Xoshiro256::seeded(7);
        let mut stats = AccessStats::default();
        let a = pool
            .alloc_store(&enc, &model, &mut rng, 1, &mut stats)
            .unwrap();
        assert_eq!(a.first_extent, 0);
        let before = pool.extent_writes()[0];
        let mut rewrites = 0u64;
        for _ in 0..6 {
            let flips = pool.disturb_region(&a, &hot, 1, &mut stats).unwrap();
            assert!(flips.iter().sum::<u64>() > 0);
            let pass = pool
                .scrub_region(&a, &enc.words, &golden, prot.as_ref(), &mut stats)
                .unwrap();
            rewrites += pass.rewritten_words;
        }
        assert!(rewrites > 0);
        assert_eq!(
            pool.extent_writes()[0],
            before + rewrites,
            "scrub rewrites missing from the extent ledger"
        );
        pool.free(&a);
        let b = pool
            .alloc_store(&enc, &model, &mut rng, 1, &mut stats)
            .unwrap();
        assert_ne!(b.first_extent, 0, "placement ignored scrub wear");
    }
}
