//! End-to-end experiment drivers shared by the CLI, benches, and examples.
//!
//! Each driver corresponds to one paper artifact (DESIGN.md §5) and returns
//! both the printable table and the raw rows so callers can post-process.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::{InferenceEngine, StoreConfig, WeightStore};
use crate::encoding::Policy;
use crate::metrics::{accuracy_table, AccuracyRow, Table};
use crate::runtime::artifacts::{model_paths, Manifest, TestSet, WeightFile};
use crate::runtime::Executor;
use crate::stt::ErrorModel;

/// Result of the Fig. 8 experiment for one model.
pub struct AccuracyExperiment {
    pub model: String,
    pub error_free: f64,
    pub rows: Vec<AccuracyRow>,
    pub table: Table,
}

/// Load manifest + weights for a model, validating consistency.
pub fn load_model(dir: &Path, model: &str) -> Result<(Manifest, WeightFile)> {
    let (_, wpath, mpath) = model_paths(dir, model);
    let manifest =
        Manifest::read(&mpath).with_context(|| format!("{model}: run `make artifacts` first"))?;
    let weights = WeightFile::read(&wpath)?;
    manifest.validate(&weights)?;
    Ok((manifest, weights))
}

/// The full Fig. 8 pipeline for one model: error-free reference, then the
/// four protection systems (unprotected / +round / +rotate / hybrid) at the
/// given soft-error `rate` and metadata `granularity`, each evaluated on
/// `eval` held-out images through the PJRT executable.
pub fn run_accuracy_experiment(
    dir: &Path,
    model: &str,
    rate: f64,
    granularity: usize,
    eval: usize,
    seed: u64,
) -> Result<AccuracyExperiment> {
    let (manifest, weights) = load_model(dir, model)?;
    let (hlo, _, _) = model_paths(dir, model);
    let test = TestSet::read(&dir.join("testset.bin"))?;

    // Error-free reference on the same evaluation slice. A single executor
    // is reused across systems: weights are re-staged per system, the
    // compiled executable is not rebuilt (the HLO compile dominates
    // end-to-end time; see EXPERIMENTS.md §Perf).
    let exec = Executor::from_hlo_file(&hlo)?;
    let mut engine = InferenceEngine::new(exec, manifest.clone(), &weights.params)?;
    let (error_free, _, _) = engine.accuracy(&test, eval)?;

    let mut rows = Vec::new();
    for policy in Policy::ALL {
        let cfg = StoreConfig {
            policy,
            granularity,
            error_model: ErrorModel::at_rate(rate),
            seed,
            ..StoreConfig::default()
        };
        let mut store = WeightStore::load(&cfg, &weights)?;
        let tensors = store.materialize()?;
        let report = store.report();
        engine.restage(&tensors)?;
        let (acc, _, _) = engine.accuracy(&test, eval)?;
        rows.push(AccuracyRow {
            system: policy.label().into(),
            accuracy: acc,
            flipped_cells: report.injected_faults,
        });
    }
    let table = accuracy_table(
        &format!("{model} (rate={rate}, g={granularity}, eval={eval}, seed={seed})"),
        error_free,
        &rows,
    );
    Ok(AccuracyExperiment {
        model: model.to_string(),
        error_free,
        rows,
        table,
    })
}
