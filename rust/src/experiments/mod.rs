//! End-to-end experiment drivers shared by the CLI, benches, and examples.
//!
//! Each driver corresponds to one paper artifact (DESIGN.md §5) and returns
//! both the printable table and the raw rows so callers can post-process.
//! Since the facade (DESIGN.md §10) every driver stages its weights
//! through [`Deployment`] — the lifecycle they used to hand-roll — and
//! `rust/tests/api_facade.rs` pins the rebuilt paths bit-identical to the
//! pre-facade ones.

use std::path::Path;

use anyhow::{Context, Result};

use crate::api::Deployment;
use crate::coordinator::{InferenceEngine, StoreConfig, StoreReport};
use crate::encoding::Policy;
use crate::metrics::{accuracy_table, AccuracyRow, Table};
use crate::runtime::artifacts::{model_paths, Manifest, ParamSpec, TestSet, WeightFile};
use crate::runtime::Executor;
use crate::stt::ErrorModel;

/// Result of the Fig. 8 experiment for one model.
pub struct AccuracyExperiment {
    pub model: String,
    pub error_free: f64,
    pub rows: Vec<AccuracyRow>,
    pub table: Table,
}

/// Load manifest + weights for a model, validating consistency.
pub fn load_model(dir: &Path, model: &str) -> Result<(Manifest, WeightFile)> {
    let (_, wpath, mpath) = model_paths(dir, model);
    let manifest =
        Manifest::read(&mpath).with_context(|| format!("{model}: run `make artifacts` first"))?;
    let weights = WeightFile::read(&wpath)?;
    manifest.validate(&weights)?;
    Ok((manifest, weights))
}

/// The full Fig. 8 pipeline for one model: error-free reference, then the
/// four protection systems (unprotected / +round / +rotate / hybrid) at the
/// given soft-error `rate` and metadata `granularity`, each evaluated on
/// `eval` held-out images through the PJRT executable. Each system's
/// weight path is one [`Deployment`] build; a single compiled executor is
/// reused across systems via [`InferenceEngine::restage`] (the HLO
/// compile dominates end-to-end time; see EXPERIMENTS.md §Perf).
pub fn run_accuracy_experiment(
    dir: &Path,
    model: &str,
    rate: f64,
    granularity: usize,
    eval: usize,
    seed: u64,
) -> Result<AccuracyExperiment> {
    let (manifest, weights) = load_model(dir, model)?;
    let (hlo, _, _) = model_paths(dir, model);
    let test = TestSet::read(&dir.join("testset.bin"))?;

    let exec = Executor::from_hlo_file(&hlo)?;
    let mut engine = InferenceEngine::new(exec, manifest.clone(), &weights.params)?;
    let (error_free, _, _) = engine.accuracy(&test, eval)?;

    let mut rows = Vec::new();
    for policy in Policy::ALL {
        let dep = Deployment::builder()
            .weights_ref(&weights)
            .name(model)
            .store(StoreConfig {
                policy,
                granularity,
                error_model: ErrorModel::at_rate(rate),
                seed,
                ..StoreConfig::default()
            })
            .build()?;
        engine.restage(dep.tensors())?;
        let (acc, _, _) = engine.accuracy(&test, eval)?;
        rows.push(AccuracyRow {
            system: policy.label().into(),
            accuracy: acc,
            flipped_cells: dep.store_report().injected_faults,
        });
    }
    let table = accuracy_table(
        &format!("{model} (rate={rate}, g={granularity}, eval={eval}, seed={seed})"),
        error_free,
        &rows,
    );
    Ok(AccuracyExperiment {
        model: model.to_string(),
        error_free,
        rows,
        table,
    })
}

// -------------------------------------------------------------- rate sweep

/// One error-rate point of a sweep: per-policy accuracy rows (in the
/// sweep's policy-axis order — [`Policy::ALL`] for the legacy drivers)
/// plus the matching store reports.
pub struct RatePoint {
    pub rate: f64,
    pub rows: Vec<AccuracyRow>,
    pub reports: Vec<StoreReport>,
}

/// Result of a Fig. 8-style error-rate sweep ([`run_rate_sweep`]).
pub struct RateSweep {
    pub model: String,
    pub error_free: f64,
    pub points: Vec<RatePoint>,
    /// Encode+store passes actually performed. The sweep's perf contract
    /// — asserted by `rust/tests/sweep_equivalence.rs` — is exactly one
    /// per policy, independent of the number of rate points.
    pub encode_passes: usize,
    pub table: Table,
}

/// Engine-agnostic core of the snapshot-reuse sweep (DESIGN.md §9/§10):
/// one staged [`Deployment`] per policy (encode + store the clean image
/// **once**), snapshot, then per rate point rewind + re-inject before
/// materializing for `eval`. With `reuse_clean` the materialize is
/// flip-set-aware: tensors whose regions took zero flips at a point reuse
/// the cached clean decode and replay its read bill
/// ([`crate::coordinator::WeightStore::materialize_reusing`]) — output
/// and accounting stay bit-identical to re-decoding everything.
fn rate_sweep_core<E>(
    weights: &WeightFile,
    base: &StoreConfig,
    rates: &[f64],
    policies: &[Policy],
    reuse_clean: bool,
    mut eval: E,
) -> Result<(Vec<RatePoint>, usize)>
where
    E: FnMut(Policy, f64, &[ParamSpec], &StoreReport) -> Result<f64>,
{
    let mut points: Vec<RatePoint> = rates
        .iter()
        .map(|&rate| RatePoint {
            rate,
            rows: Vec::new(),
            reports: Vec::new(),
        })
        .collect();
    let mut encode_passes = 0usize;
    for &policy in policies {
        let mut dep = Deployment::builder()
            .weights_ref(weights)
            .store(StoreConfig {
                policy,
                error_model: ErrorModel::at_rate(0.0),
                ..base.clone()
            })
            .staged()
            .build()
            .with_context(|| format!("storing {} image", policy.label()))?;
        encode_passes += 1;
        let snap = dep.snapshot();
        let cache = if reuse_clean {
            // Billed reads are rewound by the first reinject's restore,
            // so the capture never surfaces in a point's report.
            Some(dep.materialize_clean_cache()?)
        } else {
            None
        };
        for (point, &rate) in points.iter_mut().zip(rates) {
            dep.reinject(&snap, &ErrorModel::at_rate(rate), base.seed)?;
            match &cache {
                Some(cache) => dep.materialize_reusing(cache)?,
                None => dep.materialize()?,
            };
            let report = dep.store_report().clone();
            let accuracy = eval(policy, rate, dep.tensors(), &report)?;
            point.rows.push(AccuracyRow {
                system: policy.label().into(),
                accuracy,
                flipped_cells: report.injected_faults,
            });
            point.reports.push(report);
        }
    }
    Ok((points, encode_passes))
}

/// The snapshot-reuse sweep with the flip-set-aware materialize
/// (DESIGN.md §9/§10): encode and store each policy's image **once**
/// (fault-free), snapshot the stored words, and per rate point only
/// rewind + re-inject before materializing — where tensors untouched by
/// that point's flips reuse the cached clean decode. Flip sets,
/// accuracies, and accounting are bit-identical to building a fresh
/// store per (policy, rate) — at one encode/store instead of
/// `rates.len()` per policy.
///
/// `eval` receives `(policy, rate, tensors, report)` and returns the
/// accuracy to record; `base.seed` seeds every point's fault injection
/// (one seed, rate-indexed flip sets stay comparable across policies).
/// Returns the points (indexed like `rates`) and the number of
/// encode+store passes performed.
pub fn run_rate_sweep_with<E>(
    weights: &WeightFile,
    base: &StoreConfig,
    rates: &[f64],
    eval: E,
) -> Result<(Vec<RatePoint>, usize)>
where
    E: FnMut(Policy, f64, &[ParamSpec], &StoreReport) -> Result<f64>,
{
    rate_sweep_core(weights, base, rates, &Policy::ALL, true, eval)
}

/// [`run_rate_sweep_with`] over an explicit policy axis — the
/// `--policies` front of `mlcstt sweep`. Rows inside each point follow
/// `policies` order; passing [`Policy::ALL`] reproduces the legacy sweep
/// exactly (same deployments, same flip sets, same rows).
pub fn run_policy_sweep_with<E>(
    weights: &WeightFile,
    base: &StoreConfig,
    rates: &[f64],
    policies: &[Policy],
    eval: E,
) -> Result<(Vec<RatePoint>, usize)>
where
    E: FnMut(Policy, f64, &[ParamSpec], &StoreReport) -> Result<f64>,
{
    rate_sweep_core(weights, base, rates, policies, true, eval)
}

/// [`run_rate_sweep_with`] minus the flip-set-aware shortcut: every point
/// re-decodes every tensor. Kept as the always-rematerialize **oracle**
/// the fast path is pinned against (`rust/tests/api_facade.rs`).
pub fn run_rate_sweep_with_rematerialize<E>(
    weights: &WeightFile,
    base: &StoreConfig,
    rates: &[f64],
    eval: E,
) -> Result<(Vec<RatePoint>, usize)>
where
    E: FnMut(Policy, f64, &[ParamSpec], &StoreReport) -> Result<f64>,
{
    rate_sweep_core(weights, base, rates, &Policy::ALL, false, eval)
}

/// Render sweep points as one table: a row per (rate, policy) with
/// accuracy, delta vs the error-free reference, flips, and the energy
/// bill at that point.
pub fn rate_sweep_table(title: &str, error_free: f64, points: &[RatePoint]) -> Table {
    let mut t = Table::new(
        &format!("Fig.8 sweep — {title} (error-free = {error_free:.4})"),
        &["rate", "system", "accuracy", "delta", "flips", "read nJ", "write nJ"],
    );
    for p in points {
        for (row, report) in p.rows.iter().zip(&p.reports) {
            t.row(vec![
                format!("{:.4}", p.rate),
                row.system.clone(),
                format!("{:.4}", row.accuracy),
                format!("{:+.4}", row.accuracy - error_free),
                row.flipped_cells.to_string(),
                format!("{:.1}", report.read_energy.nanojoules),
                format!("{:.1}", report.write_energy.nanojoules),
            ]);
        }
    }
    t
}

/// The full Fig. 8 accuracy-vs-error-rate sweep for one model through the
/// PJRT executable: error-free reference once, then [`run_rate_sweep_with`]
/// over `rates`, restaging each point's corrupted tensors into a single
/// compiled engine. One encode+store per policy for the whole sweep.
pub fn run_rate_sweep(
    dir: &Path,
    model: &str,
    rates: &[f64],
    granularity: usize,
    eval: usize,
    seed: u64,
) -> Result<RateSweep> {
    run_rate_sweep_policies(dir, model, rates, &Policy::ALL, granularity, eval, seed)
}

/// [`run_rate_sweep`] over an explicit policy axis (the
/// `mlcstt sweep --policies` path): identical pipeline, rows keyed by the
/// given policies instead of the fixed Fig. 8 four.
pub fn run_rate_sweep_policies(
    dir: &Path,
    model: &str,
    rates: &[f64],
    policies: &[Policy],
    granularity: usize,
    eval: usize,
    seed: u64,
) -> Result<RateSweep> {
    let (manifest, weights) = load_model(dir, model)?;
    let (hlo, _, _) = model_paths(dir, model);
    let test = TestSet::read(&dir.join("testset.bin"))?;

    let exec = Executor::from_hlo_file(&hlo)?;
    let mut engine = InferenceEngine::new(exec, manifest.clone(), &weights.params)?;
    let (error_free, _, _) = engine.accuracy(&test, eval)?;

    let base = StoreConfig {
        granularity,
        seed,
        ..StoreConfig::default()
    };
    let (points, encode_passes) =
        run_policy_sweep_with(&weights, &base, rates, policies, |_, _, tensors, _| {
            engine.restage(tensors)?;
            let (acc, _, _) = engine.accuracy(&test, eval)?;
            Ok(acc)
        })?;
    let table = rate_sweep_table(
        &format!("{model} (g={granularity}, eval={eval}, seed={seed})"),
        error_free,
        &points,
    );
    Ok(RateSweep {
        model: model.to_string(),
        error_free,
        points,
        encode_passes,
        table,
    })
}
