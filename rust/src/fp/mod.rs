//! IEEE 754 binary16 (half precision) implemented from scratch.
//!
//! The paper stores CNN weights as half-precision words in 2-bit MLC
//! STT-RAM cells. Layout (bit 15 = MSB):
//!
//! ```text
//!   15   14..10    9..0
//!   sign exponent  mantissa        (bias 15)
//! ```
//!
//! The central observation (paper §4.1): for any |w| < 2 the exponent is at
//! most 15 (`01111`), so **bit 14 — the exponent MSB — is always zero**.
//! Weights are normalized into [-1, 1], so bit 14 is free to host a backup
//! of the sign bit; see [`crate::encoding`].
//!
//! Conversion implements round-to-nearest-even, subnormals, infinities and
//! NaN, and is verified against an exhaustive u16 round-trip plus reference
//! vectors (including the paper's own Table 2 weights).

/// Number of 2-bit MLC cells in one binary16 word.
pub const CELLS_PER_WORD: usize = 8;

/// Sign bit mask (bit 15).
pub const SIGN_MASK: u16 = 0x8000;
/// The "unused" bit for weights in [-1, 1]: exponent MSB (bit 14).
pub const BACKUP_MASK: u16 = 0x4000;

/// Convert an `f32` to binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf / NaN: preserve NaN-ness with a quiet mantissa bit.
        return if man == 0 {
            sign | 0x7C00
        } else {
            sign | 0x7E00
        };
    }

    // Unbiased exponent, rebiased for f16 (bias 15).
    let e16 = exp - 127 + 15;

    if e16 >= 0x1F {
        // Overflow -> infinity.
        return sign | 0x7C00;
    }

    if e16 <= 0 {
        // Subnormal or zero in f16.
        if e16 < -10 {
            return sign; // underflows to zero even after rounding
        }
        // Implicit leading 1, then shift into subnormal position.
        let man = man | 0x80_0000;
        let shift = 14 - e16; // 14..24
        let half = 1u32 << (shift - 1);
        let rounded = man + half - 1 + ((man >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }

    // Normal: keep top 10 mantissa bits, round-to-nearest-even on bit 12.
    let half = 0x0FFF + ((man >> 13) & 1);
    let man_r = man + half;
    if man_r & 0x80_0000 != 0 {
        // Mantissa rounding overflowed into the exponent.
        let e16 = e16 + 1;
        if e16 >= 0x1F {
            return sign | 0x7C00;
        }
        return sign | ((e16 as u16) << 10);
    }
    sign | ((e16 as u16) << 10) | (man_r >> 13) as u16
}

/// Convert binary16 bits to `f32` (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;

    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, _) => {
            // Subnormal: value = man * 2^-24. Normalize around the leading
            // set bit at position p (0..=9): value = 2^(p-24) * (1 + rest/2^p).
            let p = 31 - man.leading_zeros();
            let exp_n = 103 + p; // 127 + p - 24
            let man_n = (man << (10 - p)) & 0x3FF;
            sign | (exp_n << 23) | (man_n << 13)
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, _) => sign | 0x7FC0_0000 | (man << 13),
        _ => sign | ((exp + 127 - 15) << 23) | (man << 13),
    };
    f32::from_bits(bits)
}

/// Round-trip helper: quantize an f32 through binary16.
pub fn quantize_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// True iff bit 14 (exponent MSB) is clear — holds for all |w| < 2,
/// i.e. for every normalized CNN weight. This is the precondition for
/// sign-bit protection.
pub fn backup_bit_free(h: u16) -> bool {
    h & BACKUP_MASK == 0
}

/// The 2-bit cell contents of a word, MSB-first: cell 0 = bits 15..14
/// (sign + backup), cell 7 = bits 1..0.
#[inline]
pub fn cells(h: u16) -> [u8; CELLS_PER_WORD] {
    let mut out = [0u8; CELLS_PER_WORD];
    for (i, c) in out.iter_mut().enumerate() {
        *c = ((h >> (14 - 2 * i)) & 0b11) as u8;
    }
    out
}

/// Rebuild a word from its 8 cells (inverse of [`cells`]).
#[inline]
pub fn from_cells(cs: &[u8; CELLS_PER_WORD]) -> u16 {
    let mut h = 0u16;
    for (i, &c) in cs.iter().enumerate() {
        debug_assert!(c <= 0b11);
        h |= (c as u16) << (14 - 2 * i);
    }
    h
}

/// Counts of the four 2-bit patterns in one word: `[n00, n01, n10, n11]`.
/// The paper's Fig. 6 statistic; "soft" (vulnerable, 2-pulse) cells are
/// `01`/`10`, "easy" cells are `00`/`11`.
#[inline]
pub fn pattern_counts(h: u16) -> [u32; 4] {
    let mut counts = [0u32; 4];
    let mut w = h;
    // Cells are independent 2-bit fields; order doesn't matter for counting.
    for _ in 0..CELLS_PER_WORD {
        counts[(w & 0b11) as usize] += 1;
        w >>= 2;
    }
    counts
}

/// Number of vulnerable/expensive cells (`01` or `10`) — branchless.
///
/// A cell is soft iff its two bits differ, so XOR the odd/even bit planes
/// and popcount.
#[inline]
pub fn soft_cells(h: u16) -> u32 {
    let odd = h >> 1;
    ((h ^ odd) & 0x5555).count_ones()
}

/// Flip bit `pos` (0 = LSB .. 15 = sign).
#[inline]
pub fn flip_bit(h: u16, pos: u32) -> u16 {
    debug_assert!(pos < 16);
    h ^ (1 << pos)
}

// ------------------------------------------------------- fast converters
//
// `f32↔f16` conversion is the decode floor (ROADMAP): every stage
// downstream of the codec moves u16 words around, but the first and last
// touch of every weight is a conversion. Two accelerated implementations
// live here, both bit-exact against the scalar reference above (pinned
// exhaustively over all 65536 patterns by `rust/tests/read_path.rs`):
//
// * a 16-bit-indexed **lookup table** — 32768 magnitude entries (128 KB,
//   built once via `OnceLock`); the sign transfers with one shift-OR, so
//   the table only needs `h & 0x7FFF`;
// * a **branchless converter** — all three input classes (normal,
//   subnormal/zero, Inf/NaN) computed unconditionally and merged with
//   mask arithmetic, no data-dependent branches.
//
// The batch entry points ([`decode_f16_slice`], [`quantize_into`]) pick an
// implementation once per process via [`f16_mode`]; the scalar functions
// remain the oracle and the `MLCSTT_F16=scalar` escape hatch.

use std::sync::OnceLock;

/// Which `f16↔f32` converter the batch paths use. Resolved once from the
/// `MLCSTT_F16` environment variable (`lut` | `branchless` | `scalar`);
/// the default is [`F16Mode::Lut`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum F16Mode {
    /// 128 KB magnitude-indexed decode table (default — fastest on every
    /// target with a sane L2).
    Lut,
    /// Branch-free bit manipulation; no table, no cache footprint.
    Branchless,
    /// The reference converters, kept as oracle and escape hatch.
    Scalar,
}

/// Process-wide converter selection, latched on first resolution.
static MODE: OnceLock<F16Mode> = OnceLock::new();

/// The converter selection for this process (see [`F16Mode`]). Resolved on
/// first use from the environment layer ([`crate::util::env::f16_mode`],
/// default [`F16Mode::Lut`]) unless [`pin_f16_mode`] resolved it first.
pub fn f16_mode() -> F16Mode {
    *MODE.get_or_init(|| crate::util::env::f16_mode().unwrap_or(F16Mode::Lut))
}

/// Pin the process converter to `mode` — the builder layer of
/// [`crate::api::Config`]. First resolution wins: if a conversion (or an
/// earlier pin) already latched the mode, the existing selection is kept.
/// Returns the effective mode either way. All modes are bit-exact, so a
/// lost pin changes speed, never results.
pub fn pin_f16_mode(mode: F16Mode) -> F16Mode {
    *MODE.get_or_init(|| mode)
}

/// Magnitude half of the decode LUT: entry `m` holds the f32 bit pattern
/// of the f16 word `m` (`m < 0x8000`); negative words OR the sign into
/// bit 31. 32768 × 4 bytes = 128 KB, built once on first use.
fn f16_mag_lut() -> &'static [u32] {
    static LUT: OnceLock<Box<[u32]>> = OnceLock::new();
    LUT.get_or_init(|| {
        (0..0x8000u32)
            .map(|m| f16_bits_to_f32(m as u16).to_bits())
            .collect()
    })
}

/// [`f16_bits_to_f32`] via the 128 KB magnitude LUT (exact).
#[inline]
pub fn f16_bits_to_f32_lut(h: u16) -> f32 {
    let mag = f16_mag_lut()[(h & 0x7FFF) as usize];
    f32::from_bits(mag | (((h & 0x8000) as u32) << 16))
}

/// [`f16_bits_to_f32`] without branches: the normal, subnormal/zero, and
/// Inf/NaN images are all computed, then merged with comparison masks.
/// Exact for every one of the 65536 patterns (NaNs quieted exactly as the
/// scalar path quiets them).
#[inline]
pub fn f16_bits_to_f32_branchless(h: u16) -> f32 {
    let mag = (h & 0x7FFF) as u32;
    let sign = ((h & 0x8000) as u32) << 16;
    // Normal: shift exponent+mantissa into place, rebias 15 -> 127.
    let norm = (mag << 13) + (112u32 << 23);
    // Subnormal/zero: value = mag * 2^-24, exact in f32 (mag < 2^11).
    let sub = (mag as f32 * f32::from_bits(0x3380_0000)).to_bits();
    // All-ones / all-zero class masks.
    let is_sub = 0u32.wrapping_sub((mag < 0x0400) as u32);
    let is_inf_nan = 0u32.wrapping_sub((mag >= 0x7C00) as u32);
    let is_nan = 0u32.wrapping_sub((mag > 0x7C00) as u32);
    // Inf/NaN: push the rebiased exponent (143) up to 255; quiet NaNs the
    // way the scalar converter does (OR the quiet bit).
    let special = norm + (112u32 << 23);
    let bits = (norm & !is_sub & !is_inf_nan)
        | (sub & is_sub)
        | (special & is_inf_nan)
        | (is_nan & 0x0040_0000);
    f32::from_bits(bits | sign)
}

/// [`f32_to_f16_bits`] via the magic-addend method (Giesen's
/// `float_to_half_fast3_rtne`): round-to-nearest-even happens inside one
/// FPU add for the subnormal range and one integer add for normals, so the
/// only branches are the two class selects (compiled to cmovs). Bit-exact
/// against the scalar converter, including overflow-to-infinity at the
/// rounding boundary and NaN quieting.
#[inline]
pub fn f32_to_f16_bits_fast(x: f32) -> u16 {
    const F32_INFTY: u32 = 255 << 23;
    // Smallest magnitude that overflows f16 even before rounding (2^16).
    const F16_MAX: u32 = (127 + 16) << 23;
    // 0.5f32: adding it to a would-be-subnormal aligns the mantissa so the
    // FPU's own round-to-nearest-even produces the f16 subnormal bits.
    const DENORM_MAGIC: u32 = ((127 - 15) + (23 - 10) + 1) << 23;
    let bits = x.to_bits();
    let sign = bits & 0x8000_0000;
    let f = bits ^ sign;
    let o: u16 = if f >= F16_MAX {
        if f > F32_INFTY {
            0x7E00 // NaN -> quiet NaN
        } else {
            0x7C00 // overflow / Inf -> Inf
        }
    } else if f < (113 << 23) {
        // Subnormal-or-zero result: the magic addend performs the shift
        // and the tie-to-even rounding in one float add.
        let v = f32::from_bits(f) + f32::from_bits(DENORM_MAGIC);
        (v.to_bits() - DENORM_MAGIC) as u16
    } else {
        // Normal: rebias and round in integer space; a mantissa carry
        // propagates into the exponent exactly as IEEE requires.
        let mant_odd = (f >> 13) & 1;
        let adj = f.wrapping_add(0xC800_0FFF).wrapping_add(mant_odd);
        (adj >> 13) as u16
    };
    o | ((sign >> 16) as u16)
}

/// Convert a stored-word slice to f32 through the converter selected by
/// [`f16_mode`] (the codec's decode inner loop).
pub fn decode_f16_slice(src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len(), "decode_f16_slice length mismatch");
    match f16_mode() {
        F16Mode::Lut => {
            let lut = f16_mag_lut();
            for (d, &h) in dst.iter_mut().zip(src) {
                *d = f32::from_bits(
                    lut[(h & 0x7FFF) as usize] | (((h & 0x8000) as u32) << 16),
                );
            }
        }
        F16Mode::Branchless => {
            for (d, &h) in dst.iter_mut().zip(src) {
                *d = f16_bits_to_f32_branchless(h);
            }
        }
        F16Mode::Scalar => {
            for (d, &h) in dst.iter_mut().zip(src) {
                *d = f16_bits_to_f32(h);
            }
        }
    }
}

// ------------------------------------------------------------------ SWAR
//
// Word-packed variants of the cell statistics: four binary16 words ride in
// one `u64` lane group (lane `i` = bits `16i..16i+16`), and the per-cell
// counts fall out of plain 64-bit bitwise ops + one popcount instead of an
// 8-iteration per-word loop. Lane boundaries sit on multiples of 16, and
// every mask below is lane-local, so no shift ever leaks bits across
// words (the `>> 1` variants are masked back to even bit positions).
// `rust/src/encoding/swar.rs` builds the reformation kernels on the same
// packing; `rust/tests/swar_equivalence.rs` pins all of it against the
// scalar path over every one of the 65536 bit patterns.

/// Words per `u64` lane group in the packed hot path.
pub const LANES: usize = 4;

/// Even (intra-cell low) bit positions of all four lanes.
const EVEN4: u64 = 0x5555_5555_5555_5555;

/// Pack four words, lane 0 in the low 16 bits.
#[inline]
pub fn pack4(ws: [u16; LANES]) -> u64 {
    (ws[0] as u64) | ((ws[1] as u64) << 16) | ((ws[2] as u64) << 32) | ((ws[3] as u64) << 48)
}

/// Inverse of [`pack4`].
#[inline]
pub fn unpack4(x: u64) -> [u16; LANES] {
    [x as u16, (x >> 16) as u16, (x >> 32) as u16, (x >> 48) as u16]
}

/// Vulnerable (`01`/`10`) cells across all four packed words: the two bits
/// of a cell differ iff `x ^ (x >> 1)` is set at the cell's low bit.
#[inline]
pub fn soft_cells_packed(x: u64) -> u32 {
    ((x ^ (x >> 1)) & EVEN4).count_ones()
}

/// Pattern census `[n00, n01, n10, n11]` across all four packed words
/// (32 cells per lane group).
#[inline]
pub fn pattern_counts_packed(x: u64) -> [u32; 4] {
    let hi = x >> 1;
    let n11 = (x & hi & EVEN4).count_ones();
    let n01 = (x & !hi & EVEN4).count_ones();
    let n10 = (!x & hi & EVEN4).count_ones();
    [32 - n11 - n01 - n10, n01, n10, n11]
}

// ------------------------------------------------------------- batch API

/// Quantize a slice of f32 weights to binary16 bits into a caller buffer
/// (same length). The slice form lets threaded callers write disjoint
/// output shards without allocating. Uses the fast converter unless
/// `MLCSTT_F16=scalar` (see [`f16_mode`]); both are bit-exact.
pub fn quantize_into(src: &[f32], dst: &mut [u16]) {
    assert_eq!(src.len(), dst.len(), "quantize_into length mismatch");
    if f16_mode() == F16Mode::Scalar {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = f32_to_f16_bits(s);
        }
    } else {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = f32_to_f16_bits_fast(s);
        }
    }
}

/// Pattern census over a word stream via the packed kernel (Fig. 6 outer
/// loop): `[n00, n01, n10, n11]` summed over every word.
pub fn count_patterns_packed(words: &[u16]) -> [u64; 4] {
    let mut acc = [0u64; 4];
    let mut chunks = words.chunks_exact(LANES);
    for c in &mut chunks {
        let pc = pattern_counts_packed(pack4([c[0], c[1], c[2], c[3]]));
        for (a, &p) in acc.iter_mut().zip(&pc) {
            *a += p as u64;
        }
    }
    for &w in chunks.remainder() {
        let pc = pattern_counts(w);
        for (a, &p) in acc.iter_mut().zip(&pc) {
            *a += p as u64;
        }
    }
    acc
}

/// Total vulnerable cells over a word stream via the packed kernel.
pub fn soft_cells_batch(words: &[u16]) -> u64 {
    let mut total = 0u64;
    let mut chunks = words.chunks_exact(LANES);
    for c in &mut chunks {
        total += soft_cells_packed(pack4([c[0], c[1], c[2], c[3]])) as u64;
    }
    for &w in chunks.remainder() {
        total += soft_cells(w) as u64;
    }
    total
}

/// [`count_patterns_packed`] sharded across at most `workers` threads via
/// [`crate::util::threads::run_sharded`] (the same template as
/// `swar::energy_tally_threaded`). The census is a per-word integer sum,
/// so shard boundaries cannot affect it: every worker count returns the
/// identical histogram, not merely an equivalent one.
pub fn count_patterns_threaded(words: &[u16], workers: usize) -> [u64; 4] {
    let bounds = crate::util::threads::chunk_bounds(words.len(), 1, workers);
    if bounds.len() <= 1 {
        return count_patterns_packed(words);
    }
    let jobs: Vec<&[u16]> = bounds.iter().map(|&(s, e)| &words[s..e]).collect();
    let mut acc = [0u64; 4];
    for partial in crate::util::threads::run_sharded(jobs, workers, count_patterns_packed) {
        for (a, p) in acc.iter_mut().zip(partial) {
            *a += p;
        }
    }
    acc
}

/// [`soft_cells_batch`] sharded across at most `workers` threads; like
/// [`count_patterns_threaded`], worker-count-invariant by construction
/// (integer-exact partial sums).
pub fn soft_cells_threaded(words: &[u16], workers: usize) -> u64 {
    let bounds = crate::util::threads::chunk_bounds(words.len(), 1, workers);
    if bounds.len() <= 1 {
        return soft_cells_batch(words);
    }
    let jobs: Vec<&[u16]> = bounds.iter().map(|&(s, e)| &words[s..e]).collect();
    crate::util::threads::run_sharded(jobs, workers, soft_cells_batch)
        .into_iter()
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exhaustive: every finite f16 must round-trip bit-exactly through f32.
    #[test]
    fn exhaustive_f16_roundtrip() {
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1F;
            let man = h & 0x3FF;
            if exp == 0x1F && man != 0 {
                // NaN: only require NaN-ness to survive.
                assert!(f16_bits_to_f32(h).is_nan());
                continue;
            }
            let back = f32_to_f16_bits(f16_bits_to_f32(h));
            // -0.0 and 0.0 keep their signs distinctly.
            assert_eq!(back, h, "h={h:#06x}");
        }
    }

    #[test]
    fn reference_values() {
        // (bits, value) vectors from the IEEE 754 tables.
        let cases: &[(u16, f32)] = &[
            (0x0000, 0.0),
            (0x8000, -0.0),
            (0x3C00, 1.0),
            (0xBC00, -1.0),
            (0x4000, 2.0),
            (0x3555, 0.333251953125),
            (0x7BFF, 65504.0),            // max finite
            (0x0400, 6.103515625e-5),     // min normal
            (0x0001, 5.960464477539063e-8), // min subnormal
            (0x7C00, f32::INFINITY),
            (0xFC00, f32::NEG_INFINITY),
        ];
        for &(bits, val) in cases {
            assert_eq!(f16_bits_to_f32(bits), val, "decode {bits:#06x}");
            assert_eq!(f32_to_f16_bits(val), bits, "encode {val}");
        }
    }

    #[test]
    fn paper_table2_weights_encode_exactly() {
        // The paper's Table 2 rows are genuine binary16 words.
        assert_eq!(f32_to_f16_bits(0.004222), 0b00_01_11_00_01_01_00_11);
        assert_eq!(f32_to_f16_bits(0.020614), 0b00_10_01_01_01_00_01_11);
        assert_eq!(f32_to_f16_bits(0.0004982), 0b00_01_00_00_00_01_01_01);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10: ties-to-even
        // must round down to 1.0 (even mantissa).
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11)), 0x3C00);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9: rounds up to even.
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2f32.powi(-11)), 0x3C02);
    }

    #[test]
    fn overflow_and_nan() {
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00);
        assert_eq!(f32_to_f16_bits(-1e6), 0xFC00);
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00); // rounds up past max
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn subnormal_rounding() {
        // Halfway into the subnormal range.
        let x = f16_bits_to_f32(0x0001) / 2.0;
        assert_eq!(f32_to_f16_bits(x), 0x0000); // ties-to-even -> 0
        let y = f16_bits_to_f32(0x0003) * 0.5 + f16_bits_to_f32(0x0001) * 0.25;
        assert!(f32_to_f16_bits(y) <= 0x0002);
    }

    #[test]
    fn backup_bit_free_iff_below_two() {
        for h in 0..=u16::MAX {
            let v = f16_bits_to_f32(h);
            if v.is_finite() && v.abs() < 2.0 {
                assert!(backup_bit_free(h), "h={h:#06x} v={v}");
            }
            if backup_bit_free(h) {
                let exp = (h >> 10) & 0x1F;
                assert!(exp < 0x10, "bit14 clear implies exponent < 16");
            }
        }
        // The paper's boundary case: +2.0 is the first value using bit 14.
        assert!(!backup_bit_free(f32_to_f16_bits(2.0)));
        assert!(backup_bit_free(f32_to_f16_bits(1.0)));
        assert!(backup_bit_free(f32_to_f16_bits(-1.0)));
        assert!(backup_bit_free(f32_to_f16_bits(1.9990234))); // largest f16 < 2
    }

    #[test]
    fn cells_roundtrip_and_order() {
        let h = 0b00_01_11_00_01_01_00_11u16;
        let cs = cells(h);
        assert_eq!(cs, [0b00, 0b01, 0b11, 0b00, 0b01, 0b01, 0b00, 0b11]);
        assert_eq!(from_cells(&cs), h);
        for h in [0u16, 0xFFFF, 0x8000, 0x1234, 0xBEEF] {
            assert_eq!(from_cells(&cells(h)), h);
        }
    }

    #[test]
    fn pattern_counts_match_paper_examples() {
        // Table 2, NoChange rows.
        assert_eq!(pattern_counts(0b00_01_11_00_01_01_00_11), [3, 3, 0, 2]);
        assert_eq!(pattern_counts(0b00_10_01_01_01_00_01_11), [2, 4, 1, 1]);
        assert_eq!(pattern_counts(0b00_01_00_00_00_01_01_01), [4, 4, 0, 0]);
    }

    #[test]
    fn soft_cells_matches_pattern_counts() {
        for h in (0..=u16::MAX).step_by(7) {
            let pc = pattern_counts(h);
            assert_eq!(soft_cells(h), pc[1] + pc[2], "h={h:#06x}");
        }
        assert_eq!(soft_cells(0x0000), 0);
        assert_eq!(soft_cells(0xFFFF), 0);
        assert_eq!(soft_cells(0x5555), 8);
        assert_eq!(soft_cells(0xAAAA), 8);
    }

    #[test]
    fn flip_bit_involution() {
        for pos in 0..16 {
            assert_eq!(flip_bit(flip_bit(0x1234, pos), pos), 0x1234);
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let ws = [0x0000u16, 0xFFFF, 0xBEEF, 0x1234];
        assert_eq!(unpack4(pack4(ws)), ws);
        assert_eq!(pack4([1, 0, 0, 0]), 1);
        assert_eq!(pack4([0, 0, 0, 1]), 1u64 << 48);
    }

    #[test]
    fn packed_counts_match_scalar_lanewise() {
        // Deterministic word mix covering all lanes with distinct values.
        let mut h = 0x1357u16;
        for _ in 0..2048 {
            let ws = [h, h.wrapping_mul(31).rotate_left(3), !h, h ^ 0x5A5A];
            let x = pack4(ws);
            let soft: u32 = ws.iter().map(|&w| soft_cells(w)).sum();
            assert_eq!(soft_cells_packed(x), soft);
            let mut pc = [0u32; 4];
            for &w in &ws {
                for (a, c) in pc.iter_mut().zip(pattern_counts(w)) {
                    *a += c;
                }
            }
            assert_eq!(pattern_counts_packed(x), pc);
            h = h.wrapping_mul(0x9E37).wrapping_add(1);
        }
    }

    #[test]
    fn batch_apis_match_per_word_loops() {
        let words: Vec<u16> = (0..1001u32).map(|i| (i.wrapping_mul(40503) >> 3) as u16).collect();
        let mut acc = [0u64; 4];
        let mut soft = 0u64;
        for &w in &words {
            soft += soft_cells(w) as u64;
            for (a, c) in acc.iter_mut().zip(pattern_counts(w)) {
                *a += c as u64;
            }
        }
        assert_eq!(count_patterns_packed(&words), acc);
        assert_eq!(soft_cells_batch(&words), soft);
        for workers in [1usize, 2, 3, 7, 16] {
            assert_eq!(count_patterns_threaded(&words, workers), acc, "workers={workers}");
            assert_eq!(soft_cells_threaded(&words, workers), soft, "workers={workers}");
        }

        let fs: Vec<f32> = (0..777).map(|i| (i as f32 / 777.0) * 1.8 - 0.9).collect();
        let mut out = vec![0u16; fs.len()];
        quantize_into(&fs, &mut out);
        for (&f, &h) in fs.iter().zip(&out) {
            assert_eq!(h, f32_to_f16_bits(f));
        }
    }

    #[test]
    fn fast_decoders_match_scalar_exhaustively() {
        // The full lane-position sweep lives in tests/read_path.rs; this is
        // the in-crate exhaustive check of both accelerated decoders.
        for h in 0..=u16::MAX {
            let want = f16_bits_to_f32(h).to_bits();
            assert_eq!(f16_bits_to_f32_lut(h).to_bits(), want, "lut h={h:#06x}");
            assert_eq!(
                f16_bits_to_f32_branchless(h).to_bits(),
                want,
                "branchless h={h:#06x}"
            );
        }
    }

    #[test]
    fn fast_encoder_matches_scalar_on_f16_values_and_boundaries() {
        // Every exact f16 value round-trips identically through both
        // encoders, as do the rounding/overflow boundary cases.
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            assert_eq!(
                f32_to_f16_bits_fast(x),
                f32_to_f16_bits(x),
                "h={h:#06x} x={x}"
            );
        }
        for x in [
            0.0f32,
            -0.0,
            1.0 + 2f32.powi(-11),       // tie, rounds to even (down)
            1.0 + 3.0 * 2f32.powi(-11), // tie, rounds to even (up)
            65504.0,
            65519.9,                    // just below the round-to-inf boundary
            65520.0,                    // rounds up past max finite -> Inf
            1e6,
            -1e6,
            6.103515625e-5,             // min normal
            6.0e-5,                     // subnormal range
            5.960464477539063e-8,       // min subnormal
            2.9802322e-8,               // half the min subnormal (tie -> 0)
            1e-40,                      // f32 subnormal input
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
        ] {
            assert_eq!(f32_to_f16_bits_fast(x), f32_to_f16_bits(x), "x={x}");
        }
    }

    #[test]
    fn fast_encoder_matches_scalar_on_stepped_u32_sweep() {
        // Deterministic sweep across the whole f32 bit space (including
        // NaN payloads and subnormals): ~65k patterns at a large odd step.
        let mut bits = 0x9E37_79B9u32;
        for _ in 0..65536 {
            let x = f32::from_bits(bits);
            assert_eq!(
                f32_to_f16_bits_fast(x),
                f32_to_f16_bits(x),
                "bits={bits:#010x}"
            );
            bits = bits.wrapping_add(0x0001_0865); // odd step, full-period
        }
    }

    #[test]
    fn decode_slice_matches_scalar_under_selected_mode() {
        let words: Vec<u16> = (0..4099u32).map(|i| (i.wrapping_mul(40503)) as u16).collect();
        let mut out = vec![0f32; words.len()];
        decode_f16_slice(&words, &mut out);
        for (&h, &v) in words.iter().zip(&out) {
            assert_eq!(v.to_bits(), f16_bits_to_f32(h).to_bits(), "h={h:#06x}");
        }
    }

    #[test]
    fn quantize_error_bounded_in_unit_range() {
        // Relative error of f16 quantization for normal values is <= 2^-11.
        let mut x = 1.0e-4f32;
        while x < 1.0 {
            let q = quantize_f16(x);
            assert!(((q - x) / x).abs() <= 2f32.powi(-11) + 1e-7, "x={x}");
            x *= 1.37;
        }
    }
}
