//! Streaming statistics used by benches and experiment reports.

/// Online mean/variance/min/max (Welford).
#[derive(Clone, Debug)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Summary {
    // Not derived: the empty summary needs min/max at the identity
    // elements (±infinity), not 0.0.
    fn default() -> Self {
        Self::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.add(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Exact percentile over a stored sample (fine at bench scale).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// p in [0, 100]; nearest-rank method.
    pub fn pct(&mut self, p: f64) -> f64 {
        assert!(!self.xs.is_empty(), "no samples");
        self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (self.xs.len() - 1) as f64).round() as usize;
        self.xs[rank.min(self.xs.len() - 1)]
    }
}

/// Error Sum of Squares between two slices (paper Fig. 4 metric).
pub fn sse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum()
}

/// Geometric mean of ratios (used for energy-saving aggregates).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let mut s = Summary::new();
        s.extend([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_single_sample() {
        let mut s = Summary::new();
        s.add(7.0);
        assert_eq!(s.var(), 0.0);
        assert_eq!(s.mean(), 7.0);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert_eq!(p.pct(0.0), 1.0);
        assert_eq!(p.pct(100.0), 100.0);
        assert!((p.pct(50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn sse_basic() {
        assert_eq!(sse(&[1.0, 2.0], &[1.0, 4.0]), 4.0);
        assert_eq!(sse(&[], &[]), 0.0);
    }

    #[test]
    fn geomean_of_equal_is_identity() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
