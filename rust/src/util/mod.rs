//! Zero-dependency support utilities.
//!
//! The offline vendor registry carries only `xla` + `anyhow`, so everything
//! a framework normally pulls from crates.io is implemented here:
//!
//! * [`rng`] — SplitMix64 seeding + xoshiro256** streams (deterministic,
//!   splittable; every stochastic component in the crate takes a seed),
//! * [`json`] — a small, strict JSON parser/serializer (manifests, config),
//! * [`backoff`] — deterministic seeded equal-jitter exponential backoff
//!   (no wall-clock randomness; the retry engine under
//!   [`crate::api::deliver`]),
//! * [`cli`] — declarative flag parsing for the `mlcstt` binary,
//! * [`stats`] — streaming summaries used by benches and reports,
//! * [`prop`] — a miniature property-testing harness (random case
//!   generation + failure-case shrinking) standing in for `proptest`,
//! * [`threads`] — deterministic `std::thread::scope` work sharding for
//!   the codec/buffer hot paths (DESIGN.md §7),
//! * [`env`] — the single `MLCSTT_*` read/parse site, re-exported as
//!   [`crate::api::env`] (it lives down here so foundation modules like
//!   [`threads`] and [`crate::fp`] can use it without depending on the
//!   facade layer; DESIGN.md §10).

pub mod backoff;
pub mod cli;
pub mod env;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threads;
