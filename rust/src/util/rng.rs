//! Deterministic PRNG: SplitMix64 for seeding, xoshiro256** for streams.
//!
//! Every stochastic component in the crate (fault injection, workload
//! generation, property tests) consumes one of these, seeded explicitly, so
//! every experiment in EXPERIMENTS.md is bit-reproducible from its recorded
//! seed. Algorithms follow Blackman & Vigna (2018); constants are the
//! published reference values.

/// SplitMix64: used to expand a user seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the workhorse generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (used to give each weight tensor /
    /// each campaign its own stream without coupling draw counts).
    pub fn split(&mut self) -> Self {
        Self::seeded(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection-free widening).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (used by synthetic weight generators).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed=0 from the SplitMix64 paper code.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Xoshiro256::seeded(42);
        let mut b = Xoshiro256::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn split_streams_diverge() {
        let mut root = Xoshiro256::seeded(1);
        let mut c1 = root.split();
        let mut c2 = root.split();
        let v1: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let v2: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(v1, v2);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound_and_covers() {
        let mut r = Xoshiro256::seeded(9);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn chance_rate_roughly_correct() {
        let mut r = Xoshiro256::seeded(11);
        let n = 100_000;
        let hits = (0..n).filter(|_| r.chance(0.02)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.02).abs() < 0.003, "rate {rate}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::seeded(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seeded(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
