//! Miniature property-testing harness (offline stand-in for `proptest`).
//!
//! Usage pattern, mirroring proptest's closure style:
//!
//! ```no_run
//! // (no_run: rustdoc test binaries miss the libstdc++ rpath the crate's
//! // build sets for the PJRT shared object; the same pattern is executed
//! // for real throughout rust/tests/prop_*.rs)
//! use mlcstt::util::prop::{prop_assert, Runner};
//! let mut r = Runner::new("roundtrip", 0xC0FFEE, 500);
//! r.run(|g| {
//!     let x = g.u16(); // arbitrary weight bits
//!     let y = x.rotate_left(3).rotate_right(3);
//!     prop_assert(x == y, format!("{x:#06x} != {y:#06x}"))
//! });
//! ```
//!
//! On failure the runner re-searches smaller inputs by replaying the case
//! generator with a shrinking size budget, then panics with the seed, case
//! index, and the smallest failing message it found — enough to reproduce
//! deterministically (`Runner::new(name, seed, cases)` is pure).

use super::rng::Xoshiro256;

/// Result of one property check.
pub type PropResult = Result<(), String>;

/// Convenience assertion returning `PropResult`.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Xoshiro256,
    /// Size budget in [0.0, 1.0]; shrinking replays with smaller budgets so
    /// generators that respect `size()` produce structurally smaller cases.
    size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Self {
            rng: Xoshiro256::seeded(seed),
            size,
        }
    }

    pub fn size(&self) -> f64 {
        self.size
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn u16(&mut self) -> u16 {
        (self.rng.next_u64() >> 48) as u16
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn f32_unit(&mut self) -> f32 {
        self.rng.next_f32()
    }

    /// f32 uniform in [-1, 1] — the paper's weight domain.
    pub fn weight(&mut self) -> f32 {
        self.rng.next_f32() * 2.0 - 1.0
    }

    /// Integer in [0, bound); scales down with the shrink budget.
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let eff = ((bound as f64 * self.size).ceil() as usize).clamp(1, bound);
        self.rng.below(eff as u64) as usize
    }

    /// Length in [min, max], scaled by the shrink budget.
    pub fn len(&mut self, min: usize, max: usize) -> usize {
        let span = max - min;
        min + self.below(span + 1)
    }

    /// A vector of weights in [-1, 1].
    pub fn weights(&mut self, min_len: usize, max_len: usize) -> Vec<f32> {
        let n = self.len(min_len, max_len);
        (0..n).map(|_| self.weight()).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Drives `cases` random invocations of a property, shrinking on failure.
pub struct Runner {
    name: &'static str,
    seed: u64,
    cases: usize,
}

impl Runner {
    pub fn new(name: &'static str, seed: u64, cases: usize) -> Self {
        Self { name, seed, cases }
    }

    /// Run the property; panics (test failure) with a reproducible report on
    /// the first counterexample.
    pub fn run(&mut self, prop: impl Fn(&mut Gen) -> PropResult) {
        for case in 0..self.cases {
            let case_seed = self.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut g = Gen::new(case_seed, 1.0);
            if let Err(first_msg) = prop(&mut g) {
                // Shrink: replay the same stream with smaller size budgets;
                // keep the failure from the smallest budget that still fails.
                let mut best = (1.0, first_msg);
                for step in 1..=8 {
                    let size = 1.0 - step as f64 / 9.0;
                    let mut sg = Gen::new(case_seed, size);
                    if let Err(msg) = prop(&mut sg) {
                        best = (size, msg);
                    }
                }
                panic!(
                    "property '{}' failed (seed={:#x}, case={}, shrunk_size={:.2}):\n  {}",
                    self.name, self.seed, case, best.0, best.1
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut r = Runner::new("tautology", 1, 200);
        r.run(|g| prop_assert(g.u16() as u32 <= u16::MAX as u32, "impossible"));
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_context() {
        let mut r = Runner::new("always-false", 2, 10);
        r.run(|_| prop_assert(false, "nope"));
    }

    #[test]
    fn shrink_budget_reduces_generated_sizes() {
        let mut big = Gen::new(7, 1.0);
        let mut small = Gen::new(7, 0.1);
        let nb = big.len(0, 1000);
        let ns = small.len(0, 1000);
        assert!(ns <= nb.max(100), "shrunk len {ns} vs {nb}");
    }

    #[test]
    fn weight_gen_in_range() {
        let mut g = Gen::new(3, 1.0);
        for _ in 0..1000 {
            let w = g.weight();
            assert!((-1.0..=1.0).contains(&w));
        }
    }
}
