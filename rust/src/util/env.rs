//! The **only** place the crate reads `MLCSTT_*` environment variables.
//!
//! Before the facade, `MLCSTT_EVAL` / `MLCSTT_THREADS` / `MLCSTT_F16` /
//! `MLCSTT_ARTIFACTS` were parsed independently in `main.rs`, the
//! examples, the bench harness, and two library modules — with subtly
//! different fallback behavior at each site. Every read now funnels
//! through the typed accessors below, and [`crate::api::Config`] layers
//! builder overrides on top (builder beats env beats default).
//!
//! This module sits in `util` — below [`crate::util::threads`] and
//! [`crate::fp`], which consume it — and is re-exported as
//! [`crate::api::env`], the facade-level name entry points use
//! (DESIGN.md §10).
//!
//! Fallback semantics are part of the contract and pinned by
//! `rust/tests/env_plumbing.rs`:
//!
//! * an **unset** variable returns `None` (the caller's default applies);
//! * an **unparsable** value also returns `None` — a typo degrades to the
//!   default instead of crashing a long campaign at startup;
//! * `MLCSTT_THREADS=0` clamps to 1 (a worker ceiling of zero is
//!   meaningless, and historical callers relied on the clamp).

use std::path::PathBuf;

use crate::fp::F16Mode;

/// Raw read of one environment variable (non-UTF-8 values read as unset).
fn raw(key: &str) -> Option<String> {
    std::env::var(key).ok()
}

/// `MLCSTT_THREADS` — worker-thread ceiling for codec/buffer sharding.
/// Parsed values clamp to at least 1; unset/unparsable is `None` (callers
/// fall back to the machine's available parallelism).
pub fn threads() -> Option<usize> {
    raw("MLCSTT_THREADS")?.parse::<usize>().ok().map(|n| n.max(1))
}

/// `MLCSTT_F16` — f16 converter selection: `lut`, `branchless`, or
/// `scalar`. Unset or unrecognized is `None` (callers default to
/// [`F16Mode::Lut`]). Note the converter is process-latched on first use
/// (see [`crate::fp::f16_mode`]).
pub fn f16_mode() -> Option<F16Mode> {
    match raw("MLCSTT_F16")?.as_str() {
        "lut" => Some(F16Mode::Lut),
        "branchless" => Some(F16Mode::Branchless),
        "scalar" => Some(F16Mode::Scalar),
        _ => None,
    }
}

/// `MLCSTT_EVAL` — evaluation-size knob (test images per accuracy point,
/// weights per bench iteration). Callers supply their own default.
pub fn eval() -> Option<usize> {
    raw("MLCSTT_EVAL")?.parse().ok()
}

/// `MLCSTT_REQUESTS` — serving replay length for the demo entry points.
pub fn requests() -> Option<usize> {
    raw("MLCSTT_REQUESTS")?.parse().ok()
}

/// `MLCSTT_ARTIFACTS` — trained-artifact directory override.
pub fn artifacts() -> Option<PathBuf> {
    raw("MLCSTT_ARTIFACTS").map(PathBuf::from)
}

/// `MLCSTT_RATES` — comma-separated rate list for the load-test sweep;
/// unparsable entries are skipped (historical `load_test` behavior).
pub fn rates() -> Option<Vec<f64>> {
    raw("MLCSTT_RATES").map(|s| s.split(',').filter_map(|t| t.trim().parse().ok()).collect())
}

/// `MLCSTT_BENCH_DIR` — where `BENCH_*.json` reports land (the bench
/// harness anchors relative values at the workspace root).
pub fn bench_dir() -> Option<PathBuf> {
    raw("MLCSTT_BENCH_DIR").map(PathBuf::from)
}

/// `MLCSTT_QUEUE_DEPTH` — per-model bounded-admission depth (requests
/// in flight before [`crate::coordinator::Server`] sheds). Parsed values
/// clamp to at least 1 (a zero-depth queue could never serve, mirroring
/// the `MLCSTT_THREADS=0` clamp); unset/unparsable is `None` (callers
/// fall back to [`crate::coordinator::DEFAULT_QUEUE_DEPTH`]).
pub fn queue_depth() -> Option<usize> {
    raw("MLCSTT_QUEUE_DEPTH")?.parse::<usize>().ok().map(|n| n.max(1))
}

/// `MLCSTT_QUEUE_BUDGET` — registry-wide in-flight budget for
/// cross-model fair admission ([`crate::coordinator::FairGate`]). Unset
/// is `None`: models admit independently, no fair-share gating.
pub fn queue_budget() -> Option<usize> {
    raw("MLCSTT_QUEUE_BUDGET")?.parse().ok()
}

/// `MLCSTT_MAX_WAIT_MS` — batch-coalesce deadline in milliseconds
/// (admission-anchored; see `ServerConfig::max_wait`). Unset/unparsable
/// is `None` (callers default to 20 ms).
pub fn max_wait_ms() -> Option<u64> {
    raw("MLCSTT_MAX_WAIT_MS")?.parse().ok()
}

/// `MLCSTT_POOL_KB` — shared multi-tenant buffer-pool capacity in KB
/// ([`crate::api::BufferPool`]). Unset/unparsable is `None`: entry points
/// fall back to per-deployment private buffers or their demo geometry.
pub fn pool_kb() -> Option<usize> {
    raw("MLCSTT_POOL_KB")?.parse().ok()
}

/// `MLCSTT_POOL_BANKS` — parallel banks of the shared pool. Parsed values
/// clamp to at least 1 (mirroring the `MLCSTT_THREADS` clamp);
/// unset/unparsable is `None` (callers supply their default geometry).
pub fn pool_banks() -> Option<usize> {
    raw("MLCSTT_POOL_BANKS")?.parse::<usize>().ok().map(|n| n.max(1))
}

/// `MLCSTT_POOL_EXTENT` — extent size of the shared pool's allocator, in
/// words. Parsed values clamp to at least 1; [`crate::api::BufferPool`]
/// additionally rounds up to a multiple of the bank count (bank-slot
/// alignment). Unset/unparsable is `None`.
pub fn pool_extent() -> Option<usize> {
    raw("MLCSTT_POOL_EXTENT")?.parse::<usize>().ok().map(|n| n.max(1))
}

/// `MLCSTT_POLICY` — protection-policy selection for deployments built
/// without an explicit store override: any [`crate::encoding::Policy`]
/// label (`unprotected`, `round`, `rotate`, `hybrid`, `zero-parity`, plus
/// the long-form Fig. 8 names). Unset or unrecognized is `None` (callers
/// default to the paper's hybrid scheme), matching the `MLCSTT_F16`
/// enum-parse pattern.
pub fn policy() -> Option<crate::encoding::Policy> {
    crate::encoding::Policy::from_label(raw("MLCSTT_POLICY")?.as_str())
}

/// `MLCSTT_DELIVERY_RETRIES` — re-read budget per chunk for streamed
/// weight delivery ([`crate::api::deliver`]): how many times a failed
/// chunk read/verify is retried before the delivery fails with
/// `RetriesExhausted`. `0` means fail on the first bad read.
/// Unset/unparsable is `None` (callers default to
/// [`crate::api::DEFAULT_DELIVERY_RETRIES`]).
pub fn delivery_retries() -> Option<usize> {
    raw("MLCSTT_DELIVERY_RETRIES")?.parse().ok()
}

/// `MLCSTT_DELIVERY_BACKOFF_MS` — base delay, in milliseconds, of the
/// deterministic equal-jitter exponential backoff between chunk retries
/// ([`crate::util::backoff::Backoff`]). `0` retries immediately.
/// Unset/unparsable is `None` (callers default to
/// [`crate::api::DEFAULT_DELIVERY_BACKOFF`]).
pub fn delivery_backoff_ms() -> Option<u64> {
    raw("MLCSTT_DELIVERY_BACKOFF_MS")?.parse().ok()
}

/// `MLCSTT_CANARY` — canary probe batches a freshly staged engine must
/// classify correctly before a hot swap commits. `0` skips the canary
/// (verification + staging still gate). Unset/unparsable is `None`
/// (callers default to [`crate::api::DEFAULT_CANARY_BATCHES`]).
pub fn canary() -> Option<usize> {
    raw("MLCSTT_CANARY")?.parse().ok()
}

/// `MLCSTT_SCRUB_MS` — scrub interval for the shared pool's background
/// integrity maintenance, in milliseconds ([`crate::scrub::ScrubPolicy`]).
/// `0` disables scrubbing (the default). Unset/unparsable is `None`
/// (scrubbing stays off unless the builder supplies an interval).
pub fn scrub_ms() -> Option<u64> {
    raw("MLCSTT_SCRUB_MS")?.parse().ok()
}

/// `MLCSTT_SCRUB` — scrub-scheduler kind: `off`, `fixed`, or `adaptive`
/// ([`crate::scrub::ScrubMode`]). Unset or unrecognized is `None` (callers
/// default to `fixed` when an interval is set), matching the `MLCSTT_F16`
/// enum-parse pattern.
pub fn scrub_mode() -> Option<crate::scrub::ScrubMode> {
    match raw("MLCSTT_SCRUB")?.as_str() {
        "off" => Some(crate::scrub::ScrubMode::Off),
        "fixed" => Some(crate::scrub::ScrubMode::Fixed),
        "adaptive" => Some(crate::scrub::ScrubMode::Adaptive),
        _ => None,
    }
}

/// `MLCSTT_SCRUB_THRESH` — adaptive-scheduler decay threshold: the
/// observed corrected-cells-per-word (or estimated E[SSE] per weight) at
/// which the adaptive interval has halved once. Unset/unparsable is
/// `None` (callers default to
/// [`crate::scrub::DEFAULT_SCRUB_THRESHOLD`]).
pub fn scrub_thresh() -> Option<f64> {
    raw("MLCSTT_SCRUB_THRESH")?.parse().ok()
}

/// `MLCSTT_EVICT` — shared-pool capacity-pressure policy: `lru` (evict
/// the least-recently-served model, rebuild on demand) or `deny` (refuse
/// the allocation). Unset or unrecognized is `None` (callers default to
/// LRU), matching the `MLCSTT_F16` enum-parse pattern.
pub fn evict() -> Option<crate::buffer::shared::EvictPolicy> {
    match raw("MLCSTT_EVICT")?.as_str() {
        "lru" => Some(crate::buffer::shared::EvictPolicy::Lru),
        "deny" => Some(crate::buffer::shared::EvictPolicy::Deny),
        _ => None,
    }
}
