//! Declarative command-line parsing for the `mlcstt` binary.
//!
//! `clap` is not in the offline vendor set; this covers what the launcher
//! needs: subcommands, `--flag value`, `--flag=value`, boolean switches,
//! defaults, required flags, typed accessors, and generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

#[derive(Clone, Debug)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_switch: bool,
    required: bool,
}

/// One subcommand: a set of flags with help text.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            flags: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_switch: false,
            required: false,
        });
        self
    }

    pub fn required_flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: None,
            is_switch: false,
            required: true,
        });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: None,
            is_switch: true,
            required: false,
        });
        self
    }

    fn spec(&self, name: &str) -> Option<&FlagSpec> {
        self.flags.iter().find(|f| f.name == name)
    }

    pub fn usage(&self) -> String {
        let mut s = format!("mlcstt {} — {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            let kind = if f.is_switch {
                String::new()
            } else if let Some(d) = &f.default {
                format!(" <value> (default: {d})")
            } else {
                " <value> (required)".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", f.name, kind, f.help));
        }
        s
    }

    /// Parse `args` (without the subcommand itself).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut switches: BTreeMap<String, bool> = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError(self.usage()));
            }
            let Some(stripped) = arg.strip_prefix("--") else {
                return Err(CliError(format!(
                    "unexpected positional argument {arg:?}\n\n{}",
                    self.usage()
                )));
            };
            let (name, inline) = match stripped.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (stripped, None),
            };
            let spec = self
                .spec(name)
                .ok_or_else(|| CliError(format!("unknown flag --{name}\n\n{}", self.usage())))?;
            if spec.is_switch {
                if inline.is_some() {
                    return Err(CliError(format!("switch --{name} takes no value")));
                }
                switches.insert(name.to_string(), true);
            } else {
                let v = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        args.get(i)
                            .cloned()
                            .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                    }
                };
                values.insert(name.to_string(), v);
            }
            i += 1;
        }
        for f in &self.flags {
            if f.required && !values.contains_key(f.name) {
                return Err(CliError(format!(
                    "missing required flag --{}\n\n{}",
                    f.name,
                    self.usage()
                )));
            }
            if let (Some(d), false) = (&f.default, values.contains_key(f.name)) {
                values.insert(f.name.to_string(), d.clone());
            }
        }
        Ok(Matches { values, switches })
    }
}

/// Parsed flag values with typed accessors.
#[derive(Clone, Debug)]
pub struct Matches {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
}

impl Matches {
    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected an integer, got {:?}", self.str(name))))
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected an integer, got {:?}", self.str(name))))
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        self.str(name)
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected a number, got {:?}", self.str(name))))
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    /// Comma-separated list.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.str(name)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }
}

#[cfg(test)]
fn strs(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("demo", "test command")
            .flag("model", "vggmini", "model name")
            .flag("rate", "0.015", "fault rate")
            .required_flag("out", "output path")
            .switch("verbose", "chatty")
    }

    #[test]
    fn defaults_and_values() {
        let m = cmd().parse(&strs(&["--out", "x.json"])).unwrap();
        assert_eq!(m.str("model"), "vggmini");
        assert_eq!(m.f64("rate").unwrap(), 0.015);
        assert_eq!(m.str("out"), "x.json");
        assert!(!m.switch("verbose"));
    }

    #[test]
    fn equals_syntax_and_switch() {
        let m = cmd()
            .parse(&strs(&["--out=o", "--model=inceptionmini", "--verbose"]))
            .unwrap();
        assert_eq!(m.str("model"), "inceptionmini");
        assert!(m.switch("verbose"));
    }

    #[test]
    fn missing_required_fails() {
        assert!(cmd().parse(&[]).is_err());
    }

    #[test]
    fn unknown_flag_fails() {
        assert!(cmd().parse(&strs(&["--out", "o", "--nope", "1"])).is_err());
    }

    #[test]
    fn bad_number_fails() {
        let m = cmd().parse(&strs(&["--out", "o", "--rate", "abc"])).unwrap();
        assert!(m.f64("rate").is_err());
    }

    #[test]
    fn list_parsing() {
        let c = Command::new("x", "y").flag("models", "a,b", "names");
        let m = c.parse(&[]).unwrap();
        assert_eq!(m.list("models"), vec!["a", "b"]);
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = cmd().parse(&strs(&["--help"])).unwrap_err();
        assert!(err.0.contains("--model"));
    }
}
