//! Deterministic work sharding for the hot paths.
//!
//! The codec and buffer shard million-weight tensors across
//! `std::thread::scope` workers (no thread-pool dependency — the vendor set
//! stays offline). Two invariants keep threading invisible to results:
//!
//! * **Shard boundaries depend only on the data**, never on the worker
//!   count: encode/decode split on group-aligned boundaries, the buffer
//!   store on a fixed shard size. A shard computes the same bytes whether
//!   it runs inline or on any of N workers.
//! * **Reductions combine in shard order**, so floating-point accumulation
//!   (energy nanojoules) is bit-stable across thread counts. Quantities
//!   that are not plain sums reduce with an order-preserving carry: the
//!   buffer's banked read latency carries the open slot's running max
//!   across shard boundaries (the load-shard carry rule, DESIGN.md §8).
//!
//! Seed-order contract: stochastic shards (store fault injection, read
//! disturb) draw one RNG seed per fixed-size shard *in shard order before
//! any worker runs*, so the flip set is a function of (buffer seed, stream
//! position) alone — never of the thread schedule.
//!
//! `rust/tests/swar_equivalence.rs` pins threaded == single-thread for the
//! whole encode → store → decode pipeline; `rust/tests/read_path.rs` pins
//! the load/disturb side across 1/2/7 workers.

/// Worker ceiling: `MLCSTT_THREADS` if set (>=1, read through the single
/// env layer [`crate::util::env::threads`]), else the machine's available
/// parallelism. [`crate::api::Config`] adds the builder-override layer on
/// top of this resolution.
pub fn available() -> usize {
    crate::util::env::threads().unwrap_or_else(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Workers worth spawning for `items` units of work, requiring at least
/// `min_per_worker` units each (tiny tensors stay single-threaded — the
/// spawn cost would dominate).
pub fn auto_workers(items: usize, min_per_worker: usize) -> usize {
    available().min(items / min_per_worker.max(1)).max(1)
}

/// Run `f` once per job across at most `workers` scoped threads, handing
/// each worker one **contiguous batch** of jobs. Results come back in job
/// order — batches are contiguous and joined in spawn order — which is
/// exactly the shard-order guarantee the buffer's reductions (energy
/// partial sums, the load carry rule, per-shard seed assignment) rely on.
/// With `workers <= 1` or a single job the closure runs inline.
pub fn run_sharded<J: Send, T: Send>(
    jobs: Vec<J>,
    workers: usize,
    f: impl Fn(J) -> T + Sync,
) -> Vec<T> {
    if workers <= 1 || jobs.len() <= 1 {
        return jobs.into_iter().map(f).collect();
    }
    let per_worker = jobs.len().div_ceil(workers);
    std::thread::scope(|scope| {
        let f = &f;
        let mut handles = Vec::new();
        let mut it = jobs.into_iter();
        loop {
            let batch: Vec<J> = it.by_ref().take(per_worker).collect();
            if batch.is_empty() {
                break;
            }
            handles.push(scope.spawn(move || batch.into_iter().map(f).collect::<Vec<T>>()));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    })
}

/// Split `len` items into at most `workers` contiguous chunks whose starts
/// are multiples of `align` (the codec's metadata-group size, so a scheme
/// group never straddles two workers). Covers `0..len` exactly, in order.
pub fn chunk_bounds(len: usize, align: usize, workers: usize) -> Vec<(usize, usize)> {
    assert!(align >= 1, "align must be >= 1");
    if len == 0 {
        return Vec::new();
    }
    let workers = workers.max(1);
    let units = len.div_ceil(align);
    let per_chunk = units.div_ceil(workers) * align;
    let mut bounds = Vec::with_capacity(workers);
    let mut start = 0;
    while start < len {
        let end = (start + per_chunk).min(len);
        bounds.push((start, end));
        start = end;
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_cover_exactly_and_stay_aligned() {
        for len in [0usize, 1, 5, 16, 100, 1000, 65536, 65537] {
            for align in [1usize, 4, 16] {
                for workers in [1usize, 2, 3, 8] {
                    let b = chunk_bounds(len, align, workers);
                    let mut cursor = 0;
                    for &(s, e) in &b {
                        assert_eq!(s, cursor, "len={len} align={align} w={workers}");
                        assert!(e > s);
                        assert_eq!(s % align, 0, "start must be group-aligned");
                        cursor = e;
                    }
                    assert_eq!(cursor, len);
                    assert!(b.len() <= workers.max(1));
                }
            }
        }
    }

    #[test]
    fn single_worker_is_one_chunk() {
        assert_eq!(chunk_bounds(1000, 16, 1), vec![(0, 1000)]);
    }

    #[test]
    fn auto_workers_floors_at_one() {
        assert_eq!(auto_workers(0, 1024), 1);
        assert_eq!(auto_workers(10, 1024), 1);
        assert!(auto_workers(1 << 20, 1024) >= 1);
    }

    #[test]
    fn run_sharded_preserves_job_order_for_any_worker_count() {
        for n in [0usize, 1, 2, 7, 100, 1001] {
            let want: Vec<usize> = (0..n).map(|j| j * 3 + 1).collect();
            for workers in [1usize, 2, 3, 8, 64] {
                let jobs: Vec<usize> = (0..n).collect();
                let got = run_sharded(jobs, workers, |j| j * 3 + 1);
                assert_eq!(got, want, "n={n} workers={workers}");
            }
        }
    }
}
