//! Minimal strict JSON parser + serializer.
//!
//! Consumes the python-side manifests (`artifacts/*.manifest.json`) and the
//! crate's own config files; no `serde` in the offline vendor set. Supports
//! the full JSON grammar (RFC 8259): objects, arrays, strings with escapes
//! (incl. `\uXXXX` and surrogate pairs), numbers, booleans, null. Objects
//! store keys in a `BTreeMap`, so serialization order is deterministic
//! (sorted by key) and round-trips are stable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps serialization deterministic; manifests are small.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted lookup for nested objects.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(d + 1));
                        v.write(out, Some(d + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let (Some(d), false) = (indent, a.is_empty()) {
                    out.push('\n');
                    out.push_str(&"  ".repeat(d));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(d + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(d + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let (Some(d), false) = (indent, m.is_empty()) {
                    out.push('\n');
                    out.push_str(&"  ".repeat(d));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if !self.bytes[self.pos..].starts_with(b"\\u") {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.pos += 2;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad low surrogate"));
                                }
                                let cp =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            } else {
                                char::from_u32(hi).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Advance one UTF-8 char (input is &str, so it's valid).
                    let rest = &self.bytes[self.pos..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// Convenience constructors used by report writers.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

/// Build a `Json::Obj` from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": null}, "x"], "c": false}"#).unwrap();
        assert_eq!(j.path("c"), Some(&Json::Bool(false)));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn escapes_roundtrip() {
        let orig = Json::Str("line\n\"quote\"\t\\µπ".into());
        let text = orig.to_string();
        assert_eq!(Json::parse(&text).unwrap(), orig);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""µ😀""#).unwrap(),
            Json::Str("µ😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "12..5", "tru", "\"unterminated", "{\"a\" 1}", "1 2"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn rejects_lone_surrogate() {
        assert!(Json::parse(r#""\ud800""#).is_err());
    }

    #[test]
    fn pretty_roundtrip() {
        let j = obj(vec![
            ("model", "vggmini".into()),
            ("batch", 64usize.into()),
            ("accs", Json::Arr(vec![0.97.into(), 0.88.into()])),
        ]);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
            "batch": 64,
            "params": [{"name": "conv0_0.w", "shape": [3,3,3,32], "size": 864}],
            "training": {"test_acc": 0.9716796875}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.path("training.test_acc").unwrap().as_f64().unwrap(), 0.9716796875);
        let p = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str().unwrap(), "conv0_0.w");
        assert_eq!(p.get("size").unwrap().as_usize().unwrap(), 864);
    }
}
