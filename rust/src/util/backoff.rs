//! Deterministic seeded exponential backoff (DESIGN.md §14).
//!
//! Retry loops in this crate must stay reproducible: a delivery campaign
//! replayed with the same seed has to make the same retry decisions and
//! sleep the same (virtual) durations, or the chaos tests in
//! `rust/tests/delivery.rs` could not pin failure paths. So there is no
//! wall-clock randomness here — jitter comes from a
//! [`crate::util::rng::Xoshiro256`] stream seeded by the caller, and the
//! schedule is a pure function of `(base, cap, seed, attempt)`.
//!
//! The shape is classic equal-jitter exponential backoff: attempt `k`
//! waits somewhere in `[bound(k)/2, bound(k))` where
//! `bound(k) = base · 2^min(k, cap)`. The exponent cap keeps the wait
//! bounded no matter how many retries a caller configures, and the
//! half-floor keeps successive retries from synchronizing at zero.
//!
//! [`crate::api::deliver`] consumes this for chunk re-reads instead of an
//! inline loop; the closed-form unit tests below pin the envelope.

use std::time::Duration;

use crate::util::rng::Xoshiro256;

/// Largest allowed doubling exponent. `base · 2^20` already turns a 1 ms
/// base into ~17 min; anything above is a configuration error, so
/// [`Backoff::with_cap`] clamps here to keep the `1 << cap` shift sound.
pub const MAX_EXPONENT: u32 = 20;

/// Default doubling cap: delays stop growing after `base · 2^6` (64×).
pub const DEFAULT_EXPONENT_CAP: u32 = 6;

/// A deterministic equal-jitter exponential backoff schedule.
///
/// Construction fixes the whole schedule: two instances built with the
/// same `(base, cap, seed)` yield identical delay sequences. Callers pull
/// delays with [`Backoff::next_delay`] and decide themselves whether to
/// sleep, accumulate into a timeout budget, or both.
#[derive(Clone, Debug)]
pub struct Backoff {
    base: Duration,
    cap: u32,
    attempt: u32,
    rng: Xoshiro256,
}

impl Backoff {
    /// A schedule with the [`DEFAULT_EXPONENT_CAP`] doubling cap.
    pub fn new(base: Duration, seed: u64) -> Self {
        Self::with_cap(base, DEFAULT_EXPONENT_CAP, seed)
    }

    /// A schedule whose delays stop doubling after `base · 2^cap`
    /// (`cap` clamped to [`MAX_EXPONENT`]).
    pub fn with_cap(base: Duration, cap: u32, seed: u64) -> Self {
        Backoff {
            base,
            cap: cap.min(MAX_EXPONENT),
            attempt: 0,
            rng: Xoshiro256::seeded(seed),
        }
    }

    /// Jitter-free ceiling for attempt `k`: `base · 2^min(k, cap)`,
    /// saturating instead of overflowing for pathological bases.
    pub fn bound(&self, attempt: u32) -> Duration {
        self.base.saturating_mul(1u32 << attempt.min(self.cap))
    }

    /// Attempts drawn so far (the next [`Backoff::next_delay`] serves
    /// this attempt index).
    pub fn attempt(&self) -> u32 {
        self.attempt
    }

    /// Draw the delay for the current attempt and advance. Equal jitter:
    /// uniform in `[bound/2, bound)` for a non-zero bound, exactly zero
    /// for a zero base (callers disabling backoff pay no wait at all).
    pub fn next_delay(&mut self) -> Duration {
        let bound = self.bound(self.attempt);
        self.attempt = self.attempt.saturating_add(1);
        let half = bound / 2;
        // One RNG draw per attempt even when the base is zero, so a
        // schedule's draw count — and therefore any RNG stream split
        // after it — does not depend on the configured base.
        let u = self.rng.next_f64();
        if bound.is_zero() {
            return Duration::ZERO;
        }
        half + Duration::from_nanos((half.as_nanos() as f64 * u) as u64)
    }

    /// Rewind to attempt 0 **and** restart the jitter stream from a fresh
    /// split, for callers reusing one schedule across independent items
    /// (each item still gets a distinct but deterministic sequence).
    pub fn reset(&mut self) {
        self.attempt = 0;
        self.rng = self.rng.split();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delays(mut b: Backoff, n: usize) -> Vec<Duration> {
        (0..n).map(|_| b.next_delay()).collect()
    }

    #[test]
    fn same_seed_same_schedule() {
        let a = delays(Backoff::new(Duration::from_millis(5), 0xD15EA5E), 8);
        let b = delays(Backoff::new(Duration::from_millis(5), 0xD15EA5E), 8);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_diverge() {
        let a = delays(Backoff::new(Duration::from_millis(5), 1), 8);
        let b = delays(Backoff::new(Duration::from_millis(5), 2), 8);
        assert_ne!(a, b);
    }

    #[test]
    fn every_delay_inside_the_equal_jitter_envelope() {
        let base = Duration::from_millis(3);
        let mut b = Backoff::with_cap(base, 4, 42);
        for k in 0..12u32 {
            let bound = b.bound(k);
            let d = b.next_delay();
            assert!(d >= bound / 2, "attempt {k}: {d:?} < {:?}", bound / 2);
            assert!(d < bound, "attempt {k}: {d:?} >= {bound:?}");
        }
        assert_eq!(b.attempt(), 12);
    }

    #[test]
    fn bound_is_closed_form_and_caps() {
        let base = Duration::from_millis(2);
        let b = Backoff::with_cap(base, 4, 0);
        for k in 0..5u32 {
            assert_eq!(b.bound(k), base * (1 << k));
        }
        // Past the cap the ceiling freezes at base · 2^cap.
        assert_eq!(b.bound(9), base * 16);
        assert_eq!(b.bound(31), base * 16);
    }

    #[test]
    fn cap_clamps_to_max_exponent() {
        let b = Backoff::with_cap(Duration::from_nanos(1), 63, 0);
        assert_eq!(b.bound(u32::MAX), Duration::from_nanos(1 << MAX_EXPONENT));
    }

    #[test]
    fn zero_base_never_waits() {
        let mut b = Backoff::new(Duration::ZERO, 7);
        for _ in 0..6 {
            assert_eq!(b.next_delay(), Duration::ZERO);
        }
    }

    #[test]
    fn saturates_instead_of_overflowing() {
        let b = Backoff::with_cap(Duration::MAX, 20, 0);
        assert_eq!(b.bound(20), Duration::MAX);
    }

    #[test]
    fn reset_restarts_attempts_on_a_split_stream() {
        let mut b = Backoff::new(Duration::from_millis(1), 9);
        let first = b.next_delay();
        b.reset();
        assert_eq!(b.attempt(), 0);
        // Same attempt index, different (split) jitter stream: the bound
        // envelope holds but the draw is independent of the first pass.
        let again = b.next_delay();
        assert!(again >= b.bound(0) / 2 && again < b.bound(0));
        let _ = first;
    }
}
