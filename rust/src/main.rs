//! `mlcstt` — launcher for the MLC STT-RAM CNN-buffer reproduction.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §5):
//!
//! ```text
//! mlcstt info                               artifact + model inventory
//! mlcstt sse                                Fig. 4  bit-flip SSE study
//! mlcstt bitcount  --model vggmini          Fig. 6  stored-pattern census
//! mlcstt energy    --model vggmini          Fig. 7  read/write energy
//! mlcstt accuracy  --model vggmini          Fig. 8  fault-injection accuracy
//! mlcstt bandwidth --net vgg16              Fig. 9  systolic bandwidth
//! mlcstt serve     --model vggmini          e2e serving demo + latency
//! mlcstt deliver   --fail 2 --corrupt 1     zero-downtime hot-swap delivery demo
//! mlcstt scrub     --rate 0.02 --cycles 6   background scrubbing + retention telemetry
//! ```
//!
//! Everything is deterministic under `--seed`.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use mlcstt::api::{Config, Deployment, ModelRegistry};
use mlcstt::encoding::{Policy, WeightCodec};
use mlcstt::faults::bitflip_sse_study;
use mlcstt::metrics::{
    bandwidth_table, bitcount_table, energy_table, BandwidthRow, BitcountRow, EnergyRow, Table,
};
use mlcstt::models;
use mlcstt::runtime::artifacts::{model_paths, Manifest, TestSet, WeightFile};
use mlcstt::stt::{AccessKind, CostModel, ErrorModel};
use mlcstt::systolic::{simulate_network, top_k_by, ArrayConfig};
use mlcstt::util::cli::Command;
use mlcstt::util::rng::Xoshiro256;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "--help" || args[0] == "-h" || args[0] == "help" {
        print_usage();
        return;
    }
    let sub = args[0].clone();
    let rest = args[1..].to_vec();
    let result = match sub.as_str() {
        "version" => {
            println!("mlcstt {}", mlcstt::version());
            Ok(())
        }
        "info" => cmd_info(&rest),
        "sse" => cmd_sse(&rest),
        "bitcount" => cmd_bitcount(&rest),
        "energy" => cmd_energy(&rest),
        "accuracy" => cmd_accuracy(&rest),
        "sweep" => cmd_sweep(&rest),
        "bandwidth" => cmd_bandwidth(&rest),
        "serve" => cmd_serve(&rest),
        "deliver" => cmd_deliver(&rest),
        "scrub" => cmd_scrub(&rest),
        other => {
            print_usage();
            Err(anyhow::anyhow!("unknown subcommand {other:?}"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "mlcstt {} — MLC STT-RAM buffer for CNN accelerators (paper reproduction)\n\n\
         subcommands:\n\
         \x20 info       artifact + model inventory\n\
         \x20 sse        Fig. 4 bit-flip SSE study\n\
         \x20 bitcount   Fig. 6 stored-pattern census\n\
         \x20 energy     Fig. 7 read/write energy by granularity\n\
         \x20 accuracy   Fig. 8 fault-injection accuracy (needs artifacts)\n\
         \x20 sweep      Fig. 8 accuracy-vs-error-rate sweep (snapshot reuse)\n\
         \x20 bandwidth  Fig. 9 systolic-array bandwidth vs buffer size\n\
         \x20 serve      end-to-end serving demo with latency metrics\n\
         \x20 deliver    zero-downtime hot-swap delivery demo (chaos-injectable)\n\
         \x20 scrub      background scrubbing & retention-telemetry demo\n\
         \x20 version    print version\n\n\
         run `mlcstt <subcommand> --help` for flags",
        mlcstt::version()
    );
}

/// Resolve the artifact directory: an explicit `--artifacts` flag wins,
/// otherwise the layered config (`MLCSTT_ARTIFACTS`, then `artifacts/`).
fn artifacts_dir(m: &mlcstt::util::cli::Matches) -> PathBuf {
    let flag = m.str("artifacts");
    if flag.is_empty() {
        Config::from_env().artifacts_dir().to_path_buf()
    } else {
        PathBuf::from(flag)
    }
}

/// Shared `--artifacts` flag help (empty default = config-layer lookup).
const ARTIFACTS_HELP: &str = "artifact directory (default: $MLCSTT_ARTIFACTS, then artifacts/)";

fn load_weights(dir: &PathBuf, model: &str) -> Result<(Manifest, WeightFile)> {
    let (_, wpath, mpath) = model_paths(dir, model);
    let manifest = Manifest::read(&mpath)
        .with_context(|| format!("{model}: run `make artifacts` first"))?;
    let weights = WeightFile::read(&wpath)?;
    manifest.validate(&weights)?;
    Ok((manifest, weights))
}

// ---------------------------------------------------------------- info

fn cmd_info(args: &[String]) -> Result<()> {
    let cmd = Command::new("info", "artifact + model inventory")
        .flag("artifacts", "", ARTIFACTS_HELP);
    let m = cmd.parse(args).map_err(usage_err)?;
    let dir = artifacts_dir(&m);

    let mut t = Table::new(
        "artifact inventory",
        &["model", "params", "tensors", "batch", "test acc", "status"],
    );
    for model in ["vggmini", "inceptionmini"] {
        match load_weights(&dir, model) {
            Ok((manifest, weights)) => t.row(vec![
                model.into(),
                weights.total_elems().to_string(),
                weights.params.len().to_string(),
                manifest.batch.to_string(),
                format!("{:.4}", manifest.test_acc),
                "ready".into(),
            ]),
            Err(_) => t.row(vec![
                model.into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "missing (make artifacts)".into(),
            ]),
        }
    }
    println!("{t}");

    let mut nets = Table::new("simulator layer tables", &["network", "layers", "weights", "GMACs"]);
    for name in ["vgg16", "inceptionv3", "vggmini", "inceptionmini"] {
        let layers = models::by_name(name).unwrap();
        nets.row(vec![
            name.into(),
            layers.len().to_string(),
            layers.iter().map(|l| l.weight_elems()).sum::<usize>().to_string(),
            format!(
                "{:.2}",
                layers.iter().map(|l| l.macs()).sum::<u64>() as f64 / 1e9
            ),
        ]);
    }
    println!("{nets}");
    Ok(())
}

// ---------------------------------------------------------------- sse

fn cmd_sse(args: &[String]) -> Result<()> {
    let cmd = Command::new("sse", "Fig. 4: SSE per flipped bit position")
        .flag("samples", "1000000", "number of random weights in [-1, 1]")
        .flag("seed", "4", "PRNG seed");
    let m = cmd.parse(args).map_err(usage_err)?;
    let n = m.usize("samples")?;
    let sse = bitflip_sse_study(n, m.u64("seed")?);
    let mut t = Table::new(
        &format!("Fig.4 SSE per flipped bit ({n} samples)"),
        &["bit", "role", "SSE", "SSE/sample"],
    );
    for bit in (0..16).rev() {
        let role = match bit {
            15 => "sign",
            14 => "exp MSB (backup)",
            10..=13 => "exponent",
            _ => "mantissa",
        };
        t.row(vec![
            bit.to_string(),
            role.into(),
            format!("{:.3e}", sse[bit]),
            format!("{:.3e}", sse[bit] / n as f64),
        ]);
    }
    println!("{t}");
    Ok(())
}

// ---------------------------------------------------------------- bitcount

fn granularities() -> [usize; 5] {
    [1, 2, 4, 8, 16]
}

fn cmd_bitcount(args: &[String]) -> Result<()> {
    let cmd = Command::new("bitcount", "Fig. 6: stored bit-pattern census")
        .flag("model", "vggmini", "artifact model name")
        .flag("artifacts", "", ARTIFACTS_HELP);
    let m = cmd.parse(args).map_err(usage_err)?;
    let (_, weights) = load_weights(&artifacts_dir(&m), m.str("model"))?;
    let flat = weights.flat();

    let mut rows = Vec::new();
    let base = WeightCodec::new(Policy::Unprotected, 1).encode(&flat);
    rows.push(BitcountRow {
        system: "baseline".into(),
        counts: base.pattern_counts(),
    });
    for g in granularities() {
        let enc = WeightCodec::hybrid(g).encode(&flat);
        rows.push(BitcountRow {
            system: format!("granularity_{g}"),
            counts: enc.pattern_counts(),
        });
    }
    println!("{}", bitcount_table(m.str("model"), &rows));
    Ok(())
}

// ---------------------------------------------------------------- energy

fn cmd_energy(args: &[String]) -> Result<()> {
    let cmd = Command::new("energy", "Fig. 7: buffer read/write energy")
        .flag("model", "vggmini", "artifact model name")
        .flag("artifacts", "", ARTIFACTS_HELP);
    let m = cmd.parse(args).map_err(usage_err)?;
    let (_, weights) = load_weights(&artifacts_dir(&m), m.str("model"))?;
    let flat = weights.flat();
    let cost = CostModel::default();

    let mut rows = Vec::new();
    let base = WeightCodec::new(Policy::Unprotected, 1).encode(&flat);
    rows.push(EnergyRow {
        system: "baseline".into(),
        read: base.access_energy(&cost, AccessKind::Read),
        write: base.access_energy(&cost, AccessKind::Write),
    });
    for g in granularities() {
        let enc = WeightCodec::hybrid(g).encode(&flat);
        rows.push(EnergyRow {
            system: format!("granularity_{g}"),
            read: enc.access_energy(&cost, AccessKind::Read),
            write: enc.access_energy(&cost, AccessKind::Write),
        });
    }
    println!("{}", energy_table(m.str("model"), &rows));
    Ok(())
}

// ---------------------------------------------------------------- accuracy

fn cmd_accuracy(args: &[String]) -> Result<()> {
    let cmd = Command::new("accuracy", "Fig. 8: accuracy under fault injection")
        .flag("model", "vggmini", "artifact model name")
        .flag("artifacts", "", ARTIFACTS_HELP)
        .flag("rate", "0.02", "soft-error rate for vulnerable cells")
        .flag("granularity", "4", "metadata granularity")
        .flag("eval", "512", "test images to evaluate")
        .flag("seed", "7", "fault-injection seed");
    let m = cmd.parse(args).map_err(usage_err)?;
    let dir = artifacts_dir(&m);
    let model = m.str("model");
    let rate = m.f64("rate")?;
    let eval = m.usize("eval")?;
    let seed = m.u64("seed")?;
    let granularity = m.usize("granularity")?;

    let exp = mlcstt::experiments::run_accuracy_experiment(
        &dir,
        model,
        rate,
        granularity,
        eval,
        seed,
    )?;
    println!("{}", exp.table);
    Ok(())
}

// ---------------------------------------------------------------- sweep

fn cmd_sweep(args: &[String]) -> Result<()> {
    let cmd = Command::new("sweep", "Fig. 8: accuracy vs error rate (snapshot-reuse campaign)")
        .flag("model", "vggmini", "artifact model name")
        .flag("artifacts", "", ARTIFACTS_HELP)
        .flag("rates", "0.0,0.005,0.01,0.015,0.02", "soft-error rates to sweep")
        .flag("granularity", "4", "metadata granularity")
        .flag("eval", "", "test images per point (default: $MLCSTT_EVAL, then 512)")
        .flag("seed", "7", "fault-injection seed")
        .flag(
            "policies",
            "",
            "policy axis: \"all\" (every policy incl. zero-parity) or comma-separated \
             labels; emits bench_out/SWEEP_policies.json and runs artifact-free if \
             needed (empty = the Fig. 8 four through PJRT artifacts)",
        );
    let m = cmd.parse(args).map_err(usage_err)?;
    let rates: Vec<f64> = m
        .list("rates")
        .iter()
        .map(|r| r.parse().with_context(|| format!("bad --rates entry {r:?}")))
        .collect::<Result<_>>()?;
    let eval = if m.str("eval").is_empty() {
        Config::from_env().eval_or(512)
    } else {
        m.usize("eval")?
    };

    if !m.str("policies").is_empty() {
        return cmd_sweep_policies(&m, &rates, eval);
    }

    let exp = mlcstt::experiments::run_rate_sweep(
        &artifacts_dir(&m),
        m.str("model"),
        &rates,
        m.usize("granularity")?,
        eval,
        m.u64("seed")?,
    )?;
    println!("{}", exp.table);
    println!(
        "(encode+store passes: {} — one per policy for all {} rate points)",
        exp.encode_passes,
        rates.len()
    );
    Ok(())
}

/// The `--policies` front: sweep an explicit policy axis (ISSUE 8), print
/// the table, and write the machine-readable per-policy front — measured
/// campaign rows plus the analytic entropy-estimator rows — to
/// `SWEEP_policies.json` in `$MLCSTT_BENCH_DIR` (default `bench_out/`).
/// With trained artifacts present the metric is model accuracy through
/// PJRT; without them it is weight fidelity on a synthetic trained-shaped
/// tensor of `eval` weights (the `rate_sweep` example's fallback).
fn cmd_sweep_policies(m: &mlcstt::util::cli::Matches, rates: &[f64], eval: usize) -> Result<()> {
    use mlcstt::coordinator::StoreConfig;
    use mlcstt::experiments::{rate_sweep_table, run_policy_sweep_with, run_rate_sweep_policies};
    use mlcstt::faults::estimate_policy_impact;
    use mlcstt::runtime::artifacts::{model_available, ParamSpec};
    use mlcstt::util::json::{obj, Json};

    let spec = m.str("policies");
    let policies: Vec<Policy> = if spec == "all" {
        Policy::EXTENDED.to_vec()
    } else {
        m.list("policies")
            .iter()
            .map(|l| Policy::from_label(l).with_context(|| format!("bad --policies entry {l:?}")))
            .collect::<Result<_>>()?
    };
    let dir = artifacts_dir(m);
    let model = m.str("model");
    let granularity = m.usize("granularity")?;
    let seed = m.u64("seed")?;

    let (points, encode_passes, error_free, metric, flat, source) =
        if model_available(&dir, model) {
            let sweep =
                run_rate_sweep_policies(&dir, model, rates, &policies, granularity, eval, seed)?;
            let (_, weights) = load_weights(&dir, model)?;
            (
                sweep.points,
                sweep.encode_passes,
                sweep.error_free,
                "accuracy",
                weights.flat(),
                model.to_string(),
            )
        } else {
            println!(
                "({model} artifacts missing — sweeping a synthetic tensor, fidelity metric)\n"
            );
            let mut rng = Xoshiro256::seeded(seed);
            let weights = WeightFile {
                params: vec![ParamSpec {
                    name: "synthetic.w".into(),
                    shape: vec![eval],
                    data: (0..eval)
                        .map(|_| ((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
                        .collect(),
                }],
            };
            let base = StoreConfig {
                granularity,
                seed,
                ..StoreConfig::default()
            };
            let clean = weights.params[0].data.clone();
            let (points, encode_passes) =
                run_policy_sweep_with(&weights, &base, rates, &policies, |_, _, tensors, _| {
                    let same = clean
                        .iter()
                        .zip(&tensors[0].data)
                        .filter(|(a, b)| mlcstt::fp::quantize_f16(**a).to_bits() == b.to_bits())
                        .count();
                    Ok(same as f64 / clean.len() as f64)
                })?;
            (points, encode_passes, 1.0, "weight_fidelity", clean, "synthetic".to_string())
        };

    println!(
        "{}",
        rate_sweep_table(
            &format!("{source} policies=[{spec}] (g={granularity}, eval={eval}, seed={seed}) — {metric}"),
            error_free,
            &points,
        )
    );
    println!(
        "(encode+store passes: {encode_passes} — one per policy for all {} rate points)",
        rates.len()
    );

    let mut rows = Vec::new();
    for p in &points {
        for (row, report) in p.rows.iter().zip(&p.reports) {
            rows.push(obj(vec![
                ("system", Json::Str(row.system.clone())),
                ("rate", Json::Num(p.rate)),
                ("accuracy", Json::Num(row.accuracy)),
                ("flipped_cells", Json::Num(row.flipped_cells as f64)),
                ("read_nj", Json::Num(report.read_energy.nanojoules)),
                ("write_nj", Json::Num(report.write_energy.nanojoules)),
                ("metadata_overhead", Json::Num(report.metadata_overhead)),
                ("soft_cells", Json::Num(report.soft_cells_stored as f64)),
            ]));
        }
    }
    // The analytic competitor rides along as its own system: a predicted
    // front from the stream census alone (no fault campaign, no RNG).
    let mut estimated = Vec::new();
    for &policy in &policies {
        for &rate in rates {
            let est = estimate_policy_impact(policy, granularity, &flat, rate);
            estimated.push(obj(vec![
                ("system", Json::Str(policy.label().into())),
                ("rate", Json::Num(rate)),
                ("expected_sse", Json::Num(est.expected_sse)),
                ("expected_upsets", Json::Num(est.expected_upsets)),
                ("predicted_fidelity", Json::Num(est.predicted_fidelity)),
                ("mean_bit_entropy", Json::Num(est.mean_entropy)),
            ]));
        }
    }
    let mut systems: Vec<Json> = policies.iter().map(|p| Json::Str(p.label().into())).collect();
    systems.push(Json::Str("entropy-estimated".into()));
    let doc = obj(vec![
        ("schema", Json::Str("mlcstt/sweep-policies/v1".into())),
        ("model", Json::Str(source)),
        ("metric", Json::Str(metric.into())),
        ("granularity", Json::Num(granularity as f64)),
        ("eval", Json::Num(eval as f64)),
        ("seed", Json::Num(seed as f64)),
        ("error_free", Json::Num(error_free)),
        ("systems", Json::Arr(systems)),
        ("rows", Json::Arr(rows)),
        ("estimated", Json::Arr(estimated)),
    ]);
    let out_dir = mlcstt::api::env::bench_dir().unwrap_or_else(|| PathBuf::from("bench_out"));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let path = out_dir.join("SWEEP_policies.json");
    std::fs::write(&path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("wrote {}", path.display());
    Ok(())
}

// ---------------------------------------------------------------- bandwidth

fn cmd_bandwidth(args: &[String]) -> Result<()> {
    let cmd = Command::new("bandwidth", "Fig. 9: bandwidth vs buffer size")
        .flag("net", "vgg16", "layer table: vgg16 | inceptionv3 | vggmini | inceptionmini")
        .flag("sizes", "256,512,1024,2048", "buffer sizes in KB (first = SRAM)");
    let m = cmd.parse(args).map_err(usage_err)?;
    let net = m.str("net");
    let layers = models::by_name(net).with_context(|| format!("unknown net {net}"))?;
    // FC layers stream weights without reuse; the paper's Fig. 9 reports the
    // conv-buffer story, so restrict to spatial layers.
    let convs: Vec<_> = layers.into_iter().filter(|l| l.h > 1).collect();

    for direction in ["off-chip", "on-chip"] {
        let mut rows = Vec::new();
        for (i, kb) in m.list("sizes").iter().enumerate() {
            let kb: usize = kb.parse().context("bad --sizes entry")?;
            let cfg = ArrayConfig::new(kb * 1024);
            let reports = simulate_network(&convs, &cfg);
            let top = if direction == "off-chip" {
                top_k_by(&reports, 3, |r| r.offchip_bpc())
            } else {
                top_k_by(&reports, 3, |r| r.onchip_bpc())
            };
            rows.push(BandwidthRow {
                buffer_kb: kb,
                technology: if i == 0 { "SRAM" } else { "MLC STT-RAM" }.into(),
                top_layers: top,
            });
        }
        println!("{}", bandwidth_table(net, direction, &rows));
    }
    Ok(())
}

// ---------------------------------------------------------------- serve

fn cmd_serve(args: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "end-to-end serving demo")
        .flag("model", "vggmini", "artifact model name")
        .flag("artifacts", "", ARTIFACTS_HELP)
        .flag("requests", "256", "number of requests to replay")
        .flag("rate", "0.015", "soft-error rate")
        .flag("policy", "hybrid", "unprotected | round | rotate | hybrid | zero-parity")
        .flag("granularity", "4", "metadata granularity")
        .flag("max-wait-ms", "20", "batcher flush timeout")
        .flag("seed", "11", "campaign seed");
    let m = cmd.parse(args).map_err(usage_err)?;
    let model = m.str("model").to_string();
    let policy = Policy::from_label(m.str("policy"))
        .with_context(|| format!("bad --policy {:?}", m.str("policy")))?;
    let requests = m.usize("requests")?;
    let rate = m.f64("rate")?;
    let granularity = m.usize("granularity")?;
    let seed = m.u64("seed")?;
    let max_wait = Duration::from_millis(m.u64("max-wait-ms")?);

    // One layered config drives the whole entry point: artifact directory,
    // codec worker ceiling (MLCSTT_THREADS), batcher timeout (DESIGN §10).
    let config = Config::builder()
        .artifacts(artifacts_dir(&m))
        .max_wait(max_wait)
        .build();

    // Weight path: encode -> buffer -> faults -> decode, with accounting,
    // owned end to end by the deployment builder (store threads inherit
    // the config ceiling, the old ServerConfig -> StoreConfig hand-wire).
    let dep = Deployment::builder()
        .config(config.clone())
        .model(model.as_str())
        .policy(policy)
        .granularity(granularity)
        .error_model(ErrorModel::at_rate(rate))
        .seed(seed)
        .build()?;
    let sr = dep.store_report();
    println!(
        "weight path: {} tensors / {} weights, policy={}, g={granularity}\n\
         \x20 write {:.1} uJ, read {:.1} uJ, {} faulted cells, metadata overhead {:.4}%",
        sr.tensors,
        sr.weights,
        policy.label(),
        sr.write_energy.nanojoules / 1e3,
        sr.read_energy.nanojoules / 1e3,
        sr.injected_faults,
        100.0 * sr.metadata_overhead,
    );
    // Buffer-lifetime projection of this store's write mix (the soft-bit
    // pulses of the encoding policy decide how fast the cells age).
    let wear = dep.wear();
    println!(
        "buffer lifetime: stress {:.3}/write, relative lifetime {:.3}, \
         ~{:.2e} writes to rated endurance",
        wear.stress_per_write(),
        wear.relative_lifetime(),
        wear.writes_until_rated(),
    );

    // Serve through the registry: one named deployment, tag-routed
    // submits — the same path `registry_serve` scales to N models.
    let test = TestSet::read(&config.artifacts_dir().join("testset.bin"))?;
    let mut registry = ModelRegistry::new();
    registry.register_deployment(&dep, config.server())?;

    // Replay test images as tagged requests (open loop).
    let mut rng = Xoshiro256::seeded(seed);
    let mut tickets = Vec::with_capacity(requests);
    let mut expected = Vec::with_capacity(requests);
    for _ in 0..requests {
        let i = rng.below(test.n as u64) as usize;
        expected.push(test.labels[i] as usize);
        // `.ticket()?` lifts an Admission::Rejected into a typed error:
        // at the default queue depth this closed-ish replay never sheds.
        tickets.push(registry.submit(&model, test.image(i).to_vec())?.ticket()?);
    }
    let mut correct = 0usize;
    for (t, want) in tickets.into_iter().zip(expected) {
        if t.wait()?.class == want {
            correct += 1;
        }
    }
    let report = registry.shutdown();
    let section = &report.sections[0].1;
    println!(
        "served {} requests in {} batches (mean fill {:.1}; {} shed, {} errors)\n\
         \x20 accuracy {:.4} | p50 {:.1} ms | p95 {:.1} ms | p99 {:.1} ms | {:.1} req/s",
        section.served,
        section.batches,
        section.mean_batch_fill,
        section.shed,
        section.errors,
        correct as f64 / requests as f64,
        section.p50_ms,
        section.p95_ms,
        section.p99_ms,
        section.throughput_rps,
    );
    Ok(())
}

// ---------------------------------------------------------------- deliver

/// Synthetic linear-model geometry of the delivery demo (mirrors
/// `examples/registry_serve.rs` so the two demos tell one story).
const DELIVER_CLASSES: usize = 8;
const DELIVER_DIM: usize = 64;
const DELIVER_BATCH: usize = 8;

/// Deterministic f16-representable synthetic weights for one version.
fn synthetic_weights(seed: u64) -> WeightFile {
    use mlcstt::runtime::artifacts::ParamSpec;
    let mut rng = Xoshiro256::seeded(seed);
    WeightFile {
        params: vec![ParamSpec {
            name: "linear.w".into(),
            shape: vec![DELIVER_CLASSES, DELIVER_DIM],
            data: (0..DELIVER_CLASSES * DELIVER_DIM)
                .map(|_| {
                    mlcstt::fp::quantize_f16(((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
                })
                .collect(),
        }],
    }
}

/// The ISSUE 9 pipeline end to end on a synthetic model: serve a live
/// version, stream the next one through verify → stage → canary → swap
/// (with optional injected chaos), and prove the serving contract on
/// both verdicts — committed swaps answer from the new decode, failures
/// roll back to the incumbent. Writes `DELIVERY_cli.json`.
fn cmd_deliver(args: &[String]) -> Result<()> {
    use mlcstt::api::{
        deliver, CanaryCheck, ChaosStream, DeploymentManifest, MemoryStream, WeightStream,
    };
    use mlcstt::coordinator::{BatchClassifier, LinearEngine, StoreConfig};
    use mlcstt::runtime::artifacts::ParamSpec;
    use mlcstt::util::json::{obj, Json};

    let cmd = Command::new("deliver", "zero-downtime hot-swap delivery demo (synthetic model)")
        .flag("model", "demo", "registry tag of the served model")
        .flag("version", "2", "offered version (must advance the live version)")
        .flag("requests", "64", "requests replayed before and after the verdict")
        .flag("chunk", "128", "stream chunk size in weights")
        .flag("rate", "0.002", "soft-error rate of the staged store")
        .flag("policy", "hybrid", "unprotected | round | rotate | hybrid | zero-parity")
        .flag("granularity", "4", "metadata granularity")
        .flag("retries", "", "per-chunk retry budget (default: $MLCSTT_DELIVERY_RETRIES, then 3)")
        .flag(
            "backoff-ms",
            "",
            "retry backoff base in ms (default: $MLCSTT_DELIVERY_BACKOFF_MS, then 5)",
        )
        .flag("canary", "", "canary probe batches (default: $MLCSTT_CANARY, then 1)")
        .flag("fail", "0", "chaos: failed reads injected per chunk")
        .flag("truncate", "0", "chaos: truncated reads injected per chunk")
        .flag("corrupt", "0", "chaos: corrupted reads injected per chunk")
        .flag("seed", "11", "weights + faults + backoff-jitter seed");
    let m = cmd.parse(args).map_err(usage_err)?;
    let model = m.str("model").to_string();
    let version = m.u64("version")?;
    let requests = m.usize("requests")?;
    let chunk = m.usize("chunk")?;
    let rate = m.f64("rate")?;
    let policy = Policy::from_label(m.str("policy"))
        .with_context(|| format!("bad --policy {:?}", m.str("policy")))?;
    let granularity = m.usize("granularity")?;
    let seed = m.u64("seed")?;
    let fail = m.usize("fail")?;
    let truncate = m.usize("truncate")?;
    let corrupt = m.usize("corrupt")?;

    // Layered config: explicit flags beat the MLCSTT_DELIVERY_* /
    // MLCSTT_CANARY environment knobs, which beat the defaults.
    let mut builder = Config::builder().max_wait(Duration::from_millis(20));
    if !m.str("retries").is_empty() {
        builder = builder.delivery_retries(m.usize("retries")?);
    }
    if !m.str("backoff-ms").is_empty() {
        builder = builder.delivery_backoff(Duration::from_millis(m.u64("backoff-ms")?));
    }
    if !m.str("canary").is_empty() {
        builder = builder.canary(m.usize("canary")?);
    }
    let config = builder.build();
    let store = StoreConfig {
        policy,
        granularity,
        error_model: ErrorModel::at_rate(rate),
        seed,
        threads: config.threads(),
        ..StoreConfig::default()
    };

    // The incumbent version, staged through the usual encode -> MLC
    // store -> faults -> materialize lifecycle and served from its
    // decoded tensors.
    let dep = Deployment::builder()
        .config(config.clone())
        .weights(synthetic_weights(seed))
        .name(model.as_str())
        .store(store.clone())
        .build()?;
    let live = dep.tensors().to_vec();
    let mut registry = ModelRegistry::new();
    registry.register(
        &model,
        {
            let flat = live[0].data.clone();
            move || LinearEngine::new(DELIVER_CLASSES, DELIVER_DIM, DELIVER_BATCH, flat)
        },
        config.server(),
    )?;

    // Replay closed-loop requests and count agreement with a reference
    // decode (served answers must match it exactly).
    let replay = |registry: &ModelRegistry,
                  reference: &LinearEngine,
                  rng: &mut Xoshiro256|
     -> Result<(usize, usize)> {
        let mut served = 0usize;
        let mut agree = 0usize;
        for _ in 0..requests {
            let image: Vec<f32> =
                (0..DELIVER_DIM).map(|_| (rng.next_gaussian() * 0.5) as f32).collect();
            let want = reference.classify_batch(&image)?[0];
            let got = registry.submit(&model, image)?.ticket()?.wait()?.class;
            served += 1;
            if got == want {
                agree += 1;
            }
        }
        Ok((served, agree))
    };
    let mut rng = Xoshiro256::seeded(seed ^ 0xD15C0);
    let live_reference =
        LinearEngine::new(DELIVER_CLASSES, DELIVER_DIM, 1, live[0].data.clone())?;
    let (served_before, agree_before) = replay(&registry, &live_reference, &mut rng)?;

    // The next version: manifest + canary expectations from its clean
    // weights, streamed through optional injected chaos.
    let next = synthetic_weights(seed.wrapping_add(version));
    let manifest = DeploymentManifest::describe(&model, version, &next, chunk, &store)?;
    let clean_reference = LinearEngine::new(DELIVER_CLASSES, DELIVER_DIM, 1, next.flat())?;
    let checks: Vec<CanaryCheck> = (0..DELIVER_BATCH)
        .map(|c| {
            let row = (c % DELIVER_CLASSES) * DELIVER_DIM;
            let image = next.params[0].data[row..row + DELIVER_DIM].to_vec();
            let expect = clean_reference.classify_batch(&image)?[0];
            Ok(CanaryCheck { image, expect })
        })
        .collect::<Result<_>>()?;
    let mut stream: Box<dyn WeightStream> = if fail + truncate + corrupt > 0 {
        Box::new(
            ChaosStream::new(MemoryStream::from_weights(version, &next, chunk))
                .fail_first(fail)
                .truncate_first(truncate)
                .corrupt_first(corrupt),
        )
    } else {
        Box::new(MemoryStream::from_weights(version, &next, chunk))
    };

    println!(
        "delivering {model}@v{version}: {} weights in {} chunks \
         (chaos per chunk: {fail} fail / {truncate} truncate / {corrupt} corrupt)",
        manifest.total_elems,
        manifest.chunk_count(),
    );
    let outcome = deliver(
        &mut registry,
        &manifest,
        stream.as_mut(),
        &checks,
        &config,
        |t: &[ParamSpec]| {
            LinearEngine::new(DELIVER_CLASSES, DELIVER_DIM, DELIVER_BATCH, t[0].data.clone())
        },
    );
    let swapped = outcome.is_ok();
    match &outcome {
        Ok(r) => println!(
            "swap committed: v{} live after {} chunks / {} retries / {:.1} ms backoff / {} canary batches",
            r.version,
            r.chunks,
            r.retries,
            r.backoff_total.as_secs_f64() * 1e3,
            r.canary_batches,
        ),
        Err(e) => println!("delivery failed (incumbent keeps serving): {e}"),
    }

    // Either verdict must uphold the serving contract: a committed swap
    // answers from the new version's decode, a failure rolls back to the
    // incumbent's — both references rebuilt independently here (the
    // store decode is deterministic per recipe, DESIGN.md §12/§14).
    let reference = if swapped {
        let staged = Deployment::builder()
            .config(config.clone())
            .weights(synthetic_weights(seed.wrapping_add(version)))
            .name("verify")
            .store(manifest.store_config(config.threads()))
            .build()?;
        LinearEngine::new(DELIVER_CLASSES, DELIVER_DIM, 1, staged.tensors()[0].data.clone())?
    } else {
        live_reference
    };
    let (served_after, agree_after) = replay(&registry, &reference, &mut rng)?;
    println!(
        "before the verdict: {served_before}/{requests} served, {agree_before} matching the live decode\n\
         after  {}: {served_after}/{requests} served, {agree_after} matching the expected decode",
        if swapped { "the swap    " } else { "the rollback" },
    );
    let report = registry.shutdown();
    println!("{report}");

    let verdict = match &outcome {
        Ok(r) => ("delivery", r.to_json()),
        Err(e) => ("error", Json::Str(e.to_string())),
    };
    let doc = obj(vec![
        ("schema", Json::Str("mlcstt/delivery/v1".into())),
        ("manifest", manifest.to_json()),
        ("swapped", Json::Bool(swapped)),
        ("served_before", Json::from(served_before)),
        ("agree_before", Json::from(agree_before)),
        ("served_after", Json::from(served_after)),
        ("agree_after", Json::from(agree_after)),
        ("swaps", Json::Num(report.swaps as f64)),
        ("rollbacks", Json::Num(report.rollbacks as f64)),
        ("chunk_retries", Json::Num(report.delivery_retries as f64)),
        ("unavailable", Json::from(report.total_unavailable())),
        verdict,
    ]);
    let out_dir = mlcstt::api::env::bench_dir().unwrap_or_else(|| PathBuf::from("bench_out"));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let path = out_dir.join("DELIVERY_cli.json");
    std::fs::write(&path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("wrote {}", path.display());
    Ok(())
}

// ---------------------------------------------------------------- scrub

/// The ISSUE 10 subsystem end to end on a synthetic pooled tenant:
/// retention faults accumulate between cycles, the background scrubber
/// detects them against the golden per-shard checksums and repairs in
/// place, and the online EWMA telemetry tracks the injected error rate.
/// A final verification pass turns the retention story into one number —
/// residual dirty shards (0 with scrubbing on, >0 with it off). Writes
/// `SCRUB_cli.json`.
fn cmd_scrub(args: &[String]) -> Result<()> {
    use mlcstt::api::{BufferPool, EvictPolicy, ScrubMode};
    use mlcstt::coordinator::StoreConfig;
    use mlcstt::runtime::artifacts::ParamSpec;
    use mlcstt::util::json::{obj, Json};

    let cmd = Command::new("scrub", "background scrubbing & retention-telemetry demo (synthetic tenant)")
        .flag("rate", "0.02", "injected retention soft-error rate per cycle")
        .flag("cycles", "6", "disturb -> scrub cycles to run")
        .flag("policy", "hybrid", "unprotected | round | rotate | hybrid | zero-parity")
        .flag("granularity", "4", "metadata granularity")
        .flag("mode", "", "off | fixed | adaptive (default: $MLCSTT_SCRUB, then fixed)")
        .flag("interval-ms", "", "scrub interval in ms, 0 = off (default: $MLCSTT_SCRUB_MS, then 1)")
        .flag("thresh", "", "adaptive decay threshold (default: $MLCSTT_SCRUB_THRESH, then 0.05)")
        .flag("weights", "8192", "synthetic tenant size in weights")
        .flag("seed", "11", "weights + fault seed");
    let m = cmd.parse(args).map_err(usage_err)?;
    let rate = m.f64("rate")?;
    let cycles = m.usize("cycles")?;
    let policy = Policy::from_label(m.str("policy"))
        .with_context(|| format!("bad --policy {:?}", m.str("policy")))?;
    let granularity = m.usize("granularity")?;
    let weights = m.usize("weights")?;
    let seed = m.u64("seed")?;

    // Layered scrub knobs: explicit flags beat the MLCSTT_SCRUB_* env
    // knobs, which beat the demo default. Unlike the library default
    // (0 = off), the demo defaults to a 1 ms interval — this command
    // exists to show scrubbing; `--interval-ms 0` or `--mode off` shows
    // the decay-accumulation counterfactual instead.
    let mut builder = Config::builder();
    let interval_ms = if m.str("interval-ms").is_empty() {
        mlcstt::api::env::scrub_ms().unwrap_or(1)
    } else {
        m.u64("interval-ms")?
    };
    builder = builder.scrub_interval(Duration::from_millis(interval_ms));
    if !m.str("mode").is_empty() {
        builder = builder.scrub_mode(match m.str("mode") {
            "off" => ScrubMode::Off,
            "fixed" => ScrubMode::Fixed,
            "adaptive" => ScrubMode::Adaptive,
            other => bail!("bad --mode {other:?} (off | fixed | adaptive)"),
        });
    }
    if !m.str("thresh").is_empty() {
        builder = builder.scrub_threshold(m.f64("thresh")?);
    }
    let config = builder.build();
    let scrub_policy = config.scrub_policy();

    // One synthetic tenant in a small pool, admitted through the usual
    // encode -> MLC store lifecycle. The store's error model carries the
    // configured rate so the adaptive scheduler's E[SSE] signal and the
    // EWMA's reference point describe the same decay process.
    let mut rng = Xoshiro256::seeded(seed);
    let weight_file = WeightFile {
        params: vec![ParamSpec {
            name: "tenant.w".into(),
            shape: vec![weights],
            data: (0..weights)
                .map(|_| {
                    mlcstt::fp::quantize_f16(((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
                })
                .collect(),
        }],
    };
    let store = StoreConfig {
        policy,
        granularity,
        error_model: ErrorModel::at_rate(rate),
        seed,
        threads: config.threads(),
        ..StoreConfig::default()
    };
    let pool = BufferPool::new(weights * 4, 16, 256, EvictPolicy::Lru);
    pool.set_scrub(scrub_policy);
    pool.admit("tenant", &store, &weight_file)?;

    println!(
        "scrubbing a {weights}-weight tenant ({} / g{granularity}) under rate {rate}: \
         {cycles} cycles, scheduler {}",
        policy.label(),
        scrub_policy.label(),
    );

    // Disturb -> scrub cycles. The demo drives explicit passes (gated on
    // the resolved policy) instead of sleeping through the scheduler, so
    // the run is deterministic and instant; the scheduler itself is
    // exercised by the pool's lease-time hook and pinned in tests.
    let mut flipped_total = 0u64;
    let mut prev = pool.scrub_telemetry();
    for cycle in 0..cycles {
        let model = ErrorModel::at_rate(rate);
        let flipped = pool.disturb(&model)?;
        flipped_total += flipped;
        if scrub_policy.is_off() {
            println!("cycle {cycle}: {flipped} words flipped (scrubbing off, decay accumulates)");
        } else {
            let t = pool.scrub_pass()?;
            println!(
                "cycle {cycle}: {flipped} words flipped; scrub repaired {} words / {} shards (ewma {:.5})",
                t.corrected_words - prev.corrected_words,
                t.dirty_shards - prev.dirty_shards,
                t.observed_rate,
            );
            prev = t;
        }
    }

    // Verification pass: whatever is still dirty now is what the chosen
    // schedule failed to hold back. With per-cycle scrubbing the stored
    // image is already clean; with scrubbing off every cycle's decay is
    // still sitting in the buffer.
    let before = pool.scrub_telemetry();
    let fin = pool.scrub_pass()?;
    let residual = fin.dirty_shards - before.dirty_shards;
    if scrub_policy.is_off() {
        println!("verification pass: {residual} dirty shards accumulated without scrubbing");
    } else {
        println!("verification pass: {residual} residual dirty shards — scrubbing held the image clean");
    }
    println!(
        "online estimate {:.5} corrected cells/word (configured rate {rate}); worst E[SSE]/weight {:.3e}",
        fin.observed_rate, fin.max_sse_per_weight,
    );
    print!("{}", mlcstt::metrics::scrub_table("background scrub", &fin));

    let doc = obj(vec![
        ("schema", Json::Str("mlcstt/scrub/v1".into())),
        ("policy", Json::Str(fin.policy.into())),
        ("store_policy", Json::Str(policy.label().into())),
        ("rate", Json::from(rate)),
        ("cycles", Json::from(cycles)),
        ("weights", Json::from(weights)),
        ("flipped_words", Json::Num(flipped_total as f64)),
        ("passes", Json::Num(fin.passes as f64)),
        ("scrubbed_words", Json::Num(fin.scrubbed_words as f64)),
        ("corrected_words", Json::Num(fin.corrected_words as f64)),
        ("corrected_cells", Json::Num(fin.corrected_cells as f64)),
        ("policy_detected", Json::Num(fin.policy_detected as f64)),
        ("dirty_shards", Json::Num(fin.dirty_shards as f64)),
        ("residual_dirty_shards", Json::Num(residual as f64)),
        ("observed_rate", Json::from(fin.observed_rate)),
        ("max_sse_per_weight", Json::from(fin.max_sse_per_weight)),
        (
            "interval_ms",
            match fin.interval {
                Some(d) => Json::from(d.as_secs_f64() * 1e3),
                None => Json::Null,
            },
        ),
        (
            "bank_rates",
            Json::Arr(fin.bank_rates.iter().map(|&r| Json::from(r)).collect()),
        ),
    ]);
    let out_dir = mlcstt::api::env::bench_dir().unwrap_or_else(|| PathBuf::from("bench_out"));
    std::fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let path = out_dir.join("SCRUB_cli.json");
    std::fs::write(&path, doc.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    println!("wrote {}", path.display());
    Ok(())
}

fn usage_err(e: mlcstt::util::cli::CliError) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}

#[allow(dead_code)]
fn unreachable_guard() {
    // Keeps `bail!` imported for future subcommands without a warning churn.
    let _ = || -> Result<()> { bail!("unused") };
}
