//! Background scrubbing & retention maintenance (DESIGN.md §15).
//!
//! The paper's protection is applied once, at write time — but MLC
//! STT-RAM soft errors accumulate over *retention*: a long-resident
//! tenant in the shared pool silently decays between rebuilds. This
//! module is the layer that acts on that time axis:
//!
//! * the **scrub cursor** lives in the buffer layer
//!   ([`crate::buffer::MlcBuffer::scrub_region`] /
//!   [`crate::buffer::shared::SharedMlcBuffer::scrub_region`]): it walks a
//!   region in [`crate::buffer::LOAD_SHARD_WORDS`] steps, bills the scan
//!   through the §8 carry rule, detects decay against retained
//!   [`golden_checksums`] (FNV-1a per shard, the delivery-manifest
//!   discipline) plus the resident policy's in-word redundancy
//!   ([`crate::encoding::ProtectionPolicy::detect`]), and rewrites dirty
//!   shards from the clean image with store-path billing;
//! * [`RateEstimator`] — the **online error-rate telemetry**: a per-bank
//!   EWMA of corrected cells per scrubbed word, rank-checkable against
//!   the configured [`crate::stt::ErrorModel`] rate;
//! * [`ScrubPolicy`] — the **adaptive scheduler**: `Off` is byte-for-byte
//!   the status quo, `Fixed` scrubs on a constant interval, and
//!   `Adaptive` tightens the interval as the observed rate or a tenant's
//!   estimated E[SSE] per weight (from [`crate::faults::estimator`])
//!   crosses a threshold. [`crate::api::BufferPool`] runs passes between
//!   leases under its single lock, so a scrub never races a rebuild.
//!
//! Bit-identity contract (pinned by `rust/tests/scrub.rs`): a full scrub
//! pass rewrites exactly the decayed shards from the tenant's retained
//! clean image, drawing **no RNG**, so afterwards the buffer content,
//! decoded tensors, and every future stochastic bill are bit-identical to
//! a pool that was never disturbed — while `ScrubPolicy::Off` leaves
//! every byte of the existing behavior in place.

use std::time::Duration;

use crate::buffer::RegionScrub;

pub use crate::buffer::shard_checksums as golden_checksums;

/// Default adaptive threshold: the decay signal (max of observed
/// corrected-cells-per-word and estimated E[SSE] per weight) at which the
/// adaptive interval has halved once (pressure 1.0). The paper-rate
/// operating band ([`crate::stt::error::ERROR_RATE_LO`] ..
/// [`crate::stt::error::ERROR_RATE_HI`]) lands above this for unprotected
/// content and near it for protected.
pub const DEFAULT_SCRUB_THRESHOLD: f64 = 0.05;

/// Default EWMA smoothing factor for [`RateEstimator`].
pub const DEFAULT_EWMA_ALPHA: f64 = 0.3;

/// When (and whether) the pool scrubs between leases.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScrubPolicy {
    /// Never scrub — byte-for-byte the pre-subsystem behavior (no clock
    /// reads, no RNG draws, no accounting).
    Off,
    /// Scrub every resident tenant once per fixed interval.
    Fixed(Duration),
    /// Start from `base` and tighten as the decay signal grows: the
    /// effective interval is `base / (1 + signal / threshold)`, monotone
    /// non-increasing in both the observed corrected-flip rate and the
    /// estimated E[SSE] per weight.
    Adaptive {
        /// Interval when no decay has been observed.
        base: Duration,
        /// Signal level at which the interval has halved once.
        threshold: f64,
    },
}

/// Parseable scheduler kind — what `MLCSTT_SCRUB` names; the interval and
/// threshold knobs complete it into a [`ScrubPolicy`]
/// (see `api::Config::scrub_policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScrubMode {
    /// Never scrub.
    Off,
    /// Fixed interval (the default when only an interval is given).
    Fixed,
    /// Adaptive interval.
    Adaptive,
}

impl ScrubPolicy {
    /// The effective interval until the next pass, given the current
    /// decay signals, or `None` when scrubbing is off. For `Adaptive`
    /// this is monotone non-increasing in `observed_rate` (and in
    /// `sse_per_weight`), pinned by `rust/tests/scrub.rs`.
    pub fn interval(&self, observed_rate: f64, sse_per_weight: f64) -> Option<Duration> {
        match *self {
            ScrubPolicy::Off => None,
            ScrubPolicy::Fixed(d) => Some(d),
            ScrubPolicy::Adaptive { base, threshold } => {
                let signal = observed_rate.max(sse_per_weight);
                let pressure = if threshold > 0.0 && signal.is_finite() && signal > 0.0 {
                    signal / threshold
                } else {
                    0.0
                };
                Some(base.div_f64(1.0 + pressure))
            }
        }
    }

    /// Is this policy [`ScrubPolicy::Off`]?
    pub fn is_off(&self) -> bool {
        matches!(self, ScrubPolicy::Off)
    }

    /// Human-readable label (report/CLI key).
    pub fn label(&self) -> &'static str {
        match self {
            ScrubPolicy::Off => "off",
            ScrubPolicy::Fixed(_) => "fixed",
            ScrubPolicy::Adaptive { .. } => "adaptive",
        }
    }
}

/// One bank's running error-rate estimate.
#[derive(Clone, Debug, Default)]
struct BankRate {
    ewma: f64,
    primed: bool,
    corrected_cells: u64,
    scrubbed_words: u64,
}

/// Per-bank EWMA of corrected cells per scrubbed word — the online
/// counterpart of the configured write-error rate. Each scrub pass is one
/// sample per bank (banks with nothing scanned contribute none); the
/// first sample primes the EWMA, later samples blend in at
/// [`DEFAULT_EWMA_ALPHA`]. Deterministic: state is a pure fold over the
/// observed [`RegionScrub`] passes.
#[derive(Clone, Debug)]
pub struct RateEstimator {
    alpha: f64,
    banks: Vec<BankRate>,
}

impl RateEstimator {
    /// An estimator over `banks` banks with the default smoothing factor.
    pub fn new(banks: usize) -> Self {
        Self::with_alpha(banks, DEFAULT_EWMA_ALPHA)
    }

    /// An estimator with an explicit smoothing factor in `(0, 1]`.
    pub fn with_alpha(banks: usize, alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0, 1]");
        RateEstimator {
            alpha,
            banks: vec![BankRate::default(); banks],
        }
    }

    /// Fold one scrub pass into the per-bank estimates. The pass's bank
    /// vectors must come from the same geometry (`banks()` entries).
    pub fn observe(&mut self, pass: &RegionScrub) {
        for (b, (corr, scr)) in pass
            .corrected_per_bank
            .iter()
            .zip(&pass.scrubbed_per_bank)
            .enumerate()
        {
            if b >= self.banks.len() || *scr == 0 {
                continue;
            }
            let bank = &mut self.banks[b];
            bank.corrected_cells += corr;
            bank.scrubbed_words += scr;
            let sample = *corr as f64 / *scr as f64;
            if bank.primed {
                bank.ewma = self.alpha * sample + (1.0 - self.alpha) * bank.ewma;
            } else {
                bank.ewma = sample;
                bank.primed = true;
            }
        }
    }

    /// Banks tracked.
    pub fn banks(&self) -> usize {
        self.banks.len()
    }

    /// Per-bank EWMA of corrected cells per scrubbed word (0 until a bank
    /// has been scanned).
    pub fn bank_rates(&self) -> Vec<f64> {
        self.banks.iter().map(|b| b.ewma).collect()
    }

    /// Scrubbed-word-weighted mean of the per-bank EWMAs — the scheduler's
    /// scalar decay signal.
    pub fn observed_rate(&self) -> f64 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for b in &self.banks {
            if b.primed {
                num += b.ewma * b.scrubbed_words as f64;
                den += b.scrubbed_words as f64;
            }
        }
        if den > 0.0 {
            num / den
        } else {
            0.0
        }
    }

    /// Lifetime corrected cells across all banks.
    pub fn corrected_cells(&self) -> u64 {
        self.banks.iter().map(|b| b.corrected_cells).sum()
    }

    /// Lifetime scrubbed words across all banks.
    pub fn scrubbed_words(&self) -> u64 {
        self.banks.iter().map(|b| b.scrubbed_words).sum()
    }
}

/// Point-in-time scrub telemetry, exposed through
/// `api::BufferPool::scrub_telemetry` and rendered into the registry
/// report by `metrics::scrub_table`.
#[derive(Clone, Debug)]
pub struct ScrubTelemetry {
    /// Scheduler label in force (`off` / `fixed` / `adaptive`).
    pub policy: &'static str,
    /// Full passes completed.
    pub passes: u64,
    /// Words scanned across all passes.
    pub scrubbed_words: u64,
    /// Words found differing from the clean image and repaired.
    pub corrected_words: u64,
    /// MLC cells restored across all passes.
    pub corrected_cells: u64,
    /// Words the resident policy's in-word redundancy flagged.
    pub policy_detected: u64,
    /// Shards whose golden checksum disagreed.
    pub dirty_shards: u64,
    /// Scrubbed-word-weighted mean of the per-bank EWMAs.
    pub observed_rate: f64,
    /// Per-bank corrected-cells-per-word EWMAs.
    pub bank_rates: Vec<f64>,
    /// Worst estimated E[SSE] per weight among resident tenants (the
    /// adaptive scheduler's second signal).
    pub max_sse_per_weight: f64,
    /// Effective interval until the next pass (`None` when off).
    pub interval: Option<Duration>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stt::Energy;

    fn pass(corrected: &[u64], scrubbed: &[u64]) -> RegionScrub {
        RegionScrub {
            read_energy: Energy::ZERO,
            write_shards: Vec::new(),
            scrubbed_words: scrubbed.iter().sum(),
            rewritten_words: 0,
            corrected_words: 0,
            corrected_cells: corrected.iter().sum(),
            policy_detected: 0,
            dirty_shards: 0,
            corrected_per_bank: corrected.to_vec(),
            scrubbed_per_bank: scrubbed.to_vec(),
        }
    }

    #[test]
    fn adaptive_interval_monotone_in_rate() {
        let base = Duration::from_millis(1000);
        let p = ScrubPolicy::Adaptive {
            base,
            threshold: 0.05,
        };
        let mut last = Duration::MAX;
        for step in 0..50 {
            let rate = step as f64 * 0.005;
            let d = p.interval(rate, 0.0).unwrap();
            assert!(d <= last, "interval grew at rate {rate}");
            assert!(d <= base);
            last = d;
        }
        // Either signal alone tightens the schedule.
        assert!(p.interval(0.0, 0.2).unwrap() < base);
        assert_eq!(p.interval(0.0, 0.0).unwrap(), base);
        // Fixed ignores the signals; Off stays off.
        assert_eq!(ScrubPolicy::Fixed(base).interval(9.0, 9.0), Some(base));
        assert_eq!(ScrubPolicy::Off.interval(9.0, 9.0), None);
    }

    #[test]
    fn ewma_tracks_and_weights_banks() {
        let mut est = RateEstimator::with_alpha(2, 0.5);
        assert_eq!(est.observed_rate(), 0.0);
        // Bank 0 sees 10 corrected cells over 100 words; bank 1 is idle.
        est.observe(&pass(&[10, 0], &[100, 0]));
        assert!((est.bank_rates()[0] - 0.1).abs() < 1e-12);
        assert_eq!(est.bank_rates()[1], 0.0);
        assert!((est.observed_rate() - 0.1).abs() < 1e-12);
        // A cleaner second sample halves toward it (alpha 0.5); bank 1
        // primes at its first sample.
        est.observe(&pass(&[0, 30], &[100, 100]));
        assert!((est.bank_rates()[0] - 0.05).abs() < 1e-12);
        assert!((est.bank_rates()[1] - 0.3).abs() < 1e-12);
        assert_eq!(est.corrected_cells(), 40);
        assert_eq!(est.scrubbed_words(), 300);
        // Weighted mean: bank 0 has 200 words at 0.05, bank 1 has 100 at 0.3.
        let want = (0.05 * 200.0 + 0.3 * 100.0) / 300.0;
        assert!((est.observed_rate() - want).abs() < 1e-12);
    }
}
