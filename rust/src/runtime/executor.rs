//! PJRT executor: load HLO text, compile once, execute many.
//!
//! Follows the /opt/xla-example/load_hlo recipe: HLO *text* is the
//! interchange format (jax >= 0.5 serialized protos carry 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns them), and the
//! python side lowers with `return_tuple=True`, so results unwrap with
//! `to_tuple1`.

use std::path::Path;

use anyhow::{Context, Result};

/// A compiled model on the PJRT CPU client.
pub struct Executor {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    path: String,
}

impl Executor {
    /// Compile an HLO-text artifact on a fresh CPU client.
    pub fn from_hlo_file(path: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executor {
            client,
            exe,
            path: path.display().to_string(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    /// Execute with literal inputs; returns the elements of the 1-tuple
    /// result (the aot.py convention wraps outputs in a tuple).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.path))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple1().context("unwrapping 1-tuple result")
    }

    /// Execute with f32 tensors given as (data, shape) pairs; returns the
    /// flattened f32 output.
    pub fn execute_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let literals = inputs
            .iter()
            .map(|(data, shape)| literal_f32(data, shape))
            .collect::<Result<Vec<_>>>()?;
        let out = self.execute(&literals)?;
        out.to_vec::<f32>().context("reading f32 output")
    }

    /// Stage an f32 tensor on the device (hot-path optimization: weights are
    /// staged once per fault campaign, not per request).
    pub fn stage_f32(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .context("staging device buffer")
    }

    /// Execute against pre-staged device buffers (`execute_b`): no weight
    /// re-upload per request. Returns the elements of the 1-tuple result.
    pub fn execute_staged(&self, args: &[&xla::PjRtBuffer]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute_b(args)
            .with_context(|| format!("executing (staged) {}", self.path))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple1().context("unwrapping 1-tuple result")
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(
        n == data.len(),
        "shape {shape:?} wants {n} elems, got {}",
        data.len()
    );
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(&dims).context("reshaping literal")
}

/// Row-wise argmax over a flattened `[rows, cols]` logits buffer.
pub fn argmax_rows(logits: &[f32], cols: usize) -> Vec<usize> {
    assert!(cols > 0 && logits.len() % cols == 0);
    logits
        .chunks_exact(cols)
        .map(|row| {
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        let logits = [0.1, 0.9, 0.0, 3.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }

    #[test]
    fn argmax_ties_take_first() {
        assert_eq!(argmax_rows(&[1.0, 1.0], 2), vec![0]);
    }

    #[test]
    #[should_panic]
    fn argmax_rejects_ragged() {
        argmax_rows(&[1.0, 2.0, 3.0], 2);
    }

    // PJRT-dependent paths are exercised in rust/tests/integration_runtime.rs
    // against the artifacts; literal_f32's shape check is pure:
    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }
}
