//! Request-path runtime: PJRT execution of AOT artifacts + artifact I/O.
//!
//! * [`artifacts`] — readers for the build-time outputs of
//!   `python/compile/aot.py`: `*.weights.bin` (MLCW), `testset.bin` (MLCT),
//!   `*.manifest.json`, and `*.hlo.txt` paths. Pure Rust, unit-testable
//!   without a PJRT client.
//! * [`executor`] — the `xla` crate wrapper: HLO text ->
//!   `HloModuleProto::from_text_file` -> `XlaComputation` -> PJRT compile ->
//!   execute. One compiled executable per model; Python is never invoked.

pub mod artifacts;
pub mod executor;

pub use artifacts::{Manifest, ParamSpec, TestSet, WeightFile};
pub use executor::Executor;
