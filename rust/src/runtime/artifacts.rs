//! Readers for the build-time artifact formats (python/compile/io.py).
//!
//! ```text
//! weights.bin  : b"MLCW" u32 version=1 u32 count
//!                { u16 name_len, name, u8 ndim, u32 dims[ndim], f32 data }*
//! testset.bin  : b"MLCT" u32 version=1 u32 n,h,w,c  f32 images  i32 labels
//! manifest.json: param order/shapes + training metadata (util::json)
//! ```

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::Json;

/// One tensor in a weight file, in manifest order.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// A parsed `*.weights.bin`.
#[derive(Clone, Debug, Default)]
pub struct WeightFile {
    pub params: Vec<ParamSpec>,
}

impl WeightFile {
    pub fn read(path: &Path) -> Result<Self> {
        let buf = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&buf).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(buf: &[u8]) -> Result<Self> {
        let mut r = Cursor::new(buf);
        ensure!(r.bytes(4)? == b"MLCW", "bad weights magic");
        let version = r.u32()?;
        ensure!(version == 1, "unsupported weights version {version}");
        let count = r.u32()? as usize;
        ensure!(count < 100_000, "implausible tensor count {count}");
        let mut params = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.bytes(name_len)?.to_vec())
                .context("tensor name not utf-8")?;
            let ndim = r.u8()? as usize;
            ensure!(ndim <= 8, "implausible rank {ndim}");
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u32()? as usize);
            }
            let n: usize = shape.iter().product::<usize>().max(1);
            let data = r.f32s(if ndim == 0 { 1 } else { n })?;
            params.push(ParamSpec { name, shape, data });
        }
        ensure!(r.at_end(), "trailing bytes in weight file");
        Ok(WeightFile { params })
    }

    /// Total scalar count across tensors.
    pub fn total_elems(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    /// Flatten every tensor into one weight stream (buffer-encoding order).
    pub fn flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.total_elems());
        for p in &self.params {
            out.extend_from_slice(&p.data);
        }
        out
    }

    pub fn by_name(&self, name: &str) -> Option<&ParamSpec> {
        self.params.iter().find(|p| p.name == name)
    }
}

/// A parsed `testset.bin`.
#[derive(Clone, Debug)]
pub struct TestSet {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Row-major `[n, h, w, c]`.
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl TestSet {
    pub fn read(path: &Path) -> Result<Self> {
        let buf = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&buf)
    }

    pub fn parse(buf: &[u8]) -> Result<Self> {
        let mut r = Cursor::new(buf);
        ensure!(r.bytes(4)? == b"MLCT", "bad testset magic");
        ensure!(r.u32()? == 1, "unsupported testset version");
        let (n, h, w, c) = (
            r.u32()? as usize,
            r.u32()? as usize,
            r.u32()? as usize,
            r.u32()? as usize,
        );
        let images = r.f32s(n * h * w * c)?;
        let labels = r.i32s(n)?;
        ensure!(r.at_end(), "trailing bytes in testset");
        Ok(TestSet {
            n,
            h,
            w,
            c,
            images,
            labels,
        })
    }

    /// Image `i` as a flat slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let stride = self.h * self.w * self.c;
        &self.images[i * stride..(i + 1) * stride]
    }
}

/// A parsed `*.manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    /// (name, shape, size) in HLO parameter order.
    pub params: Vec<(String, Vec<usize>, usize)>,
    pub test_acc: f64,
    pub model: String,
    pub raw: Json,
}

impl Manifest {
    pub fn read(path: &Path) -> Result<Self> {
        let text =
            fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let batch = need_usize(&j, "batch")?;
        let num_classes = need_usize(&j, "num_classes")?;
        let input_shape = j
            .get("input_shape")
            .and_then(Json::as_arr)
            .context("manifest missing input_shape")?
            .iter()
            .map(|v| v.as_usize().context("bad input_shape entry"))
            .collect::<Result<Vec<_>>>()?;
        let mut params = Vec::new();
        for p in j
            .get("params")
            .and_then(Json::as_arr)
            .context("manifest missing params")?
        {
            let name = p
                .get("name")
                .and_then(Json::as_str)
                .context("param missing name")?
                .to_string();
            let shape = p
                .get("shape")
                .and_then(Json::as_arr)
                .context("param missing shape")?
                .iter()
                .map(|v| v.as_usize().context("bad shape entry"))
                .collect::<Result<Vec<_>>>()?;
            let size = p
                .get("size")
                .and_then(Json::as_usize)
                .context("param missing size")?;
            params.push((name, shape, size));
        }
        let test_acc = j
            .path("training.test_acc")
            .and_then(Json::as_f64)
            .context("manifest missing training.test_acc")?;
        let model = j
            .path("training.model")
            .and_then(Json::as_str)
            .context("manifest missing training.model")?
            .to_string();
        Ok(Manifest {
            batch,
            input_shape,
            num_classes,
            params,
            test_acc,
            model,
            raw: j,
        })
    }

    /// Cross-check a weight file against this manifest (order, shapes).
    pub fn validate(&self, w: &WeightFile) -> Result<()> {
        ensure!(
            w.params.len() == self.params.len(),
            "tensor count mismatch: weights {}, manifest {}",
            w.params.len(),
            self.params.len()
        );
        for (p, (name, shape, size)) in w.params.iter().zip(&self.params) {
            ensure!(&p.name == name, "order mismatch: {} vs {}", p.name, name);
            ensure!(&p.shape == shape, "{name}: shape mismatch");
            ensure!(p.len() == *size, "{name}: size mismatch");
        }
        Ok(())
    }
}

/// Locate the artifact triple for a model under `dir`.
pub fn model_paths(dir: &Path, model: &str) -> (PathBuf, PathBuf, PathBuf) {
    (
        dir.join(format!("{model}.hlo.txt")),
        dir.join(format!("{model}.weights.bin")),
        dir.join(format!("{model}.manifest.json")),
    )
}

/// True when `make artifacts` has produced everything this model needs.
pub fn model_available(dir: &Path, model: &str) -> bool {
    let (h, w, m) = model_paths(dir, model);
    h.exists() && w.exists() && m.exists()
}

// ---------------------------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated artifact (need {n} bytes at {})", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.bytes(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn i32s(&mut self, n: usize) -> Result<Vec<i32>> {
        let b = self.bytes(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn at_end(&self) -> bool {
        self.pos == self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_weights_bin() -> Vec<u8> {
        // Two tensors: "a.w" [2,3] and "a.b" [3].
        let mut b = Vec::new();
        b.extend(b"MLCW");
        b.extend(1u32.to_le_bytes());
        b.extend(2u32.to_le_bytes());
        // a.w
        b.extend(3u16.to_le_bytes());
        b.extend(b"a.w");
        b.push(2);
        b.extend(2u32.to_le_bytes());
        b.extend(3u32.to_le_bytes());
        for i in 0..6 {
            b.extend((i as f32 * 0.5).to_le_bytes());
        }
        // a.b
        b.extend(3u16.to_le_bytes());
        b.extend(b"a.b");
        b.push(1);
        b.extend(3u32.to_le_bytes());
        for i in 0..3 {
            b.extend((-(i as f32)).to_le_bytes());
        }
        b
    }

    #[test]
    fn weights_parse_roundtrip() {
        let w = WeightFile::parse(&sample_weights_bin()).unwrap();
        assert_eq!(w.params.len(), 2);
        assert_eq!(w.params[0].name, "a.w");
        assert_eq!(w.params[0].shape, vec![2, 3]);
        assert_eq!(w.params[0].data[3], 1.5);
        assert_eq!(w.params[1].data, vec![0.0, -1.0, -2.0]);
        assert_eq!(w.total_elems(), 9);
        assert_eq!(w.flat().len(), 9);
        assert!(w.by_name("a.b").is_some());
        assert!(w.by_name("zzz").is_none());
    }

    #[test]
    fn weights_reject_corruption() {
        let good = sample_weights_bin();
        // Bad magic.
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(WeightFile::parse(&bad).is_err());
        // Truncated.
        assert!(WeightFile::parse(&good[..good.len() - 2]).is_err());
        // Trailing garbage.
        let mut tail = good.clone();
        tail.push(0);
        assert!(WeightFile::parse(&tail).is_err());
    }

    fn sample_testset_bin() -> Vec<u8> {
        let (n, h, w, c) = (2usize, 2usize, 2usize, 1usize);
        let mut b = Vec::new();
        b.extend(b"MLCT");
        b.extend(1u32.to_le_bytes());
        for v in [n, h, w, c] {
            b.extend((v as u32).to_le_bytes());
        }
        for i in 0..(n * h * w * c) {
            b.extend((i as f32).to_le_bytes());
        }
        b.extend(3i32.to_le_bytes());
        b.extend(7i32.to_le_bytes());
        b
    }

    #[test]
    fn testset_parse() {
        let t = TestSet::parse(&sample_testset_bin()).unwrap();
        assert_eq!((t.n, t.h, t.w, t.c), (2, 2, 2, 1));
        assert_eq!(t.labels, vec![3, 7]);
        assert_eq!(t.image(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    const MANIFEST: &str = r#"{
        "batch": 64,
        "format_version": 1,
        "input_shape": [64, 32, 32, 3],
        "num_classes": 10,
        "params": [
            {"name": "a.w", "shape": [2, 3], "size": 6},
            {"name": "a.b", "shape": [3], "size": 3}
        ],
        "training": {"model": "vggmini", "test_acc": 0.9716}
    }"#;

    #[test]
    fn manifest_parse_and_validate() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.batch, 64);
        assert_eq!(m.input_shape, vec![64, 32, 32, 3]);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.model, "vggmini");
        assert!((m.test_acc - 0.9716).abs() < 1e-12);

        let w = WeightFile::parse(&sample_weights_bin()).unwrap();
        m.validate(&w).unwrap();
    }

    #[test]
    fn manifest_validation_catches_mismatch() {
        let m = Manifest::parse(MANIFEST).unwrap();
        let mut w = WeightFile::parse(&sample_weights_bin()).unwrap();
        w.params[0].name = "renamed".into();
        assert!(m.validate(&w).is_err());
        let mut w2 = WeightFile::parse(&sample_weights_bin()).unwrap();
        w2.params.pop();
        assert!(m.validate(&w2).is_err());
    }

    #[test]
    fn model_paths_layout() {
        let dir = Path::new("/tmp/artifacts");
        let (h, w, m) = model_paths(dir, "vggmini");
        assert!(h.ends_with("vggmini.hlo.txt"));
        assert!(w.ends_with("vggmini.weights.bin"));
        assert!(m.ends_with("vggmini.manifest.json"));
        assert!(!model_available(Path::new("/nonexistent"), "vggmini"));
    }
}

fn need_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("manifest missing {key}"))
}
