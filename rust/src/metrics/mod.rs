//! Experiment reports: the tables/series the paper's figures plot.
//!
//! Benches and examples produce these structures and print them through
//! [`Table`], so every paper artifact has a machine-greppable textual twin
//! (EXPERIMENTS.md records the outputs verbatim).

use crate::stt::Energy;

/// A plain aligned-column text table.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                w[i] = w[i].max(cell.len());
            }
        }
        w
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let w = self.widths();
        writeln!(f, "== {} ==", self.title)?;
        let fmt_row = |row: &[String]| -> String {
            row.iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        writeln!(f, "{}", fmt_row(&self.header))?;
        writeln!(f, "{}", "-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)))?;
        for row in &self.rows {
            writeln!(f, "{}", fmt_row(row))?;
        }
        Ok(())
    }
}

/// Fig. 6 row: stored-pattern census for one system configuration.
#[derive(Clone, Debug)]
pub struct BitcountRow {
    pub system: String,
    pub counts: [u64; 4], // [00, 01, 10, 11]
}

impl BitcountRow {
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn soft_fraction(&self) -> f64 {
        (self.counts[1] + self.counts[2]) as f64 / self.total() as f64
    }
}

/// Render a set of bit-count rows as the Fig. 6 table.
pub fn bitcount_table(model: &str, rows: &[BitcountRow]) -> Table {
    let mut t = Table::new(
        &format!("Fig.6 bit-pattern counts — {model}"),
        &["system", "00", "01", "10", "11", "soft%"],
    );
    for r in rows {
        t.row(vec![
            r.system.clone(),
            r.counts[0].to_string(),
            r.counts[1].to_string(),
            r.counts[2].to_string(),
            r.counts[3].to_string(),
            format!("{:.2}", 100.0 * r.soft_fraction()),
        ]);
    }
    t
}

/// Fig. 7 row: energy for one system configuration.
#[derive(Clone, Debug)]
pub struct EnergyRow {
    pub system: String,
    pub read: Energy,
    pub write: Energy,
}

/// Render energy rows with savings relative to the first (baseline) row.
pub fn energy_table(model: &str, rows: &[EnergyRow]) -> Table {
    let mut t = Table::new(
        &format!("Fig.7 buffer energy — {model}"),
        &[
            "system",
            "read nJ",
            "write nJ",
            "read save%",
            "write save%",
        ],
    );
    let base = rows.first().expect("needs a baseline row");
    for r in rows {
        t.row(vec![
            r.system.clone(),
            format!("{:.1}", r.read.nanojoules),
            format!("{:.1}", r.write.nanojoules),
            format!("{:.2}", 100.0 * (1.0 - r.read.nanojoules / base.read.nanojoules)),
            format!("{:.2}", 100.0 * (1.0 - r.write.nanojoules / base.write.nanojoules)),
        ]);
    }
    t
}

/// Fig. 8 row: classification accuracy for one protection system.
#[derive(Clone, Debug)]
pub struct AccuracyRow {
    pub system: String,
    pub accuracy: f64,
    pub flipped_cells: u64,
}

pub fn accuracy_table(model: &str, error_free: f64, rows: &[AccuracyRow]) -> Table {
    let mut t = Table::new(
        &format!("Fig.8 accuracy — {model} (error-free = {error_free:.4})"),
        &["system", "accuracy", "delta vs error-free", "cells flipped"],
    );
    for r in rows {
        t.row(vec![
            r.system.clone(),
            format!("{:.4}", r.accuracy),
            format!("{:+.4}", r.accuracy - error_free),
            r.flipped_cells.to_string(),
        ]);
    }
    t
}

/// Fig. 9 row: bandwidth for one buffer size.
#[derive(Clone, Debug)]
pub struct BandwidthRow {
    pub buffer_kb: usize,
    pub technology: String,
    /// (layer name, bytes/cycle) for the top-3 layers.
    pub top_layers: Vec<(String, f64)>,
}

pub fn bandwidth_table(model: &str, direction: &str, rows: &[BandwidthRow]) -> Table {
    let mut t = Table::new(
        &format!("Fig.9 {direction} bandwidth — {model} (top-3 layers, bytes/cycle)"),
        &["buffer", "tech", "layer1", "bpc1", "layer2", "bpc2", "layer3", "bpc3"],
    );
    for r in rows {
        let mut cells = vec![format!("{} KB", r.buffer_kb), r.technology.clone()];
        for i in 0..3 {
            if let Some((name, bpc)) = r.top_layers.get(i) {
                cells.push(name.clone());
                cells.push(format!("{bpc:.2}"));
            } else {
                cells.push("-".into());
                cells.push("-".into());
            }
        }
        t.row(cells);
    }
    t
}

/// Render per-model serving reports as an SLO table: one row per model
/// with the served/shed/errors split, the latency percentiles, and the
/// queue-depth stats (DESIGN.md §11). Used by
/// [`crate::api::RegistryReport`]'s `Display` and the serving demos.
pub fn serving_table(
    title: &str,
    rows: &[(String, crate::coordinator::ServerReport)],
) -> Table {
    let mut t = Table::new(
        title,
        &[
            "model", "served", "shed", "errors", "unavail", "rebuilds", "batches", "fill",
            "p50 ms", "p95 ms", "p99 ms", "req/s", "q.mean", "q.max",
        ],
    );
    for (name, r) in rows {
        t.row(vec![
            name.clone(),
            r.served.to_string(),
            r.shed.to_string(),
            r.errors.to_string(),
            r.unavailable.to_string(),
            r.rebuilds.to_string(),
            r.batches.to_string(),
            format!("{:.1}", r.mean_batch_fill),
            format!("{:.2}", r.p50_ms),
            format!("{:.2}", r.p95_ms),
            format!("{:.2}", r.p99_ms),
            format!("{:.1}", r.throughput_rps),
            format!("{:.1}", r.queue_mean),
            r.queue_max.to_string(),
        ]);
    }
    t
}

/// Render the shared pool's "buffer lifetime under traffic" report: one
/// row per bank with per-extent write extremes and the endurance
/// projection of that bank's absorbed wear mix
/// ([`crate::buffer::shared::SharedMlcBuffer::bank_wear`]). Surfaced by
/// [`crate::api::RegistryReport`]'s `Display` and the serving demos.
pub fn wear_table(title: &str, rows: &[crate::buffer::shared::BankWear]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "bank",
            "extents",
            "max wr",
            "mean wr",
            "stress/wr",
            "rel.life",
            "wr-to-rated",
        ],
    );
    for r in rows {
        t.row(vec![
            r.bank.to_string(),
            r.extents.to_string(),
            r.max_writes.to_string(),
            format!("{:.1}", r.mean_writes),
            format!("{:.3}", r.stress_per_write),
            format!("{:.3}", r.relative_lifetime),
            format!("{:.2e}", r.writes_until_rated),
        ]);
    }
    t
}

/// Render background-scrub telemetry (DESIGN.md §15): one row per bank
/// with its corrected-cells-per-word EWMA, then a totals row with the
/// pass counters, the weighted observed rate, and the effective interval.
/// Surfaced by [`crate::api::RegistryReport`]'s `Display` and the
/// `mlcstt scrub` demo.
pub fn scrub_table(title: &str, s: &crate::scrub::ScrubTelemetry) -> Table {
    let mut t = Table::new(
        title,
        &["bank", "ewma c/w", "passes", "scrubbed", "corrected", "dirty", "interval"],
    );
    for (b, rate) in s.bank_rates.iter().enumerate() {
        t.row(vec![
            b.to_string(),
            format!("{rate:.5}"),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
        ]);
    }
    t.row(vec![
        format!("all ({})", s.policy),
        format!("{:.5}", s.observed_rate),
        s.passes.to_string(),
        s.scrubbed_words.to_string(),
        format!("{}w/{}c", s.corrected_words, s.corrected_cells),
        s.dirty_shards.to_string(),
        match s.interval {
            Some(d) => format!("{:.0?}", d),
            None => "off".into(),
        },
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "12345".into()]);
        let s = t.to_string();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name"));
        // Aligned: both value cells end at the same column.
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bitcount_soft_fraction() {
        let r = BitcountRow {
            system: "g1".into(),
            counts: [40, 10, 10, 40],
        };
        assert_eq!(r.total(), 100);
        assert!((r.soft_fraction() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn energy_table_savings_vs_baseline() {
        let rows = vec![
            EnergyRow {
                system: "baseline".into(),
                read: Energy { nanojoules: 100.0, cycles: 0 },
                write: Energy { nanojoules: 200.0, cycles: 0 },
            },
            EnergyRow {
                system: "hybrid".into(),
                read: Energy { nanojoules: 91.0, cycles: 0 },
                write: Energy { nanojoules: 188.0, cycles: 0 },
            },
        ];
        let s = energy_table("vgg", &rows).to_string();
        assert!(s.contains("9.00"), "{s}");
        assert!(s.contains("6.00"), "{s}");
    }

    #[test]
    fn serving_table_renders_slo_columns() {
        let rep = crate::coordinator::ServerReport {
            served: 90,
            shed: 8,
            errors: 2,
            unavailable: 1,
            batches: 12,
            mean_batch_fill: 7.5,
            p50_ms: 1.25,
            p95_ms: 3.5,
            p99_ms: 4.75,
            throughput_rps: 123.4,
            wall_s: 0.8,
            queue_mean: 2.5,
            queue_max: 6,
            rebuilds: 3,
        };
        let s = serving_table("slo", &[("hot".to_string(), rep)]).to_string();
        assert!(s.contains("== slo =="));
        assert!(s.contains("hot"));
        assert!(s.contains("90"));
        assert!(s.contains("8"), "shed column");
        assert!(s.contains("3.50"), "p95 column");
        assert!(s.contains("q.max"));
        assert!(s.contains("rebuilds"));
    }

    #[test]
    fn wear_table_renders_lifetime_columns() {
        let rows = vec![crate::buffer::shared::BankWear {
            bank: 0,
            extents: 4,
            max_writes: 1200,
            mean_writes: 900.0,
            stress_per_write: 1.75,
            relative_lifetime: 0.571,
            writes_until_rated: 2.29e15,
        }];
        let s = wear_table("buffer lifetime under traffic", &rows).to_string();
        assert!(s.contains("buffer lifetime under traffic"));
        assert!(s.contains("1200"));
        assert!(s.contains("1.750"));
        assert!(s.contains("wr-to-rated"));
        assert!(s.contains("2.29e15"));
    }

    #[test]
    fn accuracy_and_bandwidth_tables_render() {
        let a = accuracy_table(
            "vgg",
            0.97,
            &[AccuracyRow {
                system: "unprotected".into(),
                accuracy: 0.69,
                flipped_cells: 1234,
            }],
        );
        assert!(a.to_string().contains("-0.2800"));

        let b = bandwidth_table(
            "vgg",
            "off-chip",
            &[BandwidthRow {
                buffer_kb: 256,
                technology: "SRAM".into(),
                top_layers: vec![("Conv11".into(), 25.5)],
            }],
        );
        let s = b.to_string();
        assert!(s.contains("256 KB"));
        assert!(s.contains("25.50"));
        assert!(s.contains('-'));
    }
}
