//! End-to-end weight-tensor codec: f32 weights -> stored MLC word stream +
//! tri-level metadata, and back.
//!
//! Pipeline (paper Fig. 5):
//!
//! ```text
//!  f32 weight --quantize--> binary16 --protect sign--> protected word
//!      --[per-group scheme selection]--> stored image + scheme symbol
//! ```
//!
//! Decoding inverts the group's scheme and clears the backup bit. The codec
//! also produces the statistics the paper reports: pattern counts (Fig. 6)
//! and metadata storage overhead (Table 3).

use super::parity;
use super::scheme::{self, Scheme};
use super::select::{select_from_tallies, Policy};
use super::swar;
use crate::fp;
use crate::stt::{AccessKind, CostModel, Energy};
use crate::util::threads;

/// Below this many weights a tensor is encoded/decoded inline — the
/// `std::thread::scope` spawn cost would exceed the work.
pub const MIN_WEIGHTS_PER_WORKER: usize = 1 << 16;

/// Encoder configuration.
#[derive(Clone, Copy, Debug)]
pub struct WeightCodec {
    /// Scheme-selection policy (Fig. 8 system).
    pub policy: Policy,
    /// Weights per metadata group (Table 3: 1, 2, 4, 8, 16).
    pub granularity: usize,
}

impl WeightCodec {
    /// A codec with an explicit policy and metadata granularity
    /// (granularity must be >= 1).
    pub fn new(policy: Policy, granularity: usize) -> Self {
        assert!(granularity >= 1, "granularity must be >= 1");
        WeightCodec {
            policy,
            granularity,
        }
    }

    /// The paper's headline configuration.
    pub fn hybrid(granularity: usize) -> Self {
        Self::new(Policy::Hybrid, granularity)
    }

    /// Encode a tensor of f32 weights (all |w| <= 2 after fp16 quantization;
    /// the trainer guarantees |w| <= 1).
    pub fn encode(&self, weights: &[f32]) -> Encoded {
        let mut out = Encoded::with_context(self.policy, self.granularity);
        self.encode_into(weights, &mut out);
        out
    }

    /// Encode into a caller-owned `Encoded`, reusing its buffers
    /// (allocation-free after the first call at a given size). Shards
    /// across worker threads when the tensor is large enough.
    pub fn encode_into(&self, weights: &[f32], out: &mut Encoded) {
        self.encode_into_threaded(
            weights,
            out,
            threads::auto_workers(weights.len(), MIN_WEIGHTS_PER_WORKER),
        );
    }

    /// [`Self::encode_into`] with an explicit worker count. Results are
    /// bit-identical for every `workers` value: shard boundaries are
    /// group-aligned and depend only on the data (see `util::threads`).
    pub fn encode_into_threaded(&self, weights: &[f32], out: &mut Encoded, workers: usize) {
        out.policy = self.policy;
        out.granularity = self.granularity;
        // Resize only on length change: every element is overwritten below,
        // so a same-size re-encode skips the clear+resize memset entirely.
        if out.words.len() != weights.len() {
            out.words.resize(weights.len(), 0);
        }

        if !self.policy.has_metadata() {
            out.schemes.clear();
            // Metadata-free stream: raw binary16 (Unprotected) or in-place
            // parity-protected words (ZeroSpaceParity). Both are per-word
            // maps, so sharding needs no group alignment.
            let encode: fn(&[f32], &mut [u16]) = if self.policy == Policy::ZeroSpaceParity {
                parity::encode_slice
            } else {
                fp::quantize_into
            };
            let bounds = threads::chunk_bounds(weights.len(), 1, workers);
            if bounds.len() <= 1 {
                encode(weights, &mut out.words);
            } else {
                std::thread::scope(|scope| {
                    let mut rest: &mut [u16] = &mut out.words;
                    for &(start, end) in &bounds {
                        let (dst, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
                        rest = tail;
                        let src = &weights[start..end];
                        scope.spawn(move || encode(src, dst));
                    }
                });
            }
            return;
        }

        let g = self.granularity;
        let n_groups = weights.len().div_ceil(g);
        if out.schemes.len() != n_groups {
            out.schemes.resize(n_groups, Scheme::NoChange);
        }
        let bounds = threads::chunk_bounds(weights.len(), g, workers);
        if bounds.len() <= 1 {
            if !weights.is_empty() {
                self.encode_range(weights, &mut out.words, &mut out.schemes);
            }
        } else {
            std::thread::scope(|scope| {
                let mut words_rest: &mut [u16] = &mut out.words;
                let mut schemes_rest: &mut [Scheme] = &mut out.schemes;
                for &(start, end) in &bounds {
                    let (w_dst, w_tail) =
                        std::mem::take(&mut words_rest).split_at_mut(end - start);
                    words_rest = w_tail;
                    let (s_dst, s_tail) = std::mem::take(&mut schemes_rest)
                        .split_at_mut((end - start).div_ceil(g));
                    schemes_rest = s_tail;
                    let src = &weights[start..end];
                    let codec = *self;
                    scope.spawn(move || codec.encode_range(src, w_dst, s_dst));
                }
            });
        }
    }

    /// Encode one group-aligned shard: quantize + sign-protect each group
    /// into a scratch buffer, pick its scheme from packed cost tallies, and
    /// apply the winner with the SWAR kernels.
    fn encode_range(&self, src: &[f32], words: &mut [u16], schemes: &mut [Scheme]) {
        let g = self.granularity;
        let mut scratch = vec![0u16; g.min(src.len())];
        for ((w_src, w_dst), slot) in src
            .chunks(g)
            .zip(words.chunks_mut(g))
            .zip(schemes.iter_mut())
        {
            let protected = &mut scratch[..w_src.len()];
            fp::quantize_into(w_src, protected);
            debug_assert!(
                protected.iter().all(|&h| fp::backup_bit_free(h)),
                "weight outside the |w| < 2 premise"
            );
            swar::protect_sign_slice(protected);
            let (s, _) = select_from_tallies(self.policy, swar::group_cost_tallies(protected));
            *slot = s;
            swar::apply_into(s, protected, w_dst);
        }
    }

    /// The pre-SWAR single-threaded per-word encoder, kept verbatim as the
    /// oracle for equivalence tests and the bench speedup denominator.
    pub fn encode_scalar(&self, weights: &[f32]) -> Encoded {
        let mut words = Vec::with_capacity(weights.len());
        let mut schemes = Vec::with_capacity(weights.len().div_ceil(self.granularity));

        if !self.policy.has_metadata() {
            words.extend(weights.iter().map(|&w| {
                let h = fp::f32_to_f16_bits(w);
                if self.policy == Policy::ZeroSpaceParity {
                    debug_assert!(
                        fp::backup_bit_free(h),
                        "weight {w} outside the |w| < 2 premise"
                    );
                    parity::encode_word(h)
                } else {
                    h
                }
            }));
            return Encoded {
                words,
                schemes,
                granularity: self.granularity,
                policy: self.policy,
            };
        }

        let protected: Vec<u16> = weights
            .iter()
            .map(|&w| {
                let h = fp::f32_to_f16_bits(w);
                debug_assert!(
                    fp::backup_bit_free(h),
                    "weight {w} outside the |w| < 2 premise"
                );
                scheme::protect_sign(h)
            })
            .collect();

        for group in protected.chunks(self.granularity) {
            // Per-word re-scoring, independent of the SWAR tally kernel.
            let mut sums = [0u32; 3];
            for &p in group {
                let c = super::select::candidate_soft_cells(p);
                for (acc, v) in sums.iter_mut().zip(c) {
                    *acc += v;
                }
            }
            let (s, _) = select_from_tallies(self.policy, sums);
            schemes.push(s);
            words.extend(group.iter().map(|&p| scheme::apply(s, p)));
        }

        Encoded {
            words,
            schemes,
            granularity: self.granularity,
            policy: self.policy,
        }
    }
}

/// An encoded weight stream: what physically sits in the MLC buffer.
#[derive(Clone, Debug)]
pub struct Encoded {
    /// Stored binary16 images, one per weight, in order.
    pub words: Vec<u16>,
    /// Per-group scheme symbols (empty for `Unprotected`), stored in the
    /// tri-level metadata plane.
    pub schemes: Vec<Scheme>,
    /// Weights per metadata group this stream was encoded at.
    pub granularity: usize,
    /// Policy this stream was encoded under (decides decode semantics).
    pub policy: Policy,
}

impl Encoded {
    /// An empty stream carrying codec context — the reusable target for
    /// [`WeightCodec::encode_into`].
    pub fn with_context(policy: Policy, granularity: usize) -> Encoded {
        Encoded {
            words: Vec::new(),
            schemes: Vec::new(),
            granularity,
            policy,
        }
    }

    /// Number of stored words (== number of weights).
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True iff the stream holds no words.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Scheme governing word index `i` (`NoChange` for the metadata-free
    /// policies, which store no per-group symbols).
    #[inline]
    pub fn scheme_of(&self, i: usize) -> Scheme {
        if self.policy.has_metadata() {
            self.schemes[i / self.granularity]
        } else {
            Scheme::NoChange
        }
    }

    /// Decode all words back to f32 (after any fault injection mutated
    /// `words` in place).
    pub fn decode(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.decode_into(&mut out);
        out
    }

    /// Decode into a caller-owned buffer (resized to fit), sharding
    /// across worker threads when the stream is large enough.
    pub fn decode_into(&self, out: &mut Vec<f32>) {
        self.decode_into_threaded(
            out,
            threads::auto_workers(self.len(), MIN_WEIGHTS_PER_WORKER),
        );
    }

    /// [`Self::decode_into`] with an explicit worker count; bit-identical
    /// for every `workers` value.
    pub fn decode_into_threaded(&self, out: &mut Vec<f32>, workers: usize) {
        // Length-change-only resize: every slot is overwritten below.
        if out.len() != self.len() {
            out.resize(self.len(), 0.0);
        }
        let g = if self.policy.has_metadata() {
            self.granularity
        } else {
            1
        };
        let bounds = threads::chunk_bounds(self.len(), g, workers);
        if bounds.len() <= 1 {
            if !self.is_empty() {
                self.decode_range(0, &self.words, out);
            }
        } else {
            std::thread::scope(|scope| {
                let mut rest: &mut [f32] = out;
                for &(start, end) in &bounds {
                    let (dst, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
                    rest = tail;
                    let src = &self.words[start..end];
                    scope.spawn(move || self.decode_range(start, src, dst));
                }
            });
        }
    }

    /// Decode one group-aligned shard starting at word index `start` via
    /// the shared [`decode_slice`] inner loop.
    fn decode_range(&self, start: usize, src: &[u16], dst: &mut [f32]) {
        decode_slice(self.policy, self.granularity, &self.schemes, start, src, dst);
    }

    /// The pre-SWAR per-word decoder, kept as the equivalence oracle.
    pub fn decode_scalar(&self) -> Vec<f32> {
        self.words
            .iter()
            .enumerate()
            .map(|(i, &w)| self.decode_word(i, w))
            .collect()
    }

    /// Decode a single stored image.
    #[inline]
    pub fn decode_word(&self, i: usize, stored: u16) -> f32 {
        match self.policy {
            Policy::Unprotected => fp::f16_bits_to_f32(stored),
            Policy::ZeroSpaceParity => parity::decode_word(stored),
            _ => fp::f16_bits_to_f32(scheme::invert(self.scheme_of(i), stored)),
        }
    }

    /// Pattern census over the stored stream (Fig. 6): `[n00,n01,n10,n11]`,
    /// via the packed SWAR kernel, sharded over
    /// [`threads::run_sharded`] like the energy census. Integer-exact and
    /// worker-count-invariant (pinned by `rust/tests/api_facade.rs`).
    pub fn pattern_counts(&self) -> [u64; 4] {
        fp::count_patterns_threaded(
            &self.words,
            threads::auto_workers(self.len(), MIN_WEIGHTS_PER_WORKER),
        )
    }

    /// Total vulnerable cells in the stored stream (packed kernel, sharded
    /// like [`Self::pattern_counts`]; integer-exact for any worker count).
    pub fn soft_cells(&self) -> u64 {
        fp::soft_cells_threaded(
            &self.words,
            threads::auto_workers(self.len(), MIN_WEIGHTS_PER_WORKER),
        )
    }

    /// Metadata storage overhead (Table 3): 2 bits per group over the
    /// 16-bit payload words. Granularity 1 -> 0.125, 16 -> 0.0078125.
    pub fn metadata_overhead(&self) -> f64 {
        if !self.policy.has_metadata() || self.is_empty() {
            return 0.0;
        }
        let groups = self.len().div_ceil(self.granularity);
        (2 * groups) as f64 / (16 * self.len()) as f64
    }

    /// Content-dependent energy + latency of accessing the entire stream
    /// once (payload words + one tri-level metadata cell per group).
    /// Latency counts each word access serially (a buffer-wide sweep);
    /// [`crate::buffer`] models banked parallelism on top of this.
    ///
    /// Default path (DESIGN.md §9): one packed SWAR census
    /// ([`swar::energy_tally_threaded`], sharded over
    /// [`threads::run_sharded`]) reduced through the
    /// [`CostModel::stream`] dot product — no per-word `CostModel::word`
    /// call. Cycles are integer-exact against the retained per-word
    /// oracle ([`Self::access_energy_scalar`]); nanojoules agree to f64
    /// rounding (the tally path rounds once per pattern instead of twice
    /// per word). The census is worker-count-invariant, so threading is
    /// invisible to the result.
    pub fn access_energy(&self, cost: &CostModel, kind: AccessKind) -> Energy {
        let tally = swar::energy_tally_threaded(
            &self.words,
            threads::auto_workers(self.len(), MIN_WEIGHTS_PER_WORKER),
        );
        let mut total = cost.stream(tally.patterns, tally.hard_words, tally.words, kind);
        self.add_metadata_cost(cost, kind, &mut total);
        total
    }

    /// The pre-tally per-word accounting loop, kept verbatim as the
    /// equivalence oracle and the bench speedup denominator.
    pub fn access_energy_scalar(&self, cost: &CostModel, kind: AccessKind) -> Energy {
        let mut total = Energy::ZERO;
        for &w in &self.words {
            total.add(cost.word(w, kind));
        }
        self.add_metadata_cost(cost, kind, &mut total);
        total
    }

    /// The tri-level metadata share of a stream access: one cell per
    /// scheme group, billed at SLC cost (identical on both accounting
    /// paths by construction).
    fn add_metadata_cost(&self, cost: &CostModel, kind: AccessKind, total: &mut Energy) {
        if self.policy.has_metadata() {
            let meta = cost.trilevel_cell(kind);
            let groups = self.schemes.len() as f64;
            total.add(Energy {
                nanojoules: meta.nanojoules * groups,
                cycles: meta.cycles * self.schemes.len() as u64,
            });
        }
    }

    /// Scheme usage histogram `[nochange, rotate, round]` — the ablation
    /// statistic behind the Fig. 6/7 granularity trends.
    pub fn scheme_histogram(&self) -> [u64; 3] {
        let mut h = [0u64; 3];
        for s in &self.schemes {
            h[s.symbol() as usize] += 1;
        }
        h
    }
}

/// Decode a group-aligned run of stored words to f32: invert each group's
/// scheme with the SWAR kernels into a scratch buffer, then convert
/// through the converter selected by [`fp::f16_mode`] (LUT by default —
/// the decode-floor lift). `start` is the stream index of `src[0]` and
/// must sit on a group boundary; `schemes` is the stream's full per-group
/// table. This is the shared inner loop of [`Encoded::decode_into_threaded`]
/// and the pipelined [`crate::buffer::MlcBuffer::load_decoded`] — both
/// produce identical bits because group boundaries, not caller chunk
/// boundaries, drive the kernels.
pub fn decode_slice(
    policy: Policy,
    granularity: usize,
    schemes: &[Scheme],
    start: usize,
    src: &[u16],
    dst: &mut [f32],
) {
    debug_assert_eq!(src.len(), dst.len());
    if policy == Policy::Unprotected {
        fp::decode_f16_slice(src, dst);
        return;
    }
    if policy == Policy::ZeroSpaceParity {
        parity::decode_slice(src, dst);
        return;
    }
    let g = granularity;
    debug_assert_eq!(start % g, 0);
    let mut scratch = vec![0u16; g.min(src.len())];
    let schemes = &schemes[start / g..];
    for ((w_src, &s), o_dst) in src.chunks(g).zip(schemes).zip(dst.chunks_mut(g)) {
        let canonical = &mut scratch[..w_src.len()];
        swar::invert_into(s, w_src, canonical);
        fp::decode_f16_slice(canonical, o_dst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f32> {
        // Deterministic weights spanning [-1, 1], fp16-exact after quantize.
        (0..n)
            .map(|i| fp::quantize_f16((i as f32 / n as f32) * 2.0 - 1.0))
            .collect()
    }

    #[test]
    fn lossless_roundtrip_without_round_scheme() {
        let ws = ramp(1000);
        for g in [1usize, 2, 4, 8, 16] {
            let codec = WeightCodec::new(Policy::ProtectRotate, g);
            let enc = codec.encode(&ws);
            let back = enc.decode();
            assert_eq!(back, ws, "granularity {g}");
        }
    }

    #[test]
    fn hybrid_roundtrip_error_bounded_by_round() {
        // Round only perturbs the low 4 mantissa bits: for |w| <= 1 the
        // absolute error is at most 15 ULPs at the value's scale.
        let ws = ramp(4096);
        let codec = WeightCodec::hybrid(4);
        let enc = codec.encode(&ws);
        for (orig, dec) in ws.iter().zip(enc.decode()) {
            let ulp = (fp::f16_bits_to_f32(fp::f32_to_f16_bits(*orig) | 0xF)
                - fp::f16_bits_to_f32(fp::f32_to_f16_bits(*orig) & !0xF))
            .abs();
            assert!(
                (orig - dec).abs() <= ulp + f32::EPSILON,
                "orig={orig} dec={dec}"
            );
        }
    }

    #[test]
    fn unprotected_is_raw_f16() {
        let ws = ramp(64);
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        assert!(enc.schemes.is_empty());
        assert_eq!(enc.metadata_overhead(), 0.0);
        for (w, &stored) in ws.iter().zip(&enc.words) {
            assert_eq!(stored, fp::f32_to_f16_bits(*w));
        }
        assert_eq!(enc.decode(), ws);
    }

    #[test]
    fn encoding_never_increases_soft_cells() {
        let ws = ramp(2048);
        let raw = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        // Sign-protected streams compare against the protected NoChange
        // image, which for negative weights converts a vulnerable 10 cell
        // into an immune 11 — so hybrid must beat even the raw count here.
        let hybrid = WeightCodec::hybrid(1).encode(&ws);
        assert!(hybrid.soft_cells() <= raw.soft_cells());
    }

    #[test]
    fn granularity_trend_soft_cells_monotone_nondecreasing() {
        // Coarser groups can only do same-or-worse (fewer choices).
        let ws = ramp(4096);
        let mut prev = 0u64;
        for g in [1usize, 2, 4, 8, 16] {
            let soft = WeightCodec::hybrid(g).encode(&ws).soft_cells();
            assert!(soft >= prev, "g={g}: {soft} < {prev}");
            prev = soft;
        }
    }

    #[test]
    fn table3_overhead_exact() {
        let ws = ramp(1024);
        let expect = [
            (1usize, 0.125),
            (2, 0.0625),
            (4, 0.03125),
            (8, 0.015625),
            (16, 0.0078125),
        ];
        for (g, ov) in expect {
            let enc = WeightCodec::hybrid(g).encode(&ws);
            assert!((enc.metadata_overhead() - ov).abs() < 1e-12, "g={g}");
        }
    }

    #[test]
    fn ragged_tail_group_handled() {
        let ws = ramp(13); // 13 % 4 != 0
        let codec = WeightCodec::hybrid(4);
        let enc = codec.encode(&ws);
        assert_eq!(enc.schemes.len(), 4); // ceil(13/4)
        assert_eq!(enc.decode().len(), 13);
        let back = WeightCodec::new(Policy::ProtectRotate, 4).encode(&ws).decode();
        assert_eq!(back, ws);
    }

    #[test]
    fn pattern_counts_sum_to_cells() {
        let ws = ramp(777);
        let enc = WeightCodec::hybrid(2).encode(&ws);
        let pc = enc.pattern_counts();
        assert_eq!(pc.iter().sum::<u64>(), 777 * 8);
        assert_eq!(pc[1] + pc[2], enc.soft_cells());
    }

    #[test]
    fn access_energy_cheaper_than_unprotected_uniformly_soft() {
        let cost = CostModel::default();
        // Mostly-negative ramp: unprotected stores many 10 sign cells.
        let ws: Vec<f32> = (0..512)
            .map(|i| fp::quantize_f16(-0.9 + 0.0001 * i as f32))
            .collect();
        let raw = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let hyb = WeightCodec::hybrid(4).encode(&ws);
        let raw_e = raw.access_energy(&cost, AccessKind::Write);
        let hyb_e = hyb.access_energy(&cost, AccessKind::Write);
        assert!(
            hyb_e.nanojoules < raw_e.nanojoules,
            "hybrid {hyb_e:?} vs raw {raw_e:?}"
        );
    }

    #[test]
    fn access_energy_tally_matches_scalar_oracle() {
        // The broad sweep lives in tests/sweep_equivalence.rs; this is the
        // fast in-crate check: cycles exact, nanojoules to f64 rounding.
        let cost = CostModel::default();
        let ws = ramp(3001);
        for policy in [Policy::Unprotected, Policy::Hybrid] {
            let enc = WeightCodec::new(policy, 4).encode(&ws);
            for kind in [AccessKind::Read, AccessKind::Write] {
                let fast = enc.access_energy(&cost, kind);
                let oracle = enc.access_energy_scalar(&cost, kind);
                assert_eq!(fast.cycles, oracle.cycles, "{policy:?} {kind:?}");
                let rel = (fast.nanojoules - oracle.nanojoules).abs() / oracle.nanojoules;
                assert!(rel < 1e-12, "{policy:?} {kind:?}: {rel}");
            }
        }
    }

    #[test]
    fn scheme_histogram_counts_groups() {
        let ws = ramp(256);
        let enc = WeightCodec::hybrid(4).encode(&ws);
        assert_eq!(enc.scheme_histogram().iter().sum::<u64>() as usize, enc.schemes.len());
    }

    #[test]
    fn swar_encode_matches_scalar_oracle() {
        let ws = ramp(3000);
        for policy in Policy::EXTENDED {
            for g in [1usize, 2, 4, 8, 16, 7] {
                let codec = WeightCodec::new(policy, g);
                let fast = codec.encode(&ws);
                let oracle = codec.encode_scalar(&ws);
                assert_eq!(fast.words, oracle.words, "{policy:?} g={g}");
                assert_eq!(fast.schemes, oracle.schemes, "{policy:?} g={g}");
                assert_eq!(fast.decode(), oracle.decode_scalar(), "{policy:?} g={g}");
            }
        }
    }

    #[test]
    fn parity_stream_is_zero_space_and_lossless() {
        let ws = ramp(1003);
        let enc = WeightCodec::new(Policy::ZeroSpaceParity, 1).encode(&ws);
        assert!(enc.schemes.is_empty());
        assert_eq!(enc.metadata_overhead(), 0.0);
        assert_eq!(enc.decode(), ws);
        for (w, &stored) in ws.iter().zip(&enc.words) {
            assert_eq!(stored, parity::encode_word(fp::f32_to_f16_bits(*w)));
        }
        // Metadata billing stays zero too: parity pays exactly what the
        // unprotected stream pays per word, nothing per group.
        let cost = CostModel::default();
        let e = enc.access_energy(&cost, AccessKind::Write);
        assert_eq!(e.cycles, enc.access_energy_scalar(&cost, AccessKind::Write).cycles);
    }

    #[test]
    fn encode_into_reuses_buffers_and_matches() {
        let codec = WeightCodec::hybrid(4);
        let mut enc = Encoded::with_context(Policy::Hybrid, 4);
        let mut dec = Vec::new();
        for n in [1000usize, 500, 1000] {
            let ws = ramp(n);
            codec.encode_into(&ws, &mut enc);
            assert_eq!(enc.words, codec.encode_scalar(&ws).words, "n={n}");
            enc.decode_into(&mut dec);
            assert_eq!(dec, enc.decode_scalar(), "n={n}");
            assert_eq!(dec.len(), n);
        }
    }

    #[test]
    fn threaded_encode_decode_bit_identical() {
        // Force multi-shard work on a tensor smaller than the auto
        // threshold by passing explicit worker counts.
        let ws = ramp(10_240);
        for g in [1usize, 4, 16] {
            let codec = WeightCodec::hybrid(g);
            let mut single = Encoded::with_context(Policy::Hybrid, g);
            codec.encode_into_threaded(&ws, &mut single, 1);
            for workers in [2usize, 3, 8] {
                let mut multi = Encoded::with_context(Policy::Hybrid, g);
                codec.encode_into_threaded(&ws, &mut multi, workers);
                assert_eq!(single.words, multi.words, "g={g} workers={workers}");
                assert_eq!(single.schemes, multi.schemes, "g={g} workers={workers}");
                let mut d1 = Vec::new();
                let mut dn = Vec::new();
                single.decode_into_threaded(&mut d1, 1);
                multi.decode_into_threaded(&mut dn, workers);
                assert_eq!(d1, dn, "g={g} workers={workers}");
            }
        }
    }

    #[test]
    fn decode_word_agrees_with_decode() {
        let ws = ramp(100);
        let enc = WeightCodec::hybrid(8).encode(&ws);
        let all = enc.decode();
        for (i, &w) in enc.words.iter().enumerate() {
            assert_eq!(enc.decode_word(i, w), all[i]);
        }
    }
}
