//! End-to-end weight-tensor codec: f32 weights -> stored MLC word stream +
//! tri-level metadata, and back.
//!
//! Pipeline (paper Fig. 5):
//!
//! ```text
//!  f32 weight --quantize--> binary16 --protect sign--> protected word
//!      --[per-group scheme selection]--> stored image + scheme symbol
//! ```
//!
//! Decoding inverts the group's scheme and clears the backup bit. The codec
//! also produces the statistics the paper reports: pattern counts (Fig. 6)
//! and metadata storage overhead (Table 3).

use super::scheme::{self, Scheme};
use super::select::{select_scheme, Policy};
use crate::fp;
use crate::stt::{AccessKind, CostModel, Energy};

/// Encoder configuration.
#[derive(Clone, Copy, Debug)]
pub struct WeightCodec {
    /// Scheme-selection policy (Fig. 8 system).
    pub policy: Policy,
    /// Weights per metadata group (Table 3: 1, 2, 4, 8, 16).
    pub granularity: usize,
}

impl WeightCodec {
    pub fn new(policy: Policy, granularity: usize) -> Self {
        assert!(granularity >= 1, "granularity must be >= 1");
        WeightCodec {
            policy,
            granularity,
        }
    }

    /// The paper's headline configuration.
    pub fn hybrid(granularity: usize) -> Self {
        Self::new(Policy::Hybrid, granularity)
    }

    /// Encode a tensor of f32 weights (all |w| <= 2 after fp16 quantization;
    /// the trainer guarantees |w| <= 1).
    pub fn encode(&self, weights: &[f32]) -> Encoded {
        let mut words = Vec::with_capacity(weights.len());
        let mut schemes = Vec::with_capacity(weights.len().div_ceil(self.granularity));

        if self.policy == Policy::Unprotected {
            // Raw binary16, one metadata-free stream.
            words.extend(weights.iter().map(|&w| fp::f32_to_f16_bits(w)));
            return Encoded {
                words,
                schemes,
                granularity: self.granularity,
                policy: self.policy,
            };
        }

        let protected: Vec<u16> = weights
            .iter()
            .map(|&w| {
                let h = fp::f32_to_f16_bits(w);
                debug_assert!(
                    fp::backup_bit_free(h),
                    "weight {w} outside the |w| < 2 premise"
                );
                scheme::protect_sign(h)
            })
            .collect();

        for group in protected.chunks(self.granularity) {
            let (s, _) = select_scheme(self.policy, group);
            schemes.push(s);
            words.extend(group.iter().map(|&p| scheme::apply(s, p)));
        }

        Encoded {
            words,
            schemes,
            granularity: self.granularity,
            policy: self.policy,
        }
    }
}

/// An encoded weight stream: what physically sits in the MLC buffer.
#[derive(Clone, Debug)]
pub struct Encoded {
    /// Stored binary16 images, one per weight, in order.
    pub words: Vec<u16>,
    /// Per-group scheme symbols (empty for `Unprotected`), stored in the
    /// tri-level metadata plane.
    pub schemes: Vec<Scheme>,
    pub granularity: usize,
    pub policy: Policy,
}

impl Encoded {
    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Scheme governing word index `i`.
    #[inline]
    pub fn scheme_of(&self, i: usize) -> Scheme {
        if self.policy == Policy::Unprotected {
            Scheme::NoChange
        } else {
            self.schemes[i / self.granularity]
        }
    }

    /// Decode all words back to f32 (after any fault injection mutated
    /// `words` in place).
    pub fn decode(&self) -> Vec<f32> {
        self.words
            .iter()
            .enumerate()
            .map(|(i, &w)| self.decode_word(i, w))
            .collect()
    }

    /// Decode a single stored image.
    #[inline]
    pub fn decode_word(&self, i: usize, stored: u16) -> f32 {
        if self.policy == Policy::Unprotected {
            return fp::f16_bits_to_f32(stored);
        }
        fp::f16_bits_to_f32(scheme::invert(self.scheme_of(i), stored))
    }

    /// Pattern census over the stored stream (Fig. 6): `[n00,n01,n10,n11]`.
    pub fn pattern_counts(&self) -> [u64; 4] {
        let mut acc = [0u64; 4];
        for &w in &self.words {
            let c = fp::pattern_counts(w);
            for k in 0..4 {
                acc[k] += c[k] as u64;
            }
        }
        acc
    }

    /// Total vulnerable cells in the stored stream.
    pub fn soft_cells(&self) -> u64 {
        self.words.iter().map(|&w| fp::soft_cells(w) as u64).sum()
    }

    /// Metadata storage overhead (Table 3): 2 bits per group over the
    /// 16-bit payload words. Granularity 1 -> 0.125, 16 -> 0.0078125.
    pub fn metadata_overhead(&self) -> f64 {
        if self.policy == Policy::Unprotected || self.is_empty() {
            return 0.0;
        }
        let groups = self.len().div_ceil(self.granularity);
        (2 * groups) as f64 / (16 * self.len()) as f64
    }

    /// Content-dependent energy + latency of accessing the entire stream
    /// once (payload words + one tri-level metadata cell per group).
    /// Latency counts each word access serially (a buffer-wide sweep);
    /// [`crate::buffer`] models banked parallelism on top of this.
    pub fn access_energy(&self, cost: &CostModel, kind: AccessKind) -> Energy {
        let mut total = Energy::ZERO;
        for &w in &self.words {
            total.add(cost.word(w, kind));
        }
        if self.policy != Policy::Unprotected {
            let meta = cost.trilevel_cell(kind);
            let groups = self.schemes.len() as f64;
            total.add(Energy {
                nanojoules: meta.nanojoules * groups,
                cycles: meta.cycles * self.schemes.len() as u64,
            });
        }
        total
    }

    /// Scheme usage histogram `[nochange, rotate, round]` — the ablation
    /// statistic behind the Fig. 6/7 granularity trends.
    pub fn scheme_histogram(&self) -> [u64; 3] {
        let mut h = [0u64; 3];
        for s in &self.schemes {
            h[s.symbol() as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f32> {
        // Deterministic weights spanning [-1, 1], fp16-exact after quantize.
        (0..n)
            .map(|i| fp::quantize_f16((i as f32 / n as f32) * 2.0 - 1.0))
            .collect()
    }

    #[test]
    fn lossless_roundtrip_without_round_scheme() {
        let ws = ramp(1000);
        for g in [1usize, 2, 4, 8, 16] {
            let codec = WeightCodec::new(Policy::ProtectRotate, g);
            let enc = codec.encode(&ws);
            let back = enc.decode();
            assert_eq!(back, ws, "granularity {g}");
        }
    }

    #[test]
    fn hybrid_roundtrip_error_bounded_by_round() {
        // Round only perturbs the low 4 mantissa bits: for |w| <= 1 the
        // absolute error is at most 15 ULPs at the value's scale.
        let ws = ramp(4096);
        let codec = WeightCodec::hybrid(4);
        let enc = codec.encode(&ws);
        for (orig, dec) in ws.iter().zip(enc.decode()) {
            let ulp = (fp::f16_bits_to_f32(fp::f32_to_f16_bits(*orig) | 0xF)
                - fp::f16_bits_to_f32(fp::f32_to_f16_bits(*orig) & !0xF))
            .abs();
            assert!(
                (orig - dec).abs() <= ulp + f32::EPSILON,
                "orig={orig} dec={dec}"
            );
        }
    }

    #[test]
    fn unprotected_is_raw_f16() {
        let ws = ramp(64);
        let enc = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        assert!(enc.schemes.is_empty());
        assert_eq!(enc.metadata_overhead(), 0.0);
        for (w, &stored) in ws.iter().zip(&enc.words) {
            assert_eq!(stored, fp::f32_to_f16_bits(*w));
        }
        assert_eq!(enc.decode(), ws);
    }

    #[test]
    fn encoding_never_increases_soft_cells() {
        let ws = ramp(2048);
        let raw = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        // Sign-protected streams compare against the protected NoChange
        // image, which for negative weights converts a vulnerable 10 cell
        // into an immune 11 — so hybrid must beat even the raw count here.
        let hybrid = WeightCodec::hybrid(1).encode(&ws);
        assert!(hybrid.soft_cells() <= raw.soft_cells());
    }

    #[test]
    fn granularity_trend_soft_cells_monotone_nondecreasing() {
        // Coarser groups can only do same-or-worse (fewer choices).
        let ws = ramp(4096);
        let mut prev = 0u64;
        for g in [1usize, 2, 4, 8, 16] {
            let soft = WeightCodec::hybrid(g).encode(&ws).soft_cells();
            assert!(soft >= prev, "g={g}: {soft} < {prev}");
            prev = soft;
        }
    }

    #[test]
    fn table3_overhead_exact() {
        let ws = ramp(1024);
        let expect = [
            (1usize, 0.125),
            (2, 0.0625),
            (4, 0.03125),
            (8, 0.015625),
            (16, 0.0078125),
        ];
        for (g, ov) in expect {
            let enc = WeightCodec::hybrid(g).encode(&ws);
            assert!((enc.metadata_overhead() - ov).abs() < 1e-12, "g={g}");
        }
    }

    #[test]
    fn ragged_tail_group_handled() {
        let ws = ramp(13); // 13 % 4 != 0
        let codec = WeightCodec::hybrid(4);
        let enc = codec.encode(&ws);
        assert_eq!(enc.schemes.len(), 4); // ceil(13/4)
        assert_eq!(enc.decode().len(), 13);
        let back = WeightCodec::new(Policy::ProtectRotate, 4).encode(&ws).decode();
        assert_eq!(back, ws);
    }

    #[test]
    fn pattern_counts_sum_to_cells() {
        let ws = ramp(777);
        let enc = WeightCodec::hybrid(2).encode(&ws);
        let pc = enc.pattern_counts();
        assert_eq!(pc.iter().sum::<u64>(), 777 * 8);
        assert_eq!(pc[1] + pc[2], enc.soft_cells());
    }

    #[test]
    fn access_energy_cheaper_than_unprotected_uniformly_soft() {
        let cost = CostModel::default();
        // Mostly-negative ramp: unprotected stores many 10 sign cells.
        let ws: Vec<f32> = (0..512)
            .map(|i| fp::quantize_f16(-0.9 + 0.0001 * i as f32))
            .collect();
        let raw = WeightCodec::new(Policy::Unprotected, 1).encode(&ws);
        let hyb = WeightCodec::hybrid(4).encode(&ws);
        let raw_e = raw.access_energy(&cost, AccessKind::Write);
        let hyb_e = hyb.access_energy(&cost, AccessKind::Write);
        assert!(
            hyb_e.nanojoules < raw_e.nanojoules,
            "hybrid {hyb_e:?} vs raw {raw_e:?}"
        );
    }

    #[test]
    fn scheme_histogram_counts_groups() {
        let ws = ramp(256);
        let enc = WeightCodec::hybrid(4).encode(&ws);
        assert_eq!(enc.scheme_histogram().iter().sum::<u64>() as usize, enc.schemes.len());
    }

    #[test]
    fn decode_word_agrees_with_decode() {
        let ws = ramp(100);
        let enc = WeightCodec::hybrid(8).encode(&ws);
        let all = enc.decode();
        for (i, &w) in enc.words.iter().enumerate() {
            assert_eq!(enc.decode_word(i, w), all[i]);
        }
    }
}
