//! Word-packed (SWAR) reformation kernels: four protected binary16 words
//! per `u64`, one bitwise pipeline instead of four branchy per-word calls.
//!
//! Packing follows [`crate::fp::pack4`]: lane `i` occupies bits
//! `16i..16i+16`, so lane boundaries sit on multiples of 16 and every mask
//! here is lane-local. The only shifts that could leak across lanes
//! (`>> 1`, `>> 13`, `<< 1`) are immediately masked back inside the 14-bit
//! reformation field or the per-lane LSB, which is what makes each kernel
//! bit-exact against its scalar counterpart in [`super::scheme`] — pinned
//! exhaustively over all 65536 patterns by `rust/tests/swar_equivalence.rs`
//! and the tests below.
//!
//! The scalar functions remain the oracle; these kernels are the hot path
//! used by [`super::codec`] and [`super::select`].

use super::scheme::{self, Scheme};
use crate::fp::{self, pack4, soft_cells_packed, unpack4, LANES};
use crate::util::threads;

/// Sign bit (bit 15) of each lane.
const SIGN4: u64 = 0x8000_8000_8000_8000;
/// Backup bit (bit 14, the free exponent MSB) of each lane.
const BACKUP4: u64 = 0x4000_4000_4000_4000;
/// The 14-bit reformation field (below the protected sign pair) per lane.
const FIELD4: u64 = 0x3FFF_3FFF_3FFF_3FFF;
/// Bit 0 of each lane.
const ONES4: u64 = 0x0001_0001_0001_0001;
/// Low nibble of each lane (the Round target).
const NIB4: u64 = 0x000F_000F_000F_000F;
/// Parity-protected field (bits 6..=13, exponent + high mantissa) of each
/// lane — the packed image of [`super::parity::PARITY_FIELD`].
const PARITY_FIELD4: u64 = 0x3FC0_3FC0_3FC0_3FC0;
/// Even (intra-cell low) bit positions of each lane.
const EVEN4: u64 = 0x5555_5555_5555_5555;

/// [`scheme::protect_sign`] on four lanes: duplicate bit 15 into bit 14.
#[inline]
pub fn protect_sign4(x: u64) -> u64 {
    (x & !BACKUP4) | ((x & SIGN4) >> 1)
}

/// [`scheme::unprotect_sign`] on four lanes: clear the backup bit.
#[inline]
pub fn unprotect_sign4(x: u64) -> u64 {
    x & !BACKUP4
}

/// [`scheme::rotate_field_right`] on four lanes: rotate each lane's low 14
/// bits right by one, sign pair untouched. The `>> 1` pushes each lane's
/// bit 0 into the lane below's bit 15; `& FIELD4` discards it.
#[inline]
pub fn rotate_field_right4(x: u64) -> u64 {
    let field = x & FIELD4;
    (x & !FIELD4) | ((field >> 1) & FIELD4) | ((field & ONES4) << 13)
}

/// [`scheme::rotate_field_left`] on four lanes (inverse of
/// [`rotate_field_right4`]).
#[inline]
pub fn rotate_field_left4(x: u64) -> u64 {
    let field = x & FIELD4;
    (x & !FIELD4) | ((field << 1) & FIELD4) | ((field >> 13) & ONES4)
}

/// [`scheme::round_low_nibble`] on four lanes. Table 1 is a pure function
/// of the nibble's top two bits — output = `b3 b3 b2 b2` — so the lookup
/// table becomes two masked shifts per bit.
#[inline]
pub fn round_low_nibble4(x: u64) -> u64 {
    let b3 = (x >> 3) & ONES4;
    let b2 = (x >> 2) & ONES4;
    let nib = (b3 << 3) | (b3 << 2) | (b2 << 1) | b2;
    (x & !NIB4) | nib
}

/// [`scheme::apply`] on four protected lanes.
#[inline]
pub fn apply4(s: Scheme, x: u64) -> u64 {
    match s {
        Scheme::NoChange => x,
        Scheme::Rotate => rotate_field_right4(x),
        Scheme::Round => round_low_nibble4(x),
    }
}

/// [`scheme::invert`] on four stored lanes (backup bits cleared).
#[inline]
pub fn invert4(s: Scheme, x: u64) -> u64 {
    unprotect_sign4(match s {
        Scheme::Rotate => rotate_field_left4(x),
        Scheme::NoChange | Scheme::Round => x,
    })
}

/// [`super::parity::encode_word`] on four quantized lanes: XOR-fold each
/// lane's protected field (bits 6..=13) down to bit 6 and store the even
/// parity in bit 14. The folds shift downward by at most 4 + 2 + 1 = 7
/// positions, so bits leaking from the lane above (whose lowest masked bit
/// sits at lane-relative 16 + 6 = 22) land no lower than bit 15 — bit 6 of
/// every lane stays contamination-free and carries the exact 8-bit parity.
#[inline]
pub fn parity_protect4(x: u64) -> u64 {
    let mut f = x & PARITY_FIELD4;
    f ^= f >> 4;
    f ^= f >> 2;
    f ^= f >> 1;
    (x & !BACKUP4) | (((f >> 6) & ONES4) << 14)
}

// --------------------------------------------------------- slice kernels

/// Sign-protect a word slice in place, four lanes at a time.
pub fn protect_sign_slice(ws: &mut [u16]) {
    let mut chunks = ws.chunks_exact_mut(LANES);
    for c in &mut chunks {
        let x = protect_sign4(pack4([c[0], c[1], c[2], c[3]]));
        c.copy_from_slice(&unpack4(x));
    }
    for w in chunks.into_remainder() {
        *w = scheme::protect_sign(*w);
    }
}

/// Packed group cost tallies: the soft cells each candidate scheme would
/// produce, summed over a group of sign-protected words, in symbol order
/// `[NoChange, Rotate, Round]`. This is the quantity
/// [`super::select::select_from_tallies`] minimizes — one packed traversal
/// of the group instead of a per-word, per-candidate re-score.
pub fn group_cost_tallies(protected: &[u16]) -> [u32; 3] {
    let mut tallies = [0u32; 3];
    let mut chunks = protected.chunks_exact(LANES);
    for c in &mut chunks {
        let x = pack4([c[0], c[1], c[2], c[3]]);
        tallies[0] += soft_cells_packed(x);
        tallies[1] += soft_cells_packed(rotate_field_right4(x));
        tallies[2] += soft_cells_packed(round_low_nibble4(x));
    }
    for &p in chunks.remainder() {
        tallies[0] += fp::soft_cells(p);
        tallies[1] += fp::soft_cells(scheme::rotate_field_right(p));
        tallies[2] += fp::soft_cells(scheme::round_low_nibble(p));
    }
    tallies
}

// ------------------------------------------------------- energy census

/// Stream census for tally-based energy accounting (DESIGN.md §9):
/// everything [`crate::stt::CostModel::stream`] needs to bill a whole
/// stored stream without calling `CostModel::word` per word.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EnergyTally {
    /// Cell-pattern histogram `[n00, n01, n10, n11]` over every stored
    /// cell — the dot-product side of the Table 4 energy bill.
    pub patterns: [u64; 4],
    /// Words whose worst pattern is intermediate (at least one `01`/`10`
    /// cell): these bill the hard word latency, the rest bill soft.
    pub hard_words: u64,
    /// Total words censused.
    pub words: u64,
}

impl EnergyTally {
    /// Fold another shard's tally into this one. Every field is an
    /// integer sum, so the reduction is order-independent — threading
    /// cannot change the result by construction.
    pub fn merge(&mut self, other: &EnergyTally) {
        for (a, b) in self.patterns.iter_mut().zip(other.patterns) {
            *a += b;
        }
        self.hard_words += other.hard_words;
        self.words += other.words;
    }
}

/// Bit 0 of each lane set iff that lane holds at least one vulnerable
/// (`01`/`10`) cell — the packed worst-pattern test behind
/// [`EnergyTally::hard_words`]. XOR the intra-cell bit planes, then
/// OR-fold each lane's even bit positions down to its bit 0. Every fold
/// shifts downward and the largest fold distance (8 + 4 + 2 = 14) is
/// smaller than the 16-bit lane pitch, so no lane's bits can reach
/// another lane's bit 0.
#[inline]
pub fn hard_word_lanes4(x: u64) -> u64 {
    let m = (x ^ (x >> 1)) & EVEN4;
    let m = m | (m >> 8);
    let m = m | (m >> 4);
    let m = m | (m >> 2);
    m & ONES4
}

/// Census one word slice with the packed kernels: pattern histogram via
/// [`fp::pattern_counts_packed`], hard-word count via
/// [`hard_word_lanes4`], scalar remainder for the ragged tail.
pub fn energy_tally(words: &[u16]) -> EnergyTally {
    let mut t = EnergyTally {
        words: words.len() as u64,
        ..EnergyTally::default()
    };
    let mut chunks = words.chunks_exact(LANES);
    for c in &mut chunks {
        let x = pack4([c[0], c[1], c[2], c[3]]);
        for (a, p) in t.patterns.iter_mut().zip(fp::pattern_counts_packed(x)) {
            *a += p as u64;
        }
        t.hard_words += hard_word_lanes4(x).count_ones() as u64;
    }
    for &w in chunks.remainder() {
        for (a, p) in t.patterns.iter_mut().zip(fp::pattern_counts(w)) {
            *a += p as u64;
        }
        t.hard_words += (fp::soft_cells(w) > 0) as u64;
    }
    t
}

/// [`energy_tally`] sharded across at most `workers` threads via
/// [`threads::run_sharded`]. Shard boundaries cannot affect the result —
/// the census is a per-word integer sum — so every worker count returns
/// the identical tally (not merely an equivalent one).
pub fn energy_tally_threaded(words: &[u16], workers: usize) -> EnergyTally {
    let bounds = threads::chunk_bounds(words.len(), 1, workers);
    if bounds.len() <= 1 {
        return energy_tally(words);
    }
    let jobs: Vec<&[u16]> = bounds.iter().map(|&(s, e)| &words[s..e]).collect();
    let mut total = EnergyTally::default();
    for partial in threads::run_sharded(jobs, workers, energy_tally) {
        total.merge(&partial);
    }
    total
}

/// Apply `s` to a protected slice, writing the stored images into `dst`
/// (same length), four lanes at a time.
pub fn apply_into(s: Scheme, src: &[u16], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    let quads = src.len() / LANES * LANES;
    for (sc, dc) in src[..quads]
        .chunks_exact(LANES)
        .zip(dst[..quads].chunks_exact_mut(LANES))
    {
        let x = apply4(s, pack4([sc[0], sc[1], sc[2], sc[3]]));
        dc.copy_from_slice(&unpack4(x));
    }
    for (&sw, dw) in src[quads..].iter().zip(&mut dst[quads..]) {
        *dw = scheme::apply(s, sw);
    }
}

/// Invert `s` on a stored slice, writing canonical words (backup cleared)
/// into `dst` (same length), four lanes at a time.
pub fn invert_into(s: Scheme, src: &[u16], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    let quads = src.len() / LANES * LANES;
    for (sc, dc) in src[..quads]
        .chunks_exact(LANES)
        .zip(dst[..quads].chunks_exact_mut(LANES))
    {
        let x = invert4(s, pack4([sc[0], sc[1], sc[2], sc[3]]));
        dc.copy_from_slice(&unpack4(x));
    }
    for (&sw, dw) in src[quads..].iter().zip(&mut dst[quads..]) {
        *dw = scheme::invert(s, sw);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Spread `h` across lanes alongside three derived words so every lane
    /// position sees every pattern over the sweep.
    fn lanes_of(h: u16) -> [u16; 4] {
        [h, h.rotate_left(5), !h, h.wrapping_mul(0x9E37)]
    }

    #[test]
    fn packed_kernels_match_scalar_sampled() {
        // The exhaustive sweep lives in tests/swar_equivalence.rs; this is
        // the fast in-crate smoke version.
        for h in (0..=u16::MAX).step_by(251) {
            let ws = lanes_of(h);
            let x = pack4(ws);
            assert_eq!(
                unpack4(protect_sign4(x)),
                ws.map(scheme::protect_sign),
                "protect h={h:#06x}"
            );
            assert_eq!(unpack4(unprotect_sign4(x)), ws.map(scheme::unprotect_sign));
            assert_eq!(
                unpack4(rotate_field_right4(x)),
                ws.map(scheme::rotate_field_right)
            );
            assert_eq!(
                unpack4(rotate_field_left4(x)),
                ws.map(scheme::rotate_field_left)
            );
            assert_eq!(
                unpack4(round_low_nibble4(x)),
                ws.map(scheme::round_low_nibble)
            );
        }
    }

    #[test]
    fn slice_kernels_match_scalar_with_ragged_tail() {
        // Lengths exercising the chunks_exact remainder (0..=9 words).
        for len in 0..10usize {
            let src: Vec<u16> = (0..len as u16).map(|i| i.wrapping_mul(0x4D2F) & !0x4000).collect();
            let mut protected = src.clone();
            protect_sign_slice(&mut protected);
            let expect: Vec<u16> = src.iter().map(|&w| scheme::protect_sign(w)).collect();
            assert_eq!(protected, expect, "len={len}");

            for s in Scheme::ALL {
                let mut stored = vec![0u16; len];
                apply_into(s, &protected, &mut stored);
                let expect: Vec<u16> =
                    protected.iter().map(|&p| scheme::apply(s, p)).collect();
                assert_eq!(stored, expect, "{s:?} len={len}");

                let mut back = vec![0u16; len];
                invert_into(s, &stored, &mut back);
                let expect: Vec<u16> = stored.iter().map(|&w| scheme::invert(s, w)).collect();
                assert_eq!(back, expect, "{s:?} len={len}");
            }
        }
    }

    #[test]
    fn parity_protect_matches_scalar_sampled() {
        use crate::encoding::parity;
        for h in (0..=u16::MAX).step_by(251) {
            let ws = lanes_of(h);
            assert_eq!(
                unpack4(parity_protect4(pack4(ws))),
                ws.map(parity::encode_word),
                "parity h={h:#06x}"
            );
        }
    }

    #[test]
    fn hard_word_lanes_match_scalar_sampled() {
        for h in (0..=u16::MAX).step_by(97) {
            let ws = lanes_of(h);
            let got = hard_word_lanes4(pack4(ws));
            for (i, &w) in ws.iter().enumerate() {
                let want = (fp::soft_cells(w) > 0) as u64;
                assert_eq!((got >> (16 * i)) & 1, want, "h={h:#06x} lane {i}");
            }
            assert_eq!(got & !ONES4, 0, "stray bits outside lane LSBs");
        }
    }

    #[test]
    fn energy_tally_matches_per_word_census() {
        // Lengths exercising the ragged tail, plus boundary streams.
        let mut streams: Vec<Vec<u16>> = (0..10usize)
            .map(|len| (0..len as u16).map(|i| i.wrapping_mul(0x4D2F)).collect())
            .collect();
        streams.push(vec![0x0000; 257]);
        streams.push(vec![0x5555; 257]);
        streams.push((0..1001u32).map(|i| (i.wrapping_mul(40503) >> 3) as u16).collect());
        for words in &streams {
            let t = energy_tally(words);
            let mut want = EnergyTally::default();
            for &w in words {
                for (a, p) in want.patterns.iter_mut().zip(fp::pattern_counts(w)) {
                    *a += p as u64;
                }
                want.hard_words += (fp::soft_cells(w) > 0) as u64;
                want.words += 1;
            }
            assert_eq!(t, want, "len={}", words.len());
            for workers in [1usize, 2, 3, 8] {
                assert_eq!(
                    energy_tally_threaded(words, workers),
                    want,
                    "len={} workers={workers}",
                    words.len()
                );
            }
        }
    }

    #[test]
    fn tallies_match_per_word_rescoring() {
        for g in [1usize, 2, 3, 4, 5, 8, 13, 16] {
            let protected: Vec<u16> = (0..g as u16)
                .map(|i| scheme::protect_sign(i.wrapping_mul(0x2AB7) & !0x4000))
                .collect();
            let t = group_cost_tallies(&protected);
            for s in Scheme::ALL {
                let expect: u32 = protected
                    .iter()
                    .map(|&p| fp::soft_cells(scheme::apply(s, p)))
                    .sum();
                assert_eq!(t[s.symbol() as usize], expect, "{s:?} g={g}");
            }
        }
    }
}
