//! The pluggable protection-policy abstraction (DESIGN.md §13).
//!
//! [`ProtectionPolicy`] extracts the codec surface the rest of the system
//! actually depends on — encode, decode, metadata billing, and the
//! vulnerable-cell mask fault injection keys on — so the paper's hybrid
//! scheme and its ablations become *implementations* next to the
//! related-work competitors (in-place zero-space parity, Guan 2019) rather
//! than hard-coded branches. The trait is object-safe: `coordinator::store`,
//! `api::Deployment`, and the sweep plumbing hold a
//! `Box<dyn ProtectionPolicy>` built by [`protection_for`].
//!
//! Contract (pinned by `rust/tests/policy_matrix.rs`):
//!
//! - `encode_into`/`decode_into` are bit-identical for every worker count.
//! - At error rate 0, decode(encode(w)) is the fp16 quantization of `w`
//!   for lossless policies, and within the Round perturbation bound for
//!   the rounding ablations.
//! - Driving the paper's scheme through the trait is bit-identical —
//!   stored words, scheme symbols, flip sets, energy bills, decoded
//!   tensors — to calling [`WeightCodec`] directly.
//! - `vulnerable_mask` marks exactly the intermediate (`01`/`10`) cells of
//!   the *stored* image, which is what makes fault injection
//!   policy-agnostic: vulnerability is content-derived.

use super::codec::{Encoded, WeightCodec};
use super::select::Policy;
use super::{parity, scheme};

/// One protection scheme's full codec surface, object-safe for dynamic
/// dispatch through store/deployment/sweep plumbing.
pub trait ProtectionPolicy: Send + Sync {
    /// The policy enum value this implementation realizes.
    fn policy(&self) -> Policy;

    /// Human-readable label (sweep/CLI key). Defaults to the enum label.
    fn label(&self) -> &'static str {
        self.policy().label()
    }

    /// Encode a weight tensor into `out` (buffers reused), bit-identical
    /// for every `workers` value.
    fn encode_into(&self, weights: &[f32], out: &mut Encoded, workers: usize);

    /// Decode a (possibly fault-mutated) stream into `out`, bit-identical
    /// for every `workers` value.
    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>, workers: usize);

    /// Exact metadata bill in bits for an `n`-weight tensor (Table 3
    /// numerator): tri-level symbols, parity bits already in-word, etc.
    fn metadata_overhead_bits(&self, n: usize) -> u64;

    /// Mask of vulnerable (intermediate-state) bit positions in one stored
    /// word: bit `2i` set iff MLC cell `i` is in a `01`/`10` state. The
    /// default is the content-derived rule every current policy shares —
    /// vulnerability lives in the stored pattern, not the scheme.
    fn vulnerable_mask(&self, stored: u16) -> u16 {
        (stored ^ (stored >> 1)) & 0x5555
    }

    /// Does this stored word carry evidence of corruption the policy can
    /// see without the original? Sign-backup policies check the protected
    /// pair (bits 15/14 must agree — every reformation preserves both);
    /// zero-space parity checks the in-word parity code. The default —
    /// no in-word redundancy — can never tell, so it reports `false`.
    /// This is the scrub cursor's *telemetry* channel (DESIGN.md §15);
    /// authoritative detection uses the retained golden shard checksums.
    fn detect(&self, _stored: u16) -> bool {
        false
    }

    /// Best-effort in-word repair of a stored image: return the closest
    /// word the policy's redundancy can reconstruct. The default (no
    /// redundancy) is the identity. Implementations must be idempotent
    /// and leave clean words untouched, so calling this on an undamaged
    /// region is a no-op. Authoritative repair in the scrub subsystem
    /// rewrites from the tenant's retained clean image instead.
    fn repair(&self, stored: u16) -> u16 {
        stored
    }
}

/// The paper's scheme family driven through the trait: a thin wrapper over
/// [`WeightCodec`], so every byte it produces is the pre-trait codec's by
/// construction (and pinned to be by `policy_matrix.rs`).
#[derive(Clone, Copy, Debug)]
pub struct SchemeProtection {
    codec: WeightCodec,
}

impl SchemeProtection {
    /// Wrap a codec configuration (any enum policy, any granularity >= 1).
    pub fn new(policy: Policy, granularity: usize) -> Self {
        SchemeProtection {
            codec: WeightCodec::new(policy, granularity),
        }
    }

    /// The wrapped codec (tests compare against it directly).
    pub fn codec(&self) -> &WeightCodec {
        &self.codec
    }
}

impl ProtectionPolicy for SchemeProtection {
    fn policy(&self) -> Policy {
        self.codec.policy
    }

    fn encode_into(&self, weights: &[f32], out: &mut Encoded, workers: usize) {
        self.codec.encode_into_threaded(weights, out, workers);
    }

    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>, workers: usize) {
        enc.decode_into_threaded(out, workers);
    }

    fn metadata_overhead_bits(&self, n: usize) -> u64 {
        if !self.codec.policy.has_metadata() || n == 0 {
            return 0;
        }
        // One tri-level symbol (2 bits) per granularity group.
        2 * n.div_ceil(self.codec.granularity) as u64
    }

    fn detect(&self, stored: u16) -> bool {
        // Every reformation keeps the protected sign pair (bits 15/14) in
        // place, so a stored disagreement is always damage.
        self.codec.policy.protects_sign() && ((stored >> 15) ^ (stored >> 14)) & 1 != 0
    }

    fn repair(&self, stored: u16) -> u16 {
        // A single soft error in the sign cell leaves the pair disagreeing;
        // re-protecting restores the invariant the decoder relies on (the
        // decode path trusts bit 15, so this is exactly idempotent).
        if self.codec.policy.protects_sign() {
            scheme::protect_sign(stored)
        } else {
            stored
        }
    }
}

/// In-place zero-space parity (Guan 2019) through the trait: granularity
/// is irrelevant (the code is per-word) and the metadata bill is exactly
/// zero — the defining property the prop tests pin.
#[derive(Clone, Copy, Debug, Default)]
pub struct ParityProtection;

impl ParityProtection {
    fn codec() -> WeightCodec {
        WeightCodec::new(Policy::ZeroSpaceParity, 1)
    }
}

impl ProtectionPolicy for ParityProtection {
    fn policy(&self) -> Policy {
        Policy::ZeroSpaceParity
    }

    fn encode_into(&self, weights: &[f32], out: &mut Encoded, workers: usize) {
        Self::codec().encode_into_threaded(weights, out, workers);
    }

    fn decode_into(&self, enc: &Encoded, out: &mut Vec<f32>, workers: usize) {
        enc.decode_into_threaded(out, workers);
    }

    fn metadata_overhead_bits(&self, _n: usize) -> u64 {
        0
    }

    fn detect(&self, stored: u16) -> bool {
        parity::mismatch(stored)
    }

    // No `repair` override: the parity code locates no bit, so in-word
    // reconstruction is impossible — detection feeds telemetry and the
    // golden-image rewrite does the actual repair.
}

/// Build the implementation for an enum policy — the single construction
/// point store/deployment/sweep plumbing goes through.
pub fn protection_for(policy: Policy, granularity: usize) -> Box<dyn ProtectionPolicy> {
    match policy {
        Policy::ZeroSpaceParity => Box::new(ParityProtection),
        _ => Box::new(SchemeProtection::new(policy, granularity)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| fp::quantize_f16((i as f32 / n as f32) * 2.0 - 1.0))
            .collect()
    }

    #[test]
    fn trait_hybrid_is_bit_identical_to_codec() {
        let ws = ramp(2000);
        for g in [1usize, 4, 16] {
            let codec = WeightCodec::hybrid(g);
            let direct = codec.encode(&ws);
            let p = protection_for(Policy::Hybrid, g);
            let mut via = Encoded::with_context(Policy::Hybrid, g);
            for workers in [1usize, 3] {
                p.encode_into(&ws, &mut via, workers);
                assert_eq!(via.words, direct.words, "g={g} workers={workers}");
                assert_eq!(via.schemes, direct.schemes, "g={g}");
                let mut dec = Vec::new();
                p.decode_into(&via, &mut dec, workers);
                assert_eq!(dec, direct.decode(), "g={g} workers={workers}");
            }
        }
    }

    #[test]
    fn overhead_bits_match_table3_ratios() {
        for (g, n) in [(1usize, 1024usize), (4, 1024), (16, 1024), (4, 13)] {
            let p = protection_for(Policy::Hybrid, g);
            assert_eq!(p.metadata_overhead_bits(n), 2 * n.div_ceil(g) as u64);
            let enc = WeightCodec::hybrid(g).encode(&ramp(n));
            let ratio = p.metadata_overhead_bits(n) as f64 / (16 * n) as f64;
            assert!((ratio - enc.metadata_overhead()).abs() < 1e-12, "g={g} n={n}");
        }
        assert_eq!(protection_for(Policy::Unprotected, 4).metadata_overhead_bits(1024), 0);
        assert_eq!(protection_for(Policy::ZeroSpaceParity, 4).metadata_overhead_bits(1024), 0);
        assert_eq!(protection_for(Policy::Hybrid, 4).metadata_overhead_bits(0), 0);
    }

    #[test]
    fn vulnerable_mask_counts_soft_cells() {
        let p = protection_for(Policy::Hybrid, 4);
        for h in (0..=u16::MAX).step_by(97) {
            let mask = p.vulnerable_mask(h);
            assert_eq!(mask.count_ones(), fp::soft_cells(h), "h={h:#06x}");
            assert_eq!(mask & !0x5555, 0, "mask outside even positions");
        }
    }

    #[test]
    fn factory_labels_cover_extended_set() {
        for policy in Policy::EXTENDED {
            let p = protection_for(policy, 4);
            assert_eq!(p.policy(), policy);
            assert_eq!(p.label(), policy.label());
        }
    }

    #[test]
    fn detect_is_quiet_on_clean_stored_images() {
        let ws = ramp(512);
        for policy in Policy::EXTENDED {
            let p = protection_for(policy, 4);
            let mut enc = Encoded::with_context(policy, 4);
            p.encode_into(&ws, &mut enc, 1);
            for (i, &w) in enc.words.iter().enumerate() {
                assert!(!p.detect(w), "{policy:?} word {i} ({w:#06x})");
                assert_eq!(p.repair(w), w, "{policy:?} repair not identity on clean word {i}");
            }
        }
    }

    #[test]
    fn detect_sees_sign_pair_and_parity_damage() {
        let ws = ramp(64);
        // Sign-backup policies flag a flipped backup bit and repair it.
        for policy in [Policy::ProtectRound, Policy::ProtectRotate, Policy::Hybrid] {
            let p = protection_for(policy, 4);
            let mut enc = Encoded::with_context(policy, 4);
            p.encode_into(&ws, &mut enc, 1);
            let hit = enc.words[3] ^ (1 << 14);
            assert!(p.detect(hit), "{policy:?} missed a sign-pair flip");
            let fixed = p.repair(hit);
            assert_eq!(fixed, enc.words[3], "{policy:?} repair");
            assert!(!p.detect(fixed));
        }
        // Parity flags a payload-bit flip but cannot locate it.
        let p = protection_for(Policy::ZeroSpaceParity, 1);
        let mut enc = Encoded::with_context(Policy::ZeroSpaceParity, 1);
        p.encode_into(&ws, &mut enc, 1);
        let hit = enc.words[5] ^ (1 << 9);
        assert!(p.detect(hit), "parity missed an exponent-field flip");
        assert_eq!(p.repair(hit), hit, "parity repair must be identity");
        // Unprotected has no redundancy to consult.
        let u = protection_for(Policy::Unprotected, 1);
        assert!(!u.detect(0xFFFF ^ (1 << 14)));
    }
}
