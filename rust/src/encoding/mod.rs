//! The paper's contribution: sign-bit protection + data reformation.
//!
//! * [`scheme`] — the three per-word reformations (NoChange / Rotate /
//!   Round), sign-bit protection, and their exact inverses;
//! * [`select`] — per-group best-of-N scheme selection (Table 2 semantics)
//!   at configurable granularity (Table 3), and the system policies of
//!   Fig. 8 (Unprotected / +Round / +Rotate / Hybrid);
//! * [`swar`] — the word-packed hot path: every reformation and cell
//!   census as a four-lane `u64` SWAR kernel, bit-exact against the
//!   scalar oracle (DESIGN.md §7);
//! * [`codec`] — end-to-end weight-tensor encoder/decoder producing the
//!   stored word stream + tri-level metadata, plus pattern statistics
//!   (Fig. 6) and metadata overhead accounting (Table 3). Large tensors
//!   shard across `std::thread::scope` workers with bit-identical output;
//! * [`parity`] — the in-place zero-space competitor (Guan 2019): even
//!   parity over the exponent/high-mantissa field in the free bit 14,
//!   detect-and-saturate on decode;
//! * [`policy`] — the [`ProtectionPolicy`] trait (DESIGN.md §13) that
//!   makes the paper's scheme one implementation among the related-work
//!   competitors, object-safe for store/deployment/sweep plumbing.

pub mod codec;
pub mod parity;
pub mod policy;
pub mod scheme;
pub mod select;
pub mod staterestrict;
pub mod swar;

pub use codec::{Encoded, WeightCodec};
pub use policy::{protection_for, ParityProtection, ProtectionPolicy, SchemeProtection};
pub use scheme::Scheme;
pub use select::{select_from_tallies, select_scheme, Policy};
