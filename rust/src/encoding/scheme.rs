//! Per-word reformations (paper §5.1) and their inverses.
//!
//! All three schemes are applied **on top of sign-bit protection**: the
//! sign (bit 15) is duplicated into the unused bit 14, so the first MLC
//! cell always holds `00` (positive) or `11` (negative) — base states that
//! are immune and single-pulse. The reformation then reshapes the remaining
//! 14 bits (7 cells):
//!
//! * `NoChange` — store as-is;
//! * `Rotate`   — rotate the low 14 bits right by one. Verified bit-exact
//!   against the paper's Table 2 rows: the rotation must *exclude* the
//!   protected sign pair (a full 16-bit rotation does not reproduce the
//!   paper's examples);
//! * `Round`    — round the last 4 mantissa bits to the nearest
//!   "MLC-friendly" nibble per Table 1 (`0000|0011|1100|1111`) — lossy, but
//!   bounded by the paper's Fig. 4 SSE study to the 4 LSBs.

use crate::fp;

/// The three reformation schemes. The discriminant doubles as the tri-level
/// metadata symbol (3 states — exactly why the paper uses tri-level cells
/// rather than a fourth scheme and 2-bit MLC metadata).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Scheme {
    NoChange = 0,
    Rotate = 1,
    Round = 2,
}

impl Scheme {
    pub const ALL: [Scheme; 3] = [Scheme::NoChange, Scheme::Rotate, Scheme::Round];

    pub fn from_symbol(v: u8) -> Option<Scheme> {
        match v {
            0 => Some(Scheme::NoChange),
            1 => Some(Scheme::Rotate),
            2 => Some(Scheme::Round),
            _ => None,
        }
    }

    pub fn symbol(self) -> u8 {
        self as u8
    }

    /// Lossless schemes round-trip bit-exactly; `Round` does not.
    pub fn is_lossless(self) -> bool {
        !matches!(self, Scheme::Round)
    }
}

/// Table 1: map each low nibble to its nearest MLC-friendly value.
/// Index = original nibble, value = stored nibble.
pub const ROUND_TABLE: [u8; 16] = [
    0b0000, 0b0000, 0b0000, 0b0000, // 0000..0011 -> 0000
    0b0011, 0b0011, 0b0011, 0b0011, // 0100..0111 -> 0011
    0b1100, 0b1100, 0b1100, 0b1100, // 1000..1011 -> 1100
    0b1111, 0b1111, 0b1111, 0b1111, // 1100..1111 -> 1111
];

/// Duplicate the sign bit (15) into the backup bit (14).
///
/// Precondition for losslessness: `fp::backup_bit_free(h)` — true for every
/// |w| < 2, i.e. all normalized weights. For other words bit 14 is simply
/// overwritten (the high-level codec asserts the precondition).
#[inline]
pub fn protect_sign(h: u16) -> u16 {
    (h & !fp::BACKUP_MASK) | ((h & fp::SIGN_MASK) >> 1)
}

/// Drop the backup copy, restoring the canonical |w| < 2 representation.
#[inline]
pub fn unprotect_sign(h: u16) -> u16 {
    h & !fp::BACKUP_MASK
}

const FIELD_MASK: u16 = 0x3FFF; // low 14 bits, below the protected pair

/// Rotate the low 14 bits right by one, keeping the sign pair in place.
#[inline]
pub fn rotate_field_right(h: u16) -> u16 {
    let field = h & FIELD_MASK;
    let rotated = (field >> 1) | ((field & 1) << 13);
    (h & !FIELD_MASK) | rotated
}

/// Inverse of [`rotate_field_right`].
#[inline]
pub fn rotate_field_left(h: u16) -> u16 {
    let field = h & FIELD_MASK;
    let rotated = ((field << 1) & FIELD_MASK) | (field >> 13);
    (h & !FIELD_MASK) | rotated
}

/// Apply Table 1 to the low nibble.
#[inline]
pub fn round_low_nibble(h: u16) -> u16 {
    (h & !0xF) | ROUND_TABLE[(h & 0xF) as usize] as u16
}

/// Apply `scheme` to a sign-protected word, producing the stored image.
#[inline]
pub fn apply(scheme: Scheme, protected: u16) -> u16 {
    match scheme {
        Scheme::NoChange => protected,
        Scheme::Rotate => rotate_field_right(protected),
        Scheme::Round => round_low_nibble(protected),
    }
}

/// Invert `scheme` on a stored image, recovering the canonical word
/// (backup bit cleared). For `Round` this recovers the *rounded* value —
/// the scheme is lossy by design.
#[inline]
pub fn invert(scheme: Scheme, stored: u16) -> u16 {
    let h = match scheme {
        Scheme::NoChange => stored,
        Scheme::Rotate => rotate_field_left(stored),
        Scheme::Round => stored,
    };
    unprotect_sign(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::{f32_to_f16_bits, pattern_counts};

    // The paper's Table 2, reproduced bit-exactly. Each case lists the
    // binary image after each scheme (already sign-protected; all three
    // example weights are positive so protection is a no-op on them).
    const W1: u16 = 0b00_01_11_00_01_01_00_11; // 0.004222
    const W2: u16 = 0b00_10_01_01_01_00_01_11; // 0.020614
    const W3: u16 = 0b00_01_00_00_00_01_01_01; // 0.0004982

    #[test]
    fn table2_row1_nochange_best() {
        assert_eq!(f32_to_f16_bits(0.004222), W1);
        assert_eq!(pattern_counts(apply(Scheme::NoChange, W1)), [3, 3, 0, 2]);
        assert_eq!(
            apply(Scheme::Rotate, W1),
            0b00_10_11_10_00_10_10_01,
            "rotate image"
        );
        assert_eq!(pattern_counts(apply(Scheme::Rotate, W1)), [2, 1, 4, 1]);
        assert_eq!(
            apply(Scheme::Round, W1),
            0b00_01_11_00_01_01_00_00,
            "round image"
        );
        assert_eq!(pattern_counts(apply(Scheme::Round, W1)), [4, 3, 0, 1]);
    }

    #[test]
    fn table2_row2_rotate_best() {
        assert_eq!(f32_to_f16_bits(0.020614), W2);
        assert_eq!(pattern_counts(W2), [2, 4, 1, 1]);
        assert_eq!(apply(Scheme::Rotate, W2), 0b00_11_00_10_10_10_00_11);
        assert_eq!(pattern_counts(apply(Scheme::Rotate, W2)), [3, 0, 3, 2]);
        assert_eq!(apply(Scheme::Round, W2), 0b00_10_01_01_01_00_00_11);
        assert_eq!(pattern_counts(apply(Scheme::Round, W2)), [3, 3, 1, 1]);
    }

    #[test]
    fn table2_row3_round_best() {
        assert_eq!(f32_to_f16_bits(0.0004982), W3);
        assert_eq!(pattern_counts(W3), [4, 4, 0, 0]);
        assert_eq!(apply(Scheme::Rotate, W3), 0b00_10_10_00_00_00_10_10);
        assert_eq!(pattern_counts(apply(Scheme::Rotate, W3)), [4, 0, 4, 0]);
        assert_eq!(apply(Scheme::Round, W3), 0b00_01_00_00_00_01_00_11);
        assert_eq!(pattern_counts(apply(Scheme::Round, W3)), [5, 2, 0, 1]);
    }

    #[test]
    fn round_table_is_table1_verbatim() {
        assert_eq!(&ROUND_TABLE[0..4], &[0, 0, 0, 0]);
        assert_eq!(&ROUND_TABLE[4..8], &[3, 3, 3, 3]);
        assert_eq!(&ROUND_TABLE[8..12], &[12, 12, 12, 12]);
        assert_eq!(&ROUND_TABLE[12..16], &[15, 15, 15, 15]);
        // Every output nibble is MLC-friendly (cells 00 or 11 only).
        for out in ROUND_TABLE {
            assert!(matches!(out, 0b0000 | 0b0011 | 0b1100 | 0b1111));
        }
    }

    #[test]
    fn protect_sets_backup_to_sign() {
        let pos = f32_to_f16_bits(0.5);
        let neg = f32_to_f16_bits(-0.5);
        assert_eq!(protect_sign(pos) & 0xC000, 0x0000); // cell0 = 00
        assert_eq!(protect_sign(neg) & 0xC000, 0xC000); // cell0 = 11
        // Idempotent, and unprotect restores the canonical word.
        assert_eq!(protect_sign(protect_sign(neg)), protect_sign(neg));
        assert_eq!(unprotect_sign(protect_sign(neg)), neg);
        assert_eq!(unprotect_sign(protect_sign(pos)), pos);
    }

    #[test]
    fn protected_sign_cell_is_base_state() {
        use crate::stt::CellPattern;
        for w in [-0.9f32, -0.1, 0.0, 0.1, 0.9] {
            let p = protect_sign(f32_to_f16_bits(w));
            let cell0 = CellPattern::from_bits((p >> 14) as u8);
            assert!(cell0.is_base(), "w={w}");
        }
    }

    #[test]
    fn rotate_roundtrips_all_words() {
        for h in 0..=u16::MAX {
            assert_eq!(rotate_field_left(rotate_field_right(h)), h);
            // Sign pair untouched.
            assert_eq!(rotate_field_right(h) & 0xC000, h & 0xC000);
        }
    }

    #[test]
    fn lossless_schemes_invert_exactly() {
        for h in (0..=u16::MAX).step_by(11) {
            let p = protect_sign(h & !fp::BACKUP_MASK);
            for s in [Scheme::NoChange, Scheme::Rotate] {
                assert_eq!(invert(s, apply(s, p)), unprotect_sign(p), "{s:?} h={h:#06x}");
            }
        }
    }

    #[test]
    fn round_error_bounded_to_nibble() {
        // Rounding only touches the low 4 bits.
        for h in (0..=u16::MAX).step_by(13) {
            let r = round_low_nibble(h);
            assert_eq!(r & !0xF, h & !0xF);
        }
    }

    #[test]
    fn scheme_symbols_fit_trilevel() {
        for s in Scheme::ALL {
            assert!(s.symbol() < 3);
            assert_eq!(Scheme::from_symbol(s.symbol()), Some(s));
        }
        assert_eq!(Scheme::from_symbol(3), None);
    }
}
