//! Scheme selection (paper §5.1 "Putting them all together") and the
//! system policies evaluated in Fig. 8.
//!
//! For a group of `g` weights (granularity, Table 3) the encoder counts the
//! vulnerable `01`/`10` cells that each candidate scheme would produce
//! *summed over the whole group*, and picks the minimum. Ties prefer the
//! lossless, cheaper option: `NoChange` > `Rotate` > `Round` — exactly
//! reproducing the paper's Table 2 "Best" column (row 1 is a NoChange/Round
//! tie resolved to NoChange).

use super::scheme::{self, Scheme};
use crate::fp;

/// Which schemes a system may choose from — the four bars of Fig. 8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Raw binary16 into MLC cells. No protection, no reformation.
    Unprotected,
    /// Sign protection + best of {NoChange, Round}.
    ProtectRound,
    /// Sign protection + best of {NoChange, Rotate}.
    ProtectRotate,
    /// Sign protection + best of all three (the paper's full scheme).
    Hybrid,
    /// In-place zero-space parity (Guan et al. 2019): instead of backing up
    /// the sign, bit 14 stores even parity over the exponent/high-mantissa
    /// field (bits 6..=13). Single flips inside the protected field are
    /// *detected* and the decode saturates into `[-1, 1]`; no reformation,
    /// no metadata symbols, zero storage overhead.
    ZeroSpaceParity,
}

impl Policy {
    /// Candidate schemes in tie-break order.
    pub fn candidates(self) -> &'static [Scheme] {
        match self {
            Policy::Unprotected => &[Scheme::NoChange],
            Policy::ProtectRound => &[Scheme::NoChange, Scheme::Round],
            Policy::ProtectRotate => &[Scheme::NoChange, Scheme::Rotate],
            Policy::Hybrid => &[Scheme::NoChange, Scheme::Rotate, Scheme::Round],
            // Parity stores words verbatim below bit 14 — reformation would
            // perturb the protected field the parity bit covers.
            Policy::ZeroSpaceParity => &[Scheme::NoChange],
        }
    }

    /// Whether bit 14 carries a sign backup (the paper's protection). The
    /// parity policy spends the same bit on detection instead, so a sign
    /// flip is as exposed as in the unprotected system.
    pub fn protects_sign(self) -> bool {
        !matches!(self, Policy::Unprotected | Policy::ZeroSpaceParity)
    }

    /// Whether encoded streams carry per-group scheme symbols (the
    /// tri-level metadata cells of §5.2). Unprotected stores raw words and
    /// parity is in-place zero-space: neither bills metadata.
    pub fn has_metadata(self) -> bool {
        !matches!(self, Policy::Unprotected | Policy::ZeroSpaceParity)
    }

    pub fn label(self) -> &'static str {
        match self {
            Policy::Unprotected => "unprotected",
            Policy::ProtectRound => "baseline+rounding",
            Policy::ProtectRotate => "baseline+rotate",
            Policy::Hybrid => "hybrid",
            Policy::ZeroSpaceParity => "zero-parity",
        }
    }

    pub fn from_label(s: &str) -> Option<Policy> {
        match s {
            "unprotected" => Some(Policy::Unprotected),
            "baseline+rounding" | "round" => Some(Policy::ProtectRound),
            "baseline+rotate" | "rotate" => Some(Policy::ProtectRotate),
            "hybrid" => Some(Policy::Hybrid),
            "zero-parity" | "parity" => Some(Policy::ZeroSpaceParity),
            _ => None,
        }
    }

    /// The four bars of Fig. 8 (the paper's design space). Legacy sweep
    /// output stays keyed to this set; [`Policy::EXTENDED`] adds the
    /// related-work competitors.
    pub const ALL: [Policy; 4] = [
        Policy::Unprotected,
        Policy::ProtectRound,
        Policy::ProtectRotate,
        Policy::Hybrid,
    ];

    /// Every policy including the related-work competitors — the axis the
    /// `mlcstt sweep --policies all` front iterates.
    pub const EXTENDED: [Policy; 5] = [
        Policy::Unprotected,
        Policy::ProtectRound,
        Policy::ProtectRotate,
        Policy::Hybrid,
        Policy::ZeroSpaceParity,
    ];
}

/// Total vulnerable cells a scheme would produce over a group of
/// sign-protected words.
#[inline]
pub fn group_soft_cells(s: Scheme, protected: &[u16]) -> u32 {
    protected
        .iter()
        .map(|&p| fp::soft_cells(scheme::apply(s, p)))
        .sum()
}

/// Soft-cell counts a word would contribute under each scheme, in symbol
/// order `[NoChange, Rotate, Round]` — the single-pass kernel behind
/// [`select_scheme`] (one traversal of the group instead of one per
/// candidate; see EXPERIMENTS.md §Perf).
#[inline]
pub fn candidate_soft_cells(p: u16) -> [u32; 3] {
    [
        fp::soft_cells(p),
        fp::soft_cells(scheme::rotate_field_right(p)),
        fp::soft_cells(scheme::round_low_nibble(p)),
    ]
}

/// Pick the best scheme from precomputed group cost tallies (soft-cell
/// sums in symbol order `[NoChange, Rotate, Round]` — see
/// [`super::swar::group_cost_tallies`]). Returns `(scheme, soft_cells_after)`.
#[inline]
pub fn select_from_tallies(policy: Policy, tallies: [u32; 3]) -> (Scheme, u32) {
    // Strict '<' keeps the earliest candidate on ties: the candidate order
    // encodes the NoChange > Rotate > Round preference.
    let mut best = (Scheme::NoChange, u32::MAX);
    for &s in policy.candidates() {
        let cost = tallies[s.symbol() as usize];
        if cost < best.1 {
            best = (s, cost);
        }
    }
    best
}

/// Pick the best scheme for a group of sign-protected words under `policy`.
/// Returns `(scheme, soft_cells_after)`. Tallies come from the packed SWAR
/// kernel; the per-word [`candidate_soft_cells`] path is the oracle it is
/// tested against.
pub fn select_scheme(policy: Policy, protected: &[u16]) -> (Scheme, u32) {
    debug_assert!(!protected.is_empty());
    select_from_tallies(policy, super::swar::group_cost_tallies(protected))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::scheme::protect_sign;
    use crate::fp::f32_to_f16_bits;

    fn protected(w: f32) -> u16 {
        protect_sign(f32_to_f16_bits(w))
    }

    #[test]
    fn table2_best_column() {
        // Row 1: NoChange (soft=3, ties with Round=3 -> prefer NoChange).
        let (s, c) = select_scheme(Policy::Hybrid, &[protected(0.004222)]);
        assert_eq!((s, c), (Scheme::NoChange, 3));
        // Row 2: Rotate (soft 5 -> 3).
        let (s, c) = select_scheme(Policy::Hybrid, &[protected(0.020614)]);
        assert_eq!((s, c), (Scheme::Rotate, 3));
        // Row 3: Round (soft 8 -> wait, counts 4,4 -> soft 8? no: 0.0004982
        // has [4,4,0,0] -> soft 4; round gives [5,2,0,1] -> soft 2).
        let (s, c) = select_scheme(Policy::Hybrid, &[protected(0.0004982)]);
        assert_eq!((s, c), (Scheme::Round, 2));
    }

    #[test]
    fn policy_candidate_sets() {
        assert_eq!(Policy::Unprotected.candidates(), &[Scheme::NoChange]);
        assert_eq!(
            Policy::Hybrid.candidates(),
            &[Scheme::NoChange, Scheme::Rotate, Scheme::Round]
        );
        assert!(!Policy::Unprotected.protects_sign());
        assert!(Policy::Hybrid.protects_sign());
    }

    #[test]
    fn restricted_policies_never_pick_excluded_schemes() {
        let ws: Vec<u16> = (0..64).map(|i| protected(0.001 * i as f32 - 0.03)).collect();
        for chunk in ws.chunks(4) {
            let (s, _) = select_scheme(Policy::ProtectRound, chunk);
            assert_ne!(s, Scheme::Rotate);
            let (s, _) = select_scheme(Policy::ProtectRotate, chunk);
            assert_ne!(s, Scheme::Round);
        }
    }

    #[test]
    fn selection_never_worse_than_nochange() {
        let ws: Vec<u16> = (0..257)
            .map(|i| protected((i as f32 / 257.0) * 1.9 - 0.95))
            .collect();
        for g in [1usize, 2, 4, 8, 16] {
            for chunk in ws.chunks(g) {
                let base = group_soft_cells(Scheme::NoChange, chunk);
                let (_, best) = select_scheme(Policy::Hybrid, chunk);
                assert!(best <= base);
            }
        }
    }

    #[test]
    fn grouping_monotone_no_better_than_singletons() {
        // Selecting per-word can only do at least as well as per-group.
        let ws: Vec<u16> = (0..32).map(|i| protected(0.02 * i as f32 - 0.3)).collect();
        let single: u32 = ws
            .iter()
            .map(|&w| select_scheme(Policy::Hybrid, &[w]).1)
            .sum();
        let (_, grouped) = select_scheme(Policy::Hybrid, &ws);
        assert!(single <= grouped);
    }

    #[test]
    fn tallies_path_agrees_with_per_word_oracle() {
        let ws: Vec<u16> = (0..97).map(|i| protected(0.017 * i as f32 - 0.8)).collect();
        for g in [1usize, 3, 4, 7, 16] {
            for chunk in ws.chunks(g) {
                let mut sums = [0u32; 3];
                for &p in chunk {
                    let c = candidate_soft_cells(p);
                    for (s, v) in sums.iter_mut().zip(c) {
                        *s += v;
                    }
                }
                for policy in [Policy::ProtectRound, Policy::ProtectRotate, Policy::Hybrid] {
                    assert_eq!(
                        select_scheme(policy, chunk),
                        select_from_tallies(policy, sums),
                        "{policy:?} g={g}"
                    );
                }
            }
        }
    }

    #[test]
    fn labels_roundtrip() {
        for p in Policy::EXTENDED {
            assert_eq!(Policy::from_label(p.label()), Some(p));
        }
        assert_eq!(Policy::from_label("parity"), Some(Policy::ZeroSpaceParity));
        assert_eq!(Policy::from_label("nope"), None);
    }

    #[test]
    fn extended_is_all_plus_parity() {
        assert_eq!(&Policy::EXTENDED[..4], &Policy::ALL[..]);
        assert_eq!(Policy::EXTENDED[4], Policy::ZeroSpaceParity);
        assert_eq!(Policy::ZeroSpaceParity.candidates(), &[Scheme::NoChange]);
        assert!(!Policy::ZeroSpaceParity.protects_sign());
        assert!(!Policy::ZeroSpaceParity.has_metadata());
        assert!(Policy::Hybrid.has_metadata());
        assert!(!Policy::Unprotected.has_metadata());
    }
}
