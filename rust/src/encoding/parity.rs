//! In-place zero-space parity protection (Guan et al. 2019, "In-Place
//! Zero-Space Memory Protection for CNN").
//!
//! The same observation that frees bit 14 for the paper's sign backup
//! (|w| < 2 for CNN weights, so the exponent MSB is always 0) frees it for
//! an *error-detecting* code instead: bit 14 stores even parity over the
//! bits whose flips hurt accuracy most — the exponent and high-mantissa
//! field, bits 6..=13 ([`PARITY_FIELD`]). On read, a parity mismatch means
//! at least one flip landed inside the protected span; the decoder cannot
//! correct it, so it *saturates*: the decoded value is clamped into
//! `[-1, 1]`, which bounds the error a high-exponent flip can inject
//! (an exponent-MSB flip alone would otherwise scale the weight by 2^8).
//!
//! Properties pinned by `rust/tests/prop_encoding.rs` and
//! `rust/tests/policy_matrix.rs`:
//!
//! - **Zero space:** the code lives entirely in the otherwise-unused bit;
//!   `metadata_overhead_bits` is exactly 0.
//! - **Single-flip detection:** any single bitflip in the detection domain
//!   ([`DETECT_MASK`]: the field plus the parity bit itself) flips the
//!   parity check and is detected.
//! - **Non-expansive repair:** clamping into `[-1, 1]` never increases
//!   `|decoded - original|` versus the unprotected decode, because the
//!   original weight already lies in that interval (projection onto a
//!   convex set containing the target is non-expansive).
//!
//! The trade against the paper's scheme: parity detects (and bounds)
//! exponent-field flips the sign backup ignores, but leaves the sign bit
//! exposed and performs no reformation, so its vulnerable-cell count is
//! that of the raw stream.

use crate::fp;

/// The protected span: exponent bits (10..=13, sans the free bit 14) plus
/// the four highest mantissa bits (6..=9) — the flips with the largest
/// value impact.
pub const PARITY_FIELD: u16 = 0x3FC0;

/// Bits whose single flips the check detects: the protected field plus the
/// parity bit itself (a flipped check bit reports a mismatch over an
/// intact field; saturation then decodes with zero error, since the field
/// is untouched and bit 14 is cleared before conversion).
pub const DETECT_MASK: u16 = PARITY_FIELD | fp::BACKUP_MASK;

/// Even parity of the protected field, positioned at bit 14.
#[inline]
pub fn parity_bit(h: u16) -> u16 {
    (((h & PARITY_FIELD).count_ones() as u16) & 1) << 14
}

/// Encode one quantized f16 word: clear bit 14 (free in the |w| < 2
/// domain) and store the field parity there. Total on all of `u16` — any
/// stray bit 14 in the input is ignored, mirroring the packed kernel
/// [`super::swar::parity_protect4`].
#[inline]
pub fn encode_word(h: u16) -> u16 {
    (h & !fp::BACKUP_MASK) | parity_bit(h)
}

/// Does the stored word fail its parity check?
#[inline]
pub fn mismatch(stored: u16) -> bool {
    (((stored >> 14) ^ (stored & PARITY_FIELD).count_ones() as u16) & 1) != 0
}

/// Decode one stored word: strip the parity bit, convert, and on a parity
/// mismatch clamp the value into `[-1, 1]`. The conversion is always
/// finite — with bit 14 cleared the f16 exponent cannot be all-ones — so
/// the clamp is well-defined even under multi-bit corruption.
#[inline]
pub fn decode_word(stored: u16) -> f32 {
    let raw = fp::f16_bits_to_f32(stored & !fp::BACKUP_MASK);
    if mismatch(stored) {
        raw.clamp(-1.0, 1.0)
    } else {
        raw
    }
}

/// Quantize a weight slice and parity-protect it into `out` (same length),
/// four lanes at a time via [`super::swar::parity_protect4`].
pub fn encode_slice(weights: &[f32], out: &mut [u16]) {
    debug_assert_eq!(weights.len(), out.len());
    fp::quantize_into(weights, out);
    let quads = out.len() / fp::LANES * fp::LANES;
    for c in out[..quads].chunks_exact_mut(fp::LANES) {
        let x = super::swar::parity_protect4(fp::pack4([c[0], c[1], c[2], c[3]]));
        c.copy_from_slice(&fp::unpack4(x));
    }
    for w in &mut out[quads..] {
        *w = encode_word(*w);
    }
}

/// Decode a stored slice into `dst` (same length): strip parity bits, bulk
/// f16→f32 convert, then clamp the mismatching positions. The scratch
/// buffer is a fixed-size stack block so the bulk converter
/// ([`fp::decode_f16_slice`]) still runs without a heap allocation per call.
pub fn decode_slice(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    const BLOCK: usize = 256;
    let mut scratch = [0u16; BLOCK];
    for (sb, db) in src.chunks(BLOCK).zip(dst.chunks_mut(BLOCK)) {
        let s = &mut scratch[..sb.len()];
        for (c, &w) in s.iter_mut().zip(sb) {
            *c = w & !fp::BACKUP_MASK;
        }
        fp::decode_f16_slice(s, db);
        for (d, &w) in db.iter_mut().zip(sb) {
            if mismatch(w) {
                *d = d.clamp(-1.0, 1.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_quantization_exact() {
        for i in 0..512 {
            let w = (i as f32 / 256.0) - 1.0;
            let enc = encode_word(fp::f32_to_f16_bits(w));
            assert!(!mismatch(enc), "clean word mismatches: w={w}");
            assert_eq!(decode_word(enc), fp::quantize_f16(w), "w={w}");
        }
    }

    #[test]
    fn single_flips_in_detection_domain_are_detected() {
        let enc = encode_word(fp::f32_to_f16_bits(0.7321));
        for pos in 0..16u32 {
            let hit = fp::flip_bit(enc, pos);
            let in_domain = (1u16 << pos) & DETECT_MASK != 0;
            assert_eq!(mismatch(hit), in_domain, "pos={pos}");
        }
    }

    #[test]
    fn parity_bit_flip_decodes_exactly() {
        // Flipping the check bit itself saturates over an intact field:
        // the decode strips bit 14 first, so the value is untouched and
        // already inside [-1, 1].
        let w = -0.4182;
        let enc = encode_word(fp::f32_to_f16_bits(w));
        let hit = fp::flip_bit(enc, 14);
        assert!(mismatch(hit));
        assert_eq!(decode_word(hit), fp::quantize_f16(w));
    }

    #[test]
    fn slice_paths_match_word_paths() {
        for len in [0usize, 1, 3, 4, 7, 255, 256, 257, 1000] {
            let ws: Vec<f32> = (0..len)
                .map(|i| (i as f32 * 0.7391).sin() * 0.9)
                .collect();
            let mut enc = vec![0u16; len];
            encode_slice(&ws, &mut enc);
            let expect: Vec<u16> = ws
                .iter()
                .map(|&w| encode_word(fp::f32_to_f16_bits(w)))
                .collect();
            assert_eq!(enc, expect, "encode len={len}");

            // Corrupt a few words so the decode exercises the clamp path.
            for (i, w) in enc.iter_mut().enumerate() {
                if i % 5 == 2 {
                    *w = fp::flip_bit(*w, (i % 14) as u32);
                }
            }
            let mut dec = vec![0.0f32; len];
            decode_slice(&enc, &mut dec);
            let expect: Vec<f32> = enc.iter().map(|&w| decode_word(w)).collect();
            assert_eq!(dec, expect, "decode len={len}");
        }
    }
}
