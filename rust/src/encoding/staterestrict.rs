//! State-restricted MLC baseline (Wen et al., DAC'14 [12]) — the related
//! work the paper builds its reliability numbers on, implemented as a
//! comparison codec.
//!
//! Idea: forbid the *most fragile* of the four MLC states (`01`, whose
//! sense margin is smallest) and store data in the remaining three states —
//! i.e., run every data cell as a tri-level cell. Capacity drops from
//! 2 bits/cell to log2(3) ≈ 1.585 bits/cell, but no `01` cell ever exists,
//! and the remaining intermediate state (`10`) is the only vulnerable one.
//!
//! A binary16 word (16 bits) needs ceil(16 / log2(3)) = 11 tri-level cells
//! (3^11 = 177,147 ≥ 65,536) instead of 8 MLC cells — a 37.5 % cell-count
//! overhead, against the paper's scheme which keeps all cells in 2-bit
//! mode and pays ≤ 12.5 % metadata instead. `bench_energy`'s ablation and
//! the tests below quantify the trade.

use crate::fp;
use crate::stt::{AccessKind, CostModel, Energy};

/// Tri-level cells per stored binary16 word.
pub const CELLS_PER_WORD_SR: usize = 11;

/// The three allowed states, as 2-bit images: `00`, `10`, `11`
/// (the fragile `01` is never programmed).
pub const ALLOWED: [u8; 3] = [0b00, 0b10, 0b11];

/// Encode one binary16 word into 11 base-3 symbols (LSD first).
pub fn encode_word(h: u16) -> [u8; CELLS_PER_WORD_SR] {
    let mut v = h as u32;
    let mut out = [0u8; CELLS_PER_WORD_SR];
    for s in out.iter_mut() {
        *s = (v % 3) as u8;
        v /= 3;
    }
    debug_assert_eq!(v, 0);
    out
}

/// Decode 11 base-3 symbols back to the word. Returns `None` if the
/// symbol stream encodes a value outside u16 (corruption artifact).
pub fn decode_word(symbols: &[u8; CELLS_PER_WORD_SR]) -> Option<u16> {
    let mut v: u32 = 0;
    for &s in symbols.iter().rev() {
        debug_assert!(s < 3);
        v = v * 3 + s as u32;
    }
    u16::try_from(v).ok()
}

/// Physical cell image of a symbol (which 2-bit state is programmed).
#[inline]
pub fn symbol_state(s: u8) -> u8 {
    ALLOWED[s as usize]
}

/// Number of vulnerable cells in a stored word: only the `10` state
/// (symbol 1) remains intermediate.
pub fn vulnerable_cells(h: u16) -> u32 {
    encode_word(h).iter().filter(|&&s| s == 1).count() as u32
}

/// Access energy of one state-restricted word under the Table 4 model:
/// `00`/`11` bill the hybrid soft (single-pulse) cost, `10` bills hard.
pub fn word_energy(h: u16, cost: &CostModel, kind: AccessKind) -> Energy {
    let vuln = vulnerable_cells(h) as f64;
    let base = CELLS_PER_WORD_SR as f64 - vuln;
    let (hardc, softc) = match kind {
        AccessKind::Read => (cost.hard_read, cost.soft_read),
        AccessKind::Write => (cost.hard_write, cost.soft_write),
    };
    Energy {
        nanojoules: vuln * hardc.nanojoules + base * softc.nanojoules,
        cycles: if vuln > 0.0 { hardc.cycles } else { softc.cycles },
    }
}

/// Cell-count overhead vs. plain 2-bit MLC storage.
pub fn cell_overhead() -> f64 {
    CELLS_PER_WORD_SR as f64 / fp::CELLS_PER_WORD as f64 - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn exhaustive_roundtrip() {
        for h in 0..=u16::MAX {
            let enc = encode_word(h);
            assert_eq!(decode_word(&enc), Some(h));
            for &s in &enc {
                assert!(s < 3);
                assert_ne!(symbol_state(s), 0b01, "fragile state programmed");
            }
        }
    }

    #[test]
    fn eleven_cells_suffice_and_ten_do_not() {
        assert!(3u32.pow(11) > u16::MAX as u32);
        assert!(3u32.pow(10) < u16::MAX as u32 + 1);
        assert!((cell_overhead() - 0.375).abs() < 1e-12);
    }

    #[test]
    fn out_of_range_symbols_detected() {
        // 3^11 - 1 decodes above u16::MAX -> None.
        let all_twos = [2u8; CELLS_PER_WORD_SR];
        assert_eq!(decode_word(&all_twos), None);
    }

    #[test]
    fn vulnerable_cells_less_than_mlc_soft_cells_on_average() {
        // Expected vulnerable fraction per cell is 1/3 for uniform data,
        // vs 1/2 soft cells in plain MLC — the [12] reliability claim.
        let mut rng = Xoshiro256::seeded(1);
        let n = 20_000;
        let mut sr = 0u64;
        let mut mlc = 0u64;
        for _ in 0..n {
            let h = (rng.next_u64() >> 48) as u16;
            sr += vulnerable_cells(h) as u64;
            mlc += fp::soft_cells(h) as u64;
        }
        let sr_frac = sr as f64 / (n * CELLS_PER_WORD_SR as u64) as f64;
        let mlc_frac = mlc as f64 / (n * 8) as f64;
        // Digits of u16 values in base 3 are *nearly* uniform over {0,1,2}
        // (the unused top of the 3^11 range biases high digits toward 0),
        // so the vulnerable fraction sits just under 1/3.
        assert!((0.25..0.34).contains(&sr_frac), "{sr_frac}");
        assert!((mlc_frac - 0.5).abs() < 0.01, "{mlc_frac}");
        assert!(sr_frac < mlc_frac);
    }

    #[test]
    fn energy_tradeoff_vs_paper_scheme() {
        // State-restrict buys reliability with 37.5% more cells; its total
        // write energy must exceed the paper's hybrid scheme on the same
        // weights (which is the paper's argument for not sacrificing
        // capacity).
        use crate::encoding::{Policy, WeightCodec};
        use crate::stt::CostModel;
        let mut rng = Xoshiro256::seeded(2);
        let ws: Vec<f32> = (0..10_000)
            .map(|_| ((rng.next_gaussian() * 0.25) as f32).clamp(-1.0, 1.0))
            .collect();
        let cost = CostModel::default();
        let hyb = WeightCodec::new(Policy::Hybrid, 4).encode(&ws);
        let hyb_write: f64 = hyb
            .words
            .iter()
            .map(|&w| cost.word(w, AccessKind::Write).nanojoules)
            .sum();
        let sr_write: f64 = ws
            .iter()
            .map(|&w| {
                word_energy(fp::f32_to_f16_bits(w), &cost, AccessKind::Write).nanojoules
            })
            .sum();
        assert!(sr_write > hyb_write, "sr {sr_write} vs hybrid {hyb_write}");
    }
}
