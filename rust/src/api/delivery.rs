//! Zero-downtime weight delivery: streamed, hash-verified, hot-swapped
//! deployments with retry/backoff, canary, and rollback (DESIGN.md §14).
//!
//! The paper's protection scheme keeps a faulty MLC buffer serving
//! accurate inferences, but until this module the system assumed weights
//! *arrive* whole and intact — one corrupted or truncated transfer meant
//! a failed build and a dropped model. [`deliver`] closes that gap with
//! an end-to-end rollout pipeline over the existing serving stack:
//!
//! 1. **Manifest** — a [`DeploymentManifest`] (model, version, protection
//!    policy, granularity, fault rate, chunk geometry, per-chunk
//!    checksums) is the unit of rollout: everything needed to verify the
//!    stream and rebuild the staged store deterministically.
//! 2. **Streamed verification** — a fallible [`WeightStream`] delivers
//!    the flattened weights chunk by chunk; every chunk is length- and
//!    hash-checked ([`chunk_checksum`], FNV-1a over the f32 bit
//!    patterns) as it lands. Failed reads retry under a bounded budget
//!    (`MLCSTT_DELIVERY_RETRIES`) with deterministic seeded equal-jitter
//!    exponential backoff ([`crate::util::backoff::Backoff`],
//!    `MLCSTT_DELIVERY_BACKOFF_MS`).
//! 3. **Staging** — the verified weights build into the registry's
//!    shared [`super::BufferPool`] under a versioned tenant tag *alongside* the
//!    live version (or into a private staged store without a pool); the
//!    incumbent keeps serving throughout.
//! 4. **Canary** — a probe batch ([`CanaryCheck`], `MLCSTT_CANARY`
//!    batches) must classify correctly through an engine built from the
//!    staged tensors before the swap may commit.
//! 5. **Atomic swap or rollback** — [`super::ModelRegistry::swap`] flips
//!    routing to the new engine in one assignment and drains the old
//!    server (no request dropped, accounting retired, never observable
//!    half-swapped). *Any* failure — verification, staging, canary, swap
//!    — leaves the incumbent serving bit-identically and surfaces as a
//!    typed [`DeliveryError`], with retries/rollbacks counted in the
//!    [`super::RegistryReport`].
//!
//! Pinned by `rust/tests/delivery.rs` (property tests over corrupted /
//! truncated / wrong-version / flaky-canary inputs) and exercised under
//! chaos in `examples/hot_swap.rs` (`make swap-demo`).

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{bail, ensure, Result};

use crate::coordinator::{BatchClassifier, StoreConfig, StoreReport};
use crate::encoding::Policy;
use crate::runtime::artifacts::{ParamSpec, WeightFile};
use crate::stt::ErrorModel;
use crate::util::backoff::Backoff;
use crate::util::json::{obj, Json};

use super::pool::PooledEngine;
use super::{Config, Deployment, ModelRegistry};

/// Default per-chunk re-read budget ([`Config::delivery_retries_or`],
/// `MLCSTT_DELIVERY_RETRIES`).
pub const DEFAULT_DELIVERY_RETRIES: usize = 3;

/// Default base delay of the retry backoff
/// ([`Config::delivery_backoff_or`], `MLCSTT_DELIVERY_BACKOFF_MS`).
pub const DEFAULT_DELIVERY_BACKOFF: Duration = Duration::from_millis(5);

/// Default canary probe batches gating a swap
/// ([`Config::canary_or`], `MLCSTT_CANARY`).
pub const DEFAULT_CANARY_BATCHES: usize = 1;

/// FNV-1a (64-bit) over a chunk's f32 **bit patterns**, little-endian
/// byte order. Bit-exact by construction: two chunks hash equal iff
/// every weight is bit-identical (NaN payloads and `-0.0` vs `0.0`
/// included), which is the same identity the staged-vs-fresh store
/// argument rests on. No crypto dependency — this guards against
/// transfer corruption, not an adversary.
pub fn chunk_checksum(chunk: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for w in chunk {
        for b in w.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// The unit of rollout: everything [`deliver`] needs to verify a stream
/// and rebuild the staged store deterministically. Schema documented in
/// DESIGN.md §14; [`DeploymentManifest::to_json`] renders it.
#[derive(Clone, Debug, PartialEq)]
pub struct DeploymentManifest {
    /// Registry tag of the model being redeployed.
    pub model: String,
    /// Offered version; must exceed the registry's live version and match
    /// the stream's claimed version ([`WeightStream::version`]).
    pub version: u64,
    /// Protection policy the staged store encodes under.
    pub policy: Policy,
    /// Metadata granularity of the staged store.
    pub granularity: usize,
    /// Write-fault rate of the staged store's error model.
    pub error_rate: f64,
    /// Fault-injection seed of the staged store (also seeds the retry
    /// backoff jitter, mixed with the chunk index).
    pub seed: u64,
    /// Weights per chunk (the final chunk may be shorter).
    pub chunk_elems: usize,
    /// Total weights across the flattened stream.
    pub total_elems: usize,
    /// `(name, shape)` per tensor, in stream order — how the verified
    /// flat stream reassembles into a [`WeightFile`].
    pub specs: Vec<(String, Vec<usize>)>,
    /// Per-chunk [`chunk_checksum`]s, in stream order.
    pub checksums: Vec<u64>,
}

impl DeploymentManifest {
    /// Describe `weights` as a rollout manifest: flatten in tensor order,
    /// chunk by `chunk_elems`, and checksum every chunk. The staged
    /// store's recipe (policy, granularity, error model, seed) is taken
    /// from `store`; its capacity/banks are ignored — the receiving
    /// pool's geometry wins, exactly as in [`super::BufferPool::admit`].
    pub fn describe(
        model: &str,
        version: u64,
        weights: &WeightFile,
        chunk_elems: usize,
        store: &StoreConfig,
    ) -> Result<Self> {
        ensure!(chunk_elems >= 1, "chunk_elems must be >= 1");
        let total = weights.total_elems();
        ensure!(total > 0, "empty weight file");
        let flat = weights.flat();
        let checksums = flat.chunks(chunk_elems).map(chunk_checksum).collect();
        Ok(DeploymentManifest {
            model: model.to_string(),
            version,
            policy: store.policy,
            granularity: store.granularity,
            error_rate: store.error_model.write_error_rate,
            seed: store.seed,
            chunk_elems,
            total_elems: total,
            specs: weights
                .params
                .iter()
                .map(|p| (p.name.clone(), p.shape.clone()))
                .collect(),
            checksums,
        })
    }

    /// Number of chunks in the stream.
    pub fn chunk_count(&self) -> usize {
        self.total_elems.div_ceil(self.chunk_elems)
    }

    /// Expected length of chunk `index` (the final chunk carries the
    /// remainder).
    pub fn chunk_len(&self, index: usize) -> usize {
        let start = index * self.chunk_elems;
        self.chunk_elems.min(self.total_elems.saturating_sub(start))
    }

    /// The staged store's [`StoreConfig`]: the manifest's recipe plus the
    /// caller's worker ceiling (capacity/banks stay at their defaults —
    /// the pool's geometry wins on admission).
    pub fn store_config(&self, threads: usize) -> StoreConfig {
        StoreConfig {
            policy: self.policy,
            granularity: self.granularity,
            error_model: ErrorModel::at_rate(self.error_rate),
            seed: self.seed,
            threads,
            ..StoreConfig::default()
        }
    }

    /// Reassemble a fully-verified flat stream into a [`WeightFile`]
    /// under this manifest's tensor specs.
    pub fn reassemble(&self, flat: Vec<f32>) -> Result<WeightFile> {
        ensure!(
            flat.len() == self.total_elems,
            "stream carries {} weights, manifest wants {}",
            flat.len(),
            self.total_elems
        );
        let mut params = Vec::with_capacity(self.specs.len());
        let mut off = 0usize;
        for (name, shape) in &self.specs {
            let n: usize = shape.iter().product();
            ensure!(off + n <= flat.len(), "tensor {name} overruns the stream");
            params.push(ParamSpec {
                name: name.clone(),
                shape: shape.clone(),
                data: flat[off..off + n].to_vec(),
            });
            off += n;
        }
        ensure!(off == flat.len(), "specs cover {off} of {} weights", flat.len());
        Ok(WeightFile { params })
    }

    /// Render the manifest schema (DESIGN.md §14) as JSON.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", Json::from(self.model.as_str())),
            ("version", Json::Num(self.version as f64)),
            ("policy", Json::from(self.policy.label())),
            ("granularity", Json::Num(self.granularity as f64)),
            ("error_rate", Json::Num(self.error_rate)),
            ("seed", Json::Num(self.seed as f64)),
            ("chunk_elems", Json::Num(self.chunk_elems as f64)),
            ("total_elems", Json::Num(self.total_elems as f64)),
            ("chunks", Json::Num(self.chunk_count() as f64)),
            (
                "checksums",
                Json::Arr(
                    self.checksums
                        .iter()
                        .map(|c| Json::from(format!("{c:016x}").as_str()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// A fallible, re-readable chunk source for one weight version. Reads
/// may fail transiently (network, storage) and may return corrupted or
/// short data — [`deliver`] verifies every chunk and retries failed
/// reads, so implementations must tolerate `read_chunk` being called
/// repeatedly for the same index.
pub trait WeightStream {
    /// The version this source claims to carry; gated against the
    /// manifest before any chunk is read.
    fn version(&self) -> u64;

    /// Read chunk `index` (0-based) of the flattened weight stream.
    fn read_chunk(&mut self, index: usize) -> Result<Vec<f32>>;
}

/// An in-memory [`WeightStream`] over a flattened weight vector — the
/// synthetic source the demos and tests deliver from (a file- or
/// network-backed source implements the same trait).
pub struct MemoryStream {
    version: u64,
    flat: Vec<f32>,
    chunk_elems: usize,
}

impl MemoryStream {
    /// A stream claiming `version`, over `weights` flattened in tensor
    /// order, chunked by `chunk_elems` (matching the manifest geometry).
    pub fn from_weights(version: u64, weights: &WeightFile, chunk_elems: usize) -> Self {
        MemoryStream {
            version,
            flat: weights.flat(),
            chunk_elems: chunk_elems.max(1),
        }
    }
}

impl WeightStream for MemoryStream {
    fn version(&self) -> u64 {
        self.version
    }

    fn read_chunk(&mut self, index: usize) -> Result<Vec<f32>> {
        let start = index * self.chunk_elems;
        if start >= self.flat.len() {
            bail!("chunk {index} out of range ({} weights)", self.flat.len());
        }
        let end = (start + self.chunk_elems).min(self.flat.len());
        Ok(self.flat[start..end].to_vec())
    }
}

/// A chaos decorator over any [`WeightStream`]: injects deterministic,
/// per-chunk-attempt faults — synthetic read timeouts, truncation, bit
/// corruption — so retry/rollback paths can be driven on purpose. For
/// each affected chunk, attempt `n` (0-based) fails while `n <`
/// `fail_reads`, returns a short chunk while `n < fail_reads +
/// truncate_reads`, returns a bit-flipped chunk while `n < fail_reads +
/// truncate_reads + corrupt_reads`, and is clean afterwards — so a
/// retry budget at least that deep always converges, and a shallower
/// one deterministically exhausts.
pub struct ChaosStream<S> {
    inner: S,
    fail_reads: usize,
    truncate_reads: usize,
    corrupt_reads: usize,
    /// Restrict faults to this chunk (`None` = every chunk).
    only_chunk: Option<usize>,
    /// Attempts observed per chunk index.
    reads: HashMap<usize, usize>,
}

impl<S: WeightStream> ChaosStream<S> {
    /// Wrap `inner` with no faults configured (builders below add them).
    pub fn new(inner: S) -> Self {
        ChaosStream {
            inner,
            fail_reads: 0,
            truncate_reads: 0,
            corrupt_reads: 0,
            only_chunk: None,
            reads: HashMap::new(),
        }
    }

    /// First `n` attempts per affected chunk error ("synthetic timeout").
    pub fn fail_first(mut self, n: usize) -> Self {
        self.fail_reads = n;
        self
    }

    /// The next `n` attempts per affected chunk come back one weight
    /// short.
    pub fn truncate_first(mut self, n: usize) -> Self {
        self.truncate_reads = n;
        self
    }

    /// The next `n` attempts per affected chunk come back with one bit
    /// flipped in the first weight.
    pub fn corrupt_first(mut self, n: usize) -> Self {
        self.corrupt_reads = n;
        self
    }

    /// Only inject faults on chunk `index` (default: every chunk).
    pub fn on_chunk(mut self, index: usize) -> Self {
        self.only_chunk = Some(index);
        self
    }
}

impl<S: WeightStream> WeightStream for ChaosStream<S> {
    fn version(&self) -> u64 {
        self.inner.version()
    }

    fn read_chunk(&mut self, index: usize) -> Result<Vec<f32>> {
        let n = {
            let seen = self.reads.entry(index).or_insert(0);
            let n = *seen;
            *seen += 1;
            n
        };
        let affected = match self.only_chunk {
            None => true,
            Some(c) => c == index,
        };
        if !affected {
            return self.inner.read_chunk(index);
        }
        if n < self.fail_reads {
            bail!("synthetic timeout reading chunk {index} (attempt {n})");
        }
        let mut data = self.inner.read_chunk(index)?;
        if n < self.fail_reads.saturating_add(self.truncate_reads) {
            data.pop();
            return Ok(data);
        }
        let corrupt_until = self
            .fail_reads
            .saturating_add(self.truncate_reads)
            .saturating_add(self.corrupt_reads);
        if n < corrupt_until {
            if let Some(w) = data.first_mut() {
                *w = f32::from_bits(w.to_bits() ^ 0x0040_0000);
            }
        }
        Ok(data)
    }
}

/// Typed delivery failure. Every variant means the same thing for the
/// serving side: **the incumbent version is still live and serving
/// bit-identically** — [`deliver`] never commits a partial swap.
#[derive(Clone, Debug, PartialEq)]
pub enum DeliveryError {
    /// A chunk's FNV-1a checksum did not match the manifest.
    ChecksumMismatch {
        /// Chunk index in the stream.
        chunk: usize,
        /// Manifest checksum.
        want: u64,
        /// Checksum of the bytes actually read.
        got: u64,
    },
    /// A chunk came back shorter (or longer) than the manifest geometry.
    Truncated {
        /// Chunk index in the stream.
        chunk: usize,
        /// Expected weight count.
        want: usize,
        /// Received weight count.
        got: usize,
    },
    /// The offered version conflicts: the stream claims a different
    /// version than the manifest, or the manifest does not advance the
    /// registry's live version.
    VersionConflict {
        /// The model being delivered.
        model: String,
        /// The manifest's offered version.
        offered: u64,
        /// The conflicting version observed (the stream's claim, or the
        /// already-live version for a stale rollout).
        found: u64,
    },
    /// A chunk kept failing past the retry budget; `cause` is the final
    /// attempt's typed failure.
    RetriesExhausted {
        /// Chunk index that exhausted its budget.
        chunk: usize,
        /// Re-reads performed (the configured budget).
        retries: usize,
        /// The last attempt's failure.
        cause: Box<DeliveryError>,
    },
    /// The stream's `read_chunk` itself errored (timeout, I/O).
    Read {
        /// Chunk index of the failed read.
        chunk: usize,
        /// The source error, with its context chain.
        message: String,
    },
    /// The staged engine failed its canary probe — wrong predictions or
    /// an engine error on the probe batch.
    CanaryFailed {
        /// Probe predictions checked before the verdict.
        checked: usize,
        /// Probe predictions that diverged from the expectation.
        mismatches: usize,
        /// What went wrong (divergence summary or the engine's error).
        message: String,
    },
    /// Staging the verified weights (pool admission, store build, engine
    /// construction, or the swap itself) failed.
    Staging {
        /// The underlying error, with its context chain.
        message: String,
    },
}

impl std::fmt::Display for DeliveryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeliveryError::ChecksumMismatch { chunk, want, got } => {
                write!(f, "chunk {chunk}: checksum mismatch (want {want:016x}, got {got:016x})")
            }
            DeliveryError::Truncated { chunk, want, got } => {
                write!(f, "chunk {chunk}: truncated ({got} of {want} weights)")
            }
            DeliveryError::VersionConflict { model, offered, found } => {
                write!(f, "version conflict for {model:?}: offered v{offered}, found v{found}")
            }
            DeliveryError::RetriesExhausted { chunk, retries, cause } => {
                write!(f, "chunk {chunk}: {retries} retries exhausted; last failure: {cause}")
            }
            DeliveryError::Read { chunk, message } => {
                write!(f, "chunk {chunk}: read failed: {message}")
            }
            DeliveryError::CanaryFailed { checked, mismatches, message } => {
                write!(f, "canary failed ({mismatches}/{checked} diverged): {message}")
            }
            DeliveryError::Staging { message } => write!(f, "staging failed: {message}"),
        }
    }
}

impl std::error::Error for DeliveryError {}

/// One canary expectation: the staged engine must classify `image` as
/// `expect`. [`deliver`] fills `MLCSTT_CANARY` probe batches from these
/// cyclically.
#[derive(Clone, Debug)]
pub struct CanaryCheck {
    /// Probe image (`image_elems` floats for the staged engine).
    pub image: Vec<f32>,
    /// Required predicted class.
    pub expect: usize,
}

/// What a committed (or failed) delivery did — the `DELIVERY_*.json`
/// payload of `examples/hot_swap.rs` and `mlcstt deliver`.
#[derive(Clone, Debug)]
pub struct DeliveryReport {
    /// The redeployed model's registry tag.
    pub model: String,
    /// The now-live version.
    pub version: u64,
    /// Chunks verified.
    pub chunks: usize,
    /// Chunk re-reads spent (beyond each chunk's first attempt).
    pub retries: u64,
    /// Backoff delay accumulated across those retries.
    pub backoff_total: Duration,
    /// Canary probe batches the staged engine passed.
    pub canary_batches: usize,
    /// Staged store accounting (encode + fault injection + materialize
    /// of the *new* version).
    pub store: StoreReport,
}

impl DeliveryReport {
    /// Render as JSON for the `DELIVERY_*.json` artifacts.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", Json::from(self.model.as_str())),
            ("version", Json::Num(self.version as f64)),
            ("chunks", Json::Num(self.chunks as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("backoff_ms", Json::Num(self.backoff_total.as_secs_f64() * 1e3)),
            ("canary_batches", Json::Num(self.canary_batches as f64)),
            ("injected_faults", Json::Num(self.store.injected_faults as f64)),
            ("write_nj", Json::Num(self.store.write_energy.nanojoules)),
            ("read_nj", Json::Num(self.store.read_energy.nanojoules)),
        ])
    }
}

/// Versioned pool tenant tag for a staged/live delivery.
fn pool_tag(model: &str, version: u64) -> String {
    format!("{model}@v{version}")
}

/// Record the failure-path accounting and surface the typed error.
fn fail(
    registry: &mut ModelRegistry,
    retries: u64,
    err: DeliveryError,
) -> Result<DeliveryReport, DeliveryError> {
    registry.note_retries(retries);
    registry.note_rollback();
    Err(err)
}

/// Read chunk `index` once and verify it against the manifest.
fn read_verified<S: WeightStream + ?Sized>(
    stream: &mut S,
    manifest: &DeploymentManifest,
    index: usize,
) -> Result<Vec<f32>, DeliveryError> {
    let data = stream.read_chunk(index).map_err(|e| DeliveryError::Read {
        chunk: index,
        message: format!("{e:#}"),
    })?;
    let want = manifest.chunk_len(index);
    if data.len() != want {
        return Err(DeliveryError::Truncated {
            chunk: index,
            want,
            got: data.len(),
        });
    }
    let got = chunk_checksum(&data);
    if got != manifest.checksums[index] {
        return Err(DeliveryError::ChecksumMismatch {
            chunk: index,
            want: manifest.checksums[index],
            got,
        });
    }
    Ok(data)
}

/// Probe a staged engine: fill `batches` canary batches from `checks`
/// cyclically and require every prediction to match.
fn run_canary<C, B>(
    tensors: &[ParamSpec],
    checks: &[CanaryCheck],
    batches: usize,
    build: &mut B,
) -> Result<(), DeliveryError>
where
    C: BatchClassifier,
    B: FnMut(&[ParamSpec]) -> Result<C>,
{
    if batches == 0 || checks.is_empty() {
        return Ok(());
    }
    let engine = build(tensors).map_err(|e| DeliveryError::Staging {
        message: format!("building canary engine: {e:#}"),
    })?;
    let bs = engine.batch_size();
    let elems = engine.image_elems();
    let mut images = vec![0f32; bs * elems];
    let mut checked = 0usize;
    let mut mismatches = 0usize;
    for b in 0..batches {
        let mut expected = Vec::with_capacity(bs);
        for j in 0..bs {
            let p = &checks[(b * bs + j) % checks.len()];
            if p.image.len() != elems {
                return Err(DeliveryError::Staging {
                    message: format!(
                        "canary probe wants {elems} floats, got {}",
                        p.image.len()
                    ),
                });
            }
            images[j * elems..(j + 1) * elems].copy_from_slice(&p.image);
            expected.push(p.expect);
        }
        let preds = engine
            .classify_batch(&images)
            .map_err(|e| DeliveryError::CanaryFailed {
                checked,
                mismatches,
                message: format!("probe batch {b} errored: {e:#}"),
            })?;
        for (j, want) in expected.iter().enumerate() {
            checked += 1;
            if preds[j] != *want {
                mismatches += 1;
            }
        }
    }
    if mismatches > 0 {
        return Err(DeliveryError::CanaryFailed {
            checked,
            mismatches,
            message: "staged predictions diverged from canary expectations".into(),
        });
    }
    Ok(())
}

/// Deliver `manifest`'s version of `manifest.model` from `stream` into
/// `registry`, hot-swapping on success — the module-level pipeline
/// (verify → stage → canary → swap) in one call.
///
/// `build` turns a tensor set into the serving engine; with a pool
/// attached it also becomes the staged tenant's rebuild hook (the
/// [`PooledEngine`] contract of
/// [`super::ModelRegistry::register_pooled`]), so the new version
/// survives eviction like any tenant. `checks` are the canary
/// expectations ([`Config::canary_or`] batches gate the swap; pass `&[]`
/// or set the knob to 0 to skip).
///
/// On `Err`, the incumbent is untouched and still serving — the staged
/// tenant (if any) has been withdrawn, the rollback is counted, and the
/// retry spend is in the registry report either way.
pub fn deliver<S, C, B>(
    registry: &mut ModelRegistry,
    manifest: &DeploymentManifest,
    stream: &mut S,
    checks: &[CanaryCheck],
    config: &Config,
    mut build: B,
) -> Result<DeliveryReport, DeliveryError>
where
    S: WeightStream + ?Sized,
    C: BatchClassifier,
    B: FnMut(&[ParamSpec]) -> Result<C> + Send + 'static,
{
    let model = manifest.model.clone();
    if !registry.models().iter().any(|m| *m == model) {
        return fail(
            registry,
            0,
            DeliveryError::Staging {
                message: format!("unknown model {model:?} ({} registered)", registry.len()),
            },
        );
    }
    // Version gates fail fast: no chunk is worth reading for a stream
    // that claims the wrong version or a rollout that does not advance.
    if stream.version() != manifest.version {
        return fail(
            registry,
            0,
            DeliveryError::VersionConflict {
                model,
                offered: manifest.version,
                found: stream.version(),
            },
        );
    }
    let live = registry.version(&model);
    if manifest.version <= live {
        return fail(
            registry,
            0,
            DeliveryError::VersionConflict {
                model,
                offered: manifest.version,
                found: live,
            },
        );
    }
    if manifest.checksums.len() != manifest.chunk_count() {
        return fail(
            registry,
            0,
            DeliveryError::Staging {
                message: format!(
                    "manifest carries {} checksums for {} chunks",
                    manifest.checksums.len(),
                    manifest.chunk_count()
                ),
            },
        );
    }

    // 1. Streamed, incrementally verified transfer with bounded retries
    //    under deterministic seeded backoff.
    let budget = config.delivery_retries_or(DEFAULT_DELIVERY_RETRIES);
    let base = config.delivery_backoff_or(DEFAULT_DELIVERY_BACKOFF);
    let mut flat: Vec<f32> = Vec::with_capacity(manifest.total_elems);
    let mut retries_total: u64 = 0;
    let mut backoff_total = Duration::ZERO;
    for i in 0..manifest.chunk_count() {
        // Per-chunk schedule, deterministically derived from the manifest
        // seed + chunk index (golden-ratio mix): replays are bit-exact.
        let mut backoff = Backoff::new(
            base,
            manifest.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let mut failures = 0usize;
        loop {
            match read_verified(stream, manifest, i) {
                Ok(mut data) => {
                    flat.append(&mut data);
                    break;
                }
                Err(cause) => {
                    if failures >= budget {
                        let err = if budget == 0 {
                            cause
                        } else {
                            DeliveryError::RetriesExhausted {
                                chunk: i,
                                retries: budget,
                                cause: Box::new(cause),
                            }
                        };
                        return fail(registry, retries_total, err);
                    }
                    failures += 1;
                    retries_total += 1;
                    let d = backoff.next_delay();
                    backoff_total += d;
                    if !d.is_zero() {
                        std::thread::sleep(d);
                    }
                }
            }
        }
    }
    let weights = match manifest.reassemble(flat) {
        Ok(w) => w,
        Err(e) => {
            return fail(
                registry,
                retries_total,
                DeliveryError::Staging {
                    message: format!("{e:#}"),
                },
            )
        }
    };

    // 2. Stage alongside the live version, canary, then atomically swap.
    let staging = pool_tag(&model, manifest.version);
    let store_cfg = manifest.store_config(config.threads());
    let canary_batches = config.canary_or(DEFAULT_CANARY_BATCHES);
    let report = |store: StoreReport| DeliveryReport {
        model: model.clone(),
        version: manifest.version,
        chunks: manifest.chunk_count(),
        retries: retries_total,
        backoff_total,
        canary_batches: if checks.is_empty() { 0 } else { canary_batches },
        store,
    };

    if let Some(pool) = registry.pool().cloned() {
        // A stale tenant from an aborted earlier attempt must not block
        // redelivery of the same version.
        if pool.contains(&staging) {
            let _ = pool.remove(&staging);
        }
        let store = match pool.admit(&staging, &store_cfg, &weights) {
            Ok(r) => r,
            Err(e) => {
                return fail(
                    registry,
                    retries_total,
                    DeliveryError::Staging {
                        message: format!("{e:#}"),
                    },
                )
            }
        };
        let tensors = match pool.tensors(&staging) {
            Ok(t) => t,
            Err(e) => {
                let _ = pool.remove(&staging);
                return fail(
                    registry,
                    retries_total,
                    DeliveryError::Staging {
                        message: format!("{e:#}"),
                    },
                );
            }
        };
        if let Err(err) = run_canary(&tensors, checks, canary_batches, &mut build) {
            let _ = pool.remove(&staging);
            return fail(registry, retries_total, err);
        }
        let lease = match pool.lease(&staging) {
            Ok(l) => l,
            Err(e) => {
                let _ = pool.remove(&staging);
                return fail(
                    registry,
                    retries_total,
                    DeliveryError::Staging {
                        message: format!("{e:#}"),
                    },
                );
            }
        };
        let swap =
            registry.swap(&model, move || PooledEngine::new(lease, build), config.server());
        if let Err(e) = swap {
            let _ = pool.remove(&staging);
            return fail(
                registry,
                retries_total,
                DeliveryError::Staging {
                    message: format!("{e:#}"),
                },
            );
        }
        // Committed: stamp the version and withdraw the loser's tenant
        // (the caller-admitted plain tag for a first delivery, the prior
        // versioned tag afterwards).
        registry.set_version(&model, manifest.version);
        let old_tenant = if live == 0 { model.clone() } else { pool_tag(&model, live) };
        if pool.contains(&old_tenant) {
            let _ = pool.remove(&old_tenant);
        }
        registry.note_retries(retries_total);
        Ok(report(store))
    } else {
        // No pool: stage a private store (encode + faults + materialize),
        // serve the decoded tensors from a plain engine factory.
        let dep = match Deployment::builder()
            .weights(weights)
            .name(&staging)
            .store(store_cfg)
            .build()
        {
            Ok(d) => d,
            Err(e) => {
                return fail(
                    registry,
                    retries_total,
                    DeliveryError::Staging {
                        message: format!("{e:#}"),
                    },
                )
            }
        };
        let tensors = dep.tensors().to_vec();
        let store = dep.store_report().clone();
        if let Err(err) = run_canary(&tensors, checks, canary_batches, &mut build) {
            return fail(registry, retries_total, err);
        }
        if let Err(e) = registry.swap(&model, move || build(&tensors), config.server()) {
            return fail(
                registry,
                retries_total,
                DeliveryError::Staging {
                    message: format!("{e:#}"),
                },
            );
        }
        registry.set_version(&model, manifest.version);
        registry.note_retries(retries_total);
        Ok(report(store))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weight_file(n: usize) -> WeightFile {
        let data: Vec<f32> = (0..n)
            .map(|i| crate::fp::quantize_f16((i as f32 / n as f32) * 1.6 - 0.8))
            .collect();
        WeightFile {
            params: vec![ParamSpec {
                name: "w".into(),
                shape: vec![n],
                data,
            }],
        }
    }

    #[test]
    fn checksum_is_bit_exact_and_order_sensitive() {
        let a = chunk_checksum(&[1.0, 2.0, 3.0]);
        assert_eq!(a, chunk_checksum(&[1.0, 2.0, 3.0]));
        assert_ne!(a, chunk_checksum(&[1.0, 3.0, 2.0]));
        // Bit identity, not numeric identity.
        assert_ne!(chunk_checksum(&[0.0]), chunk_checksum(&[-0.0]));
        assert_ne!(
            chunk_checksum(&[f32::from_bits(1)]),
            chunk_checksum(&[f32::from_bits(2)])
        );
    }

    #[test]
    fn manifest_chunk_geometry_covers_the_stream() {
        let wf = weight_file(100);
        let m = DeploymentManifest::describe("m", 1, &wf, 32, &StoreConfig::default()).unwrap();
        assert_eq!(m.chunk_count(), 4);
        assert_eq!(m.checksums.len(), 4);
        assert_eq!(m.chunk_len(0), 32);
        assert_eq!(m.chunk_len(3), 4, "tail chunk carries the remainder");
        assert_eq!((0..4).map(|i| m.chunk_len(i)).sum::<usize>(), 100);
        // Round-trip: a clean memory stream reassembles bit-identically.
        let mut s = MemoryStream::from_weights(1, &wf, 32);
        let mut flat = Vec::new();
        for i in 0..m.chunk_count() {
            let chunk = read_verified(&mut s, &m, i).unwrap();
            flat.extend(chunk);
        }
        let back = m.reassemble(flat).unwrap();
        assert_eq!(back.params[0].data, wf.params[0].data);
        assert_eq!(back.params[0].shape, wf.params[0].shape);
    }

    #[test]
    fn chaos_stream_fault_schedule_is_deterministic() {
        let wf = weight_file(64);
        let m = DeploymentManifest::describe("m", 1, &wf, 32, &StoreConfig::default()).unwrap();
        let mut s = ChaosStream::new(MemoryStream::from_weights(1, &wf, 32))
            .fail_first(1)
            .truncate_first(1)
            .corrupt_first(1)
            .on_chunk(0);
        // Attempt 0: synthetic timeout.
        assert!(matches!(
            read_verified(&mut s, &m, 0),
            Err(DeliveryError::Read { chunk: 0, .. })
        ));
        // Attempt 1: truncated.
        assert_eq!(
            read_verified(&mut s, &m, 0).unwrap_err(),
            DeliveryError::Truncated { chunk: 0, want: 32, got: 31 }
        );
        // Attempt 2: corrupted -> checksum mismatch.
        assert!(matches!(
            read_verified(&mut s, &m, 0),
            Err(DeliveryError::ChecksumMismatch { chunk: 0, .. })
        ));
        // Attempt 3: clean; other chunks always clean.
        assert!(read_verified(&mut s, &m, 0).is_ok());
        assert!(read_verified(&mut s, &m, 1).is_ok());
    }

    #[test]
    fn manifest_json_carries_the_schema_fields() {
        let wf = weight_file(8);
        let m = DeploymentManifest::describe("demo", 3, &wf, 4, &StoreConfig::default()).unwrap();
        let j = m.to_json().to_string_pretty();
        for key in ["model", "version", "policy", "granularity", "error_rate", "checksums"] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[test]
    fn delivery_error_displays_are_actionable() {
        let e = DeliveryError::RetriesExhausted {
            chunk: 2,
            retries: 3,
            cause: Box::new(DeliveryError::ChecksumMismatch { chunk: 2, want: 1, got: 2 }),
        };
        let s = format!("{e}");
        assert!(s.contains("chunk 2"), "{s}");
        assert!(s.contains("3 retries"), "{s}");
        assert!(s.contains("checksum mismatch"), "{s}");
    }
}
