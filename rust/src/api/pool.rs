//! Shared multi-tenant buffer pool: N models' weights in one banked MLC
//! buffer, with LRU eviction and on-demand, bit-identical rebuilds.
//!
//! [`BufferPool`] owns a [`SharedMlcBuffer`] (bank-aligned extent
//! allocator + wear ledger, DESIGN.md §12) and a tenant table. A tenant is
//! admitted once ([`BufferPool::admit`]) with its [`StoreConfig`] and
//! weights; the pool encodes the clean tensors **once** and keeps them,
//! because every (re)build replays the same deterministic recipe:
//!
//! 1. reset the tenant's [`AccessStats`] and reseed a frest fault RNG from
//!    the tenant's seed;
//! 2. store each tensor in file order through
//!    [`SharedMlcBuffer::alloc_store`] (per-shard fault seeds drawn from
//!    the tenant stream in shard order — exactly the draw order of a
//!    private [`WeightStore::load`]);
//! 3. materialize each tensor in order through the fused load→decode.
//!
//! Region-relative bank slots make read bills placement-independent and
//! write energy is content-only, so the decoded tensors *and* the energy
//! bills of every rebuild are bit-identical to a fresh private store with
//! the same `(policy, granularity, error model, seed, threads)` and the
//! pool's bank count — the eviction contract pinned by
//! `rust/tests/shared_buffer.rs`.
//!
//! Under capacity pressure the pool evicts the least-recently-*served*
//! resident tenant ([`EvictPolicy::Lru`]) and the victim rebuilds on its
//! next request, transparently, inside [`PooledEngine::classify_batch`] —
//! the stall is counted in [`crate::coordinator::ServerReport::rebuilds`].
//!
//! Between leases the pool also runs **background scrubbing** (DESIGN.md
//! §15) when a [`ScrubPolicy`] other than `Off` is installed: under the
//! same single lock, every resident region is walked against its golden
//! checksums, decayed shards are repaired from the clean image, and the
//! per-bank corrected-flip EWMA feeds the adaptive scheduler — so a scrub
//! never races a rebuild, and `Off` is byte-for-byte the old behavior.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::buffer::shared::{BankWear, PoolRegion, SharedMlcBuffer};
use crate::buffer::{shard_checksums, AccessStats, BufferError, LOAD_SHARD_WORDS, STORE_SHARD_WORDS};
use crate::coordinator::store::workers_for;
use crate::coordinator::{BatchClassifier, StoreConfig, StoreReport};
use crate::encoding::codec::MIN_WEIGHTS_PER_WORKER;
use crate::encoding::{protection_for, Encoded, WeightCodec};
use crate::faults::estimator::estimate_impact;
use crate::runtime::artifacts::{ParamSpec, WeightFile};
use crate::scrub::{RateEstimator, ScrubPolicy, ScrubTelemetry};
use crate::stt::ErrorModel;
use crate::util::rng::Xoshiro256;

pub use crate::buffer::shared::EvictPolicy;

/// Default pool bank count ([`crate::coordinator::StoreConfig`]'s default
/// geometry).
pub const DEFAULT_POOL_BANKS: usize = 16;

/// Default extent size in words (16 KB extents at 2 bytes/word).
pub const DEFAULT_POOL_EXTENT: usize = 8192;

/// One admitted model: its build recipe (clean encodings + store config)
/// and, while resident, its regions and decoded tensors.
struct Tenant {
    name: String,
    /// Clean encoded tensors, in weight-file order — encoded once at
    /// admit; every rebuild re-stores these exact images.
    clean: Vec<Encoded>,
    /// Golden per-shard FNV checksums of each clean encoding (DESIGN.md
    /// §15). Rebuilds re-store the same words, so these survive eviction
    /// and stay the scrub cursor's detection reference for the tenant's
    /// whole pool lifetime.
    golden: Vec<Vec<u64>>,
    /// Admit-time estimated E[SSE] per weight at the tenant's configured
    /// write-error rate ([`crate::faults::estimator::estimate_impact`]) —
    /// the adaptive scrub scheduler's second decay signal.
    sse_per_weight: f64,
    /// `(name, shape)` per tensor, for re-materialized [`ParamSpec`]s.
    specs: Vec<(String, Vec<usize>)>,
    model: ErrorModel,
    seed: u64,
    threads: usize,
    /// Admit-time constants of the tenant's [`StoreReport`].
    weights: usize,
    metadata_overhead: f64,
    soft_cells: u64,
    /// Extent runs backing the tenant, `Some` iff resident.
    resident: Option<Vec<PoolRegion>>,
    /// Decoded tensors of the latest build (cleared on eviction).
    tensors: Vec<ParamSpec>,
    /// Per-tenant accounting, reset at each (re)build start so it always
    /// equals what a fresh private store+materialize would have billed.
    stats: AccessStats,
    /// LRU clock stamp of the last serve/touch.
    last_served: u64,
    /// Builds performed (1 after admit, +1 per post-eviction rebuild).
    builds: u64,
}

/// Background-scrub state of one pool (DESIGN.md §15): the scheduler
/// policy, the per-bank error-rate telemetry, and lifetime counters.
struct ScrubState {
    policy: ScrubPolicy,
    estimator: RateEstimator,
    /// When the last scheduled pass finished (`None` until the first).
    /// Read only when the policy is not [`ScrubPolicy::Off`] — `Off`
    /// performs no clock reads at all, keeping it byte-for-byte the
    /// pre-subsystem behavior.
    last: Option<Instant>,
    passes: u64,
    scrubbed_words: u64,
    corrected_words: u64,
    corrected_cells: u64,
    policy_detected: u64,
    dirty_shards: u64,
}

impl ScrubState {
    fn new(banks: usize) -> Self {
        ScrubState {
            policy: ScrubPolicy::Off,
            estimator: RateEstimator::new(banks),
            last: None,
            passes: 0,
            scrubbed_words: 0,
            corrected_words: 0,
            corrected_cells: 0,
            policy_detected: 0,
            dirty_shards: 0,
        }
    }
}

struct PoolInner {
    shared: SharedMlcBuffer,
    tenants: Vec<Tenant>,
    index: HashMap<String, usize>,
    evict: EvictPolicy,
    scrub: ScrubState,
    /// Monotone LRU clock.
    clock: u64,
    /// On-demand rebuilds after an eviction (admit-time builds excluded).
    rebuilds: u64,
    /// Regions evicted under capacity pressure.
    evictions: u64,
}

/// A cloneable handle to one shared buffer pool. All methods lock the
/// pool; tenant builds hold the lock for their duration, which is what
/// serializes an eviction against the victim's next request.
#[derive(Clone)]
pub struct BufferPool {
    inner: Arc<Mutex<PoolInner>>,
}

impl BufferPool {
    /// A pool of `capacity_bytes` across `banks`, with `extent_words`
    /// allocation granularity (rounded up to a multiple of `banks` for
    /// bank-slot alignment) and the given capacity-pressure policy.
    pub fn new(
        capacity_bytes: usize,
        banks: usize,
        extent_words: usize,
        evict: EvictPolicy,
    ) -> Self {
        let banks = banks.max(1);
        let extent = extent_words.max(1).div_ceil(banks) * banks;
        BufferPool {
            inner: Arc::new(Mutex::new(PoolInner {
                shared: SharedMlcBuffer::new(capacity_bytes, banks, extent, 0),
                tenants: Vec::new(),
                index: HashMap::new(),
                evict,
                scrub: ScrubState::new(banks),
                clock: 0,
                rebuilds: 0,
                evictions: 0,
            })),
        }
    }

    /// Build a pool from the facade [`super::Config`]'s `MLCSTT_POOL_*` /
    /// `MLCSTT_EVICT` / `MLCSTT_SCRUB_*` knobs; `None` when no `pool_kb`
    /// was configured.
    pub fn from_config(config: &super::Config) -> Option<Self> {
        config.pool_kb().map(|kb| {
            let pool = BufferPool::new(
                kb * 1024,
                config.pool_banks_or(DEFAULT_POOL_BANKS),
                config.pool_extent_or(DEFAULT_POOL_EXTENT),
                config.evict_policy(),
            );
            pool.set_scrub(config.scrub_policy());
            pool
        })
    }

    /// Install the background-scrub scheduler policy (DESIGN.md §15).
    /// [`ScrubPolicy::Off`] (the default) disables scheduled scrubbing
    /// entirely; an explicit [`BufferPool::scrub_pass`] still works.
    pub fn set_scrub(&self, policy: ScrubPolicy) {
        let mut inner = self.inner.lock().unwrap();
        inner.scrub.policy = policy;
        inner.scrub.last = None;
    }

    /// Admit a model: encode its tensors once under `cfg`'s codec
    /// settings, then build it into the pool (evicting under pressure per
    /// the pool policy). `cfg.banks` and `cfg.capacity_bytes` are ignored
    /// — the pool's geometry wins; everything else (policy, granularity,
    /// error model, seed, threads) is the tenant's build recipe. Returns
    /// the initial build's report, which every later rebuild reproduces
    /// bit-identically.
    pub fn admit(&self, name: &str, cfg: &StoreConfig, weights: &WeightFile) -> Result<StoreReport> {
        let mut inner = self.inner.lock().unwrap();
        if inner.index.contains_key(name) {
            bail!("model {name:?} is already admitted to the pool");
        }
        let total = weights.total_elems();
        anyhow::ensure!(total > 0, "empty weight file");

        let codec = WeightCodec::new(cfg.policy, cfg.granularity);
        let mut clean = Vec::with_capacity(weights.params.len());
        let mut golden = Vec::with_capacity(weights.params.len());
        let mut specs = Vec::with_capacity(weights.params.len());
        let mut overhead_num = 0.0;
        let mut soft = 0u64;
        let mut sse = 0.0f64;
        for p in &weights.params {
            let w = workers_for(cfg.threads, p.data.len(), MIN_WEIGHTS_PER_WORKER);
            let mut enc = Encoded::with_context(cfg.policy, cfg.granularity);
            codec.encode_into_threaded(&p.data, &mut enc, w);
            soft += enc.soft_cells();
            overhead_num += enc.metadata_overhead() * enc.len() as f64;
            sse += estimate_impact(&enc, cfg.error_model.write_error_rate).expected_sse;
            golden.push(shard_checksums(&enc.words));
            specs.push((p.name.clone(), p.shape.clone()));
            clean.push(enc);
        }

        let idx = inner.tenants.len();
        inner.tenants.push(Tenant {
            name: name.to_string(),
            clean,
            golden,
            sse_per_weight: sse / total as f64,
            specs,
            model: cfg.error_model.clone(),
            seed: cfg.seed,
            threads: cfg.threads,
            weights: total,
            metadata_overhead: overhead_num / total as f64,
            soft_cells: soft,
            resident: None,
            tensors: Vec::new(),
            stats: AccessStats::default(),
            last_served: 0,
            builds: 0,
        });
        if let Err(e) = inner.build_tenant(idx) {
            inner.tenants.pop();
            return Err(e).with_context(|| format!("admitting model {name:?}"));
        }
        inner.index.insert(name.to_string(), idx);
        inner.touch(idx);
        Ok(inner.report_of(idx))
    }

    /// The tenant's accounting as a [`StoreReport`] — after any build
    /// (initial or post-eviction), bit-identical to a fresh private
    /// [`crate::coordinator::WeightStore::load`] + `materialize` under
    /// the same recipe and the pool's bank count.
    pub fn report(&self, name: &str) -> Result<StoreReport> {
        let inner = self.inner.lock().unwrap();
        let idx = inner.idx(name)?;
        Ok(inner.report_of(idx))
    }

    /// Whether the model's regions are currently in the buffer.
    pub fn resident(&self, name: &str) -> Result<bool> {
        let inner = self.inner.lock().unwrap();
        let idx = inner.idx(name)?;
        Ok(inner.tenants[idx].resident.is_some())
    }

    /// Rebuild the model now if it was evicted; returns `true` iff a
    /// rebuild ran. (Serving uses [`ModelLease::rebuild_with`], which
    /// does this and engine reconstruction under one lock.)
    pub fn ensure_resident(&self, name: &str) -> Result<bool> {
        let mut inner = self.inner.lock().unwrap();
        let idx = inner.idx(name)?;
        inner.make_resident(idx)
    }

    /// The model's decoded tensors (rebuilding first if evicted).
    pub fn tensors(&self, name: &str) -> Result<Vec<ParamSpec>> {
        let mut inner = self.inner.lock().unwrap();
        let idx = inner.idx(name)?;
        inner.make_resident(idx)?;
        Ok(inner.tenants[idx].tensors.clone())
    }

    /// Whether `name` is currently admitted (resident or evicted).
    pub fn contains(&self, name: &str) -> bool {
        self.inner.lock().unwrap().index.contains_key(name)
    }

    /// Withdraw a model from the pool: free its resident regions (if
    /// any) back to the extent allocator and drop its tenant entry,
    /// clean encodings included. The delivery path uses this to discard
    /// the losing version once a hot swap commits or rolls back
    /// (DESIGN.md §14) — the winner's regions are untouched. Errors on
    /// unknown names; outstanding [`ModelLease`]s for the removed model
    /// error on their next use.
    pub fn remove(&self, name: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let idx = inner.idx(name)?;
        if let Some(regions) = inner.tenants[idx].resident.take() {
            for r in &regions {
                inner.shared.free(r);
            }
        }
        inner.tenants.swap_remove(idx);
        inner.index.remove(name);
        // swap_remove moved the former tail into `idx`: re-point it.
        if idx < inner.tenants.len() {
            let moved = inner.tenants[idx].name.clone();
            inner.index.insert(moved, idx);
        }
        Ok(())
    }

    /// A serving lease on one admitted model (errors on unknown names).
    pub fn lease(&self, name: &str) -> Result<ModelLease> {
        let inner = self.inner.lock().unwrap();
        inner.idx(name)?;
        Ok(ModelLease {
            pool: self.clone(),
            name: name.to_string(),
        })
    }

    /// On-demand rebuilds absorbed after evictions (admits excluded).
    pub fn rebuilds(&self) -> u64 {
        self.inner.lock().unwrap().rebuilds
    }

    /// Regions evicted under capacity pressure.
    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }

    /// The pool's "buffer lifetime under traffic" report
    /// ([`SharedMlcBuffer::bank_wear`]).
    pub fn bank_wear(&self) -> Vec<BankWear> {
        self.inner.lock().unwrap().shared.bank_wear()
    }

    /// Leveling quality across banks ([`SharedMlcBuffer::wear_spread`]).
    pub fn wear_spread(&self) -> f64 {
        self.inner.lock().unwrap().shared.wear_spread()
    }

    /// Run one full scrub pass right now — every resident tenant, every
    /// region — regardless of the scheduler policy, and return the
    /// updated telemetry. Holds the pool lock for the duration, so a
    /// pass never races a rebuild or an eviction.
    pub fn scrub_pass(&self) -> Result<ScrubTelemetry> {
        let mut inner = self.inner.lock().unwrap();
        inner.scrub_pass()?;
        Ok(inner.scrub_telemetry())
    }

    /// Point-in-time scrub telemetry (DESIGN.md §15): scheduler label,
    /// lifetime pass counters, the per-bank corrected-flip EWMAs, and the
    /// effective interval until the next scheduled pass.
    pub fn scrub_telemetry(&self) -> ScrubTelemetry {
        self.inner.lock().unwrap().scrub_telemetry()
    }

    /// Retention aging hook: re-run the write-path fault sampler over
    /// every resident region in place (the pool's own seed stream),
    /// returning the total flipped words. Demos and tests use this to
    /// model time passing between leases; serving never calls it.
    pub fn disturb(&self, model: &ErrorModel) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let PoolInner { tenants, shared, .. } = &mut *inner;
        let mut total = 0u64;
        for tenant in tenants.iter_mut() {
            let Tenant { resident, stats, threads, .. } = tenant;
            if let Some(regions) = resident {
                for pr in regions.iter() {
                    let workers = workers_for(*threads, pr.region.len, LOAD_SHARD_WORDS);
                    total += shared
                        .disturb_region(pr, model, workers, stats)?
                        .iter()
                        .sum::<u64>();
                }
            }
        }
        Ok(total)
    }

    /// Free extents right now (diagnostic).
    pub fn free_extents(&self) -> usize {
        self.inner.lock().unwrap().shared.free_extents()
    }

    /// Allocation granularity in words (after bank-alignment rounding).
    pub fn extent_words(&self) -> usize {
        self.inner.lock().unwrap().shared.extent_words()
    }
}

impl PoolInner {
    fn idx(&self, name: &str) -> Result<usize> {
        self.index
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("unknown pool model {name:?} ({} admitted)", self.tenants.len()))
    }

    fn touch(&mut self, idx: usize) {
        self.clock += 1;
        self.tenants[idx].last_served = self.clock;
    }

    /// Rebuild `idx` if evicted; returns whether a rebuild ran.
    fn make_resident(&mut self, idx: usize) -> Result<bool> {
        if self.tenants[idx].resident.is_some() {
            return Ok(false);
        }
        self.build_tenant(idx)
            .with_context(|| format!("rebuilding model {:?}", self.tenants[idx].name))?;
        self.rebuilds += 1;
        Ok(true)
    }

    /// (Re)build tenant `idx` from its clean encodings: reset its stats,
    /// replay its seed stream, store every tensor (evicting under
    /// pressure), then materialize every tensor — the deterministic
    /// recipe that makes rebuilds bit-identical to a fresh store.
    fn build_tenant(&mut self, idx: usize) -> Result<()> {
        debug_assert!(self.tenants[idx].resident.is_none());
        self.tenants[idx].stats = AccessStats::default();
        let mut rng = Xoshiro256::seeded(self.tenants[idx].seed);
        let mut regions: Vec<PoolRegion> = Vec::with_capacity(self.tenants[idx].clean.len());

        for t in 0..self.tenants[idx].clean.len() {
            loop {
                // Split-borrow dance: the tenant and the shared buffer are
                // both fields of self, so take the tenant entry apart.
                let (tenant, shared) = {
                    let PoolInner { tenants, shared, .. } = self;
                    (&mut tenants[idx], shared)
                };
                let workers = workers_for(tenant.threads, tenant.clean[t].len(), STORE_SHARD_WORDS);
                match shared.alloc_store(
                    &tenant.clean[t],
                    &tenant.model,
                    &mut rng,
                    workers,
                    &mut tenant.stats,
                ) {
                    Ok(r) => {
                        regions.push(r);
                        break;
                    }
                    Err(BufferError::CapacityExceeded { requested, free }) => {
                        if self.evict == EvictPolicy::Deny || !self.evict_someone(idx) {
                            for r in &regions {
                                self.shared.free(r);
                            }
                            self.tenants[idx].stats = AccessStats::default();
                            bail!(
                                "pool capacity exceeded ({requested} words requested, {free} \
                                 free, evict={:?}) storing tensor {}",
                                self.evict,
                                self.tenants[idx].specs[t].0
                            );
                        }
                        // Retry the same tensor: the failed attempt drew
                        // no RNG state and billed nothing.
                    }
                    Err(e) => {
                        for r in &regions {
                            self.shared.free(r);
                        }
                        self.tenants[idx].stats = AccessStats::default();
                        return Err(e.into());
                    }
                }
            }
        }

        // Materialize in store order (the read half of a fresh build).
        let mut tensors = Vec::with_capacity(regions.len());
        for (t, r) in regions.iter().enumerate() {
            let (tenant, shared) = {
                let PoolInner { tenants, shared, .. } = self;
                (&mut tenants[idx], shared)
            };
            let workers = workers_for(tenant.threads, r.region.len, LOAD_SHARD_WORDS);
            let mut data = Vec::new();
            shared
                .load_decoded(r, &mut data, workers, &mut tenant.stats)
                .map_err(anyhow::Error::from)
                .with_context(|| format!("materializing tensor {}", tenant.specs[t].0))?;
            let (name, shape) = tenant.specs[t].clone();
            tensors.push(ParamSpec { name, shape, data });
        }

        let tenant = &mut self.tenants[idx];
        tenant.resident = Some(regions);
        tenant.tensors = tensors;
        tenant.builds += 1;
        Ok(())
    }

    /// Evict the least-recently-served resident tenant other than
    /// `requester`; false when no one is evictable.
    fn evict_someone(&mut self, requester: usize) -> bool {
        let victim = self
            .tenants
            .iter()
            .enumerate()
            .filter(|(i, t)| *i != requester && t.resident.is_some())
            .min_by_key(|(_, t)| t.last_served)
            .map(|(i, _)| i);
        match victim {
            Some(v) => {
                if let Some(regions) = self.tenants[v].resident.take() {
                    for r in &regions {
                        self.shared.free(r);
                    }
                }
                // The decoded copies leave with the regions: the victim
                // rebuilds from its clean encodings on its next request.
                self.tenants[v].tensors = Vec::new();
                self.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// One scrub pass over every resident tenant's regions, folding the
    /// per-pass telemetry into the estimator and lifetime counters. Runs
    /// under the caller's pool lock (never racing a rebuild) and draws no
    /// RNG, so tenant fault streams are untouched.
    fn scrub_pass(&mut self) -> Result<()> {
        let PoolInner { tenants, shared, scrub, .. } = self;
        for tenant in tenants.iter_mut() {
            let Tenant { resident, clean, golden, stats, .. } = tenant;
            let Some(regions) = resident else { continue };
            for (t, pr) in regions.iter().enumerate() {
                let enc = &clean[t];
                let prot = protection_for(enc.policy, enc.granularity);
                let pass = shared.scrub_region(pr, &enc.words, &golden[t], prot.as_ref(), stats)?;
                scrub.estimator.observe(&pass);
                scrub.scrubbed_words += pass.scrubbed_words;
                scrub.corrected_words += pass.corrected_words;
                scrub.corrected_cells += pass.corrected_cells;
                scrub.policy_detected += pass.policy_detected;
                scrub.dirty_shards += pass.dirty_shards;
            }
        }
        self.scrub.passes += 1;
        Ok(())
    }

    /// Run a scheduled scrub pass if one is due. Called from the lease
    /// path under the pool lock; with [`ScrubPolicy::Off`] this returns
    /// before touching the clock, keeping the off path byte-for-byte the
    /// pre-subsystem behavior.
    fn maybe_scrub(&mut self) -> Result<()> {
        if self.scrub.policy.is_off() {
            return Ok(());
        }
        let interval = self
            .scrub
            .policy
            .interval(self.scrub.estimator.observed_rate(), self.max_sse_per_weight())
            .expect("non-off policy always has an interval");
        let due = match self.scrub.last {
            None => true,
            Some(t) => t.elapsed() >= interval,
        };
        if due {
            self.scrub_pass()?;
            self.scrub.last = Some(Instant::now());
        }
        Ok(())
    }

    /// Worst admit-time E[SSE]-per-weight estimate among tenants — the
    /// adaptive scheduler's second decay signal.
    fn max_sse_per_weight(&self) -> f64 {
        self.tenants
            .iter()
            .map(|t| t.sse_per_weight)
            .fold(0.0, f64::max)
    }

    fn scrub_telemetry(&self) -> ScrubTelemetry {
        let s = &self.scrub;
        let max_sse = self.max_sse_per_weight();
        ScrubTelemetry {
            policy: s.policy.label(),
            passes: s.passes,
            scrubbed_words: s.scrubbed_words,
            corrected_words: s.corrected_words,
            corrected_cells: s.corrected_cells,
            policy_detected: s.policy_detected,
            dirty_shards: s.dirty_shards,
            observed_rate: s.estimator.observed_rate(),
            bank_rates: s.estimator.bank_rates(),
            max_sse_per_weight: max_sse,
            interval: s.policy.interval(s.estimator.observed_rate(), max_sse),
        }
    }

    fn report_of(&self, idx: usize) -> StoreReport {
        let t = &self.tenants[idx];
        StoreReport {
            tensors: t.clean.len(),
            weights: t.weights,
            write_energy: t.stats.write_energy,
            read_energy: t.stats.read_energy,
            injected_faults: t.stats.injected_faults,
            metadata_overhead: t.metadata_overhead,
            soft_cells_stored: t.soft_cells,
        }
    }
}

/// One model's serving handle on a [`BufferPool`]: everything an engine
/// needs to survive eviction — residency checks, LRU touches, and
/// atomic rebuild-plus-reconstruct.
#[derive(Clone)]
pub struct ModelLease {
    pool: BufferPool,
    name: String,
}

impl ModelLease {
    /// The leased model's name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Atomically (under one pool lock): rebuild the model if it was
    /// evicted, stamp the LRU clock, and — only when a rebuild ran —
    /// reconstruct the engine from the fresh tensors. `None` means the
    /// model was still resident and the caller's engine is still good
    /// (rebuilt tensors are bit-identical, so "still good" is exact, not
    /// approximate).
    pub fn rebuild_with<C, B>(&self, build: &mut B) -> Result<Option<C>>
    where
        B: FnMut(&[ParamSpec]) -> Result<C>,
    {
        let mut inner = self.pool.inner.lock().unwrap();
        let idx = inner.idx(&self.name)?;
        let rebuilt = inner.make_resident(idx)?;
        inner.touch(idx);
        inner.maybe_scrub()?;
        if rebuilt {
            Ok(Some(build(&inner.tenants[idx].tensors)?))
        } else {
            Ok(None)
        }
    }

    /// Build an engine from the model's current tensors (rebuilding
    /// first if evicted), under one pool lock.
    pub fn build_engine<C, B>(&self, build: &mut B) -> Result<C>
    where
        B: FnMut(&[ParamSpec]) -> Result<C>,
    {
        let mut inner = self.pool.inner.lock().unwrap();
        let idx = inner.idx(&self.name)?;
        inner.make_resident(idx)?;
        inner.touch(idx);
        inner.maybe_scrub()?;
        build(&inner.tenants[idx].tensors)
    }

    /// This model's current [`StoreReport`].
    pub fn report(&self) -> Result<StoreReport> {
        self.pool.report(&self.name)
    }
}

/// A [`BatchClassifier`] whose weights live in a shared [`BufferPool`]:
/// if the model was evicted since the last batch, `classify_batch`
/// transparently rebuilds the region (bit-identical weights + bills) and
/// reconstructs the inner engine before classifying — the
/// evict→rematerialize stall the serving report counts as
/// [`crate::coordinator::ServerReport::rebuilds`].
///
/// Interior mutability (`RefCell`/`Cell`) because [`BatchClassifier`]
/// classifies through `&self` and the engine lives pinned inside one
/// worker thread (the factory pattern of [`crate::coordinator::Server`]).
pub struct PooledEngine<C, B>
where
    C: BatchClassifier,
    B: FnMut(&[ParamSpec]) -> Result<C>,
{
    lease: ModelLease,
    build: std::cell::RefCell<B>,
    engine: std::cell::RefCell<C>,
    rebuilds: std::cell::Cell<u64>,
}

impl<C, B> PooledEngine<C, B>
where
    C: BatchClassifier,
    B: FnMut(&[ParamSpec]) -> Result<C>,
{
    /// Construct the engine from the leased model's tensors (rebuilding
    /// them first if the model was evicted between admit and serve).
    pub fn new(lease: ModelLease, mut build: B) -> Result<Self> {
        let engine = lease.build_engine(&mut build)?;
        Ok(PooledEngine {
            lease,
            build: std::cell::RefCell::new(build),
            engine: std::cell::RefCell::new(engine),
            rebuilds: std::cell::Cell::new(0),
        })
    }
}

impl<C, B> BatchClassifier for PooledEngine<C, B>
where
    C: BatchClassifier,
    B: FnMut(&[ParamSpec]) -> Result<C>,
{
    fn batch_size(&self) -> usize {
        self.engine.borrow().batch_size()
    }

    fn image_elems(&self) -> usize {
        self.engine.borrow().image_elems()
    }

    fn classify_batch(&self, images: &[f32]) -> Result<Vec<usize>> {
        if let Some(fresh) = self.lease.rebuild_with(&mut *self.build.borrow_mut())? {
            *self.engine.borrow_mut() = fresh;
            self.rebuilds.set(self.rebuilds.get() + 1);
        }
        self.engine.borrow().classify_batch(images)
    }

    fn rebuilds(&self) -> u64 {
        self.rebuilds.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp;

    fn weight_file(n: usize, scale: f32) -> WeightFile {
        let data: Vec<f32> = (0..n)
            .map(|i| fp::quantize_f16(((i as f32 / n as f32) * 1.6 - 0.8) * scale))
            .collect();
        WeightFile {
            params: vec![ParamSpec {
                name: "w".into(),
                shape: vec![n],
                data,
            }],
        }
    }

    fn cfg(seed: u64) -> StoreConfig {
        StoreConfig {
            error_model: ErrorModel::at_rate(0.0),
            seed,
            ..StoreConfig::default()
        }
    }

    #[test]
    fn admit_then_report_matches_private_store() {
        // One tenant, no pressure: the pool report must equal a private
        // WeightStore at the same recipe + the pool's bank count.
        let wf = weight_file(4096, 1.0);
        let pool = BufferPool::new(8192 * 2, 16, 256, EvictPolicy::Lru);
        let rep = pool.admit("m", &cfg(3), &wf).unwrap();

        let mut fresh = crate::coordinator::WeightStore::load(&cfg(3), &wf).unwrap();
        let want_tensors = fresh.materialize().unwrap();
        let want = fresh.report();
        assert_eq!(rep.write_energy, want.write_energy);
        assert_eq!(rep.read_energy, want.read_energy);
        assert_eq!(rep.injected_faults, want.injected_faults);
        assert_eq!(rep.weights, want.weights);
        assert_eq!(pool.tensors("m").unwrap()[0].data, want_tensors[0].data);
    }

    #[test]
    fn deny_policy_refuses_instead_of_evicting() {
        let wf = weight_file(4096, 1.0);
        let pool = BufferPool::new(4096 * 2, 16, 256, EvictPolicy::Deny);
        pool.admit("a", &cfg(1), &wf).unwrap();
        let err = pool.admit("b", &cfg(2), &wf).unwrap_err();
        assert!(format!("{err:#}").contains("evict=Deny"), "{err:#}");
        // The failed admit left no tenant behind.
        assert!(pool.report("b").is_err());
        assert!(pool.resident("a").unwrap());
    }

    #[test]
    fn remove_frees_regions_and_keeps_index_consistent() {
        let wf = weight_file(1024, 1.0);
        let pool = BufferPool::new(8192 * 8, 16, 256, EvictPolicy::Lru);
        pool.admit("a", &cfg(1), &wf).unwrap();
        pool.admit("b", &cfg(2), &wf).unwrap();
        pool.admit("c", &cfg(3), &wf).unwrap();
        let free_before = pool.free_extents();

        assert!(pool.contains("a"));
        pool.remove("a").unwrap();
        assert!(!pool.contains("a"));
        assert!(pool.free_extents() > free_before, "regions returned");
        assert!(pool.remove("a").is_err(), "double remove is an error");

        // swap_remove moved "c" into a's slot: both survivors still
        // resolve and serve.
        assert!(pool.resident("b").unwrap());
        assert!(pool.resident("c").unwrap());
        pool.report("b").unwrap();
        pool.report("c").unwrap();

        // The freed name can be admitted again (redelivery).
        pool.admit("a", &cfg(4), &wf).unwrap();
        assert!(pool.contains("a"));
    }

    #[test]
    fn scrub_pass_repairs_disturbed_tenants_and_feeds_telemetry() {
        let wf = weight_file(4096, 1.0);
        let pool = BufferPool::new(8192 * 2, 16, 256, EvictPolicy::Lru);
        pool.admit("m", &cfg(3), &wf).unwrap();

        let flipped = pool.disturb(&ErrorModel::at_rate(0.5)).unwrap();
        assert!(flipped > 0, "hot disturb must flip something");

        let t = pool.scrub_pass().unwrap();
        assert_eq!(t.passes, 1);
        assert!(t.corrected_words > 0 && t.dirty_shards > 0);
        assert!(t.corrected_cells >= t.corrected_words);
        assert!(t.observed_rate > 0.0);
        assert_eq!(t.bank_rates.len(), 16);
        assert_eq!(pool.rebuilds(), 0, "repair is in place, not a rebuild");

        // The repair restored the golden image: a second pass scans the
        // same words but finds nothing left to correct.
        let t2 = pool.scrub_pass().unwrap();
        assert_eq!(t2.passes, 2);
        assert_eq!(t2.scrubbed_words, 2 * t.scrubbed_words);
        assert_eq!(t2.corrected_words, t.corrected_words);
        assert_eq!(t2.dirty_shards, t.dirty_shards);

        // The scheduler tightens under the observed decay.
        pool.set_scrub(ScrubPolicy::Adaptive {
            base: std::time::Duration::from_millis(1000),
            threshold: 0.05,
        });
        let t3 = pool.scrub_telemetry();
        assert_eq!(t3.policy, "adaptive");
        assert!(t3.interval.unwrap() < std::time::Duration::from_millis(1000));
    }

    #[test]
    fn lru_eviction_prefers_least_recently_served() {
        // Pool fits exactly one model; admitting b evicts a; serving a
        // rebuilds it (and evicts b).
        let wf = weight_file(4096, 1.0);
        let pool = BufferPool::new(4096 * 2, 16, 256, EvictPolicy::Lru);
        pool.admit("a", &cfg(1), &wf).unwrap();
        pool.admit("b", &cfg(2), &wf).unwrap();
        assert!(!pool.resident("a").unwrap());
        assert!(pool.resident("b").unwrap());
        assert_eq!(pool.evictions(), 1);
        assert_eq!(pool.rebuilds(), 0, "admits are not rebuilds");

        assert!(pool.ensure_resident("a").unwrap());
        assert!(pool.resident("a").unwrap());
        assert!(!pool.resident("b").unwrap());
        assert_eq!(pool.rebuilds(), 1);
        assert_eq!(pool.evictions(), 2);
    }
}
