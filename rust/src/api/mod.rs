//! The public facade: one layered configuration, one deployment
//! lifecycle, one multi-model serving registry (DESIGN.md §10).
//!
//! * [`env`] — the single place `MLCSTT_*` environment variables are
//!   read and parsed (re-exported from `util::env`, which sits below the
//!   foundation modules that consume it);
//! * [`Config`] — layered resolution (builder → env → default) with the
//!   legacy [`crate::coordinator::ServerConfig`] /
//!   [`crate::coordinator::StoreConfig`] structs as views;
//! * [`Deployment`] — a builder owning the encode → MLC store → fault →
//!   materialize → engine lifecycle every entry point used to hand-roll;
//! * [`ModelRegistry`] — N named deployments served from N thread-pinned
//!   workers with per-model request routing and report sections;
//! * [`BufferPool`] — one shared multi-tenant MLC buffer (extent
//!   allocator, LRU eviction, wear-leveled placement) behind leases whose
//!   [`PooledEngine`]s rebuild evicted models bit-identically on demand
//!   (DESIGN.md §12);
//! * [`deliver`] — zero-downtime weight delivery: a streamed,
//!   hash-verified [`DeploymentManifest`] rollout with bounded seeded
//!   retry/backoff, canary gating, and atomic hot swap or rollback
//!   (DESIGN.md §14);
//! * [`ScrubPolicy`] — background scrubbing of pooled tenants: golden
//!   checksums detect retention damage between leases, repairs replay
//!   the store path bit-identically, and the per-bank
//!   corrected-flip EWMA drives the adaptive scheduler (DESIGN.md §15).
//!
//! Every rebuilt path is pinned bit-identical to its pre-facade
//! hand-rolled equivalent (flip sets, energy reports, accuracies) by
//! `rust/tests/api_facade.rs`.

pub use crate::util::env;

mod config;
mod delivery;
mod deployment;
mod pool;
mod registry;

pub use config::{Config, ConfigBuilder};
pub use delivery::{
    chunk_checksum, deliver, CanaryCheck, ChaosStream, DeliveryError, DeliveryReport,
    DeploymentManifest, MemoryStream, WeightStream, DEFAULT_CANARY_BATCHES,
    DEFAULT_DELIVERY_BACKOFF, DEFAULT_DELIVERY_RETRIES,
};
pub use deployment::{Deployment, DeploymentBuilder};
pub use pool::{
    BufferPool, EvictPolicy, ModelLease, PooledEngine, DEFAULT_POOL_BANKS, DEFAULT_POOL_EXTENT,
};
pub use registry::{ModelRegistry, RegistryReport};

pub use crate::scrub::{ScrubMode, ScrubPolicy, ScrubTelemetry};
