//! The deployment lifecycle — encode → MLC store → (faults) →
//! materialize → engine — behind one builder.
//!
//! Before the facade every entry point hand-rolled this sequence:
//! `mlcstt serve`, `serve_e2e`, `load_test`, and both experiment drivers
//! each wired `StoreConfig` → [`WeightStore::load`] → `materialize` →
//! engine factory by hand. [`Deployment::builder`] owns it now; the old
//! paths are rebuilt on it and `rust/tests/api_facade.rs` pins the
//! rebuilt paths bit-identical to the hand-rolled ones (flip sets,
//! energy reports, accuracies).
//!
//! ```no_run
//! use mlcstt::api::{Config, Deployment};
//! use mlcstt::stt::ErrorModel;
//!
//! # fn main() -> anyhow::Result<()> {
//! let dep = Deployment::builder()
//!     .config(Config::from_env())
//!     .model("vggmini")
//!     .error_model(ErrorModel::at_rate(0.015))
//!     .build()?;
//! println!("{} faulted cells", dep.store_report().injected_faults);
//! let factory = dep.engine_factory()?; // feed to Server / ModelRegistry
//! # let _ = factory;
//! # Ok(())
//! # }
//! ```

use std::borrow::Cow;
use std::path::PathBuf;

use anyhow::{anyhow, bail, ensure, Result};

use crate::coordinator::{
    CleanMaterialize, InferenceEngine, StoreConfig, StoreReport, StoreSnapshot, WeightStore,
};
use crate::encoding::Policy;
use crate::experiments::load_model;
use crate::runtime::artifacts::{model_paths, Manifest, ParamSpec, WeightFile};
use crate::runtime::Executor;
use crate::stt::ErrorModel;

use super::Config;

/// A model deployed behind the simulated MLC STT-RAM buffer: the loaded
/// [`WeightStore`], the materialized (possibly fault-corrupted) tensors,
/// and — when built from trained artifacts — the manifest + HLO needed to
/// bind a PJRT engine. Build with [`Deployment::builder`].
pub struct Deployment {
    name: String,
    manifest: Option<Manifest>,
    hlo: Option<PathBuf>,
    store: WeightStore,
    tensors: Vec<ParamSpec>,
    report: StoreReport,
}

impl Deployment {
    /// Start building a deployment.
    pub fn builder<'w>() -> DeploymentBuilder<'w> {
        DeploymentBuilder::default()
    }

    /// Deployment name: the artifact model name, or the builder override.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The materialized tensors (empty until the first materialize when
    /// built with [`DeploymentBuilder::staged`]).
    pub fn tensors(&self) -> &[ParamSpec] {
        &self.tensors
    }

    /// Store accounting as of the last (re)materialize.
    pub fn store_report(&self) -> &StoreReport {
        &self.report
    }

    /// Endurance wear absorbed storing this deployment's weights
    /// (delegates to [`WeightStore::wear`]): the stress mix of the
    /// store's write traffic, for lifetime projections.
    pub fn wear(&self) -> &crate::stt::WearTracker {
        self.store.wear()
    }

    /// The protection policy the weights are stored under.
    pub fn policy(&self) -> Policy {
        self.store.policy()
    }

    /// The artifact manifest, when built from trained artifacts.
    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// Capture the stored image + accounting for a sweep campaign
    /// (delegates to [`WeightStore::snapshot`]; DESIGN.md §9).
    pub fn snapshot(&self) -> StoreSnapshot {
        self.store.snapshot()
    }

    /// Rewind to `snap` and re-inject faults at `model`'s rate under
    /// `seed` (delegates to [`WeightStore::reinject`]). The in-memory
    /// tensors go stale until the next materialize. Returns words
    /// corrupted.
    pub fn reinject(&mut self, snap: &StoreSnapshot, model: &ErrorModel, seed: u64) -> Result<u64> {
        self.store.reinject(snap, model, seed)
    }

    /// Read every tensor back through the buffer (bills read energy) and
    /// refresh [`Self::tensors`] / [`Self::store_report`].
    pub fn materialize(&mut self) -> Result<&[ParamSpec]> {
        self.tensors = self.store.materialize()?;
        self.report = self.store.report();
        Ok(&self.tensors)
    }

    /// Capture a clean-materialize cache for the flip-set-aware sweep
    /// (delegates to [`WeightStore::materialize_clean_cache`]; call on the
    /// clean store right after [`Self::snapshot`]). Does not refresh
    /// [`Self::tensors`] — the capture belongs to the sweep, not to this
    /// deployment's serving state.
    pub fn materialize_clean_cache(&mut self) -> Result<CleanMaterialize> {
        self.store.materialize_clean_cache()
    }

    /// Flip-set-aware materialize (delegates to
    /// [`WeightStore::materialize_reusing`]): zero-flip regions reuse the
    /// cached clean decode + replayed bill, bit-identical to
    /// [`Self::materialize`]. Refreshes tensors and report.
    pub fn materialize_reusing(&mut self, cache: &CleanMaterialize) -> Result<&[ParamSpec]> {
        self.tensors = self.store.materialize_reusing(cache)?;
        self.report = self.store.report();
        Ok(&self.tensors)
    }

    /// A `Send` factory that builds this deployment's PJRT
    /// [`InferenceEngine`] **inside** the serving worker thread (the
    /// thread-pinned-FFI pattern [`crate::coordinator::Server::start`]
    /// requires). Needs trained artifacts (manifest + HLO) and a
    /// materialized tensor set.
    pub fn engine_factory(
        &self,
    ) -> Result<impl FnOnce() -> Result<InferenceEngine> + Send + 'static> {
        let manifest = self
            .manifest
            .clone()
            .ok_or_else(|| anyhow!("deployment {:?} has no artifact manifest", self.name))?;
        let hlo = self
            .hlo
            .clone()
            .ok_or_else(|| anyhow!("deployment {:?} has no HLO artifact", self.name))?;
        ensure!(
            !self.tensors.is_empty(),
            "deployment {:?} is staged: call materialize() before serving",
            self.name
        );
        let tensors = self.tensors.clone();
        Ok(move || {
            let exec = Executor::from_hlo_file(&hlo)?;
            InferenceEngine::new(exec, manifest, &tensors)
        })
    }

    /// Build the PJRT engine on the **current** thread (experiment loops
    /// that restage tensors into one pinned executor use
    /// [`Self::engine_factory`] + [`InferenceEngine::restage`] instead).
    pub fn engine(&self) -> Result<InferenceEngine> {
        self.engine_factory()?()
    }
}

/// Builder for [`Deployment`]. Field defaults mirror
/// [`StoreConfig::default`] (hybrid policy, granularity 4, paper error
/// rate, 16 banks, fit-the-model capacity), with the codec worker cap
/// taken from the resolved [`Config`] unless a base [`StoreConfig`] or an
/// explicit [`Self::threads`] override says otherwise. The lifetime `'w`
/// is that of a borrowed weight file ([`Self::weights_ref`]) and only
/// constrains the builder, never the built [`Deployment`].
#[derive(Default)]
pub struct DeploymentBuilder<'w> {
    config: Option<Config>,
    name: Option<String>,
    model: Option<String>,
    weights: Option<Cow<'w, WeightFile>>,
    manifest: Option<Manifest>,
    hlo: Option<PathBuf>,
    base_store: Option<StoreConfig>,
    policy: Option<Policy>,
    granularity: Option<usize>,
    error_model: Option<ErrorModel>,
    seed: Option<u64>,
    banks: Option<usize>,
    capacity_bytes: Option<usize>,
    threads: Option<usize>,
    staged: bool,
}

impl<'w> DeploymentBuilder<'w> {
    /// Use this layered configuration (defaults to [`Config::from_env`]).
    pub fn config(mut self, cfg: Config) -> Self {
        self.config = Some(cfg);
        self
    }

    /// Override the deployment name (defaults to the model name, or
    /// `"in-memory"` for weight-file sources).
    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.name = Some(name.into());
        self
    }

    /// Source the weights (and manifest + HLO) from the trained artifact
    /// `model` under the config's artifact directory.
    pub fn model(mut self, model: impl Into<String>) -> Self {
        self.model = Some(model.into());
        self
    }

    /// Source the weights from an in-memory [`WeightFile`] (no engine
    /// unless [`Self::manifest`] and [`Self::hlo`] are also provided —
    /// store-only deployments are fine for sweeps and analyses).
    pub fn weights(mut self, weights: WeightFile) -> Self {
        self.weights = Some(Cow::Owned(weights));
        self
    }

    /// Like [`Self::weights`], but borrowing the weight file for the
    /// builder's lifetime — the experiment drivers build one deployment
    /// per policy over the same weights, and this keeps that loop free of
    /// per-policy deep copies (the store encodes from a borrow anyway).
    pub fn weights_ref(mut self, weights: &'w WeightFile) -> Self {
        self.weights = Some(Cow::Borrowed(weights));
        self
    }

    /// Manifest for an in-memory weight source (enables the engine path
    /// without re-reading artifacts from disk).
    pub fn manifest(mut self, manifest: Manifest) -> Self {
        self.manifest = Some(manifest);
        self
    }

    /// HLO artifact path for an in-memory weight source.
    pub fn hlo(mut self, hlo: impl Into<PathBuf>) -> Self {
        self.hlo = Some(hlo.into());
        self
    }

    /// Seed every store field from an existing [`StoreConfig`] (the
    /// migration path for pre-facade call sites; individual setters below
    /// still override on top).
    pub fn store(mut self, base: StoreConfig) -> Self {
        self.base_store = Some(base);
        self
    }

    /// Protection policy (default [`Policy::Hybrid`]).
    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Metadata granularity (default 4).
    pub fn granularity(mut self, granularity: usize) -> Self {
        self.granularity = Some(granularity);
        self
    }

    /// Fault model (default: the paper's 1.5e-2 write rate).
    pub fn error_model(mut self, model: ErrorModel) -> Self {
        self.error_model = Some(model);
        self
    }

    /// Shorthand for [`Self::error_model`] at a write rate.
    pub fn error_rate(self, rate: f64) -> Self {
        self.error_model(ErrorModel::at_rate(rate))
    }

    /// Fault-injection seed (default `0xD1CE`).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Buffer banks (default 16).
    pub fn banks(mut self, banks: usize) -> Self {
        self.banks = Some(banks);
        self
    }

    /// Buffer capacity in bytes (default: sized to fit the model).
    pub fn capacity_bytes(mut self, bytes: usize) -> Self {
        self.capacity_bytes = Some(bytes);
        self
    }

    /// Codec worker cap for this deployment's store (default: the
    /// config's resolved ceiling, or the base store's cap when
    /// [`Self::store`] was used).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Defer materialization: [`DeploymentBuilder::build`] stops after
    /// encode + store (no read billed), leaving [`Deployment::tensors`]
    /// empty until an explicit materialize. Sweep campaigns need this so
    /// the snapshot captures a read-free accounting baseline.
    pub fn staged(mut self) -> Self {
        self.staged = true;
        self
    }

    /// Load weights, encode + store them under the resolved
    /// [`StoreConfig`], and (unless [`Self::staged`]) materialize the
    /// decoded tensors — the whole pre-serving lifecycle in one place.
    pub fn build(self) -> Result<Deployment> {
        let config = self.config.unwrap_or_else(Config::from_env);
        let (default_name, weights, manifest, hlo) = match (self.model, self.weights) {
            (Some(model), None) => {
                let dir = config.artifacts_dir();
                let (manifest, weights) = load_model(dir, &model)?;
                let (hlo, _, _) = model_paths(dir, &model);
                (model, Cow::Owned(weights), Some(manifest), Some(hlo))
            }
            (None, Some(weights)) => ("in-memory".to_string(), weights, self.manifest, self.hlo),
            (Some(_), Some(_)) => bail!("set either .model() or .weights(), not both"),
            (None, None) => bail!("deployment needs a source: .model(name) or .weights(file)"),
        };
        let name = self.name.unwrap_or(default_name);

        // The no-base_store default resolves the protection policy through
        // the config layers (builder > `MLCSTT_POLICY` > hybrid); an
        // explicit `.store(...)` base or `.policy(...)` setter still wins.
        let mut sc = self.base_store.unwrap_or_else(|| config.store());
        if let Some(policy) = self.policy {
            sc.policy = policy;
        }
        if let Some(granularity) = self.granularity {
            sc.granularity = granularity;
        }
        if let Some(model) = self.error_model {
            sc.error_model = model;
        }
        if let Some(seed) = self.seed {
            sc.seed = seed;
        }
        if let Some(banks) = self.banks {
            sc.banks = banks;
        }
        if let Some(bytes) = self.capacity_bytes {
            sc.capacity_bytes = Some(bytes);
        }
        if let Some(threads) = self.threads {
            sc.threads = threads;
        }

        let mut store = WeightStore::load(&sc, weights.as_ref())?;
        let (tensors, report) = if self.staged {
            (Vec::new(), store.report())
        } else {
            let tensors = store.materialize()?;
            let report = store.report();
            (tensors, report)
        };
        Ok(Deployment {
            name,
            manifest,
            hlo,
            store,
            tensors,
            report,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp;

    fn weight_file(n: usize) -> WeightFile {
        let data: Vec<f32> = (0..n)
            .map(|i| fp::quantize_f16((i as f32 / n as f32) * 1.6 - 0.8))
            .collect();
        WeightFile {
            params: vec![ParamSpec {
                name: "w".into(),
                shape: vec![n],
                data,
            }],
        }
    }

    #[test]
    fn build_matches_hand_rolled_store_path() {
        // The broader sweep lives in tests/api_facade.rs; this pins the
        // in-crate basics: same config -> same tensors + accounting.
        let wf = weight_file(4096);
        let sc = StoreConfig {
            error_model: ErrorModel::at_rate(0.02),
            seed: 9,
            ..StoreConfig::default()
        };
        let mut store = WeightStore::load(&sc, &wf).unwrap();
        let want = store.materialize().unwrap();
        let want_report = store.report();

        let dep = Deployment::builder().weights(wf).store(sc).build().unwrap();
        assert_eq!(dep.name(), "in-memory");
        for (a, b) in want.iter().zip(dep.tensors()) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(dep.store_report().read_energy, want_report.read_energy);
        assert_eq!(dep.store_report().write_energy, want_report.write_energy);
        assert_eq!(dep.store_report().injected_faults, want_report.injected_faults);
    }

    #[test]
    fn staged_build_bills_no_read_and_refuses_to_serve() {
        let dep = Deployment::builder()
            .weights(weight_file(512))
            .error_rate(0.0)
            .staged()
            .build()
            .unwrap();
        assert!(dep.tensors().is_empty());
        assert_eq!(dep.store_report().read_energy.nanojoules, 0.0);
        assert!(dep.engine_factory().is_err());
    }

    #[test]
    fn builder_rejects_conflicting_and_missing_sources() {
        assert!(Deployment::builder().build().is_err());
        let err = Deployment::builder()
            .weights(weight_file(8))
            .model("vggmini")
            .build();
        assert!(err.is_err());
    }
}
