//! Multi-model serving: N named deployments behind one routing facade.
//!
//! [`crate::coordinator::Server`] pins one engine to one worker thread
//! (PJRT handles are not `Send`, so the engine is constructed *inside*
//! its thread from a `Send` factory). [`ModelRegistry`] extends that from
//! one pinned engine to N: each registered model gets its own pinned
//! worker + batcher, requests are routed by model tag at
//! [`ModelRegistry::submit`] (an indexed O(1) lookup), and
//! [`ModelRegistry::shutdown`] returns one [`ServerReport`] section per
//! model, in registration order.
//!
//! Routing contract (pinned by `rust/tests/api_facade.rs` and
//! `rust/tests/overload.rs`):
//!
//! * a tag addresses exactly the engine registered under it — per-model
//!   queues share no state, so one model's backlog never delays another's
//!   batcher;
//! * routing adds no randomness: for a deterministic engine the response
//!   to (tag, image) is independent of interleaving with other models'
//!   traffic;
//! * unknown tags and duplicate registrations are errors, not silent
//!   fallbacks;
//! * under a registry-wide in-flight budget ([`ModelRegistry::with_budget`],
//!   implemented by [`FairGate`]), global overload sheds only models over
//!   their fair share — a cold model keeps admitting while a hot sibling
//!   sheds (DESIGN.md §11).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::buffer::shared::BankWear;
use crate::coordinator::{
    Admission, BatchClassifier, FairGate, Server, ServerConfig, ServerReport,
};
use crate::runtime::artifacts::ParamSpec;
use crate::scrub::ScrubTelemetry;

use super::pool::{BufferPool, PooledEngine};
use super::Deployment;

/// A set of named, independently thread-pinned model servers with
/// tag-routed submission and optional cross-model fair admission.
#[derive(Default)]
pub struct ModelRegistry {
    entries: Vec<(String, Server)>,
    /// Tag → index into `entries` (registration order preserved there).
    index: HashMap<String, usize>,
    /// Cross-model admission gate, when a budget is configured.
    gate: Option<FairGate>,
    /// Shared multi-tenant weight pool, when one is attached
    /// ([`ModelRegistry::with_pool`]).
    pool: Option<BufferPool>,
    /// Drained serving reports of servers replaced by [`ModelRegistry::swap`],
    /// in retirement order — surfaced as [`RegistryReport::retired`] so a
    /// hot swap never loses the old engine's accounting.
    retired: Vec<(String, ServerReport)>,
    /// Completed hot swaps ([`ModelRegistry::swap`]).
    swaps: u64,
    /// Delivery rollbacks recorded by [`ModelRegistry::note_rollback`].
    rollbacks: u64,
    /// Chunk re-reads recorded by [`ModelRegistry::note_retries`].
    delivery_retries: u64,
    /// Live delivered version per model (absent/0 = never delivered).
    versions: HashMap<String, u64>,
}

/// Final per-model serving metrics, in registration order — the
/// multi-model counterpart of [`ServerReport`].
#[derive(Clone, Debug)]
pub struct RegistryReport {
    /// `(model name, that model's serving report)` per registered model.
    pub sections: Vec<(String, ServerReport)>,
    /// Per-bank wear of the attached [`BufferPool`] at shutdown — the
    /// "buffer lifetime under traffic" report. Empty without a pool.
    pub wear: Vec<BankWear>,
    /// Regions evicted from the pool under capacity pressure (0 without
    /// a pool).
    pub pool_evictions: u64,
    /// Background-scrub telemetry of the attached [`BufferPool`] at
    /// shutdown (DESIGN.md §15). `None` without a pool.
    pub scrub: Option<ScrubTelemetry>,
    /// Serving reports of servers retired by hot swaps
    /// ([`ModelRegistry::swap`]), in retirement order: the pre-swap
    /// engine's traffic, fully drained — hot swaps never lose accounting.
    pub retired: Vec<(String, ServerReport)>,
    /// Completed hot swaps over the registry's lifetime.
    pub swaps: u64,
    /// Delivery rollbacks (failed verifications/stagings/canaries that
    /// left the incumbent serving; [`ModelRegistry::note_rollback`]).
    pub rollbacks: u64,
    /// Chunk re-reads spent by weight deliveries
    /// ([`ModelRegistry::note_retries`]), successful or not.
    pub delivery_retries: u64,
}

impl RegistryReport {
    /// Every live and retired section, in that order — hot-swapped-out
    /// servers count toward totals so a swap never loses traffic.
    fn all_sections(&self) -> impl Iterator<Item = &(String, ServerReport)> {
        self.sections.iter().chain(self.retired.iter())
    }

    /// Requests served across all models (including swap-retired servers).
    pub fn total_served(&self) -> usize {
        self.all_sections().map(|(_, r)| r.served).sum()
    }

    /// Requests shed at admission across all models (including
    /// swap-retired servers).
    pub fn total_shed(&self) -> usize {
        self.all_sections().map(|(_, r)| r.shed).sum()
    }

    /// Requests resolved as engine errors across all models (including
    /// swap-retired servers).
    pub fn total_errors(&self) -> usize {
        self.all_sections().map(|(_, r)| r.errors).sum()
    }

    /// Evict→rematerialize stalls absorbed across all models' workers
    /// (including swap-retired servers).
    pub fn total_rebuilds(&self) -> u64 {
        self.all_sections().map(|(_, r)| r.rebuilds).sum()
    }

    /// Requests declined as typed [`crate::coordinator::RequestError::Unavailable`]
    /// across all models (live and retired sections).
    pub fn total_unavailable(&self) -> usize {
        self.all_sections().map(|(_, r)| r.unavailable).sum()
    }
}

impl ModelRegistry {
    /// An empty registry with independent per-model admission (no
    /// cross-model budget).
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty registry whose models share a registry-wide in-flight
    /// `budget`: while total in-flight stays under the budget every model
    /// admits freely; at the budget, only models below their fair share
    /// (`budget / models`, floored at 1) keep admitting. See [`FairGate`].
    pub fn with_budget(budget: usize) -> Self {
        ModelRegistry {
            gate: Some(FairGate::new(budget)),
            ..Self::default()
        }
    }

    /// Register `name` with an engine `factory` (run **inside** the new
    /// worker thread — the thread-pinned-FFI pattern of
    /// [`Server::start`]). Blocks until the engine is up; errors on a
    /// duplicate name or a factory failure.
    pub fn register<C, F>(&mut self, name: &str, factory: F, cfg: ServerConfig) -> Result<()>
    where
        C: BatchClassifier,
        F: FnOnce() -> Result<C> + Send + 'static,
    {
        if self.index.contains_key(name) {
            bail!("model {name:?} is already registered");
        }
        let server = Server::start_with_gate(factory, cfg, self.gate.clone())?;
        // Count the model only once its server is up: a failed factory
        // must not shrink the siblings' fair share forever. The gate is
        // consulted only by later submits, so the order is unobservable.
        if let Some(g) = &self.gate {
            g.add_model();
        }
        self.index.insert(name.to_string(), self.entries.len());
        self.entries.push((name.to_string(), server));
        Ok(())
    }

    /// Register a materialized [`Deployment`] under its own name, using
    /// its PJRT engine factory.
    pub fn register_deployment(&mut self, dep: &Deployment, cfg: ServerConfig) -> Result<()> {
        let name = dep.name().to_string();
        self.register(&name, dep.engine_factory()?, cfg)
    }

    /// Attach a shared multi-tenant weight pool; models registered with
    /// [`ModelRegistry::register_pooled`] serve from it and survive
    /// eviction transparently. The pool handle is cloneable, so the
    /// caller can keep one for admits and wear sampling.
    pub fn with_pool(mut self, pool: BufferPool) -> Self {
        self.pool = Some(pool);
        self
    }

    /// The attached pool, if any.
    pub fn pool(&self) -> Option<&BufferPool> {
        self.pool.as_ref()
    }

    /// Register `name` — already admitted to the attached pool — behind a
    /// [`PooledEngine`]: `build` turns the model's pooled tensors into a
    /// concrete engine, and runs again (inside the worker thread) after
    /// every eviction, on the bit-identical rebuilt tensors. The stalls
    /// are surfaced as [`ServerReport::rebuilds`].
    pub fn register_pooled<C, B>(&mut self, name: &str, build: B, cfg: ServerConfig) -> Result<()>
    where
        C: BatchClassifier,
        B: FnMut(&[ParamSpec]) -> Result<C> + Send + 'static,
    {
        let Some(pool) = &self.pool else {
            bail!("registry has no buffer pool (attach one with with_pool) for {name:?}");
        };
        let lease = pool.lease(name)?;
        self.register(name, move || PooledEngine::new(lease, build), cfg)
    }

    /// Hot-swap the engine serving `name` — the commit point of a
    /// zero-downtime delivery ([`super::deliver`], DESIGN.md §14).
    ///
    /// Ordering is the whole contract:
    ///
    /// 1. the replacement server starts first (its `factory` runs inside
    ///    the new worker thread and must come up) — any failure here
    ///    returns `Err` with the incumbent untouched and still serving;
    /// 2. only then is the incumbent parked
    ///    ([`Server::set_unavailable`], reason `"hot swap: draining"`) and
    ///    replaced in the routing table — from this instant new
    ///    submissions reach the new engine;
    /// 3. the incumbent is drained ([`Server::shutdown`] joins its worker,
    ///    resolving every admitted request) and its report is retired into
    ///    [`RegistryReport::retired`], so no traffic is dropped and no
    ///    accounting is lost.
    ///
    /// The registry is never observable half-swapped: `&mut self`
    /// excludes concurrent routing, and the table flips in one assignment
    /// between a fully-up new server and a fully-drained old one. The
    /// [`FairGate`] model count is unchanged (one server leaves, one
    /// enters).
    pub fn swap<C, F>(&mut self, name: &str, factory: F, cfg: ServerConfig) -> Result<()>
    where
        C: BatchClassifier,
        F: FnOnce() -> Result<C> + Send + 'static,
    {
        let Some(&i) = self.index.get(name) else {
            bail!("unknown model {name:?} ({} registered)", self.entries.len());
        };
        let fresh = Server::start_with_gate(factory, cfg, self.gate.clone())?;
        let old = std::mem::replace(&mut self.entries[i].1, fresh);
        old.set_unavailable(name, "hot swap: draining");
        self.retired.push((name.to_string(), old.shutdown()));
        self.swaps += 1;
        Ok(())
    }

    /// Park `name`: until [`ModelRegistry::set_available`], its requests
    /// resolve as typed
    /// [`crate::coordinator::RequestError::Unavailable`] (counted in
    /// [`ServerReport::unavailable`]). For rebuild/maintenance windows
    /// where an operator wants routing to answer honestly instead of
    /// queueing into a stalled engine.
    pub fn set_unavailable(&self, model: &str, reason: &str) -> Result<()> {
        match self.index.get(model) {
            Some(&i) => {
                self.entries[i].1.set_unavailable(model, reason);
                Ok(())
            }
            None => bail!("unknown model {model:?} ({} registered)", self.entries.len()),
        }
    }

    /// Reopen admission for `model` after [`ModelRegistry::set_unavailable`].
    pub fn set_available(&self, model: &str) -> Result<()> {
        match self.index.get(model) {
            Some(&i) => {
                self.entries[i].1.set_available();
                Ok(())
            }
            None => bail!("unknown model {model:?} ({} registered)", self.entries.len()),
        }
    }

    /// The live delivered version of `model`: what the last committed
    /// [`super::deliver`] stamped via [`ModelRegistry::set_version`], or 0
    /// for a model that has only ever served its registration-time
    /// weights. Unknown models also report 0 (version gating happens
    /// before existence checks would matter).
    pub fn version(&self, model: &str) -> u64 {
        self.versions.get(model).copied().unwrap_or(0)
    }

    /// Stamp `model`'s live version — the commit marker of a delivery.
    /// [`super::deliver`] calls this only after the swap succeeded, so a
    /// rolled-back delivery never advances the version and a stale
    /// re-offer of the same manifest fails its version gate.
    pub fn set_version(&mut self, model: &str, version: u64) {
        self.versions.insert(model.to_string(), version);
    }

    /// Record a delivery rollback (verification/staging/canary failure
    /// that left the incumbent serving) for [`RegistryReport::rollbacks`].
    pub fn note_rollback(&mut self) {
        self.rollbacks += 1;
    }

    /// Record `n` delivery chunk re-reads for
    /// [`RegistryReport::delivery_retries`].
    pub fn note_retries(&mut self, n: u64) {
        self.delivery_retries += n;
    }

    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True iff no model is registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Route one image to the model registered under `model`. The result
    /// is the same bounded-admission decision as [`Server::submit`]:
    /// [`Admission::Accepted`] with a ticket, or [`Admission::Rejected`]
    /// when that model's queue (or the registry fair-share budget) sheds
    /// it. The lookup is O(1); the unknown-tag error message is built
    /// only on the error path.
    pub fn submit(&self, model: &str, image: Vec<f32>) -> Result<Admission> {
        match self.index.get(model) {
            Some(&i) => self.entries[i].1.submit(image),
            None => bail!("unknown model {model:?} ({} registered)", self.entries.len()),
        }
    }

    /// Live per-model in-flight queue depths, in registration order —
    /// the sampling hook for load monitors and the overload tests.
    pub fn queue_depths(&self) -> Vec<(&str, usize)> {
        self.entries
            .iter()
            .map(|(n, s)| (n.as_str(), s.queued()))
            .collect()
    }

    /// Registry-wide in-flight total (0 without a budget gate).
    pub fn in_flight(&self) -> usize {
        match &self.gate {
            Some(g) => g.in_flight(),
            None => self.entries.iter().map(|(_, s)| s.queued()).sum(),
        }
    }

    /// Stop every model's worker and collect the per-model report
    /// sections, in registration order, plus the pool's wear ledger when
    /// one is attached.
    pub fn shutdown(self) -> RegistryReport {
        let sections: Vec<(String, ServerReport)> = self
            .entries
            .into_iter()
            .map(|(name, server)| (name, server.shutdown()))
            .collect();
        // Sample wear only after the workers stopped, so late rebuilds
        // are in the ledger.
        let wear = self.pool.as_ref().map(BufferPool::bank_wear).unwrap_or_default();
        let pool_evictions = self.pool.as_ref().map(BufferPool::evictions).unwrap_or(0);
        let scrub = self.pool.as_ref().map(BufferPool::scrub_telemetry);
        RegistryReport {
            sections,
            wear,
            pool_evictions,
            scrub,
            retired: self.retired,
            swaps: self.swaps,
            rollbacks: self.rollbacks,
            delivery_retries: self.delivery_retries,
        }
    }
}

impl std::fmt::Display for RegistryReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let table = crate::metrics::serving_table("registry serving report", &self.sections);
        write!(f, "{table}")?;
        writeln!(
            f,
            "totals: {} served / {} shed / {} errors / {} unavailable / {} rebuilds",
            self.total_served(),
            self.total_shed(),
            self.total_errors(),
            self.total_unavailable(),
            self.total_rebuilds()
        )?;
        if !self.retired.is_empty() {
            let table = crate::metrics::serving_table("retired by hot swap", &self.retired);
            write!(f, "{table}")?;
        }
        if self.swaps + self.rollbacks + self.delivery_retries > 0 {
            writeln!(
                f,
                "delivery: {} swaps / {} rollbacks / {} chunk retries",
                self.swaps, self.rollbacks, self.delivery_retries
            )?;
        }
        if !self.wear.is_empty() {
            let wear = crate::metrics::wear_table("buffer lifetime under traffic", &self.wear);
            write!(f, "{wear}")?;
            writeln!(f, "pool evictions: {}", self.pool_evictions)?;
        }
        if let Some(s) = &self.scrub {
            if s.passes > 0 || s.policy != "off" {
                let t = crate::metrics::scrub_table("background scrub", s);
                write!(f, "{t}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::LinearEngine;
    use std::time::Duration;

    fn cfg() -> ServerConfig {
        ServerConfig {
            max_wait: Duration::from_millis(1),
            codec_threads: 1,
            ..ServerConfig::default()
        }
    }

    fn engine_a() -> Result<LinearEngine> {
        // Class 0 likes +x, class 1 likes -x.
        LinearEngine::new(2, 2, 2, vec![1.0, 0.0, -1.0, 0.0])
    }

    fn engine_b() -> Result<LinearEngine> {
        // Swapped: class 0 likes -x.
        LinearEngine::new(2, 2, 2, vec![-1.0, 0.0, 1.0, 0.0])
    }

    #[test]
    fn routes_by_tag_and_reports_per_model() {
        let mut reg = ModelRegistry::new();
        reg.register("a", engine_a, cfg()).unwrap();
        reg.register("b", engine_b, cfg()).unwrap();
        assert_eq!(reg.models(), vec!["a", "b"]);
        assert_eq!(reg.len(), 2);

        let img = vec![1.0f32, 0.0];
        let ta = reg.submit("a", img.clone()).unwrap().ticket().unwrap();
        let tb = reg.submit("b", img.clone()).unwrap().ticket().unwrap();
        assert_eq!(ta.wait().unwrap().class, 0, "model a: +x is class 0");
        assert_eq!(tb.wait().unwrap().class, 1, "model b: +x is class 1");
        assert!(reg.submit("nope", img).is_err());

        let report = reg.shutdown();
        assert_eq!(report.sections.len(), 2);
        assert_eq!(report.sections[0].0, "a");
        assert_eq!(report.sections[0].1.served, 1);
        assert_eq!(report.sections[1].1.served, 1);
        assert_eq!(report.total_served(), 2);
        assert_eq!(report.total_shed(), 0);
        assert_eq!(report.total_errors(), 0);
    }

    #[test]
    fn register_pooled_requires_a_pool() {
        let mut reg = ModelRegistry::new();
        let err = reg
            .register_pooled("m", |_t: &[ParamSpec]| engine_a(), cfg())
            .unwrap_err();
        assert!(format!("{err}").contains("no buffer pool"), "{err}");
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut reg = ModelRegistry::new();
        reg.register("m", engine_a, cfg()).unwrap();
        assert!(reg.register("m", engine_b, cfg()).is_err());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn swap_flips_routing_and_retires_the_old_report() {
        let mut reg = ModelRegistry::new();
        reg.register("m", engine_a, cfg()).unwrap();
        let img = vec![1.0f32, 0.0];
        let t = reg.submit("m", img.clone()).unwrap().ticket().unwrap();
        assert_eq!(t.wait().unwrap().class, 0, "incumbent: +x is class 0");

        reg.swap("m", engine_b, cfg()).unwrap();
        let t = reg.submit("m", img.clone()).unwrap().ticket().unwrap();
        assert_eq!(t.wait().unwrap().class, 1, "replacement: +x is class 1");
        assert!(reg.swap("ghost", engine_a, cfg()).is_err());

        let report = reg.shutdown();
        assert_eq!(report.swaps, 1);
        assert_eq!(report.retired.len(), 1);
        assert_eq!(report.retired[0].1.served, 1, "pre-swap traffic retained");
        assert_eq!(report.sections[0].1.served, 1);
        assert_eq!(report.total_served(), 2, "totals span live + retired");
    }

    #[test]
    fn parked_model_declines_with_typed_unavailability() {
        let mut reg = ModelRegistry::new();
        reg.register("m", engine_a, cfg()).unwrap();
        reg.set_unavailable("m", "rebuild in progress").unwrap();
        let err = reg
            .submit("m", vec![1.0f32, 0.0])
            .unwrap()
            .ticket()
            .unwrap()
            .wait()
            .unwrap_err();
        assert_eq!(
            err,
            crate::coordinator::RequestError::Unavailable {
                model: "m".into(),
                reason: "rebuild in progress".into(),
            }
        );
        reg.set_available("m").unwrap();
        let t = reg.submit("m", vec![1.0f32, 0.0]).unwrap().ticket().unwrap();
        assert_eq!(t.wait().unwrap().class, 0);
        let report = reg.shutdown();
        assert_eq!(report.total_unavailable(), 1);
        assert_eq!(report.total_served(), 1);
    }

    #[test]
    fn queue_depths_sample_every_model() {
        let mut reg = ModelRegistry::with_budget(16);
        reg.register("a", engine_a, cfg()).unwrap();
        reg.register("b", engine_b, cfg()).unwrap();
        let depths = reg.queue_depths();
        assert_eq!(depths.len(), 2);
        assert_eq!(depths[0].0, "a");
        let _ = reg.in_flight();
        let report = reg.shutdown();
        assert_eq!(report.total_served(), 0);
    }
}
