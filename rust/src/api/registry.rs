//! Multi-model serving: N named deployments behind one routing facade.
//!
//! [`crate::coordinator::Server`] pins one engine to one worker thread
//! (PJRT handles are not `Send`, so the engine is constructed *inside*
//! its thread from a `Send` factory). [`ModelRegistry`] extends that from
//! one pinned engine to N: each registered model gets its own pinned
//! worker + batcher, requests are routed by model tag at
//! [`ModelRegistry::submit`], and [`ModelRegistry::shutdown`] returns one
//! [`ServerReport`] section per model, in registration order.
//!
//! Routing contract (pinned by `rust/tests/api_facade.rs`):
//!
//! * a tag addresses exactly the engine registered under it — per-model
//!   queues share nothing, so one model's backlog never delays another's
//!   batcher;
//! * routing adds no randomness: for a deterministic engine the response
//!   to (tag, image) is independent of interleaving with other models'
//!   traffic;
//! * unknown tags and duplicate registrations are errors, not silent
//!   fallbacks.

use anyhow::{bail, Result};

use crate::coordinator::{BatchClassifier, Server, ServerConfig, ServerReport, Ticket};

use super::Deployment;

/// A set of named, independently thread-pinned model servers with
/// tag-routed submission.
#[derive(Default)]
pub struct ModelRegistry {
    entries: Vec<(String, Server)>,
}

/// Final per-model serving metrics, in registration order — the
/// multi-model counterpart of [`ServerReport`].
#[derive(Clone, Debug)]
pub struct RegistryReport {
    /// `(model name, that model's serving report)` per registered model.
    pub sections: Vec<(String, ServerReport)>,
}

impl ModelRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `name` with an engine `factory` (run **inside** the new
    /// worker thread — the thread-pinned-FFI pattern of
    /// [`Server::start`]). Blocks until the engine is up; errors on a
    /// duplicate name or a factory failure.
    pub fn register<C, F>(&mut self, name: &str, factory: F, cfg: ServerConfig) -> Result<()>
    where
        C: BatchClassifier,
        F: FnOnce() -> Result<C> + Send + 'static,
    {
        if self.entries.iter().any(|(n, _)| n == name) {
            bail!("model {name:?} is already registered");
        }
        let server = Server::start(factory, cfg)?;
        self.entries.push((name.to_string(), server));
        Ok(())
    }

    /// Register a materialized [`Deployment`] under its own name, using
    /// its PJRT engine factory.
    pub fn register_deployment(&mut self, dep: &Deployment, cfg: ServerConfig) -> Result<()> {
        let name = dep.name().to_string();
        self.register(&name, dep.engine_factory()?, cfg)
    }

    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True iff no model is registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Route one image to the model registered under `model`; returns the
    /// per-request [`Ticket`] exactly like [`Server::submit`].
    pub fn submit(&self, model: &str, image: Vec<f32>) -> Result<Ticket> {
        match self.entries.iter().find(|(n, _)| n == model) {
            Some((_, server)) => server.submit(image),
            None => bail!("unknown model {model:?} (registered: {:?})", self.models()),
        }
    }

    /// Stop every model's worker and collect the per-model report
    /// sections, in registration order.
    pub fn shutdown(self) -> RegistryReport {
        RegistryReport {
            sections: self
                .entries
                .into_iter()
                .map(|(name, server)| (name, server.shutdown()))
                .collect(),
        }
    }
}

impl std::fmt::Display for RegistryReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (name, r) in &self.sections {
            writeln!(
                f,
                "{name}: {} req in {} batches (fill {:.1}) | p50 {:.1} ms p99 {:.1} ms | {:.1} req/s",
                r.served, r.batches, r.mean_batch_fill, r.p50_ms, r.p99_ms, r.throughput_rps
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::LinearEngine;
    use std::time::Duration;

    fn cfg() -> ServerConfig {
        ServerConfig {
            max_wait: Duration::from_millis(1),
            codec_threads: 1,
        }
    }

    fn engine_a() -> Result<LinearEngine> {
        // Class 0 likes +x, class 1 likes -x.
        LinearEngine::new(2, 2, 2, vec![1.0, 0.0, -1.0, 0.0])
    }

    fn engine_b() -> Result<LinearEngine> {
        // Swapped: class 0 likes -x.
        LinearEngine::new(2, 2, 2, vec![-1.0, 0.0, 1.0, 0.0])
    }

    #[test]
    fn routes_by_tag_and_reports_per_model() {
        let mut reg = ModelRegistry::new();
        reg.register("a", engine_a, cfg()).unwrap();
        reg.register("b", engine_b, cfg()).unwrap();
        assert_eq!(reg.models(), vec!["a", "b"]);
        assert_eq!(reg.len(), 2);

        let img = vec![1.0f32, 0.0];
        let ta = reg.submit("a", img.clone()).unwrap();
        let tb = reg.submit("b", img.clone()).unwrap();
        assert_eq!(ta.wait().unwrap().class, 0, "model a: +x is class 0");
        assert_eq!(tb.wait().unwrap().class, 1, "model b: +x is class 1");
        assert!(reg.submit("nope", img).is_err());

        let report = reg.shutdown();
        assert_eq!(report.sections.len(), 2);
        assert_eq!(report.sections[0].0, "a");
        assert_eq!(report.sections[0].1.served, 1);
        assert_eq!(report.sections[1].1.served, 1);
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut reg = ModelRegistry::new();
        reg.register("m", engine_a, cfg()).unwrap();
        assert!(reg.register("m", engine_b, cfg()).is_err());
        assert_eq!(reg.len(), 1);
    }
}
